#!/usr/bin/env python3
"""CI perf-regression gate over the hotpath bench artifact.

Usage: bench_gate.py BASELINE CURRENT
       bench_gate.py --serve BASELINE SERVE_JSON

Default mode compares ``bitmacs_per_s`` per (kernel, precision, threads)
key in CURRENT (``BENCH_hotpath.json``) against the committed BASELINE
floors (``rust/BENCH_baseline.json``) and exits non-zero when

* a key present in both regresses more than ``tolerance`` (default 15%)
  below its baseline, or
* the active SIMD fused kernel fails to beat the scalar fused kernel at
  the same (precision, threads=1) — the whole point of the SIMD path.

The baseline may additionally carry an optional ``prologue_floors``
list of ``{"kernel", "precision", "threads",
"min_speedup_vs_reference"}`` entries gating the fused streaming
activation prologue's measured ``speedup_vs_reference`` from the
``prologue`` section of the current artifact (same tolerance). A floor
whose key this host did not produce only warns, and a baseline without
the section skips the prologue gate entirely — so floors can be
ratcheted in from real artifact runs.

``--serve`` mode gates the serving replica sweep
(``BENCH_serve.json``): the baseline may carry an optional
``serve_floors`` list of ``{"replicas": R, "throughput_rps": floor}``
entries; each is compared against the sweep point with the same replica
count (same tolerance). A floor entry may additionally name the QoS
tiers it expects the sweep point to report (``"tiers": ["exact", ...]``)
— a sweep point missing one of those tier keys warns loudly instead of
silently gating on a shrunken tier set. When the baseline has no
``serve_floors`` section the gate is a no-op that still prints the
observed sweep, so the floors can be ratcheted in later from real
artifact runs.

Prints a GitHub-flavoured markdown delta table; pipe it into
``$GITHUB_STEP_SUMMARY``. Baseline keys missing from the current run
(e.g. an AVX-512 floor on an AVX2-only runner, NEON floors on x86) only
warn: the shared runner fleet is heterogeneous.
"""

import json
import sys


def key_map(doc):
    return {
        (e["kernel"], e["precision"], e["threads"]): float(e["bitmacs_per_s"])
        for e in doc["entries"]
        if "bitmacs_per_s" in e
    }


def prologue_map(doc):
    return {
        (e["kernel"], e["precision"], int(e["threads"])): float(e["speedup_vs_reference"])
        for e in doc.get("prologue", [])
        if "speedup_vs_reference" in e
    }


def serve_gate(baseline_path, serve_path):
    with open(baseline_path) as f:
        base = json.load(f)
    with open(serve_path) as f:
        cur = json.load(f)
    tol = float(base.get("tolerance", 0.15))
    floors, floor_tiers = {}, {}
    for e in base.get("serve_floors", []):
        r = int(e["replicas"])
        floors[r] = float(e["throughput_rps"])
        floor_tiers[r] = list(e.get("tiers", []))
    entries = {int(e["replicas"]): e for e in cur.get("entries", [])}
    points = {r: float(e["throughput_rps"]) for r, e in entries.items()}

    print(f"### serve throughput gate (tolerance {tol:.0%})\n")
    print("| replicas | floor rps | current rps | delta | verdict |")
    print("|---|---|---|---|---|")
    failures, warnings = [], []
    for r in sorted(points):
        c = points[r]
        b = floors.get(r)
        if b is None:
            print(f"| {r} | — | {c:.1f} | — | no floor committed |")
            continue
        delta = c / b - 1.0
        ok = c >= b * (1.0 - tol)
        if not ok:
            failures.append(f"replicas={r}: {c:.1f} rps vs floor {b:.1f} ({delta:+.1%})")
        verdict = "ok" if ok else f"**REGRESSION >{tol:.0%}**"
        print(f"| {r} | {b:.1f} | {c:.1f} | {delta:+.1%} | {verdict} |")
        swept = {t.get("tier") for t in entries[r].get("tiers", [])}
        for name in floor_tiers.get(r, []):
            if name not in swept:
                warnings.append(
                    f"replicas={r}: baseline expects tier '{name}' in the sweep point, "
                    f"but BENCH_serve.json only reports {sorted(t for t in swept if t)}"
                )
    for r in sorted(set(floors) - set(points)):
        warnings.append(f"serve floor for replicas={r} not produced by this run")
    for w in warnings:
        print(f"\n> warning: {w}")
    if failures:
        print("\n**serve gate FAILED:**\n")
        for f_ in failures:
            print(f"- {f_}")
        return 1
    if not floors:
        print("\nno serve_floors in baseline — observational only, gate passes")
    else:
        print("\nserve gate passed: all swept replica counts within tolerance of their floors")
    return 0


def main():
    if len(sys.argv) == 4 and sys.argv[1] == "--serve":
        return serve_gate(sys.argv[2], sys.argv[3])
    if len(sys.argv) != 3:
        print(
            "usage: bench_gate.py BASELINE CURRENT | bench_gate.py --serve BASELINE SERVE_JSON",
            file=sys.stderr,
        )
        return 2
    with open(sys.argv[1]) as f:
        base = json.load(f)
    with open(sys.argv[2]) as f:
        cur = json.load(f)
    tol = float(base.get("tolerance", 0.15))
    bmap, cmap = key_map(base), key_map(cur)
    failures, warnings = [], []

    print(f"### hotpath perf gate (tolerance {tol:.0%})\n")
    dispatch = cur.get("dispatch", {})
    if dispatch:
        print(
            f"active kernel `{dispatch.get('kernel', '?')}`, "
            f"block `{dispatch.get('block_c_words', '?')}x"
            f"{dispatch.get('block_l_cols', '?')}`, "
            f"available `{dispatch.get('available', '?')}`\n"
        )
    print("| kernel | precision | threads | baseline bit-MACs/s | current | delta | verdict |")
    print("|---|---|---|---|---|---|---|")
    for key in sorted(set(bmap) | set(cmap)):
        k, p, t = key
        b, c = bmap.get(key), cmap.get(key)
        if b is None:
            print(f"| {k} | {p} | {t} | — | {c:.3g} | — | new key (no floor yet) |")
            continue
        if c is None:
            warnings.append(f"baseline key {key} not produced by this host")
            print(f"| {k} | {p} | {t} | {b:.3g} | — | — | not run on this host |")
            continue
        delta = c / b - 1.0
        ok = c >= b * (1.0 - tol)
        if not ok:
            failures.append(f"{key}: {c:.3g} vs floor {b:.3g} ({delta:+.1%})")
        verdict = "ok" if ok else f"**REGRESSION >{tol:.0%}**"
        print(f"| {k} | {p} | {t} | {b:.3g} | {c:.3g} | {delta:+.1%} | {verdict} |")

    # The selected SIMD kernel must beat the scalar fused kernel
    # single-threaded on the same precision.
    simd_keys = [
        k for k in cmap if k[0].startswith("fused-") and k[0] != "fused-scalar" and k[2] == 1
    ]
    for key in sorted(simd_keys):
        scalar_key = ("fused-scalar", key[1], 1)
        if scalar_key not in cmap:
            continue
        ratio = cmap[key] / cmap[scalar_key]
        line = f"{key[0]} over fused-scalar @ {key[1]} (1 thread): {ratio:.2f}x"
        if ratio <= 1.0:
            failures.append("SIMD kernel not faster than scalar: " + line)
        print(f"\n{line}")

    # Optional prologue floors: the fused streaming activation prologue
    # must keep its measured speedup over the retained three-pass
    # reference path.
    pfloors = {
        (e["kernel"], e["precision"], int(e["threads"])): float(e["min_speedup_vs_reference"])
        for e in base.get("prologue_floors", [])
    }
    pcur = prologue_map(cur)
    if pfloors or pcur:
        print("\n### prologue gate (fused streaming pass vs three-pass reference)\n")
        print("| kernel | precision | threads | floor speedup | current | verdict |")
        print("|---|---|---|---|---|---|")
        for key in sorted(set(pfloors) | set(pcur)):
            k, p, t = key
            floor, c = pfloors.get(key), pcur.get(key)
            if c is None:
                warnings.append(f"prologue floor {key} not produced by this host")
                print(f"| {k} | {p} | {t} | {floor:.2f}x | — | not run on this host |")
                continue
            if floor is None:
                print(f"| {k} | {p} | {t} | — | {c:.2f}x | new key (no floor yet) |")
                continue
            ok = c >= floor * (1.0 - tol)
            if not ok:
                failures.append(
                    f"prologue {key}: {c:.2f}x vs floor {floor:.2f}x "
                    f"(fused pass no longer pays for itself)"
                )
            verdict = "ok" if ok else f"**REGRESSION >{tol:.0%}**"
            print(f"| {k} | {p} | {t} | {floor:.2f}x | {c:.2f}x | {verdict} |")

    for w in warnings:
        print(f"\n> warning: {w}")
    if failures:
        print("\n**perf gate FAILED:**\n")
        for f_ in failures:
            print(f"- {f_}")
        return 1
    print("\nperf gate passed: all produced keys within tolerance of their floors")
    return 0


if __name__ == "__main__":
    sys.exit(main())
