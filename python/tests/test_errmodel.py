"""errmodel_jax (L2, AOT-lowered) vs errmodel_ref (sequential numpy) —
semantic equivalence of the undervolting error model, plus its invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref

S_BITS = 6  # small synthetic config for tests (model is generic in s_bits)
P_BINS = 4
N_NEI = 2
C_DIM = 40  # outputs in 0..40 -> 6 bits


def rand_setup(seed, seqlen=6, k=3, l=2, table_scale=0.5):
    rng = np.random.default_rng(seed)
    exact = rng.integers(0, C_DIM + 1, size=(seqlen, k, l)).astype(np.int64)
    tables = (rng.random((S_BITS, C_DIM + 1, P_BINS, 2 ** N_NEI))
              * table_scale).astype(np.float32)
    uniforms = rng.random((seqlen, k, l, S_BITS)).astype(np.float32)
    return exact, tables, uniforms


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       g_frac=st.floats(0.0, 1.0))
def test_jax_matches_numpy_ref(seed, g_frac):
    exact, tables, uniforms = rand_setup(seed)
    seqlen = exact.shape[0]
    approx = np.asarray(
        np.random.default_rng(seed + 1).random(seqlen) < g_frac)
    want = ref.errmodel_ref(exact, tables, uniforms, C_DIM, N_NEI, P_BINS,
                            plane_approx=approx)
    got = M.errmodel_jax(
        jnp.asarray(exact, dtype=jnp.int32), jnp.asarray(tables),
        jnp.asarray(uniforms), jnp.asarray(approx),
        c_dim=C_DIM, n_nei=N_NEI, p_bins=P_BINS, s_bits=S_BITS)
    np.testing.assert_array_equal(np.asarray(got, dtype=np.int64), want)


def test_zero_tables_identity():
    exact, tables, uniforms = rand_setup(3)
    got = M.errmodel_jax(
        jnp.asarray(exact, dtype=jnp.int32),
        jnp.zeros_like(jnp.asarray(tables)), jnp.asarray(uniforms),
        jnp.ones(exact.shape[0], dtype=bool),
        c_dim=C_DIM, n_nei=N_NEI, p_bins=P_BINS, s_bits=S_BITS)
    np.testing.assert_array_equal(np.asarray(got, dtype=np.int64), exact)


def test_guarded_steps_exact():
    """plane_approx=False everywhere -> exact, even with certain-flip tables."""
    exact, tables, uniforms = rand_setup(4)
    got = M.errmodel_jax(
        jnp.asarray(exact, dtype=jnp.int32),
        jnp.ones_like(jnp.asarray(tables)), jnp.asarray(uniforms),
        jnp.zeros(exact.shape[0], dtype=bool),
        c_dim=C_DIM, n_nei=N_NEI, p_bins=P_BINS, s_bits=S_BITS)
    np.testing.assert_array_equal(np.asarray(got, dtype=np.int64), exact)


def test_certain_flip_all_bits():
    """All-ones tables on approx steps flip every bit of every output."""
    exact, tables, uniforms = rand_setup(5)
    got = np.asarray(M.errmodel_jax(
        jnp.asarray(exact, dtype=jnp.int32),
        jnp.ones_like(jnp.asarray(tables)), jnp.asarray(uniforms),
        jnp.ones(exact.shape[0], dtype=bool),
        c_dim=C_DIM, n_nei=N_NEI, p_bins=P_BINS, s_bits=S_BITS),
        dtype=np.int64)
    np.testing.assert_array_equal(got, exact ^ ((1 << S_BITS) - 1))


def test_gav_schedule_properties():
    for ab, wb in [(2, 2), (3, 3), (4, 4), (8, 8), (4, 2)]:
        smax = ab + wb - 2
        # G=0: everything undervolted.
        assert all(M.gav_schedule(ab, wb, 0))
        # G=max: everything guarded.
        assert not any(M.gav_schedule(ab, wb, M.max_g(ab, wb)))
        # Monotone: larger G never unguards a step.
        prev = M.gav_schedule(ab, wb, 0)
        for g in range(1, M.max_g(ab, wb) + 1):
            cur = M.gav_schedule(ab, wb, g)
            assert all((not c) or p for p, c in zip(prev, cur))
            prev = cur
        # Guarded steps are exactly those with significance > smax - G.
        g = 2 if smax >= 2 else 1
        mask = M.gav_schedule(ab, wb, g)
        i = 0
        for bb in range(wb):
            for ba in range(ab):
                assert mask[i] == ((ba + bb) <= smax - g)
                i += 1
