"""Pallas kernel vs pure-jnp oracle — the CORE correctness signal of L1.

Hypothesis sweeps shapes and precisions; assert exact equality (integer
math carried in f32, which is exact within the asserted bound)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitserial, ref


def rand_int_matrix(rng, shape, bits):
    lo, hi = ref.quant_range(bits)
    return jnp.asarray(rng.integers(lo, hi + 1, size=shape, dtype=np.int64),
                       dtype=jnp.int32)


@settings(max_examples=12, deadline=None)
@given(
    a_bits=st.integers(2, 8),
    b_bits=st.integers(2, 8),
    c=st.sampled_from([36, 144, 288, 576]),
    l=st.sampled_from([4, 8]),
    k=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_bitserial_gemm_vs_ref(a_bits, b_bits, c, l, k, seed):
    rng = np.random.default_rng(seed)
    a = rand_int_matrix(rng, (c, l), a_bits)
    b = rand_int_matrix(rng, (k, c), b_bits)
    a_planes = ref.to_bitplanes(a, a_bits).astype(jnp.float32)
    b_planes = ref.to_bitplanes(b, b_bits).astype(jnp.float32)
    got = bitserial.bitserial_gemm(a_planes, b_planes,
                                   a_bits=a_bits, b_bits=b_bits)
    want = ref.gemm_exact(a, b)
    np.testing.assert_array_equal(np.asarray(got, dtype=np.int64),
                                  np.asarray(want, dtype=np.int64))


@settings(max_examples=10, deadline=None)
@given(
    c=st.sampled_from([72, 144, 576]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_binary_plane_vs_ref(c, seed):
    rng = np.random.default_rng(seed)
    a_plane = jnp.asarray(rng.integers(0, 2, size=(c, 8)), dtype=jnp.float32)
    b_plane = jnp.asarray(rng.integers(0, 2, size=(16, c)), dtype=jnp.float32)
    got = bitserial.binary_gemm_plane(a_plane, b_plane)
    want = ref.binary_gemm_plane(a_plane.astype(jnp.int32),
                                 b_plane.astype(jnp.int32))
    np.testing.assert_array_equal(np.asarray(got, dtype=np.int64),
                                  np.asarray(want, dtype=np.int64))
    # iPE output range invariant: 0..C
    assert float(got.min()) >= 0.0 and float(got.max()) <= c


def test_hardware_tile_shape():
    """The paper's physical tile [C,L,K]=[576,8,16] — the exact AOT shape."""
    rng = np.random.default_rng(0)
    a = rand_int_matrix(rng, (576, 8), 4)
    b = rand_int_matrix(rng, (16, 576), 4)
    a_planes = ref.to_bitplanes(a, 4).astype(jnp.float32)
    b_planes = ref.to_bitplanes(b, 4).astype(jnp.float32)
    got = bitserial.bitserial_gemm(a_planes, b_planes, a_bits=4, b_bits=4)
    np.testing.assert_array_equal(
        np.asarray(got, dtype=np.int64),
        np.asarray(ref.gemm_exact(a, b), dtype=np.int64))


def test_vmem_footprint_under_budget():
    """BlockSpec tiling must fit a TPU core's VMEM (16 MiB) with 2x
    double-buffering headroom."""
    assert bitserial.vmem_footprint_bytes(8, 8) * 2 < 16 * 1024 * 1024


@pytest.mark.parametrize("a_bits,b_bits", [(2, 2), (3, 3), (4, 4), (8, 8)])
def test_exactness_bound_holds(a_bits, b_bits):
    """int32 accumulation is exact for every supported precision."""
    c = bitserial.C_DIM
    assert c * ((1 << a_bits) - 1) * ((1 << b_bits) - 1) < (1 << 31)
