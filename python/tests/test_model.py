"""L2 model-level tests: QAT ResNet shapes/grads, fake-quant properties,
synthetic dataset sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as datagen
from compile import model as M


def test_fake_quant_grid():
    x = jnp.linspace(-1, 1, 101)
    q = M.fake_quant(x, 4, jnp.max(jnp.abs(x)))
    scale = 1.0 / 7
    codes = np.asarray(q) / scale
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-5)
    assert np.abs(codes).max() <= 7


def test_fake_quant_ste_gradient():
    f = lambda x: jnp.sum(M.fake_quant(x, 4, jnp.max(jnp.abs(x))))
    g = jax.grad(f)(jnp.asarray([0.3, -0.7, 0.9]))
    # Straight-through: gradient of identity.
    np.testing.assert_allclose(np.asarray(g), 1.0, atol=1e-5)


def test_fake_quant_high_bits_near_identity():
    x = jnp.asarray([0.123, -0.456, 0.789])
    q = M.fake_quant(x, 16, jnp.max(jnp.abs(x)))
    np.testing.assert_allclose(np.asarray(q), np.asarray(x), atol=1e-4)


@pytest.fixture(scope="module")
def small_params():
    return M.resnet18_init(jax.random.PRNGKey(0), width_mult=0.125)


def test_resnet_forward_shape(small_params):
    x = jnp.zeros((2, 32, 32, 3))
    logits = M.resnet18_apply(small_params, x, width_mult=0.125)
    assert logits.shape == (2, 10)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_resnet_quantized_forward(small_params):
    x = jnp.asarray(np.random.default_rng(0).random((2, 32, 32, 3)),
                    dtype=jnp.float32)
    for ab, wb in [(8, 8), (4, 4), (2, 2)]:
        logits = M.resnet18_apply(small_params, x, a_bits=ab, w_bits=wb,
                                  width_mult=0.125)
        assert logits.shape == (2, 10)
        assert np.all(np.isfinite(np.asarray(logits)))


def test_resnet_grad_flows(small_params):
    x = jnp.asarray(np.random.default_rng(1).random((2, 32, 32, 3)),
                    dtype=jnp.float32)
    y = jnp.asarray([1, 3])

    def loss(p):
        logits = M.resnet18_apply(p, x, a_bits=4, w_bits=4, width_mult=0.125)
        return -jnp.mean(jnp.sum(
            jax.nn.one_hot(y, 10) * jax.nn.log_softmax(logits), -1))

    grads = jax.grad(loss)(small_params)
    gnorm = sum(float(jnp.sum(g ** 2)) for k, g in grads.items()
                if k.endswith("conv1/w"))
    assert gnorm > 0, "no gradient reached the conv weights through STE"


def test_param_count_scales_with_width():
    n = lambda wm: sum(
        int(np.prod(s)) for s in M.resnet18_param_shapes(wm).values())
    assert n(0.25) < n(0.5) < n(1.0)
    # Full-width CIFAR ResNet-18 is ~11M params.
    assert 10_000_000 < n(1.0) < 13_000_000


def test_dataset_classes_and_range():
    x, y = datagen.make_dataset(40, seed=0)
    assert x.shape == (40, 32, 32, 3) and y.shape == (40,)
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert set(np.unique(y)) == set(range(10))


def test_dataset_deterministic():
    x1, y1 = datagen.make_dataset(16, seed=5)
    x2, y2 = datagen.make_dataset(16, seed=5)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_dataset_classes_distinguishable():
    """A trivial nearest-class-mean classifier must beat chance by a lot —
    otherwise the QAT benchmark can't show accuracy degradation trends."""
    xtr, ytr = datagen.make_dataset(400, seed=1)
    xev, yev = datagen.make_dataset(100, seed=2)
    means = np.stack([xtr[ytr == c].mean(0).ravel() for c in range(10)])
    feats = xev.reshape(len(xev), -1)
    pred = np.argmin(
        ((feats[:, None, :] - means[None]) ** 2).sum(-1), axis=1)
    acc = (pred == yev).mean()
    assert acc > 0.5, f"synthetic classes too hard: ncm acc={acc}"
