"""Oracle self-consistency: the bit-serial decomposition must be *exactly*
the integer GEMM, for every precision and shape. If these fail nothing else
in the repo means anything."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def rand_int_matrix(rng, shape, bits):
    lo, hi = ref.quant_range(bits)
    return jnp.asarray(rng.integers(lo, hi + 1, size=shape, dtype=np.int64),
                       dtype=jnp.int32)


@settings(max_examples=40, deadline=None)
@given(
    a_bits=st.integers(2, 8),
    b_bits=st.integers(2, 8),
    c=st.integers(1, 64),
    l=st.integers(1, 8),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_bitserial_equals_exact(a_bits, b_bits, c, l, k, seed):
    rng = np.random.default_rng(seed)
    a = rand_int_matrix(rng, (c, l), a_bits)
    b = rand_int_matrix(rng, (k, c), b_bits)
    exact = ref.gemm_exact(a, b)
    serial = ref.bitserial_gemm_ref(a, b, a_bits, b_bits)
    np.testing.assert_array_equal(np.asarray(exact), np.asarray(serial))


@settings(max_examples=30, deadline=None)
@given(bits=st.integers(2, 12), seed=st.integers(0, 2**31 - 1))
def test_bitplane_roundtrip(bits, seed):
    rng = np.random.default_rng(seed)
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    x = jnp.asarray(rng.integers(lo, hi + 1, size=(13, 7), dtype=np.int64),
                    dtype=jnp.int32)
    planes = ref.to_bitplanes(x, bits)
    back = ref.from_bitplanes(planes, bits)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(back))
    assert set(np.unique(np.asarray(planes))) <= {0, 1}


@settings(max_examples=20, deadline=None)
@given(
    a_bits=st.integers(2, 6),
    b_bits=st.integers(2, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_sequence_recombination(a_bits, b_bits, seed):
    rng = np.random.default_rng(seed)
    a = rand_int_matrix(rng, (24, 4), a_bits)
    b = rand_int_matrix(rng, (5, 24), b_bits)
    seq = ref.ipe_sequence(a, b, a_bits, b_bits)
    assert seq.shape == (a_bits * b_bits, 5, 4)
    # iPE outputs are unsigned partial popcounts in 0..C.
    assert int(seq.min()) >= 0 and int(seq.max()) <= 24
    p = ref.recombine_sequence(seq, a_bits, b_bits)
    np.testing.assert_array_equal(
        np.asarray(p), np.asarray(ref.gemm_exact(a, b)))


def test_quantize_sym_basic():
    x = jnp.asarray([[-1.0, -0.5, 0.0, 0.5, 1.0]])
    q, scale = ref.quantize_sym(x, 4)
    assert int(q.max()) == 7 and int(q.min()) == -7
    np.testing.assert_allclose(np.asarray(q) * float(scale),
                               np.asarray(x), atol=float(scale) / 2 + 1e-7)


def test_quantize_sym_zero_input():
    q, scale = ref.quantize_sym(jnp.zeros((3, 3)), 4)
    assert float(scale) > 0
    np.testing.assert_array_equal(np.asarray(q), 0)


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_quant_range_symmetric(bits):
    lo, hi = ref.quant_range(bits)
    assert lo == -hi and hi == 2 ** (bits - 1) - 1
