"""Layer-2 JAX model: GAVINA's compute graph, built on the L1 kernels.

Three build-time components live here:

1. ``bitserial_gemm_tile`` — the full mixed-precision integer GEMM of one
   GAVINA hardware tile ([C,L] x [K,C]), composed from the Pallas bit-plane
   kernel. AOT-lowered to ``artifacts/bitserial_gemm_aXwY.hlo.txt`` and
   executed from the Rust runtime.
2. ``errmodel_jax`` — the GAVINA undervolting error model (paper Listing 2)
   as a vectorized scan over the (bb, ba) step sequence, with the LUT
   calibration tables as a runtime input. Lowered to
   ``artifacts/errinject_aXwY.hlo.txt``.
3. A quantization-aware ResNet-18 (CIFAR topology, configurable width
   multiplier) used by ``train.py`` for the progressive-precision QAT of
   paper §IV-D. Only the *trained weights* ship as artifacts; inference on
   the request path runs in Rust.

Python never runs at serving time: everything here exists to produce
``artifacts/``.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from compile.kernels import bitserial, ref

# ---------------------------------------------------------------------------
# GAV schedule (paper Fig. 2)
# ---------------------------------------------------------------------------


def gav_schedule(a_bits: int, b_bits: int, g: int) -> list[bool]:
    """Per-step undervolting mask under the two-level GAV policy.

    Step order is the controller's (bb outer, ba inner). A step computing
    significance s = ba + bb is *guarded* (V_guard, exact) iff
    ``s > s_max - g`` where ``s_max = a_bits + b_bits - 2``; otherwise it is
    *approximate* (V_aprox, undervolted). g=0 undervolts everything,
    g = s_max + 1 guards everything. Returns True where undervolted.
    """
    s_max = a_bits + b_bits - 2
    assert 0 <= g <= s_max + 1, f"G out of range: {g}"
    mask = []
    for bb in range(b_bits):
        for ba in range(a_bits):
            mask.append((ba + bb) <= s_max - g)
    return mask


def max_g(a_bits: int, b_bits: int) -> int:
    """Largest meaningful G (everything guarded)."""
    return a_bits + b_bits - 1


# ---------------------------------------------------------------------------
# (1) Bit-serial GEMM of one hardware tile
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("a_bits", "b_bits"))
def bitserial_gemm_tile(a_planes: jnp.ndarray, b_planes: jnp.ndarray, *,
                        a_bits: int, b_bits: int) -> jnp.ndarray:
    """Exact integer GEMM of one GAVINA tile from bit-planes (f32 {0,1}).

    Thin alias over the L1 kernel so the AOT entry point and the tests have
    a single name to target.
    """
    return bitserial.bitserial_gemm(a_planes, b_planes,
                                    a_bits=a_bits, b_bits=b_bits)


# ---------------------------------------------------------------------------
# (2) Undervolting error model (Listing 2), vectorized
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("c_dim", "n_nei", "p_bins", "s_bits"))
def errmodel_jax(exact_seq: jnp.ndarray,  # [T, K, L] int32, values 0..C
                 tables: jnp.ndarray,     # [s_bits, C+1, p_bins, 2^n_nei] f32
                 uniforms: jnp.ndarray,   # [T, K, L, s_bits] f32 U(0,1)
                 plane_approx: jnp.ndarray,  # [T] bool
                 *, c_dim: int, n_nei: int, p_bins: int, s_bits: int
                 ) -> jnp.ndarray:
    """Sample undervolting bit-errors onto an exact iPE output sequence.

    Semantics identical to ``ref.errmodel_ref`` (checked in pytest): scan
    over the step sequence carrying the previous exact output; per step,
    walk bits MSB->LSB, look up the flip probability from the 4-D LUT
    (bit, exact value, previous-value bin, neighbour condition), draw the
    flip, and XOR the accumulated mask onto the exact value. Guarded steps
    pass through exactly.
    """

    def step(prev, inp):
        exact, u, approx = inp
        pbin = jnp.minimum((prev * p_bins) // (c_dim + 1), p_bins - 1)
        bit_err: list[Any] = [None] * s_bits
        err_mask = jnp.zeros_like(exact)
        for bit in range(s_bits - 1, -1, -1):
            cond = jnp.zeros_like(exact)
            for j in range(1, n_nei + 1):
                if bit + j < s_bits:
                    cond = cond | (bit_err[bit + j] << (j - 1))
            prob = tables[bit][exact, pbin, cond]
            flip = (u[..., bit] < prob).astype(jnp.int32)
            bit_err[bit] = flip
            err_mask = err_mask | (flip << bit)
        out = jnp.where(approx, exact ^ err_mask, exact)
        return exact, out

    _, outs = jax.lax.scan(
        step, jnp.zeros_like(exact_seq[0]),
        (exact_seq, uniforms, plane_approx))
    return outs


@functools.partial(
    jax.jit, static_argnames=("a_bits", "b_bits", "c_dim", "n_nei",
                              "p_bins", "s_bits"))
def gav_gemm_tile(a_planes: jnp.ndarray, b_planes: jnp.ndarray,
                  tables: jnp.ndarray, uniforms: jnp.ndarray,
                  plane_approx: jnp.ndarray, *,
                  a_bits: int, b_bits: int, c_dim: int, n_nei: int,
                  p_bins: int, s_bits: int) -> jnp.ndarray:
    """One GAVINA tile under GAV: bit-plane GEMM steps -> error injection ->
    L0/L1 shift-accumulate. This is the full approximate tile computation
    the Rust hot path implements natively; lowered to HLO for cross-checks.
    """
    steps = []
    for bb in range(b_bits):
        for ba in range(a_bits):
            steps.append(bitserial.binary_gemm_plane(
                a_planes[ba], b_planes[bb]))
    exact_seq = jnp.stack(steps).astype(jnp.int32)  # [T, K, L]
    approx_seq = errmodel_jax(
        exact_seq, tables, uniforms, plane_approx,
        c_dim=c_dim, n_nei=n_nei, p_bins=p_bins, s_bits=s_bits)
    # Shift-accumulate (L0/L1) with sign rule.
    t = 0
    k, l = approx_seq.shape[1], approx_seq.shape[2]
    p = jnp.zeros((k, l), dtype=jnp.int32)
    for bb in range(b_bits):
        for ba in range(a_bits):
            sign = -1 if (ba == a_bits - 1) != (bb == b_bits - 1) else 1
            p = p + sign * (approx_seq[t] << (ba + bb))
            t += 1
    return p


# ---------------------------------------------------------------------------
# (3) Quantization-aware ResNet-18 (CIFAR topology)
# ---------------------------------------------------------------------------


def fake_quant(x: jnp.ndarray, bits: int, amax: jnp.ndarray) -> jnp.ndarray:
    """Uniform symmetric fake-quantization with straight-through estimator.

    ``amax`` may be a scalar (per-tensor) or broadcastable (per-channel).
    """
    hi = 2 ** (bits - 1) - 1
    scale = jnp.maximum(amax, 1e-8) / hi
    q = jnp.clip(jnp.round(x / scale), -hi, hi) * scale
    # STE: forward q, backward identity.
    return x + jax.lax.stop_gradient(q - x)


def weight_amax(w: jnp.ndarray) -> jnp.ndarray:
    """Per-output-channel |max| for conv weights [kh, kw, cin, cout] —
    per-channel weight quantization (Brevitas' default for convs). The
    Rust executor applies the matching per-channel scale after the integer
    GEMM."""
    if w.ndim == 4:
        return jnp.max(jnp.abs(w), axis=(0, 1, 2), keepdims=True)
    return jnp.max(jnp.abs(w))


def act_amax(x: jnp.ndarray) -> jnp.ndarray:
    """Activation range: a robust cap instead of the raw max — at 2-3 bits
    a single outlier otherwise wastes the whole grid. `mean+6σ of |x|`,
    clipped by the true max. (Mirrored exactly by rust/src/dnn's
    activation quantizer so both executors see the same integers.)"""
    ax = jnp.abs(x)
    mu = jnp.mean(ax)
    sd = jnp.std(ax)
    return jnp.minimum(jnp.max(ax), mu + 6.0 * sd)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn_apply(x, scale, bias, mean, var):
    return (x - mean) * scale * jax.lax.rsqrt(var + 1e-5) + bias


# ResNet-18 CIFAR topology: conv3x3(16w) -> 4 stages x 2 BasicBlocks,
# channels (16, 32, 64, 128) * width/0.25 ... expressed via width multiplier
# against the standard (64, 128, 256, 512).
STAGES = [(64, 1), (128, 2), (256, 2), (512, 2)]  # (base_channels, stride)
BLOCKS_PER_STAGE = 2


def resnet18_param_shapes(width_mult: float = 0.25,
                          num_classes: int = 10) -> dict[str, tuple]:
    """Shape table for the parameter pytree (flat dict, name -> shape)."""
    ch = lambda c: max(8, int(c * width_mult))
    shapes: dict[str, tuple] = {"conv0/w": (3, 3, 3, ch(64))}
    shapes.update(_bn_shapes("bn0", ch(64)))
    cin = ch(64)
    for si, (c, stride) in enumerate(STAGES):
        cout = ch(c)
        for bi in range(BLOCKS_PER_STAGE):
            s = stride if bi == 0 else 1
            p = f"s{si}b{bi}"
            shapes[f"{p}/conv1/w"] = (3, 3, cin, cout)
            shapes.update(_bn_shapes(f"{p}/bn1", cout))
            shapes[f"{p}/conv2/w"] = (3, 3, cout, cout)
            shapes.update(_bn_shapes(f"{p}/bn2", cout))
            if s != 1 or cin != cout:
                shapes[f"{p}/down/w"] = (1, 1, cin, cout)
                shapes.update(_bn_shapes(f"{p}/dbn", cout))
            cin = cout
    shapes["fc/w"] = (cin, num_classes)
    shapes["fc/b"] = (num_classes,)
    return shapes


def _bn_shapes(prefix: str, c: int) -> dict[str, tuple]:
    return {f"{prefix}/scale": (c,), f"{prefix}/bias": (c,),
            f"{prefix}/mean": (c,), f"{prefix}/var": (c,)}


def resnet18_init(key, width_mult: float = 0.25,
                  num_classes: int = 10) -> dict[str, jnp.ndarray]:
    """He-init parameters for the QAT ResNet-18."""
    params = {}
    for name, shape in resnet18_param_shapes(width_mult, num_classes).items():
        key, sub = jax.random.split(key)
        if name.endswith("/w") and len(shape) == 4:
            fan_in = shape[0] * shape[1] * shape[2]
            params[name] = jax.random.normal(sub, shape) * jnp.sqrt(2.0 / fan_in)
        elif name == "fc/w":
            params[name] = jax.random.normal(sub, shape) * jnp.sqrt(1.0 / shape[0])
        elif name.endswith("/scale") or name.endswith("/var"):
            params[name] = jnp.ones(shape)
        else:
            params[name] = jnp.zeros(shape)
    return params


def _qconv_bn_relu(x, params, conv_name, bn_name, *, stride, a_bits, w_bits,
                   relu=True, quant_in=True):
    """Quantized conv + BN + ReLU. Activations and weights are fake-quantized
    per tensor — this is what maps onto GAVINA's aXwY integer GEMMs."""
    w = params[f"{conv_name}/w"]
    if w_bits < 32:
        w = fake_quant(w, w_bits, weight_amax(w))
    if quant_in and a_bits < 32:
        x = fake_quant(x, a_bits, act_amax(x))
    y = _conv(x, w, stride)
    y = _bn_apply(y, params[f"{bn_name}/scale"], params[f"{bn_name}/bias"],
                  params[f"{bn_name}/mean"], params[f"{bn_name}/var"])
    return jax.nn.relu(y) if relu else y


def resnet18_apply(params: dict[str, jnp.ndarray], x: jnp.ndarray, *,
                   a_bits: int = 32, w_bits: int = 32,
                   width_mult: float = 0.25) -> jnp.ndarray:
    """Forward pass. x: [N, 32, 32, 3] in [0,1]. Returns logits [N, classes].

    The first conv quantizes its input (the image) too — on GAVINA every
    layer, including the input layer, runs as an integer GEMM (the paper's
    Fig. 8a shows exactly that layer to be the most GAV-sensitive).
    """
    ch = lambda c: max(8, int(c * width_mult))
    x = _qconv_bn_relu(x, params, "conv0", "bn0", stride=1,
                       a_bits=a_bits, w_bits=w_bits)
    cin = ch(64)
    for si, (c, stride) in enumerate(STAGES):
        cout = ch(c)
        for bi in range(BLOCKS_PER_STAGE):
            s = stride if bi == 0 else 1
            p = f"s{si}b{bi}"
            y = _qconv_bn_relu(x, params, f"{p}/conv1", f"{p}/bn1", stride=s,
                               a_bits=a_bits, w_bits=w_bits)
            y = _qconv_bn_relu(y, params, f"{p}/conv2", f"{p}/bn2", stride=1,
                               a_bits=a_bits, w_bits=w_bits, relu=False)
            if f"{p}/down/w" in params:
                sc = _qconv_bn_relu(x, params, f"{p}/down", f"{p}/dbn",
                                    stride=s, a_bits=a_bits, w_bits=w_bits,
                                    relu=False)
            else:
                sc = x
            x = jax.nn.relu(y + sc)
            cin = cout
    x = jnp.mean(x, axis=(1, 2))  # global average pool
    if a_bits < 32:
        x = fake_quant(x, a_bits, act_amax(x))
    return x @ params["fc/w"] + params["fc/b"]
