"""Pure-jnp reference oracles for the GAVINA bit-serial compute path.

Everything in this file is the *semantic ground truth* the Pallas kernels,
the AOT-lowered HLO artifacts and the Rust cycle-level simulator are all
checked against. No pallas, no cleverness — plain jnp so it is obviously
correct.

Conventions (shared with the Rust side, see rust/src/quant/):
  * Signed operands use two's complement over ``bits`` bits:
    value = -2^(bits-1) * b_{bits-1} + sum_i 2^i * b_i.
  * Matrices follow the paper's Listing 1 shapes: A is [C, L] (activations),
    B is [K, C] (weights), P = B @ A is [K, L].
  * Bit-planes are stored "bit-serial": plane index is the significance.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Quantization (uniform symmetric, per tensor) — paper §IV-B / [27]
# ---------------------------------------------------------------------------


def quant_range(bits: int) -> tuple[int, int]:
    """Symmetric signed integer range for ``bits`` bits: [-(2^(b-1)-1), 2^(b-1)-1].

    Symmetric quantization drops the most negative code so the grid is
    symmetric around zero (standard practice, and what Brevitas does with
    ``narrow_range=True``).
    """
    hi = 2 ** (bits - 1) - 1
    return -hi, hi


def quantize_sym(x: jnp.ndarray, bits: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Uniform symmetric quantization. Returns (int values, scale)."""
    lo, hi = quant_range(bits)
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    scale = amax / hi
    q = jnp.clip(jnp.round(x / scale), lo, hi).astype(jnp.int32)
    return q, scale


# ---------------------------------------------------------------------------
# Bit-plane slicing
# ---------------------------------------------------------------------------


def to_bitplanes(x_int: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Slice signed ints into two's-complement bit-planes.

    Returns planes with shape ``(bits,) + x.shape``; plane ``i`` holds bit
    ``i`` (LSB first). Works for any ints representable in ``bits`` bits.
    """
    # Two's complement over `bits` bits: reinterpret as unsigned.
    ux = jnp.where(x_int < 0, x_int + (1 << bits), x_int).astype(jnp.uint32)
    planes = [(ux >> i) & 1 for i in range(bits)]
    return jnp.stack(planes).astype(jnp.int32)


def from_bitplanes(planes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Inverse of :func:`to_bitplanes` (two's complement reassembly)."""
    weights = jnp.array(
        [-(1 << (bits - 1)) if i == bits - 1 else (1 << i) for i in range(bits)],
        dtype=jnp.int32,
    )
    return jnp.tensordot(weights, planes.astype(jnp.int32), axes=1)


# ---------------------------------------------------------------------------
# GEMM references
# ---------------------------------------------------------------------------


def gemm_exact(a_int: jnp.ndarray, b_int: jnp.ndarray) -> jnp.ndarray:
    """Exact integer GEMM P[K,L] = B[K,C] @ A[C,L] in int32."""
    return jnp.matmul(b_int.astype(jnp.int32), a_int.astype(jnp.int32))


def binary_gemm_plane(a_plane: jnp.ndarray, b_plane: jnp.ndarray) -> jnp.ndarray:
    """One bit-serial step: the Parallel Array's binary GEMM.

    a_plane: [C, L] of {0,1}; b_plane: [K, C] of {0,1}.
    Output: [K, L] unsigned partial sums in 0..C (the iPE outputs).
    AND + popcount over C is exactly a {0,1} matmul.
    """
    return jnp.matmul(b_plane.astype(jnp.int32), a_plane.astype(jnp.int32))


def bitserial_gemm_ref(
    a_int: jnp.ndarray, b_int: jnp.ndarray, a_bits: int, b_bits: int
) -> jnp.ndarray:
    """Bit-serial GEMM per Listing 1 — must equal :func:`gemm_exact`.

    sign = -1 iff exactly one of (ba, bb) indexes its operand's MSB
    (two's-complement MSB carries negative weight; two negatives cancel).
    """
    a_planes = to_bitplanes(a_int, a_bits)  # [a_bits, C, L]
    b_planes = to_bitplanes(b_int, b_bits)  # [b_bits, K, C]
    k, l = b_int.shape[0], a_int.shape[1]
    p = jnp.zeros((k, l), dtype=jnp.int32)
    for ba in range(a_bits):
        for bb in range(b_bits):
            sign = -1 if (ba == a_bits - 1) != (bb == b_bits - 1) else 1
            part = binary_gemm_plane(a_planes[ba], b_planes[bb])
            p = p + sign * (part << (ba + bb))
    return p


# ---------------------------------------------------------------------------
# iPE output sequence (what the undervolted Parallel Array produces) — the
# error model operates on this sequence, ordered exactly as GAVINA's
# controller schedules the (ba, bb) steps (Fig. 3 example: bb outer, ba inner).
# ---------------------------------------------------------------------------


def ipe_sequence(
    a_int: jnp.ndarray, b_int: jnp.ndarray, a_bits: int, b_bits: int
) -> jnp.ndarray:
    """Exact iPE outputs per (bb, ba) step: shape [seqlen, K, L], values 0..C."""
    a_planes = to_bitplanes(a_int, a_bits)
    b_planes = to_bitplanes(b_int, b_bits)
    steps = []
    for bb in range(b_bits):
        for ba in range(a_bits):
            steps.append(binary_gemm_plane(a_planes[ba], b_planes[bb]))
    return jnp.stack(steps)


def recombine_sequence(seq: jnp.ndarray, a_bits: int, b_bits: int) -> jnp.ndarray:
    """Shift-accumulate an iPE output sequence back into the integer GEMM.

    This mirrors the L0/L1 accumulator: it is where an (approximate) iPE
    sequence — e.g. with undervolting errors injected — becomes the final
    (approximate) GEMM result.
    """
    k, l = seq.shape[1], seq.shape[2]
    p = jnp.zeros((k, l), dtype=jnp.int32)
    i = 0
    for bb in range(b_bits):
        for ba in range(a_bits):
            sign = -1 if (ba == a_bits - 1) != (bb == b_bits - 1) else 1
            p = p + sign * (seq[i].astype(jnp.int32) << (ba + bb))
            i += 1
    return p


# ---------------------------------------------------------------------------
# Error-model reference (Listing 2) — numpy, sequential, obviously-correct.
# ---------------------------------------------------------------------------


def errmodel_ref(
    exact_seq: np.ndarray,  # [seqlen, K, L] ints in 0..C
    tables: np.ndarray,  # [s_bits, C+1, p_bins, n_cond] flip probabilities
    uniforms: np.ndarray,  # [seqlen, K, L, s_bits] pre-drawn U(0,1)
    c_dim: int,
    n_nei: int,
    p_bins: int,
    plane_approx: np.ndarray | None = None,  # [seqlen] bool: step undervolted?
) -> np.ndarray:
    """Reference implementation of the GAVINA undervolting model (Listing 2).

    Iterates bits MSB -> LSB; the flip probability of bit ``b`` is indexed by
    (b, exact value, previous-value bin, condition of the n_nei more
    significant neighbour bits). Guarded steps (plane_approx False) are exact.
    The first step of the sequence sees prev=0 (registers reset at context
    load), matching the Rust simulator.
    """
    s_bits = tables.shape[0]
    seqlen = exact_seq.shape[0]
    out = exact_seq.copy()
    prev = np.zeros_like(exact_seq[0])
    for t in range(seqlen):
        exact = exact_seq[t]
        if plane_approx is not None and not plane_approx[t]:
            prev = exact
            continue
        pbin = np.minimum((prev.astype(np.int64) * p_bins) // (c_dim + 1), p_bins - 1)
        bit_err = np.zeros((s_bits,) + exact.shape, dtype=np.int64)
        err_mask = np.zeros_like(exact)
        for bit in range(s_bits - 1, -1, -1):
            cond = np.zeros_like(exact)
            for j in range(1, n_nei + 1):
                if bit + j < s_bits:
                    cond = cond | (bit_err[bit + j] << (j - 1))
            prob = tables[bit, exact, pbin, cond]
            flip = (uniforms[t, ..., bit] < prob).astype(np.int64)
            bit_err[bit] = flip
            err_mask = err_mask | (flip << bit)
        out[t] = exact ^ err_mask
        prev = exact
    return out
