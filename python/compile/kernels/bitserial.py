"""Layer-1 Pallas kernels: the GAVINA Parallel-Array hot-spot.

The ASIC computes, every clock cycle, a binary GEMM between one activation
bit-plane A_bit[C, L] and one weight bit-plane B_bit[K, C]:

    iPE[k, l] = popcount_c( A_bit[c, l] & B_bit[k, c] )     (0 <= iPE <= C)

and shift-accumulates the result with significance 2^(ba+bb) and the
two's-complement sign rule. On TPU-style hardware we re-express the
AND+popcount reduction as a dense {0,1} matmul so it lands on the MXU
systolic array, and the (bb, ba) bit-plane loop becomes the Pallas *grid*:
the same HBM->VMEM schedule the ASIC implements with its A0/B0 SCM level.

The per-plane dot runs as int32 accumulation (int8 x int8 -> int32 is the
MXU's native integer mode); every intermediate is bounded by
C * (2^a_bits - 1) * (2^b_bits - 1) < 2^31 (asserted below), so the whole
bit-serial GEMM is exact for all supported precisions including a8w8.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness runs through the interpreter, TPU performance is
estimated analytically in DESIGN.md / EXPERIMENTS.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Architectural tile of the paper's physical design (Sec. IV-A).
C_DIM, L_DIM, K_DIM = 576, 8, 16

# MXU-friendly sub-tile of the C reduction dimension. 576 = 4 * 144; we pad
# the C axis to a multiple of C_BLK inside the wrapper so BlockSpec tiling
# stays regular.
C_BLK = 144


def _plane_signed_shift(step: jnp.ndarray, a_bits: int, b_bits: int):
    """Decode grid step -> signed 2^(ba+bb) weight under the (bb outer,
    ba inner) schedule used by the GAVINA controller (Fig. 3)."""
    ba = step % a_bits
    bb = step // a_bits
    neg = (ba == a_bits - 1) != (bb == b_bits - 1)
    shift = jnp.left_shift(jnp.int32(1), (ba + bb).astype(jnp.int32))
    return jnp.where(neg, -shift, shift)


def _bitserial_kernel(a_ref, b_ref, o_ref, *, a_bits: int, b_bits: int):
    """Grid: (a_bits*b_bits, C//C_BLK). a_ref block: [1, C_BLK, L] of the
    current activation plane; b_ref block: [1, K, C_BLK] of the current
    weight plane; o_ref: the full [K, L] int32 accumulator (revisited every
    step)."""
    step = pl.program_id(0)
    cblk = pl.program_id(1)

    @pl.when((step == 0) & (cblk == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Binary GEMM: {0,1} values; int8 x int8 -> int32 (MXU integer mode).
    a = a_ref[0].astype(jnp.int8)
    b = b_ref[0].astype(jnp.int8)
    part = jnp.dot(b, a, preferred_element_type=jnp.int32)
    o_ref[...] += _plane_signed_shift(step, a_bits, b_bits) * part


@functools.partial(jax.jit, static_argnames=("a_bits", "b_bits"))
def bitserial_gemm(a_planes: jnp.ndarray, b_planes: jnp.ndarray, *,
                   a_bits: int, b_bits: int) -> jnp.ndarray:
    """Bit-serial integer GEMM over pre-sliced bit-planes.

    a_planes: [a_bits, C, L] f32 of {0,1}; b_planes: [b_bits, K, C] f32 of
    {0,1}. Returns [K, L] int32 holding the exact signed integer GEMM
    B @ A for the two's-complement operands the planes encode.
    """
    ab, c, l = a_planes.shape
    bb_, k, c2 = b_planes.shape
    assert ab == a_bits and bb_ == b_bits and c == c2, "plane shape mismatch"
    # Exactness bound for int32 accumulation (see module docstring).
    assert c * ((1 << a_bits) - 1) * ((1 << b_bits) - 1) < (1 << 31)

    cpad = (-c) % C_BLK
    if cpad:
        a_planes = jnp.pad(a_planes, ((0, 0), (0, cpad), (0, 0)))
        b_planes = jnp.pad(b_planes, ((0, 0), (0, 0), (0, cpad)))
        c += cpad

    # Flatten the (bb, ba) loop into one grid axis, ba fastest (controller
    # schedule). Plane index for step s: a-plane = s % a_bits (axis 0 of
    # a_planes), b-plane = s // a_bits.
    steps = a_bits * b_bits
    grid = (steps, c // C_BLK)

    return pl.pallas_call(
        functools.partial(_bitserial_kernel, a_bits=a_bits, b_bits=b_bits),
        grid=grid,
        in_specs=[
            # a_planes[s % a_bits, cblk*C_BLK :+ C_BLK, :]
            pl.BlockSpec((1, C_BLK, l), lambda s, cb: (s % a_bits, cb, 0)),
            # b_planes[s // a_bits, :, cblk*C_BLK :+ C_BLK]
            pl.BlockSpec((1, k, C_BLK), lambda s, cb: (s // a_bits, 0, cb)),
        ],
        out_specs=pl.BlockSpec((k, l), lambda s, cb: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, l), jnp.int32),
        interpret=True,
    )(a_planes, b_planes)


def _plane_kernel(a_ref, b_ref, o_ref):
    """Single-plane binary GEMM kernel (the raw Parallel Array step).
    Grid: (C//C_BLK,)."""
    cblk = pl.program_id(0)

    @pl.when(cblk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(b_ref[...], a_ref[...],
                          preferred_element_type=jnp.float32)


@jax.jit
def binary_gemm_plane(a_plane: jnp.ndarray, b_plane: jnp.ndarray) -> jnp.ndarray:
    """One Parallel-Array cycle: a_plane [C, L] x b_plane [K, C] -> [K, L]
    unsigned iPE outputs (values 0..C), as f32."""
    c, l = a_plane.shape
    k, c2 = b_plane.shape
    assert c == c2
    cpad = (-c) % C_BLK
    if cpad:
        a_plane = jnp.pad(a_plane, ((0, cpad), (0, 0)))
        b_plane = jnp.pad(b_plane, ((0, 0), (0, cpad)))
        c += cpad
    return pl.pallas_call(
        _plane_kernel,
        grid=(c // C_BLK,),
        in_specs=[
            pl.BlockSpec((C_BLK, l), lambda cb: (cb, 0)),
            pl.BlockSpec((k, C_BLK), lambda cb: (0, cb)),
        ],
        out_specs=pl.BlockSpec((k, l), lambda cb: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, l), jnp.float32),
        interpret=True,
    )(a_plane, b_plane)


def vmem_footprint_bytes(a_bits: int, b_bits: int,
                         c: int = C_DIM, l: int = L_DIM, k: int = K_DIM) -> int:
    """Static VMEM footprint of one bitserial_gemm grid step (for the
    DESIGN.md roofline estimate): A block + B block + accumulator, f32."""
    return 4 * (C_BLK * l + k * C_BLK + k * l)
