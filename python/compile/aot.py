"""AOT bridge: lower the L2/L1 jax functions to HLO *text* artifacts that
the Rust runtime loads via the PJRT C API.

HLO text, NOT ``lowered.compile()``/``.serialize()``: the image's
xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction ids fail
``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Artifacts produced (all shapes are the paper's hardware tile
[C,L,K] = [576,8,16], Sec. IV-A):

  bitserial_gemm_aXwY.hlo.txt  exact integer GEMM of one tile from
                               bit-planes: (a_planes [X,576,8] f32{0,1},
                               b_planes [Y,16,576] f32{0,1}) -> [16,8] f32
  binary_plane.hlo.txt         one Parallel-Array cycle:
                               ([576,8], [16,576]) -> [16,8]
  errinject_aXwY.hlo.txt       the undervolting error model applied to one
                               tile's iPE step sequence (LUT tables and
                               uniforms as runtime inputs)
  gav_gemm_aXwY.hlo.txt        full approximate tile: planes -> steps ->
                               error injection -> shift-accumulate

A manifest (artifacts/manifest.txt) lists each artifact with its input
signature so the Rust loader can self-check shapes at startup.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels import bitserial

C, L, K = bitserial.C_DIM, bitserial.L_DIM, bitserial.K_DIM
S_BITS = 10  # ceil(log2(C+1)) for C=576
P_BINS = 16
N_NEI = 2

PRECISIONS = [(2, 2), (3, 3), (4, 4), (8, 8)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(out_dir: str, name: str, lowered, manifest: list[str],
           signature: str) -> None:
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    manifest.append(f"{name}\t{signature}")
    print(f"  wrote {name} ({len(text)} chars)")


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build_all(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest: list[str] = []

    # --- single binary plane (raw Parallel Array cycle) ---
    lowered = jax.jit(bitserial.binary_gemm_plane).lower(f32(C, L), f32(K, C))
    _write(out_dir, "binary_plane.hlo.txt", lowered, manifest,
           f"a_plane f32[{C},{L}], b_plane f32[{K},{C}] -> f32[{K},{L}]")

    for (ab, wb) in PRECISIONS:
        # --- exact bit-serial GEMM of one tile ---
        fn = lambda ap, bp: M.bitserial_gemm_tile(ap, bp, a_bits=ab, b_bits=wb)
        lowered = jax.jit(fn).lower(f32(ab, C, L), f32(wb, K, C))
        _write(out_dir, f"bitserial_gemm_a{ab}w{wb}.hlo.txt", lowered,
               manifest,
               f"a_planes f32[{ab},{C},{L}], b_planes f32[{wb},{K},{C}] "
               f"-> f32[{K},{L}]")

        # --- error injection on the iPE step sequence ---
        seqlen = ab * wb
        errfn = lambda seq, tab, uni, msk: M.errmodel_jax(
            seq, tab, uni, msk, c_dim=C, n_nei=N_NEI, p_bins=P_BINS,
            s_bits=S_BITS)
        lowered = jax.jit(errfn).lower(
            i32(seqlen, K, L), f32(S_BITS, C + 1, P_BINS, 2 ** N_NEI),
            f32(seqlen, K, L, S_BITS),
            jax.ShapeDtypeStruct((seqlen,), jnp.bool_))
        _write(out_dir, f"errinject_a{ab}w{wb}.hlo.txt", lowered, manifest,
               f"exact i32[{seqlen},{K},{L}], tables "
               f"f32[{S_BITS},{C + 1},{P_BINS},{2 ** N_NEI}], uniforms "
               f"f32[{seqlen},{K},{L},{S_BITS}], approx pred[{seqlen}] "
               f"-> i32[{seqlen},{K},{L}]")

    # --- full approximate tile (a4w4 reference config) ---
    ab, wb = 4, 4
    gavfn = lambda ap, bp, tab, uni, msk: M.gav_gemm_tile(
        ap, bp, tab, uni, msk, a_bits=ab, b_bits=wb, c_dim=C, n_nei=N_NEI,
        p_bins=P_BINS, s_bits=S_BITS)
    lowered = jax.jit(gavfn).lower(
        f32(ab, C, L), f32(wb, K, C),
        f32(S_BITS, C + 1, P_BINS, 2 ** N_NEI),
        f32(ab * wb, K, L, S_BITS),
        jax.ShapeDtypeStruct((ab * wb,), jnp.bool_))
    _write(out_dir, f"gav_gemm_a{ab}w{wb}.hlo.txt", lowered, manifest,
           f"a_planes f32[{ab},{C},{L}], b_planes f32[{wb},{K},{C}], tables, "
           f"uniforms, approx -> i32[{K},{L}]")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    args = ap.parse_args()
    out_dir = args.out
    # argparse gives us e.g. ../artifacts/model.hlo.txt from the Makefile's
    # legacy invocation; accept both a dir and a file-ish path.
    if out_dir.endswith(".hlo.txt"):
        out_dir = os.path.dirname(out_dir)
    build_all(out_dir)


if __name__ == "__main__":
    main()
