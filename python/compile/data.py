"""Synthetic CIFAR-like dataset (substitution for CIFAR-10 — see DESIGN.md).

The environment has no network access, so the real CIFAR-10 cannot be
downloaded. We substitute a *procedural* 10-class 32x32x3 image task with
class structure rich enough that a quantized ResNet-18 has to learn real
features (oriented gratings + class-colored blobs + per-sample pose/phase
jitter + pixel noise), yet learnable in minutes on one CPU core. The role
of the dataset in the paper is to expose accuracy-vs-G / accuracy-vs-
precision *trends*; this task preserves that role: accuracy is high when
exact, degrades with quantization noise and with injected GAV errors.

The same generator seeds/test split are exported to ``artifacts/`` so the
Rust evaluation path scores the identical images.
"""

from __future__ import annotations

import numpy as np

IMG = 32
NUM_CLASSES = 10


def _grating(theta: float, freq: float, phase: float) -> np.ndarray:
    ys, xs = np.mgrid[0:IMG, 0:IMG].astype(np.float32) / IMG
    t = xs * np.cos(theta) + ys * np.sin(theta)
    return 0.5 + 0.5 * np.sin(2 * np.pi * freq * t + phase)


def _blob(cx: float, cy: float, r: float) -> np.ndarray:
    ys, xs = np.mgrid[0:IMG, 0:IMG].astype(np.float32) / IMG
    d2 = (xs - cx) ** 2 + (ys - cy) ** 2
    return np.exp(-d2 / (2 * r * r))


# Per-class signature: (grating angle, frequency, RGB tint, blob quadrant)
_CLASS_DEFS = [
    (0.0, 2.0, (1.0, 0.2, 0.2), (0.25, 0.25)),
    (np.pi / 4, 3.0, (0.2, 1.0, 0.2), (0.75, 0.25)),
    (np.pi / 2, 2.0, (0.2, 0.2, 1.0), (0.25, 0.75)),
    (3 * np.pi / 4, 4.0, (1.0, 1.0, 0.2), (0.75, 0.75)),
    (0.0, 5.0, (1.0, 0.2, 1.0), (0.5, 0.5)),
    (np.pi / 3, 2.5, (0.2, 1.0, 1.0), (0.25, 0.5)),
    (2 * np.pi / 3, 3.5, (1.0, 0.6, 0.2), (0.5, 0.25)),
    (np.pi / 6, 4.5, (0.6, 0.2, 1.0), (0.75, 0.5)),
    (5 * np.pi / 6, 1.5, (0.4, 0.8, 0.4), (0.5, 0.75)),
    (np.pi / 2, 5.5, (0.8, 0.8, 0.8), (0.25, 0.25)),
]


def make_dataset(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate ``n`` images. Returns (images [n,32,32,3] float32 in [0,1],
    labels [n] int32). Class-balanced round-robin."""
    rng = np.random.default_rng(seed)
    images = np.zeros((n, IMG, IMG, 3), dtype=np.float32)
    labels = np.zeros(n, dtype=np.int32)
    for i in range(n):
        cls = i % NUM_CLASSES
        theta, freq, tint, (bx, by) = _CLASS_DEFS[cls]
        theta = theta + rng.normal(0, 0.12)
        freq = freq * (1 + rng.normal(0, 0.08))
        phase = rng.uniform(0, 2 * np.pi)
        g = _grating(theta, freq, phase)
        blob = _blob(bx + rng.normal(0, 0.05), by + rng.normal(0, 0.05),
                     0.15 + rng.normal(0, 0.02))
        img = np.zeros((IMG, IMG, 3), dtype=np.float32)
        for ch in range(3):
            img[..., ch] = 0.55 * g * tint[ch] + 0.45 * blob * tint[ch]
        img += rng.normal(0, 0.06, img.shape).astype(np.float32)
        images[i] = np.clip(img, 0.0, 1.0)
        labels[i] = cls
    return images, labels


def train_eval_split(n_train: int = 2000, n_eval: int = 512,
                     seed: int = 2025) -> tuple:
    """Deterministic train/eval sets (disjoint seeds)."""
    xtr, ytr = make_dataset(n_train, seed)
    xev, yev = make_dataset(n_eval, seed + 1)
    return (xtr, ytr), (xev, yev)
