"""Progressive-precision QAT of the ResNet-18 benchmark model (paper §IV-D).

"To maintain as much accuracy as possible in low precisions, we
progressively retrain the model from high to low precision: e.g. the a2w2
model is retrained from the a3w3 weights, which were retrained from a4w4."

This script runs at artifact-build time only (make artifacts). It trains a
float model on the synthetic CIFAR task, then fine-tunes it down the
precision ladder a8w8 -> a4w4 -> a3w3 -> a2w2 with fake-quant QAT, and
exports, per precision:

    artifacts/weights_aXwY.bin   — float weights (GVNT container)

plus the shared evaluation set:

    artifacts/dataset_eval.bin   — images u8 [N,32,32,3], labels i32 [N]

The Rust side (rust/src/dnn/) quantizes weights/activations itself with the
same symmetric scheme, lowers convs to GEMM tiles and runs them through the
GAVINA simulator or the errmodel hot path.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as datagen
from compile import model as M
from compile import tensorio

# Precision ladder with per-step fine-tune epochs: lower precisions fight
# more quantization noise and get longer retraining (paper §IV-D trains
# progressively high -> low for the same reason).
LADDER = [(8, 8, 1.0), (4, 4, 1.5), (3, 3, 2.5), (2, 2, 3.5)]


def _bn_update(params, stats, momentum=0.9):
    """Fold fresh batch statistics into the running BN estimates."""
    new = dict(params)
    for k, (mean, var) in stats.items():
        new[f"{k}/mean"] = momentum * params[f"{k}/mean"] + (1 - momentum) * mean
        new[f"{k}/var"] = momentum * params[f"{k}/var"] + (1 - momentum) * var
    return new


def make_steps(width_mult: float, a_bits: int, w_bits: int, lr: float):
    def loss_fn(params, x, y):
        logits = M.resnet18_apply(params, x, a_bits=a_bits, w_bits=w_bits,
                                  width_mult=width_mult)
        onehot = jax.nn.one_hot(y, datagen.NUM_CLASSES)
        return -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), -1))

    @jax.jit
    def train_step(params, mom, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        # Global-norm gradient clipping: the small synthetic task with BN in
        # inference form is prone to loss spikes that snowball into NaN.
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
        clip = jnp.minimum(1.0, 1.0 / (gnorm + 1e-9))
        grads = {k: g * clip for k, g in grads.items()}
        new_p, new_m = {}, {}
        for k in params:
            g = grads[k]
            m = 0.9 * mom[k] + g
            # BN running stats are not trained by SGD.
            if k.endswith("/mean") or k.endswith("/var"):
                new_p[k], new_m[k] = params[k], mom[k]
            else:
                new_p[k] = params[k] - lr * m
                new_m[k] = m
        return new_p, new_m, loss

    @jax.jit
    def eval_step(params, x, y):
        logits = M.resnet18_apply(params, x, a_bits=a_bits, w_bits=w_bits,
                                  width_mult=width_mult)
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

    return train_step, eval_step


def batch_norm_calibrate(params, x, width_mult, a_bits, w_bits):
    """One full-batch forward in float to refresh BN running stats.

    We train with BN in inference form (running stats), which is stable for
    this small task; a periodic recalibration keeps the stats honest.
    """
    # Collect activations per BN layer by re-running the forward with hooks —
    # for simplicity we recompute means/vars from a single large batch using
    # the conv outputs. Implemented as a direct pass over the graph.
    ch = lambda c: max(8, int(c * width_mult))
    stats = {}

    def conv_bn(xin, conv, bn, stride, relu=True):
        w = params[f"{conv}/w"]
        y = jax.lax.conv_general_dilated(
            xin, w, (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        mean = jnp.mean(y, axis=(0, 1, 2))
        var = jnp.var(y, axis=(0, 1, 2))
        stats[bn] = (mean, var)
        y = (y - mean) * params[f"{bn}/scale"] * jax.lax.rsqrt(var + 1e-5) \
            + params[f"{bn}/bias"]
        return jax.nn.relu(y) if relu else y

    h = conv_bn(x, "conv0", "bn0", 1)
    for si, (c, stride) in enumerate(M.STAGES):
        for bi in range(M.BLOCKS_PER_STAGE):
            s = stride if bi == 0 else 1
            p = f"s{si}b{bi}"
            y = conv_bn(h, f"{p}/conv1", f"{p}/bn1", s)
            y = conv_bn(y, f"{p}/conv2", f"{p}/bn2", 1, relu=False)
            if f"{p}/down/w" in params:
                sc = conv_bn(h, f"{p}/down", f"{p}/dbn", s, relu=False)
            else:
                sc = h
            h = jax.nn.relu(y + sc)
    return _bn_update(params, stats, momentum=0.0)


def train(out_dir: str, width_mult: float = 0.25, n_train: int = 2000,
          n_eval: int = 512, float_epochs: int = 8, qat_epochs: int = 3,
          batch: int = 64, lr: float = 0.004, seed: int = 7) -> dict:
    (xtr, ytr), (xev, yev) = datagen.train_eval_split(n_train, n_eval)
    key = jax.random.PRNGKey(seed)
    params = M.resnet18_init(key, width_mult)
    mom = {k: jnp.zeros_like(v) for k, v in params.items()}

    os.makedirs(out_dir, exist_ok=True)
    # Export the eval set once (u8 images to keep the artifact small).
    tensorio.save_tensors(os.path.join(out_dir, "dataset_eval.bin"), {
        "images": (xev * 255.0 + 0.5).astype(np.uint8),
        "labels": yev.astype(np.int32),
    })

    results = {}
    nb = len(xtr) // batch
    rng = np.random.default_rng(seed)

    def run_epochs(tag, a_bits, w_bits, epochs, cur_lr):
        nonlocal params, mom
        train_step, eval_step = make_steps(width_mult, a_bits, w_bits, cur_lr)
        for ep in range(epochs):
            perm = rng.permutation(len(xtr))
            tot = 0.0
            # BN recalibration on a large float batch each epoch.
            params = batch_norm_calibrate(
                params, jnp.asarray(xtr[perm[: 4 * batch]]), width_mult,
                a_bits, w_bits)
            params = {k: jnp.asarray(v) for k, v in params.items()}
            for b in range(nb):
                idx = perm[b * batch:(b + 1) * batch]
                params, mom, loss = train_step(
                    params, mom, jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx]))
                tot += float(loss)
            acc = float(eval_step(params, jnp.asarray(xev), jnp.asarray(yev)))
            print(f"[{tag}] epoch {ep}: loss={tot / nb:.4f} eval_acc={acc:.4f}",
                  flush=True)
        return acc

    t0 = time.time()
    run_epochs("float", 32, 32, float_epochs, lr)
    for (ab, wb, mult) in LADDER:
        epochs = max(1, int(round(qat_epochs * mult)))
        acc = run_epochs(f"a{ab}w{wb}", ab, wb, epochs, lr * 0.25)
        results[f"a{ab}w{wb}"] = acc
        tensorio.save_tensors(
            os.path.join(out_dir, f"weights_a{ab}w{wb}.bin"),
            {k: np.asarray(v, dtype=np.float32) for k, v in params.items()})
    print(f"training done in {time.time() - t0:.1f}s: {results}", flush=True)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--width-mult", type=float, default=0.25)
    ap.add_argument("--n-train", type=int, default=2000)
    ap.add_argument("--float-epochs", type=int, default=8)
    ap.add_argument("--qat-epochs", type=int, default=3)
    args = ap.parse_args()
    train(args.out, width_mult=args.width_mult, n_train=args.n_train,
          float_epochs=args.float_epochs, qat_epochs=args.qat_epochs)


if __name__ == "__main__":
    main()
