"""Tiny binary tensor container shared between the python compile path and
the Rust runtime (rust/src/dnn/weights.rs implements the reader).

Layout (little-endian):
    magic   b"GVNT"
    version u32 (=1)
    count   u32
    count * [ name_len u32 | name utf8 | dtype u8 | ndim u32 | dims u32*ndim
              | raw data ]
dtype: 0 = f32, 1 = i32, 2 = u8.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"GVNT"
_DTYPES = {0: np.float32, 1: np.int32, 2: np.uint8}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.uint8): 2}


def save_tensors(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            code = _CODES[arr.dtype]
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BI", code, arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())


def load_tensors(path: str) -> dict[str, np.ndarray]:
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, f"bad magic in {path}"
        version, count = struct.unpack("<II", f.read(8))
        assert version == 1
        out = {}
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            code, ndim = struct.unpack("<BI", f.read(5))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            dtype = _DTYPES[code]
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(n * dtype().itemsize), dtype=dtype)
            out[name] = data.reshape(dims)
        return out
