//! End-to-end driver (the paper's §IV-D benchmark): quantized ResNet-18 on
//! the synthetic-CIFAR task, executed convolution-by-convolution on the
//! cycle-level GAVINA simulator with GLS-calibrated undervolting errors.
//!
//! ```bash
//! make artifacts                     # trains weights + exports eval set
//! cargo run --release --example resnet_cifar [n_images] [precision]
//! ```
//!
//! Reports accuracy and modelled accelerator energy across the GAV range
//! G = 0 (fully undervolted) … G_max (exact) — the Fig. 8b trade-off for
//! uniform per-layer G.

use std::path::Path;
use std::sync::Arc;

use gavina::arch::{GavSchedule, Precision};
use gavina::engine::{EngineBuilder, GavPolicy};
use gavina::errmodel;
use gavina::power::PowerModel;
use gavina::stats::accuracy;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n_images: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let prec = args
        .get(2)
        .and_then(|s| Precision::parse(s))
        .unwrap_or(Precision::new(4, 4));
    let artifacts = Path::new("artifacts");

    // Trained weights + eval set from `make artifacts`.
    let eval = gavina::dnn::load_eval_set(&artifacts.join("dataset_eval.bin")).expect("eval set");
    let n = n_images.min(eval.n);
    let images = &eval.images[..n * 32 * 32 * 3];
    let labels = &eval.labels[..n];

    // GLS-calibrated error tables (built by `gavina calibrate`).
    let tables_path = artifacts.join("caltables_v035.bin");
    let (tables, v_aprox) = errmodel::io::load(&tables_path)
        .expect("run `gavina calibrate` first (GLS error-model calibration)");
    println!("error tables calibrated at V_aprox = {v_aprox} V");

    // One validated builder; each sweep point clones it with a new policy.
    let builder = EngineBuilder::new()
        .weights_from_file(&artifacts.join(format!("weights_{}.bin", prec.tag())))
        .expect("run `make artifacts` first (trains weights)")
        .precision(prec)
        .tables(Arc::new(tables))
        .seed(11);
    let power = PowerModel::paper_calibrated();

    // Float reference accuracy (quantization only, no undervolting).
    let engine_ref = builder
        .clone()
        .backend_float()
        .policy(GavPolicy::Exact)
        .build()
        .expect("engine config");
    let ref_out = engine_ref.infer_batched(images, n, 16).expect("reference pass");
    let ref_acc = accuracy(&ref_out.logits, labels, ref_out.classes);
    println!("\n{prec} exact (quantization-only) accuracy on {n} images: {ref_acc:.4}\n");

    println!("  G | accuracy | Δacc    | TOP/sW | energy/img [mJ] | corrupted");
    println!("----+----------+---------+--------+-----------------+----------");
    for g in (0..=prec.max_g()).rev() {
        let sched = GavSchedule::two_level(prec, g);
        let engine = builder
            .clone()
            .policy(GavPolicy::Uniform(g))
            .build()
            .expect("engine config");
        let out = engine.infer_batched(images, n, 16).expect("forward pass");
        let acc = accuracy(&out.logits, labels, out.classes);
        let tops_w = power.tops_per_watt(&sched, 0.96);
        let energy = power.energy_mj(&sched, out.stats.cycles) / n as f64;
        println!(
            " {g:2} | {acc:8.4} | {:+7.4} | {tops_w:6.2} | {energy:15.4} | {}",
            acc - ref_acc,
            out.stats.corrupted
        );
    }
    println!(
        "\nReading: high G ≈ exact accuracy at guarded power; low G trades accuracy for"
    );
    println!("the paper's up-to-×{:.2} energy-efficiency boost (Fig. 8b shape).",
             power.undervolting_boost(prec));
}
