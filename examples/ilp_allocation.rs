//! Per-layer GAV allocation with the branch-and-bound ILP (paper §IV-D,
//! Fig. 8): profile each conv layer's output perturbation under isolated
//! undervolting, then allocate per-layer G values optimally for a sweep of
//! average-G targets and compare against naive uniform allocation.
//!
//! ```bash
//! make artifacts && cargo run --release --example ilp_allocation [n_images]
//! ```

use std::path::Path;

use gavina::arch::{ArchConfig, Precision};
use gavina::dnn::{self, Backend, Executor};
use gavina::errmodel;
use gavina::ilp::{GavAllocator, LayerChoices};
use gavina::stats::{accuracy, mse_f32};

fn main() {
    let n_images: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let prec = Precision::new(4, 4);
    let artifacts = Path::new("artifacts");
    let weights = dnn::load_tensors(&artifacts.join("weights_a4w4.bin"))
        .expect("run `make artifacts` first");
    let eval = dnn::load_eval_set(&artifacts.join("dataset_eval.bin")).expect("eval set");
    let n = n_images.min(eval.n);
    let images = &eval.images[..n * 32 * 32 * 3];
    let labels = &eval.labels[..n];
    let (tables, _) = errmodel::io::load(&artifacts.join("caltables_v035.bin"))
        .expect("run `gavina calibrate` first");
    let arch = ArchConfig::paper();
    let names = dnn::conv_layer_names();

    // Exact reference.
    let ref_out =
        Executor::new(&weights, 0.25, prec, Backend::Float).forward_batched(images, n, 16);
    let ref_acc = accuracy(&ref_out.logits, labels, ref_out.classes);
    println!("exact a4w4 accuracy: {ref_acc:.4} ({n} images)\n");

    // --- Fig. 8a: per-layer perturbation profile -----------------------
    println!("per-layer output MSE when ONLY that layer is undervolted (Fig. 8a):");
    println!("{:>2} {:12} | G=0        G=2        G=4        G=6", "#", "layer");
    let mut layers = Vec::new();
    for (li, name) in names.iter().enumerate() {
        let mut cost = vec![0.0; (prec.max_g() + 1) as usize];
        let mut macs = 0u64;
        for g in 0..=prec.max_g() {
            if g == prec.max_g() {
                continue; // exact: cost 0
            }
            let mut ex = Executor::new(
                &weights,
                0.25,
                prec,
                Backend::Gavina {
                    arch: arch.clone(),
                    tables: Some(&tables),
                    seed: 23 + li as u64,
                },
            );
            ex.layer_gs = vec![prec.max_g(); names.len()];
            ex.layer_gs[li] = g;
            let out = ex.forward_batched(images, n, 16);
            macs = out.stats.layer_macs[li];
            cost[g as usize] = mse_f32(&ref_out.logits, &out.logits);
        }
        println!(
            "{li:>2} {name:12} | {:9.3e}  {:9.3e}  {:9.3e}  {:9.3e}",
            cost[0], cost[2], cost[4], cost[6]
        );
        layers.push(LayerChoices {
            ops: macs as f64,
            cost,
        });
    }

    // --- Fig. 8b: ILP allocation vs uniform G across G_tar -------------
    let allocator = GavAllocator::new(layers);
    println!("\nG_tar | ILP accuracy | uniform-G accuracy | ILP allocation");
    println!("------+--------------+--------------------+----------------");
    for g_tar in [2.0, 3.0, 4.0, 5.0, 6.0] {
        let alloc = allocator.solve(g_tar);
        let run = |gs: Vec<u32>| {
            let mut ex = Executor::new(
                &weights,
                0.25,
                prec,
                Backend::Gavina {
                    arch: arch.clone(),
                    tables: Some(&tables),
                    seed: 31,
                },
            );
            ex.layer_gs = gs;
            let out = ex.forward_batched(images, n, 16);
            accuracy(&out.logits, labels, out.classes)
        };
        let ilp_acc = run(alloc.gs.clone());
        let uni_acc = run(vec![g_tar.floor() as u32; names.len()]);
        println!(
            " {g_tar:4.1} | {ilp_acc:12.4} | {uni_acc:18.4} | {:?}",
            alloc.gs
        );
    }
    println!("\nFig. 8 shape: sensitive layers (the input conv) get large G automatically;");
    println!("ILP allocation dominates uniform G at equal average guarding.");
}
