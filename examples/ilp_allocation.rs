//! Per-layer GAV allocation with the branch-and-bound ILP (paper §IV-D,
//! Fig. 8): profile each conv layer's output perturbation under isolated
//! undervolting through `Engine::profile_layers`, then allocate per-layer
//! G values optimally for a sweep of average-G targets and compare
//! against naive uniform allocation.
//!
//! ```bash
//! make artifacts && cargo run --release --example ilp_allocation [n_images]
//! ```

use std::path::Path;
use std::sync::Arc;

use gavina::arch::Precision;
use gavina::dnn;
use gavina::engine::{EngineBuilder, GavPolicy};
use gavina::errmodel;
use gavina::ilp::GavAllocator;
use gavina::stats::accuracy;

fn main() {
    let n_images: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let prec = Precision::new(4, 4);
    let artifacts = Path::new("artifacts");
    let eval = dnn::load_eval_set(&artifacts.join("dataset_eval.bin")).expect("eval set");
    let n = n_images.min(eval.n);
    let images = &eval.images[..n * 32 * 32 * 3];
    let labels = &eval.labels[..n];
    let (tables, _) = errmodel::io::load(&artifacts.join("caltables_v035.bin"))
        .expect("run `gavina calibrate` first");
    let names = dnn::conv_layer_names();

    // One validated builder: the profiling engine seeds layer `li` at
    // `seed + li` (23 + li, the historical profile seeds), the accuracy
    // sweep engines run at seed 31.
    let builder = EngineBuilder::new()
        .weights_from_file(&artifacts.join("weights_a4w4.bin"))
        .expect("run `make artifacts` first")
        .precision(prec)
        .tables(Arc::new(tables));

    // Exact reference.
    let engine_ref = builder
        .clone()
        .backend_float()
        .build()
        .expect("engine config");
    let ref_out = engine_ref.infer_batched(images, n, 16).expect("reference");
    let ref_acc = accuracy(&ref_out.logits, labels, ref_out.classes);
    println!("exact a4w4 accuracy: {ref_acc:.4} ({n} images)\n");

    // --- Fig. 8a: per-layer perturbation profile -----------------------
    let profiler = builder.clone().seed(23).build().expect("engine config");
    let layers = profiler
        .profile_layers(images, n, 16)
        .expect("layer profiling");
    println!("per-layer output MSE when ONLY that layer is undervolted (Fig. 8a):");
    println!("{:>2} {:12} | G=0        G=2        G=4        G=6", "#", "layer");
    for (li, name) in names.iter().enumerate() {
        let cost = &layers[li].cost;
        println!(
            "{li:>2} {name:12} | {:9.3e}  {:9.3e}  {:9.3e}  {:9.3e}",
            cost[0], cost[2], cost[4], cost[6]
        );
    }

    // --- Fig. 8b: ILP allocation vs uniform G across G_tar -------------
    let allocator = GavAllocator::new(layers);
    let eval_builder = builder.seed(31);
    println!("\nG_tar | ILP accuracy | uniform-G accuracy | ILP allocation");
    println!("------+--------------+--------------------+----------------");
    for g_tar in [2.0, 3.0, 4.0, 5.0, 6.0] {
        let alloc = allocator.solve(g_tar);
        let run = |policy: GavPolicy| {
            let engine = eval_builder
                .clone()
                .policy(policy)
                .build()
                .expect("engine config");
            let out = engine.infer_batched(images, n, 16).expect("forward pass");
            accuracy(&out.logits, labels, out.classes)
        };
        let ilp_acc = run(GavPolicy::PerLayer(alloc.gs.clone()));
        let uni_acc = run(GavPolicy::Uniform(g_tar.floor() as u32));
        println!(
            " {g_tar:4.1} | {ilp_acc:12.4} | {uni_acc:18.4} | {:?}",
            alloc.gs
        );
    }
    println!("\nFig. 8 shape: sensitive layers (the input conv) get large G automatically;");
    println!("ILP allocation dominates uniform G at equal average guarding.");
}
