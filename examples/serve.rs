//! Serving demo: the `gavina::serve` QoS layer batching inference
//! requests onto the GAVINA simulator — build an `Engine`, start a
//! three-tier service with the load-adaptive undervolting governor,
//! replay the evaluation set as a request stream, and report per-tier
//! latency percentiles, throughput, energy and the governor trajectory.
//!
//! ```bash
//! cargo run --release --example serve [n_requests] [g] [threads]
//! ```
//!
//! With `make artifacts` present the demo serves the trained a4w4
//! ResNet on real CIFAR images and reports accuracy; without artifacts
//! it falls back to synthetic weights and random images (same serving
//! path, no accuracy line) so it runs anywhere — CI uses it as a smoke
//! step.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use gavina::arch::Precision;
use gavina::dnn;
use gavina::engine::{EngineBuilder, GavPolicy, GavinaError};
use gavina::errmodel;
use gavina::power::PowerModel;
use gavina::serve::{CanaryOptions, GovernorOptions, ServeOptions, SubmitOptions};
use gavina::stats::accuracy;

fn main() {
    let n_req: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(48);
    let prec = Precision::new(4, 4);
    let g: u32 = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(prec.max_g());
    let threads: usize = std::env::args()
        .nth(3)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);

    let artifacts = Path::new("artifacts");
    let tables = errmodel::io::load(&artifacts.join("caltables_v035.bin"))
        .map(|(t, _)| Arc::new(t))
        .ok();

    // Artifacts are optional: fall back to synthetic weights + random
    // images so the demo (and the CI smoke step) runs without
    // `make artifacts`.
    let (builder, images, labels) = match (
        dnn::load_tensors(&artifacts.join("weights_a4w4.bin")),
        dnn::load_eval_set(&artifacts.join("dataset_eval.bin")),
    ) {
        // The guard keeps n_avail ≥ 1 below (an empty eval set would
        // otherwise divide-and-modulo by zero).
        (Ok(w), Ok(eval)) if eval.n > 0 => {
            let b = EngineBuilder::new().weights(w);
            (b, eval.images.clone(), Some(eval.labels.clone()))
        }
        _ => {
            eprintln!("no artifacts found — serving synthetic weights on random images");
            let b = EngineBuilder::new().synthetic_weights(0.25, 7);
            let mut rng = gavina::util::Prng::new(11);
            let imgs: Vec<f32> = (0..64 * 3072).map(|_| rng.next_f32()).collect();
            (b, imgs, None)
        }
    };

    let engine = Arc::new(
        builder
            .precision(prec)
            .tables_opt(tables)
            .policy(GavPolicy::Uniform(g))
            .threads(threads)
            .seed(7)
            .build()
            .expect("engine config"),
    );

    // Three QoS tiers + the governor on the default (guarded) tier,
    // with the canary re-running a slice of served requests on the
    // bit-exact reference so the governor reacts to *measured* drift.
    let opts = ServeOptions {
        replicas: 2,
        queue_depth: 256,
        governor: Some(GovernorOptions {
            period: Duration::from_millis(20),
            ..Default::default()
        }),
        canary: Some(CanaryOptions {
            sample_rate: 0.25,
            ..Default::default()
        }),
        ..Default::default()
    };
    println!(
        "starting service: {} replicas/tier × {} intra-batch threads, admission depth {}, \
         tiers [{}], governor on, canary on, {prec} ({})",
        opts.replicas,
        gavina::util::parallel::resolve_threads(engine.threads()),
        opts.queue_depth,
        opts.tiers
            .iter()
            .map(|t| t.name.as_str())
            .collect::<Vec<_>>()
            .join(", "),
        engine.policy().describe(),
    );
    let service = Arc::clone(&engine).serve(opts).expect("serve options");
    let session = service.session();

    // Replay: requests wrap around the available images, so n_req may
    // exceed the eval-set size (the stream just repeats).
    let n_avail = images.len() / 3072;
    let n = n_req.max(1);
    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(n);
    for i in 0..n {
        let image = images[(i % n_avail) * 3072..(i % n_avail + 1) * 3072].to_vec();
        // Every 8th request asks for the bit-exact reproducibility tier;
        // the rest ride the governed default tier.
        let ticket = if i % 8 == 0 {
            session.submit_with(image, SubmitOptions::new().tier("exact"))
        } else {
            session.submit(image)
        };
        match ticket {
            Ok(t) => tickets.push((i, t)),
            Err(GavinaError::Overloaded { capacity }) => {
                eprintln!("request {i} rejected: admission full at {capacity}");
            }
            Err(e) => panic!("submit failed: {e}"),
        }
    }

    // Accuracy is computed over the requests that were actually served
    // (admission may have rejected some), so the logit/label sets stay
    // aligned and NaN-free.
    let mut served_logits = Vec::with_capacity(tickets.len() * 10);
    let mut served_labels = Vec::with_capacity(tickets.len());
    for (i, t) in tickets {
        let resp = t
            .wait_timeout(Duration::from_secs(600))
            .expect("service answered")
            .expect("response within 600 s");
        served_logits.extend_from_slice(&resp.expect_logits("request failed"));
        if let Some(labels) = &labels {
            served_labels.push(labels[i % n_avail]);
        }
    }
    let served = served_labels.len().max(served_logits.len() / 10);
    let wall = t0.elapsed().as_secs_f64();

    if !served_labels.is_empty() {
        let acc = accuracy(&served_logits, &served_labels, 10);
        println!("accuracy under service config: {acc:.4}");
    }

    let report = service.shutdown();
    let power = PowerModel::paper_calibrated();
    println!("\nserved {served}/{n} requests in {wall:.2} s ({} rejected)", report.rejected);
    for m in &report.tiers {
        if m.requests == 0 {
            continue;
        }
        // Energy is modelled on each tier's own schedule (the exact tier
        // runs fully guarded, aggressive at G=0 — not the base engine's).
        println!(
            "tier {:10} {:5} reqs  {:7.1} req/s  p50 {:6.1} ms  p99 {:6.1} ms  \
             {:8.3} mJ  {} corrupted",
            m.tier,
            m.requests,
            m.requests_per_sec,
            m.p50_us as f64 / 1e3,
            m.p99_us as f64 / 1e3,
            m.energy_mj(&power, &m.effective_schedule(prec)),
            m.corrupted,
        );
    }
    println!(
        "governor: {} ticks, mean-G trajectory [{}]",
        report.governor.len(),
        report
            .governor
            .iter()
            .map(|s| format!("{:.1}", s.mean_g))
            .collect::<Vec<_>>()
            .join(" ")
    );
    for c in &report.canary {
        println!("{}", c.summary_line());
        let hot = c.hot_layers();
        if !hot.is_empty() {
            println!("  hot layers (step-error rate): {hot}");
        }
    }
}
