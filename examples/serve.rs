//! Serving demo: the Layer-3 coordinator batching inference requests onto
//! the GAVINA simulator — build an `Engine`, replay the evaluation set
//! as a request stream, report latency percentiles, throughput and
//! accelerator-side energy.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve [n_requests] [g] [threads]
//! ```
//!
//! `threads` sets the intra-batch worker threads per batch executor
//! (1 = serial, 0 = one per core) — run with 1 and then your core count
//! to see single-thread vs multi-thread serving throughput.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use gavina::arch::{GavSchedule, Precision};
use gavina::coordinator::ServeOptions;
use gavina::dnn;
use gavina::engine::{EngineBuilder, GavPolicy};
use gavina::errmodel;
use gavina::power::PowerModel;
use gavina::stats::accuracy;

fn main() {
    let n_req: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(48);
    let prec = Precision::new(4, 4);
    let g: u32 = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(prec.max_g());
    let threads: usize = std::env::args()
        .nth(3)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);

    let artifacts = Path::new("artifacts");
    let eval = dnn::load_eval_set(&artifacts.join("dataset_eval.bin")).expect("eval set");
    let tables = errmodel::io::load(&artifacts.join("caltables_v035.bin"))
        .map(|(t, _)| Arc::new(t))
        .ok();

    let engine = Arc::new(
        EngineBuilder::new()
            .weights_from_file(&artifacts.join("weights_a4w4.bin"))
            .expect("run `make artifacts`")
            .precision(prec)
            .tables_opt(tables)
            .policy(GavPolicy::Uniform(g))
            .threads(threads)
            .seed(7)
            .build()
            .expect("engine config"),
    );
    let opts = ServeOptions {
        workers: 4,
        max_batch: 8,
        batch_timeout: Duration::from_millis(10),
    };
    println!(
        "starting coordinator: {} workers × {} intra-batch threads, max batch {}, {prec} ({})",
        opts.workers,
        gavina::util::parallel::resolve_threads(engine.threads()),
        opts.max_batch,
        engine.policy().describe(),
    );
    let coord = engine.serve(opts);

    let n = n_req.min(eval.n);
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|i| coord.submit(eval.images[i * 3072..(i + 1) * 3072].to_vec()))
        .collect();

    let mut logits = Vec::with_capacity(n * 10);
    for rx in rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(600))
            .expect("response");
        logits.extend_from_slice(&resp.expect_logits("request failed"));
    }
    let wall = t0.elapsed().as_secs_f64();
    let acc = accuracy(&logits, &eval.labels[..n], 10);

    let m = coord.shutdown();
    let (p50, p95, max) = m.latency_percentiles();
    let power = PowerModel::paper_calibrated();
    let sched = GavSchedule::two_level(prec, g);
    let cycles = m.sim_cycles.load(std::sync::atomic::Ordering::Relaxed);

    println!(
        "\nserved {n} requests in {wall:.2} s  ({:.1} req/s service-side)",
        m.requests_per_sec()
    );
    println!("accuracy under service config: {acc:.4}");
    println!(
        "latency  p50 {:.1} ms   p95 {:.1} ms   max {:.1} ms",
        p50 as f64 / 1e3,
        p95 as f64 / 1e3,
        max as f64 / 1e3
    );
    println!(
        "batches: {} (avg {:.1} img/batch)",
        m.batches.load(std::sync::atomic::Ordering::Relaxed),
        n as f64 / m.batches.load(std::sync::atomic::Ordering::Relaxed).max(1) as f64
    );
    println!(
        "accelerator: {cycles} cycles = {:.2} ms hw time, {:.3} mJ ({:.3} mJ/img)",
        cycles as f64 / 50e6 * 1e3,
        power.energy_mj(&sched, cycles),
        power.energy_mj(&sched, cycles) / n as f64
    );
}
