//! Error-vs-power characterization sweep (paper §IV-B, Fig. 6): run the
//! uniform-inner-product random GEMM workload through GLS-calibrated error
//! injection for every precision and every G, reporting VAR_NED and the
//! approximate-region power — the two axes of Fig. 6a/6b.
//!
//! ```bash
//! cargo run --release --example gav_sweep [--full]
//! ```
//!
//! `--full` uses the paper's [4608, 64] × [64, 4608] matrices; the default
//! is a 4× smaller slice so the sweep finishes in ~a minute.

use gavina::arch::{ArchConfig, GavSchedule, Precision};
use gavina::errmodel::{calibrate, io, CalibrationConfig};
use gavina::gls::{DelayModel, GlsContext};
use gavina::power::PowerModel;
use gavina::simulator::{GavinaSim, GemmJob};
use gavina::stats::var_ned;
use gavina::util::Prng;
use gavina::workload::{uniform_ip_matrices, ERROR_ANALYSIS_SHAPE};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let arch = ArchConfig::paper();
    let power = PowerModel::paper_calibrated();

    // Load (or produce) the calibrated tables for the paper array.
    let tables_path = std::path::Path::new("artifacts/caltables_v035.bin");
    let tables = match io::load(tables_path) {
        Ok((t, _)) => t,
        Err(_) => {
            eprintln!("no calibrated tables; running a quick GLS calibration…");
            let ctx = GlsContext::new(
                arch.c_dim,
                arch.clk_period_ps() as f64,
                DelayModel::default(),
                3,
            );
            let (t, _) = calibrate(
                &ctx,
                CalibrationConfig {
                    n_streams: 128,
                    seq_len: 32,
                    ..Default::default()
                },
            );
            t
        }
    };

    let (c_full, l_full, k_full) = ERROR_ANALYSIS_SHAPE;
    let (c, l, k) = if full {
        (c_full, l_full, k_full)
    } else {
        (c_full / 4, l_full / 2, k_full / 2)
    };
    println!("workload: [{c}, {l}] × [{k}, {c}] uniform-inner-product matrices\n");
    println!("prec | G  | VAR_NED     | approx power [mW] | system [mW] | TOP/sW");
    println!("-----+----+-------------+-------------------+-------------+-------");

    for prec in Precision::EVAL_SET {
        let mut rng = Prng::new(0xF16_6A + prec.a_bits as u64);
        let (a, b) = uniform_ip_matrices(c, l, k, prec, &mut rng);
        let exact = gavina::gemm::gemm_exact(&a, &b, c, l, k);
        for g in 0..=prec.max_g() {
            let sched = GavSchedule::two_level(prec, g);
            let mut sim = GavinaSim::new(arch.clone(), Some(&tables), 5 + g as u64);
            let rep = sim.run_gemm(&GemmJob {
                a: &a,
                b: &b,
                c,
                l,
                k,
                sched: sched.clone(),
            });
            let v = var_ned(&exact, &rep.p);
            println!(
                "{prec} | {g:2} | {v:11.4e} | {:17.2} | {:11.2} | {:6.2}",
                power.array_avg_power_mw(&sched),
                power.system_power_mw(&sched),
                power.tops_per_watt(&sched, 0.96)
            );
        }
        println!("-----+----+-------------+-------------------+-------------+-------");
    }
    println!("\nFig. 6a shape: VAR_NED decays ~exponentially with G at every precision;");
    println!("Fig. 6b shape: approx-region power spans ×{:.2} guarded→aggressive.",
             power.array_power_mw(arch.v_guard) / power.array_power_mw(arch.v_aprox));
}
