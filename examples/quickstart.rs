//! Quickstart: the GAVINA public API in one page.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Quantize two matrices and run a bit-serial GEMM exactly.
//! 2. Calibrate an undervolting error model from gate-level simulation
//!    (a small array so it runs in seconds).
//! 3. Re-run the GEMM under an aggressive GAV schedule and measure the
//!    error (VAR_NED) and the modelled power saving.
//! 4. Wrap the whole stack in the `Engine` facade: build once, infer a
//!    quantized ResNet-18 batch exactly and under aggressive GAV.

use gavina::arch::{ArchConfig, GavSchedule, Precision};
use gavina::engine::{EngineBuilder, GavPolicy};
use gavina::errmodel::{calibrate, CalibrationConfig};
use gavina::gls::{DelayModel, GlsContext};
use gavina::power::PowerModel;
use gavina::simulator::{GavinaSim, GemmJob};
use gavina::stats::var_ned;
use gavina::util::Prng;
use gavina::workload::uniform_ip_matrices;

fn main() {
    // --- 1. an exact mixed-precision bit-serial GEMM ------------------
    let arch = ArchConfig::tiny(); // [C, L, K] = [36, 4, 4] for speed
    let prec = Precision::new(4, 4);
    let mut rng = Prng::new(42);
    let (c, l, k) = (72, 8, 8); // 2x2x2 hardware tiles
    let (a, b) = uniform_ip_matrices(c, l, k, prec, &mut rng);

    let exact_sched = GavSchedule::all_guarded(prec);
    let mut sim = GavinaSim::new(arch.clone(), None, 1);
    let job = GemmJob {
        a: &a,
        b: &b,
        c,
        l,
        k,
        sched: exact_sched.clone(),
    };
    let exact = sim.run_gemm(&job);
    println!(
        "exact GEMM: {} tiles, {} cycles, utilization {:.2}",
        exact.n_tiles,
        exact.cycles,
        exact.utilization(&arch, &exact_sched)
    );

    // --- 2. calibrate the undervolting error model from GLS -----------
    let ctx = GlsContext::new(
        arch.c_dim,
        arch.clk_period_ps() as f64,
        DelayModel::default(),
        7,
    );
    let (tables, stats) = calibrate(
        &ctx,
        CalibrationConfig {
            n_streams: 128,
            seq_len: 32,
            ..Default::default()
        },
    );
    println!(
        "calibrated error model from {} GLS samples in {:.1}s",
        stats.samples, stats.gls_seconds
    );

    // --- 3. the same GEMM under aggressive undervolting ----------------
    let power = PowerModel::paper_calibrated();
    println!("\n  G | VAR_NED     | approx-region power");
    for g in 0..=prec.max_g() {
        let sched = GavSchedule::two_level(prec, g);
        let mut sim_uv = GavinaSim::new(arch.clone(), Some(&tables), 2);
        let rep = sim_uv.run_gemm(&GemmJob {
            a: &a,
            b: &b,
            c,
            l,
            k,
            sched: sched.clone(),
        });
        let err = var_ned(&exact.p, &rep.p);
        println!(
            "  {g} | {err:11.3e} | {:6.2} mW",
            power.array_avg_power_mw(&sched)
        );
    }
    println!(
        "\nundervolting boost at a2w2 (throughput unchanged): ×{:.2}",
        power.undervolting_boost(Precision::new(2, 2))
    );

    // --- 4. the Engine facade: network-level inference -----------------
    // Everything above, packaged: EngineBuilder validates weights, arch,
    // policy and tables once; the resulting Engine is immutable and
    // Arc-shareable (see `engine.serve(...)` for the serving layer).
    let tables = std::sync::Arc::new(tables);
    let builder = EngineBuilder::new()
        .synthetic_weights(0.125, 42) // narrow ResNet-18, no artifacts needed
        .precision(prec)
        .arch(arch)
        .tables(tables)
        .seed(9);
    let exact_engine = builder
        .clone()
        .policy(GavPolicy::Exact)
        .build()
        .expect("engine config");
    let uv_engine = builder
        .policy(GavPolicy::Uniform(0)) // fully undervolted
        .build()
        .expect("engine config");
    let mut rng2 = Prng::new(1);
    let images: Vec<f32> = (0..2 * 32 * 32 * 3).map(|_| rng2.next_f32()).collect();
    let exact_net = exact_engine.infer(&images, 2).expect("exact inference");
    let uv_net = uv_engine.infer(&images, 2).expect("undervolted inference");
    println!(
        "\nEngine facade: ResNet-18 logits for 2 images, exact vs fully undervolted:"
    );
    println!(
        "  {} corrupted values, logit MSE {:.3e}, {} sim cycles",
        uv_net.stats.corrupted,
        gavina::stats::mse_f32(&exact_net.logits, &uv_net.logits),
        uv_net.stats.cycles
    );
    // Malformed input is a typed error, not a panic:
    let err = uv_engine.infer(&images[..100], 1).unwrap_err();
    println!("  bad request -> {err}");
}
