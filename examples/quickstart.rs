//! Quickstart: the GAVINA public API in one page.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Quantize two matrices and run a bit-serial GEMM exactly.
//! 2. Calibrate an undervolting error model from gate-level simulation
//!    (a small array so it runs in seconds).
//! 3. Re-run the GEMM under an aggressive GAV schedule and measure the
//!    error (VAR_NED) and the modelled power saving.

use gavina::arch::{ArchConfig, GavSchedule, Precision};
use gavina::errmodel::{calibrate, CalibrationConfig};
use gavina::gls::{DelayModel, GlsContext};
use gavina::power::PowerModel;
use gavina::simulator::{GavinaSim, GemmJob};
use gavina::stats::var_ned;
use gavina::util::Prng;
use gavina::workload::uniform_ip_matrices;

fn main() {
    // --- 1. an exact mixed-precision bit-serial GEMM ------------------
    let arch = ArchConfig::tiny(); // [C, L, K] = [36, 4, 4] for speed
    let prec = Precision::new(4, 4);
    let mut rng = Prng::new(42);
    let (c, l, k) = (72, 8, 8); // 2x2x2 hardware tiles
    let (a, b) = uniform_ip_matrices(c, l, k, prec, &mut rng);

    let exact_sched = GavSchedule::all_guarded(prec);
    let mut sim = GavinaSim::new(arch.clone(), None, 1);
    let job = GemmJob {
        a: &a,
        b: &b,
        c,
        l,
        k,
        sched: exact_sched.clone(),
    };
    let exact = sim.run_gemm(&job);
    println!(
        "exact GEMM: {} tiles, {} cycles, utilization {:.2}",
        exact.n_tiles,
        exact.cycles,
        exact.utilization(&arch, &exact_sched)
    );

    // --- 2. calibrate the undervolting error model from GLS -----------
    let ctx = GlsContext::new(
        arch.c_dim,
        arch.clk_period_ps() as f64,
        DelayModel::default(),
        7,
    );
    let (tables, stats) = calibrate(
        &ctx,
        CalibrationConfig {
            n_streams: 128,
            seq_len: 32,
            ..Default::default()
        },
    );
    println!(
        "calibrated error model from {} GLS samples in {:.1}s",
        stats.samples, stats.gls_seconds
    );

    // --- 3. the same GEMM under aggressive undervolting ----------------
    let power = PowerModel::paper_calibrated();
    println!("\n  G | VAR_NED     | approx-region power");
    for g in 0..=prec.max_g() {
        let sched = GavSchedule::two_level(prec, g);
        let mut sim_uv = GavinaSim::new(arch.clone(), Some(&tables), 2);
        let rep = sim_uv.run_gemm(&GemmJob {
            a: &a,
            b: &b,
            c,
            l,
            k,
            sched: sched.clone(),
        });
        let err = var_ned(&exact.p, &rep.p);
        println!(
            "  {g} | {err:11.3e} | {:6.2} mW",
            power.array_avg_power_mw(&sched)
        );
    }
    println!(
        "\nundervolting boost at a2w2 (throughput unchanged): ×{:.2}",
        power.undervolting_boost(Precision::new(2, 2))
    );
}
