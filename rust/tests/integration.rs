//! Cross-module integration tests: the full pipeline (workload →
//! quantization → cycle simulator → error model → metrics → power) wired
//! together the way the benches and the CLI use it, plus artifact-backed
//! checks that run when `make artifacts` has been executed.

use std::path::{Path, PathBuf};

use gavina::arch::{ArchConfig, GavSchedule, Precision};
use gavina::errmodel::{calibrate, CalibrationConfig, ErrorTables, ModelParams};
use gavina::gls::{DelayModel, GlsContext};
use gavina::power::PowerModel;
use gavina::simulator::{GavinaSim, GemmJob};
use gavina::stats::var_ned;
use gavina::util::Prng;
use gavina::workload::uniform_ip_matrices;

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Calibrate once on the tiny array and reuse (GLS is the slow part).
fn tiny_tables() -> (ArchConfig, ErrorTables) {
    let arch = ArchConfig::tiny();
    let ctx = GlsContext::new(
        arch.c_dim,
        arch.clk_period_ps() as f64,
        DelayModel::default(),
        0xA11,
    );
    let (t, stats) = calibrate(
        &ctx,
        CalibrationConfig {
            n_streams: 160,
            seq_len: 32,
            ..Default::default()
        },
    );
    assert!(stats.samples > 0);
    (arch, t)
}

#[test]
fn pipeline_error_decays_exponentially_with_g() {
    // The Fig. 6a headline on the full pipeline: VAR_NED at G=0 must
    // exceed VAR_NED at mid G, which must exceed ~0 at G_max.
    let (arch, tables) = tiny_tables();
    let prec = Precision::new(4, 4);
    let mut rng = Prng::new(1);
    let (c, l, k) = (arch.c_dim * 3, arch.l_dim * 2, arch.k_dim * 2);
    let (a, b) = uniform_ip_matrices(c, l, k, prec, &mut rng);
    let exact = gavina::gemm::gemm_exact(&a, &b, c, l, k);

    let var_at = |g: u32| {
        let mut sim = GavinaSim::new(arch.clone(), Some(&tables), 7 + g as u64);
        let rep = sim.run_gemm(&GemmJob {
            a: &a,
            b: &b,
            c,
            l,
            k,
            sched: GavSchedule::two_level(prec, g),
        });
        var_ned(&exact, &rep.p)
    };
    let v0 = var_at(0);
    let v_max = var_at(prec.max_g());
    assert_eq!(v_max, 0.0);
    assert!(v0 > 0.0, "fully undervolted run must show errors");
    // Monotone trend over the sweep (tolerate sampling noise ×3).
    let mut last = f64::INFINITY;
    for g in 0..=prec.max_g() {
        let v = var_at(g);
        assert!(v <= last * 3.0 + 1e-12, "VAR_NED trend broken at g={g}");
        last = v;
    }
}

#[test]
fn model_tracks_gls_on_the_pipeline() {
    // §IV-C acceptance on the tiny array: cycle-sim with LUT injection vs
    // cycle-sim with full GLS, same operands and schedule — VAR_NED within
    // an order of magnitude (the paper reports 8% on the big array with a
    // much larger calibration run).
    let (arch, tables) = tiny_tables();
    let ctx = GlsContext::new(
        arch.c_dim,
        arch.clk_period_ps() as f64,
        DelayModel::default(),
        0xA11, // same context family as calibration
    );
    let prec = Precision::new(4, 4);
    let sched = GavSchedule::all_approx(prec);
    let mut rng = Prng::new(3);
    let (c, l, k) = (arch.c_dim, arch.l_dim, arch.k_dim);
    let mut v_model_acc = 0.0;
    let mut v_gls_acc = 0.0;
    for trial in 0..8 {
        let (a, b) = uniform_ip_matrices(c, l, k, prec, &mut rng);
        let exact = gavina::gemm::gemm_exact(&a, &b, c, l, k);
        let job = GemmJob {
            a: &a,
            b: &b,
            c,
            l,
            k,
            sched: sched.clone(),
        };
        let mut sim_m = GavinaSim::new(arch.clone(), Some(&tables), 100 + trial);
        v_model_acc += var_ned(&exact, &sim_m.run_gemm(&job).p);
        let mut sim_g = GavinaSim::new_gls(arch.clone(), &ctx, 200 + trial);
        v_gls_acc += var_ned(&exact, &sim_g.run_gemm(&job).p);
    }
    assert!(v_gls_acc > 0.0, "GLS backend must produce errors");
    assert!(v_model_acc > 0.0, "model backend must produce errors");
    let ratio = v_model_acc / v_gls_acc;
    assert!(
        (0.1..10.0).contains(&ratio),
        "model/GLS VAR_NED ratio {ratio:.2} out of band"
    );
}

#[test]
fn power_and_error_tradeoff_is_consistent() {
    // More guarding => less error AND more power. Both monotone.
    let (arch, tables) = tiny_tables();
    let power = PowerModel::paper_calibrated();
    let prec = Precision::new(3, 3);
    let mut rng = Prng::new(5);
    let (c, l, k) = (arch.c_dim * 2, arch.l_dim, arch.k_dim);
    let (a, b) = uniform_ip_matrices(c, l, k, prec, &mut rng);
    let exact = gavina::gemm::gemm_exact(&a, &b, c, l, k);
    let mut last_power = -1.0;
    let mut first_err = None;
    let mut last_err = None;
    for g in 0..=prec.max_g() {
        let sched = GavSchedule::two_level(prec, g);
        let p = power.system_power_mw(&sched);
        assert!(p >= last_power, "power must grow with G");
        last_power = p;
        let mut sim = GavinaSim::new(arch.clone(), Some(&tables), 11);
        let rep = sim.run_gemm(&GemmJob {
            a: &a,
            b: &b,
            c,
            l,
            k,
            sched,
        });
        let v = var_ned(&exact, &rep.p);
        if g == 0 {
            first_err = Some(v);
        }
        last_err = Some(v);
    }
    assert!(first_err.unwrap() >= last_err.unwrap());
    assert_eq!(last_err.unwrap(), 0.0);
}

#[test]
fn errmodel_io_roundtrip_through_pipeline() {
    let (arch, tables) = tiny_tables();
    let dir = std::env::temp_dir().join("gavina_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tables.bin");
    gavina::errmodel::io::save(&path, &tables, 0.35).unwrap();
    let (loaded, v) = gavina::errmodel::io::load(&path).unwrap();
    assert_eq!(v, 0.35);

    // Same seed + same tables => identical corrupted results.
    let prec = Precision::new(2, 2);
    let mut rng = Prng::new(9);
    let (c, l, k) = (arch.c_dim, arch.l_dim, arch.k_dim);
    let (a, b) = uniform_ip_matrices(c, l, k, prec, &mut rng);
    let job = GemmJob {
        a: &a,
        b: &b,
        c,
        l,
        k,
        sched: GavSchedule::all_approx(prec),
    };
    let run = |t: &ErrorTables| {
        let mut sim = GavinaSim::new(arch.clone(), Some(t), 42);
        sim.run_gemm(&job).p
    };
    assert_eq!(run(&tables), run(&loaded));
}

#[test]
fn ilp_allocation_beats_uniform_on_synthetic_profile() {
    // A skewed sensitivity profile (like Fig. 8a): ILP must achieve lower
    // total MSE than uniform G at the same average budget.
    let mut rng = Prng::new(13);
    let n_layers = 12;
    let n_g = 9;
    let mut layers = Vec::new();
    for li in 0..n_layers {
        let scale = if li == 0 { 50.0 } else { rng.next_f64() * 2.0 };
        let cost: Vec<f64> = (0..n_g)
            .map(|g| scale * (-(g as f64) * 0.9).exp())
            .collect();
        layers.push(gavina::ilp::LayerChoices {
            ops: 1.0 + rng.next_f64() * 10.0,
            cost,
        });
    }
    let uniform_g = 4u32;
    let uniform_cost: f64 = layers.iter().map(|l| l.cost[uniform_g as usize]).sum();
    let alloc = gavina::ilp::GavAllocator::new(layers).solve(uniform_g as f64);
    assert!(
        alloc.cost <= uniform_cost + 1e-12,
        "ILP {:.4} must beat uniform {:.4}",
        alloc.cost,
        uniform_cost
    );
}

#[test]
fn dense_table_export_matches_ragged_probs() {
    let params = ModelParams::paper(36);
    let (_, tables) = tiny_tables();
    assert_eq!(tables.params, params);
    let dense = tables.to_dense();
    let nc_full = 1 << params.n_nei;
    for bit in 0..params.s_bits {
        for e in (0..=params.c_dim as u16).step_by(7) {
            for pb in 0..params.p_bins {
                for cond in 0..params.n_cond(bit) {
                    let idx = ((bit * (params.c_dim + 1) + e as usize) * params.p_bins + pb)
                        * nc_full
                        + cond;
                    assert_eq!(dense[idx], tables.prob(bit, e, pb, cond));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Artifact-backed integration (skipped when `make artifacts` hasn't run).
// ---------------------------------------------------------------------

#[test]
fn trained_weights_reach_usable_accuracy() {
    let wpath = artifacts_dir().join("weights_a8w8.bin");
    let dpath = artifacts_dir().join("dataset_eval.bin");
    if !wpath.exists() || !dpath.exists() {
        eprintln!("skipping (no artifacts)");
        return;
    }
    let eval = gavina::dnn::load_eval_set(&dpath).unwrap();
    let n = 64.min(eval.n);
    let engine = gavina::engine::EngineBuilder::new()
        .weights_from_file(&wpath)
        .unwrap()
        .precision(Precision::new(8, 8))
        .backend_float()
        .build()
        .unwrap();
    let out = engine
        .infer_batched(&eval.images[..n * 3072], n, 16)
        .unwrap();
    let acc = gavina::stats::accuracy(&out.logits, &eval.labels[..n], out.classes);
    assert!(
        acc > 0.6,
        "a8w8 QAT weights should classify well above chance: {acc}"
    );
}

#[test]
fn precision_ladder_accuracy_is_monotone_ish() {
    // Paper trend: accuracy degrades as precision drops (quantization
    // noise), a8w8 ≥ a4w4 ≥ a3w3 (a2w2 can be noisy; allow slack).
    let dpath = artifacts_dir().join("dataset_eval.bin");
    if !dpath.exists() {
        return;
    }
    let eval = gavina::dnn::load_eval_set(&dpath).unwrap();
    let n = 96.min(eval.n);
    let mut accs = Vec::new();
    for prec in [Precision::new(8, 8), Precision::new(4, 4), Precision::new(3, 3)] {
        let wpath = artifacts_dir().join(format!("weights_{}.bin", prec.tag()));
        if !wpath.exists() {
            return;
        }
        let engine = gavina::engine::EngineBuilder::new()
            .weights_from_file(&wpath)
            .unwrap()
            .precision(prec)
            .backend_float()
            .build()
            .unwrap();
        let out = engine
            .infer_batched(&eval.images[..n * 3072], n, 16)
            .unwrap();
        accs.push(gavina::stats::accuracy(
            &out.logits,
            &eval.labels[..n],
            out.classes,
        ));
    }
    assert!(
        accs[0] + 0.05 >= accs[1] && accs[1] + 0.08 >= accs[2],
        "precision ladder accuracy not trending down: {accs:?}"
    );
}

// Requires the real PJRT runtime: in the default build `Runtime::new`
// is the stub that always errors, and the manifest-exists guard below
// would not save us once `make artifacts` has run.
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_artifact_cross_check_all_precisions() {
    use gavina::quant::PackedPlanes;
    let dir = artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        return;
    }
    let mut rt = gavina::runtime::Runtime::new(&dir).unwrap();
    let (c, l, k) = (576, 8, 16);
    let mut rng = Prng::new(21);
    for prec in Precision::EVAL_SET {
        let (a, b) = gavina::workload::gemm_workload(c, l, k, prec, &mut rng);
        let pa = PackedPlanes::from_a_matrix(&a, c, l, prec.a_bits);
        let pb = PackedPlanes::from_b_matrix(&b, k, c, prec.b_bits);
        let mut a_planes = Vec::new();
        for plane in 0..prec.a_bits {
            let dense = pa.unpack_plane(plane); // [l, c]
            for ci in 0..c {
                for li in 0..l {
                    a_planes.push(dense[li * c + ci]);
                }
            }
        }
        let mut b_planes = Vec::new();
        for plane in 0..prec.b_bits {
            b_planes.extend_from_slice(&pb.unpack_plane(plane));
        }
        let hlo = rt
            .bitserial_gemm_tile(prec, &a_planes, &b_planes, c, l, k)
            .unwrap();
        let native = gavina::gemm::bitserial_gemm(&pa, &pb);
        assert!(
            hlo.iter().zip(&native).all(|(h, n)| *h as i64 == *n),
            "{prec}: PJRT artifact disagrees with native GEMM"
        );
    }
}

// Drives the artifact through the raw `xla` literal API, so it only
// compiles when the real PJRT runtime (feature `pjrt`) is built.
#[cfg(feature = "pjrt")]
#[test]
fn errinject_artifact_matches_native_model() {
    // The L2 JAX port of Listing 2 (AOT-lowered to errinject_a4w4) and
    // the native Rust sampler must agree *exactly* when fed the same
    // pre-drawn uniforms — this pins the two implementations of the
    // paper's error model against each other across the language boundary.
    let dir = artifacts_dir();
    if !dir.join("errinject_a4w4.hlo.txt").exists() {
        return;
    }
    let arch = ArchConfig::paper();
    let prec = Precision::new(4, 4);
    let params = ModelParams::paper(arch.c_dim);
    let (s_bits, p_bins, n_nei) = (params.s_bits, params.p_bins, params.n_nei);
    let (k, l) = (arch.k_dim, arch.l_dim);
    let seqlen = prec.steps();

    // Random-ish tables with structure.
    let mut rng = Prng::new(77);
    let mut tables = ErrorTables::zeroed(params);
    for bit in 3..s_bits {
        for e in 0..=params.c_dim as u16 {
            for pb in 0..p_bins {
                for cd in 0..params.n_cond(bit) {
                    if rng.chance(0.3) {
                        tables.set_prob(bit, e, pb, cd, rng.next_f32() * 0.4);
                    }
                }
            }
        }
    }

    // Exact sequence + uniforms + schedule.
    let (a, b) = uniform_ip_matrices(arch.c_dim, l, k, prec, &mut rng);
    let pa = gavina::quant::PackedPlanes::from_a_matrix(&a, arch.c_dim, l, prec.a_bits);
    let pb = gavina::quant::PackedPlanes::from_b_matrix(&b, k, arch.c_dim, prec.b_bits);
    let seq = gavina::gemm::ipe_sequence(&pa, &pb);
    let uniforms: Vec<f32> = (0..seqlen * k * l * s_bits)
        .map(|_| rng.next_f32())
        .collect();
    let sched = GavSchedule::two_level(prec, 3);
    let approx_mask = sched.approx_mask();

    // --- native evaluation with the *given* uniforms (ref.py semantics:
    // uniform index [t, kl, bit]) ---
    let mut native: Vec<Vec<u16>> = seq.clone();
    {
        let mut prev = vec![0u16; k * l];
        for t in 0..seqlen {
            let exact_step = seq[t].clone();
            if approx_mask[t] {
                for i in 0..k * l {
                    let exact = exact_step[i];
                    let pbin = params.prev_bin(prev[i]);
                    let mut flips = 0u32;
                    for bit in (0..s_bits).rev() {
                        let nei = s_bits - 1 - bit;
                        let cond = if nei == 0 {
                            0
                        } else {
                            let take = n_nei.min(nei);
                            ((flips >> (bit + 1)) & ((1 << take) - 1)) as usize
                        };
                        let u = uniforms[(t * k * l + i) * s_bits + bit];
                        if u < tables.prob(bit, exact, pbin, cond) {
                            flips |= 1 << bit;
                        }
                    }
                    native[t][i] = exact ^ flips as u16;
                }
            }
            prev = exact_step;
        }
    }

    // --- artifact evaluation ---
    // Inputs: exact i32[T,K,L], tables f32[s,C+1,pb,4], uniforms
    // f32[T,K,L,s], approx pred[T]. The artifact's [K,L] layout is
    // iPE-major (k, l) like ours.
    let mut rt = gavina::runtime::Runtime::new(&dir).unwrap();
    let exact_f: Vec<f32> = seq.iter().flat_map(|s| s.iter().map(|&v| v as f32)).collect();
    // execute_f32 only feeds f32 literals; errinject takes i32+pred inputs,
    // so drive it through the raw literal API here.
    let exe = rt.load("errinject_a4w4.hlo.txt").unwrap();
    let exact_i: Vec<i32> = exact_f.iter().map(|&v| v as i32).collect();
    let lit_exact = xla::Literal::vec1(&exact_i)
        .reshape(&[seqlen as i64, k as i64, l as i64])
        .unwrap();
    let dense = tables.to_dense();
    let lit_tables = xla::Literal::vec1(&dense)
        .reshape(&[
            s_bits as i64,
            (params.c_dim + 1) as i64,
            p_bins as i64,
            (1 << n_nei) as i64,
        ])
        .unwrap();
    let lit_uni = xla::Literal::vec1(&uniforms)
        .reshape(&[seqlen as i64, k as i64, l as i64, s_bits as i64])
        .unwrap();
    let mask_i32: Vec<i32> = approx_mask.iter().map(|&b| b as i32).collect();
    let lit_mask = xla::Literal::vec1(&mask_i32)
        .reshape(&[seqlen as i64])
        .unwrap()
        .convert(xla::PrimitiveType::Pred)
        .unwrap();
    let result = exe
        .execute::<xla::Literal>(&[lit_exact, lit_tables, lit_uni, lit_mask])
        .unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let out = result.to_tuple1().unwrap();
    let artifact: Vec<i32> = out.to_vec::<i32>().unwrap();

    let native_flat: Vec<i32> = native
        .iter()
        .flat_map(|s| s.iter().map(|&v| v as i32))
        .collect();
    assert_eq!(artifact.len(), native_flat.len());
    let diffs = artifact
        .iter()
        .zip(&native_flat)
        .filter(|(a, b)| a != b)
        .count();
    assert_eq!(
        diffs, 0,
        "L2 artifact and native Listing-2 model disagree on {diffs} of {} values",
        native_flat.len()
    );
    // Sanity: the test actually injected something.
    let exact_flat: Vec<i32> = exact_f.iter().map(|&v| v as i32).collect();
    assert_ne!(artifact, exact_flat, "test vacuous: no errors injected");
}
