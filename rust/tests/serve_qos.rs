//! Integration tests for the `gavina::serve` QoS surface:
//!
//! * a full admission queue yields a typed `Overloaded` error — the
//!   service stays up and workers stay alive,
//! * `shutdown()` drains every *accepted* ticket,
//! * the `exact` tier's served logits are bit-identical to
//!   `Engine::infer` on the same images, regardless of traffic around
//!   them — including when exact requests are packed into cross-request
//!   batches (per-image activation quantization),
//! * the canary closes the governor loop: sampling is replay-
//!   deterministic, re-runs are bit-identical to `Engine::infer` and
//!   never consume admission permits, and measured drift steps the
//!   ladder toward guarded and holds it there through the dwell.
//!
//! Concurrency-sensitive tests pin worker state with a gated backend
//! (every GEMM blocks until the test opens the gate) instead of timing
//! assumptions, so they hold under ThreadSanitizer and loaded CI
//! machines alike.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use gavina::arch::{ArchConfig, Precision};
use gavina::engine::backend::{BackendGemm, LayerGemm};
use gavina::engine::{Engine, EngineBuilder, ExecBackend, FloatBackend, GavPolicy, GavinaError};
use gavina::serve::{CanaryOptions, ServeOptions, StepTrigger, SubmitOptions, TierSpec};
use gavina::util::Prng;

const IMAGE_LEN: usize = 32 * 32 * 3;

fn tiny_engine(policy: GavPolicy) -> Arc<Engine> {
    Arc::new(
        EngineBuilder::new()
            .synthetic_weights(0.125, 1)
            .precision(Precision::new(2, 2))
            .arch(ArchConfig::tiny())
            .policy(policy)
            .seed(9)
            .build()
            .unwrap(),
    )
}

fn rand_images(seed: u64, n: usize) -> Vec<Vec<f32>> {
    let mut rng = Prng::new(seed);
    (0..n)
        .map(|_| (0..IMAGE_LEN).map(|_| rng.next_f32()).collect())
        .collect()
}

/// Blocks every GEMM until opened; reports how many worker threads are
/// parked inside the engine. Duplicated from the serve unit tests —
/// there is no shared test-helper crate.
struct Gate {
    state: Mutex<(bool, usize)>, // (open, currently blocked)
    cv: Condvar,
}

impl Gate {
    fn new() -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new((false, 0)),
            cv: Condvar::new(),
        })
    }

    fn open(&self) {
        self.state.lock().unwrap().0 = true;
        self.cv.notify_all();
    }

    fn pass(&self) {
        let mut s = self.state.lock().unwrap();
        if s.0 {
            return;
        }
        s.1 += 1;
        self.cv.notify_all();
        while !s.0 {
            s = self.cv.wait(s).unwrap();
        }
        s.1 -= 1;
    }

    fn await_blocked(&self, n: usize) {
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut s = self.state.lock().unwrap();
        while s.1 < n {
            assert!(Instant::now() < deadline, "gate never saw {n} blocked workers");
            let (guard, _) = self.cv.wait_timeout(s, Duration::from_millis(20)).unwrap();
            s = guard;
        }
    }
}

struct GatedFloat {
    gate: Arc<Gate>,
}

impl ExecBackend for GatedFloat {
    fn name(&self) -> &'static str {
        "gated-float"
    }

    fn run_layer_gemm(&self, job: &LayerGemm) -> BackendGemm {
        self.gate.pass();
        FloatBackend.run_layer_gemm(job)
    }

    fn is_simulated(&self) -> bool {
        false
    }
}

fn gated_engine(gate: &Arc<Gate>, policy: GavPolicy) -> Arc<Engine> {
    Arc::new(
        EngineBuilder::new()
            .synthetic_weights(0.125, 1)
            .precision(Precision::new(2, 2))
            .arch(ArchConfig::tiny())
            .backend(Arc::new(GatedFloat {
                gate: Arc::clone(gate),
            }))
            .policy(policy)
            .seed(9)
            .threads(1)
            .build()
            .unwrap(),
    )
}

fn one_tier(replicas: usize, queue_depth: usize, max_batch: usize) -> ServeOptions {
    ServeOptions {
        replicas,
        queue_depth,
        steal: true,
        steal_reserve: 2,
        default_tier: "guarded".into(),
        tiers: vec![TierSpec {
            name: "guarded".into(),
            policy: None,
            max_batch,
        }],
        governor: None,
        canary: None,
    }
}

#[test]
fn full_admission_queue_is_typed_overloaded_and_drains_on_shutdown() {
    // The gate pins the single replica inside its first batch, so every
    // accepted request stays in flight and admission fills
    // deterministically.
    let gate = Gate::new();
    let service = gated_engine(&gate, GavPolicy::Exact)
        .serve(one_tier(1, 4, 64))
        .unwrap();
    let session = service.session();
    let images = rand_images(2, 4);
    let tickets: Vec<_> = images
        .iter()
        .map(|img| session.submit(img.clone()).expect("within capacity"))
        .collect();
    assert_eq!(service.in_flight(), 4);

    // The 5th submit must be a typed rejection — never a panic, a block,
    // or a silent drop.
    match session.submit(images[0].clone()) {
        Err(GavinaError::Overloaded { capacity }) => assert_eq!(capacity, 4),
        other => panic!("expected Overloaded, got {other:?}"),
    }
    assert_eq!(service.rejected(), 1);

    // The service is still up: shutdown drains every *accepted* ticket
    // once the gate opens.
    let handle = std::thread::spawn(move || service.shutdown());
    gate.open();
    for t in &tickets {
        let resp = t
            .wait_timeout(Duration::from_secs(120))
            .unwrap()
            .expect("accepted ticket must be answered");
        assert_eq!(resp.expect_logits("drained request").len(), 10);
    }
    let report = handle.join().unwrap();
    assert_eq!(report.requests(), 4, "all accepted tickets served");
    assert_eq!(report.rejected, 1);
    assert_eq!(report.tier("guarded").unwrap().errors, 0);
}

#[test]
fn capacity_frees_after_responses() {
    let service = tiny_engine(GavPolicy::Exact).serve(one_tier(1, 1, 1)).unwrap();
    let session = service.session();
    let images = rand_images(3, 3);
    // Sequential submit/wait cycles through a depth-1 queue: each
    // response must free its admission slot for the next request.
    for img in &images {
        let t = session.submit(img.clone()).expect("slot free after response");
        let resp = t.wait_timeout(Duration::from_secs(120)).unwrap().expect("served");
        assert_eq!(resp.expect_logits("served").len(), 10);
    }
    let report = service.shutdown();
    assert_eq!(report.requests(), 3);
    assert_eq!(report.rejected, 0);
}

#[test]
fn exact_tier_is_bit_identical_to_engine_infer() {
    // Base engine undervolts (uniform G=1); the exact tier pre-resolves
    // a fully-guarded variant sharing its packed planes. The tier now
    // batches (max_batch = 4): per-image activation quantization keeps
    // every packed request bit-identical to a standalone single-image
    // infer, whatever its batch co-tenants are.
    let engine = tiny_engine(GavPolicy::Uniform(1));
    let opts = ServeOptions {
        replicas: 2,
        queue_depth: 64,
        steal: true,
        steal_reserve: 2,
        default_tier: "guarded".into(),
        tiers: vec![
            TierSpec::new("exact", Some(GavPolicy::Exact)).max_batch(4),
            TierSpec::new("guarded", None).max_batch(4),
        ],
        governor: None,
        canary: None,
    };
    let service = Arc::clone(&engine).serve(opts).unwrap();
    let session = service.session();

    let images = rand_images(5, 6);
    // Interleave exact-tier requests with guarded traffic: exact
    // requests land in cross-request batches (possibly stolen, possibly
    // packed together) and must still match standalone execution bit for
    // bit.
    let mut exact_tickets = Vec::new();
    for img in &images {
        let _ = session.submit(img.clone()).unwrap(); // guarded noise
        exact_tickets.push(
            session
                .submit_with(img.clone(), SubmitOptions::new().tier("exact"))
                .unwrap(),
        );
    }

    // The reference: a standalone fully-guarded engine over the same
    // weights, one image per call.
    let reference = tiny_engine(GavPolicy::Exact);
    for (img, t) in images.iter().zip(exact_tickets) {
        let resp = t.wait_timeout(Duration::from_secs(120)).unwrap().expect("response");
        assert_eq!(resp.tier(), "exact");
        assert!(resp.batch_size() >= 1 && resp.batch_size() <= 4);
        let served = resp.expect_logits("exact request");
        let expect = reference.infer(img, 1).unwrap().logits;
        assert_eq!(
            served, expect,
            "exact tier must be bit-identical to Engine::infer at any batch size"
        );
    }
    service.shutdown();
}

#[test]
fn governed_service_swaps_schedules_under_pinned_load() {
    use gavina::serve::GovernorOptions;
    // Pin high load (the gate parks the single replica inside its first
    // batch; the rest of the submissions stay queued), let the governor
    // tick a few times, and watch the default tier's live schedule step
    // toward aggressive undervolting.
    let gate = Gate::new();
    let mut opts = one_tier(1, 8, 64);
    opts.governor = Some(GovernorOptions {
        period: Duration::from_millis(5),
        high_load: 0.6,
        low_load: 0.2,
        ..Default::default()
    });
    let engine = gated_engine(&gate, GavPolicy::Exact);
    let max_g = engine.precision().max_g();
    let service = Arc::clone(&engine).serve(opts).unwrap();
    let session = service.session();
    let before = service.tier_layer_gs("guarded").unwrap();
    assert_eq!(before, vec![max_g; before.len()]);

    let images = rand_images(7, 6);
    let tickets: Vec<_> = images
        .iter()
        .map(|img| session.submit(img.clone()).unwrap())
        .collect();
    // load = 6/8 = 0.75 ≥ 0.6: the governor must step down, one rung per
    // period. Wait until the recorded trajectory holds at least two
    // distinct schedules (i.e. it actually moved while load was pinned).
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let traj = service.governor_trajectory();
        let mut seen: Vec<Vec<u32>> = Vec::new();
        for s in &traj {
            if !seen.contains(&s.layer_gs) {
                seen.push(s.layer_gs.clone());
            }
        }
        if seen.len() >= 2 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "governor never adapted under pinned load"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    let now_gs = service.tier_layer_gs("guarded").unwrap();
    assert!(
        now_gs.iter().sum::<u32>() < before.iter().sum::<u32>(),
        "under load the schedule must move toward lower G"
    );
    let handle = std::thread::spawn(move || service.shutdown());
    gate.open();
    for t in tickets {
        t.wait_timeout(Duration::from_secs(120))
            .unwrap()
            .expect("drained")
            .expect_logits("drained request");
    }
    let report = handle.join().unwrap();
    assert!(!report.governor.is_empty());
    // The trajectory itself records the movement.
    let first = &report.governor.first().unwrap().layer_gs;
    let distinct = report.governor.iter().any(|s| &s.layer_gs != first);
    assert!(distinct, "trajectory must contain at least two schedules");
    // Every trajectory entry carries its trigger: with the canary off,
    // the only signals are load and steady.
    assert!(report
        .governor
        .iter()
        .all(|s| matches!(s.trigger, StepTrigger::Load | StepTrigger::Steady)));
}

/// MSB-always-flips error tables: every undervolted significance step
/// corrupts loudly, so an aggressive schedule drifts hard and a guarded
/// one is clean.
fn hot_tables(arch: &ArchConfig) -> gavina::errmodel::ErrorTables {
    use gavina::errmodel::{ErrorTables, ModelParams};
    let params = ModelParams::paper(arch.c_dim);
    let mut tables = ErrorTables::zeroed(params);
    let msb = params.s_bits - 1;
    for e in 0..=params.c_dim as u16 {
        for pb in 0..params.p_bins {
            tables.set_prob(msb, e, pb, 0, 1.0);
        }
    }
    tables
}

fn hot_engine(seed: u64) -> Arc<Engine> {
    let arch = ArchConfig::tiny();
    Arc::new(
        EngineBuilder::new()
            .synthetic_weights(0.125, 1)
            .precision(Precision::new(2, 2))
            .arch(arch.clone())
            .tables(Arc::new(hot_tables(&arch)))
            .policy(GavPolicy::Uniform(0))
            .seed(seed)
            .threads(1)
            .build()
            .unwrap(),
    )
}

/// One tier, one replica, canary on — sequential submit/wait keeps the
/// batch-id sequence (and therefore every injection and sampling stream)
/// fully deterministic.
fn canary_opts(sample_rate: f64) -> ServeOptions {
    ServeOptions {
        canary: Some(CanaryOptions {
            sample_rate,
            window: 16,
            min_samples: 2,
            ..Default::default()
        }),
        ..one_tier(1, 8, 1)
    }
}

#[test]
fn canary_rerun_is_bit_identical_to_engine_infer() {
    // The re-run entry point is the per-request data plane: row-sliced
    // logits from one canary_rerun call must equal standalone
    // Engine::infer on each image (per-image activation quantization).
    let engine = tiny_engine(GavPolicy::Exact);
    let images = rand_images(21, 3);
    let rows: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();
    let rerun = engine.canary_rerun(&rows).unwrap();
    let c = rerun.classes;
    for (i, img) in images.iter().enumerate() {
        assert_eq!(
            &rerun.logits[i * c..(i + 1) * c],
            engine.infer(img, 1).unwrap().logits.as_slice(),
            "canary re-run must be bit-identical to Engine::infer"
        );
    }
}

#[test]
fn canary_sampling_and_estimates_replay_identically() {
    // Two services over the same engine, fed the same request stream:
    // the sampled set (pinned by the XOR fingerprint) and every drift
    // estimate must reproduce exactly.
    let engine = hot_engine(9);
    let images = rand_images(23, 10);
    let run = || {
        let service = Arc::clone(&engine).serve(canary_opts(0.5)).unwrap();
        let session = service.session();
        for img in &images {
            session
                .submit(img.clone())
                .unwrap()
                .wait_timeout(Duration::from_secs(120))
                .unwrap()
                .expect("served");
        }
        let report = service.shutdown();
        assert_eq!(report.canary.len(), 1, "one observed tier");
        report.canary.into_iter().next().unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.fingerprint, b.fingerprint, "identical sampled sets");
    assert_eq!(a.sampled, b.sampled);
    assert_eq!(a.flips, b.flips);
    assert_eq!(a.observed_flip_rate, b.observed_flip_rate);
    assert_eq!(a.mean_linf, b.mean_linf);
    assert_eq!(a.max_linf, b.max_linf);
    assert_eq!(a.layer_step_error_rates, b.layer_step_error_rates);
    assert!(a.sampled > 0, "rate 0.5 over 10 requests must sample");
    assert!(
        a.max_linf > 0.0,
        "hot tables on an aggressive tier must show measurable drift"
    );
}

#[test]
fn canary_reruns_never_consume_admission_permits() {
    // queue_depth 1 + sample_rate 1.0: every request is re-run on the
    // reference, yet the sequential submit/wait loop must never see
    // Overloaded — the re-run path sits below the admission gate.
    let opts = ServeOptions {
        canary: Some(CanaryOptions {
            sample_rate: 1.0,
            ..Default::default()
        }),
        ..one_tier(1, 1, 1)
    };
    let engine = tiny_engine(GavPolicy::Uniform(1));
    let service = Arc::clone(&engine).serve(opts).unwrap();
    let session = service.session();
    let images = rand_images(29, 8);
    for img in &images {
        let t = session.submit(img.clone()).expect("slot free: canary holds no permit");
        t.wait_timeout(Duration::from_secs(120)).unwrap().expect("served");
    }
    let report = service.shutdown();
    assert_eq!(report.rejected, 0, "canary re-runs must not occupy admission slots");
    assert_eq!(report.canary.len(), 1);
    let c = &report.canary[0];
    assert_eq!(c.sampled, 8, "rate 1.0 samples every request");
    // No error tables: the undervolted tier computes exactly — served
    // logits match the reference bit for bit.
    assert_eq!(c.flips, 0);
    assert_eq!(c.max_linf, 0.0);
}

#[test]
fn measured_drift_escalates_the_governor_and_dwell_blocks_redescent() {
    use gavina::serve::GovernorOptions;
    // Aggressive default tier with always-flip tables, load pinned HIGH
    // (which, alone, would hold the ladder at its most aggressive rung):
    // only measured drift can move the schedule toward guarded, so every
    // ascent is Drift-tagged; afterwards the huge dwell must veto the
    // high-load descent (DwellHold) — the ladder may not flap back.
    let engine = hot_engine(31);
    let mut opts = ServeOptions {
        canary: Some(CanaryOptions {
            sample_rate: 1.0,
            window: 8,
            min_samples: 2,
            high_watermark: 0.05,
            low_watermark: 0.01,
            dwell_ticks: 100_000,
        }),
        ..one_tier(1, 16, 4)
    };
    opts.governor = Some(GovernorOptions {
        period: Duration::from_millis(5),
        high_load: 0.5,
        low_load: 0.05,
        ..Default::default()
    });
    let max_g = engine.precision().max_g();
    let service = Arc::clone(&engine).serve(opts).unwrap();
    let session = service.session();
    let before = service.tier_layer_gs("guarded").unwrap();
    assert!(before.iter().sum::<u32>() < before.len() as u32 * max_g);

    // Closed loop keeping ~12 in flight: load ≈ 12/16 = 0.75 ≥ 0.5, so
    // the load signal always votes for the aggressive rung.
    let images = rand_images(37, 16);
    let mut outstanding: std::collections::VecDeque<gavina::serve::Ticket> = Default::default();
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut i = 0usize;
    let mut guarded_since: Option<usize> = None;
    loop {
        while outstanding.len() < 12 {
            match session.submit(images[i % images.len()].clone()) {
                Ok(t) => outstanding.push_back(t),
                Err(GavinaError::Overloaded { .. }) => break,
                Err(e) => panic!("submit failed: {e}"),
            }
            i += 1;
        }
        if let Some(t) = outstanding.pop_front() {
            t.wait_timeout(Duration::from_secs(120)).unwrap().expect("served");
        }
        let gs = service.tier_layer_gs("guarded").unwrap();
        let ticks = service.governor_ticks();
        if gs.iter().all(|&g| g == max_g) {
            // Fully guarded: keep the load pinned for ≥ 10 more governor
            // ticks so the dwell veto is actually exercised.
            let since = *guarded_since.get_or_insert(ticks);
            if ticks >= since + 10 {
                break;
            }
        }
        assert!(
            Instant::now() < deadline,
            "drift never escalated the ladder to fully guarded"
        );
    }
    for t in outstanding {
        t.wait_timeout(Duration::from_secs(120)).unwrap().expect("drained");
    }
    let report = service.shutdown();

    // (1) Drift did the climbing: ascents under pinned-high load carry
    // the Drift tag.
    let traj = &report.governor;
    let first_drift = traj
        .iter()
        .position(|s| s.trigger == StepTrigger::Drift)
        .expect("at least one Drift-tagged escalation");
    // (2) No re-descent after escalation began: mean G is monotonically
    // non-decreasing from the first Drift step on — oscillating load
    // cannot flap the schedule while drift is hot.
    for w in traj[first_drift..].windows(2) {
        assert!(
            w[1].mean_g >= w[0].mean_g - 1e-12,
            "ladder re-descended during the dwell: {} -> {}",
            w[0].mean_g,
            w[1].mean_g
        );
    }
    // (3) The veto is visible: with load pinned high and dwell armed,
    // held ticks are DwellHold-tagged (a Load tag after the climb would
    // be exactly the forbidden descent).
    assert!(
        traj[first_drift..]
            .iter()
            .any(|s| s.trigger == StepTrigger::DwellHold),
        "dwell veto must appear in the trajectory"
    );
    assert!(traj[first_drift..]
        .iter()
        .all(|s| s.trigger != StepTrigger::Load));
    // (4) The drift was real and measured.
    let c = &report.canary[0];
    assert!(c.flips > 0, "always-flip tables must flip top-1 classes");
    assert!(c.observed_flip_rate >= 0.0 && c.sampled > 0);
    // (5) The default tier's metrics surface the governor state.
    let m = report.tier("guarded").unwrap();
    assert!(m.governor_rung.is_some(), "governed tier exposes its rung");
    assert!(
        matches!(
            m.governor_trigger,
            Some(StepTrigger::Drift | StepTrigger::DwellHold)
        ),
        "final trigger must be drift-side, got {:?}",
        m.governor_trigger
    );
}
