//! Integration tests for the `gavina::serve` QoS surface:
//!
//! * a full admission queue yields a typed `Overloaded` error — the
//!   service stays up and workers stay alive,
//! * `shutdown()` drains every *accepted* ticket,
//! * the `exact` tier's served logits are bit-identical to
//!   `Engine::infer` on the same images, regardless of traffic around
//!   them.

use std::sync::Arc;
use std::time::Duration;

use gavina::arch::{ArchConfig, Precision};
use gavina::engine::{Engine, EngineBuilder, GavPolicy, GavinaError};
use gavina::serve::{ServeOptions, SubmitOptions, TierSpec};
use gavina::util::Prng;

const IMAGE_LEN: usize = 32 * 32 * 3;

fn tiny_engine(policy: GavPolicy) -> Arc<Engine> {
    Arc::new(
        EngineBuilder::new()
            .synthetic_weights(0.125, 1)
            .precision(Precision::new(2, 2))
            .arch(ArchConfig::tiny())
            .policy(policy)
            .seed(9)
            .build()
            .unwrap(),
    )
}

fn rand_images(seed: u64, n: usize) -> Vec<Vec<f32>> {
    let mut rng = Prng::new(seed);
    (0..n)
        .map(|_| (0..IMAGE_LEN).map(|_| rng.next_f32()).collect())
        .collect()
}

#[test]
fn full_admission_queue_is_typed_overloaded_and_drains_on_shutdown() {
    // A batch that never dispatches (max_batch and timeout both out of
    // reach) pins every accepted request in flight, so admission fills
    // deterministically.
    let opts = ServeOptions {
        workers: 2,
        queue_depth: 4,
        default_tier: "guarded".into(),
        tiers: vec![TierSpec {
            name: "guarded".into(),
            policy: None,
            max_batch: 64,
            batch_timeout: Duration::from_secs(3600),
        }],
        governor: None,
    };
    let service = tiny_engine(GavPolicy::Exact).serve(opts).unwrap();
    let session = service.session();
    let images = rand_images(2, 4);
    let tickets: Vec<_> = images
        .iter()
        .map(|img| session.submit(img.clone()).expect("within capacity"))
        .collect();
    assert_eq!(service.in_flight(), 4);

    // The 5th submit must be a typed rejection — never a panic, a block,
    // or a silent drop.
    match session.submit(images[0].clone()) {
        Err(GavinaError::Overloaded { capacity }) => assert_eq!(capacity, 4),
        other => panic!("expected Overloaded, got {other:?}"),
    }
    assert_eq!(service.rejected(), 1);

    // The service is still up: shutdown drains every *accepted* ticket
    // (the pinned batch flushes and executes; workers were alive to take
    // it).
    let handle = std::thread::spawn(move || service.shutdown());
    for t in &tickets {
        let resp = t
            .wait_timeout(Duration::from_secs(120))
            .unwrap()
            .expect("accepted ticket must be answered");
        assert_eq!(resp.expect_logits("drained request").len(), 10);
    }
    let report = handle.join().unwrap();
    assert_eq!(report.requests(), 4, "all accepted tickets served");
    assert_eq!(report.rejected, 1);
    assert_eq!(report.tier("guarded").unwrap().errors, 0);
}

#[test]
fn capacity_frees_after_responses() {
    let opts = ServeOptions {
        workers: 1,
        queue_depth: 1,
        default_tier: "guarded".into(),
        tiers: vec![TierSpec {
            name: "guarded".into(),
            policy: None,
            max_batch: 1,
            batch_timeout: Duration::from_millis(1),
        }],
        governor: None,
    };
    let service = tiny_engine(GavPolicy::Exact).serve(opts).unwrap();
    let session = service.session();
    let images = rand_images(3, 3);
    // Sequential submit/wait cycles through a depth-1 queue: each
    // response must free its admission slot for the next request.
    for img in &images {
        let t = session.submit(img.clone()).expect("slot free after response");
        let resp = t.wait_timeout(Duration::from_secs(120)).unwrap().expect("served");
        assert_eq!(resp.expect_logits("served").len(), 10);
    }
    let report = service.shutdown();
    assert_eq!(report.requests(), 3);
    assert_eq!(report.rejected, 0);
}

#[test]
fn exact_tier_is_bit_identical_to_engine_infer() {
    // Base engine undervolts (uniform G=1); the exact tier pre-resolves
    // a fully-guarded variant sharing its packed planes and runs
    // max_batch = 1, so per-request activation quantization matches a
    // standalone single-image infer exactly.
    let engine = tiny_engine(GavPolicy::Uniform(1));
    let opts = ServeOptions {
        workers: 2,
        queue_depth: 64,
        default_tier: "guarded".into(),
        tiers: vec![
            TierSpec::new("exact", Some(GavPolicy::Exact)).max_batch(1),
            TierSpec::new("guarded", None)
                .max_batch(4)
                .batch_timeout(Duration::from_millis(2)),
        ],
        governor: None,
    };
    let service = Arc::clone(&engine).serve(opts).unwrap();
    let session = service.session();

    let images = rand_images(5, 6);
    // Interleave exact-tier requests with guarded traffic so exact
    // requests would land in mixed batches if the tier didn't isolate
    // them.
    let mut exact_tickets = Vec::new();
    for img in &images {
        let _ = session.submit(img.clone()).unwrap(); // guarded noise
        exact_tickets.push(
            session
                .submit_with(img.clone(), SubmitOptions::new().tier("exact"))
                .unwrap(),
        );
    }

    // The reference: a standalone fully-guarded engine over the same
    // weights, one image per call.
    let reference = tiny_engine(GavPolicy::Exact);
    for (img, t) in images.iter().zip(exact_tickets) {
        let resp = t.wait_timeout(Duration::from_secs(120)).unwrap().expect("response");
        assert_eq!(resp.tier(), "exact");
        assert_eq!(resp.batch_size(), 1);
        let served = resp.expect_logits("exact request");
        let expect = reference.infer(img, 1).unwrap().logits;
        assert_eq!(
            served, expect,
            "exact tier must be bit-identical to Engine::infer"
        );
    }
    service.shutdown();
}

#[test]
fn governed_service_swaps_schedules_under_pinned_load() {
    use gavina::serve::GovernorOptions;
    // Pin high load (pending batch never dispatches), let the governor
    // tick a few times, and watch the default tier's live schedule step
    // toward aggressive undervolting.
    let opts = ServeOptions {
        workers: 1,
        queue_depth: 8,
        default_tier: "guarded".into(),
        tiers: vec![TierSpec {
            name: "guarded".into(),
            policy: None,
            max_batch: 64,
            batch_timeout: Duration::from_secs(3600),
        }],
        governor: Some(GovernorOptions {
            period: Duration::from_millis(5),
            high_load: 0.6,
            low_load: 0.2,
            ..Default::default()
        }),
    };
    let engine = tiny_engine(GavPolicy::Exact);
    let max_g = engine.precision().max_g();
    let service = Arc::clone(&engine).serve(opts).unwrap();
    let session = service.session();
    let before = service.tier_layer_gs("guarded").unwrap();
    assert_eq!(before, vec![max_g; before.len()]);

    let images = rand_images(7, 6);
    let tickets: Vec<_> = images
        .iter()
        .map(|img| session.submit(img.clone()).unwrap())
        .collect();
    // load = 6/8 = 0.75 ≥ 0.6: the governor must step down, one rung per
    // period. Wait until the recorded trajectory holds at least two
    // distinct schedules (i.e. it actually moved while load was pinned).
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let traj = service.governor_trajectory();
        let mut seen: Vec<Vec<u32>> = Vec::new();
        for s in &traj {
            if !seen.contains(&s.layer_gs) {
                seen.push(s.layer_gs.clone());
            }
        }
        if seen.len() >= 2 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "governor never adapted under pinned load"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    let now_gs = service.tier_layer_gs("guarded").unwrap();
    assert!(
        now_gs.iter().sum::<u32>() < before.iter().sum::<u32>(),
        "under load the schedule must move toward lower G"
    );
    let handle = std::thread::spawn(move || service.shutdown());
    for t in tickets {
        t.wait_timeout(Duration::from_secs(120))
            .unwrap()
            .expect("drained")
            .expect_logits("drained request");
    }
    let report = handle.join().unwrap();
    assert!(!report.governor.is_empty());
    // The trajectory itself records the movement.
    let first = &report.governor.first().unwrap().layer_gs;
    let distinct = report
        .governor
        .iter()
        .any(|s| &s.layer_gs != first);
    assert!(distinct, "trajectory must contain at least two schedules");
}
