//! Golden-parity tests for the `gavina::engine` facade: `Engine::infer`
//! must produce **bit-identical** logits and `ForwardStats` to the
//! pre-redesign path (direct `Executor` construction with a hand-set
//! `layer_gs` vector) on synthetic weights, for the `Exact`, `Uniform`
//! and `PerLayer` policies — the API moved, the numerics must not.

use std::sync::Arc;

use gavina::arch::{ArchConfig, Precision};
use gavina::dnn::exec::synth::synthetic_weights;
use gavina::dnn::{conv_layer_names, Executor, ForwardResult, TensorMap, IMAGE_LEN};
use gavina::engine::{EngineBuilder, FloatBackend, GavPolicy, GavinaBackend};
use gavina::errmodel::{ErrorTables, ModelParams};
use gavina::util::Prng;

const WM: f64 = 0.125;
const SEED: u64 = 41;

fn test_tables(arch: &ArchConfig) -> Arc<ErrorTables> {
    // Dense synthetic tables with a mid-size flip probability so
    // undervolted runs actually corrupt values — parity on error-free
    // runs would prove much less.
    let params = ModelParams::paper(arch.c_dim);
    let mut tables = ErrorTables::zeroed(params);
    for bit in 0..params.s_bits {
        for e in 0..=params.c_dim as u16 {
            for pb in 0..params.p_bins {
                for cd in 0..params.n_cond(bit) {
                    tables.set_prob(bit, e, pb, cd, 0.05);
                }
            }
        }
    }
    Arc::new(tables)
}

fn rand_images(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = Prng::new(seed);
    (0..n * IMAGE_LEN).map(|_| rng.next_f32()).collect()
}

/// The pre-redesign path: hand-built `Executor` over the simulator
/// backend with an explicitly assigned `layer_gs` vector.
fn legacy_forward(
    weights: &TensorMap,
    prec: Precision,
    arch: &ArchConfig,
    tables: Option<Arc<ErrorTables>>,
    layer_gs: Vec<u32>,
    images: &[f32],
    n: usize,
) -> ForwardResult {
    let backend = GavinaBackend {
        arch: arch.clone(),
        tables,
        seed: SEED,
    };
    let mut ex = Executor::new(weights, WM, prec, &backend);
    ex.layer_gs = layer_gs;
    ex.forward(images, n)
}

fn engine_forward(
    weights: Arc<TensorMap>,
    prec: Precision,
    arch: &ArchConfig,
    tables: Option<Arc<ErrorTables>>,
    policy: GavPolicy,
    images: &[f32],
    n: usize,
) -> ForwardResult {
    let engine = EngineBuilder::new()
        .weights(weights)
        .width_mult(WM)
        .precision(prec)
        .arch(arch.clone())
        .tables_opt(tables)
        .policy(policy)
        .seed(SEED)
        .build()
        .expect("engine config");
    engine.infer(images, n).expect("engine inference")
}

fn assert_bit_identical(a: &ForwardResult, b: &ForwardResult) {
    assert_eq!(a.logits, b.logits, "logits must be bit-identical");
    assert_eq!(a.n, b.n);
    assert_eq!(a.classes, b.classes);
    assert_eq!(a.stats, b.stats, "ForwardStats must be identical");
}

#[test]
fn exact_policy_matches_legacy_executor() {
    let prec = Precision::new(2, 2);
    let arch = ArchConfig::tiny();
    let weights = Arc::new(synthetic_weights(WM, 1));
    let tables = test_tables(&arch);
    let images = rand_images(2, 2);
    let n_layers = conv_layer_names().len();

    let legacy = legacy_forward(
        &weights,
        prec,
        &arch,
        Some(Arc::clone(&tables)),
        vec![prec.max_g(); n_layers],
        &images,
        2,
    );
    let engine = engine_forward(
        weights,
        prec,
        &arch,
        Some(tables),
        GavPolicy::Exact,
        &images,
        2,
    );
    assert_bit_identical(&legacy, &engine);
    // Fully guarded: the error model must not have fired.
    assert_eq!(engine.stats.corrupted, 0);
    assert!(engine.stats.cycles > 0);
}

#[test]
fn uniform_policy_matches_legacy_executor() {
    let prec = Precision::new(2, 2);
    let arch = ArchConfig::tiny();
    let weights = Arc::new(synthetic_weights(WM, 3));
    let tables = test_tables(&arch);
    let images = rand_images(4, 1);
    let n_layers = conv_layer_names().len();

    for g in [0u32, 1, 2] {
        let legacy = legacy_forward(
            &weights,
            prec,
            &arch,
            Some(Arc::clone(&tables)),
            vec![g; n_layers],
            &images,
            1,
        );
        let engine = engine_forward(
            Arc::clone(&weights),
            prec,
            &arch,
            Some(Arc::clone(&tables)),
            GavPolicy::Uniform(g),
            &images,
            1,
        );
        assert_bit_identical(&legacy, &engine);
        if g == 0 {
            assert!(
                engine.stats.corrupted > 0,
                "fully undervolted parity run must actually inject errors"
            );
        }
    }
}

#[test]
fn per_layer_policy_matches_legacy_executor() {
    let prec = Precision::new(2, 2);
    let arch = ArchConfig::tiny();
    let weights = Arc::new(synthetic_weights(WM, 5));
    let tables = test_tables(&arch);
    let images = rand_images(6, 1);
    let n_layers = conv_layer_names().len();

    // A mixed allocation: guard the input conv, undervolt a spread of
    // mid/deep layers at different G.
    let gs: Vec<u32> = (0..n_layers as u32)
        .map(|i| i * 7 % (prec.max_g() + 1))
        .collect();

    let legacy = legacy_forward(
        &weights,
        prec,
        &arch,
        Some(Arc::clone(&tables)),
        gs.clone(),
        &images,
        1,
    );
    let engine = engine_forward(
        weights,
        prec,
        &arch,
        Some(tables),
        GavPolicy::PerLayer(gs),
        &images,
        1,
    );
    assert_bit_identical(&legacy, &engine);
}

#[test]
fn float_backend_matches_legacy_float_executor() {
    let prec = Precision::new(4, 4);
    let weights = Arc::new(synthetic_weights(WM, 7));
    let images = rand_images(8, 2);

    let mut legacy_ex = Executor::new(&weights, WM, prec, &FloatBackend);
    legacy_ex.layer_gs = vec![prec.max_g(); conv_layer_names().len()];
    let legacy = legacy_ex.forward(&images, 2);

    let engine = EngineBuilder::new()
        .weights(weights)
        .width_mult(WM)
        .precision(prec)
        .backend_float()
        .policy(GavPolicy::Exact)
        .seed(SEED)
        .build()
        .unwrap();
    let out = engine.infer(&images, 2).unwrap();
    assert_bit_identical(&legacy, &out);
    assert_eq!(out.stats.cycles, 0, "float reference models no hardware");
}

#[test]
fn batched_inference_matches_legacy_forward_batched() {
    let prec = Precision::new(2, 2);
    let arch = ArchConfig::tiny();
    let weights = Arc::new(synthetic_weights(WM, 9));
    let tables = test_tables(&arch);
    let n = 5;
    let images = rand_images(10, n);
    let n_layers = conv_layer_names().len();

    let backend = GavinaBackend {
        arch: arch.clone(),
        tables: Some(Arc::clone(&tables)),
        seed: SEED,
    };
    let mut ex = Executor::new(&weights, WM, prec, &backend);
    ex.layer_gs = vec![1; n_layers];
    let legacy = ex.forward_batched(&images, n, 2);

    let engine = EngineBuilder::new()
        .weights(weights)
        .width_mult(WM)
        .precision(prec)
        .arch(arch)
        .tables(tables)
        .policy(GavPolicy::Uniform(1))
        .seed(SEED)
        .build()
        .unwrap();
    let out = engine.infer_batched(&images, n, 2).unwrap();
    assert_bit_identical(&legacy, &out);
}
