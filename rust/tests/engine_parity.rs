//! Golden-parity tests for the `gavina::engine` facade and the
//! compile-once data plane.
//!
//! Two pins, both **bit-identical** (logits and `ForwardStats`):
//!
//! 1. `Engine::infer` vs a hand-built `Executor` with a hand-set G
//!    vector, for the `Exact`, `Uniform` and `PerLayer` policies — the
//!    API moved, the numerics must not.
//! 2. `Engine::infer` (weights quantized, bit-plane-packed and BN-folded
//!    exactly once at `build()`) vs [`per_request_forward`] — a verbatim
//!    in-test reproduction of the pre-`PlannedModel` data plane that
//!    re-quantizes the f32 weights, re-packs the B-side planes and
//!    re-derives the BN constants on **every** call, then applies BN as
//!    a separate pass. The refactor moved the work to build time; the
//!    arithmetic must not have moved at all.

use std::sync::Arc;

use gavina::arch::{ArchConfig, GavSchedule, Precision};
use gavina::dnn::exec::synth::synthetic_weights;
use gavina::dnn::lower::{col2im, im2col, weights_to_b, ConvGeom};
use gavina::dnn::weights::AnyTensor;
use gavina::dnn::{
    conv_layer_names, Executor, ForwardResult, ForwardStats, LayerPlan, Tensor, TensorMap,
    IMAGE_LEN,
};
use gavina::engine::backend::{ExecBackend, LayerGemm};
use gavina::engine::{EngineBuilder, FloatBackend, GavPolicy, GavinaBackend};
use gavina::errmodel::{ErrorTables, ModelParams};
use gavina::quant::InterleavedPlanes;
use gavina::util::Prng;

const WM: f64 = 0.125;
const SEED: u64 = 41;

fn test_tables(arch: &ArchConfig) -> Arc<ErrorTables> {
    // Dense synthetic tables with a mid-size flip probability so
    // undervolted runs actually corrupt values — parity on error-free
    // runs would prove much less.
    let params = ModelParams::paper(arch.c_dim);
    let mut tables = ErrorTables::zeroed(params);
    for bit in 0..params.s_bits {
        for e in 0..=params.c_dim as u16 {
            for pb in 0..params.p_bins {
                for cd in 0..params.n_cond(bit) {
                    tables.set_prob(bit, e, pb, cd, 0.05);
                }
            }
        }
    }
    Arc::new(tables)
}

fn rand_images(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = Prng::new(seed);
    (0..n * IMAGE_LEN).map(|_| rng.next_f32()).collect()
}

/// The pre-redesign facade path: hand-built `Executor` over the simulator
/// backend with an explicitly assigned per-layer G vector.
fn legacy_forward(
    weights: &TensorMap,
    prec: Precision,
    arch: &ArchConfig,
    tables: Option<Arc<ErrorTables>>,
    layer_gs: Vec<u32>,
    images: &[f32],
    n: usize,
) -> ForwardResult {
    let backend = GavinaBackend {
        arch: arch.clone(),
        tables,
        seed: SEED,
    };
    Executor::new(weights, WM, prec, &backend).with_layer_gs(layer_gs).forward(images, n)
}

fn engine_forward(
    weights: Arc<TensorMap>,
    prec: Precision,
    arch: &ArchConfig,
    tables: Option<Arc<ErrorTables>>,
    policy: GavPolicy,
    images: &[f32],
    n: usize,
) -> ForwardResult {
    let engine = EngineBuilder::new()
        .weights(weights)
        .width_mult(WM)
        .precision(prec)
        .arch(arch.clone())
        .tables_opt(tables)
        .policy(policy)
        .seed(SEED)
        .build()
        .expect("engine config");
    engine.infer(images, n).expect("engine inference")
}

fn assert_bit_identical(a: &ForwardResult, b: &ForwardResult) {
    assert_eq!(a.logits, b.logits, "logits must be bit-identical");
    assert_eq!(a.n, b.n);
    assert_eq!(a.classes, b.classes);
    assert_eq!(a.stats, b.stats, "ForwardStats must be identical");
}

// ---------------------------------------------------------------------
// The pre-compile-once data plane, reproduced verbatim: everything the
// old `Executor::qconv`/`bn`/`forward` did per request, per call.
// ---------------------------------------------------------------------

fn wf32<'m>(weights: &'m TensorMap, name: &str) -> (&'m [usize], &'m [f32]) {
    weights
        .get(name)
        .and_then(AnyTensor::as_f32)
        .unwrap_or_else(|| panic!("missing f32 weight '{name}'"))
}

/// One conv exactly as the old per-request `Executor::qconv`: quantize
/// activations AND weights, pack both operand planes, run the backend
/// GEMM, dequantize, fold back with `col2im`. The weight quantization and
/// B-side packing here happen on every call — the work `build()` now
/// does once.
#[allow(clippy::too_many_arguments)]
fn per_request_qconv(
    weights: &TensorMap,
    prec: Precision,
    backend: &dyn ExecBackend,
    layer_gs: &[u32],
    x: &Tensor,
    conv: &str,
    stride: usize,
    layer_idx: usize,
    stats: &mut ForwardStats,
) -> Tensor {
    let (wdims, wdata) = wf32(weights, &format!("{conv}/w"));
    let g = ConvGeom::new(x, wdims, stride);
    let (c_dim, l_dim, k_dim) = (g.c_dim(), g.l_dim(), g.k_dim());

    // --- activation quantization (per tensor, robust range) ---
    let hi_a = ((1i32 << (prec.a_bits - 1)) - 1) as f32;
    let sa = x.robust_amax().max(1e-8) / hi_a;
    let a_f = im2col(x, &g);
    let qa: Vec<i32> = a_f
        .iter()
        .map(|&v| ((v / sa).round() as i32).clamp(-hi_a as i32, hi_a as i32))
        .collect();

    // --- per-request weight quantization (per output channel) ---
    let hi_w = ((1i32 << (prec.b_bits - 1)) - 1) as f32;
    let b_f = weights_to_b(wdims, wdata);
    let mut sw = vec![0.0f32; k_dim];
    for k in 0..k_dim {
        let amax = b_f[k * c_dim..(k + 1) * c_dim]
            .iter()
            .fold(0.0f32, |m, v| m.max(v.abs()))
            .max(1e-8);
        sw[k] = amax / hi_w;
    }
    let qb: Vec<i32> = b_f
        .iter()
        .enumerate()
        .map(|(i, &v)| {
            let k = i / c_dim;
            ((v / sw[k]).round() as i32).clamp(-hi_w as i32, hi_w as i32)
        })
        .collect();

    // --- per-request packing of BOTH operands, then the backend GEMM ---
    // (The operand layout moved to the fused kernel's interleaved form;
    // the packed bit content — what injection and the GEMM consume — is
    // identical, property-tested in `quant::interleaved`.)
    let pa = InterleavedPlanes::from_a_matrix(&qa, c_dim, l_dim, prec.a_bits);
    let plan = LayerPlan::for_gemm(
        &qb,
        k_dim,
        c_dim,
        GavSchedule::two_level(prec, layer_gs[layer_idx]),
        layer_idx,
    );
    let out = backend.run_layer_gemm(&LayerGemm {
        a: &pa,
        plan: &plan,
        stream: 0,
    });
    stats.cycles += out.counters.cycles;
    stats.tiles += out.counters.tiles;
    stats.corrupted += out.counters.corrupted;
    stats.executed_macs += out.counters.executed_macs;
    stats.steps_approx += out.counters.steps_approx;
    stats.steps_guarded += out.counters.steps_guarded;
    stats.useful_macs += g.macs();
    if stats.layer_macs.len() <= layer_idx {
        stats.layer_macs.resize(layer_idx + 1, 0);
        stats.layer_dims.resize(layer_idx + 1, (0, 0, 0));
    }
    stats.layer_macs[layer_idx] = g.macs();
    stats.layer_dims[layer_idx] = (c_dim, l_dim, k_dim);
    if stats.layer_corrupted.len() <= layer_idx {
        stats.layer_corrupted.resize(layer_idx + 1, 0);
        stats.layer_steps.resize(layer_idx + 1, 0);
    }
    stats.layer_corrupted[layer_idx] += out.counters.corrupted;
    stats.layer_steps[layer_idx] += out.counters.steps_approx;

    // --- dequantize ---
    let mut p = vec![0.0f32; k_dim * l_dim];
    for k in 0..k_dim {
        let s = sa * sw[k];
        for l in 0..l_dim {
            p[k * l_dim + l] = out.p[k * l_dim + l] as f32 * s;
        }
    }
    col2im(&p, &g)
}

/// BN exactly as the old separate `Executor::bn` pass, constants
/// re-derived per call.
fn per_request_bn(weights: &TensorMap, x: &mut Tensor, bn: &str) {
    let (_, scale) = wf32(weights, &format!("{bn}/scale"));
    let (_, bias) = wf32(weights, &format!("{bn}/bias"));
    let (_, mean) = wf32(weights, &format!("{bn}/mean"));
    let (_, var) = wf32(weights, &format!("{bn}/var"));
    let c = *x.dims.last().unwrap();
    assert_eq!(scale.len(), c);
    let mul: Vec<f32> = (0..c).map(|i| scale[i] / (var[i] + 1e-5).sqrt()).collect();
    for (i, v) in x.data.iter_mut().enumerate() {
        let ci = i % c;
        *v = (*v - mean[ci]) * mul[ci] + bias[ci];
    }
}

/// The full pre-refactor forward pass: per-request quantization, packing
/// and BN, over the same pluggable backend.
fn per_request_forward(
    weights: &TensorMap,
    prec: Precision,
    backend: &dyn ExecBackend,
    layer_gs: &[u32],
    images: &[f32],
    n: usize,
) -> ForwardResult {
    assert_eq!(images.len(), n * IMAGE_LEN);
    let mut stats = ForwardStats::default();
    let mut layer = 0usize;
    let mut x = Tensor::new(vec![n, 32, 32, 3], images.to_vec());

    let qconv_bn = |x: &Tensor,
                        conv: &str,
                        bnn: &str,
                        stride: usize,
                        relu: bool,
                        layer: &mut usize,
                        stats: &mut ForwardStats|
     -> Tensor {
        let mut y = per_request_qconv(
            weights,
            prec,
            backend,
            layer_gs,
            x,
            conv,
            stride,
            *layer,
            stats,
        );
        *layer += 1;
        per_request_bn(weights, &mut y, bnn);
        if relu {
            y.relu_inplace();
        }
        y
    };

    x = qconv_bn(&x, "conv0", "bn0", 1, true, &mut layer, &mut stats);
    let stages: [(usize, usize); 4] = [(64, 1), (128, 2), (256, 2), (512, 2)];
    for (si, (_, stride)) in stages.iter().enumerate() {
        for bi in 0..2 {
            let s = if bi == 0 { *stride } else { 1 };
            let p = format!("s{si}b{bi}");
            let y = qconv_bn(
                &x,
                &format!("{p}/conv1"),
                &format!("{p}/bn1"),
                s,
                true,
                &mut layer,
                &mut stats,
            );
            let mut y = qconv_bn(
                &y,
                &format!("{p}/conv2"),
                &format!("{p}/bn2"),
                1,
                false,
                &mut layer,
                &mut stats,
            );
            let sc = if weights.contains_key(&format!("{p}/down/w")) {
                qconv_bn(
                    &x,
                    &format!("{p}/down"),
                    &format!("{p}/dbn"),
                    s,
                    false,
                    &mut layer,
                    &mut stats,
                )
            } else {
                x.clone()
            };
            y.add_inplace(&sc);
            y.relu_inplace();
            x = y;
        }
    }

    // GAP -> fake-quant -> fc (fc itself stays in float).
    let mut gap = x.global_avg_pool();
    let hi_a = ((1i32 << (prec.a_bits - 1)) - 1) as f32;
    let sa = gap.robust_amax().max(1e-8) / hi_a;
    for v in &mut gap.data {
        *v = ((*v / sa).round()).clamp(-hi_a, hi_a) * sa;
    }
    let (fdims, fw) = wf32(weights, "fc/w");
    let (_, fb) = wf32(weights, "fc/b");
    let (cin_fc, classes) = (fdims[0], fdims[1]);
    assert_eq!(gap.dims, vec![n, cin_fc]);
    let mut logits = vec![0.0f32; n * classes];
    for ni in 0..n {
        for k in 0..classes {
            let mut acc = fb[k];
            for ci in 0..cin_fc {
                acc += gap.data[ni * cin_fc + ci] * fw[ci * classes + k];
            }
            logits[ni * classes + k] = acc;
        }
    }
    ForwardResult {
        logits,
        n,
        classes,
        stats,
    }
}

// ---------------------------------------------------------------------
// Compile-once vs per-request golden parity
// ---------------------------------------------------------------------

#[test]
fn planned_engine_matches_per_request_data_plane_float() {
    let prec = Precision::new(4, 4);
    let weights = Arc::new(synthetic_weights(WM, 21));
    let images = rand_images(22, 2);
    let n_layers = conv_layer_names().len();

    let golden = per_request_forward(
        &weights,
        prec,
        &FloatBackend,
        &vec![prec.max_g(); n_layers],
        &images,
        2,
    );
    let engine = EngineBuilder::new()
        .weights(Arc::clone(&weights))
        .width_mult(WM)
        .precision(prec)
        .backend_float()
        .policy(GavPolicy::Exact)
        .seed(SEED)
        .build()
        .unwrap();
    let planned = engine.infer(&images, 2).unwrap();
    assert_bit_identical(&golden, &planned);
}

#[test]
fn planned_engine_matches_per_request_data_plane_gavina() {
    // Mixed per-layer Gs + dense error tables: the hardest parity case —
    // error injection makes the result depend on the exact packed tile
    // bits, the tile order and the per-layer seeds, all of which the
    // compile-once refactor re-plumbed.
    let prec = Precision::new(2, 2);
    let arch = ArchConfig::tiny();
    let weights = Arc::new(synthetic_weights(WM, 23));
    let tables = test_tables(&arch);
    let images = rand_images(24, 2);
    let n_layers = conv_layer_names().len();
    let gs: Vec<u32> = (0..n_layers as u32)
        .map(|i| i * 5 % (prec.max_g() + 1))
        .collect();

    let backend = GavinaBackend {
        arch: arch.clone(),
        tables: Some(Arc::clone(&tables)),
        seed: SEED,
    };
    let golden = per_request_forward(&weights, prec, &backend, &gs, &images, 2);
    assert!(
        golden.stats.corrupted > 0,
        "parity run must actually inject errors"
    );

    let engine = EngineBuilder::new()
        .weights(Arc::clone(&weights))
        .width_mult(WM)
        .precision(prec)
        .arch(arch)
        .tables(tables)
        .policy(GavPolicy::PerLayer(gs))
        .seed(SEED)
        .build()
        .unwrap();
    let planned = engine.infer(&images, 2).unwrap();
    assert_bit_identical(&golden, &planned);
}

#[test]
fn no_weight_repacking_across_requests() {
    // Two infer() calls on one engine must agree bit-for-bit with each
    // other and with a fresh engine built from the same weights — the
    // compiled plans are immutable and fully determine the result.
    let prec = Precision::new(2, 2);
    let arch = ArchConfig::tiny();
    let weights = Arc::new(synthetic_weights(WM, 25));
    let tables = test_tables(&arch);
    let images = rand_images(26, 1);
    let build = || {
        EngineBuilder::new()
            .weights(Arc::clone(&weights))
            .width_mult(WM)
            .precision(prec)
            .arch(arch.clone())
            .tables(Arc::clone(&tables))
            .policy(GavPolicy::Uniform(0))
            .seed(SEED)
            .build()
            .unwrap()
    };
    let engine = build();
    let a = engine.infer(&images, 1).unwrap();
    let b = engine.infer(&images, 1).unwrap();
    let c = build().infer(&images, 1).unwrap();
    assert_bit_identical(&a, &b);
    assert_bit_identical(&a, &c);
    assert!(engine.model().packed_weight_bytes() > 0);
}

// ---------------------------------------------------------------------
// Facade parity (PR 2 pins, kept green across the data-plane refactor)
// ---------------------------------------------------------------------

#[test]
fn exact_policy_matches_legacy_executor() {
    let prec = Precision::new(2, 2);
    let arch = ArchConfig::tiny();
    let weights = Arc::new(synthetic_weights(WM, 1));
    let tables = test_tables(&arch);
    let images = rand_images(2, 2);
    let n_layers = conv_layer_names().len();

    let legacy = legacy_forward(
        &weights,
        prec,
        &arch,
        Some(Arc::clone(&tables)),
        vec![prec.max_g(); n_layers],
        &images,
        2,
    );
    let engine = engine_forward(
        weights,
        prec,
        &arch,
        Some(tables),
        GavPolicy::Exact,
        &images,
        2,
    );
    assert_bit_identical(&legacy, &engine);
    // Fully guarded: the error model must not have fired.
    assert_eq!(engine.stats.corrupted, 0);
    assert!(engine.stats.cycles > 0);
}

#[test]
fn uniform_policy_matches_legacy_executor() {
    let prec = Precision::new(2, 2);
    let arch = ArchConfig::tiny();
    let weights = Arc::new(synthetic_weights(WM, 3));
    let tables = test_tables(&arch);
    let images = rand_images(4, 1);
    let n_layers = conv_layer_names().len();

    for g in [0u32, 1, 2] {
        let legacy = legacy_forward(
            &weights,
            prec,
            &arch,
            Some(Arc::clone(&tables)),
            vec![g; n_layers],
            &images,
            1,
        );
        let engine = engine_forward(
            Arc::clone(&weights),
            prec,
            &arch,
            Some(Arc::clone(&tables)),
            GavPolicy::Uniform(g),
            &images,
            1,
        );
        assert_bit_identical(&legacy, &engine);
        if g == 0 {
            assert!(
                engine.stats.corrupted > 0,
                "fully undervolted parity run must actually inject errors"
            );
        }
    }
}

#[test]
fn per_layer_policy_matches_legacy_executor() {
    let prec = Precision::new(2, 2);
    let arch = ArchConfig::tiny();
    let weights = Arc::new(synthetic_weights(WM, 5));
    let tables = test_tables(&arch);
    let images = rand_images(6, 1);
    let n_layers = conv_layer_names().len();

    // A mixed allocation: guard the input conv, undervolt a spread of
    // mid/deep layers at different G.
    let gs: Vec<u32> = (0..n_layers as u32)
        .map(|i| i * 7 % (prec.max_g() + 1))
        .collect();

    let legacy = legacy_forward(
        &weights,
        prec,
        &arch,
        Some(Arc::clone(&tables)),
        gs.clone(),
        &images,
        1,
    );
    let engine = engine_forward(
        weights,
        prec,
        &arch,
        Some(tables),
        GavPolicy::PerLayer(gs),
        &images,
        1,
    );
    assert_bit_identical(&legacy, &engine);
}

#[test]
fn float_backend_matches_legacy_float_executor() {
    let prec = Precision::new(4, 4);
    let weights = Arc::new(synthetic_weights(WM, 7));
    let images = rand_images(8, 2);

    let legacy = Executor::new(&weights, WM, prec, &FloatBackend).forward(&images, 2);

    let engine = EngineBuilder::new()
        .weights(weights)
        .width_mult(WM)
        .precision(prec)
        .backend_float()
        .policy(GavPolicy::Exact)
        .seed(SEED)
        .build()
        .unwrap();
    let out = engine.infer(&images, 2).unwrap();
    assert_bit_identical(&legacy, &out);
    assert_eq!(out.stats.cycles, 0, "float reference models no hardware");
}

#[test]
fn batched_inference_matches_legacy_forward_batched() {
    let prec = Precision::new(2, 2);
    let arch = ArchConfig::tiny();
    let weights = Arc::new(synthetic_weights(WM, 9));
    let tables = test_tables(&arch);
    let n = 5;
    let images = rand_images(10, n);
    let n_layers = conv_layer_names().len();

    let backend = GavinaBackend {
        arch: arch.clone(),
        tables: Some(Arc::clone(&tables)),
        seed: SEED,
    };
    let legacy = Executor::new(&weights, WM, prec, &backend)
        .with_layer_gs(vec![1; n_layers])
        .forward_batched(&images, n, 2);

    let engine = EngineBuilder::new()
        .weights(weights)
        .width_mult(WM)
        .precision(prec)
        .arch(arch)
        .tables(tables)
        .policy(GavPolicy::Uniform(1))
        .seed(SEED)
        .build()
        .unwrap();
    let out = engine.infer_batched(&images, n, 2).unwrap();
    assert_bit_identical(&legacy, &out);
}
