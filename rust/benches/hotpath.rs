//! Hot-path microbenchmarks (the §Perf targets in EXPERIMENTS.md):
//!
//! * bit-packed binary-plane GEMM (u64 AND+popcount) — bit-MACs/ms
//! * multithreaded bit-serial GEMM, single vs `--threads N` — bit-MACs/ms
//! * fused plane-interleaved kernel vs the reference step-sequence
//!   kernel at a4w4/a8w8, serial + MT, on **every** available SIMD path
//!   — speedup lines plus a structured `BENCH_hotpath.json` artifact
//!   (kernel, precision, threads, bit-MACs/s) that CI uploads so the
//!   perf trajectory is tracked
//! * fused streaming activation prologue (im2col→quantize→interleave in
//!   one pass) vs the retained three-pass reference — `prologue_ms` vs
//!   `gemm_ms` split per (kernel, precision, threads) in the artifact,
//!   with an in-bench bit-equality check against the reference packing
//! * full bit-serial tile GEMM (pack + 16 steps + recombine)
//! * error-model injection throughput — values/ms
//! * cycle-simulator end-to-end GEMM — MACs/ms
//! * GLS event throughput — iPE-cycles/s
//! * compile-once data plane: one-time `build()` lowering cost, then
//!   planned steady-state vs per-request lowering — ms/image + speedup
//! * ResNet-18 image latency on the Gavina backend (model path)
//!
//! Flags: `--quick` (CI-sized runs), `--threads N` (worker threads for
//! the multithreaded section; 0/absent = one per core).

mod common;

use gavina::arch::{ArchConfig, GavSchedule, Precision};
use gavina::gls::{DelayModel, GlsContext};
use gavina::quant::PackedPlanes;
use gavina::simulator::{GavinaSim, GemmJob};
use gavina::util::Prng;
use gavina::workload::gemm_workload;

fn rate(label: &str, amount: f64, unit: &str, secs: f64) {
    println!("[perf] {label:44} {:>12.1} {unit}/ms ({:.3} ms total)", amount / secs / 1e3, secs * 1e3);
}

/// `--threads N` flag (absent or 0 = auto). A present flag with a
/// missing/garbled value is an error, not a silent fallback.
fn arg_threads() -> usize {
    let args: Vec<String> = std::env::args().collect();
    match args.iter().position(|a| a == "--threads") {
        None => 0,
        Some(i) => args.get(i + 1).and_then(|s| s.parse().ok()).unwrap_or_else(|| {
            eprintln!("--threads requires a non-negative integer value");
            std::process::exit(2)
        }),
    }
}

/// Time `reps` runs of one GEMM kernel; returns (total seconds, result).
fn time_gemm(reps: usize, mut f: impl FnMut() -> Vec<i64>) -> (f64, Vec<i64>) {
    let t0 = std::time::Instant::now();
    let mut out = Vec::new();
    for _ in 0..reps {
        out = f();
    }
    (t0.elapsed().as_secs_f64(), out)
}

fn main() {
    let quick = common::quick();
    let threads = gavina::util::parallel::resolve_threads(arg_threads());
    let arch = ArchConfig::paper();
    let prec = Precision::new(4, 4);
    let mut rng = Prng::new(0x407);

    // ---- packed binary-plane GEMM --------------------------------------
    let (a, b) = gemm_workload(arch.c_dim, arch.l_dim, arch.k_dim, prec, &mut rng);
    let pa = PackedPlanes::from_a_matrix(&a, arch.c_dim, arch.l_dim, prec.a_bits);
    let pb = PackedPlanes::from_b_matrix(&b, arch.k_dim, arch.c_dim, prec.b_bits);
    let reps = if quick { 2_000 } else { 20_000 };
    let mut out = vec![0u16; arch.k_dim * arch.l_dim];
    let t0 = std::time::Instant::now();
    for i in 0..reps {
        gavina::gemm::binary_plane_gemm(&pa, (i % 4) as u8, &pb, ((i / 4) % 4) as u8, &mut out);
    }
    let secs = t0.elapsed().as_secs_f64();
    let bitmacs = (arch.macs_per_tile() as u64 * reps as u64) as f64;
    rate("binary plane GEMM (u64 popcount)", bitmacs, "bit-MAC", secs);
    std::hint::black_box(&out);

    // ---- multithreaded bit-serial GEMM (row-block tiling) ---------------
    // Operands pre-converted to the fused kernel's interleaved layout
    // outside the timed loops, so the speedup column measures the kernel
    // rather than the one-time layout conversion.
    {
        use gavina::quant::InterleavedPlanes;
        let (c, l, k) = if quick { (1152, 32, 64) } else { (2304, 64, 128) };
        let (a, b) = gemm_workload(c, l, k, prec, &mut rng);
        let pa = InterleavedPlanes::from_a_matrix(&a, c, l, prec.a_bits);
        let pb = InterleavedPlanes::from_b_matrix(&b, k, c, prec.b_bits);
        let reps = if quick { 3 } else { 10 };
        let bitmacs = gavina::gemm::bit_macs(c, l, k, prec) as f64 * reps as f64;

        let t0 = std::time::Instant::now();
        let mut serial = Vec::new();
        for _ in 0..reps {
            serial = gavina::gemm::kernel::fused_gemm(&pa, &pb);
        }
        let secs_1 = t0.elapsed().as_secs_f64();
        rate(
            &format!("bit-serial GEMM {c}x{l}x{k} (1 thread)"),
            bitmacs,
            "bit-MAC",
            secs_1,
        );

        let t0 = std::time::Instant::now();
        let mut tiled = Vec::new();
        for _ in 0..reps {
            tiled = gavina::gemm::kernel::fused_gemm_mt(&pa, &pb, threads);
        }
        let secs_t = t0.elapsed().as_secs_f64();
        rate(
            &format!("bit-serial GEMM {c}x{l}x{k} ({threads} threads)"),
            bitmacs,
            "bit-MAC",
            secs_t,
        );
        println!(
            "[perf] {:44} {:>11.2}x ({} threads vs 1)",
            "multithreaded GEMM speedup",
            secs_1 / secs_t.max(1e-12),
            threads
        );
        assert_eq!(
            serial, tiled,
            "multithreaded GEMM must be bit-exact with the serial kernel"
        );
    }

    // ---- fused vs reference kernel (+ BENCH_hotpath.json artifact) ------
    // Times the fused kernel on every available path (scalar always,
    // plus each SIMD kind the host supports — avx2/avx512/avx512hs/neon)
    // against the step-sequence reference, per precision, serial + MT —
    // and records which kernel/block the dispatcher picked so the perf
    // trajectory in CI knows *which* path each number came from.
    {
        use gavina::gemm::kernel::{fused_gemm_mt_with, fused_gemm_with};
        use gavina::gemm::simd::{self, KernelKind};
        use gavina::quant::InterleavedPlanes;
        let active = simd::active();
        let block = simd::block_shape();
        let avail: Vec<&str> = simd::available().iter().map(|k| k.name()).collect();
        println!(
            "[perf] {:44} {:>12} (block {}x{}, available: {})",
            "kernel dispatch",
            active.name(),
            block.c_words,
            block.l_cols,
            avail.join("+")
        );
        let kinds = simd::available();
        debug_assert_eq!(kinds[0], KernelKind::Scalar, "scalar anchors the ratio column");
        let mut entries: Vec<String> = Vec::new();
        let mut speedups: Vec<String> = Vec::new();
        let mut simd_ratios: Vec<String> = Vec::new();
        let mut prologues: Vec<String> = Vec::new();
        let (c, l, k) = if quick { (1152, 32, 64) } else { (2304, 64, 128) };
        for prec in [Precision::new(4, 4), Precision::new(8, 8)] {
            let (a, b) = gemm_workload(c, l, k, prec, &mut rng);
            let pa = PackedPlanes::from_a_matrix(&a, c, l, prec.a_bits);
            let pb = PackedPlanes::from_b_matrix(&b, k, c, prec.b_bits);
            let ia = InterleavedPlanes::from_packed(&pa);
            let ib = InterleavedPlanes::from_packed(&pb);
            let reps = if quick { 2 } else { 5 };
            let bitmacs = gavina::gemm::bit_macs(c, l, k, prec) as f64 * reps as f64;
            let mut entry = |kernel: &str, th: usize, secs: f64| {
                entries.push(format!(
                    "    {{\"kernel\": \"{kernel}\", \"precision\": \"{}\", \"threads\": {th}, \
                     \"ms\": {:.3}, \"bitmacs_per_s\": {:.0}}}",
                    prec.tag(),
                    secs * 1e3 / reps as f64,
                    bitmacs / secs.max(1e-12)
                ));
            };
            let (s_ref1, r_ref1) = time_gemm(reps, || gavina::gemm::bitserial_gemm_ref(&pa, &pb));
            entry("reference", 1, s_ref1);
            let (s_reft, r_reft) =
                time_gemm(reps, || gavina::gemm::bitserial_gemm_ref_mt(&pa, &pb, threads));
            entry("reference", threads, s_reft);
            assert_eq!(r_ref1, r_reft, "reference MT must match serial");
            let mut timed: Vec<(KernelKind, f64)> = Vec::new();
            for &kind in &kinds {
                let name = format!("fused-{kind}");
                let (s_fus1, r_fus1) = time_gemm(reps, || fused_gemm_with(kind, &ia, &ib));
                entry(&name, 1, s_fus1);
                let (s_fust, r_fust) =
                    time_gemm(reps, || fused_gemm_mt_with(kind, &ia, &ib, threads));
                entry(&name, threads, s_fust);
                assert_eq!(
                    r_ref1, r_fus1,
                    "fused[{kind}] must be bit-identical to the reference kernel"
                );
                assert_eq!(r_ref1, r_fust, "fused[{kind}] MT must match serial");
                for (th, s_ref, s_fus) in [(1, s_ref1, s_fus1), (threads, s_reft, s_fust)] {
                    println!(
                        "[perf] {:44} {:>11.2}x (ref {:.3} -> fused {:.3} ms, {th} thr)",
                        format!("fused[{kind}] vs reference {} {c}x{l}x{k}", prec.tag()),
                        s_ref / s_fus.max(1e-12),
                        s_ref * 1e3 / reps as f64,
                        s_fus * 1e3 / reps as f64,
                    );
                    speedups.push(format!(
                        "    {{\"kernel\": \"{name}\", \"precision\": \"{}\", \"threads\": {th}, \
                         \"fused_over_reference\": {:.3}}}",
                        prec.tag(),
                        s_ref / s_fus.max(1e-12)
                    ));
                }
                timed.push((kind, s_fus1));
            }
            let (_, s_sc1) = timed[0];
            for &(ks, s_simd1) in &timed[1..] {
                println!(
                    "[perf] {:44} {:>11.2}x (scalar {:.3} -> {ks} {:.3} ms, 1 thr)",
                    format!("simd over scalar [{ks}] {} {c}x{l}x{k}", prec.tag()),
                    s_sc1 / s_simd1.max(1e-12),
                    s_sc1 * 1e3 / reps as f64,
                    s_simd1 * 1e3 / reps as f64,
                );
                simd_ratios.push(format!(
                    "    {{\"kernel\": \"fused-{ks}\", \"precision\": \"{}\", \"threads\": 1, \
                     \"simd_over_scalar\": {:.3}}}",
                    prec.tag(),
                    s_sc1 / s_simd1.max(1e-12)
                ));
            }
        }
        // ---- fused activation prologue vs three-pass reference ----------
        // Times the streaming im2col→quantize→interleave prologue
        // (`pack_a_fused_with`) against the retained three-pass reference
        // (f32 im2col matrix → i32 staging → repack) on a ResNet-ish 3×3
        // SAME conv at a8w8 with per-image scales, per kernel and thread
        // count — and splits the per-layer cost into prologue_ms vs
        // gemm_ms so `bench_gate.py` can floor the prologue speedup
        // independently of the GEMM throughput floors.
        {
            use gavina::dnn::exec::{pack_a_fused_with, pack_a_reference};
            use gavina::dnn::lower::ConvGeom;
            use gavina::dnn::tensor::robust_amax_slice;
            use gavina::dnn::Tensor;
            use gavina::gemm::kernel::fused_gemm_mt_with as gemm_mt;

            let prec = Precision::new(8, 8);
            let hi_a = ((1i32 << (prec.a_bits - 1)) - 1) as f32;
            let (n, h, w, cin, cout) =
                if quick { (2, 16, 16, 32, 16) } else { (4, 32, 32, 64, 32) };
            let g = ConvGeom::from_dims(n, h, w, &[3, 3, cin, cout], 1);
            let mut prng = Prng::new(0xA11);
            let data: Vec<f32> =
                (0..n * h * w * cin).map(|_| prng.next_f32() * 2.0 - 1.0).collect();
            let img = h * w * cin;
            let sa: Vec<f32> = (0..n)
                .map(|i| robust_amax_slice(&data[i * img..(i + 1) * img]) / hi_a)
                .collect();
            let x = Tensor::new(vec![n, h, w, cin], data);
            let (_, bm) = gemm_workload(g.c_dim(), 8, g.k_dim(), prec, &mut prng);
            let ib = InterleavedPlanes::from_b_matrix(&bm, g.k_dim(), g.c_dim(), prec.b_bits);
            let reps = if quick { 5 } else { 20 };

            // The serial three-pass baseline (kernel-independent): warm
            // the scratch allocations once, then time steady-state reps.
            let (mut af, mut qa) = (Vec::new(), Vec::new());
            let mut ia_ref = InterleavedPlanes::zeroed(prec.a_bits, 0, 0);
            pack_a_reference(&x, &g, &sa, hi_a, prec.a_bits, &mut af, &mut qa, &mut ia_ref);
            let t0 = std::time::Instant::now();
            for _ in 0..reps {
                pack_a_reference(&x, &g, &sa, hi_a, prec.a_bits, &mut af, &mut qa, &mut ia_ref);
            }
            let ref_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

            let ths = if threads > 1 { vec![1, threads] } else { vec![1] };
            let mut ia = InterleavedPlanes::zeroed(prec.a_bits, 0, 0);
            for &kind in &kinds {
                for &th in &ths {
                    pack_a_fused_with(kind, &x, &g, &sa, hi_a, prec.a_bits, th, &mut ia);
                    assert_eq!(
                        ia, ia_ref,
                        "fused prologue [{kind}, {th} thr] must be bit-identical to the reference"
                    );
                    let t0 = std::time::Instant::now();
                    for _ in 0..reps {
                        pack_a_fused_with(kind, &x, &g, &sa, hi_a, prec.a_bits, th, &mut ia);
                    }
                    let fus_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
                    let t0 = std::time::Instant::now();
                    for _ in 0..reps {
                        std::hint::black_box(gemm_mt(kind, &ia, &ib, th));
                    }
                    let gemm_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
                    println!(
                        "[perf] {:44} {:>11.2}x (ref {ref_ms:.3} -> fused {fus_ms:.3} ms, \
                         gemm {gemm_ms:.3} ms, {th} thr)",
                        format!("prologue fused[{kind}] {} {n}x{h}x{w}x{cin}", prec.tag()),
                        ref_ms / fus_ms.max(1e-9),
                    );
                    prologues.push(format!(
                        "    {{\"kernel\": \"fused-{kind}\", \"precision\": \"{}\", \
                         \"threads\": {th}, \"prologue_ms\": {fus_ms:.3}, \
                         \"gemm_ms\": {gemm_ms:.3}, \"reference_prologue_ms\": {ref_ms:.3}, \
                         \"speedup_vs_reference\": {:.3}}}",
                        prec.tag(),
                        ref_ms / fus_ms.max(1e-9)
                    ));
                }
            }
        }
        let json = format!(
            "{{\n  \"bench\": \"hotpath\",\n  \"quick\": {quick},\n  \"threads\": {threads},\n  \
             \"dispatch\": {{\"kernel\": \"{}\", \"block_c_words\": {}, \"block_l_cols\": {}, \
             \"available\": \"{}\"}},\n  \
             \"entries\": [\n{}\n  ],\n  \"fused_vs_reference\": [\n{}\n  ],\n  \
             \"simd_over_scalar\": [\n{}\n  ],\n  \"prologue\": [\n{}\n  ]\n}}\n",
            active.name(),
            block.c_words,
            block.l_cols,
            avail.join("+"),
            entries.join(",\n"),
            speedups.join(",\n"),
            simd_ratios.join(",\n"),
            prologues.join(",\n")
        );
        std::fs::write("BENCH_hotpath.json", &json).expect("write BENCH_hotpath.json");
        println!(
            "[perf] {:44} {:>12} entries -> BENCH_hotpath.json",
            "structured bench artifact",
            entries.len()
        );
    }

    // ---- full tile: pack + steps + recombine ----------------------------
    let reps = if quick { 200 } else { 2_000 };
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        let pa = PackedPlanes::from_a_matrix(&a, arch.c_dim, arch.l_dim, prec.a_bits);
        let pb = PackedPlanes::from_b_matrix(&b, arch.k_dim, arch.c_dim, prec.b_bits);
        std::hint::black_box(gavina::gemm::bitserial_gemm(&pa, &pb));
    }
    let secs = t0.elapsed().as_secs_f64();
    rate(
        "full a4w4 tile (pack+16 steps+recombine)",
        (arch.macs_per_tile() * reps) as f64,
        "MAC",
        secs,
    );

    // ---- error-model injection ------------------------------------------
    let tables = common::load_tables();
    let sched = GavSchedule::all_approx(prec);
    let seq0 = gavina::gemm::ipe_sequence(&pa, &pb);
    let reps = if quick { 200 } else { 2_000 };
    let mut inj_rng = Prng::new(0x13);
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        let mut seq = seq0.clone();
        std::hint::black_box(tables.inject(&mut seq, &sched, &mut inj_rng));
    }
    let secs = t0.elapsed().as_secs_f64();
    let values = (prec.steps() * arch.n_ipes() * reps) as f64;
    rate("error-model injection", values, "value", secs);

    // ---- cycle simulator end-to-end --------------------------------------
    let (c, l, k) = (1152, 64, 64);
    let (a, b) = gemm_workload(c, l, k, prec, &mut rng);
    let job = GemmJob {
        a: &a,
        b: &b,
        c,
        l,
        k,
        sched: sched.clone(),
    };
    let reps = if quick { 2 } else { 10 };
    let t0 = std::time::Instant::now();
    for i in 0..reps {
        let mut sim = GavinaSim::new(arch.clone(), Some(&tables), i as u64);
        std::hint::black_box(sim.run_gemm(&job));
    }
    let secs = t0.elapsed().as_secs_f64();
    rate(
        "cycle sim a4w4 GEMM 1152x64x64 (+errors)",
        ((c * l * k) as u64 * reps) as f64,
        "MAC",
        secs,
    );

    // ---- GLS event throughput --------------------------------------------
    let ctx = GlsContext::new(
        arch.c_dim,
        arch.clk_period_ps() as f64,
        DelayModel::default(),
        5,
    );
    let mut sim = ctx.spawn(0);
    let n_steps = if quick { 100 } else { 500 };
    let mut transitions = 0u64;
    let mut grng = Prng::new(0x615);
    let t0 = std::time::Instant::now();
    for _ in 0..n_steps {
        let a: Vec<bool> = (0..arch.c_dim).map(|_| grng.chance(0.5)).collect();
        let w: Vec<bool> = (0..arch.c_dim).map(|_| grng.chance(0.5)).collect();
        transitions += sim.step(&a, &w, 0.35).n_transitions;
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "[perf] {:44} {:>12.1} iPE-cycle/s ({:.1} transitions/cycle)",
        "GLS event-driven sim (C=576, V_aprox)",
        n_steps as f64 / secs,
        transitions as f64 / n_steps as f64
    );

    // ---- compile-once data plane: planned vs per-request lowering ---------
    {
        use gavina::dnn::exec::synth::synthetic_weights;
        use gavina::dnn::Executor;
        use gavina::engine::{EngineBuilder, FloatBackend, GavPolicy};

        let wm = 0.25;
        let weights = synthetic_weights(wm, 0xC0);
        let n = if quick { 2 } else { 4 };
        let mut irng = Prng::new(0xC1);
        let imgs: Vec<f32> = (0..n * 32 * 32 * 3).map(|_| irng.next_f32()).collect();

        // One-time compilation: quantize + bit-plane-pack all weights,
        // fold BN, resolve schedules. Reported separately so the
        // compile-once win (and its cost) is visible in the CI artifact.
        let t0 = std::time::Instant::now();
        let engine = EngineBuilder::new()
            .weights(weights.clone())
            .width_mult(wm)
            .precision(prec)
            .backend_float()
            .policy(GavPolicy::Exact)
            .build()
            .expect("engine config");
        let build_s = t0.elapsed().as_secs_f64();
        println!(
            "[perf] {:44} {:>12.3} ms ({} KiB packed weight planes)",
            "engine build() (lower + pack weights, 1x)",
            build_s * 1e3,
            engine.model().packed_weight_bytes() / 1024
        );

        let reps = if quick { 2 } else { 5 };
        // Warm-up: touch the scratch arena + page in the plans.
        let warm = engine.infer_batched(&imgs, n, n).expect("forward pass");

        let t0 = std::time::Instant::now();
        let mut planned = Vec::new();
        for _ in 0..reps {
            planned = engine.infer_batched(&imgs, n, n).expect("forward pass").logits;
        }
        let secs_planned = t0.elapsed().as_secs_f64();
        println!(
            "[perf] {:44} {:>12.3} ms/image",
            "planned steady-state infer (compile-once)",
            secs_planned * 1e3 / (reps * n) as f64
        );

        // The pre-refactor behaviour: every request re-lowers the model
        // (re-quantize + re-pack weights, re-fold BN) before forwarding.
        let t0 = std::time::Instant::now();
        let mut unplanned = Vec::new();
        for _ in 0..reps {
            unplanned = Executor::new(&weights, wm, prec, &FloatBackend)
                .forward(&imgs, n)
                .logits;
        }
        let secs_unplanned = t0.elapsed().as_secs_f64();
        println!(
            "[perf] {:44} {:>12.3} ms/image",
            "per-request lowering infer (first-call cost)",
            secs_unplanned * 1e3 / (reps * n) as f64
        );
        println!(
            "[perf] {:44} {:>11.2}x (per-request / planned)",
            "compile-once speedup",
            secs_unplanned / secs_planned.max(1e-12)
        );
        assert_eq!(
            planned, unplanned,
            "planned and per-request lowering must produce identical logits"
        );
        assert_eq!(warm.logits, planned, "steady-state must not drift");
    }

    // ---- ResNet-18 image latency ------------------------------------------
    let artifacts = common::artifacts_dir();
    if let Ok(weights) = gavina::dnn::load_tensors(&artifacts.join("weights_a4w4.bin")) {
        if let Ok(eval) = gavina::dnn::load_eval_set(&artifacts.join("dataset_eval.bin")) {
            let n = if quick { 2 } else { 8 };
            let engine = gavina::engine::EngineBuilder::new()
                .weights(weights)
                .precision(prec)
                .arch(arch.clone())
                .tables(std::sync::Arc::new(tables))
                .seed(3)
                .policy(gavina::engine::GavPolicy::Uniform(5))
                .build()
                .expect("engine config");
            let t0 = std::time::Instant::now();
            std::hint::black_box(
                engine
                    .infer_batched(&eval.images[..n * 3072], n, n)
                    .expect("forward pass"),
            );
            let secs = t0.elapsed().as_secs_f64();
            println!(
                "[perf] {:44} {:>12.1} ms/image (paper GPU model: 200 ms/img)",
                "ResNet-18 a4w4 inference (model path)",
                secs * 1e3 / n as f64
            );
        }
    }
}
