//! Regenerates **Fig. 1**: the digital DNN-accelerator landscape —
//! energy efficiency vs precision, undervolting vs not — with GAVINA's
//! operating points overlaid. Printed as an ASCII scatter (log-efficiency
//! x precision) plus the underlying datapoint table.

mod common;

use gavina::arch::GavSchedule;
use gavina::arch::Precision;
use gavina::baseline::LITERATURE;
use gavina::power::PowerModel;

fn main() {
    let power = PowerModel::paper_calibrated();
    let util = 0.96;

    common::section("Fig. 1 — accelerator landscape (TOP/sW vs precision)");
    // Collect points: (name, bits, tops/w, uv).
    let mut points: Vec<(String, u8, f64, bool)> = LITERATURE
        .iter()
        .filter(|e| !e.tops_per_w.is_nan())
        .map(|e| (format!("{} {}", e.name, e.reference), e.precision_bits, e.tops_per_w, e.undervolting))
        .collect();
    for prec in Precision::EVAL_SET {
        let lo = power.tops_per_watt(&GavSchedule::all_guarded(prec), util);
        let hi = power.tops_per_watt(&GavSchedule::all_approx(prec), util);
        points.push((format!("GAVINA {prec} (guard)"), prec.a_bits, lo, false));
        points.push((format!("GAVINA {prec} (UV)"), prec.a_bits, hi, true));
    }
    points.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());

    println!("{:28} {:>5} {:>9}  UV", "design", "bits", "TOP/sW");
    for (name, bits, tw, uv) in &points {
        println!("{name:28} {bits:>5} {tw:>9.1}  {}", if *uv { "✓" } else { "×" });
    }

    // ASCII scatter: rows = log10(TOP/sW) bands, cols = precision.
    common::section("scatter (rows: log10 TOP/sW, cols: precision bits)");
    println!("            1b   2b   3b   4b   8b");
    for band in (0..8).rev() {
        let lo = 10f64.powf(band as f64 / 2.0 - 0.25);
        let hi = 10f64.powf(band as f64 / 2.0 + 0.25);
        let mut row = String::new();
        for bits in [1u8, 2, 3, 4, 8] {
            let mut c = "  .  ";
            for (name, pb, tw, uv) in &points {
                if *pb == bits && *tw >= lo && *tw < hi {
                    c = if name.starts_with("GAVINA") {
                        if *uv {
                            "  G* "
                        } else {
                            "  G  "
                        }
                    } else if *uv {
                        "  u  "
                    } else {
                        "  o  "
                    };
                }
            }
            row.push_str(c);
        }
        println!("{:8.1} |{row}", (lo * hi).sqrt());
    }
    println!("\nlegend: G = GAVINA, G* = GAVINA undervolted, o = literature, u = literature w/ UV");
    println!("shape: GAVINA's UV points push each precision column up ~×1.9, reaching the");
    println!("low-precision frontier the 8-bit undervolting accelerators cannot touch.");
}
