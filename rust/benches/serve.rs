//! Closed-loop serving bench, two parts:
//!
//! 1. **Governor ramp** — per-tier latency percentiles and throughput
//!    under a low → high → low load ramp, plus the governor's
//!    per-layer-G trajectory across the ramp. Asserts the governor
//!    visits at least two distinct schedules (the paper's §IV-D
//!    flexibility exercised at serving time).
//! 2. **Replica sweep** — the same mixed three-tier traffic pushed
//!    through 1 / 2 / 4 / 8 replicas per tier (continuous batching +
//!    work-stealing, no governor), emitting a structured
//!    `BENCH_serve.json` artifact (throughput, per-tier p50/p99, steal
//!    counts) that CI uploads and gates on. Asserts aggregate
//!    throughput does not degrade from 1 → 4 replicas and the exact
//!    tier's p99 under mixed load stays bounded relative to the
//!    single-replica run.
//!
//! Flags: `--quick` (CI-sized run).

mod common;

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use gavina::arch::{ArchConfig, Precision};
use gavina::engine::{Engine, EngineBuilder, GavPolicy, GavinaError};
use gavina::serve::{
    CanaryOptions, GovernorOptions, ServeOptions, Service, Session, SubmitOptions, Ticket,
    TierSpec,
};
use gavina::util::Prng;

/// Keep `concurrency` requests outstanding until `n_requests` have been
/// submitted *and* the governor has ticked at least `min_ticks` more
/// times (so every phase is long enough for the control loop to react).
/// Returns (served, rejected).
fn run_phase(
    service: &Service,
    session: &Session,
    images: &[Vec<f32>],
    concurrency: usize,
    n_requests: usize,
    min_ticks: usize,
) -> (usize, usize) {
    let tick0 = service.governor_ticks();
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut outstanding: VecDeque<Ticket> = VecDeque::new();
    let mut sent = 0usize;
    let mut served = 0usize;
    let mut rejected = 0usize;
    let mut i = 0usize;
    loop {
        let need_requests = sent < n_requests;
        let need_ticks = service.governor_ticks() < tick0 + min_ticks;
        if !need_requests && !need_ticks {
            break;
        }
        if Instant::now() > deadline {
            eprintln!("[serve] phase wall-clock cap hit (governor too slow?)");
            break;
        }
        // Every 8th request exercises the bit-exact tier; the rest ride
        // the governed default tier.
        let image = images[i % images.len()].clone();
        let res = if i % 8 == 0 {
            session.submit_with(image, SubmitOptions::new().tier("exact"))
        } else {
            session.submit(image)
        };
        i += 1;
        match res {
            Ok(t) => {
                outstanding.push_back(t);
                sent += 1;
            }
            Err(GavinaError::Overloaded { .. }) => {
                rejected += 1;
                // Back off: drain one response before retrying.
                if let Some(t) = outstanding.pop_front() {
                    t.wait().expect("response");
                    served += 1;
                }
            }
            Err(e) => panic!("submit failed: {e}"),
        }
        while outstanding.len() >= concurrency {
            let t = outstanding.pop_front().expect("nonempty");
            t.wait().expect("response");
            served += 1;
        }
    }
    for t in outstanding {
        t.wait().expect("response");
        served += 1;
    }
    (served, rejected)
}

fn governor_ramp(engine: &Arc<Engine>, quick: bool) {
    let queue_depth = 16;
    let opts = ServeOptions {
        replicas: 1,
        queue_depth,
        steal: true,
        steal_reserve: 2,
        default_tier: "guarded".into(),
        tiers: vec![
            TierSpec::new("exact", Some(GavPolicy::Exact)).max_batch(4),
            TierSpec::new("guarded", None).max_batch(4),
            TierSpec::new("aggressive", Some(GavPolicy::Uniform(0))).max_batch(8),
        ],
        governor: Some(GovernorOptions {
            period: Duration::from_millis(15),
            high_load: 0.6,
            low_load: 0.3,
            ..Default::default()
        }),
        // Canary on: the bench engine carries no error tables, so the
        // observed flip rate is 0.0 and the governor's load behavior is
        // unchanged — but the sampling/re-run path runs end-to-end and
        // the per-tier observed_flip_rate lines below are a CI artifact
        // check.
        canary: Some(CanaryOptions {
            sample_rate: 0.25,
            ..Default::default()
        }),
    };
    println!(
        "[serve] closed-loop bench: {}, queue_depth {queue_depth}, governor period 15 ms, \
         canary sample rate 0.25",
        engine.precision()
    );

    let mut rng = Prng::new(0x5EED);
    let images: Vec<Vec<f32>> = (0..32)
        .map(|_| (0..32 * 32 * 3).map(|_| rng.next_f32()).collect())
        .collect();

    let service = Arc::clone(engine).serve(opts).expect("serve options");
    let session = service.session();

    // Load ramp: low → high → low concurrency, relative to queue_depth
    // and the governor's 0.3 / 0.6 thresholds.
    let n = if quick { 24 } else { 96 };
    let ticks = if quick { 3 } else { 6 };
    let phases = [("low", 2usize, n), ("high", 12, 3 * n), ("low", 2, n)];
    let t0 = Instant::now();
    let mut total_rejected = 0usize;
    for (name, concurrency, n_requests) in phases {
        let p0 = Instant::now();
        let (served, rejected) =
            run_phase(&service, &session, &images, concurrency, n_requests, ticks);
        total_rejected += rejected;
        println!(
            "[serve] phase {name:5} concurrency {concurrency:2}: {served} served, \
             {rejected} rejected in {:.2} s",
            p0.elapsed().as_secs_f64()
        );
    }
    let wall = t0.elapsed().as_secs_f64();

    let report = service.shutdown();
    for m in &report.tiers {
        println!(
            "[serve] tier {:10} {:5} reqs {:8.1} req/s  p50 {:7.2} ms  p99 {:7.2} ms  \
             max {:7.2} ms  {} batches  {} steals",
            m.tier,
            m.requests,
            m.requests_per_sec,
            m.p50_us as f64 / 1e3,
            m.p99_us as f64 / 1e3,
            m.max_us as f64 / 1e3,
            m.batches,
            m.steals,
        );
    }
    println!(
        "[serve] total: {} reqs in {wall:.2} s ({total_rejected} briefly rejected at admission)",
        report.requests()
    );

    // The governor must have moved the default tier's per-layer G across
    // the ramp: at least two distinct schedules in the trajectory.
    let mut distinct: Vec<&Vec<u32>> = Vec::new();
    for step in &report.governor {
        if !distinct.iter().any(|gs| **gs == step.layer_gs) {
            distinct.push(&step.layer_gs);
        }
    }
    println!(
        "[serve] governor trajectory ({} ticks): mean-G [{}]",
        report.governor.len(),
        report
            .governor
            .iter()
            .map(|s| format!("{:.1}", s.mean_g))
            .collect::<Vec<_>>()
            .join(" ")
    );
    for gs in &distinct {
        println!("[serve]   schedule visited: {gs:?}");
    }
    println!("[serve] governor distinct schedules: {}", distinct.len());
    assert!(
        distinct.len() >= 2,
        "governor must move per-layer G between at least two distinct schedules \
         across the load ramp (saw {})",
        distinct.len()
    );

    // Per-tier canary drift lines (CI greps for observed_flip_rate).
    assert!(!report.canary.is_empty(), "canary was enabled — reports must exist");
    for c in &report.canary {
        println!("[serve] {}", c.summary_line());
        assert!(c.sampled > 0, "rate 0.25 over the ramp must sample requests");
        assert_eq!(
            c.flips, 0,
            "no error tables — served logits must match the exact reference"
        );
    }
}

/// One sweep point's results, for the JSON artifact and the asserts.
struct SweepPoint {
    replicas: usize,
    throughput_rps: f64,
    steals: u64,
    exact_p99_us: u64,
    tier_lines: Vec<String>,
}

/// Push `n_requests` of mixed three-tier traffic through a fresh
/// service (no governor) and measure aggregate throughput.
fn sweep_point(
    engine: &Arc<Engine>,
    images: &[Vec<f32>],
    replicas: usize,
    n_requests: usize,
) -> SweepPoint {
    let opts = ServeOptions {
        replicas,
        queue_depth: 64,
        steal: true,
        steal_reserve: 2,
        default_tier: "guarded".into(),
        tiers: vec![
            TierSpec::new("exact", Some(GavPolicy::Exact)).max_batch(4),
            TierSpec::new("guarded", None).max_batch(8),
            TierSpec::new("aggressive", Some(GavPolicy::Uniform(0))).max_batch(16),
        ],
        governor: None,
        canary: None,
    };
    let service = Arc::clone(engine).serve(opts).expect("serve options");
    let session = service.session();
    let concurrency = 12usize;
    let mut outstanding: VecDeque<Ticket> = VecDeque::new();
    let t0 = Instant::now();
    let mut served = 0usize;
    let mut sent = 0usize;
    let mut i = 0usize;
    while sent < n_requests {
        let image = images[i % images.len()].clone();
        // Mixed load: every 8th request exact, every 3rd aggressive,
        // the rest on the default tier.
        let res = if i % 8 == 0 {
            session.submit_with(image, SubmitOptions::new().tier("exact"))
        } else if i % 3 == 0 {
            session.submit_with(image, SubmitOptions::new().tier("aggressive"))
        } else {
            session.submit(image)
        };
        i += 1;
        match res {
            Ok(t) => {
                outstanding.push_back(t);
                sent += 1;
            }
            Err(GavinaError::Overloaded { .. }) => {
                if let Some(t) = outstanding.pop_front() {
                    t.wait().expect("response");
                    served += 1;
                }
            }
            Err(e) => panic!("submit failed: {e}"),
        }
        while outstanding.len() >= concurrency {
            let t = outstanding.pop_front().expect("nonempty");
            t.wait().expect("response");
            served += 1;
        }
    }
    for t in outstanding {
        t.wait().expect("response");
        served += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = service.shutdown();
    assert_eq!(served, n_requests, "closed loop must answer every request");
    let exact_p99_us = report.tier("exact").map(|m| m.p99_us).unwrap_or(0);
    let tier_lines = report
        .tiers
        .iter()
        .map(|m| {
            format!(
                "      {{\"tier\": \"{}\", \"requests\": {}, \"p50_us\": {}, \"p99_us\": {}, \
                 \"batches\": {}, \"steals\": {}}}",
                m.tier, m.requests, m.p50_us, m.p99_us, m.batches, m.steals
            )
        })
        .collect();
    SweepPoint {
        replicas,
        throughput_rps: served as f64 / wall,
        steals: report.steals(),
        exact_p99_us,
        tier_lines,
    }
}

fn replica_sweep(engine: &Arc<Engine>, quick: bool) {
    let mut rng = Prng::new(0xB00);
    let images: Vec<Vec<f32>> = (0..32)
        .map(|_| (0..32 * 32 * 3).map(|_| rng.next_f32()).collect())
        .collect();
    let n_requests = if quick { 160 } else { 640 };
    println!("[serve] replica sweep: {n_requests} mixed requests per point, concurrency 12");

    let mut points = Vec::new();
    for replicas in [1usize, 2, 4, 8] {
        let p = sweep_point(engine, &images, replicas, n_requests);
        println!(
            "[serve] replica sweep replicas={} throughput {:8.1} rps  exact p99 {:7.2} ms  \
             {} steals",
            p.replicas,
            p.throughput_rps,
            p.exact_p99_us as f64 / 1e3,
            p.steals,
        );
        points.push(p);
    }

    let entries: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "    {{\n      \"replicas\": {},\n      \"throughput_rps\": {:.1},\n      \
                 \"steals\": {},\n      \"exact_p99_us\": {},\n      \"tiers\": [\n{}\n      ]\n    }}",
                p.replicas,
                p.throughput_rps,
                p.steals,
                p.exact_p99_us,
                p.tier_lines.join(",\n")
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"serve_replica_sweep\",\n  \"quick\": {},\n  \
         \"n_requests\": {},\n  \"concurrency\": 12,\n  \"entries\": [\n{}\n  ]\n}}\n",
        quick,
        n_requests,
        entries.join(",\n")
    );
    std::fs::write("BENCH_serve.json", &json).expect("write BENCH_serve.json");
    println!(
        "[serve] structured bench artifact: {} sweep points -> BENCH_serve.json",
        points.len()
    );

    // Scaling gates, deliberately tolerant — CI machines are noisy and
    // the tiny model saturates quickly. Throughput must not *degrade*
    // from sharding (1 → 4 replicas), and the exact tier's tail under
    // mixed load must stay in the same regime as the single-replica run.
    let thr1 = points[0].throughput_rps;
    let thr4 = points[2].throughput_rps;
    assert!(
        thr4 >= thr1 * 0.9,
        "4-replica throughput must not degrade vs 1 replica: {thr4:.1} vs {thr1:.1} rps"
    );
    let p99_1 = points[0].exact_p99_us as f64;
    let p99_4 = points[2].exact_p99_us as f64;
    assert!(
        p99_4 <= p99_1 * 2.0 + 25_000.0,
        "exact-tier p99 under mixed load blew up with 4 replicas: \
         {:.2} ms vs {:.2} ms at 1 replica",
        p99_4 / 1e3,
        p99_1 / 1e3
    );
}

fn main() {
    let quick = common::quick();
    let engine = Arc::new(
        EngineBuilder::new()
            .synthetic_weights(0.125, 0x5E)
            .precision(Precision::new(2, 2))
            .arch(ArchConfig::tiny())
            .policy(GavPolicy::Uniform(2))
            .seed(3)
            .threads(1)
            .build()
            .expect("engine config"),
    );
    governor_ramp(&engine, quick);
    replica_sweep(&engine, quick);
}
