//! Closed-loop serving bench: per-tier latency percentiles and
//! throughput under a low → high → low load ramp, plus the governor's
//! per-layer-G trajectory across the ramp.
//!
//! The load generator keeps a fixed number of requests outstanding
//! (closed loop) per phase; the governor watches the admission-queue
//! load fraction and slides the default tier along its undervolting
//! ladder — the bench asserts it visits at least two distinct per-layer
//! schedules, which is the paper's §IV-D flexibility exercised at
//! serving time.
//!
//! Flags: `--quick` (CI-sized run).

mod common;

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use gavina::arch::{ArchConfig, Precision};
use gavina::engine::{EngineBuilder, GavPolicy, GavinaError};
use gavina::serve::{
    GovernorOptions, ServeOptions, Service, Session, SubmitOptions, Ticket, TierSpec,
};
use gavina::util::Prng;

/// Keep `concurrency` requests outstanding until `n_requests` have been
/// submitted *and* the governor has ticked at least `min_ticks` more
/// times (so every phase is long enough for the control loop to react).
/// Returns (served, rejected).
fn run_phase(
    service: &Service,
    session: &Session,
    images: &[Vec<f32>],
    concurrency: usize,
    n_requests: usize,
    min_ticks: usize,
) -> (usize, usize) {
    let tick0 = service.governor_ticks();
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut outstanding: VecDeque<Ticket> = VecDeque::new();
    let mut sent = 0usize;
    let mut served = 0usize;
    let mut rejected = 0usize;
    let mut i = 0usize;
    loop {
        let need_requests = sent < n_requests;
        let need_ticks = service.governor_ticks() < tick0 + min_ticks;
        if !need_requests && !need_ticks {
            break;
        }
        if Instant::now() > deadline {
            eprintln!("[serve] phase wall-clock cap hit (governor too slow?)");
            break;
        }
        // Every 8th request exercises the bit-exact tier; the rest ride
        // the governed default tier.
        let image = images[i % images.len()].clone();
        let res = if i % 8 == 0 {
            session.submit_with(image, SubmitOptions::new().tier("exact"))
        } else {
            session.submit(image)
        };
        i += 1;
        match res {
            Ok(t) => {
                outstanding.push_back(t);
                sent += 1;
            }
            Err(GavinaError::Overloaded { .. }) => {
                rejected += 1;
                // Back off: drain one response before retrying.
                if let Some(t) = outstanding.pop_front() {
                    t.wait().expect("response");
                    served += 1;
                }
            }
            Err(e) => panic!("submit failed: {e}"),
        }
        while outstanding.len() >= concurrency {
            let t = outstanding.pop_front().expect("nonempty");
            t.wait().expect("response");
            served += 1;
        }
    }
    for t in outstanding {
        t.wait().expect("response");
        served += 1;
    }
    (served, rejected)
}

fn main() {
    let quick = common::quick();
    let prec = Precision::new(2, 2);
    let engine = Arc::new(
        EngineBuilder::new()
            .synthetic_weights(0.125, 0x5E)
            .precision(prec)
            .arch(ArchConfig::tiny())
            .policy(GavPolicy::Uniform(2))
            .seed(3)
            .build()
            .expect("engine config"),
    );

    let queue_depth = 16;
    let opts = ServeOptions {
        workers: 2,
        queue_depth,
        default_tier: "guarded".into(),
        tiers: vec![
            TierSpec::new("exact", Some(GavPolicy::Exact)).max_batch(1),
            TierSpec::new("guarded", None)
                .max_batch(4)
                .batch_timeout(Duration::from_millis(4)),
            TierSpec::new("aggressive", Some(GavPolicy::Uniform(0)))
                .max_batch(8)
                .batch_timeout(Duration::from_millis(2)),
        ],
        governor: Some(GovernorOptions {
            period: Duration::from_millis(15),
            high_load: 0.6,
            low_load: 0.3,
            ..Default::default()
        }),
    };
    println!(
        "[serve] closed-loop bench: {prec}, queue_depth {queue_depth}, governor period 15 ms"
    );

    let mut rng = Prng::new(0x5EED);
    let images: Vec<Vec<f32>> = (0..32)
        .map(|_| (0..32 * 32 * 3).map(|_| rng.next_f32()).collect())
        .collect();

    let service = Arc::clone(&engine).serve(opts).expect("serve options");
    let session = service.session();

    // Load ramp: low → high → low concurrency, relative to queue_depth
    // and the governor's 0.3 / 0.6 thresholds.
    let n = if quick { 24 } else { 96 };
    let ticks = if quick { 3 } else { 6 };
    let phases = [("low", 2usize, n), ("high", 12, 3 * n), ("low", 2, n)];
    let t0 = Instant::now();
    let mut total_rejected = 0usize;
    for (name, concurrency, n_requests) in phases {
        let p0 = Instant::now();
        let (served, rejected) =
            run_phase(&service, &session, &images, concurrency, n_requests, ticks);
        total_rejected += rejected;
        println!(
            "[serve] phase {name:5} concurrency {concurrency:2}: {served} served, \
             {rejected} rejected in {:.2} s",
            p0.elapsed().as_secs_f64()
        );
    }
    let wall = t0.elapsed().as_secs_f64();

    let report = service.shutdown();
    for m in &report.tiers {
        println!(
            "[serve] tier {:10} {:5} reqs {:8.1} req/s  p50 {:7.2} ms  p99 {:7.2} ms  \
             max {:7.2} ms  {} batches",
            m.tier,
            m.requests,
            m.requests_per_sec,
            m.p50_us as f64 / 1e3,
            m.p99_us as f64 / 1e3,
            m.max_us as f64 / 1e3,
            m.batches,
        );
    }
    println!(
        "[serve] total: {} reqs in {wall:.2} s ({total_rejected} briefly rejected at admission)",
        report.requests()
    );

    // The governor must have moved the default tier's per-layer G across
    // the ramp: at least two distinct schedules in the trajectory.
    let mut distinct: Vec<&Vec<u32>> = Vec::new();
    for step in &report.governor {
        if !distinct.iter().any(|gs| **gs == step.layer_gs) {
            distinct.push(&step.layer_gs);
        }
    }
    println!(
        "[serve] governor trajectory ({} ticks): mean-G [{}]",
        report.governor.len(),
        report
            .governor
            .iter()
            .map(|s| format!("{:.1}", s.mean_g))
            .collect::<Vec<_>>()
            .join(" ")
    );
    for gs in &distinct {
        println!("[serve]   schedule visited: {gs:?}");
    }
    println!("[serve] governor distinct schedules: {}", distinct.len());
    assert!(
        distinct.len() >= 2,
        "governor must move per-layer G between at least two distinct schedules \
         across the load ramp (saw {})",
        distinct.len()
    );
}
