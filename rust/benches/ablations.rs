//! Ablations of the design choices DESIGN.md calls out — beyond the
//! paper's evaluated configuration:
//!
//! 1. **Multi-level GAV** (§II/§III "can be extended to any number of
//!    discrete voltage levels"): a 3-level policy (0.35 / 0.45 / guard)
//!    against the paper's 2-level policy on the error-vs-power plane.
//! 2. **Error-model hyper-parameters** (§IV-C `[n_nei, p_bins]`): how much
//!    do the previous-value and neighbour dependencies buy in fidelity?
//! 3. **SCM vs SRAM memories** (§IV-A: SCM = ×4 memory power reduction):
//!    system-level impact on TOP/sW and on the undervolting boost.

mod common;

use gavina::arch::{ArchConfig, GavSchedule, Precision, VoltageMode};
use gavina::errmodel::{
    calibrate_with_params, CalibrationConfig, ModelParams, MultiLevelTables,
};
use gavina::gls::{DelayModel, GlsContext, TileGls};
use gavina::power::PowerModel;
use gavina::quant::PackedPlanes;
use gavina::stats::{mean, var_ned};
use gavina::util::Prng;
use gavina::workload::uniform_ip_matrices;

fn main() {
    let quick = common::quick();
    ablation_multilevel(quick);
    ablation_model_params(quick);
    ablation_scm_vs_sram();
}

// --------------------------------------------------------------------
// 1. Multi-level GAV
// --------------------------------------------------------------------
fn ablation_multilevel(quick: bool) {
    common::section("Ablation 1 — multi-level GAV (0.35 V / 0.45 V / guard)");
    let arch = ArchConfig::paper();
    let prec = Precision::new(4, 4);
    let power = PowerModel::paper_calibrated();
    let ctx = GlsContext::new(
        arch.c_dim,
        arch.clk_period_ps() as f64,
        DelayModel::default(),
        0xAB1,
    );
    let streams = if quick { 128 } else { 384 };
    let cal = |v: f64| {
        calibrate_with_params(
            &ctx,
            CalibrationConfig {
                n_streams: streams,
                seq_len: 32,
                v_aprox: v,
                ..Default::default()
            },
            ModelParams::paper(arch.c_dim),
        )
        .0
    };
    let t35 = common::bench_time("calibrate tables @0.35V", || cal(0.35));
    let t45 = common::bench_time("calibrate tables @0.45V", || cal(0.45));
    let ml = MultiLevelTables::new(vec![(0.35, t35.clone()), (0.45, t45)]);

    let mut rng = Prng::new(0x3117_4EE1);
    let (a, b) = uniform_ip_matrices(arch.c_dim, arch.l_dim * 1, arch.k_dim, prec, &mut rng);
    let pa = PackedPlanes::from_a_matrix(&a, arch.c_dim, arch.l_dim, prec.a_bits);
    let pb = PackedPlanes::from_b_matrix(&b, arch.k_dim, arch.c_dim, prec.b_bits);
    let exact = gavina::gemm::gemm_exact(&a, &b, arch.c_dim, arch.l_dim, arch.k_dim);
    let trials = if quick { 8 } else { 32 };

    let eval = |sched: &GavSchedule, use_multi: bool, rng: &mut Prng| -> (f64, f64) {
        let mut vars = Vec::new();
        for _ in 0..trials {
            let mut seq = gavina::gemm::ipe_sequence(&pa, &pb);
            if use_multi {
                ml.inject(&mut seq, sched, rng);
            } else {
                t35.inject(&mut seq, sched, rng);
            }
            vars.push(var_ned(&exact, &gavina::gemm::recombine(&seq, prec)));
        }
        let p = power.array_avg_power_multi(sched, &[0.35, 0.45]);
        (mean(&vars), p)
    };

    println!("\npolicy                      | VAR_NED     | array power [mW]");
    println!("----------------------------+-------------+-----------------");
    // Two-level sweep (the paper's policy).
    for g in [2u32, 4, 6] {
        let sched = GavSchedule::two_level(prec, g);
        let (v, p) = eval(&sched, false, &mut rng);
        println!("2-level G={g}                 | {v:11.4e} | {p:8.2}");
    }
    // Three-level: top t1 guarded, next t2 at 0.45, rest 0.35.
    for (t1, t2) in [(1u32, 2u32), (2, 2), (2, 4), (4, 2)] {
        let s_max = prec.s_max();
        let sched = GavSchedule::custom(prec, |s| {
            if s + t1 > s_max {
                VoltageMode::Guarded
            } else if s + t1 + t2 > s_max {
                VoltageMode::Level(1) // 0.45 V
            } else {
                VoltageMode::Level(0) // 0.35 V
            }
        });
        let (v, p) = eval(&sched, true, &mut rng);
        println!("3-level guard={t1} mid={t2}       | {v:11.4e} | {p:8.2}");
    }
    println!("\n(reading: 3-level points sit below the 2-level error/power frontier —");
    println!(" a mid voltage recovers most accuracy of guarding at a fraction of its power)");
}

// --------------------------------------------------------------------
// 2. Error-model hyper-parameters
// --------------------------------------------------------------------
fn ablation_model_params(quick: bool) {
    common::section("Ablation 2 — error-model hyper-parameters [n_nei, p_bins]");
    let arch = ArchConfig::paper(); // the real array: C=576, 10-bit sums
    let ctx = GlsContext::new(
        arch.c_dim,
        arch.clk_period_ps() as f64,
        DelayModel::default(),
        0xAB2,
    );
    let prec = Precision::new(4, 4);
    let sched = GavSchedule::all_approx(prec);
    let streams = if quick { 256 } else { 768 };
    let trials = if quick { 4 } else { 8 };

    // Ground truth: GLS tiles.
    let mut rng = Prng::new(0x1AB2E);
    let mut tiles = Vec::new();
    let mut tg = TileGls::new(&ctx, arch.clone());
    for _ in 0..trials {
        let (a, b) = uniform_ip_matrices(arch.c_dim, arch.l_dim, arch.k_dim, prec, &mut rng);
        let pa = PackedPlanes::from_a_matrix(&a, arch.c_dim, arch.l_dim, prec.a_bits);
        let pb = PackedPlanes::from_b_matrix(&b, arch.k_dim, arch.c_dim, prec.b_bits);
        let exact = gavina::gemm::gemm_exact(&a, &b, arch.c_dim, arch.l_dim, arch.k_dim);
        let v_gls = var_ned(&exact, &tg.run_tile(&pa, &pb, &sched).approx_gemm(prec));
        tiles.push((pa, pb, exact, v_gls));
    }
    let gls_mean = mean(&tiles.iter().map(|t| t.3).collect::<Vec<_>>());
    println!("GLS reference VAR_NED (mean of {trials} tiles): {gls_mean:.4e}\n");

    println!("n_nei | p_bins | model VAR_NED | deviation vs GLS");
    println!("------+--------+---------------+-----------------");
    for n_nei in [0usize, 1, 2] {
        for p_bins in [1usize, 4, 16] {
            let params = ModelParams {
                s_bits: gavina::util::bits_for(arch.c_dim as u64) as usize,
                c_dim: arch.c_dim,
                p_bins,
                n_nei,
            };
            let (tables, _) = calibrate_with_params(
                &ctx,
                CalibrationConfig {
                    n_streams: streams,
                    seq_len: 32,
                    ..Default::default()
                },
                params,
            );
            let mut vars = Vec::new();
            let mut rng2 = Prng::new(7);
            for (pa, pb, exact, _) in &tiles {
                let mut seq = gavina::gemm::ipe_sequence(pa, pb);
                tables.inject(&mut seq, &sched, &mut rng2);
                vars.push(var_ned(exact, &gavina::gemm::recombine(&seq, prec)));
            }
            let m = mean(&vars);
            println!(
                "  {n_nei}   |   {p_bins:2}   | {m:13.4e} | {:+7.1}%",
                (m / gls_mean - 1.0) * 100.0
            );
        }
    }
    println!("\n(the paper's [2, 16] should sit closest to GLS; dropping the neighbour");
    println!(" dependency overestimates isolated flips, dropping prev-bins misses the");
    println!(" switching-distance effect)");
}

// --------------------------------------------------------------------
// 3. SCM vs SRAM memories
// --------------------------------------------------------------------
fn ablation_scm_vs_sram() {
    common::section("Ablation 3 — SCM vs SRAM memories (paper §IV-A: SCM = ×4 mem power)");
    let scm = PowerModel::paper_calibrated();
    let sram = PowerModel::paper_calibrated().with_sram_memories();
    println!("config | prec | total guarded [mW] | TOP/sW (guard–aggr) | UV boost");
    for (name, m) in [("SCM ", &scm), ("SRAM", &sram)] {
        for prec in [Precision::new(2, 2), Precision::new(8, 8)] {
            let pg = m.system_power_mw(&GavSchedule::all_guarded(prec));
            let lo = m.tops_per_watt(&GavSchedule::all_guarded(prec), 0.96);
            let hi = m.tops_per_watt(&GavSchedule::all_approx(prec), 0.96);
            println!(
                "{name}   | {prec} | {pg:18.2} | {lo:6.2} – {hi:6.2}     | ×{:.2}",
                m.undervolting_boost(prec)
            );
        }
    }
    println!("\n(SRAM memories both cut absolute efficiency AND shrink the undervolting");
    println!(" boost — the array becomes a smaller share of total power, which is why");
    println!(" the paper pays ×2 area for SCMs)");
}
