//! Regenerates **Fig. 7**: fidelity of the heuristic error model against
//! gate-level simulation — (b/c) per-bit error maps GLS vs model, (d)
//! model-vs-GLS agreement on a batch of images through the quantized
//! network, plus the §IV-C acceptance criterion (VAR_NED within a band of
//! GLS) and the headline model speedup.

mod common;

use std::sync::Arc;

use gavina::arch::{ArchConfig, GavSchedule, Precision};
use gavina::dnn;
use gavina::engine::{EngineBuilder, GavPolicy};
use gavina::gls::{DelayModel, GlsContext, TileGls};
use gavina::quant::PackedPlanes;
use gavina::stats::{accuracy, bit_flip_rates, mean, var_ned};
use gavina::util::Prng;
use gavina::workload::uniform_ip_matrices;

fn main() {
    let quick = common::quick();
    let tables = Arc::new(common::load_tables());
    let arch = ArchConfig::paper();
    let prec = Precision::new(4, 4);
    let sched = GavSchedule::all_approx(prec);
    let ctx = Arc::new(GlsContext::new(
        arch.c_dim,
        arch.clk_period_ps() as f64,
        DelayModel::default(),
        17,
    ));

    // ---- Fig. 7b/c: per-bit error maps, GLS vs model -------------------
    common::section("Fig. 7b/c — per-bit flip rates on iPE outputs (GLS vs model)");
    let n_tiles = if quick { 2 } else { 6 };
    let mut rng = Prng::new(0xF17);
    let mut gls_exact = Vec::new();
    let mut gls_sampled = Vec::new();
    let mut model_exact = Vec::new();
    let mut model_sampled = Vec::new();
    let mut tg = TileGls::new(&ctx, arch.clone());
    let mut gls_secs = 0.0;
    let mut model_secs = 0.0;
    for t in 0..n_tiles {
        let (a, b) = uniform_ip_matrices(arch.c_dim, arch.l_dim, arch.k_dim, prec, &mut rng);
        let pa = PackedPlanes::from_a_matrix(&a, arch.c_dim, arch.l_dim, prec.a_bits);
        let pb = PackedPlanes::from_b_matrix(&b, arch.k_dim, arch.c_dim, prec.b_bits);

        let t0 = std::time::Instant::now();
        let trace = tg.run_tile(&pa, &pb, &sched);
        gls_secs += t0.elapsed().as_secs_f64();
        for (ex, sa) in trace.exact.iter().zip(&trace.sampled) {
            gls_exact.extend_from_slice(ex);
            gls_sampled.extend_from_slice(sa);
        }

        let t0 = std::time::Instant::now();
        let exact_seq = gavina::gemm::ipe_sequence(&pa, &pb);
        let mut seq = exact_seq.clone();
        let mut inj_rng = Prng::new(0xAB + t as u64);
        tables.inject(&mut seq, &sched, &mut inj_rng);
        model_secs += t0.elapsed().as_secs_f64();
        for (ex, sa) in exact_seq.iter().zip(&seq) {
            model_exact.extend_from_slice(ex);
            model_sampled.extend_from_slice(sa);
        }
    }
    let s_bits = arch.sum_bits();
    let r_gls = bit_flip_rates(&gls_exact, &gls_sampled, s_bits);
    let r_model = bit_flip_rates(&model_exact, &model_sampled, s_bits);
    println!("bit | GLS rate | model rate");
    for bit in 0..s_bits {
        println!("{bit:3} | {:8.4} | {:8.4}", r_gls[bit], r_model[bit]);
    }
    let speedup = gls_secs / model_secs.max(1e-9);
    println!("\nmodel speedup over GLS on identical tiles: ×{speedup:.0} (paper: ×3.6e4 vs Cadence GLS)");

    // ---- §IV-C acceptance: VAR_NED within a band -----------------------
    common::section("Model VAR_NED vs GLS VAR_NED (paper: within 8% on average)");
    let mut dev = Vec::new();
    for trial in 0..n_tiles {
        let (a, b) = uniform_ip_matrices(arch.c_dim, arch.l_dim, arch.k_dim, prec, &mut rng);
        let pa = PackedPlanes::from_a_matrix(&a, arch.c_dim, arch.l_dim, prec.a_bits);
        let pb = PackedPlanes::from_b_matrix(&b, arch.k_dim, arch.c_dim, prec.b_bits);
        let exact = gavina::gemm::gemm_exact(&a, &b, arch.c_dim, arch.l_dim, arch.k_dim);
        let v_gls = var_ned(&exact, &tg.run_tile(&pa, &pb, &sched).approx_gemm(prec));
        let mut seq = gavina::gemm::ipe_sequence(&pa, &pb);
        let mut inj_rng = Prng::new(0xCD + trial as u64);
        tables.inject(&mut seq, &sched, &mut inj_rng);
        let v_model = var_ned(&exact, &gavina::gemm::recombine(&seq, prec));
        let d = (v_model - v_gls).abs() / v_gls.max(1e-12);
        println!("tile {trial}: GLS {v_gls:.4e}  model {v_model:.4e}  |dev| {:.1}%", d * 100.0);
        dev.push(d);
    }
    println!("mean |deviation|: {:.1}%", mean(&dev) * 100.0);

    // ---- Fig. 7d: accuracy, model vs GLS-backed, on images -------------
    common::section("Fig. 7d — accuracy on images: error model vs GLS-backed run");
    let artifacts = common::artifacts_dir();
    let weights = match dnn::load_tensors(&artifacts.join("weights_a4w4.bin")) {
        Ok(w) => w,
        Err(_) => {
            println!("(no trained weights; skipping Fig. 7d — run `make artifacts`)");
            return;
        }
    };
    let eval = dnn::load_eval_set(&artifacts.join("dataset_eval.bin")).expect("eval set");
    // GLS-backed network runs are the paper's 2-hour-per-image bottleneck
    // (they used 30 images); our GLS is faster but still ~10^3 slower than
    // the model, so Fig. 7d undervolts a representative 3-layer subset
    // (input conv + one mid + one deep conv) *identically on both sides*
    // and compares the resulting accuracy.
    let n_img = if quick { 2 } else { 4 };
    let g = 4; // a moderately aggressive configuration
    let images = &eval.images[..n_img * 3072];
    let labels = &eval.labels[..n_img];
    let n_layers = dnn::conv_layer_names().len();
    let mut layer_gs = vec![prec.max_g(); n_layers];
    for li in [0usize, 9, 18] {
        layer_gs[li] = g;
    }

    // One weight map shared by both engines; the GLS engine swaps only
    // the backend — that is the whole point of the ExecBackend seam.
    let builder = EngineBuilder::new()
        .weights(weights)
        .precision(prec)
        .arch(arch.clone())
        .policy(GavPolicy::PerLayer(layer_gs.clone()));
    let model_engine = builder
        .clone()
        .tables(Arc::clone(&tables))
        .seed(33)
        .build()
        .expect("engine config");
    let gls_engine = builder
        .backend_gls(Arc::clone(&ctx))
        .seed(91)
        .build()
        .expect("engine config");

    let (out_model, model_s) = gavina::util::timeit(|| {
        model_engine
            .infer_batched(images, n_img, n_img)
            .expect("model-backed pass")
    });
    let acc_model = accuracy(&out_model.logits, labels, out_model.classes);

    // The *GLS itself* injects errors on every undervolted conv GEMM step
    // — the Fig. 5 methodology at network scale (what took the paper
    // ~2 h/image on Cadence GLS).
    let (out_gls, gls_s) = gavina::util::timeit(|| {
        gls_engine
            .infer_batched(images, n_img, n_img.max(1))
            .expect("GLS-backed pass")
    });
    let acc_gls = accuracy(&out_gls.logits, labels, out_gls.classes);
    println!("model-based accuracy: {acc_model:.3} ({:.2} s/img)", model_s / n_img as f64);
    println!("GLS-backed accuracy:  {acc_gls:.3} ({:.2} s/img)", gls_s / n_img as f64);
    println!("(paper Fig. 7d: the two runs track closely, model slightly pessimistic)");
}
