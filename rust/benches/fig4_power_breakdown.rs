//! Regenerates **Fig. 4b**: the power-consumption breakdown of GAVINA per
//! precision configuration, at V_guard (no undervolting) — and the
//! undervolted counterpart that Fig. 6b's system-level boost rests on.

mod common;

use gavina::arch::{GavSchedule, Precision};
use gavina::power::PowerModel;

fn main() {
    let power = PowerModel::paper_calibrated();

    common::section("Fig. 4b — power distribution per precision (V_guard)");
    println!("prec | array  | A0/B0  | A1/B1/P+L1 | ctrl+L0 | total  | paper total");
    let paper_totals = [("a8w8", 31.2), ("a4w4", 35.4), ("a3w3", 40.1), ("a2w2", 38.67)];
    for (i, prec) in Precision::EVAL_SET.iter().rev().enumerate() {
        let bd = power.system_breakdown(&GavSchedule::all_guarded(*prec));
        println!(
            "{prec} | {:6.2} | {:6.2} | {:10.2} | {:7.2} | {:6.2} | {:.2} mW",
            bd.array_mw,
            bd.a0b0_mw,
            bd.tile_mw,
            bd.ctrl_mw,
            bd.total_mw(),
            paper_totals[i].1
        );
    }

    common::section("Same breakdown fully undervolted (the Fig. 6b endpoint)");
    println!("prec | array  | memories+ctrl | total  | boost vs guarded");
    for prec in Precision::EVAL_SET.iter().rev() {
        let bd = power.system_breakdown(&GavSchedule::all_approx(*prec));
        let rest = bd.a0b0_mw + bd.tile_mw + bd.ctrl_mw;
        println!(
            "{prec} | {:6.2} | {:13.2} | {:6.2} | ×{:.2}",
            bd.array_mw,
            rest,
            bd.total_mw(),
            power.undervolting_boost(*prec)
        );
    }
    println!("\n(shape: memories dominate once the array is undervolted — §IV-B;");
    println!(" array power span guarded→aggressive ×{:.2}, paper reports up to ×3.5)",
        power.array_power_mw(0.55) / power.array_power_mw(0.35));
}
