//! Regenerates **Table I** (GAVINA specifications) and the GAVINA rows of
//! **Table II** (TOP/s, TOP/sW per precision), measuring sustained
//! utilization with the cycle-level simulator on a ResNet-18-shaped
//! workload mix instead of assuming the peak.

mod common;

use gavina::arch::{ArchConfig, GavSchedule, Precision};
use gavina::power::PowerModel;
use gavina::simulator::{GavinaSim, GemmJob};
use gavina::util::Prng;
use gavina::workload::gemm_workload;

/// Full-width ResNet-18 (CIFAR) conv GEMM shapes for one image — the
/// paper's benchmark network. The inner-layer `C` values are exact
/// multiples of the array's 576 (3·3·64 = 576 — the §IV-A design
/// motivation), so sustained utilization sits a few % under peak, matching
/// Table II's 1.774 of 1.84 TOP/s.
const RESNET_SHAPES: &[(usize, usize, usize)] = &[
    (27, 1024, 64),    // conv0 (C-padding waste lives here)
    (576, 1024, 64),   // s0 convs
    (576, 1024, 64),
    (576, 256, 128),   // s1b0/conv1
    (1152, 256, 128),  // s1 inner
    (1152, 64, 256),   // s2
    (2304, 64, 256),
    (2304, 16, 512),   // s3
    (4608, 16, 512),
];

fn main() {
    let arch = ArchConfig::paper();
    let power = PowerModel::paper_calibrated();

    common::section("Table I — GAVINA specifications (model)");
    println!("Technology                    (modelled 12 nm-class, alpha-power delays)");
    println!(
        "Parallel Array Size (CxLxK)   {} ({}x{}x{})",
        arch.macs_per_tile(),
        arch.c_dim,
        arch.l_dim,
        arch.k_dim
    );
    println!(
        "Clock Period / Frequency      {:.1} ns / {:.0} MHz",
        1e9 / arch.freq_hz,
        arch.freq_hz / 1e6
    );
    println!(
        "Max. Throughput (a2w2)        {:.2} TOP/s      (paper: 1.84)",
        arch.peak_tops(Precision::new(2, 2))
    );
    println!("V_mem                         {:.2} V          (paper: 0.40)", arch.v_mem);
    println!(
        "V_guard | V_aprox             {:.2} | {:.2} V   (paper: 0.55 | 0.35)",
        arch.v_guard, arch.v_aprox
    );
    let p22 = Precision::new(2, 2);
    println!(
        "Avg. Power @ Peak TOP/s       {:.2} | {:.2} mW  (paper: 38.67 | 19.86)",
        power.system_power_mw(&GavSchedule::all_guarded(p22)),
        power.system_power_mw(&GavSchedule::all_approx(p22))
    );

    common::section("Sustained utilization on ResNet-18-shaped GEMMs (cycle sim)");
    let mut rng = Prng::new(77);
    let shapes: &[(usize, usize, usize)] = if common::quick() {
        &RESNET_SHAPES[..4]
    } else {
        RESNET_SHAPES
    };
    println!("prec | utilization | sustained TOP/s (peak)");
    let mut utils = Vec::new();
    for prec in Precision::EVAL_SET {
        let sched = GavSchedule::all_guarded(prec);
        let (mut macs, mut cycles) = (0u64, 0u64);
        common::bench_time(&format!("cycle-sim ResNet shapes {prec}"), || {
            for &(c, l, k) in shapes {
                let (a, b) = gemm_workload(c, l, k, prec, &mut rng);
                let mut sim = GavinaSim::new(arch.clone(), None, 3);
                let rep = sim.run_gemm(&GemmJob {
                    a: &a,
                    b: &b,
                    c,
                    l,
                    k,
                    sched: sched.clone(),
                });
                macs += rep.useful_macs;
                cycles += rep.cycles;
            }
        });
        let peak_per_cycle = arch.macs_per_tile() as f64 / prec.steps() as f64;
        let util = (macs as f64 / cycles as f64) / peak_per_cycle;
        let sustained = 2.0 * macs as f64 / (cycles as f64 / arch.freq_hz) / 1e12;
        println!(
            "{prec} | {util:11.3} | {sustained:.3} TOP/s ({:.3})",
            arch.peak_tops(prec)
        );
        utils.push(util);
    }
    let avg_util: f64 = utils.iter().sum::<f64>() / utils.len() as f64;

    common::section("Table II — GAVINA TOP/sW rows (measured utilization)");
    println!("prec | TOP/s | TOP/sW guarded – aggressive | paper");
    // Ordered to match EVAL_SET.iter().rev(): a8w8 first.
    let paper = [
        ("a8w8", 0.111, 3.56, 6.52),
        ("a4w4", 0.443, 12.52, 23.78),
        ("a3w3", 0.776, 19.37, 38.13),
        ("a2w2", 1.774, 45.87, 89.32),
    ];
    for (i, prec) in Precision::EVAL_SET.iter().rev().enumerate() {
        let lo = power.tops_per_watt(&GavSchedule::all_guarded(*prec), avg_util);
        let hi = power.tops_per_watt(&GavSchedule::all_approx(*prec), avg_util);
        let (tag, pt, plo, phi) = paper[i];
        assert_eq!(tag, &prec.tag());
        println!(
            "{prec} | {:.3} | {lo:6.2} – {hi:6.2} | {pt:.3} TOP/s, {plo} – {phi}",
            arch.peak_tops(*prec) * avg_util
        );
    }
    println!("\n(shape check: a2w2 ≈ 2× a3w3 ≈ 4× a4w4 ≈ 16× a8w8; ~×1.95 undervolting span)");
}
