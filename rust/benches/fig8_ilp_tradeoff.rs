//! Regenerates **Fig. 8**: (a) per-layer output perturbation (MSE) when a
//! single layer is undervolted at different G; (b) the energy-efficiency /
//! accuracy trade-off of ILP-allocated GAV configurations across
//! precisions — including the paper's headline "20% efficiency boost with
//! negligible accuracy degradation".

mod common;

use gavina::arch::{ArchConfig, GavSchedule, Precision};
use gavina::dnn::{self, Backend, Executor};
use gavina::ilp::{GavAllocator, LayerChoices};
use gavina::power::PowerModel;
use gavina::stats::{accuracy, mse_f32};

fn main() {
    let quick = common::quick();
    let tables = common::load_tables();
    let arch = ArchConfig::paper();
    let power = PowerModel::paper_calibrated();
    let artifacts = common::artifacts_dir();
    let names = dnn::conv_layer_names();

    let eval = match dnn::load_eval_set(&artifacts.join("dataset_eval.bin")) {
        Ok(e) => e,
        Err(_) => {
            println!("(no eval set; run `make artifacts` first)");
            return;
        }
    };
    let n_prof = if quick { 4 } else { 12 }; // images for MSE profiling
    let n_eval = if quick { 32 } else { 96 }; // images for accuracy

    // ---- Fig. 8a: per-layer MSE profile at a4w4 -------------------------
    common::section("Fig. 8a — per-layer output MSE vs G (a4w4)");
    let prec = Precision::new(4, 4);
    let weights = dnn::load_tensors(&artifacts.join("weights_a4w4.bin")).expect("weights");
    let images = &eval.images[..n_prof * 3072];
    let ref_out =
        Executor::new(&weights, 0.25, prec, Backend::Float).forward_batched(images, n_prof, 16);

    let mut layer_choices = Vec::new();
    println!("{:>2} {:12} | MSE at G = 0, 2, 4, 6 (0 at G_max by construction)", "#", "layer");
    for (li, name) in names.iter().enumerate() {
        let mut cost = vec![0.0f64; (prec.max_g() + 1) as usize];
        let mut macs = 1u64;
        for g in 0..prec.max_g() {
            let mut ex = Executor::new(
                &weights,
                0.25,
                prec,
                Backend::Gavina {
                    arch: arch.clone(),
                    tables: Some(&tables),
                    seed: 71 + li as u64,
                },
            );
            ex.layer_gs = vec![prec.max_g(); names.len()];
            ex.layer_gs[li] = g;
            let out = ex.forward_batched(images, n_prof, 16);
            macs = out.stats.layer_macs[li].max(1);
            cost[g as usize] = mse_f32(&ref_out.logits, &out.logits);
        }
        println!(
            "{li:>2} {name:12} | {:9.3e} {:9.3e} {:9.3e} {:9.3e}",
            cost[0], cost[2], cost[4], cost[6]
        );
        layer_choices.push(LayerChoices {
            ops: macs as f64,
            cost,
        });
    }
    // Shape check: the input layer is among the most sensitive (paper).
    let sens: Vec<f64> = layer_choices.iter().map(|l| l.cost[0] / l.ops).collect();
    let rank0 = sens.iter().filter(|&&s| s > sens[0]).count();
    println!("\ninput-layer per-op sensitivity rank: {} of {} (paper: most sensitive)",
             rank0 + 1, names.len());

    // ---- Fig. 8b: ILP energy-efficiency vs accuracy ---------------------
    common::section("Fig. 8b — energy-efficiency vs accuracy (ILP allocation)");
    let allocator = GavAllocator::new(layer_choices);
    let eval_images = &eval.images[..n_eval * 3072];
    let eval_labels = &eval.labels[..n_eval];
    let exact_out = Executor::new(&weights, 0.25, prec, Backend::Float)
        .forward_batched(eval_images, n_eval, 16);
    let exact_acc = accuracy(&exact_out.logits, eval_labels, exact_out.classes);
    println!("a4w4 exact accuracy: {exact_acc:.4} ({n_eval} images)");
    println!("\nG_tar | avg G | accuracy | Δacc    | TOP/sW | eff. boost vs guarded");
    let max_g = prec.max_g();
    let guarded_eff = power.tops_per_watt(&GavSchedule::all_guarded(prec), 0.96);
    for g_tar in [3.0, 4.0, 5.0, 6.0, 7.0] {
        let alloc = allocator.solve(g_tar);
        let mut ex = Executor::new(
            &weights,
            0.25,
            prec,
            Backend::Gavina {
                arch: arch.clone(),
                tables: Some(&tables),
                seed: 83,
            },
        );
        ex.layer_gs = alloc.gs.clone();
        let out = ex.forward_batched(eval_images, n_eval, 16);
        let acc = accuracy(&out.logits, eval_labels, out.classes);
        // Energy: per-layer schedules weighted by per-layer cycles — use
        // the op-weighted average G as the effective uniform schedule.
        let eff_g = alloc.avg_g.round().clamp(0.0, max_g as f64) as u32;
        let eff = power.tops_per_watt(&GavSchedule::two_level(prec, eff_g), 0.96);
        println!(
            " {g_tar:4.1} | {:5.2} | {acc:8.4} | {:+7.4} | {eff:6.2} | {:+.1}%",
            alloc.avg_g,
            acc - exact_acc,
            (eff / guarded_eff - 1.0) * 100.0
        );
    }
    println!("\n(paper: up to 20% efficiency boost with negligible accuracy drop at");
    println!(" higher precisions; sharper degradation at low precision — see below)");

    // ---- Fig. 8b low-precision contrast ---------------------------------
    common::section("Fig. 8b contrast — a2w2 under the same treatment");
    let prec2 = Precision::new(2, 2);
    if let Ok(w2) = dnn::load_tensors(&artifacts.join("weights_a2w2.bin")) {
        let exact2 = Executor::new(&w2, 0.25, prec2, Backend::Float)
            .forward_batched(eval_images, n_eval, 16);
        let acc2 = accuracy(&exact2.logits, eval_labels, exact2.classes);
        println!("a2w2 exact accuracy: {acc2:.4}");
        for g in (0..=prec2.max_g()).rev() {
            let mut ex = Executor::new(
                &w2,
                0.25,
                prec2,
                Backend::Gavina {
                    arch: arch.clone(),
                    tables: Some(&tables),
                    seed: 97,
                },
            );
            ex.layer_gs = vec![g; names.len()];
            let out = ex.forward_batched(eval_images, n_eval, 16);
            let acc = accuracy(&out.logits, eval_labels, out.classes);
            println!(
                "  uniform G={g}: accuracy {acc:.4} (Δ {:+.4})",
                acc - acc2
            );
        }
    }
}
