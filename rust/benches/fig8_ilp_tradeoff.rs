//! Regenerates **Fig. 8**: (a) per-layer output perturbation (MSE) when a
//! single layer is undervolted at different G; (b) the energy-efficiency /
//! accuracy trade-off of ILP-allocated GAV configurations across
//! precisions — including the paper's headline "20% efficiency boost with
//! negligible accuracy degradation".

mod common;

use std::sync::Arc;

use gavina::arch::{GavSchedule, Precision};
use gavina::dnn;
use gavina::engine::{EngineBuilder, GavPolicy};
use gavina::ilp::GavAllocator;
use gavina::power::PowerModel;
use gavina::stats::accuracy;

fn main() {
    let quick = common::quick();
    let tables = Arc::new(common::load_tables());
    let power = PowerModel::paper_calibrated();
    let artifacts = common::artifacts_dir();
    let names = dnn::conv_layer_names();

    let eval = match dnn::load_eval_set(&artifacts.join("dataset_eval.bin")) {
        Ok(e) => e,
        Err(_) => {
            println!("(no eval set; run `make artifacts` first)");
            return;
        }
    };
    let n_prof = if quick { 4 } else { 12 }; // images for MSE profiling
    let n_eval = if quick { 32 } else { 96 }; // images for accuracy

    // ---- Fig. 8a: per-layer MSE profile at a4w4 -------------------------
    common::section("Fig. 8a — per-layer output MSE vs G (a4w4)");
    let prec = Precision::new(4, 4);
    let builder = EngineBuilder::new()
        .weights_from_file(&artifacts.join("weights_a4w4.bin"))
        .expect("weights")
        .precision(prec)
        .tables(Arc::clone(&tables));
    let images = &eval.images[..n_prof * 3072];

    // Profiling engine: layer `li` profiles at seed 71 + li (historical).
    let profiler = builder.clone().seed(71).build().expect("engine config");
    let layer_choices = profiler
        .profile_layers(images, n_prof, 16)
        .expect("layer profiling");
    println!("{:>2} {:12} | MSE at G = 0, 2, 4, 6 (0 at G_max by construction)", "#", "layer");
    for (li, name) in names.iter().enumerate() {
        let cost = &layer_choices[li].cost;
        println!(
            "{li:>2} {name:12} | {:9.3e} {:9.3e} {:9.3e} {:9.3e}",
            cost[0], cost[2], cost[4], cost[6]
        );
    }
    // Shape check: the input layer is among the most sensitive (paper).
    let sens: Vec<f64> = layer_choices.iter().map(|l| l.cost[0] / l.ops).collect();
    let rank0 = sens.iter().filter(|&&s| s > sens[0]).count();
    println!("\ninput-layer per-op sensitivity rank: {} of {} (paper: most sensitive)",
             rank0 + 1, names.len());

    // ---- Fig. 8b: ILP energy-efficiency vs accuracy ---------------------
    common::section("Fig. 8b — energy-efficiency vs accuracy (ILP allocation)");
    let allocator = GavAllocator::new(layer_choices);
    let eval_images = &eval.images[..n_eval * 3072];
    let eval_labels = &eval.labels[..n_eval];
    let exact_engine = builder
        .clone()
        .backend_float()
        .build()
        .expect("engine config");
    let exact_out = exact_engine
        .infer_batched(eval_images, n_eval, 16)
        .expect("reference");
    let exact_acc = accuracy(&exact_out.logits, eval_labels, exact_out.classes);
    println!("a4w4 exact accuracy: {exact_acc:.4} ({n_eval} images)");
    println!("\nG_tar | avg G | accuracy | Δacc    | TOP/sW | eff. boost vs guarded");
    let max_g = prec.max_g();
    let guarded_eff = power.tops_per_watt(&GavSchedule::all_guarded(prec), 0.96);
    let sweep_builder = builder.seed(83);
    for g_tar in [3.0, 4.0, 5.0, 6.0, 7.0] {
        let alloc = allocator.solve(g_tar);
        let engine = sweep_builder
            .clone()
            .policy(GavPolicy::PerLayer(alloc.gs.clone()))
            .build()
            .expect("engine config");
        let out = engine
            .infer_batched(eval_images, n_eval, 16)
            .expect("forward pass");
        let acc = accuracy(&out.logits, eval_labels, out.classes);
        // Energy: per-layer schedules weighted by per-layer cycles — use
        // the op-weighted average G as the effective uniform schedule.
        let eff_g = alloc.avg_g.round().clamp(0.0, max_g as f64) as u32;
        let eff = power.tops_per_watt(&GavSchedule::two_level(prec, eff_g), 0.96);
        println!(
            " {g_tar:4.1} | {:5.2} | {acc:8.4} | {:+7.4} | {eff:6.2} | {:+.1}%",
            alloc.avg_g,
            acc - exact_acc,
            (eff / guarded_eff - 1.0) * 100.0
        );
    }
    println!("\n(paper: up to 20% efficiency boost with negligible accuracy drop at");
    println!(" higher precisions; sharper degradation at low precision — see below)");

    // ---- Fig. 8b low-precision contrast ---------------------------------
    common::section("Fig. 8b contrast — a2w2 under the same treatment");
    let prec2 = Precision::new(2, 2);
    // Missing/unreadable a2w2 weights skip this contrast section (as the
    // pre-engine bench did) instead of killing the run after Fig. 8a.
    if let Ok(b2) = EngineBuilder::new().weights_from_file(&artifacts.join("weights_a2w2.bin")) {
        let builder2 = b2
            .precision(prec2)
            .tables(Arc::clone(&tables))
            .seed(97);
        let exact2 = builder2
            .clone()
            .backend_float()
            .build()
            .expect("engine config")
            .infer_batched(eval_images, n_eval, 16)
            .expect("reference");
        let acc2 = accuracy(&exact2.logits, eval_labels, exact2.classes);
        println!("a2w2 exact accuracy: {acc2:.4}");
        for g in (0..=prec2.max_g()).rev() {
            let engine = builder2
                .clone()
                .policy(GavPolicy::Uniform(g))
                .build()
                .expect("engine config");
            let out = engine
                .infer_batched(eval_images, n_eval, 16)
                .expect("forward pass");
            let acc = accuracy(&out.logits, eval_labels, out.classes);
            println!(
                "  uniform G={g}: accuracy {acc:.4} (Δ {:+.4})",
                acc - acc2
            );
        }
    }
}
