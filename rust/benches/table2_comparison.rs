//! Regenerates **Table II** (comparison with the state of the art) and the
//! §V claims: GAVINA vs RBE / BitBlade / Shin-TED / X-NVDLA / X-TPU,
//! including the technology-scaled efficiency comparison and the
//! behavioural baselines (TED value-drop vs GAV error propagation on the
//! same workload).

mod common;

use gavina::arch::{ArchConfig, GavSchedule, Precision};
use gavina::baseline::{tech_scale_efficiency, FixedLsbTep, TedAccelerator, LITERATURE};
use gavina::power::PowerModel;
use gavina::simulator::{GavinaSim, GemmJob};
use gavina::stats::var_ned;
use gavina::util::Prng;
use gavina::workload::uniform_ip_matrices;

fn main() {
    let power = PowerModel::paper_calibrated();
    let arch = ArchConfig::paper();
    let util = 0.96;

    common::section("Table II — energy-efficiency comparison [TOP/sW]");
    println!("{:22} {:>5} {:>6} {:>10} {:>14}", "accelerator", "tech", "bits", "TOP/sW", "scaled to 12nm");
    for e in LITERATURE {
        if e.tops_per_w.is_nan() {
            println!("{:22} {:>5} {:>6} {:>10} {:>14}", e.name, e.technology_nm, e.precision_bits, "rel-only", "-");
            continue;
        }
        let scaled = e.tops_per_w * tech_scale_efficiency(e.technology_nm, 12);
        println!(
            "{:22} {:>5} {:>6} {:>10.1} {:>14.1}",
            e.name, e.technology_nm, e.precision_bits, e.tops_per_w, scaled
        );
    }
    for prec in Precision::EVAL_SET.iter().rev() {
        let lo = power.tops_per_watt(&GavSchedule::all_guarded(*prec), util);
        let hi = power.tops_per_watt(&GavSchedule::all_approx(*prec), util);
        println!("{:22} {:>5} {:>6} {:>4.1} – {:>4.1} {:>14}", format!("GAVINA {prec}"), 12, prec.a_bits, lo, hi, "(this work)");
    }

    common::section("§V claims checked against the model");
    // ×2.08 vs RBE at matching precision (a2w2, guarded).
    let g22 = power.tops_per_watt(&GavSchedule::all_guarded(Precision::new(2, 2)), util);
    let rbe = LITERATURE.iter().find(|e| e.name.contains("RBE")).unwrap();
    println!(
        "vs RBE (a2w2 guarded):      ×{:.2}   (paper: ×2.08)",
        g22 / rbe.tops_per_w
    );
    // ×3.04 vs Shin et al. most aggressive.
    let shin = LITERATURE.iter().find(|e| e.name.contains("Shin")).unwrap();
    println!(
        "vs Shin-TED best voltage:   ×{:.2}   (paper: ×3.04, unscaled techs)",
        g22 / shin.tops_per_w
    );
    // Undervolting boost ranges.
    println!(
        "max system UV boost:        ×{:.2}   (paper: ×1.96; [7] +35%, [8] +57%)",
        power.undervolting_boost(Precision::new(2, 2))
    );
    println!(
        "8b→2b total boost:          ×{:.1}   (paper: ×18)",
        power.tops_per_watt(&GavSchedule::all_approx(Precision::new(2, 2)), util)
            / power.tops_per_watt(&GavSchedule::all_guarded(Precision::new(8, 8)), util)
    );
    println!(
        "compute-only UV reduction:  ×{:.2}   (paper: ×3.5; [2] reports ×2.2)",
        power.array_power_mw(arch.v_guard) / power.array_power_mw(arch.v_aprox)
    );

    common::section("Behavioural baselines on one workload (error at matched voltage)");
    let tables = common::load_tables();
    let prec8 = Precision::new(8, 8);
    let (c, l, k) = if common::quick() { (576, 16, 16) } else { (1152, 32, 32) };
    let mut rng = Prng::new(0x7AB2);
    let (a, b) = uniform_ip_matrices(c, l, k, prec8, &mut rng);
    let exact = gavina::gemm::gemm_exact(&a, &b, c, l, k);

    println!("scheme                     | VAR_NED at V≈0.45 | VAR_NED at V≈0.40");
    let ted = TedAccelerator::default();
    let tep = FixedLsbTep {
        n_lsb: 8,
        ..Default::default()
    };
    let v_ted_45 = var_ned(&exact, &ted.gemm(&a, &b, c, l, k, 0.45, &mut rng));
    let v_ted_40 = var_ned(&exact, &ted.gemm(&a, &b, c, l, k, 0.40, &mut rng));
    println!("TED value-drop (Shin-like) | {v_ted_45:17.4e} | {v_ted_40:17.4e}");
    let v_tep_45 = var_ned(&exact, &tep.gemm(&a, &b, c, l, k, 0.45, &mut rng));
    let v_tep_40 = var_ned(&exact, &tep.gemm(&a, &b, c, l, k, 0.40, &mut rng));
    println!("fixed-LSB TEP (X-NVDLA)    | {v_tep_45:17.4e} | {v_tep_40:17.4e}");
    // GAV at two G points for context (its knob is G, not V).
    for g in [10, 6] {
        let sched = GavSchedule::two_level(prec8, g);
        let mut sim = GavinaSim::new(arch.clone(), Some(&tables), 3);
        let rep = sim.run_gemm(&GemmJob {
            a: &a,
            b: &b,
            c,
            l,
            k,
            sched,
        });
        println!(
            "GAV a8w8 G={g:<2}             | {:17.4e} | (same — V fixed at 0.35, G is the knob)",
            var_ned(&exact, &rep.p)
        );
    }
    println!("\n(contrast: baselines trade error by *voltage*; GAV holds the aggressive");
    println!(" voltage and trades error by *significance guarding* at constant throughput)");
}
