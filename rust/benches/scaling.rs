//! Thread-scaling sweep of the bit-serial GEMM hot path: throughput of
//! the fused micro-kernel ([`gavina::gemm::kernel::fused_gemm_mt`]) at
//! 1/2/4/… workers against the serial kernel, with a bit-exactness check
//! at every point. Operands are pre-converted to the interleaved layout
//! outside the timed loops so the scaling column measures the kernel,
//! not the one-time layout conversion.
//!
//! ```bash
//! cargo bench --bench scaling -- [--quick]
//! ```

mod common;

use gavina::arch::Precision;
use gavina::quant::InterleavedPlanes;
use gavina::util::parallel::resolve_threads;
use gavina::util::Prng;
use gavina::workload::gemm_workload;

fn main() {
    let quick = common::quick();
    let prec = Precision::new(4, 4);
    let mut rng = Prng::new(0x5CA1);
    let (c, l, k) = if quick { (1152, 32, 64) } else { (2304, 64, 256) };
    let reps = if quick { 3 } else { 8 };

    common::section(&format!(
        "bit-serial GEMM thread scaling ({c}x{l}x{k}, {}, {} reps)",
        prec.tag(),
        reps
    ));
    let (a, b) = gemm_workload(c, l, k, prec, &mut rng);
    let pa = InterleavedPlanes::from_a_matrix(&a, c, l, prec.a_bits);
    let pb = InterleavedPlanes::from_b_matrix(&b, k, c, prec.b_bits);
    let bitmacs = gavina::gemm::bit_macs(c, l, k, prec) as f64 * reps as f64;

    let active = gavina::gemm::simd::active();
    let block = gavina::gemm::simd::block_shape();
    println!(
        "kernel dispatch: {active} (block {}x{}, set GAVINA_KERNEL to override)",
        block.c_words, block.l_cols
    );

    // Forced-scalar serial contrast so the table's SIMD uplift (and any
    // regression in it) is visible in every CI artifact.
    let t0 = std::time::Instant::now();
    let mut scalar = Vec::new();
    for _ in 0..reps {
        scalar = gavina::gemm::kernel::fused_gemm_with(
            gavina::gemm::simd::KernelKind::Scalar,
            &pa,
            &pb,
        );
    }
    let secs_scalar = t0.elapsed().as_secs_f64();
    println!(
        "forced-scalar serial kernel: {:>10.1} bit-MAC/ms",
        bitmacs / secs_scalar / 1e3
    );

    let t0 = std::time::Instant::now();
    let mut reference = Vec::new();
    for _ in 0..reps {
        reference = gavina::gemm::kernel::fused_gemm(&pa, &pb);
    }
    let secs_serial = t0.elapsed().as_secs_f64();
    println!(
        "serial kernel ({active}): {:>10.1} bit-MAC/ms ({:.2}x over scalar)",
        bitmacs / secs_serial / 1e3,
        secs_scalar / secs_serial.max(1e-12)
    );
    assert_eq!(
        scalar, reference,
        "scalar and dispatched kernels must be bit-identical"
    );

    let cores = resolve_threads(0);
    let mut counts = vec![1usize, 2, 4, 8];
    if !counts.contains(&cores) {
        counts.push(cores);
    }
    counts.sort_unstable();
    counts.dedup();

    println!("\nthreads | bit-MAC/ms | speedup vs 1 thread | bit-exact");
    println!("--------+------------+---------------------+----------");
    let mut secs_1thread: Option<f64> = None;
    for &t in &counts {
        let t0 = std::time::Instant::now();
        let mut out = Vec::new();
        for _ in 0..reps {
            out = gavina::gemm::kernel::fused_gemm_mt(&pa, &pb, t);
        }
        let secs = t0.elapsed().as_secs_f64();
        if t == 1 {
            secs_1thread = Some(secs);
        }
        let base = secs_1thread.expect("counts must start at 1 thread");
        let exact = out == reference;
        println!(
            "{t:>7} | {:>10.1} | {:>19.2}x | {}",
            bitmacs / secs / 1e3,
            base / secs.max(1e-12),
            if exact { "yes" } else { "NO" }
        );
        assert!(exact, "threads={t}: tiled kernel diverged from serial");
    }
    println!(
        "\n(machine reports {cores} available cores; row-block tiling has no cross-thread\n\
         reduction, so scaling is limited only by memory bandwidth)"
    );
}
