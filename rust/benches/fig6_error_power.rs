//! Regenerates **Fig. 6**: (a) VAR_NED vs G for every precision; (b) error
//! vs approximate-region power — on the §IV-B uniform-inner-product random
//! GEMM workload, with calibrated error-model injection plus GLS
//! ground-truth spot checks.

mod common;

use gavina::arch::{ArchConfig, GavSchedule, Precision};
use gavina::gls::{DelayModel, GlsContext, TileGls};
use gavina::power::PowerModel;
use gavina::quant::PackedPlanes;
use gavina::simulator::{GavinaSim, GemmJob};
use gavina::stats::var_ned;
use gavina::util::Prng;
use gavina::workload::{uniform_ip_matrices, ERROR_ANALYSIS_SHAPE};

fn main() {
    let quick = common::quick();
    let tables = common::load_tables();
    let arch = ArchConfig::paper();
    let power = PowerModel::paper_calibrated();

    let (cf, lf, kf) = ERROR_ANALYSIS_SHAPE;
    let (c, l, k) = if quick {
        (cf / 8, lf / 4, kf / 4)
    } else {
        (cf / 2, lf, kf)
    };

    common::section(&format!(
        "Fig. 6a — VAR_NED vs G per precision ([{c},{l}]x[{k},{c}] uniform-IP workload)"
    ));
    println!("prec |  G | VAR_NED      | approx-region power [mW] (Fig. 6b x-axis)");
    for prec in Precision::EVAL_SET {
        let mut rng = Prng::new(0x600D + prec.a_bits as u64);
        let (a, b) = uniform_ip_matrices(c, l, k, prec, &mut rng);
        let exact = gavina::gemm::gemm_exact(&a, &b, c, l, k);
        let mut last = f64::INFINITY;
        common::bench_time(&format!("G sweep {prec}"), || {
            for g in 0..=prec.max_g() {
                let sched = GavSchedule::two_level(prec, g);
                let mut sim = GavinaSim::new(arch.clone(), Some(&tables), 5 + g as u64);
                let rep = sim.run_gemm(&GemmJob {
                    a: &a,
                    b: &b,
                    c,
                    l,
                    k,
                    sched: sched.clone(),
                });
                let v = var_ned(&exact, &rep.p);
                println!(
                    "{prec} | {g:2} | {v:12.5e} | {:8.2}",
                    power.array_avg_power_mw(&sched)
                );
                // Fig. 6a shape: decays (allow small non-monotonic noise).
                assert!(
                    v <= last * 3.0 + 1e-12,
                    "VAR_NED must trend down with G ({v} after {last})"
                );
                last = v;
            }
        });
    }

    common::section("GLS ground-truth spot checks (a4w4, single hardware tile)");
    let prec = Precision::new(4, 4);
    let ctx = GlsContext::new(
        arch.c_dim,
        arch.clk_period_ps() as f64,
        DelayModel::default(),
        9,
    );
    let mut rng = Prng::new(0x6157);
    let (a, b) = uniform_ip_matrices(arch.c_dim, arch.l_dim, arch.k_dim, prec, &mut rng);
    let pa = PackedPlanes::from_a_matrix(&a, arch.c_dim, arch.l_dim, prec.a_bits);
    let pb = PackedPlanes::from_b_matrix(&b, arch.k_dim, arch.c_dim, prec.b_bits);
    let exact = gavina::gemm::gemm_exact(&a, &b, arch.c_dim, arch.l_dim, arch.k_dim);
    let mut tg = TileGls::new(&ctx, arch.clone());
    println!(" G | GLS VAR_NED  | model VAR_NED");
    for g in [0u32, 2, 4, 6, prec.max_g()] {
        let sched = GavSchedule::two_level(prec, g);
        let trace = common::bench_time(&format!("GLS tile g={g}"), || tg.run_tile(&pa, &pb, &sched));
        let v_gls = var_ned(&exact, &trace.approx_gemm(prec));
        let mut seq = gavina::gemm::ipe_sequence(&pa, &pb);
        tables.inject(&mut seq, &sched, &mut rng);
        let v_model = var_ned(&exact, &gavina::gemm::recombine(&seq, prec));
        println!(" {g} | {v_gls:12.5e} | {v_model:12.5e}");
    }
    println!("\n(Fig. 6 shape: exponential VAR_NED decay in G; array power ×{:.2} span)",
        power.array_power_mw(arch.v_guard) / power.array_power_mw(arch.v_aprox));
}
