//! Shared bench harness (the vendored crate set has no criterion; each
//! bench is a `harness = false` binary that prints the paper's table or
//! figure series, plus wall-clock timing in criterion-like style).

// Each bench binary compiles this module separately and uses a different
// subset of it; unused-helper warnings are per-target noise.
#![allow(dead_code)]

use std::path::{Path, PathBuf};

use gavina::arch::ArchConfig;
use gavina::errmodel::{self, CalibrationConfig, ErrorTables};
use gavina::gls::{DelayModel, GlsContext};

pub fn artifacts_dir() -> PathBuf {
    // Benches run from the workspace root.
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// `--quick` flag: smaller workloads for CI-style runs.
pub fn quick() -> bool {
    std::env::args().any(|a| a == "--quick")
}

/// Load the GLS-calibrated tables, calibrating on the spot if absent.
/// Under `--quick` the fallback calibration is CI-sized (sparser tables,
/// same format) and is not cached, so full runs are never polluted.
pub fn load_tables() -> ErrorTables {
    let path = artifacts_dir().join("caltables_v035.bin");
    if let Ok((t, _)) = errmodel::io::load(&path) {
        return t;
    }
    eprintln!("[bench] calibrating error tables (first run)…");
    let arch = ArchConfig::paper();
    let ctx = GlsContext::new(
        arch.c_dim,
        arch.clk_period_ps() as f64,
        DelayModel::default(),
        0xBE4C,
    );
    if quick() {
        let cfg = CalibrationConfig {
            n_streams: 192,
            seq_len: 32,
            ..Default::default()
        };
        let (t, _) = errmodel::calibrate(&ctx, cfg);
        return t;
    }
    let (t, _) = errmodel::calibrate(&ctx, CalibrationConfig::default());
    let _ = std::fs::create_dir_all(artifacts_dir());
    let _ = errmodel::io::save(&path, &t, 0.35);
    t
}

/// Time a closure, printing a criterion-style line.
pub fn bench_time<T>(label: &str, f: impl FnOnce() -> T) -> T {
    let t0 = std::time::Instant::now();
    let out = f();
    println!("[time] {label:40} {:>10.3} ms", t0.elapsed().as_secs_f64() * 1e3);
    out
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
