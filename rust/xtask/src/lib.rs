//! Repo-contract static analysis for the GAVINA crate: `gavina-xtask check`.
//!
//! Several of the crate's core invariants — bit-exactness of the SIMD
//! kernels (never an FMA, scalar ground truth), the SAFETY story of every
//! `unsafe` site, the std-only dependency policy, the concurrency
//! discipline of the serving layer — are contracts clippy cannot express.
//! This crate parses the sources line-wise (comments and string literals
//! separated from code, so prose never trips a code rule) and enforces
//! them as machine-checked rules with `file:line` diagnostics.
//!
//! | rule id | contract |
//! |---|---|
//! | `unsafe-doc` | every line introducing `unsafe` carries a `SAFETY:` comment |
//! | `unsafe-scope` | `unsafe` only in the audited module allowlist |
//! | `no-fma` | no `mul_add` / FMA intrinsics anywhere (bit-exactness) |
//! | `float-accum` | float intrinsics in `gemm/simd/` ISA files only in `affine*` fns |
//! | `feature-guard` | every `#[target_feature]` feature is runtime-detected in the dispatch |
//! | `spawn-scope` | `thread::spawn`/`scope` in the library only in `util/parallel.rs` + `serve/` |
//! | `relaxed-order` | `Ordering::Relaxed` in the library only where explicitly annotated |
//! | `static-mut` | no `static mut`, ever |
//! | `dep-guard` | no external (non-`path`) dependencies in any `Cargo.toml` |
//!
//! Escape hatch: `gavina-lint: allow(<rule>, …)` in a comment on the same
//! or the immediately preceding line; in a `//!` inner-doc line it grants
//! file scope. Annotations are only read from comments, never from code.
//!
//! The checker does not scan its own sources (`rust/xtask/`): rule
//! patterns appear there as string literals and test fixtures. Its
//! manifest *is* covered by `dep-guard`.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One enforced contract. Stable ids are the `gavina-lint: allow(..)`
/// vocabulary and the tag in every diagnostic.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    UnsafeDoc,
    UnsafeScope,
    NoFma,
    FloatAccum,
    FeatureGuard,
    SpawnScope,
    RelaxedOrder,
    StaticMut,
    DepGuard,
}

/// Every rule, in diagnostic-id order.
pub const ALL_RULES: [Rule; 9] = [
    Rule::UnsafeDoc,
    Rule::UnsafeScope,
    Rule::NoFma,
    Rule::FloatAccum,
    Rule::FeatureGuard,
    Rule::SpawnScope,
    Rule::RelaxedOrder,
    Rule::StaticMut,
    Rule::DepGuard,
];

impl Rule {
    /// Stable lowercase id used in diagnostics and `allow(..)` annotations.
    pub fn id(self) -> &'static str {
        match self {
            Rule::UnsafeDoc => "unsafe-doc",
            Rule::UnsafeScope => "unsafe-scope",
            Rule::NoFma => "no-fma",
            Rule::FloatAccum => "float-accum",
            Rule::FeatureGuard => "feature-guard",
            Rule::SpawnScope => "spawn-scope",
            Rule::RelaxedOrder => "relaxed-order",
            Rule::StaticMut => "static-mut",
            Rule::DepGuard => "dep-guard",
        }
    }

    /// One-line description (the `list` subcommand and the README table).
    pub fn describe(self) -> &'static str {
        match self {
            Rule::UnsafeDoc => "every `unsafe` site carries a SAFETY: comment",
            Rule::UnsafeScope => {
                "unsafe only in gemm/simd/, gemm/kernel.rs, quant/interleaved.rs, quant/simd.rs"
            }
            Rule::NoFma => "no mul_add / FMA intrinsics anywhere (bit-exactness contract)",
            Rule::FloatAccum => "float intrinsics in SIMD ISA files only inside affine* fns",
            Rule::FeatureGuard => "#[target_feature] must be runtime-detected in simd/mod.rs",
            Rule::SpawnScope => "thread::spawn/scope in src/ only in util/parallel.rs and serve/",
            Rule::RelaxedOrder => "Ordering::Relaxed in src/ needs a gavina-lint allow annotation",
            Rule::StaticMut => "`static mut` is forbidden",
            Rule::DepGuard => "Cargo.toml deps must be internal path deps (std-only policy)",
        }
    }
}

/// One contract violation, pointing at a source line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    /// Repo-root-relative path, forward slashes.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file,
            self.line,
            self.rule.id(),
            self.message
        )
    }
}

/// What `run_check` covered, plus everything it found.
#[derive(Debug, Default)]
pub struct CheckReport {
    /// `.rs` files scanned.
    pub sources: usize,
    /// `Cargo.toml` manifests scanned.
    pub manifests: usize,
    pub diagnostics: Vec<Diagnostic>,
}

// ---------------------------------------------------------------------
// Line model: code with comments removed and string contents blanked,
// plus the comment text — so code rules never fire on prose or literals
// and annotations are only honored inside comments.
// ---------------------------------------------------------------------

#[derive(Debug, Default)]
struct Line {
    code: String,
    comment: String,
}

/// Split source text into per-line (code, comment) views. Handles `//`
/// and (nested) `/* */` comments spanning lines, string literals (their
/// contents are blanked from the code view) and char literals. String
/// state deliberately resets at line ends: multi-line literals stay in
/// the code view, which at worst produces a diagnostic the escape hatch
/// can answer — never a silently skipped rule.
fn split_lines(text: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut block_depth = 0usize;
    for raw in text.lines() {
        let chars: Vec<char> = raw.chars().collect();
        let mut code = String::with_capacity(raw.len());
        let mut comment = String::new();
        let mut in_str = false;
        let mut i = 0usize;
        while i < chars.len() {
            let c = chars[i];
            if block_depth > 0 {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    block_depth -= 1;
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    block_depth += 1;
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
                continue;
            }
            if in_str {
                if c == '\\' {
                    code.push(' ');
                    i += 2;
                } else {
                    if c == '"' {
                        in_str = false;
                        code.push('"');
                    } else {
                        code.push(' ');
                    }
                    i += 1;
                }
                continue;
            }
            match c {
                '"' => {
                    in_str = true;
                    code.push('"');
                    i += 1;
                }
                '/' if chars.get(i + 1) == Some(&'/') => {
                    comment.extend(&chars[i + 2..]);
                    break;
                }
                '/' if chars.get(i + 1) == Some(&'*') => {
                    block_depth += 1;
                    i += 2;
                }
                '\'' => {
                    // Char literal ('x', '\n', '\u{..}') vs lifetime ('a).
                    if chars.get(i + 1) == Some(&'\\') {
                        let mut j = i + 2;
                        while j < chars.len() && chars[j] != '\'' {
                            j += 1;
                        }
                        code.push(' ');
                        i = j + 1;
                    } else if chars.get(i + 2) == Some(&'\'') {
                        code.push(' ');
                        i += 3;
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                }
                _ => {
                    code.push(c);
                    i += 1;
                }
            }
        }
        out.push(Line { code, comment });
    }
    out
}

/// Rule ids named by `gavina-lint: allow(a, b)` markers in `text`.
fn annotations(text: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut rest = text;
    while let Some(p) = rest.find("gavina-lint:") {
        rest = &rest[p + "gavina-lint:".len()..];
        let Some(q) = rest.find("allow(") else { break };
        let tail = &rest[q + "allow(".len()..];
        let Some(e) = tail.find(')') else { break };
        out.extend(tail[..e].split(',').map(str::trim));
        rest = &tail[e + 1..];
    }
    out
}

/// Does a whole-word occurrence of `tok` appear in `code`? Word
/// characters are ASCII alphanumerics and `_`, so `unsafe` does not
/// match inside `unsafe_op_in_unsafe_fn`.
fn has_token(code: &str, tok: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0usize;
    while let Some(p) = code[start..].find(tok) {
        let p = start + p;
        let before = p == 0 || {
            let c = bytes[p - 1];
            !(c.is_ascii_alphanumeric() || c == b'_')
        };
        let end = p + tok.len();
        let after = end >= bytes.len() || {
            let c = bytes[end];
            !(c.is_ascii_alphanumeric() || c == b'_')
        };
        if before && after {
            return true;
        }
        start = end;
    }
    false
}

/// Every `"quoted"` span in `raw` (used on lines already known to carry a
/// `target_feature` attribute or a `feature_detected!` call).
fn quoted_strings(raw: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut rest = raw;
    while let Some(p) = rest.find('"') {
        let tail = &rest[p + 1..];
        let Some(q) = tail.find('"') else { break };
        out.push(&tail[..q]);
        rest = &tail[q + 1..];
    }
    out
}

struct SourceView<'a> {
    raw: Vec<&'a str>,
    lines: Vec<Line>,
    file_allows: Vec<String>,
}

impl<'a> SourceView<'a> {
    fn new(text: &'a str) -> Self {
        let raw: Vec<&str> = text.lines().collect();
        let lines = split_lines(text);
        let mut file_allows = Vec::new();
        for (r, l) in raw.iter().zip(&lines) {
            if r.trim_start().starts_with("//!") {
                file_allows.extend(annotations(&l.comment).iter().map(|s| s.to_string()));
            }
        }
        Self {
            raw,
            lines,
            file_allows,
        }
    }

    /// Is `rule` allowed at line index `i` (same line, the line above, or
    /// file scope)?
    fn allowed(&self, i: usize, rule: Rule) -> bool {
        let id = rule.id();
        if self.file_allows.iter().any(|a| a.as_str() == id) {
            return true;
        }
        if annotations(&self.lines[i].comment).contains(&id) {
            return true;
        }
        i > 0 && annotations(&self.lines[i - 1].comment).contains(&id)
    }

    /// Does the `unsafe` introduced at line `i` carry a SAFETY comment —
    /// on the same line, or in the contiguous run of comment / attribute
    /// / blank lines directly above (doc `# Safety` sections included)?
    fn has_safety_comment(&self, i: usize) -> bool {
        let hit = |l: &Line| l.comment.to_ascii_lowercase().contains("safety");
        if hit(&self.lines[i]) {
            return true;
        }
        let mut j = i;
        while j > 0 {
            j -= 1;
            if hit(&self.lines[j]) {
                return true;
            }
            let code = self.lines[j].code.trim();
            if !code.is_empty() && !code.starts_with("#[") && !code.starts_with("#!") {
                return false;
            }
        }
        false
    }
}

// ---------------------------------------------------------------------
// Scoping: which rules watch which paths. Labels are repo-root-relative.
// ---------------------------------------------------------------------

/// Modules audited for `unsafe` (PR 6's SIMD hot path and the layouts it
/// reads, plus the SIMD quantize+pack prologue). Everything else must
/// stay safe code.
const UNSAFE_ALLOWLIST: &[&str] = &[
    "rust/src/gemm/simd/",
    "rust/src/gemm/kernel.rs",
    "rust/src/quant/interleaved.rs",
    "rust/src/quant/simd.rs",
];

/// The only library homes for thread creation: the scoped worker pool and
/// the serving layer. `rust/src/canary/` deliberately stays *outside*
/// this list — the observability plane runs on the governor and worker
/// threads and must never spawn its own (pinned by the fixture tests).
const SPAWN_ALLOWLIST: &[&str] = &["rust/src/util/parallel.rs", "rust/src/serve/"];

fn in_allowlist(label: &str, list: &[&str]) -> bool {
    for p in list {
        if label == *p || (p.ends_with('/') && label.starts_with(*p)) {
            return true;
        }
    }
    false
}

fn in_library(label: &str) -> bool {
    label.starts_with("rust/src/")
}

fn is_simd_isa_file(label: &str) -> bool {
    label.starts_with("rust/src/gemm/simd/") && !label.ends_with("/mod.rs")
}

/// Substrings whose presence in code means a fused multiply-add: the
/// float method, the x86 `*fmadd*` intrinsic family, the NEON `vfma*`
/// family. Matching code only (never comments or string literals).
const FMA_PATTERNS: &[&str] = &["mul_add", "fmadd", "vfma"];

/// Float vector-intrinsic call markers for the `float-accum` rule.
const FLOAT_INTRINSIC_PATTERNS: &[&str] = &["_ps(", "_pd(", "_f32(", "_f64("];

/// Name of the fn a line belongs to, tracked line-wise: updated whenever
/// a `fn <ident>` definition appears in the code view.
fn update_current_fn(code: &str, current: &mut String) {
    let mut start = 0usize;
    while let Some(p) = code[start..].find("fn ") {
        let p = start + p;
        let boundary = p == 0 || {
            let c = code.as_bytes()[p - 1];
            !(c.is_ascii_alphanumeric() || c == b'_')
        };
        let name: String = code[p + 3..]
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        if boundary && !name.is_empty() {
            *current = name;
        }
        start = p + 3;
    }
}

/// Run every per-file source rule on one file. Pure function of
/// `(label, text)` so fixtures can drive it directly in tests.
pub fn check_source(label: &str, text: &str) -> Vec<Diagnostic> {
    let view = SourceView::new(text);
    let mut diags = Vec::new();
    let mut push = |line: usize, rule: Rule, message: String| {
        diags.push(Diagnostic {
            file: label.to_string(),
            line: line + 1,
            rule,
            message,
        });
    };
    let mut current_fn = String::new();
    for (i, line) in view.lines.iter().enumerate() {
        let code = line.code.as_str();
        update_current_fn(code, &mut current_fn);

        if has_token(code, "unsafe") {
            if !view.allowed(i, Rule::UnsafeDoc) && !view.has_safety_comment(i) {
                push(
                    i,
                    Rule::UnsafeDoc,
                    "`unsafe` without a `// SAFETY:` comment stating the upheld invariant".into(),
                );
            }
            if !in_allowlist(label, UNSAFE_ALLOWLIST) && !view.allowed(i, Rule::UnsafeScope) {
                push(
                    i,
                    Rule::UnsafeScope,
                    format!(
                        "`unsafe` outside the audited allowlist ({})",
                        UNSAFE_ALLOWLIST.join(", ")
                    ),
                );
            }
        }

        if let Some(pat) = FMA_PATTERNS.iter().find(|&&p| code.contains(p)) {
            if !view.allowed(i, Rule::NoFma) {
                push(
                    i,
                    Rule::NoFma,
                    format!(
                        "fused multiply-add (`{pat}`) breaks the bit-exactness contract: \
                         use separate mul + add"
                    ),
                );
            }
        }

        if is_simd_isa_file(label)
            && FLOAT_INTRINSIC_PATTERNS.iter().any(|p| code.contains(p))
            && !current_fn.contains("affine")
            && !view.allowed(i, Rule::FloatAccum)
        {
            push(
                i,
                Rule::FloatAccum,
                format!(
                    "float intrinsic in fn `{current_fn}`: float accumulation in SIMD ISA \
                     files is only documented for the dense_affine (`affine*`) mul+add path"
                ),
            );
        }

        if in_library(label)
            && (code.contains("thread::spawn") || code.contains("thread::scope"))
            && !in_allowlist(label, SPAWN_ALLOWLIST)
            && !view.allowed(i, Rule::SpawnScope)
        {
            push(
                i,
                Rule::SpawnScope,
                format!(
                    "thread creation outside the sanctioned homes ({})",
                    SPAWN_ALLOWLIST.join(", ")
                ),
            );
        }

        if in_library(label)
            && code.contains("Ordering::Relaxed")
            && !view.allowed(i, Rule::RelaxedOrder)
        {
            push(
                i,
                Rule::RelaxedOrder,
                "Ordering::Relaxed needs a `gavina-lint: allow(relaxed-order)` annotation \
                 justifying why no stronger ordering is required"
                    .into(),
            );
        }

        if code.contains("static mut") && !view.allowed(i, Rule::StaticMut) {
            push(
                i,
                Rule::StaticMut,
                "`static mut` is forbidden: use OnceLock / atomics / Mutex".into(),
            );
        }
    }
    diags
}

/// `feature-guard`: every feature named by a `#[target_feature(enable =
/// "…")]` attribute in the SIMD files (`gemm/simd/` and the quantize
/// prologue `quant/simd.rs`) must be runtime-detected in the dispatch
/// file (`gemm/simd/mod.rs`), directly or via the implication closure
/// below (detecting `avx2` proves `avx`).
pub fn check_feature_guards(files: &[(String, String)]) -> Vec<Diagnostic> {
    const IMPLIES: &[(&str, &[&str])] = &[("avx2", &["avx"]), ("avx512f", &["avx2", "avx"])];
    fn contains_str(v: &[String], s: &str) -> bool {
        v.iter().any(|x| x.as_str() == s)
    }
    let mut detected: Vec<String> = Vec::new();
    for (label, text) in files {
        if !label.ends_with("gemm/simd/mod.rs") {
            continue;
        }
        let lines = split_lines(text);
        for (raw, line) in text.lines().zip(&lines) {
            if line.code.contains("feature_detected") {
                detected.extend(quoted_strings(raw).iter().map(|s| s.to_string()));
            }
        }
    }
    // Transitive closure over the implication map.
    loop {
        let mut grew = false;
        for &(have, implied) in IMPLIES {
            if contains_str(&detected, have) {
                for &f in implied {
                    if !contains_str(&detected, f) {
                        detected.push(f.to_string());
                        grew = true;
                    }
                }
            }
        }
        if !grew {
            break;
        }
    }
    let mut diags = Vec::new();
    for (label, text) in files {
        if !label.contains("gemm/simd/") && !label.contains("quant/simd") {
            continue;
        }
        let view = SourceView::new(text);
        for (i, line) in view.lines.iter().enumerate() {
            if !has_token(&line.code, "target_feature") {
                continue;
            }
            let feats = quoted_strings(view.raw[i]);
            for feat in feats.iter().flat_map(|s| s.split(',')) {
                let feat = feat.trim();
                if feat.is_empty() || contains_str(&detected, feat) {
                    continue;
                }
                if view.allowed(i, Rule::FeatureGuard) {
                    continue;
                }
                diags.push(Diagnostic {
                    file: label.clone(),
                    line: i + 1,
                    rule: Rule::FeatureGuard,
                    message: format!(
                        "target_feature `{feat}` has no matching runtime-detection guard \
                         in gemm/simd/mod.rs (is_*_feature_detected!)"
                    ),
                });
            }
        }
    }
    diags
}

/// `dep-guard`: scan one `Cargo.toml`. Any entry in a `*dependencies*`
/// section must be an internal `path` dependency (or `workspace = true`,
/// which resolves to a `[workspace.dependencies]` table that is itself
/// scanned). Everything else violates the std-only policy.
pub fn check_manifest(label: &str, text: &str) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let raws: Vec<&str> = text.lines().collect();
    let allowed = |i: usize| {
        annotations(raws[i]).contains(&Rule::DepGuard.id())
            || (i > 0 && annotations(raws[i - 1]).contains(&Rule::DepGuard.id()))
    };
    let mut push = |line: usize, name: &str| {
        diags.push(Diagnostic {
            file: label.to_string(),
            line: line + 1,
            rule: Rule::DepGuard,
            message: format!(
                "external dependency `{name}` violates the std-only policy \
                 (only internal `path` dependencies are allowed)"
            ),
        });
    };
    let dep_kinds = ["dependencies", "dev-dependencies", "build-dependencies"];
    let mut in_dep_section = false;
    // `[dependencies.foo]`-style single-dep table: (header line, name,
    // saw a `path` key, header carried an allow annotation).
    let mut pending: Option<(usize, String, bool, bool)> = None;
    for (i, raw) in raws.iter().enumerate() {
        let line = raw.trim();
        if line.starts_with('[') {
            if let Some((hl, name, has_path, ann)) = pending.take() {
                if !has_path && !ann {
                    push(hl, &name);
                }
            }
            in_dep_section = false;
            let Some(end) = line.find(']') else { continue };
            let sect = &line[1..end];
            let segs: Vec<&str> = sect.split('.').collect();
            if let Some(pos) = segs.iter().position(|s| dep_kinds.contains(s)) {
                if pos + 1 == segs.len() {
                    in_dep_section = true;
                } else {
                    let name = segs[pos + 1..].join(".");
                    pending = Some((i, name, false, allowed(i)));
                }
            }
            continue;
        }
        if let Some(p) = pending.as_mut() {
            if line.starts_with("path") && line.contains('=') {
                p.2 = true;
            }
            continue;
        }
        if !in_dep_section || line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some(eq) = line.find('=') else { continue };
        let name = line[..eq].trim().trim_matches('"');
        let value = &line[eq + 1..];
        let internal = value.contains("path =") || value.contains("path=");
        let via_workspace = value.contains("workspace = true") || value.contains("workspace=true");
        if !internal && !via_workspace && !allowed(i) {
            push(i, name);
        }
    }
    if let Some((hl, name, has_path, ann)) = pending.take() {
        if !has_path && !ann {
            push(hl, &name);
        }
    }
    diags
}

// ---------------------------------------------------------------------
// Tree walking.
// ---------------------------------------------------------------------

fn walk(
    dir: &Path,
    want_ext: Option<&str>,
    want_name: Option<&str>,
    out: &mut Vec<PathBuf>,
) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.path());
    for entry in entries {
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if name == "target" || name == ".git" || name == "xtask" {
                continue;
            }
            walk(&path, want_ext, want_name, out)?;
            continue;
        }
        let ext = path.extension().and_then(|x| x.to_str());
        if want_ext.is_some_and(|e| ext == Some(e)) || want_name.is_some_and(|n| name == n) {
            out.push(path);
        }
    }
    Ok(())
}

fn label_for(repo_root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(repo_root).unwrap_or(path);
    rel.to_string_lossy().replace('\\', "/")
}

/// Run the whole contract check over the repository tree: source rules
/// on `rust/src`, `rust/tests`, `rust/benches` and `examples/`,
/// `feature-guard` across `gemm/simd/` + `quant/simd.rs`, and
/// `dep-guard` on every `Cargo.toml` under `rust/` (the xtask's own
/// manifest included).
pub fn run_check(repo_root: &Path) -> io::Result<CheckReport> {
    let mut report = CheckReport::default();
    let mut rs_files = Vec::new();
    for sub in ["rust/src", "rust/tests", "rust/benches", "examples"] {
        let dir = repo_root.join(sub);
        if dir.is_dir() {
            walk(&dir, Some("rs"), None, &mut rs_files)?;
        }
    }
    let mut sources = Vec::with_capacity(rs_files.len());
    for path in &rs_files {
        sources.push((label_for(repo_root, path), fs::read_to_string(path)?));
    }
    report.sources = sources.len();
    for (label, text) in &sources {
        report.diagnostics.extend(check_source(label, text));
    }
    let mut simd: Vec<(String, String)> = Vec::new();
    for (label, text) in &sources {
        if label.contains("gemm/simd/") || label.contains("quant/simd") {
            simd.push((label.clone(), text.clone()));
        }
    }
    report.diagnostics.extend(check_feature_guards(&simd));

    let mut manifests = Vec::new();
    let rust_dir = repo_root.join("rust");
    if rust_dir.is_dir() {
        // Note: `walk` skips `xtask/` for sources; collect its manifest
        // explicitly so dep-guard still covers it.
        walk(&rust_dir, None, Some("Cargo.toml"), &mut manifests)?;
        let xtask_manifest = rust_dir.join("xtask/Cargo.toml");
        if xtask_manifest.is_file() {
            manifests.push(xtask_manifest);
        }
    }
    manifests.sort();
    manifests.dedup();
    report.manifests = manifests.len();
    for path in &manifests {
        let label = label_for(repo_root, path);
        report
            .diagnostics
            .extend(check_manifest(&label, &fs::read_to_string(path)?));
    }

    report.diagnostics.sort_by_key(|d| (d.file.clone(), d.line, d.rule));
    Ok(report)
}

#[cfg(test)]
mod tests;
