//! CLI for the repo-contract checks: `cargo run -p gavina-xtask -- check`.
//!
//! Subcommands: `check` (default) scans the tree and exits non-zero on
//! any violation; `list` prints every rule id with its one-line
//! contract. `--root <dir>` overrides the repo root (the default is
//! derived from this crate's manifest location, so the binary works from
//! any working directory).

use std::env;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use gavina_xtask::{run_check, ALL_RULES};

const USAGE: &str = "usage: gavina-xtask [check|list] [--root <repo-root>]";

/// xtask lives at `<repo>/rust/xtask`; the repo root is two levels up.
fn default_root() -> PathBuf {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    match manifest.parent().and_then(Path::parent) {
        Some(root) => root.to_path_buf(),
        None => PathBuf::from("."),
    }
}

fn main() -> ExitCode {
    let mut cmd = String::from("check");
    let mut root = default_root();
    let mut args = env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "check" | "list" => cmd = arg,
            "--root" => match args.next() {
                Some(dir) => root = PathBuf::from(dir),
                None => {
                    eprintln!("--root needs a value\n{USAGE}");
                    return ExitCode::from(2);
                }
            },
            other => {
                eprintln!("unknown argument `{other}`\n{USAGE}");
                return ExitCode::from(2);
            }
        }
    }
    if cmd == "list" {
        for rule in ALL_RULES {
            println!("{:<14} {}", rule.id(), rule.describe());
        }
        return ExitCode::SUCCESS;
    }
    let report = match run_check(&root) {
        Ok(report) => report,
        Err(err) => {
            eprintln!("gavina-xtask: scanning {} failed: {err}", root.display());
            return ExitCode::from(2);
        }
    };
    for diag in &report.diagnostics {
        println!("{diag}");
    }
    println!(
        "gavina-xtask check: {} sources + {} manifests scanned, {} violation(s)",
        report.sources,
        report.manifests,
        report.diagnostics.len()
    );
    if report.diagnostics.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
