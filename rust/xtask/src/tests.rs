//! Fixture tests for every rule — including the acceptance fixtures: an
//! uncommented `unsafe` block, an FMA intrinsic, a non-allowlisted
//! dependency and a stray `thread::spawn` must all fail, and the real
//! tree must pass.

use super::*;

fn ids(diags: &[Diagnostic]) -> Vec<&'static str> {
    diags.iter().map(|d| d.rule.id()).collect()
}

const SIMD_LABEL: &str = "rust/src/gemm/simd/x86.rs";

#[test]
fn uncommented_unsafe_block_fires_unsafe_doc() {
    let src = "pub fn f(p: *const u64) -> u64 {\n    unsafe { *p }\n}\n";
    let diags = check_source(SIMD_LABEL, src);
    assert_eq!(ids(&diags), vec!["unsafe-doc"]);
    assert_eq!(diags[0].line, 2);
}

#[test]
fn safety_comment_above_satisfies_unsafe_doc() {
    let src = "pub fn f(p: *const u64) -> u64 {\n    \
               // SAFETY: caller guarantees p is valid (fixture)\n    \
               unsafe { *p }\n}\n";
    assert!(check_source(SIMD_LABEL, src).is_empty());
}

#[test]
fn safety_doc_section_covers_unsafe_fn_through_attributes() {
    let src = "/// # Safety\n///\n/// `p` must be valid.\n\
               #[target_feature(enable = \"avx2\")]\n\
               pub unsafe fn f(p: *const u64) -> u64 {\n    \
               // SAFETY: contract forwarded from the fn's Safety section\n    \
               unsafe { *p }\n}\n";
    assert!(check_source(SIMD_LABEL, src).is_empty());
}

#[test]
fn unsafe_outside_the_allowlist_fires_unsafe_scope() {
    let src = "// SAFETY: fixture\nlet v = unsafe { *p };\n";
    let diags = check_source("rust/src/dnn/exec.rs", src);
    assert_eq!(ids(&diags), vec!["unsafe-scope"]);
    let allowed = check_source("rust/src/quant/interleaved.rs", src);
    assert!(allowed.is_empty());
}

#[test]
fn quant_simd_is_in_the_unsafe_allowlist() {
    // The SIMD quantize+pack prologue is an audited unsafe module; the
    // rest of quant/ (and dnn/) stays safe code.
    let src = "// SAFETY: fixture\nlet v = unsafe { *p };\n";
    assert!(check_source("rust/src/quant/simd.rs", src).is_empty());
    assert_eq!(
        ids(&check_source("rust/src/quant/packed.rs", src)),
        vec!["unsafe-scope"]
    );
}

#[test]
fn unsafe_in_prose_or_identifier_does_not_fire() {
    let src = "#![deny(unsafe_op_in_unsafe_fn)]\n\
               // this comment says unsafe and that is fine\n\
               let s = \"unsafe\";\n";
    assert!(check_source("rust/src/lib.rs", src).is_empty());
}

#[test]
fn fma_intrinsics_and_mul_add_fire_no_fma() {
    for line in [
        "let y = x.mul_add(a, b);\n",
        "let v = _mm256_fmadd_ps(a, b, c);\n",
        "let v = vfmaq_f32(a, b, c);\n",
    ] {
        let diags = check_source("rust/benches/hotpath.rs", line);
        assert_eq!(ids(&diags), vec!["no-fma"], "{line}");
    }
    // Prose may discuss FMA freely; only code is linted.
    let prose = "// never vfma / mul_add here: separate mul + add only\n";
    let clean = check_source("rust/benches/hotpath.rs", prose);
    assert!(clean.is_empty());
}

#[test]
fn float_intrinsics_only_inside_affine_fns_in_isa_files() {
    let bad = "unsafe fn dot_avx2(a: *const u64) {\n    \
               let v = _mm256_add_ps(x, y);\n}\n";
    let diags = check_source(SIMD_LABEL, bad);
    assert!(ids(&diags).contains(&"float-accum"), "{diags:?}");
    let good = "unsafe fn affine_cols8_avx(x: *const f32) {\n    \
                let v = _mm256_add_ps(a, _mm256_mul_ps(b, c));\n}\n";
    let good_ids = ids(&check_source(SIMD_LABEL, good));
    assert!(!good_ids.contains(&"float-accum"));
    // The dispatch module is not an ISA file.
    let in_mod = "fn autotune() {\n    let v = some_helper_f32(x);\n}\n";
    let mod_diags = check_source("rust/src/gemm/simd/mod.rs", in_mod);
    assert!(mod_diags.is_empty());
}

#[test]
fn stray_thread_spawn_fires_spawn_scope() {
    let src = "let h = std::thread::spawn(|| {});\n";
    let diags = check_source("rust/src/dnn/exec.rs", src);
    assert_eq!(ids(&diags), vec!["spawn-scope"]);
    assert!(check_source("rust/src/serve/mod.rs", src).is_empty());
    assert!(check_source("rust/src/util/parallel.rs", src).is_empty());
    // The canary subsystem is pure observability — it runs on the
    // governor/worker threads and must never spawn its own.
    let canary = check_source("rust/src/canary/sampler.rs", src);
    assert_eq!(ids(&canary), vec!["spawn-scope"]);
    // Integration tests and benches drive the library from outside it.
    assert!(check_source("rust/tests/serve_qos.rs", src).is_empty());
}

#[test]
fn relaxed_ordering_requires_an_annotation() {
    let bare = "let n = x.load(Ordering::Relaxed);\n";
    let diags = check_source("rust/src/serve/session.rs", bare);
    assert_eq!(ids(&diags), vec!["relaxed-order"]);
    let annotated = "// gavina-lint: allow(relaxed-order): monotonic counter\n\
                     let n = x.load(Ordering::Relaxed);\n";
    let site = check_source("rust/src/serve/session.rs", annotated);
    assert!(site.is_empty());
    let file_scope = "//! gavina-lint: allow(relaxed-order): counters only\n\
                      let n = x.load(Ordering::Relaxed);\n";
    let whole_file = check_source("rust/src/serve/metrics.rs", file_scope);
    assert!(whole_file.is_empty());
    // src/canary/ is covered like the rest of the library: a bare
    // Relaxed in the drift estimator needs the same annotation.
    let in_canary = check_source("rust/src/canary/estimator.rs", bare);
    assert_eq!(ids(&in_canary), vec!["relaxed-order"]);
}

#[test]
fn static_mut_is_always_flagged() {
    let src = "static mut COUNTER: u32 = 0;\n";
    let diags = check_source("rust/src/stats/mod.rs", src);
    assert_eq!(ids(&diags), vec!["static-mut"]);
    let escaped = "static mut COUNTER: u32 = 0; // gavina-lint: allow(static-mut)\n";
    assert!(check_source("rust/src/stats/mod.rs", escaped).is_empty());
}

#[test]
fn string_literals_never_trip_code_rules() {
    let src = "let s = \"thread::spawn Ordering::Relaxed static mut unsafe\";\n";
    assert!(check_source("rust/src/config/mod.rs", src).is_empty());
}

#[test]
fn non_allowlisted_dependency_fires_dep_guard() {
    let manifest = "[package]\nname = \"gavina\"\n\n[dependencies]\nrand = \"0.8\"\n";
    let diags = check_manifest("rust/Cargo.toml", manifest);
    assert_eq!(ids(&diags), vec!["dep-guard"]);
    assert_eq!(diags[0].line, 5);
    assert!(diags[0].message.contains("rand"));
}

#[test]
fn path_and_workspace_dependencies_are_internal() {
    let manifest = "[dependencies]\n\
                    gavina = { path = \"..\" }\n\
                    shared = { workspace = true }\n";
    let diags = check_manifest("rust/xtask/Cargo.toml", manifest);
    assert!(diags.is_empty());
}

#[test]
fn dotted_dependency_tables_are_checked() {
    let external = "[dependencies.serde]\nversion = \"1\"\nfeatures = [\"derive\"]\n";
    let diags = check_manifest("rust/Cargo.toml", external);
    assert_eq!(ids(&diags), vec!["dep-guard"]);
    assert_eq!(diags[0].line, 1);
    let internal = "[dependencies.gavina]\npath = \"..\"\n";
    assert!(check_manifest("rust/Cargo.toml", internal).is_empty());
}

#[test]
fn dev_dependencies_are_covered_and_annotations_work() {
    let manifest = "[dev-dependencies]\ncriterion = { version = \"0.5\" }\n";
    let diags = check_manifest("rust/Cargo.toml", manifest);
    assert_eq!(ids(&diags), vec!["dep-guard"]);
    let waived = "[dev-dependencies]\n\
                  # gavina-lint: allow(dep-guard): vendored offline, see DESIGN.md\n\
                  criterion = { version = \"0.5\" }\n";
    assert!(check_manifest("rust/Cargo.toml", waived).is_empty());
}

#[test]
fn undetected_target_feature_fires_feature_guard() {
    let dispatch = "pub fn is_available() -> bool {\n    \
                    std::arch::is_x86_feature_detected!(\"avx2\")\n}\n";
    let isa = "#[target_feature(enable = \"fma\")]\nunsafe fn f() {}\n";
    let files = vec![
        ("rust/src/gemm/simd/mod.rs".to_string(), dispatch.to_string()),
        ("rust/src/gemm/simd/x86.rs".to_string(), isa.to_string()),
    ];
    let diags = check_feature_guards(&files);
    assert_eq!(ids(&diags), vec!["feature-guard"]);
    assert!(diags[0].message.contains("`fma`"));
}

#[test]
fn detected_and_implied_features_pass_feature_guard() {
    let dispatch = "fn avail() -> bool {\n    \
                    std::arch::is_x86_feature_detected!(\"avx2\")\n        \
                    && std::arch::is_x86_feature_detected!(\"avx512f\")\n}\n";
    let isa = "#[target_feature(enable = \"avx2\")]\nunsafe fn a() {}\n\
               #[target_feature(enable = \"avx\")]\nunsafe fn b() {}\n\
               #[target_feature(enable = \"avx512f,avx2\")]\nunsafe fn c() {}\n";
    let files = vec![
        ("rust/src/gemm/simd/mod.rs".to_string(), dispatch.to_string()),
        ("rust/src/gemm/simd/x86.rs".to_string(), isa.to_string()),
    ];
    assert!(check_feature_guards(&files).is_empty());
}

#[test]
fn feature_guard_scan_covers_quant_simd() {
    // quant/simd.rs uses #[target_feature] too; its features must be
    // detected in gemm/simd/mod.rs like the ISA files' own.
    let dispatch = "pub fn is_available() -> bool {\n    \
                    std::arch::is_x86_feature_detected!(\"avx2\")\n}\n";
    let detected = "#[target_feature(enable = \"avx2\")]\nunsafe fn q() {}\n";
    let undetected = "#[target_feature(enable = \"avx512vpopcntdq\")]\nunsafe fn q() {}\n";
    let ok = vec![
        ("rust/src/gemm/simd/mod.rs".to_string(), dispatch.to_string()),
        ("rust/src/quant/simd.rs".to_string(), detected.to_string()),
    ];
    assert!(check_feature_guards(&ok).is_empty());
    let bad = vec![
        ("rust/src/gemm/simd/mod.rs".to_string(), dispatch.to_string()),
        ("rust/src/quant/simd.rs".to_string(), undetected.to_string()),
    ];
    let diags = check_feature_guards(&bad);
    assert_eq!(ids(&diags), vec!["feature-guard"]);
    assert_eq!(diags[0].file, "rust/src/quant/simd.rs");
}

#[test]
fn annotation_parser_reads_lists_and_ignores_noise() {
    assert_eq!(
        annotations(" gavina-lint: allow(no-fma, dep-guard) rationale"),
        vec!["no-fma", "dep-guard"]
    );
    assert!(annotations("nothing to see").is_empty());
    assert!(annotations("gavina-lint: allow(").is_empty());
}

#[test]
fn token_matcher_respects_word_boundaries() {
    assert!(has_token("unsafe { }", "unsafe"));
    assert!(has_token("pub unsafe fn f()", "unsafe"));
    assert!(!has_token("unsafe_op_in_unsafe_fn", "unsafe"));
    assert!(!has_token("deny(unsafe_code)", "unsafe"));
}

#[test]
fn block_comments_span_lines_in_the_line_model() {
    let lines = split_lines("/* SAFETY: spans\nlines */ unsafe { x }\n");
    assert!(lines[0].code.trim().is_empty());
    assert!(lines[1].code.contains("unsafe"));
    assert!(lines[1].comment.contains("lines"));
}

/// The contract check itself is a tier-1 test: the real tree must be
/// clean. This is what keeps the gates honest even when the CI job that
/// runs the binary is skipped.
#[test]
fn real_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .and_then(Path::parent)
        .expect("xtask lives at <repo>/rust/xtask");
    if !root.join("rust/src").is_dir() {
        return; // vendored or partial checkout; the CI job still runs the binary
    }
    let report = run_check(root).expect("scan repo tree");
    assert!(report.sources > 40, "saw only {} sources", report.sources);
    assert!(report.manifests >= 2, "expected crate + xtask manifests");
    let mut rendered = Vec::new();
    for d in &report.diagnostics {
        rendered.push(d.to_string());
    }
    assert!(
        rendered.is_empty(),
        "repo contract violations:\n{}",
        rendered.join("\n")
    );
}
