//! The serve plane's dispatch core: per-replica bounded deques with
//! continuous batching and cross-tier work-stealing.
//!
//! One [`Dispatch`] replaces the old batcher thread + single `work_tx`
//! channel. Every tier owns `replicas` FIFO lanes (`VecDeque<Request>`),
//! all guarded by **one** mutex together with the `closed` flag — so the
//! submit/shutdown race that previously needed a post-send `SeqCst`
//! re-check is impossible by construction: a submit either enqueues
//! before `close()` takes the lock (and is drained), or observes
//! `closed` and returns a typed error. The critical sections are
//! pointer-sized pushes/pops, orders of magnitude shorter than the
//! millisecond-scale batches workers execute, so one lock is not a
//! scalability concern — batch *formation* is what must be cheap, and it
//! is O(replicas) pops.
//!
//! **Continuous batching**: there are no `batch_timeout` windows. The
//! moment a worker is idle it claims *everything* queued for its home
//! tier (own lane first, then sibling lanes) up to the tier's
//! `max_batch`, and runs it as one packed GEMM A-side. A lone request
//! never waits for a barrier; a burst packs densely.
//!
//! Canary re-runs never pass through these lanes: sampled rows execute
//! inline on the worker that served them, *after* its responses went
//! out, via `Engine::canary_rerun` — dispatch only ever carries client
//! requests.
//!
//! **Work-stealing**: a worker whose home tier is empty takes up to one
//! batch from another tier's lane *tails* (newest first — the classic
//! owner-FIFO/thief-LIFO split) and runs it on the *victim's* engine, so
//! an aggressive-tier backlog cannot idle the exact tier's replicas or
//! vice versa. Tiers whose engine is fully guarded (`GavPolicy::Exact`)
//! are protected victims: thieves leave at least `steal_reserve`
//! requests behind so exact-tier work keeps its dedicated, predictable
//! lanes under mixed load. During shutdown draining, stealing is
//! unconditionally enabled (and reserves waived) so every accepted
//! request is answered no matter which worker gets to it first.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::engine::GavinaError;

use super::session::Request;

/// A batch a worker claimed: which tier it belongs to (and must execute
/// on), and whether it was stolen from a foreign tier.
pub(crate) struct Claimed {
    pub(crate) tier: usize,
    pub(crate) stolen: bool,
    pub(crate) batch: Vec<Request>,
}

struct DispatchInner {
    /// `queues[tier * replicas + replica]` — one FIFO lane per replica.
    queues: Vec<VecDeque<Request>>,
    /// Round-robin cursor per tier for tie-breaking submit placement.
    rr: Vec<usize>,
    closed: bool,
}

/// All queue state of the serve plane (see the module docs).
pub(crate) struct Dispatch {
    inner: Mutex<DispatchInner>,
    cv: Condvar,
    replicas: usize,
    steal: bool,
    steal_reserve: usize,
    /// Per-tier batch bound (continuous batching claims up to this).
    max_batch: Vec<usize>,
    /// Per-tier steal protection (exact-policy tiers).
    protected: Vec<bool>,
}

impl Dispatch {
    pub(crate) fn new(
        replicas: usize,
        steal: bool,
        steal_reserve: usize,
        max_batch: Vec<usize>,
        protected: Vec<bool>,
    ) -> Self {
        let n_tiers = max_batch.len();
        debug_assert_eq!(protected.len(), n_tiers);
        debug_assert!(replicas >= 1);
        Self {
            inner: Mutex::new(DispatchInner {
                queues: (0..n_tiers * replicas).map(|_| VecDeque::new()).collect(),
                rr: vec![0; n_tiers],
                closed: false,
            }),
            cv: Condvar::new(),
            replicas,
            steal,
            steal_reserve,
            max_batch,
            protected,
        }
    }

    pub(crate) fn replicas(&self) -> usize {
        self.replicas
    }

    /// Enqueue one accepted request onto the shortest of its tier's
    /// lanes (ties broken round-robin). Fails with a typed error after
    /// [`Dispatch::close`] — the request (and its admission permit) is
    /// dropped, never stranded: the `closed` flag lives under the same
    /// lock as the queues, so there is no submit/shutdown race window.
    pub(crate) fn submit(&self, tier: usize, req: Request) -> Result<(), GavinaError> {
        {
            let mut inner = self.inner.lock().unwrap();
            if inner.closed {
                return Err(GavinaError::Backend("serving pipeline is shut down".into()));
            }
            let base = tier * self.replicas;
            let rr = inner.rr[tier];
            let mut best = 0usize;
            let mut best_len = usize::MAX;
            for i in 0..self.replicas {
                let r = (rr + i) % self.replicas;
                let len = inner.queues[base + r].len();
                if len < best_len {
                    best_len = len;
                    best = r;
                }
            }
            inner.rr[tier] = (best + 1) % self.replicas;
            inner.queues[base + best].push_back(req);
        }
        self.cv.notify_one();
        Ok(())
    }

    /// Block until there is work, and claim one batch. Returns `None`
    /// exactly when the dispatch is closed *and* every lane is empty —
    /// the worker's signal to exit. `home_replica` is the lane the
    /// worker drains first (its own), for locality under load.
    pub(crate) fn claim(&self, home_tier: usize, home_replica: usize) -> Option<Claimed> {
        let n_tiers = self.max_batch.len();
        let mut inner = self.inner.lock().unwrap();
        loop {
            // 1) Continuous batching over the home tier: own lane first,
            //    then sibling lanes, up to max_batch in one claim.
            if let Some(batch) = self.take_home(&mut inner, home_tier, home_replica) {
                return Some(Claimed {
                    tier: home_tier,
                    stolen: false,
                    batch,
                });
            }
            // 2) Steal from another tier's tails (always during the
            //    shutdown drain, so closing answers every request).
            if self.steal || inner.closed {
                let closed = inner.closed;
                for off in 1..n_tiers {
                    let t = (home_tier + off) % n_tiers;
                    if let Some(batch) = self.steal_tail(&mut inner, t, closed) {
                        return Some(Claimed {
                            tier: t,
                            stolen: true,
                            batch,
                        });
                    }
                }
            }
            if inner.closed {
                return None;
            }
            // Timeout is a lost-wakeup backstop only; submits notify.
            let (guard, _) = self
                .cv
                .wait_timeout(inner, Duration::from_millis(50))
                .unwrap();
            inner = guard;
        }
    }

    /// Take up to `max_batch[tier]` requests from the front of the
    /// tier's lanes, starting with the worker's own lane.
    fn take_home(
        &self,
        inner: &mut DispatchInner,
        tier: usize,
        home_replica: usize,
    ) -> Option<Vec<Request>> {
        let limit = self.max_batch[tier];
        let base = tier * self.replicas;
        let mut batch = Vec::new();
        for i in 0..self.replicas {
            let lane = base + (home_replica + i) % self.replicas;
            while batch.len() < limit {
                match inner.queues[lane].pop_front() {
                    Some(r) => batch.push(r),
                    None => break,
                }
            }
            if batch.len() >= limit {
                break;
            }
        }
        if batch.is_empty() {
            None
        } else {
            Some(batch)
        }
    }

    /// Steal up to one batch from tier `t`'s lane tails. Protected
    /// (exact) tiers keep `steal_reserve` queued requests; a closed
    /// dispatch waives the reserve so the drain completes.
    fn steal_tail(&self, inner: &mut DispatchInner, t: usize, closed: bool) -> Option<Vec<Request>> {
        let base = t * self.replicas;
        let total: usize = inner.queues[base..base + self.replicas]
            .iter()
            .map(VecDeque::len)
            .sum();
        let reserve = if self.protected[t] && !closed {
            self.steal_reserve
        } else {
            0
        };
        let take = total.saturating_sub(reserve).min(self.max_batch[t]);
        if take == 0 {
            return None;
        }
        let mut batch = Vec::with_capacity(take);
        while batch.len() < take {
            // Newest work first: pop from the tail of the longest lane.
            let lane = (base..base + self.replicas)
                .max_by_key(|&q| inner.queues[q].len())
                .expect("replicas >= 1");
            match inner.queues[lane].pop_back() {
                Some(r) => batch.push(r),
                None => break,
            }
        }
        Some(batch)
    }

    /// Per-lane queue depths of one tier, `[replica]`-indexed.
    pub(crate) fn tier_depths(&self, tier: usize) -> Vec<usize> {
        let inner = self.inner.lock().unwrap();
        let base = tier * self.replicas;
        (base..base + self.replicas)
            .map(|q| inner.queues[q].len())
            .collect()
    }

    /// Close for new submits and wake every worker to drain + exit.
    pub(crate) fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}
