//! QoS tiers and the `[serve]` configuration surface.
//!
//! A **tier** is a named energy/accuracy operating point: a
//! [`GavPolicy`] (resolved once at service start via
//! [`Engine::with_policy`](crate::engine::Engine::with_policy), sharing
//! the packed weight planes) plus its own batching bound and metrics.
//! Each tier gets `replicas` dedicated worker lanes; idle replicas steal
//! batches from other tiers (see the [`serve`](super) module docs). The
//! built-in trio mirrors the paper's flexibility axis:
//!
//! * `exact` — fully guarded. Per-image activation quantization makes
//!   every served request **bit-identical** to a standalone
//!   [`Engine::infer`](crate::engine::Engine::infer) call regardless of
//!   batch co-tenants, so the exact tier batches too (`max_batch = 4`).
//!   Its queue is also a protected steal victim: thieves leave
//!   `steal_reserve` requests behind. The reproducibility tier.
//! * `guarded` — the base engine's own policy, normal batching. The
//!   balanced default.
//! * `aggressive` — `G = 0` everywhere (every LSB plane-combination
//!   undervolted), large batches. The energy-optimal tier; the governor
//!   moves the *default* tier toward it under load.
//!
//! ## Config schema
//!
//! ```toml
//! [serve]
//! replicas = 2             # worker lanes per tier (>= 1)
//! steal = true             # idle replicas steal foreign tiers' batches
//! steal_reserve = 2        # queued requests a protected tier keeps
//! queue_depth = 64         # bounded admission: max in-flight requests
//! default_tier = "guarded"
//! max_batch = 8            # global batching default; tiers may override
//!
//! [serve.tier.exact]
//! policy = "exact"
//! max_batch = 4
//!
//! [serve.tier.guarded]
//! policy = "uniform"
//! g = 3
//!
//! [serve.tier.aggressive]
//! policy = "uniform"
//! g = 0
//! max_batch = 16
//!
//! [serve.governor]         # present => load-adaptive governor enabled
//! period_ms = 100
//! target_power_mw = 25.0   # optional modeled power budget
//! high_load = 0.75
//! low_load = 0.25
//! min_g = 0
//!
//! [serve.canary]           # present => canary drift observability enabled
//! sample_rate = 0.05       # fraction of requests re-run on the exact replica
//! window = 256             # sliding drift window (samples)
//! high_watermark = 0.05    # flip rate that steps the ladder toward guarded
//! low_watermark = 0.01     # flip rate below which dwell may drain
//! dwell_ticks = 8          # governor ticks held before re-descending
//! min_samples = 16         # window fill before the signal is trusted
//! ```
//!
//! `workers = N` (the pre-replica total worker count) is still accepted
//! and maps to `replicas = ceil(N / n_tiers)`; setting both `workers`
//! and `replicas` is an error. `batch_timeout_ms` is accepted and
//! type-checked for compatibility but **ignored**: continuous batching
//! has no flush windows — an idle worker claims everything queued the
//! moment it is free.
//!
//! Tier policies: `exact`, `base` (the engine's own policy as built),
//! `uniform` (needs `g`), `per_layer` (needs `layer_gs`). `ilp` is
//! rejected here — it needs a profile set, so resolve it on the
//! [`EngineBuilder`](crate::engine::EngineBuilder) instead. Unknown or
//! ill-typed keys are typed [`GavinaError::Config`] errors that name the
//! offending config line.

use std::time::Duration;

use crate::canary::CanaryOptions;
use crate::config::{Config, Value};
use crate::engine::{GavPolicy, GavinaError};

use super::governor::GovernorOptions;

/// One QoS tier: a named policy + batching operating point.
#[derive(Clone, Debug)]
pub struct TierSpec {
    /// Tier name, the key clients pass to
    /// [`SubmitOptions::tier`](super::SubmitOptions::tier).
    pub name: String,
    /// `None` = the base engine's own policy (as built); `Some(p)` is
    /// resolved via `Engine::with_policy` at service start, sharing the
    /// packed weight planes.
    pub policy: Option<GavPolicy>,
    /// Largest batch one worker claims in one go (1 = per-request
    /// execution). There is no timeout knob: batching is continuous.
    pub max_batch: usize,
}

impl TierSpec {
    /// A tier with the default batching bound (`max_batch 8`).
    pub fn new(name: &str, policy: Option<GavPolicy>) -> Self {
        Self {
            name: name.to_string(),
            policy,
            max_batch: 8,
        }
    }

    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n;
        self
    }
}

/// Service configuration: admission bound, per-tier replica lanes,
/// work-stealing, QoS tiers and the optional governor. Everything
/// model/accelerator-side (precision, error tables, intra-batch threads)
/// lives on the [`Engine`](crate::engine::Engine).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Worker lanes **per tier** — the pool is `tiers × replicas`
    /// threads, each with its own FIFO lane.
    pub replicas: usize,
    /// Bounded admission: the maximum number of accepted-but-unanswered
    /// requests. At the bound, `submit` fails fast with
    /// [`GavinaError::Overloaded`].
    pub queue_depth: usize,
    /// Idle replicas steal batches from other tiers' lane tails. Off,
    /// tiers are fully isolated (stealing still happens during the
    /// shutdown drain so no accepted ticket is stranded).
    pub steal: bool,
    /// Queued requests a protected (exact-policy) tier keeps away from
    /// thieves, preserving its replicas' locality under mixed load.
    pub steal_reserve: usize,
    /// Name of the tier `submit` routes to when no tier is given; the
    /// governor (when enabled) adapts this tier's per-layer G.
    pub default_tier: String,
    /// The QoS tiers (at least one; names must be unique).
    pub tiers: Vec<TierSpec>,
    /// Load-adaptive undervolting governor for the default tier.
    pub governor: Option<GovernorOptions>,
    /// Canary drift observability: deterministic sampling of in-flight
    /// requests, exact-replica re-execution and the drift feedback the
    /// governor closes its loop on. `None` = no canary (the historical
    /// load-only governor behavior).
    pub canary: Option<CanaryOptions>,
}

impl Default for ServeOptions {
    /// The built-in `exact` / `guarded` / `aggressive` trio (see the
    /// [module docs](self)), two replicas per tier, stealing on,
    /// admission depth 64, governor off.
    fn default() -> Self {
        Self {
            replicas: 2,
            queue_depth: 64,
            steal: true,
            steal_reserve: 2,
            default_tier: "guarded".into(),
            tiers: vec![
                TierSpec::new("exact", Some(GavPolicy::Exact)).max_batch(4),
                TierSpec::new("guarded", None),
                TierSpec::new("aggressive", Some(GavPolicy::Uniform(0))).max_batch(16),
            ],
            governor: None,
            canary: None,
        }
    }
}

impl ServeOptions {
    /// Structural validation shared by the builder and config paths —
    /// `Service::start` calls this, so a hand-built `ServeOptions` gets
    /// the same checks as a parsed one.
    pub fn validate(&self) -> Result<(), GavinaError> {
        if self.replicas == 0 {
            return Err(GavinaError::Config(
                "[serve] replicas must be ≥ 1 (0 workers would never serve)".into(),
            ));
        }
        if self.queue_depth == 0 {
            return Err(GavinaError::Config(
                "[serve] queue_depth must be ≥ 1 (0 would reject every request)".into(),
            ));
        }
        if self.tiers.is_empty() {
            return Err(GavinaError::Config(
                "[serve] at least one QoS tier is required".into(),
            ));
        }
        for (i, t) in self.tiers.iter().enumerate() {
            if t.name.is_empty() {
                return Err(GavinaError::Config("[serve] tier names must be non-empty".into()));
            }
            if t.max_batch == 0 {
                return Err(GavinaError::Config(format!(
                    "[serve] tier '{}' max_batch must be ≥ 1",
                    t.name
                )));
            }
            if self.tiers[..i].iter().any(|o| o.name == t.name) {
                return Err(GavinaError::Config(format!(
                    "[serve] duplicate tier name '{}'",
                    t.name
                )));
            }
            if matches!(t.policy, Some(GavPolicy::IlpBudget { .. })) {
                return Err(GavinaError::Config(format!(
                    "[serve] tier '{}': IlpBudget needs a profile set — resolve it on the \
                     EngineBuilder and use policy \"base\"",
                    t.name
                )));
            }
        }
        if !self.tiers.iter().any(|t| t.name == self.default_tier) {
            return Err(GavinaError::Config(format!(
                "[serve] default_tier '{}' is not a configured tier (have: {})",
                self.default_tier,
                self.tiers
                    .iter()
                    .map(|t| t.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            )));
        }
        if let Some(g) = &self.governor {
            g.validate()?;
        }
        if let Some(c) = &self.canary {
            c.validate()?;
        }
        Ok(())
    }

    /// Load from the `[serve]`, `[serve.tier.*]` and `[serve.governor]`
    /// sections of a parsed config (see the [module docs](self) for the
    /// schema). Unknown keys, ill-typed values and out-of-range numbers
    /// are [`GavinaError::Config`] errors carrying the config line.
    pub fn from_config(cfg: &Config) -> Result<Self, GavinaError> {
        const KNOWN_TOP: &[&str] = &[
            "workers",
            "replicas",
            "steal",
            "steal_reserve",
            "queue_depth",
            "max_batch",
            "batch_timeout_ms",
            "default_tier",
        ];
        const KNOWN_TIER: &[&str] = &["policy", "g", "layer_gs", "max_batch", "batch_timeout_ms"];
        const KNOWN_GOV: &[&str] =
            &["period_ms", "target_power_mw", "high_load", "low_load", "min_g"];
        const KNOWN_CANARY: &[&str] = &[
            "sample_rate",
            "window",
            "high_watermark",
            "low_watermark",
            "dwell_ticks",
            "min_samples",
        ];

        // Error helper: every diagnostic names the config line when the
        // key came from a file (mirrors the parser's duplicate-key
        // errors).
        let bad = |key: &str, msg: String| -> GavinaError {
            match cfg.line_of(&format!("serve.{key}")) {
                Some(line) => GavinaError::Config(format!("[serve] {msg} (config line {line})")),
                None => GavinaError::Config(format!("[serve] {msg}")),
            }
        };

        // Section-header pass: a bare `[serve.governor]` enables the
        // governor with all defaults, a bare `[serve.tier.x]` names a
        // tier (which then fails the needs-policy check instead of being
        // silently ignored), and a typoed `[serve.bogus]` sub-section is
        // a hard error.
        let mut tier_names: Vec<String> = Vec::new();
        let mut has_governor = false;
        let mut has_canary = false;
        for (sect, line) in cfg.sections_with_prefix("serve.") {
            if let Some(name) = sect.strip_prefix("tier.") {
                if name.is_empty() || name.contains('.') {
                    return Err(GavinaError::Config(format!(
                        "[serve] tier sections are [serve.tier.<name>]; got \
                         [serve.{sect}] (config line {line})"
                    )));
                }
                if !tier_names.iter().any(|n| n == name) {
                    tier_names.push(name.to_string());
                }
            } else if sect == "governor" {
                has_governor = true;
            } else if sect == "canary" {
                has_canary = true;
            } else {
                return Err(GavinaError::Config(format!(
                    "unknown section [serve.{sect}] (config line {line}; want \
                     [serve.tier.<name>], [serve.governor] or [serve.canary])"
                )));
            }
        }

        // Key inventory pass: reject unknown keys up front, collect tier
        // names (BTreeMap iteration => sorted, deterministic order).
        for (key, _) in cfg.keys_with_prefix("serve.") {
            if let Some(rest) = key.strip_prefix("tier.") {
                let Some((name, tkey)) = rest.split_once('.') else {
                    return Err(bad(
                        key,
                        format!("tier keys are [serve.tier.<name>] key = …; got '{key}'"),
                    ));
                };
                if !KNOWN_TIER.contains(&tkey) {
                    return Err(bad(
                        key,
                        format!(
                            "unknown tier key '{tkey}' for tier '{name}' (known: {})",
                            KNOWN_TIER.join(", ")
                        ),
                    ));
                }
                if !tier_names.iter().any(|n| n == name) {
                    tier_names.push(name.to_string());
                }
            } else if let Some(gkey) = key.strip_prefix("governor.") {
                if !KNOWN_GOV.contains(&gkey) {
                    return Err(bad(
                        key,
                        format!("unknown governor key '{gkey}' (known: {})", KNOWN_GOV.join(", ")),
                    ));
                }
                has_governor = true;
            } else if let Some(ckey) = key.strip_prefix("canary.") {
                if !KNOWN_CANARY.contains(&ckey) {
                    return Err(bad(
                        key,
                        format!(
                            "unknown canary key '{ckey}' (known: {})",
                            KNOWN_CANARY.join(", ")
                        ),
                    ));
                }
                has_canary = true;
            } else if !KNOWN_TOP.contains(&key) {
                return Err(bad(
                    key,
                    format!(
                        "unknown key '{key}' (known: {}; plus tier.<name>.*, governor.* \
                         and canary.*)",
                        KNOWN_TOP.join(", ")
                    ),
                ));
            }
        }

        // Typed scalar loaders (all line-numbered on failure).
        let int_ge = |key: &str, default: i64, min: i64| -> Result<i64, GavinaError> {
            match cfg.get(&format!("serve.{key}")) {
                None => Ok(default),
                Some(v) => v
                    .as_int()
                    .filter(|&i| i >= min)
                    .ok_or_else(|| bad(key, format!("'{key}' must be an integer ≥ {min}"))),
            }
        };
        let float_opt = |key: &str| -> Result<Option<f64>, GavinaError> {
            match cfg.get(&format!("serve.{key}")) {
                None => Ok(None),
                Some(v) => v
                    .as_float()
                    .map(Some)
                    .ok_or_else(|| bad(key, format!("'{key}' must be a number"))),
            }
        };
        let str_opt = |key: &str| -> Result<Option<String>, GavinaError> {
            match cfg.get(&format!("serve.{key}")) {
                None => Ok(None),
                Some(v) => v
                    .as_str()
                    .map(|s| Some(s.to_string()))
                    .ok_or_else(|| bad(key, format!("'{key}' must be a string"))),
            }
        };
        let bool_or = |key: &str, default: bool| -> Result<bool, GavinaError> {
            match cfg.get(&format!("serve.{key}")) {
                None => Ok(default),
                Some(v) => v
                    .as_bool()
                    .ok_or_else(|| bad(key, format!("'{key}' must be a boolean"))),
            }
        };

        let d = ServeOptions::default();
        let queue_depth = int_ge("queue_depth", d.queue_depth as i64, 1)? as usize;
        let steal = bool_or("steal", d.steal)?;
        let steal_reserve = int_ge("steal_reserve", d.steal_reserve as i64, 0)? as usize;
        let global_batch = int_ge("max_batch", 8, 1)? as usize;
        // Accepted for compatibility with pre-continuous-batching
        // configs: type-checked (a typo'd value still fails loudly) but
        // otherwise ignored — there are no flush windows any more.
        let _ = int_ge("batch_timeout_ms", 20, 1)?;

        let tiers = if tier_names.is_empty() {
            // No [serve.tier.*] sections: the built-in trio, with the
            // global batching bound (when given) applied to every tier —
            // including exact: per-image activation quantization keeps
            // exact-tier responses bit-identical at any batch size.
            let mut tiers = d.tiers.clone();
            if cfg.get("serve.max_batch").is_some() {
                for t in &mut tiers {
                    t.max_batch = global_batch;
                }
            }
            tiers
        } else {
            let mut tiers = Vec::with_capacity(tier_names.len());
            for name in &tier_names {
                let k = |suffix: &str| format!("tier.{name}.{suffix}");
                let pol_key = k("policy");
                let pol = str_opt(&pol_key)?.ok_or_else(|| {
                    bad(
                        &pol_key,
                        format!("tier '{name}' needs policy = \"exact|base|uniform|per_layer\""),
                    )
                })?;
                let g_key = k("g");
                let g = match cfg.get(&format!("serve.{g_key}")) {
                    None => None,
                    Some(v) => Some(
                        v.as_int().and_then(|i| u32::try_from(i).ok()).ok_or_else(|| {
                            bad(&g_key, format!("'{g_key}' must be a non-negative integer"))
                        })?,
                    ),
                };
                let lgs_key = k("layer_gs");
                let layer_gs = match cfg.get(&format!("serve.{lgs_key}")) {
                    None => None,
                    Some(Value::Array(xs)) => Some(
                        xs.iter()
                            .map(|x| x.as_int().and_then(|i| u32::try_from(i).ok()))
                            .collect::<Option<Vec<u32>>>()
                            .ok_or_else(|| {
                                bad(
                                    &lgs_key,
                                    format!("'{lgs_key}' must be an array of non-negative integers"),
                                )
                            })?,
                    ),
                    Some(_) => {
                        return Err(bad(&lgs_key, format!("'{lgs_key}' must be an array")))
                    }
                };
                let policy = match pol.as_str() {
                    "exact" => Some(GavPolicy::Exact),
                    "base" => None,
                    "uniform" => Some(GavPolicy::Uniform(g.ok_or_else(|| {
                        bad(&pol_key, format!("tier '{name}' policy \"uniform\" needs g"))
                    })?)),
                    "per_layer" => Some(GavPolicy::PerLayer(layer_gs.clone().ok_or_else(
                        || {
                            bad(
                                &pol_key,
                                format!("tier '{name}' policy \"per_layer\" needs layer_gs = [..]"),
                            )
                        },
                    )?)),
                    "ilp" => {
                        return Err(bad(
                            &pol_key,
                            format!(
                                "tier '{name}' policy \"ilp\" needs a profile set — resolve it \
                                 on the EngineBuilder and use \"base\""
                            ),
                        ))
                    }
                    other => {
                        return Err(bad(
                            &pol_key,
                            format!(
                                "tier '{name}' policy '{other}' (want exact|base|uniform|per_layer)"
                            ),
                        ))
                    }
                };
                // A G knob the chosen policy would silently drop is
                // exactly the typo class this loader exists to reject.
                if g.is_some() && pol != "uniform" {
                    return Err(bad(
                        &g_key,
                        format!("tier '{name}' sets g but policy \"{pol}\" ignores it"),
                    ));
                }
                if layer_gs.is_some() && pol != "per_layer" {
                    return Err(bad(
                        &lgs_key,
                        format!("tier '{name}' sets layer_gs but policy \"{pol}\" ignores it"),
                    ));
                }
                let max_batch = int_ge(&k("max_batch"), global_batch as i64, 1)? as usize;
                // Compatibility: type-checked, ignored (see above).
                let _ = int_ge(&k("batch_timeout_ms"), 20, 1)?;
                tiers.push(TierSpec {
                    name: name.clone(),
                    policy,
                    max_batch,
                });
            }
            tiers
        };

        // Replica resolution, after tiers so the legacy total-worker form
        // can divide by the tier count.
        let replicas = match (cfg.get("serve.replicas"), cfg.get("serve.workers")) {
            (Some(_), Some(_)) => {
                return Err(bad(
                    "replicas",
                    "set either replicas (per tier) or the legacy workers (total), not both"
                        .into(),
                ))
            }
            (Some(_), None) => int_ge("replicas", d.replicas as i64, 1)? as usize,
            (None, Some(_)) => {
                // Legacy `workers = N` was the TOTAL worker count over one
                // shared queue; spread it across the per-tier lanes.
                let workers = int_ge("workers", 2, 1)? as usize;
                workers.div_ceil(tiers.len()).max(1)
            }
            (None, None) => d.replicas,
        };

        let default_tier = match str_opt("default_tier")? {
            Some(name) => name,
            None if tiers.iter().any(|t| t.name == "guarded") => "guarded".into(),
            None => tiers[0].name.clone(),
        };

        let governor = if has_governor {
            let gd = GovernorOptions::default();
            let float_or = |key: &str, dflt: f64| -> Result<f64, GavinaError> {
                Ok(float_opt(key)?.unwrap_or(dflt))
            };
            Some(GovernorOptions {
                period: Duration::from_millis(int_ge(
                    "governor.period_ms",
                    gd.period.as_millis() as i64,
                    1,
                )? as u64),
                target_power_mw: float_opt("governor.target_power_mw")?,
                high_load: float_or("governor.high_load", gd.high_load)?,
                low_load: float_or("governor.low_load", gd.low_load)?,
                min_g: int_ge("governor.min_g", gd.min_g as i64, 0)? as u32,
            })
        } else {
            None
        };

        let canary = if has_canary {
            let cd = CanaryOptions::default();
            let float_or = |key: &str, dflt: f64| -> Result<f64, GavinaError> {
                Ok(float_opt(key)?.unwrap_or(dflt))
            };
            Some(CanaryOptions {
                sample_rate: float_or("canary.sample_rate", cd.sample_rate)?,
                window: int_ge("canary.window", cd.window as i64, 1)? as usize,
                high_watermark: float_or("canary.high_watermark", cd.high_watermark)?,
                low_watermark: float_or("canary.low_watermark", cd.low_watermark)?,
                dwell_ticks: int_ge("canary.dwell_ticks", cd.dwell_ticks as i64, 0)? as u32,
                min_samples: int_ge("canary.min_samples", cd.min_samples as i64, 1)? as usize,
            })
        } else {
            None
        };

        let opts = ServeOptions {
            replicas,
            queue_depth,
            steal,
            steal_reserve,
            default_tier,
            tiers,
            governor,
            canary,
        };
        opts.validate()?;
        Ok(opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse;

    #[test]
    fn default_options_validate() {
        let d = ServeOptions::default();
        d.validate().unwrap();
        assert_eq!(d.tiers.len(), 3);
        assert_eq!(d.tiers[0].name, "exact");
        assert_eq!(d.tiers[0].max_batch, 4, "exact batches too (per-image scales)");
        assert_eq!(d.default_tier, "guarded");
        assert_eq!(d.replicas, 2);
        assert!(d.steal);
    }

    #[test]
    fn legacy_flat_serve_section_still_loads() {
        let cfg = parse("[serve]\nworkers = 3\nmax_batch = 16\nbatch_timeout_ms = 5\n").unwrap();
        let opts = ServeOptions::from_config(&cfg).unwrap();
        // Legacy total worker count spreads across the per-tier lanes:
        // ceil(3 / 3 tiers) = 1 replica per tier.
        assert_eq!(opts.replicas, 1);
        // The global batching bound applies to every tier, exact
        // included — per-image quantization keeps it bit-identical.
        assert!(opts.tiers.iter().all(|t| t.max_batch == 16));
        assert_eq!(opts.tiers.len(), 3);
        assert!(opts.governor.is_none());
    }

    #[test]
    fn replicas_and_steal_keys_load_and_conflict_with_workers() {
        let cfg = parse("[serve]\nreplicas = 4\nsteal = false\nsteal_reserve = 0\n").unwrap();
        let opts = ServeOptions::from_config(&cfg).unwrap();
        assert_eq!(opts.replicas, 4);
        assert!(!opts.steal);
        assert_eq!(opts.steal_reserve, 0);

        let cfg = parse("[serve]\nreplicas = 4\nworkers = 2\n").unwrap();
        let err = ServeOptions::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("not both"), "{err}");

        let cfg = parse("[serve]\nsteal = 3\n").unwrap();
        let err = ServeOptions::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("'steal' must be a boolean"), "{err}");
    }

    #[test]
    fn tier_sections_build_tiers() {
        let cfg = parse(
            "[serve]\nqueue_depth = 8\ndefault_tier = \"fast\"\n\
             [serve.tier.fast]\npolicy = \"uniform\"\ng = 1\nmax_batch = 4\n\
             [serve.tier.gold]\npolicy = \"exact\"\nbatch_timeout_ms = 5\n\
             [serve.tier.own]\npolicy = \"base\"\n",
        )
        .unwrap();
        let opts = ServeOptions::from_config(&cfg).unwrap();
        assert_eq!(opts.queue_depth, 8);
        assert_eq!(opts.default_tier, "fast");
        // Sorted by name (BTreeMap order): fast, gold, own.
        assert_eq!(opts.tiers[0].name, "fast");
        assert_eq!(opts.tiers[0].policy, Some(GavPolicy::Uniform(1)));
        assert_eq!(opts.tiers[0].max_batch, 4);
        // batch_timeout_ms is tolerated (type-checked, ignored).
        assert_eq!(opts.tiers[1].policy, Some(GavPolicy::Exact));
        assert_eq!(opts.tiers[2].policy, None);
    }

    #[test]
    fn unknown_and_illtyped_keys_are_line_numbered_errors() {
        let cfg = parse("[serve]\nworkers = 2\nworkerz = 3\n").unwrap();
        let err = ServeOptions::from_config(&cfg).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown key 'workerz'"), "{msg}");
        assert!(msg.contains("line 3"), "{msg}");

        let cfg = parse("[serve.tier.fast]\npolcy = \"exact\"\n").unwrap();
        let err = ServeOptions::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("unknown tier key 'polcy'"), "{err}");
        assert!(err.contains("line 2"), "{err}");

        let cfg = parse("[serve.governor]\nperiodms = 10\n").unwrap();
        let err = ServeOptions::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("unknown governor key 'periodms'"), "{err}");

        // workers = 0 is an explicit error, not a silent default.
        let cfg = parse("[serve]\nworkers = 0\n").unwrap();
        let err = ServeOptions::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("≥ 1"), "{err}");
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn tier_policy_mismatches_are_rejected() {
        // uniform without g.
        let cfg = parse("[serve.tier.t]\npolicy = \"uniform\"\n").unwrap();
        assert!(ServeOptions::from_config(&cfg).is_err());
        // g set but ignored by the policy.
        let cfg = parse("[serve.tier.t]\npolicy = \"exact\"\ng = 2\n").unwrap();
        let err = ServeOptions::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("ignores it"), "{err}");
        // ilp tiers must go through the EngineBuilder.
        let cfg = parse("[serve.tier.t]\npolicy = \"ilp\"\n").unwrap();
        let err = ServeOptions::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("profile set"), "{err}");
        // per_layer loads its array.
        let cfg =
            parse("[serve.tier.t]\npolicy = \"per_layer\"\nlayer_gs = [1, 2, 3]\n").unwrap();
        let opts = ServeOptions::from_config(&cfg).unwrap();
        assert_eq!(opts.tiers[0].policy, Some(GavPolicy::PerLayer(vec![1, 2, 3])));
    }

    #[test]
    fn default_tier_must_exist_and_governor_loads() {
        let cfg = parse(
            "[serve]\ndefault_tier = \"nope\"\n[serve.tier.t]\npolicy = \"exact\"\n",
        )
        .unwrap();
        let err = ServeOptions::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("default_tier 'nope'"), "{err}");

        let cfg = parse(
            "[serve.governor]\nperiod_ms = 50\ntarget_power_mw = 25.0\nhigh_load = 0.8\n",
        )
        .unwrap();
        let opts = ServeOptions::from_config(&cfg).unwrap();
        let g = opts.governor.expect("governor section enables it");
        assert_eq!(g.period, Duration::from_millis(50));
        assert_eq!(g.target_power_mw, Some(25.0));
        assert!((g.high_load - 0.8).abs() < 1e-12);
        // Defaults fill the rest.
        assert!((g.low_load - GovernorOptions::default().low_load).abs() < 1e-12);
    }

    #[test]
    fn bare_sections_are_observed_not_silently_dropped() {
        // A bare [serve.governor] header enables the governor with all
        // defaults — "presence enables", even with zero keys.
        let cfg = parse("[serve.governor]\n").unwrap();
        let opts = ServeOptions::from_config(&cfg).unwrap();
        let g = opts.governor.expect("bare section enables governor");
        assert_eq!(g.period, GovernorOptions::default().period);

        // A bare tier section is a named tier missing its policy — a
        // loud error, not a silently ignored header.
        let cfg = parse("[serve.tier.fast]\n").unwrap();
        let err = ServeOptions::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("needs policy"), "{err}");

        // Typoed sub-sections are hard errors with the header line.
        let cfg = parse("[serve]\nworkers = 1\n[serve.bogus]\n").unwrap();
        let err = ServeOptions::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("unknown section [serve.bogus]"), "{err}");
        assert!(err.contains("line 3"), "{err}");
    }

    #[test]
    fn canary_section_loads_with_defaults_and_overrides() {
        // Bare header: "presence enables", all defaults.
        let cfg = parse("[serve.canary]\n").unwrap();
        let opts = ServeOptions::from_config(&cfg).unwrap();
        let c = opts.canary.expect("bare section enables canary");
        let d = CanaryOptions::default();
        assert_eq!(c.sample_rate, d.sample_rate);
        assert_eq!(c.window, d.window);
        assert_eq!(c.dwell_ticks, d.dwell_ticks);

        // Explicit keys override; defaults fill the rest.
        let cfg = parse(
            "[serve.canary]\nsample_rate = 0.2\nwindow = 32\nhigh_watermark = 0.2\n\
             low_watermark = 0.05\ndwell_ticks = 4\nmin_samples = 8\n",
        )
        .unwrap();
        let c = ServeOptions::from_config(&cfg).unwrap().canary.unwrap();
        assert!((c.sample_rate - 0.2).abs() < 1e-12);
        assert_eq!(c.window, 32);
        assert!((c.high_watermark - 0.2).abs() < 1e-12);
        assert!((c.low_watermark - 0.05).abs() < 1e-12);
        assert_eq!(c.dwell_ticks, 4);
        assert_eq!(c.min_samples, 8);

        // No section: no canary (historical governor behavior).
        let cfg = parse("[serve]\nreplicas = 1\n").unwrap();
        assert!(ServeOptions::from_config(&cfg).unwrap().canary.is_none());
    }

    #[test]
    fn canary_mistakes_are_loud_line_numbered_errors() {
        let cfg = parse("[serve.canary]\nsample_rte = 0.1\n").unwrap();
        let err = ServeOptions::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("unknown canary key 'sample_rte'"), "{err}");
        assert!(err.contains("line 2"), "{err}");

        // Out-of-range values fail CanaryOptions::validate via the same
        // from_config path.
        let cfg = parse("[serve.canary]\nsample_rate = 0.0\n").unwrap();
        let err = ServeOptions::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("sample_rate"), "{err}");

        let cfg =
            parse("[serve.canary]\nhigh_watermark = 0.01\nlow_watermark = 0.05\n").unwrap();
        let err = ServeOptions::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("low_watermark"), "{err}");

        let cfg = parse("[serve.canary]\nmin_samples = 99\nwindow = 8\n").unwrap();
        let err = ServeOptions::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("min_samples"), "{err}");
    }

    #[test]
    fn validate_catches_structural_mistakes() {
        let base = ServeOptions::default;
        assert!(ServeOptions { replicas: 0, ..base() }.validate().is_err());
        assert!(ServeOptions { queue_depth: 0, ..base() }.validate().is_err());
        assert!(ServeOptions { default_tier: "none".into(), ..base() }
            .validate()
            .is_err());
        let mut o = base();
        o.tiers.push(TierSpec::new("exact", None));
        assert!(o.validate().unwrap_err().to_string().contains("duplicate"));
        let mut o = base();
        o.tiers[1].policy = Some(GavPolicy::IlpBudget { gtar: 1.0 });
        assert!(o.validate().is_err());
    }
}
