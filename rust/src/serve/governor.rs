//! The load-adaptive undervolting governor: a serving-time control loop
//! over the paper's §IV-D flexibility knob.
//!
//! GAVINA's GAV schedule trades energy for accuracy **without touching
//! throughput** (§III: undervolted steps run at the same clock), so a
//! serving governor does not shed load by degrading G — it spends the
//! paper's flexibility where it pays: under heavy traffic (or a modeled
//! power budget) the *default* tier slides toward aggressive
//! undervolting, cutting energy per request; when load drains it climbs
//! back toward fully guarded operation.
//!
//! Mechanics: at service start the governor pre-resolves a **ladder** of
//! engine variants, one rung per G level, via
//! [`Engine::with_policy`](crate::engine::Engine::with_policy) — PR 3's
//! `Arc`-shared packed planes make each rung a schedule re-resolution,
//! never a re-pack. Rungs are *per-layer* schedules: the first and last
//! conv layers keep one extra guarded step (the classic
//! sensitive-boundary-layer guard the error tables motivate), so a rung
//! is `PerLayer([g+1, g, …, g, g+1])` rather than plain uniform G. Each
//! tick the governor samples the admission-queue load fraction, steps
//! one rung down/up past the configured thresholds, caps the result by
//! the optional [`PowerModel`]-modeled power budget, and swaps the
//! default tier's engine pointer (an `Arc` store — in-flight batches
//! finish on the old schedule). Every tick appends a [`GovernorStep`] to
//! a bounded trajectory that benches and dashboards can read back.
//!
//! PR 9 closes the loop on *measured* drift: when a
//! [`CanaryRuntime`](crate::canary::CanaryRuntime) is attached, each tick
//! first consults the canary's observed top-1 flip rate for the governed
//! tier via [`Feedback::advise`](crate::canary::Feedback::advise) +
//! [`decide`](crate::canary::decide) — drift above the high watermark
//! steps toward guarded ([`StepTrigger::Drift`]) and holds through a
//! dwell before load may re-descend ([`StepTrigger::DwellHold`]). Load
//! and the power budget keep their historical roles; every trajectory
//! entry now carries the [`StepTrigger`] that produced it.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::canary::{decide, CanaryRuntime, DriftAdvice, Feedback};
use crate::engine::{Engine, GavPolicy, GavinaError};
use crate::power::PowerModel;

pub use crate::canary::StepTrigger;

use super::Shared;

/// Governor configuration (the `[serve.governor]` section).
#[derive(Clone, Debug)]
pub struct GovernorOptions {
    /// Control-loop tick period.
    pub period: Duration,
    /// Optional modeled system-power budget [mW] for the default tier:
    /// the governor never settles on a rung whose modeled power exceeds
    /// it.
    pub target_power_mw: Option<f64>,
    /// Admission load fraction at or above which the governor steps one
    /// rung toward aggressive undervolting.
    pub high_load: f64,
    /// Load fraction at or below which it steps back toward guarded.
    pub low_load: f64,
    /// Floor for the per-layer G body (accuracy guard): the governor
    /// never undervolts below this rung.
    pub min_g: u32,
}

impl Default for GovernorOptions {
    fn default() -> Self {
        Self {
            period: Duration::from_millis(100),
            target_power_mw: None,
            high_load: 0.75,
            low_load: 0.25,
            min_g: 0,
        }
    }
}

impl GovernorOptions {
    pub(crate) fn validate(&self) -> Result<(), GavinaError> {
        if self.period.is_zero() {
            return Err(GavinaError::Config(
                "[serve.governor] period must be > 0".into(),
            ));
        }
        if !(0.0..=1.0).contains(&self.low_load)
            || !(0.0..=1.0).contains(&self.high_load)
            || self.low_load >= self.high_load
        {
            return Err(GavinaError::Config(format!(
                "[serve.governor] need 0 ≤ low_load < high_load ≤ 1 (got {} / {})",
                self.low_load, self.high_load
            )));
        }
        if let Some(p) = self.target_power_mw {
            if !p.is_finite() || p <= 0.0 {
                return Err(GavinaError::Config(format!(
                    "[serve.governor] target_power_mw {p} must be positive"
                )));
            }
        }
        Ok(())
    }
}

/// One governor tick, recorded whether or not the schedule moved.
#[derive(Clone, Debug)]
pub struct GovernorStep {
    /// Time since service start.
    pub at: Duration,
    /// Admission load fraction sampled at the tick.
    pub load: f64,
    /// The per-layer G schedule in force after the tick.
    pub layer_gs: Vec<u32>,
    /// Arithmetic mean of `layer_gs` (trajectory plots).
    pub mean_g: f64,
    /// Modeled system power of the schedule [mW].
    pub modeled_power_mw: f64,
    /// The signal that produced (or blocked) this tick's transition.
    pub trigger: StepTrigger,
}

/// Bound on the recorded trajectory: a long-running service keeps the
/// most recent ticks, O(1) memory.
const TRAJECTORY_CAP: usize = 4096;

/// One rung of the pre-resolved undervolting ladder.
pub(crate) struct Rung {
    pub(crate) engine: Arc<Engine>,
    pub(crate) layer_gs: Vec<u32>,
    pub(crate) mean_g: f64,
    pub(crate) power_mw: f64,
}

/// Pre-resolve the ladder for `base`: one rung per G level in
/// `min_g..=max_g`, sharing the base engine's packed planes.
pub(crate) fn build_ladder(
    base: &Arc<Engine>,
    opts: &GovernorOptions,
    power: &PowerModel,
) -> Result<Vec<Rung>, GavinaError> {
    let prec = base.precision();
    let max_g = prec.max_g();
    if opts.min_g > max_g {
        return Err(GavinaError::Config(format!(
            "[serve.governor] min_g {} exceeds G_max {max_g} for {prec}",
            opts.min_g
        )));
    }
    let n_layers = base.layer_gs().len();
    let mut rungs = Vec::with_capacity((max_g - opts.min_g + 1) as usize);
    for g in opts.min_g..=max_g {
        // Per-layer guard: the boundary layers (first conv, last conv)
        // keep one extra guarded step below full guarding.
        let mut gs = vec![g; n_layers];
        if g < max_g && n_layers > 0 {
            gs[0] = g + 1;
            gs[n_layers - 1] = g + 1;
        }
        let engine = if gs == base.layer_gs() {
            Arc::clone(base)
        } else {
            Arc::new(base.with_policy(GavPolicy::PerLayer(gs.clone()))?)
        };
        let mean_g = crate::arch::GavSchedule::mean_g(&gs);
        let power_mw = power.system_power_mw(&engine.effective_schedule());
        rungs.push(Rung {
            engine,
            layer_gs: gs,
            mean_g,
            power_mw,
        });
    }
    Ok(rungs)
}

/// The rung whose mean G is nearest the engine's current allocation —
/// where the governor starts.
pub(crate) fn start_rung(rungs: &[Rung], base: &Engine) -> usize {
    let mean = crate::arch::GavSchedule::mean_g(&base.layer_gs());
    let mut best = 0;
    let mut best_d = f64::INFINITY;
    for (i, r) in rungs.iter().enumerate() {
        let d = (r.mean_g - mean).abs();
        if d < best_d {
            best_d = d;
            best = i;
        }
    }
    best
}

/// The governor thread body: tick until `stop_rx` fires (or every sender
/// is gone), adapting the default tier's engine.
pub(crate) fn run(
    shared: Arc<Shared>,
    rungs: Vec<Rung>,
    opts: GovernorOptions,
    stop_rx: Receiver<()>,
    trajectory: Arc<Mutex<VecDeque<GovernorStep>>>,
    mut rung: usize,
    canary: Option<Arc<CanaryRuntime>>,
) {
    let mut fb = Feedback::new();
    loop {
        match stop_rx.recv_timeout(opts.period) {
            Ok(()) | Err(RecvTimeoutError::Disconnected) => break,
            Err(RecvTimeoutError::Timeout) => {}
        }
        let load = shared.admission.load_fraction();
        // Drift first: the canary's measured flip rate on the governed
        // tier. With canary off, `advise` degenerates to `Clear` and
        // `decide` reproduces the historical load-only law exactly.
        let advice = match &canary {
            Some(c) => fb.advise(c.tier_stats(shared.default_tier).as_ref(), c.options()),
            None => DriftAdvice::Clear,
        };
        let (mut next, mut trigger) =
            decide(rung, rungs.len(), advice, load, opts.low_load, opts.high_load);
        // The power budget stays a ceiling, not a signal: never settle on
        // a rung whose modeled power exceeds it — even one drift asked for.
        if let Some(budget) = opts.target_power_mw {
            while next > 0 && rungs[next].power_mw > budget {
                next -= 1;
                trigger = StepTrigger::PowerBudget;
            }
        }
        if next != rung {
            rung = next;
            *shared.tiers[shared.default_tier].engine.lock().unwrap() =
                Arc::clone(&rungs[rung].engine);
        }
        *shared.governor_state.lock().unwrap() = Some((rung, trigger));
        let step = GovernorStep {
            at: shared.started.elapsed(),
            load,
            layer_gs: rungs[rung].layer_gs.clone(),
            mean_g: rungs[rung].mean_g,
            modeled_power_mw: rungs[rung].power_mw,
            trigger,
        };
        let mut t = trajectory.lock().unwrap();
        if t.len() >= TRAJECTORY_CAP {
            t.pop_front();
        }
        t.push_back(step);
    }
}

/// Signal handle kept by the [`Service`](super::Service): dropping the
/// sender also stops the thread (`recv_timeout` disconnects).
pub(crate) type StopHandle = Sender<()>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchConfig, Precision};
    use crate::engine::EngineBuilder;

    fn base_engine(g: u32) -> Arc<Engine> {
        Arc::new(
            EngineBuilder::new()
                .synthetic_weights(0.125, 1)
                .precision(Precision::new(2, 2))
                .arch(ArchConfig::tiny())
                .policy(GavPolicy::Uniform(g))
                .build()
                .unwrap(),
        )
    }

    #[test]
    fn options_validation() {
        GovernorOptions::default().validate().unwrap();
        let bad = GovernorOptions {
            low_load: 0.8,
            high_load: 0.5,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = GovernorOptions {
            target_power_mw: Some(-1.0),
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = GovernorOptions {
            period: Duration::ZERO,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn ladder_spans_min_g_to_max_and_guards_boundary_layers() {
        let base = base_engine(1);
        let power = PowerModel::paper_calibrated();
        let opts = GovernorOptions::default();
        let rungs = build_ladder(&base, &opts, &power).unwrap();
        let max_g = base.precision().max_g();
        assert_eq!(rungs.len(), (max_g + 1) as usize);
        // Bottom rung: body at 0, boundary layers at 1.
        let gs0 = &rungs[0].layer_gs;
        assert_eq!(gs0[0], 1);
        assert_eq!(*gs0.last().unwrap(), 1);
        assert!(gs0[1..gs0.len() - 1].iter().all(|&g| g == 0));
        // Top rung: fully guarded everywhere.
        let top = rungs.last().unwrap();
        assert!(top.layer_gs.iter().all(|&g| g == max_g));
        // Modeled power grows monotonically with guarding.
        for w in rungs.windows(2) {
            assert!(w[0].power_mw <= w[1].power_mw + 1e-9);
        }
        // min_g floor is honored.
        let floored = build_ladder(
            &base,
            &GovernorOptions {
                min_g: 2,
                ..Default::default()
            },
            &power,
        )
        .unwrap();
        assert_eq!(floored.len(), (max_g - 1) as usize);
        assert!(floored[0].layer_gs.iter().all(|&g| g >= 2));
        // min_g beyond G_max is a config error.
        assert!(build_ladder(
            &base,
            &GovernorOptions {
                min_g: max_g + 1,
                ..Default::default()
            },
            &power,
        )
        .is_err());
    }

    #[test]
    fn start_rung_matches_base_allocation() {
        let power = PowerModel::paper_calibrated();
        let opts = GovernorOptions::default();
        let base = base_engine(2);
        let rungs = build_ladder(&base, &opts, &power).unwrap();
        // Uniform G=2 (a2w2: max_g = 3) is nearest the g=2 rung.
        assert_eq!(start_rung(&rungs, &base), 2);
        let exact = base_engine(base.precision().max_g());
        assert_eq!(start_rung(&build_ladder(&exact, &opts, &power).unwrap(), &exact), 3);
    }
}
