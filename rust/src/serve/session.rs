//! The client-facing half of the serving API: [`Session`] handles,
//! admission control, [`Ticket`]s and [`Response`]s.
//!
//! A [`Session`] is the only way requests enter the service. `submit`
//! owns everything the old `coordinator::Request` left to the client:
//! the arrival timestamp is stamped here (latency can no longer be
//! forged or skewed by the caller), the response channel is private, and
//! deadlines/cancellation ride on the returned [`Ticket`].

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::engine::GavinaError;

use super::Shared;

/// The bounded admission gate: a counting semaphore over every request
/// the service has accepted but not yet answered. When `capacity`
/// requests are in flight, [`Session::submit`] fails fast with
/// [`GavinaError::Overloaded`] instead of buffering unboundedly.
///
/// Only `submit` acquires permits. Canary re-runs deliberately sit below
/// this gate ([`Engine::canary_rerun`](crate::engine::Engine::canary_rerun)
/// executes directly, never through a `Session`), so observability can
/// never steal admission capacity from client traffic.
pub(crate) struct Admission {
    available: AtomicUsize,
    capacity: usize,
}

impl Admission {
    pub(crate) fn new(capacity: usize) -> Self {
        Self {
            available: AtomicUsize::new(capacity),
            capacity,
        }
    }

    /// Associated fn (not a method): the permit must hold its own
    /// `Arc<Admission>` so release-on-drop outlives any one holder.
    pub(crate) fn try_acquire(this: &Arc<Self>) -> Option<Permit> {
        // Relaxed: only the initial CAS guess — a stale read is corrected
        // by the compare-exchange loop. gavina-lint: allow(relaxed-order)
        let mut cur = this.available.load(Ordering::Relaxed);
        loop {
            if cur == 0 {
                return None;
            }
            match this.available.compare_exchange_weak(
                cur,
                cur - 1,
                Ordering::AcqRel,
                // Failure load only re-seeds the retry; no data is
                // published on it. gavina-lint: allow(relaxed-order)
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(Permit(Arc::clone(this))),
                Err(seen) => cur = seen,
            }
        }
    }

    fn release(&self) {
        self.available.fetch_add(1, Ordering::Release);
    }

    pub(crate) fn capacity(&self) -> usize {
        self.capacity
    }

    /// Accepted-but-unanswered requests right now.
    pub(crate) fn in_flight(&self) -> usize {
        // Relaxed: monitoring snapshot only — nothing is synchronized on
        // this read. gavina-lint: allow(relaxed-order)
        let available = self.available.load(Ordering::Relaxed);
        self.capacity.saturating_sub(available)
    }

    /// `in_flight / capacity` — the governor's load signal.
    pub(crate) fn load_fraction(&self) -> f64 {
        self.in_flight() as f64 / self.capacity.max(1) as f64
    }
}

/// RAII admission permit: released when the request it rode in on is
/// dropped — which happens on every exit path (response sent, send
/// failure, worker teardown), so capacity can never leak.
pub(crate) struct Permit(Arc<Admission>);

impl Drop for Permit {
    fn drop(&mut self) {
        self.0.release();
    }
}

/// One accepted request, internal to the serving pipeline. Clients only
/// ever see the [`Ticket`]; every field here is owned by the service.
pub(crate) struct Request {
    pub(crate) image: Vec<f32>,
    /// Stamped inside [`Session::submit`] — never client-supplied.
    pub(crate) submitted: Instant,
    /// Optional execution deadline, measured from `submitted`.
    pub(crate) deadline: Option<Duration>,
    pub(crate) cancelled: Arc<AtomicBool>,
    pub(crate) resp: Sender<Response>,
    /// Held (not read) so admission capacity frees exactly when the
    /// request leaves the pipeline.
    pub(crate) _permit: Permit,
}

/// Per-request submission options: QoS tier selection and a deadline.
///
/// ```
/// use std::time::Duration;
/// use gavina::serve::SubmitOptions;
///
/// let opts = SubmitOptions::new()
///     .tier("exact")
///     .deadline(Duration::from_millis(250));
/// ```
#[derive(Clone, Debug, Default)]
pub struct SubmitOptions {
    pub(crate) tier: Option<String>,
    pub(crate) deadline: Option<Duration>,
}

impl SubmitOptions {
    pub fn new() -> Self {
        Self::default()
    }

    /// Route the request to a named QoS tier instead of the default one.
    pub fn tier(mut self, name: &str) -> Self {
        self.tier = Some(name.to_string());
        self
    }

    /// Drop the request (with a typed [`GavinaError::DeadlineExceeded`]
    /// response) if it has not started executing within `d` of submit.
    pub fn deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }
}

/// A client handle onto a running [`Service`](super::Service). Cheap to
/// clone; hand one to every producer thread.
#[derive(Clone)]
pub struct Session {
    pub(crate) shared: Arc<Shared>,
}

impl Session {
    /// Submit one image (flat NHWC, `32·32·3` floats in `[0, 1]`) to the
    /// default QoS tier. Admission is bounded: when `queue_depth`
    /// requests are already in flight this returns
    /// [`GavinaError::Overloaded`] immediately — the service never
    /// buffers unboundedly and never silently drops an accepted request.
    ///
    /// ```
    /// use std::sync::Arc;
    /// use gavina::arch::{ArchConfig, Precision};
    /// use gavina::engine::EngineBuilder;
    /// use gavina::serve::ServeOptions;
    ///
    /// let engine = Arc::new(
    ///     EngineBuilder::new()
    ///         .synthetic_weights(0.125, 1)
    ///         .precision(Precision::new(2, 2))
    ///         .arch(ArchConfig::tiny())
    ///         .build()
    ///         .unwrap(),
    /// );
    /// let service = engine.serve(ServeOptions::default()).unwrap();
    /// let session = service.session();
    ///
    /// let ticket = session.submit(vec![0.5; 32 * 32 * 3]).unwrap();
    /// let logits = ticket.wait().unwrap().expect_logits("served");
    /// assert_eq!(logits.len(), 10);
    /// service.shutdown();
    /// ```
    pub fn submit(&self, image: Vec<f32>) -> Result<Ticket, GavinaError> {
        self.submit_with(image, SubmitOptions::default())
    }

    /// [`Session::submit`] with per-request options (tier, deadline).
    pub fn submit_with(
        &self,
        image: Vec<f32>,
        opts: SubmitOptions,
    ) -> Result<Ticket, GavinaError> {
        let tier = match &opts.tier {
            None => self.shared.default_tier,
            Some(name) => self.shared.tier_index(name).ok_or_else(|| {
                GavinaError::Config(format!(
                    "unknown QoS tier '{name}' (configured: {})",
                    self.shared.tier_names().join(", ")
                ))
            })?,
        };
        let permit = match Admission::try_acquire(&self.shared.admission) {
            Some(p) => p,
            None => {
                // Relaxed: monotonic statistics counter, read only for
                // reporting. gavina-lint: allow(relaxed-order)
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(GavinaError::Overloaded {
                    capacity: self.shared.admission.capacity(),
                });
            }
        };
        let cancelled = Arc::new(AtomicBool::new(false));
        let (resp_tx, resp_rx) = channel();
        let req = Request {
            image,
            submitted: Instant::now(),
            deadline: opts.deadline,
            cancelled: Arc::clone(&cancelled),
            resp: resp_tx,
            _permit: permit,
        };
        // The dispatch holds `closed` under the same lock as its queues,
        // so this either enqueues before shutdown's close() (and the
        // drain answers the ticket) or returns a typed error here — in
        // which case dropping `req` releases the admission permit. No
        // post-enqueue re-check is needed; the old channel-based path's
        // SeqCst race window is gone by construction.
        self.shared.dispatch.submit(tier, req)?;
        Ok(Ticket {
            rx: resp_rx,
            cancelled,
        })
    }
}

/// The handle for one accepted request: wait for the [`Response`] or
/// cancel. Dropping the ticket abandons the response (the request still
/// executes unless cancelled first).
pub struct Ticket {
    rx: Receiver<Response>,
    cancelled: Arc<AtomicBool>,
}

impl Ticket {
    /// Block until the response arrives. Errors only if the service was
    /// torn down without answering (which
    /// [`Service::shutdown`](super::Service::shutdown) never does for
    /// accepted tickets).
    pub fn wait(self) -> Result<Response, GavinaError> {
        self.rx
            .recv()
            .map_err(|_| GavinaError::Backend("serving pipeline is shut down".into()))
    }

    /// Block for at most `d`. `Ok(Some(response))` when it arrived,
    /// `Ok(None)` when the response is still pending after `d` — the
    /// ticket stays valid, poll again — and `Err` when the service was
    /// torn down without answering. A local poll timeout is deliberately
    /// *not* [`GavinaError::DeadlineExceeded`]: that variant is the
    /// service's terminal verdict on a request's submission deadline,
    /// and conflating the two would make callers abandon tickets whose
    /// response is still coming.
    pub fn wait_timeout(&self, d: Duration) -> Result<Option<Response>, GavinaError> {
        match self.rx.recv_timeout(d) {
            Ok(r) => Ok(Some(r)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                Err(GavinaError::Backend("serving pipeline is shut down".into()))
            }
        }
    }

    /// Request cancellation: if the request has not started executing,
    /// it is answered with [`GavinaError::Cancelled`] instead of running.
    /// Requests already inside a batch complete normally.
    pub fn cancel(&self) {
        // Relaxed: best-effort flag — a batch that misses the store runs
        // the request normally, which the cancellation contract allows.
        // gavina-lint: allow(relaxed-order)
        self.cancelled.store(true, Ordering::Relaxed);
    }
}

/// The response to one request: class logits (or a typed error) plus
/// tracing info. Internals are private — latency and batch size are
/// measured by the service, never client-assembled.
#[derive(Clone, Debug)]
pub struct Response {
    result: Result<Vec<f32>, GavinaError>,
    latency: Duration,
    batch_size: usize,
    tier: Arc<str>,
}

impl Response {
    pub(crate) fn new(
        result: Result<Vec<f32>, GavinaError>,
        latency: Duration,
        batch_size: usize,
        tier: Arc<str>,
    ) -> Self {
        Self {
            result,
            latency,
            batch_size,
            tier,
        }
    }

    /// Logits on success; the typed error otherwise.
    pub fn result(&self) -> Result<&[f32], &GavinaError> {
        match &self.result {
            Ok(l) => Ok(l.as_slice()),
            Err(e) => Err(e),
        }
    }

    /// Consume into the owned result.
    pub fn into_result(self) -> Result<Vec<f32>, GavinaError> {
        self.result
    }

    /// Whether the request produced logits.
    pub fn is_ok(&self) -> bool {
        self.result.is_ok()
    }

    /// End-to-end latency, submit (service-stamped) → response.
    pub fn latency(&self) -> Duration {
        self.latency
    }

    /// How many requests executed in this response's physical batch.
    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// The QoS tier that served this request.
    pub fn tier(&self) -> &str {
        &self.tier
    }

    /// The logits, or a panic with the typed error (tests / demos).
    pub fn expect_logits(self, msg: &str) -> Vec<f32> {
        match self.result {
            Ok(l) => l,
            Err(e) => panic!("{msg}: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_is_a_counting_semaphore() {
        let adm = Arc::new(Admission::new(2));
        assert_eq!(adm.capacity(), 2);
        assert_eq!(adm.in_flight(), 0);
        let p1 = Admission::try_acquire(&adm).expect("first permit");
        let p2 = Admission::try_acquire(&adm).expect("second permit");
        assert_eq!(adm.in_flight(), 2);
        assert!((adm.load_fraction() - 1.0).abs() < 1e-12);
        assert!(Admission::try_acquire(&adm).is_none(), "capacity exhausted");
        drop(p1);
        assert_eq!(adm.in_flight(), 1);
        let p3 = Admission::try_acquire(&adm).expect("freed capacity is reusable");
        drop(p2);
        drop(p3);
        assert_eq!(adm.in_flight(), 0);
    }

    #[test]
    fn admission_survives_concurrent_acquire_release_storms() {
        // Hammer the compare-exchange loop from many threads (this also
        // runs under the CI ThreadSanitizer job): capacity must never be
        // oversubscribed while permits churn, and every dropped permit
        // must return its slot.
        const THREADS: usize = 8;
        const ROUNDS: usize = 500;
        let adm = Arc::new(Admission::new(3));
        let granted = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..THREADS {
            let adm = Arc::clone(&adm);
            let granted = Arc::clone(&granted);
            handles.push(std::thread::spawn(move || {
                for _ in 0..ROUNDS {
                    let Some(permit) = Admission::try_acquire(&adm) else {
                        std::hint::spin_loop();
                        continue;
                    };
                    granted.fetch_add(1, Ordering::SeqCst);
                    // We hold one permit, so the gate is neither empty
                    // nor past its capacity.
                    let seen = adm.in_flight();
                    assert!((1..=adm.capacity()).contains(&seen));
                    drop(permit);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(adm.in_flight(), 0, "every permit must release on drop");
        assert!(granted.load(Ordering::SeqCst) > 0, "some acquires must win");
    }

    #[test]
    fn submit_options_builder() {
        let o = SubmitOptions::new()
            .tier("exact")
            .deadline(Duration::from_millis(5));
        assert_eq!(o.tier.as_deref(), Some("exact"));
        assert_eq!(o.deadline, Some(Duration::from_millis(5)));
        let d = SubmitOptions::default();
        assert!(d.tier.is_none() && d.deadline.is_none());
    }
}
