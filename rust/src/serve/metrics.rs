//! Per-tier serving metrics: lock-free counters, a bounded latency
//! reservoir, and the plain-data [`MetricsSnapshot`] the public API hands
//! out.
//!
//! Every atomic in this module is a monotonic statistics counter that is
//! only ever read to build a snapshot — no control flow or data is
//! synchronized on these values, so relaxed ordering is correct
//! file-wide. gavina-lint: allow(relaxed-order)

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::arch::{GavSchedule, Precision};
use crate::canary::StepTrigger;
use crate::power::PowerModel;

/// Latency reservoir capacity: percentiles are computed over a uniform
/// sample of at most this many observations, so a long-running service
/// holds O(1) memory instead of one `u64` per request ever served.
pub(crate) const LATENCY_RESERVOIR: usize = 4096;

/// Uniform reservoir sample of latency observations (Vitter's Algorithm
/// R with a cheap xorshift index source — metrics, not cryptography).
pub(crate) struct Reservoir {
    pub(crate) buf: Vec<u64>,
    pub(crate) seen: u64,
    rng: u64,
}

impl Reservoir {
    pub(crate) fn new() -> Self {
        Self {
            buf: Vec::new(),
            seen: 0,
            rng: 0x9E37_79B9_7F4A_7C15,
        }
    }

    pub(crate) fn push(&mut self, v: u64) {
        self.seen += 1;
        if self.buf.len() < LATENCY_RESERVOIR {
            self.buf.push(v);
            return;
        }
        self.rng ^= self.rng << 13;
        self.rng ^= self.rng >> 7;
        self.rng ^= self.rng << 17;
        let j = self.rng % self.seen;
        if (j as usize) < LATENCY_RESERVOIR {
            self.buf[j as usize] = v;
        }
    }
}

/// Aggregated metrics of one QoS tier (internal: the public view is
/// [`MetricsSnapshot`]).
pub(crate) struct TierMetrics {
    requests: AtomicU64,
    batches: AtomicU64,
    /// Requests answered with an error `Response` (bad shape, missed
    /// deadline, backend failure) — cancellations are counted separately.
    errors: AtomicU64,
    cancelled: AtomicU64,
    sim_cycles: AtomicU64,
    corrupted: AtomicU64,
    /// Batches of this tier's work executed by a foreign tier's idle
    /// replica (work-stealing).
    stolen: AtomicU64,
    /// Wall-clock microseconds any worker spent executing this tier's
    /// batches (its own replicas *and* thieves).
    busy_us: AtomicU64,
    latencies_us: Mutex<Reservoir>,
    /// Running true maximum — the one statistic a uniform reservoir
    /// systematically loses once eviction starts.
    max_latency_us: AtomicU64,
    started: Instant,
    last_record: Mutex<Option<Instant>>,
}

impl TierMetrics {
    pub(crate) fn new(started: Instant) -> Self {
        Self {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            sim_cycles: AtomicU64::new(0),
            corrupted: AtomicU64::new(0),
            stolen: AtomicU64::new(0),
            busy_us: AtomicU64::new(0),
            latencies_us: Mutex::new(Reservoir::new()),
            max_latency_us: AtomicU64::new(0),
            started,
            last_record: Mutex::new(None),
        }
    }

    pub(crate) fn record(&self, n_req: usize, lat: &[Duration], cycles: u64, corrupted: u64) {
        self.requests.fetch_add(n_req as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.sim_cycles.fetch_add(cycles, Ordering::Relaxed);
        self.corrupted.fetch_add(corrupted, Ordering::Relaxed);
        {
            let mut l = self.latencies_us.lock().unwrap();
            for d in lat {
                let us = d.as_micros() as u64;
                self.max_latency_us.fetch_max(us, Ordering::Relaxed);
                l.push(us);
            }
        }
        *self.last_record.lock().unwrap() = Some(Instant::now());
    }

    pub(crate) fn record_errors(&self, n: usize) {
        self.errors.fetch_add(n as u64, Ordering::Relaxed);
        *self.last_record.lock().unwrap() = Some(Instant::now());
    }

    pub(crate) fn record_cancelled(&self, n: usize) {
        self.cancelled.fetch_add(n as u64, Ordering::Relaxed);
        *self.last_record.lock().unwrap() = Some(Instant::now());
    }

    /// One batch of this tier's work was claimed by a foreign tier's
    /// idle replica.
    pub(crate) fn record_steal(&self) {
        self.stolen.fetch_add(1, Ordering::Relaxed);
    }

    /// A worker spent `d` executing one of this tier's batches.
    pub(crate) fn record_busy(&self, d: Duration) {
        self.busy_us.fetch_add(d.as_micros() as u64, Ordering::Relaxed);
    }

    /// A consistent-enough point-in-time copy (counters are relaxed; the
    /// percentiles come from the bounded reservoir, the max is exact).
    /// `layer_gs` is the tier's schedule at snapshot time,
    /// `replica_queue_depths` its per-lane queue depths, `replicas` the
    /// configured lanes per tier (for the occupancy denominator).
    /// `governor` is the governor's `(rung, trigger)` state when this
    /// tier is the governed one, `None` otherwise.
    pub(crate) fn snapshot(
        &self,
        tier: &str,
        layer_gs: Vec<u32>,
        replica_queue_depths: Vec<usize>,
        replicas: usize,
        governor: Option<(usize, StepTrigger)>,
    ) -> MetricsSnapshot {
        let mut lat = self.latencies_us.lock().unwrap().buf.clone();
        lat.sort_unstable();
        let pick = |q: f64| -> u64 {
            if lat.is_empty() {
                0
            } else {
                lat[((lat.len() - 1) as f64 * q) as usize]
            }
        };
        let requests = self.requests.load(Ordering::Relaxed);
        let requests_per_sec = match *self.last_record.lock().unwrap() {
            Some(t) => {
                let secs = t.duration_since(self.started).as_secs_f64();
                if secs > 0.0 {
                    requests as f64 / secs
                } else {
                    0.0
                }
            }
            None => 0.0,
        };
        let elapsed_us = self.started.elapsed().as_micros() as u64;
        let occupancy = if elapsed_us > 0 && replicas > 0 {
            self.busy_us.load(Ordering::Relaxed) as f64
                / (elapsed_us as f64 * replicas as f64)
        } else {
            0.0
        };
        MetricsSnapshot {
            tier: tier.to_string(),
            layer_gs,
            requests,
            batches: self.batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            sim_cycles: self.sim_cycles.load(Ordering::Relaxed),
            corrupted: self.corrupted.load(Ordering::Relaxed),
            steals: self.stolen.load(Ordering::Relaxed),
            queue_depth: replica_queue_depths.iter().sum(),
            replica_queue_depths,
            occupancy,
            p50_us: pick(0.50),
            p95_us: pick(0.95),
            p99_us: pick(0.99),
            max_us: self.max_latency_us.load(Ordering::Relaxed),
            requests_per_sec,
            governor_rung: governor.map(|(r, _)| r),
            governor_trigger: governor.map(|(_, t)| t),
        }
    }
}

/// Point-in-time metrics of one QoS tier: plain data, safe to hold after
/// the service is gone. Produced by
/// [`Service::metrics`](super::Service::metrics) and
/// [`Service::shutdown`](super::Service::shutdown).
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Tier name (`exact`, `guarded`, …).
    pub tier: String,
    /// The per-layer G schedule the tier was running at snapshot time
    /// (for a governed tier this moves over the service's lifetime).
    pub layer_gs: Vec<u32>,
    /// Requests answered with logits.
    pub requests: u64,
    /// Physical batches executed.
    pub batches: u64,
    /// Requests answered with an error `Response` (bad shape, missed
    /// deadline, backend failure).
    pub errors: u64,
    /// Requests cancelled via their ticket before execution.
    pub cancelled: u64,
    /// Accelerator cycles simulated for this tier's traffic.
    pub sim_cycles: u64,
    /// Undervolting-corrupted values injected into this tier's traffic.
    pub corrupted: u64,
    /// Batches of this tier's work executed by a foreign tier's idle
    /// replica (work-stealing).
    pub steals: u64,
    /// Requests queued for this tier right now, summed over its lanes.
    pub queue_depth: usize,
    /// Per-replica-lane queue depths at snapshot time.
    pub replica_queue_depths: Vec<usize>,
    /// Busy time of this tier's batches over `replicas × wall-clock`.
    /// Can exceed 1.0 when foreign thieves execute this tier's backlog
    /// on top of its own replicas.
    pub occupancy: f64,
    /// End-to-end latency percentiles over a bounded reservoir [µs].
    pub p50_us: u64,
    /// 95th percentile latency [µs].
    pub p95_us: u64,
    /// 99th percentile latency [µs].
    pub p99_us: u64,
    /// Exact running maximum latency [µs].
    pub max_us: u64,
    /// Served requests per second, service start → last recorded batch.
    pub requests_per_sec: f64,
    /// The governor's current ladder rung (0 = most aggressive), when
    /// this tier is the governed default tier and the governor has
    /// ticked at least once.
    pub governor_rung: Option<usize>,
    /// The signal behind the governor's latest transition (or hold) —
    /// see [`StepTrigger`].
    pub governor_trigger: Option<StepTrigger>,
}

impl MetricsSnapshot {
    /// The uniform-G schedule representing this tier's allocation at
    /// snapshot time ([`GavSchedule::representative`] over
    /// [`MetricsSnapshot::layer_gs`]). `prec` is the serving engine's
    /// precision.
    pub fn effective_schedule(&self, prec: Precision) -> GavSchedule {
        GavSchedule::representative(prec, &self.layer_gs)
    }

    /// Accelerator-side energy for this tier's served traffic [mJ],
    /// modelled on the given schedule — typically
    /// [`MetricsSnapshot::effective_schedule`], i.e. *this tier's* own
    /// allocation, not the base engine's.
    pub fn energy_mj(&self, power: &PowerModel, sched: &GavSchedule) -> f64 {
        power.energy_mj(sched, self.sim_cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reservoir_bounds_memory_and_keeps_percentiles_sane() {
        let mut r = Reservoir::new();
        for i in 0..(LATENCY_RESERVOIR as u64 * 4) {
            r.push(i);
        }
        assert_eq!(r.buf.len(), LATENCY_RESERVOIR);
        assert_eq!(r.seen, LATENCY_RESERVOIR as u64 * 4);
        // The sample must span the observed range, not just the prefix.
        assert!(r.buf.iter().any(|&v| v >= LATENCY_RESERVOIR as u64));
    }

    #[test]
    fn snapshot_orders_percentiles() {
        let m = TierMetrics::new(Instant::now());
        let lats: Vec<Duration> = (1..=100).map(Duration::from_millis).collect();
        m.record(100, &lats, 1234, 5);
        m.record_errors(2);
        m.record_cancelled(1);
        m.record_steal();
        m.record_steal();
        m.record_busy(Duration::from_millis(3));
        let s = m.snapshot("t", vec![2; 4], vec![1, 0, 2], 3, Some((1, StepTrigger::Drift)));
        assert_eq!(s.tier, "t");
        assert_eq!(s.governor_rung, Some(1));
        assert_eq!(s.governor_trigger, Some(StepTrigger::Drift));
        // The snapshot's energy schedule is the tier's own allocation.
        assert_eq!(
            s.effective_schedule(Precision::new(2, 2)).g(),
            Some(2),
            "representative schedule must come from the tier's layer_gs"
        );
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 1);
        assert_eq!(s.errors, 2);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.sim_cycles, 1234);
        assert_eq!(s.corrupted, 5);
        assert_eq!(s.steals, 2);
        assert_eq!(s.queue_depth, 3, "lane depths sum");
        assert_eq!(s.replica_queue_depths, vec![1, 0, 2]);
        assert!(s.occupancy > 0.0, "recorded busy time must show up");
        assert!(s.p50_us > 0 && s.p50_us <= s.p95_us);
        assert!(s.p95_us <= s.p99_us && s.p99_us <= s.max_us);
        assert_eq!(s.max_us, 100_000);
        assert!(s.requests_per_sec > 0.0);
    }

    #[test]
    fn empty_snapshot_is_all_zero() {
        let s = TierMetrics::new(Instant::now()).snapshot("idle", Vec::new(), vec![0, 0], 2, None);
        assert_eq!(s.requests, 0);
        assert_eq!(s.governor_rung, None);
        assert_eq!(s.governor_trigger, None);
        assert_eq!((s.p50_us, s.p99_us, s.max_us), (0, 0, 0));
        assert_eq!(s.requests_per_sec, 0.0);
        assert_eq!(s.steals, 0);
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.occupancy, 0.0);
    }
}
