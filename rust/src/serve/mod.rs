//! `gavina::serve` — the QoS serving layer: bounded admission, per-request
//! energy tiers, and a load-adaptive undervolting governor.
//!
//! This module replaces the old `coordinator`'s ad-hoc types (public
//! `Request` fields, client-stamped timestamps, an unbounded queue and
//! one global policy frozen at build) with a typed serving surface:
//!
//! ```text
//! Session::submit ──▶ bounded admission ──▶ batcher ──▶ worker pool ──▶ Ticket
//!   (tier, deadline,    (queue_depth;        (per-tier    (N threads; each
//!    cancellation)       Overloaded when      batches)     batch runs its
//!                        full)                             tier's Engine)
//!                                        governor thread ──┘
//!                                        (adapts the default tier's
//!                                         per-layer G under load)
//! ```
//!
//! * [`Session`] — the only way in. `submit(image) -> Ticket` stamps the
//!   arrival time service-side, owns the response channel, and carries
//!   deadline + cancellation on the [`Ticket`].
//! * **Bounded admission** — at `queue_depth` in-flight requests,
//!   `submit` fails fast with [`GavinaError::Overloaded`]; the service
//!   backpressures instead of buffering unboundedly, and never silently
//!   drops an accepted request.
//! * [`TierSpec`] **QoS tiers** — each tier maps to a pre-resolved
//!   engine variant (`Engine::with_policy`, sharing packed planes) with
//!   its own batching and [`MetricsSnapshot`]. The `exact` tier runs
//!   `max_batch = 1`, making its logits bit-identical to a standalone
//!   [`Engine::infer`](crate::engine::Engine::infer).
//! * [`GovernorOptions`] **governor** — a control loop that slides the
//!   default tier along a pre-resolved per-layer-G ladder under observed
//!   load or a modeled power budget, recording a [`GovernorStep`]
//!   trajectory.
//!
//! Start a service with [`Engine::serve`](crate::engine::Engine::serve)
//! or [`Service::start`]; stop it with [`Service::shutdown`], which
//! drains every accepted ticket before returning the final
//! [`ServeReport`].

mod governor;
mod metrics;
mod session;
mod tier;

pub use governor::{GovernorOptions, GovernorStep};
pub use metrics::MetricsSnapshot;
pub use session::{Response, Session, SubmitOptions, Ticket};
pub use tier::{ServeOptions, TierSpec};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::dnn::IMAGE_LEN;
use crate::engine::{Engine, GavinaError};
use crate::power::PowerModel;

use metrics::TierMetrics;
use session::{Admission, Request};

/// Messages into the batcher thread.
pub(crate) enum Msg {
    /// `(tier index, request)`.
    Req(usize, Request),
    Shutdown,
}

/// Sentinel tier index the batcher sends to poison one worker.
const POISON: usize = usize::MAX;

/// One tier at runtime: its (swappable) engine, batching knobs, metrics.
pub(crate) struct TierRuntime {
    pub(crate) name: Arc<str>,
    /// Swapped by the governor (default tier only); workers clone the
    /// `Arc` per batch, so in-flight batches finish on the old schedule.
    pub(crate) engine: Mutex<Arc<Engine>>,
    pub(crate) max_batch: usize,
    pub(crate) batch_timeout: Duration,
    pub(crate) metrics: TierMetrics,
}

/// State shared by sessions, batcher, workers and the governor.
pub(crate) struct Shared {
    pub(crate) admission: Arc<Admission>,
    pub(crate) tiers: Vec<TierRuntime>,
    pub(crate) default_tier: usize,
    /// Submissions rejected at admission ([`GavinaError::Overloaded`]).
    pub(crate) rejected: AtomicU64,
    /// Set (SeqCst) *before* the `Shutdown` message is sent, and
    /// re-checked by `submit` *after* its own send: a submit that
    /// observes `closed == false` post-send is guaranteed FIFO-ahead of
    /// the `Shutdown` message, so every `Ok` ticket really is drained.
    pub(crate) closed: AtomicBool,
    pub(crate) started: Instant,
}

impl Shared {
    pub(crate) fn tier_index(&self, name: &str) -> Option<usize> {
        self.tiers.iter().position(|t| &*t.name == name)
    }

    pub(crate) fn tier_names(&self) -> Vec<String> {
        self.tiers.iter().map(|t| t.name.to_string()).collect()
    }
}

/// The final report [`Service::shutdown`] returns: per-tier metrics, the
/// admission-rejection count, and the governor's recorded trajectory.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// One snapshot per configured tier, in tier order.
    pub tiers: Vec<MetricsSnapshot>,
    /// Submissions rejected with [`GavinaError::Overloaded`].
    pub rejected: u64,
    /// Governor ticks (empty when the governor was off).
    pub governor: Vec<GovernorStep>,
}

impl ServeReport {
    /// The snapshot for a named tier.
    pub fn tier(&self, name: &str) -> Option<&MetricsSnapshot> {
        self.tiers.iter().find(|t| t.tier == name)
    }

    /// Total requests served across tiers.
    pub fn requests(&self) -> u64 {
        self.tiers.iter().map(|t| t.requests).sum()
    }
}

/// The running service: batcher + worker pool + optional governor over a
/// shared [`Engine`]. Create client handles with [`Service::session`].
pub struct Service {
    tx: Sender<Msg>,
    shared: Arc<Shared>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    governor: Option<(governor::StopHandle, std::thread::JoinHandle<()>)>,
    trajectory: Arc<Mutex<std::collections::VecDeque<GovernorStep>>>,
}

impl Service {
    /// Validate `opts`, pre-resolve every tier's engine variant (and the
    /// governor's ladder), and start the batcher + worker pool (also
    /// reachable as [`Engine::serve`](crate::engine::Engine::serve)).
    pub fn start(engine: Arc<Engine>, opts: ServeOptions) -> Result<Self, GavinaError> {
        opts.validate()?;
        let started = Instant::now();
        let mut tiers = Vec::with_capacity(opts.tiers.len());
        for spec in &opts.tiers {
            let tier_engine = match &spec.policy {
                None => Arc::clone(&engine),
                Some(p) if p == engine.policy() => Arc::clone(&engine),
                // Re-resolves the schedules only; packed planes are
                // shared with the base engine (PR 3).
                Some(p) => Arc::new(engine.with_policy(p.clone())?),
            };
            tiers.push(TierRuntime {
                name: Arc::from(spec.name.as_str()),
                engine: Mutex::new(tier_engine),
                max_batch: spec.max_batch,
                batch_timeout: spec.batch_timeout,
                metrics: TierMetrics::new(started),
            });
        }
        let default_tier = opts
            .tiers
            .iter()
            .position(|t| t.name == opts.default_tier)
            .expect("validated: default_tier exists");
        let shared = Arc::new(Shared {
            admission: Arc::new(Admission::new(opts.queue_depth)),
            tiers,
            default_tier,
            rejected: AtomicU64::new(0),
            closed: AtomicBool::new(false),
            started,
        });

        // Resolve the governor's ladder before any thread spawns, so a
        // bad governor config fails fast with nothing to tear down.
        let ladder = match &opts.governor {
            None => None,
            Some(gopts) => {
                let base = Arc::clone(&shared.tiers[default_tier].engine.lock().unwrap());
                let power = PowerModel::paper_calibrated();
                let rungs = governor::build_ladder(&base, gopts, &power)?;
                let rung0 = governor::start_rung(&rungs, &base);
                Some((gopts.clone(), rungs, rung0))
            }
        };

        let (tx, rx) = channel::<Msg>();
        let (work_tx, work_rx) = channel::<(usize, Vec<Request>)>();
        let work_rx = Arc::new(Mutex::new(work_rx));

        let mut workers = Vec::with_capacity(opts.workers);
        for wi in 0..opts.workers {
            let shared = Arc::clone(&shared);
            let work_rx = Arc::clone(&work_rx);
            workers.push(std::thread::spawn(move || {
                loop {
                    let msg = { work_rx.lock().unwrap().recv() };
                    let Ok((ti, batch)) = msg else { break };
                    if ti == POISON {
                        break;
                    }
                    run_batch(&shared, ti, wi as u64, batch);
                }
            }));
        }

        let batcher_shared = Arc::clone(&shared);
        let n_workers = opts.workers;
        let batcher = std::thread::spawn(move || {
            batcher_loop(rx, work_tx, &batcher_shared, n_workers);
        });

        let trajectory = Arc::new(Mutex::new(std::collections::VecDeque::new()));
        let governor = ladder.map(|(g_opts, rungs, rung0)| {
            let (stop_tx, stop_rx) = channel::<()>();
            let g_shared = Arc::clone(&shared);
            let g_traj = Arc::clone(&trajectory);
            let handle = std::thread::spawn(move || {
                governor::run(g_shared, rungs, g_opts, stop_rx, g_traj, rung0);
            });
            (stop_tx, handle)
        });

        Ok(Self {
            tx,
            shared,
            batcher: Some(batcher),
            workers,
            governor,
            trajectory,
        })
    }

    /// A client handle (cheap to clone, one per producer thread).
    pub fn session(&self) -> Session {
        Session {
            tx: self.tx.clone(),
            shared: Arc::clone(&self.shared),
        }
    }

    /// Point-in-time metrics for every tier, in tier order.
    pub fn metrics(&self) -> Vec<MetricsSnapshot> {
        self.shared
            .tiers
            .iter()
            .map(|t| t.metrics.snapshot(&t.name, t.engine.lock().unwrap().layer_gs()))
            .collect()
    }

    /// Point-in-time metrics for one named tier.
    pub fn tier_metrics(&self, name: &str) -> Option<MetricsSnapshot> {
        self.shared.tier_index(name).map(|i| {
            let t = &self.shared.tiers[i];
            t.metrics.snapshot(name, t.engine.lock().unwrap().layer_gs())
        })
    }

    /// Submissions rejected at admission so far.
    pub fn rejected(&self) -> u64 {
        // Relaxed: monotonic statistics counter, reporting only.
        // gavina-lint: allow(relaxed-order)
        self.shared.rejected.load(Ordering::Relaxed)
    }

    /// Accepted-but-unanswered requests right now.
    pub fn in_flight(&self) -> usize {
        self.shared.admission.in_flight()
    }

    /// The governor trajectory recorded so far (empty when off). This
    /// deep-clones the bounded trajectory — for cheap polling (progress
    /// displays, load generators) use [`Service::governor_ticks`].
    pub fn governor_trajectory(&self) -> Vec<GovernorStep> {
        self.trajectory.lock().unwrap().iter().cloned().collect()
    }

    /// How many governor ticks are currently retained — an O(1) read
    /// for cheap polling (saturates at the trajectory's 4096-step
    /// retention bound, like the history itself).
    pub fn governor_ticks(&self) -> usize {
        self.trajectory.lock().unwrap().len()
    }

    /// The per-layer G schedule a tier is currently running.
    pub fn tier_layer_gs(&self, name: &str) -> Option<Vec<u32>> {
        self.shared
            .tier_index(name)
            .map(|i| self.shared.tiers[i].engine.lock().unwrap().layer_gs())
    }

    /// Stop the governor, drain **every accepted ticket** (pending
    /// batches are flushed and executed, never dropped), join all
    /// threads, and return the final [`ServeReport`].
    pub fn shutdown(mut self) -> ServeReport {
        if let Some((stop, handle)) = self.governor.take() {
            let _ = stop.send(());
            let _ = handle.join();
        }
        // Order matters: close admission-for-new-submits *before* the
        // Shutdown message, so `Session::submit`'s post-send re-check
        // can never hand out a ticket the batcher won't see.
        self.shared.closed.store(true, Ordering::SeqCst);
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        ServeReport {
            tiers: self.metrics(),
            rejected: self.rejected(),
            governor: self.governor_trajectory(),
        }
    }
}

/// The batcher thread: groups requests into per-tier batches bounded by
/// each tier's `max_batch` / `batch_timeout`, because the accelerator
/// amortizes its A0/B0 plane streams over the `L` dimension.
fn batcher_loop(
    rx: Receiver<Msg>,
    work_tx: Sender<(usize, Vec<Request>)>,
    shared: &Shared,
    workers: usize,
) {
    let n_tiers = shared.tiers.len();
    let mut pending: Vec<Vec<Request>> = (0..n_tiers).map(|_| Vec::new()).collect();
    let mut deadlines: Vec<Option<Instant>> = vec![None; n_tiers];
    loop {
        let timeout = deadlines
            .iter()
            .flatten()
            .min()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_secs(3600));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Req(ti, r)) => {
                if pending[ti].is_empty() {
                    deadlines[ti] = Some(Instant::now() + shared.tiers[ti].batch_timeout);
                }
                pending[ti].push(r);
                if pending[ti].len() >= shared.tiers[ti].max_batch {
                    let _ = work_tx.send((ti, std::mem::take(&mut pending[ti])));
                    deadlines[ti] = None;
                }
            }
            Ok(Msg::Shutdown) => {
                // Accepted tickets racing shutdown: pull everything that
                // already made it into the channel before draining.
                while let Ok(msg) = rx.try_recv() {
                    if let Msg::Req(ti, r) = msg {
                        pending[ti].push(r);
                    }
                }
                for (ti, batch) in pending.iter_mut().enumerate() {
                    if !batch.is_empty() {
                        let _ = work_tx.send((ti, std::mem::take(batch)));
                    }
                }
                // Poison the pool: one sentinel per worker, FIFO-after
                // the flushed batches, so every batch executes first.
                for _ in 0..workers {
                    let _ = work_tx.send((POISON, Vec::new()));
                }
                break;
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        // Sweep expired partial batches after *every* wakeup, not just
        // on recv timeouts — with continuous traffic to other tiers,
        // recv_timeout keeps returning messages and the timeout arm
        // alone would starve an expired tier's flush indefinitely.
        let now = Instant::now();
        for ti in 0..n_tiers {
            if deadlines[ti].is_some_and(|d| d <= now) {
                if !pending[ti].is_empty() {
                    let _ = work_tx.send((ti, std::mem::take(&mut pending[ti])));
                }
                deadlines[ti] = None;
            }
        }
    }
}

/// Answer one request: the admission permit is released *before* the
/// response is sent, so a client that resubmits the moment its response
/// arrives is guaranteed a free slot (no spurious `Overloaded`).
/// Returns the end-to-end latency.
fn respond(
    r: Request,
    result: Result<Vec<f32>, GavinaError>,
    batch_size: usize,
    tier: &Arc<str>,
) -> Duration {
    let Request {
        submitted,
        resp,
        _permit: permit,
        ..
    } = r;
    let latency = submitted.elapsed();
    drop(permit);
    let _ = resp.send(Response::new(result, latency, batch_size, Arc::clone(tier)));
    latency
}

/// Execute one tier batch on a worker thread. Cancelled, deadline-missed
/// and malformed requests get per-request error [`Response`]s and never
/// reach the executor; the rest proceed. Worker threads must survive
/// arbitrary client input.
fn run_batch(shared: &Shared, ti: usize, worker_id: u64, batch: Vec<Request>) {
    let tier = &shared.tiers[ti];
    let engine = { Arc::clone(&tier.engine.lock().unwrap()) };

    let mut good: Vec<Request> = Vec::with_capacity(batch.len());
    let mut dropped: Vec<(Request, GavinaError)> = Vec::new();
    for r in batch {
        // Relaxed: best-effort cancellation flag — a missed store just
        // runs the request normally. gavina-lint: allow(relaxed-order)
        if r.cancelled.load(Ordering::Relaxed) {
            dropped.push((r, GavinaError::Cancelled));
        } else if r
            .deadline
            .is_some_and(|d| r.submitted.elapsed() > d)
        {
            let waited_ms = r.submitted.elapsed().as_millis() as u64;
            dropped.push((r, GavinaError::DeadlineExceeded { waited_ms }));
        } else if r.image.len() != IMAGE_LEN {
            let got = r.image.len();
            dropped.push((
                r,
                GavinaError::Shape {
                    what: "request image".into(),
                    expected: IMAGE_LEN,
                    got,
                },
            ));
        } else {
            good.push(r);
        }
    }
    // Every response from one physical batch reports the same
    // batch_size: the number of requests that actually executed.
    let n = good.len();
    let mut cancelled = 0usize;
    let mut errors = 0usize;
    for (r, e) in dropped {
        if matches!(e, GavinaError::Cancelled) {
            cancelled += 1;
        } else {
            errors += 1;
        }
        respond(r, Err(e), n, &tier.name);
    }
    if cancelled > 0 {
        tier.metrics.record_cancelled(cancelled);
    }
    if errors > 0 {
        tier.metrics.record_errors(errors);
    }
    if good.is_empty() {
        return;
    }

    let mut images = Vec::with_capacity(n * IMAGE_LEN);
    for r in &good {
        images.extend_from_slice(&r.image);
    }
    match engine.infer_parallel(&images, n, worker_id.wrapping_mul(0xD1F)) {
        Ok(result) => {
            let classes = result.classes;
            let mut lats = Vec::with_capacity(n);
            for (i, r) in good.into_iter().enumerate() {
                lats.push(respond(
                    r,
                    Ok(result.logits[i * classes..(i + 1) * classes].to_vec()),
                    n,
                    &tier.name,
                ));
            }
            tier.metrics
                .record(n, &lats, result.stats.cycles, result.stats.corrupted);
        }
        Err(e) => {
            // Shouldn't happen (shapes were validated above), but a
            // failing backend must not kill the worker either.
            tier.metrics.record_errors(n);
            for r in good {
                respond(r, Err(e.clone()), n, &tier.name);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchConfig, Precision};
    use crate::engine::{EngineBuilder, GavPolicy};
    use crate::util::Prng;

    fn small_engine(threads: usize) -> Arc<Engine> {
        Arc::new(
            EngineBuilder::new()
                .synthetic_weights(0.125, 1)
                .precision(Precision::new(2, 2))
                .arch(ArchConfig::tiny())
                .policy(GavPolicy::Exact)
                .seed(1)
                .threads(threads)
                .build()
                .unwrap(),
        )
    }

    fn one_tier_opts(max_batch: usize, timeout: Duration) -> ServeOptions {
        ServeOptions {
            workers: 2,
            queue_depth: 64,
            default_tier: "guarded".into(),
            tiers: vec![TierSpec {
                name: "guarded".into(),
                policy: None,
                max_batch,
                batch_timeout: timeout,
            }],
            governor: None,
        }
    }

    fn rand_image(rng: &mut Prng) -> Vec<f32> {
        (0..IMAGE_LEN).map(|_| rng.next_f32()).collect()
    }

    #[test]
    fn serves_requests_end_to_end() {
        let service = small_engine(1)
            .serve(one_tier_opts(4, Duration::from_millis(5)))
            .unwrap();
        let session = service.session();
        let mut rng = Prng::new(2);
        let mut tickets = Vec::new();
        for _ in 0..10 {
            tickets.push(session.submit(rand_image(&mut rng)).unwrap());
        }
        for t in tickets {
            let resp = t.wait_timeout(Duration::from_secs(120)).unwrap().expect("response");
            assert!(resp.batch_size() >= 1 && resp.batch_size() <= 4);
            assert_eq!(resp.tier(), "guarded");
            assert!(resp.latency() > Duration::ZERO);
            let logits = resp.expect_logits("good request");
            assert_eq!(logits.len(), 10);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
        let report = service.shutdown();
        let m = report.tier("guarded").unwrap();
        assert_eq!(m.requests, 10);
        assert_eq!(m.errors, 0);
        assert!(m.batches >= 3); // max_batch 4
        assert!(m.sim_cycles > 0);
        assert!(m.p50_us > 0 && m.p95_us >= m.p50_us && m.p99_us >= m.p95_us);
        assert!(m.max_us >= m.p99_us);
        assert!(m.requests_per_sec > 0.0);
        assert_eq!(report.rejected, 0);
        assert!(report.governor.is_empty());
    }

    #[test]
    fn bad_request_gets_error_response_and_workers_survive() {
        let service = small_engine(1)
            .serve(one_tier_opts(4, Duration::from_millis(5)))
            .unwrap();
        let session = service.session();
        let mut rng = Prng::new(3);
        let mut good = Vec::new();
        for _ in 0..3 {
            good.push(session.submit(rand_image(&mut rng)).unwrap());
        }
        let bad_ticket = session.submit(vec![0.5; 100]).unwrap(); // short image
        for _ in 0..7 {
            good.push(session.submit(rand_image(&mut rng)).unwrap());
        }
        let bad = bad_ticket
            .wait_timeout(Duration::from_secs(120))
            .unwrap()
            .expect("error response");
        match bad.result() {
            Err(GavinaError::Shape { expected, got, .. }) => {
                assert_eq!(*expected, IMAGE_LEN);
                assert_eq!(*got, 100);
            }
            other => panic!("expected shape error, got {other:?}"),
        }
        for t in good {
            let resp = t.wait_timeout(Duration::from_secs(120)).unwrap().expect("response");
            assert_eq!(resp.expect_logits("good request").len(), 10);
        }
        let report = service.shutdown();
        let m = report.tier("guarded").unwrap();
        assert_eq!(m.requests, 10);
        assert_eq!(m.errors, 1);
    }

    #[test]
    fn batching_respects_max_batch_and_intra_batch_threads() {
        let service = small_engine(2)
            .serve(one_tier_opts(2, Duration::from_millis(5)))
            .unwrap();
        let session = service.session();
        let mut rng = Prng::new(4);
        let tickets: Vec<_> = (0..6)
            .map(|_| session.submit(rand_image(&mut rng)).unwrap())
            .collect();
        for t in tickets {
            let resp = t.wait_timeout(Duration::from_secs(120)).unwrap().expect("response");
            assert!(resp.batch_size() <= 2);
            assert_eq!(resp.expect_logits("good request").len(), 10);
        }
        service.shutdown();
    }

    #[test]
    fn shutdown_flushes_pending() {
        // max_batch never reached, timeout never fires: the pending
        // sub-batch must still drain at shutdown.
        let service = small_engine(1)
            .serve(one_tier_opts(64, Duration::from_secs(3600)))
            .unwrap();
        let session = service.session();
        let mut rng = Prng::new(6);
        let ticket = session.submit(rand_image(&mut rng)).unwrap();
        let handle = std::thread::spawn(move || service.shutdown());
        let resp = ticket
            .wait_timeout(Duration::from_secs(120))
            .unwrap()
            .expect("flushed");
        assert_eq!(resp.expect_logits("flushed request").len(), 10);
        let report = handle.join().unwrap();
        assert_eq!(report.requests(), 1);
    }

    #[test]
    fn cancellation_yields_typed_cancelled_response() {
        // Long batch timeout: the request sits in the batcher until
        // shutdown flushes it, by which point it is cancelled.
        let service = small_engine(1)
            .serve(one_tier_opts(64, Duration::from_secs(3600)))
            .unwrap();
        let session = service.session();
        let mut rng = Prng::new(8);
        let ticket = session.submit(rand_image(&mut rng)).unwrap();
        ticket.cancel();
        let handle = std::thread::spawn(move || service.shutdown());
        let resp = ticket
            .wait_timeout(Duration::from_secs(120))
            .unwrap()
            .expect("cancelled response");
        assert!(matches!(resp.result(), Err(GavinaError::Cancelled)));
        let report = handle.join().unwrap();
        let m = report.tier("guarded").unwrap();
        assert_eq!(m.cancelled, 1);
        assert_eq!(m.requests, 0);
    }

    #[test]
    fn deadline_expired_requests_get_typed_response() {
        let service = small_engine(1)
            .serve(one_tier_opts(64, Duration::from_millis(30)))
            .unwrap();
        let session = service.session();
        let mut rng = Prng::new(9);
        // A deadline that has certainly passed by the time the batch
        // timeout (30 ms) flushes it.
        let ticket = session
            .submit_with(
                rand_image(&mut rng),
                SubmitOptions::new().deadline(Duration::from_millis(1)),
            )
            .unwrap();
        let resp = ticket
            .wait_timeout(Duration::from_secs(120))
            .unwrap()
            .expect("deadline response");
        match resp.result() {
            Err(GavinaError::DeadlineExceeded { waited_ms }) => assert!(*waited_ms >= 1),
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        service.shutdown();
    }

    #[test]
    fn permit_is_released_before_the_response_is_sent() {
        // Pins the ordering in `respond`: the RAII admission permit is
        // dropped *before* the response send, so a client that resubmits
        // the instant its response arrives always finds the
        // queue_depth-1 slot free — `rejected` staying at zero is the
        // whole assertion.
        let mut opts = one_tier_opts(1, Duration::from_millis(1));
        opts.queue_depth = 1;
        let service = small_engine(1).serve(opts).unwrap();
        let session = service.session();
        let mut rng = Prng::new(13);
        for _ in 0..8 {
            let t = session.submit(rand_image(&mut rng)).expect("slot free");
            let resp = t.wait_timeout(Duration::from_secs(120)).unwrap().expect("response");
            assert_eq!(resp.expect_logits("served").len(), 10);
        }
        let report = service.shutdown();
        assert_eq!(report.rejected, 0, "resubmit never races a held permit");
    }

    #[test]
    fn submit_shutdown_race_never_strands_an_accepted_ticket() {
        // Races submitters against shutdown (this also runs under the CI
        // ThreadSanitizer job). The SeqCst `closed` re-check in
        // `submit_with` is the invariant under test: every `Ok` ticket
        // must resolve with a response and every refusal must be a typed
        // error — a ticket that never fires is the one forbidden
        // outcome.
        for seed in 0..4u64 {
            let service = small_engine(1)
                .serve(one_tier_opts(4, Duration::from_millis(1)))
                .unwrap();
            let start = Arc::new(std::sync::Barrier::new(5));
            let mut submitters = Vec::new();
            for worker in 0..4u64 {
                let session = service.session();
                let gate = Arc::clone(&start);
                submitters.push(std::thread::spawn(move || {
                    let mut rng = Prng::new(seed * 31 + worker);
                    gate.wait();
                    let mut resolved = 0u64;
                    for _ in 0..8 {
                        // A typed refusal (shut down / overloaded) is
                        // fine; an accepted ticket must resolve.
                        let Ok(ticket) = session.submit(rand_image(&mut rng)) else {
                            continue;
                        };
                        let resp = ticket
                            .wait_timeout(Duration::from_secs(120))
                            .unwrap()
                            .expect("accepted ticket must never be stranded");
                        assert_eq!(resp.expect_logits("served").len(), 10);
                        resolved += 1;
                    }
                    resolved
                }));
            }
            start.wait();
            let report = service.shutdown();
            let mut resolved = 0u64;
            for h in submitters {
                resolved += h.join().unwrap();
            }
            // `<=`, not `==`: a submit that races the shutdown window
            // returns `Err` after its send, yet the drained request may
            // still execute and be counted — only the reverse (a
            // resolved ticket the metrics missed) would be a bug.
            assert!(resolved <= report.requests(), "resolved tickets counted");
        }
    }

    #[test]
    fn submit_routes_to_named_tier_and_unknown_tier_is_typed() {
        let mut opts = one_tier_opts(4, Duration::from_millis(5));
        opts.tiers
            .push(TierSpec::new("exact", Some(GavPolicy::Exact)).max_batch(1));
        let service = small_engine(1).serve(opts).unwrap();
        let session = service.session();
        let mut rng = Prng::new(11);
        let t = session
            .submit_with(rand_image(&mut rng), SubmitOptions::new().tier("exact"))
            .unwrap();
        let resp = t.wait_timeout(Duration::from_secs(120)).unwrap().expect("response");
        assert_eq!(resp.tier(), "exact");
        assert_eq!(resp.batch_size(), 1);
        match session.submit_with(rand_image(&mut rng), SubmitOptions::new().tier("nope")) {
            Err(GavinaError::Config(msg)) => assert!(msg.contains("unknown QoS tier")),
            other => panic!("expected config error, got {other:?}"),
        }
        let report = service.shutdown();
        assert_eq!(report.tier("exact").unwrap().requests, 1);
    }
}
