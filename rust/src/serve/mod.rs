//! `gavina::serve` — the QoS serving layer: bounded admission, per-request
//! energy tiers, continuous batching over sharded replicas, and a
//! load-adaptive undervolting governor.
//!
//! This module replaces the old `coordinator`'s ad-hoc types (public
//! `Request` fields, client-stamped timestamps, an unbounded queue and
//! one global policy frozen at build) with a typed serving surface:
//!
//! ```text
//! Session::submit ──▶ bounded admission ──▶ per-replica lanes ──▶ Ticket
//!   (tier, deadline,    (queue_depth;         │ tier₀: [r0] [r1] …
//!    cancellation)       Overloaded when      │ tier₁: [r0] [r1] …
//!                        full)                ▼
//!                                     replica workers (tiers × replicas)
//!                                       · claim ALL queued home-tier
//!                                         work up to max_batch — no
//!                                         batch windows (continuous)
//!                                       · idle ⇒ steal a batch from a
//!                                         foreign tier's lane tails
//!                                         (exact tiers keep a reserve)
//!                                     governor thread ──┘
//!                                     (adapts the default tier's
//!                                      per-layer G under load)
//! ```
//!
//! * [`Session`] — the only way in. `submit(image) -> Ticket` stamps the
//!   arrival time service-side, owns the response channel, and carries
//!   deadline + cancellation on the [`Ticket`].
//! * **Bounded admission** — at `queue_depth` in-flight requests,
//!   `submit` fails fast with [`GavinaError::Overloaded`]; the service
//!   backpressures instead of buffering unboundedly, and never silently
//!   drops an accepted request.
//! * [`TierSpec`] **QoS tiers** — each tier maps to a pre-resolved
//!   engine variant (`Engine::with_policy`, sharing packed planes) with
//!   `replicas` dedicated worker lanes and its own [`MetricsSnapshot`].
//!   Cross-request batches use **per-image activation quantization**
//!   ([`Engine::infer_rows_parallel`](crate::engine::Engine::infer_rows_parallel)),
//!   so an `exact`-tier request returns logits bit-identical to a
//!   standalone [`Engine::infer`](crate::engine::Engine::infer) no
//!   matter which requests share its batch.
//! * **Continuous batching + work-stealing** ([`dispatch`] module) — an
//!   idle worker immediately claims everything queued for its home tier
//!   (up to `max_batch`) instead of waiting out a batch window, and
//!   steals batches from other tiers' lane tails when its own tier is
//!   empty, so a slow aggressive-tier backlog cannot idle exact-tier
//!   replicas (and vice versa). Each batch's error-injection stream is
//!   seeded from a monotonically increasing batch id, so no two batches
//!   replay the same RNG stream.
//! * [`GovernorOptions`] **governor** — a control loop that slides the
//!   default tier along a pre-resolved per-layer-G ladder under observed
//!   load or a modeled power budget, recording a [`GovernorStep`]
//!   trajectory.
//! * [`CanaryOptions`] **canary** ([`crate::canary`]) — deterministic
//!   sampling of served rows, re-executed on a bit-exact reference
//!   replica below the serve stack (no admission permits, no dispatch
//!   lanes); the measured top-1 flip rate closes the governor loop:
//!   drift above the high watermark steps the ladder toward guarded and
//!   holds through a dwell, every trajectory entry tagged with its
//!   [`StepTrigger`].
//!
//! Start a service with [`Engine::serve`](crate::engine::Engine::serve)
//! or [`Service::start`]; stop it with [`Service::shutdown`], which
//! drains every accepted ticket before returning the final
//! [`ServeReport`].

mod dispatch;
mod governor;
mod metrics;
mod session;
mod tier;

pub use crate::canary::{CanaryOptions, CanaryTierReport};
pub use governor::{GovernorOptions, GovernorStep, StepTrigger};
pub use metrics::MetricsSnapshot;
pub use session::{Response, Session, SubmitOptions, Ticket};
pub use tier::{ServeOptions, TierSpec};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::canary::CanaryRuntime;
use crate::dnn::IMAGE_LEN;
use crate::engine::{Engine, GavPolicy, GavinaError};
use crate::power::PowerModel;

use dispatch::Dispatch;
use metrics::TierMetrics;
use session::{Admission, Request};

/// One tier at runtime: its (swappable) engine, batching bound, metrics.
pub(crate) struct TierRuntime {
    pub(crate) name: Arc<str>,
    /// Swapped by the governor (default tier only); workers clone the
    /// `Arc` per batch, so in-flight batches finish on the old schedule.
    pub(crate) engine: Mutex<Arc<Engine>>,
    pub(crate) max_batch: usize,
    pub(crate) metrics: TierMetrics,
}

/// State shared by sessions, workers and the governor.
pub(crate) struct Shared {
    pub(crate) admission: Arc<Admission>,
    pub(crate) tiers: Vec<TierRuntime>,
    pub(crate) default_tier: usize,
    pub(crate) dispatch: Dispatch,
    /// Submissions rejected at admission ([`GavinaError::Overloaded`]).
    pub(crate) rejected: AtomicU64,
    /// Monotonic batch id: every executed batch draws a fresh value and
    /// mixes it into its error-injection stream seed, so two batches on
    /// the same worker never replay one RNG stream.
    pub(crate) batch_seq: AtomicU64,
    pub(crate) started: Instant,
    /// Canary drift observability (`[serve.canary]`): workers sample and
    /// re-execute rows through it, the governor reads its drift stats.
    pub(crate) canary: Option<Arc<CanaryRuntime>>,
    /// The governor's latest `(rung, trigger)` — surfaced on the default
    /// tier's [`MetricsSnapshot`]; `None` until the first tick (or when
    /// the governor is off).
    pub(crate) governor_state: Mutex<Option<(usize, StepTrigger)>>,
}

impl Shared {
    pub(crate) fn tier_index(&self, name: &str) -> Option<usize> {
        self.tiers.iter().position(|t| &*t.name == name)
    }

    pub(crate) fn tier_names(&self) -> Vec<String> {
        self.tiers.iter().map(|t| t.name.to_string()).collect()
    }

    fn snapshot_tier(&self, i: usize) -> MetricsSnapshot {
        let t = &self.tiers[i];
        let governor = if i == self.default_tier {
            *self.governor_state.lock().unwrap()
        } else {
            None
        };
        t.metrics.snapshot(
            &t.name,
            t.engine.lock().unwrap().layer_gs(),
            self.dispatch.tier_depths(i),
            self.dispatch.replicas(),
            governor,
        )
    }
}

/// The final report [`Service::shutdown`] returns: per-tier metrics, the
/// admission-rejection count, and the governor's recorded trajectory.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// One snapshot per configured tier, in tier order.
    pub tiers: Vec<MetricsSnapshot>,
    /// Submissions rejected with [`GavinaError::Overloaded`].
    pub rejected: u64,
    /// Governor ticks (empty when the governor was off).
    pub governor: Vec<GovernorStep>,
    /// Canary drift reports, one per observed tier (empty when the
    /// canary was off).
    pub canary: Vec<CanaryTierReport>,
}

impl ServeReport {
    /// The snapshot for a named tier.
    pub fn tier(&self, name: &str) -> Option<&MetricsSnapshot> {
        self.tiers.iter().find(|t| t.tier == name)
    }

    /// Total requests served across tiers.
    pub fn requests(&self) -> u64 {
        self.tiers.iter().map(|t| t.requests).sum()
    }

    /// Total batches stolen across tiers (executed by a foreign tier's
    /// idle replica).
    pub fn steals(&self) -> u64 {
        self.tiers.iter().map(|t| t.steals).sum()
    }
}

/// The running service: `tiers × replicas` claim-and-steal workers plus
/// an optional governor over a shared [`Engine`]. Create client handles
/// with [`Service::session`].
pub struct Service {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    governor: Option<(governor::StopHandle, std::thread::JoinHandle<()>)>,
    trajectory: Arc<Mutex<std::collections::VecDeque<GovernorStep>>>,
}

impl Service {
    /// Validate `opts`, pre-resolve every tier's engine variant (and the
    /// governor's ladder), and start the replica worker pool (also
    /// reachable as [`Engine::serve`](crate::engine::Engine::serve)).
    pub fn start(engine: Arc<Engine>, opts: ServeOptions) -> Result<Self, GavinaError> {
        opts.validate()?;
        let started = Instant::now();
        let mut tiers = Vec::with_capacity(opts.tiers.len());
        let mut protected = Vec::with_capacity(opts.tiers.len());
        for spec in &opts.tiers {
            let tier_engine = match &spec.policy {
                None => Arc::clone(&engine),
                Some(p) if p == engine.policy() => Arc::clone(&engine),
                // Re-resolves the schedules only; packed planes are
                // shared with the base engine (PR 3).
                Some(p) => Arc::new(engine.with_policy(p.clone())?),
            };
            // Fully-guarded tiers get steal protection: thieves leave
            // `steal_reserve` queued requests behind, so exact traffic
            // keeps its dedicated lanes under mixed load.
            protected.push(matches!(tier_engine.policy(), GavPolicy::Exact));
            tiers.push(TierRuntime {
                name: Arc::from(spec.name.as_str()),
                engine: Mutex::new(tier_engine),
                max_batch: spec.max_batch,
                metrics: TierMetrics::new(started),
            });
        }
        let default_tier = opts
            .tiers
            .iter()
            .position(|t| t.name == opts.default_tier)
            .expect("validated: default_tier exists");
        // Canary runtime before any thread spawns: resolving the exact
        // reference replica can fail, and like the governor ladder it
        // must fail fast with nothing to tear down. An exact tier's
        // already-resolved engine doubles as the reference; Exact tiers
        // themselves are never observed (they ARE the reference).
        let canary = match &opts.canary {
            None => None,
            Some(copts) => {
                let reference = match tiers.iter().zip(&protected).find(|(_, &p)| p) {
                    Some((t, _)) => Arc::clone(&t.engine.lock().unwrap()),
                    None => Arc::new(engine.exact_reference()?),
                };
                let observed: Vec<bool> = protected.iter().map(|&p| !p).collect();
                Some(Arc::new(CanaryRuntime::new(copts.clone(), reference, observed)))
            }
        };
        let dispatch = Dispatch::new(
            opts.replicas,
            opts.steal,
            opts.steal_reserve,
            tiers.iter().map(|t| t.max_batch).collect(),
            protected,
        );
        let shared = Arc::new(Shared {
            admission: Arc::new(Admission::new(opts.queue_depth)),
            tiers,
            default_tier,
            dispatch,
            rejected: AtomicU64::new(0),
            batch_seq: AtomicU64::new(0),
            started,
            canary,
            governor_state: Mutex::new(None),
        });

        // Resolve the governor's ladder before any thread spawns, so a
        // bad governor config fails fast with nothing to tear down.
        let ladder = match &opts.governor {
            None => None,
            Some(gopts) => {
                let base = Arc::clone(&shared.tiers[default_tier].engine.lock().unwrap());
                let power = PowerModel::paper_calibrated();
                let rungs = governor::build_ladder(&base, gopts, &power)?;
                let rung0 = governor::start_rung(&rungs, &base);
                Some((gopts.clone(), rungs, rung0))
            }
        };

        let n_tiers = shared.tiers.len();
        let mut workers = Vec::with_capacity(n_tiers * opts.replicas);
        for ti in 0..n_tiers {
            for ri in 0..opts.replicas {
                let shared = Arc::clone(&shared);
                let worker_id = (ti * opts.replicas + ri) as u64;
                workers.push(std::thread::spawn(move || {
                    loop {
                        let Some(claim) = shared.dispatch.claim(ti, ri) else {
                            break; // closed and fully drained
                        };
                        if claim.stolen {
                            shared.tiers[claim.tier].metrics.record_steal();
                        }
                        let t0 = Instant::now();
                        run_batch(&shared, claim.tier, worker_id, claim.batch);
                        shared.tiers[claim.tier].metrics.record_busy(t0.elapsed());
                    }
                }));
            }
        }

        let trajectory = Arc::new(Mutex::new(std::collections::VecDeque::new()));
        let governor = ladder.map(|(g_opts, rungs, rung0)| {
            let (stop_tx, stop_rx) = channel::<()>();
            let g_shared = Arc::clone(&shared);
            let g_traj = Arc::clone(&trajectory);
            let handle = std::thread::spawn(move || {
                let canary = g_shared.canary.clone();
                governor::run(g_shared, rungs, g_opts, stop_rx, g_traj, rung0, canary);
            });
            (stop_tx, handle)
        });

        Ok(Self {
            shared,
            workers,
            governor,
            trajectory,
        })
    }

    /// A client handle (cheap to clone, one per producer thread).
    pub fn session(&self) -> Session {
        Session {
            shared: Arc::clone(&self.shared),
        }
    }

    /// Point-in-time metrics for every tier, in tier order.
    pub fn metrics(&self) -> Vec<MetricsSnapshot> {
        (0..self.shared.tiers.len())
            .map(|i| self.shared.snapshot_tier(i))
            .collect()
    }

    /// Point-in-time metrics for one named tier.
    pub fn tier_metrics(&self, name: &str) -> Option<MetricsSnapshot> {
        self.shared.tier_index(name).map(|i| self.shared.snapshot_tier(i))
    }

    /// Submissions rejected at admission so far.
    pub fn rejected(&self) -> u64 {
        // Relaxed: monotonic statistics counter, reporting only.
        // gavina-lint: allow(relaxed-order)
        self.shared.rejected.load(Ordering::Relaxed)
    }

    /// Accepted-but-unanswered requests right now.
    pub fn in_flight(&self) -> usize {
        self.shared.admission.in_flight()
    }

    /// The governor trajectory recorded so far (empty when off). This
    /// deep-clones the bounded trajectory — for cheap polling (progress
    /// displays, load generators) use [`Service::governor_ticks`].
    pub fn governor_trajectory(&self) -> Vec<GovernorStep> {
        self.trajectory.lock().unwrap().iter().cloned().collect()
    }

    /// How many governor ticks are currently retained — an O(1) read
    /// for cheap polling (saturates at the trajectory's 4096-step
    /// retention bound, like the history itself).
    pub fn governor_ticks(&self) -> usize {
        self.trajectory.lock().unwrap().len()
    }

    /// The per-layer G schedule a tier is currently running.
    pub fn tier_layer_gs(&self, name: &str) -> Option<Vec<u32>> {
        self.shared
            .tier_index(name)
            .map(|i| self.shared.tiers[i].engine.lock().unwrap().layer_gs())
    }

    /// Stop the governor, drain **every accepted ticket** (queued
    /// requests are claimed and executed — with stealing unconditionally
    /// enabled so any worker finishes any tier's backlog — never
    /// dropped), join all threads, and return the final [`ServeReport`].
    pub fn shutdown(mut self) -> ServeReport {
        if let Some((stop, handle)) = self.governor.take() {
            let _ = stop.send(());
            let _ = handle.join();
        }
        // `closed` lives under the dispatch lock: a submit either
        // enqueued before this and will be drained, or gets a typed
        // shutdown error — no ticket can be stranded.
        self.shared.dispatch.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        ServeReport {
            tiers: self.metrics(),
            rejected: self.rejected(),
            governor: self.governor_trajectory(),
            canary: match &self.shared.canary {
                None => Vec::new(),
                Some(c) => {
                    let names = self.shared.tier_names();
                    c.reports(&names.iter().map(|s| s.as_str()).collect::<Vec<_>>())
                }
            },
        }
    }
}

/// Answer one request: the admission permit is released *before* the
/// response is sent, so a client that resubmits the moment its response
/// arrives is guaranteed a free slot (no spurious `Overloaded`).
/// Returns the end-to-end latency.
fn respond(
    r: Request,
    result: Result<Vec<f32>, GavinaError>,
    batch_size: usize,
    tier: &Arc<str>,
) -> Duration {
    let Request {
        submitted,
        resp,
        _permit: permit,
        ..
    } = r;
    let latency = submitted.elapsed();
    drop(permit);
    let _ = resp.send(Response::new(result, latency, batch_size, Arc::clone(tier)));
    latency
}

/// Execute one tier batch on a worker thread. Cancelled, deadline-missed
/// and malformed requests get per-request error [`Response`]s and never
/// reach the executor; the rest run as one cross-request packed batch
/// (per-image activation scales keep every row bit-independent). Worker
/// threads must survive arbitrary client input.
fn run_batch(shared: &Shared, ti: usize, worker_id: u64, batch: Vec<Request>) {
    let tier = &shared.tiers[ti];
    let engine = { Arc::clone(&tier.engine.lock().unwrap()) };

    let mut good: Vec<Request> = Vec::with_capacity(batch.len());
    let mut dropped: Vec<(Request, GavinaError)> = Vec::new();
    for r in batch {
        // Relaxed: best-effort cancellation flag — a missed store just
        // runs the request normally. gavina-lint: allow(relaxed-order)
        if r.cancelled.load(Ordering::Relaxed) {
            dropped.push((r, GavinaError::Cancelled));
        } else if r.deadline.is_some_and(|d| r.submitted.elapsed() > d) {
            let waited_ms = r.submitted.elapsed().as_millis() as u64;
            dropped.push((r, GavinaError::DeadlineExceeded { waited_ms }));
        } else if r.image.len() != IMAGE_LEN {
            let got = r.image.len();
            dropped.push((
                r,
                GavinaError::Shape {
                    what: "request image".into(),
                    expected: IMAGE_LEN,
                    got,
                },
            ));
        } else {
            good.push(r);
        }
    }
    // Every response from one physical batch reports the same
    // batch_size: the number of requests that actually executed.
    let n = good.len();
    let mut cancelled = 0usize;
    let mut errors = 0usize;
    for (r, e) in dropped {
        if matches!(e, GavinaError::Cancelled) {
            cancelled += 1;
        } else {
            errors += 1;
        }
        respond(r, Err(e), n, &tier.name);
    }
    if cancelled > 0 {
        tier.metrics.record_cancelled(cancelled);
    }
    if errors > 0 {
        tier.metrics.record_errors(errors);
    }
    if good.is_empty() {
        return;
    }

    // Per-batch stream seed: mixing a fresh monotonic batch id means
    // consecutive batches on one worker draw *different* injection
    // streams; the old worker_id-only seed replayed one stream forever.
    // Guarded/exact execution is stream-independent, so determinism
    // contracts hold. Relaxed: only uniqueness matters, nothing
    // synchronizes on the counter. gavina-lint: allow(relaxed-order)
    let batch_id = shared.batch_seq.fetch_add(1, Ordering::Relaxed);
    let stream = batch_id
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ worker_id.wrapping_mul(0xD1F);

    // Cross-request packed batch: rows borrow the request images — no
    // concatenated copy — and per-image activation scales keep each
    // row's logits identical to standalone execution.
    let rows: Vec<&[f32]> = good.iter().map(|r| r.image.as_slice()).collect();
    let result = engine.infer_rows_parallel(&rows, stream);
    drop(rows);
    match result {
        Ok(result) => {
            let classes = result.classes;
            // The canary's sampling decision is pure in (stream, row) and
            // its image clones are taken *before* the responses go out —
            // `respond` consumes the requests.
            let picked: Vec<(usize, Vec<f32>)> = match &shared.canary {
                None => Vec::new(),
                Some(c) => c
                    .pick_rows(ti, stream, n)
                    .into_iter()
                    .map(|i| (i, good[i].image.clone()))
                    .collect(),
            };
            let mut lats = Vec::with_capacity(n);
            for (i, r) in good.into_iter().enumerate() {
                lats.push(respond(
                    r,
                    Ok(result.logits[i * classes..(i + 1) * classes].to_vec()),
                    n,
                    &tier.name,
                ));
            }
            tier.metrics
                .record(n, &lats, result.stats.cycles, result.stats.corrupted);
            // Exact re-runs happen after every response is sent: off the
            // request critical path, and through `Engine::canary_rerun`
            // only — below admission, so no permit is ever consumed.
            if let Some(c) = &shared.canary {
                c.observe_batch(ti, stream, &picked, &result);
            }
        }
        Err(e) => {
            // Shouldn't happen (shapes were validated above), but a
            // failing backend must not kill the worker either.
            tier.metrics.record_errors(n);
            for r in good {
                respond(r, Err(e.clone()), n, &tier.name);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchConfig, Precision};
    use crate::engine::backend::{BackendGemm, ExecBackend, LayerGemm};
    use crate::engine::{EngineBuilder, FloatBackend, GavPolicy};
    use crate::util::Prng;
    use std::sync::Condvar;

    fn small_engine(threads: usize) -> Arc<Engine> {
        Arc::new(
            EngineBuilder::new()
                .synthetic_weights(0.125, 1)
                .precision(Precision::new(2, 2))
                .arch(ArchConfig::tiny())
                .policy(GavPolicy::Exact)
                .seed(1)
                .threads(threads)
                .build()
                .unwrap(),
        )
    }

    fn one_tier_opts(max_batch: usize) -> ServeOptions {
        ServeOptions {
            replicas: 2,
            queue_depth: 64,
            steal: true,
            steal_reserve: 2,
            default_tier: "guarded".into(),
            tiers: vec![TierSpec {
                name: "guarded".into(),
                policy: None,
                max_batch,
            }],
            governor: None,
            canary: None,
        }
    }

    fn rand_image(rng: &mut Prng) -> Vec<f32> {
        (0..IMAGE_LEN).map(|_| rng.next_f32()).collect()
    }

    /// A backend gate for deterministic concurrency tests: every GEMM
    /// blocks at its first layer until `open()`, and `blocked()` reports
    /// how many worker threads are currently parked inside the engine —
    /// so tests can pin "this worker is mid-batch" without sleeps.
    struct Gate {
        state: Mutex<(bool, usize)>, // (open, currently blocked)
        cv: Condvar,
    }

    impl Gate {
        fn new() -> Arc<Self> {
            Arc::new(Self {
                state: Mutex::new((false, 0)),
                cv: Condvar::new(),
            })
        }

        fn open(&self) {
            self.state.lock().unwrap().0 = true;
            self.cv.notify_all();
        }

        fn pass(&self) {
            let mut s = self.state.lock().unwrap();
            if s.0 {
                return;
            }
            s.1 += 1;
            self.cv.notify_all();
            while !s.0 {
                s = self.cv.wait(s).unwrap();
            }
            s.1 -= 1;
        }

        /// Wait (bounded) until `n` workers are parked at the gate.
        fn await_blocked(&self, n: usize) {
            let deadline = Instant::now() + Duration::from_secs(30);
            let mut s = self.state.lock().unwrap();
            while s.1 < n {
                assert!(Instant::now() < deadline, "gate never saw {n} blocked workers");
                let (guard, _) = self
                    .cv
                    .wait_timeout(s, Duration::from_millis(20))
                    .unwrap();
                s = guard;
            }
        }
    }

    struct GatedFloat {
        gate: Arc<Gate>,
    }

    impl ExecBackend for GatedFloat {
        fn name(&self) -> &'static str {
            "gated-float"
        }

        fn run_layer_gemm(&self, job: &LayerGemm) -> BackendGemm {
            self.gate.pass();
            FloatBackend.run_layer_gemm(job)
        }

        fn is_simulated(&self) -> bool {
            false
        }
    }

    fn gated_engine(gate: &Arc<Gate>, policy: GavPolicy) -> Arc<Engine> {
        Arc::new(
            EngineBuilder::new()
                .synthetic_weights(0.125, 1)
                .precision(Precision::new(2, 2))
                .arch(ArchConfig::tiny())
                .backend(Arc::new(GatedFloat {
                    gate: Arc::clone(gate),
                }))
                .policy(policy)
                .seed(1)
                .threads(1)
                .build()
                .unwrap(),
        )
    }

    #[test]
    fn serves_requests_end_to_end() {
        let service = small_engine(1).serve(one_tier_opts(4)).unwrap();
        let session = service.session();
        let mut rng = Prng::new(2);
        let mut tickets = Vec::new();
        for _ in 0..10 {
            tickets.push(session.submit(rand_image(&mut rng)).unwrap());
        }
        for t in tickets {
            let resp = t.wait_timeout(Duration::from_secs(120)).unwrap().expect("response");
            assert!(resp.batch_size() >= 1 && resp.batch_size() <= 4);
            assert_eq!(resp.tier(), "guarded");
            assert!(resp.latency() > Duration::ZERO);
            let logits = resp.expect_logits("good request");
            assert_eq!(logits.len(), 10);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
        let report = service.shutdown();
        let m = report.tier("guarded").unwrap();
        assert_eq!(m.requests, 10);
        assert_eq!(m.errors, 0);
        assert!(m.batches >= 3); // max_batch 4
        assert!(m.sim_cycles > 0);
        assert!(m.p50_us > 0 && m.p95_us >= m.p50_us && m.p99_us >= m.p95_us);
        assert!(m.max_us >= m.p99_us);
        assert!(m.requests_per_sec > 0.0);
        assert!(m.occupancy > 0.0, "busy time must be accounted");
        assert_eq!(m.queue_depth, 0, "drained at shutdown");
        assert_eq!(report.rejected, 0);
        assert!(report.governor.is_empty());
    }

    #[test]
    fn bad_request_gets_error_response_and_workers_survive() {
        let service = small_engine(1).serve(one_tier_opts(4)).unwrap();
        let session = service.session();
        let mut rng = Prng::new(3);
        let mut good = Vec::new();
        for _ in 0..3 {
            good.push(session.submit(rand_image(&mut rng)).unwrap());
        }
        let bad_ticket = session.submit(vec![0.5; 100]).unwrap(); // short image
        for _ in 0..7 {
            good.push(session.submit(rand_image(&mut rng)).unwrap());
        }
        let bad = bad_ticket
            .wait_timeout(Duration::from_secs(120))
            .unwrap()
            .expect("error response");
        match bad.result() {
            Err(GavinaError::Shape { expected, got, .. }) => {
                assert_eq!(*expected, IMAGE_LEN);
                assert_eq!(*got, 100);
            }
            other => panic!("expected shape error, got {other:?}"),
        }
        for t in good {
            let resp = t.wait_timeout(Duration::from_secs(120)).unwrap().expect("response");
            assert_eq!(resp.expect_logits("good request").len(), 10);
        }
        let report = service.shutdown();
        let m = report.tier("guarded").unwrap();
        assert_eq!(m.requests, 10);
        assert_eq!(m.errors, 1);
    }

    #[test]
    fn batching_respects_max_batch_and_intra_batch_threads() {
        let service = small_engine(2).serve(one_tier_opts(2)).unwrap();
        let session = service.session();
        let mut rng = Prng::new(4);
        let tickets: Vec<_> = (0..6)
            .map(|_| session.submit(rand_image(&mut rng)).unwrap())
            .collect();
        for t in tickets {
            let resp = t.wait_timeout(Duration::from_secs(120)).unwrap().expect("response");
            assert!(resp.batch_size() <= 2);
            assert_eq!(resp.expect_logits("good request").len(), 10);
        }
        service.shutdown();
    }

    #[test]
    fn shutdown_drains_queued_requests() {
        // The single replica is parked inside a batch at the gate while a
        // second request sits queued; shutdown must claim and execute it,
        // never drop it.
        let gate = Gate::new();
        let mut opts = one_tier_opts(1);
        opts.replicas = 1;
        let service = gated_engine(&gate, GavPolicy::Exact).serve(opts).unwrap();
        let session = service.session();
        let mut rng = Prng::new(6);
        let first = session.submit(rand_image(&mut rng)).unwrap();
        gate.await_blocked(1);
        let queued = session.submit(rand_image(&mut rng)).unwrap();
        let handle = std::thread::spawn(move || service.shutdown());
        gate.open();
        assert_eq!(
            first
                .wait_timeout(Duration::from_secs(120))
                .unwrap()
                .expect("in-flight request")
                .expect_logits("served")
                .len(),
            10
        );
        assert_eq!(
            queued
                .wait_timeout(Duration::from_secs(120))
                .unwrap()
                .expect("queued request drains at shutdown")
                .expect_logits("drained")
                .len(),
            10
        );
        let report = handle.join().unwrap();
        assert_eq!(report.requests(), 2);
    }

    #[test]
    fn cancellation_yields_typed_cancelled_response() {
        // Park the only replica at the gate, queue a second request,
        // cancel it: when the worker reaches it, it must answer with a
        // typed Cancelled instead of executing.
        let gate = Gate::new();
        let mut opts = one_tier_opts(1);
        opts.replicas = 1;
        let service = gated_engine(&gate, GavPolicy::Exact).serve(opts).unwrap();
        let session = service.session();
        let mut rng = Prng::new(8);
        let first = session.submit(rand_image(&mut rng)).unwrap();
        gate.await_blocked(1);
        let victim = session.submit(rand_image(&mut rng)).unwrap();
        victim.cancel();
        gate.open();
        let resp = victim
            .wait_timeout(Duration::from_secs(120))
            .unwrap()
            .expect("cancelled response");
        assert!(matches!(resp.result(), Err(GavinaError::Cancelled)));
        first.wait_timeout(Duration::from_secs(120)).unwrap().expect("response");
        let report = service.shutdown();
        let m = report.tier("guarded").unwrap();
        assert_eq!(m.cancelled, 1);
        assert_eq!(m.requests, 1);
    }

    #[test]
    fn deadline_expired_requests_get_typed_response() {
        let gate = Gate::new();
        let mut opts = one_tier_opts(1);
        opts.replicas = 1;
        let service = gated_engine(&gate, GavPolicy::Exact).serve(opts).unwrap();
        let session = service.session();
        let mut rng = Prng::new(9);
        let first = session.submit(rand_image(&mut rng)).unwrap();
        gate.await_blocked(1);
        // Queued behind the parked replica with a deadline that expires
        // while it waits.
        let late = session
            .submit_with(
                rand_image(&mut rng),
                SubmitOptions::new().deadline(Duration::from_millis(1)),
            )
            .unwrap();
        std::thread::sleep(Duration::from_millis(10));
        gate.open();
        let resp = late
            .wait_timeout(Duration::from_secs(120))
            .unwrap()
            .expect("deadline response");
        match resp.result() {
            Err(GavinaError::DeadlineExceeded { waited_ms }) => assert!(*waited_ms >= 1),
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        first.wait_timeout(Duration::from_secs(120)).unwrap().expect("response");
        service.shutdown();
    }

    #[test]
    fn permit_is_released_before_the_response_is_sent() {
        // Pins the ordering in `respond`: the RAII admission permit is
        // dropped *before* the response send, so a client that resubmits
        // the instant its response arrives always finds the
        // queue_depth-1 slot free — `rejected` staying at zero is the
        // whole assertion.
        let mut opts = one_tier_opts(1);
        opts.queue_depth = 1;
        let service = small_engine(1).serve(opts).unwrap();
        let session = service.session();
        let mut rng = Prng::new(13);
        for _ in 0..8 {
            let t = session.submit(rand_image(&mut rng)).expect("slot free");
            let resp = t.wait_timeout(Duration::from_secs(120)).unwrap().expect("response");
            assert_eq!(resp.expect_logits("served").len(), 10);
        }
        let report = service.shutdown();
        assert_eq!(report.rejected, 0, "resubmit never races a held permit");
    }

    #[test]
    fn submit_shutdown_race_never_strands_an_accepted_ticket() {
        // Races submitters against shutdown (this also runs under the CI
        // ThreadSanitizer job). The invariant under test: `closed` lives
        // under the same lock as the queues, so a submit either enqueues
        // before close() (and is drained) or gets a typed error — every
        // `Ok` ticket must resolve with a response; a ticket that never
        // fires is the one forbidden outcome.
        for seed in 0..4u64 {
            let service = small_engine(1).serve(one_tier_opts(4)).unwrap();
            let start = Arc::new(std::sync::Barrier::new(5));
            let mut submitters = Vec::new();
            for worker in 0..4u64 {
                let session = service.session();
                let gate = Arc::clone(&start);
                submitters.push(std::thread::spawn(move || {
                    let mut rng = Prng::new(seed * 31 + worker);
                    gate.wait();
                    let mut resolved = 0u64;
                    for _ in 0..8 {
                        // A typed refusal (shut down / overloaded) is
                        // fine; an accepted ticket must resolve.
                        let Ok(ticket) = session.submit(rand_image(&mut rng)) else {
                            continue;
                        };
                        let resp = ticket
                            .wait_timeout(Duration::from_secs(120))
                            .unwrap()
                            .expect("accepted ticket must never be stranded");
                        assert_eq!(resp.expect_logits("served").len(), 10);
                        resolved += 1;
                    }
                    resolved
                }));
            }
            start.wait();
            let report = service.shutdown();
            let mut resolved = 0u64;
            for h in submitters {
                resolved += h.join().unwrap();
            }
            assert_eq!(resolved, report.requests(), "every Ok ticket resolves, every resolution is counted");
        }
    }

    #[test]
    fn submit_routes_to_named_tier_and_unknown_tier_is_typed() {
        let mut opts = one_tier_opts(4);
        opts.tiers
            .push(TierSpec::new("exact", Some(GavPolicy::Exact)).max_batch(1));
        let service = small_engine(1).serve(opts).unwrap();
        let session = service.session();
        let mut rng = Prng::new(11);
        let t = session
            .submit_with(rand_image(&mut rng), SubmitOptions::new().tier("exact"))
            .unwrap();
        let resp = t.wait_timeout(Duration::from_secs(120)).unwrap().expect("response");
        assert_eq!(resp.tier(), "exact");
        assert_eq!(resp.batch_size(), 1);
        match session.submit_with(rand_image(&mut rng), SubmitOptions::new().tier("nope")) {
            Err(GavinaError::Config(msg)) => assert!(msg.contains("unknown QoS tier")),
            other => panic!("expected config error, got {other:?}"),
        }
        let report = service.shutdown();
        assert_eq!(report.tier("exact").unwrap().requests, 1);
    }

    #[test]
    fn consecutive_batches_on_one_worker_use_distinct_injection_streams() {
        use crate::errmodel::{ErrorTables, ModelParams};
        // Undervolted engine with dense error tables: injection depends
        // on the per-batch RNG stream. Two sequential submissions of the
        // *same image* on the *same worker* must observe different
        // streams — the old worker_id-only seed replayed one stream and
        // returned identical corrupted logits forever.
        let arch = ArchConfig::tiny();
        let params = ModelParams::paper(arch.c_dim);
        let mut tables = ErrorTables::zeroed(params);
        for bit in 0..params.s_bits {
            for e in 0..=params.c_dim as u16 {
                for pb in 0..params.p_bins {
                    for cd in 0..params.n_cond(bit) {
                        tables.set_prob(bit, e, pb, cd, 0.5);
                    }
                }
            }
        }
        let engine = Arc::new(
            EngineBuilder::new()
                .synthetic_weights(0.125, 1)
                .precision(Precision::new(2, 2))
                .arch(arch)
                .tables(tables)
                .policy(GavPolicy::Uniform(0))
                .seed(7)
                .threads(1)
                .build()
                .unwrap(),
        );
        let mut opts = one_tier_opts(1);
        opts.replicas = 1; // exactly one worker => both batches run on it
        let service = engine.serve(opts).unwrap();
        let session = service.session();
        let mut rng = Prng::new(17);
        let image = rand_image(&mut rng);
        let a = session
            .submit(image.clone())
            .unwrap()
            .wait_timeout(Duration::from_secs(120))
            .unwrap()
            .expect("first batch")
            .expect_logits("first batch");
        let b = session
            .submit(image)
            .unwrap()
            .wait_timeout(Duration::from_secs(120))
            .unwrap()
            .expect("second batch")
            .expect_logits("second batch");
        assert_ne!(
            a, b,
            "two batches on one worker must draw different injection streams"
        );
        let report = service.shutdown();
        assert!(report.tier("guarded").unwrap().corrupted > 0);
    }

    #[test]
    fn work_stealing_drains_foreign_tiers_but_respects_exact_reserve() {
        // Two tiers, one replica each. The gold (exact) tier's replica is
        // parked at the gate; its queue fills to the steal reserve — the
        // busy tier's idle replica must NOT steal from it. One request
        // past the reserve, the thief takes exactly the excess, runs it
        // on gold's engine, and gold's steal counter records the theft.
        let gate = Gate::new();
        let opts = ServeOptions {
            replicas: 1,
            queue_depth: 32,
            steal: true,
            steal_reserve: 2,
            default_tier: "busy".into(),
            tiers: vec![
                TierSpec {
                    name: "busy".into(),
                    policy: None,
                    max_batch: 4,
                },
                TierSpec {
                    name: "gold".into(),
                    policy: Some(GavPolicy::Exact),
                    max_batch: 4,
                },
            ],
            governor: None,
            canary: None,
        };
        let service = gated_engine(&gate, GavPolicy::Uniform(1)).serve(opts).unwrap();
        let session = service.session();
        let mut rng = Prng::new(19);
        let image = rand_image(&mut rng);
        let gold = |img: Vec<f32>| {
            session
                .submit_with(img, SubmitOptions::new().tier("gold"))
                .unwrap()
        };
        // Busy's replica cannot steal gold's first request: the reserve
        // already protects a single queued exact request. Gold's own
        // replica claims it and parks at the gate.
        let mut tickets = vec![gold(image.clone())];
        gate.await_blocked(1);
        // Two more: exactly at the reserve — still protected.
        tickets.push(gold(image.clone()));
        tickets.push(gold(image.clone()));
        std::thread::sleep(Duration::from_millis(150)); // > claim() poll period
        let m = service.tier_metrics("gold").unwrap();
        assert_eq!(m.steals, 0, "at/below the reserve nothing is stolen");
        assert_eq!(m.queue_depth, 2, "both requests still queued for gold");
        // One past the reserve: busy's idle replica steals the excess and
        // parks inside gold's engine — the second blocked worker.
        tickets.push(gold(image.clone()));
        gate.await_blocked(2);
        let m = service.tier_metrics("gold").unwrap();
        assert_eq!(m.steals, 1, "the excess past the reserve is stolen");
        gate.open();
        for t in tickets {
            let resp = t.wait_timeout(Duration::from_secs(120)).unwrap().expect("response");
            assert_eq!(resp.tier(), "gold", "stolen work still runs as its own tier");
            assert_eq!(resp.expect_logits("served").len(), 10);
        }
        let report = service.shutdown();
        assert_eq!(report.tier("gold").unwrap().requests, 4);
        assert_eq!(report.tier("busy").unwrap().steals, 0);
        assert_eq!(report.steals(), 1);
    }
}
