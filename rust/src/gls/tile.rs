//! Full-tile GLS: run one `[C,L] × [K,C]` bit-serial GEMM through `K·L`
//! independent iPE simulators under a GAV schedule — the Rust equivalent of
//! the paper's Fig. 5 experimental setup (exact + approximate GLS runs).

use super::{GlsContext, GlsSim};
use crate::arch::{ArchConfig, GavSchedule, VoltageMode};
use crate::quant::PackedPlanes;

/// Trace of one tile: per-step exact and sampled iPE outputs, plus energy
/// accounting.
#[derive(Clone, Debug)]
pub struct TileTrace {
    /// Exact iPE outputs per step, `[T][K·L]` (row-major over k then l).
    pub exact: Vec<Vec<u16>>,
    /// GLS-sampled (possibly erroneous) outputs, same layout.
    pub sampled: Vec<Vec<u16>>,
    /// Per-step undervolted flag (copied from the schedule).
    pub approx: Vec<bool>,
    /// Switched capacitance × V² summed over the tile (arbitrary units) —
    /// the Parallel Array's dynamic energy for this tile.
    pub energy: f64,
    /// Same, but evaluated as if every step ran at `V_guard` (the exact
    /// baseline for the Fig. 6b power ratio).
    pub switched_cap_per_step: Vec<f64>,
}

/// Tile-level simulator: spawns fresh iPE instances per tile (registers
/// reset at context load, matching the error model's `prev = 0` start).
pub struct TileGls<'a> {
    ctx: &'a GlsContext,
    arch: ArchConfig,
    /// One long-lived simulator per iPE (reset per tile, not reallocated
    /// — §Perf).
    sims: Vec<GlsSim<'a>>,
    /// Base RNG stream so repeated tiles draw fresh metastability
    /// resolutions.
    tile_counter: u64,
}

impl<'a> TileGls<'a> {
    pub fn new(ctx: &'a GlsContext, arch: ArchConfig) -> Self {
        assert_eq!(ctx.nl.c_dim, arch.c_dim);
        let sims = (0..arch.k_dim * arch.l_dim)
            .map(|i| ctx.spawn(i as u64))
            .collect();
        Self {
            ctx,
            arch,
            sims,
            tile_counter: 0,
        }
    }

    /// Run one tile under the given schedule. `a`/`b` are the packed
    /// operands (their precisions define the step sequence).
    pub fn run_tile(&mut self, a: &PackedPlanes, b: &PackedPlanes, sched: &GavSchedule) -> TileTrace {
        let prec = sched.precision();
        assert_eq!((a.bits, b.bits), (prec.a_bits, prec.b_bits));
        let (c, l_dim, k_dim) = (self.arch.c_dim, a.n_vecs, b.n_vecs);
        assert!(l_dim <= self.arch.l_dim && k_dim <= self.arch.k_dim);
        let t_steps = prec.steps();
        self.tile_counter += 1;

        // Reset state per tile (registers reset at context load), reusing
        // the long-lived simulators.
        for sim in &mut self.sims {
            sim.reset();
        }
        let _ = &self.ctx;

        let mut exact = Vec::with_capacity(t_steps);
        let mut sampled = Vec::with_capacity(t_steps);
        let mut cap_per_step = Vec::with_capacity(t_steps);
        let mut energy = 0.0;
        let approx = sched.approx_mask();

        // Pre-extract per-plane bit vectors once per step.
        let mut a_cols: Vec<Vec<bool>> = vec![vec![false; c]; l_dim];
        let mut b_rows: Vec<Vec<bool>> = vec![vec![false; c]; k_dim];

        for (t, (ba, bb)) in prec.step_order().enumerate() {
            for (l, col) in a_cols.iter_mut().enumerate() {
                for (ci, bit) in col.iter_mut().enumerate() {
                    *bit = a.bit(ba, l, ci) == 1;
                }
            }
            for (k, row) in b_rows.iter_mut().enumerate() {
                for (ci, bit) in row.iter_mut().enumerate() {
                    *bit = b.bit(bb, k, ci) == 1;
                }
            }

            let v_dd = match sched.mode(t) {
                VoltageMode::Guarded => self.arch.v_guard,
                VoltageMode::Approximate => self.arch.v_aprox,
                VoltageMode::Level(_) => self.arch.v_aprox,
            };

            let mut ex = vec![0u16; k_dim * l_dim];
            let mut sa = vec![0u16; k_dim * l_dim];
            let mut cap = 0.0;
            for k in 0..k_dim {
                for l in 0..l_dim {
                    let idx = k * l_dim + l;
                    let r = self.sims[k * self.arch.l_dim + l].step(&a_cols[l], &b_rows[k], v_dd);
                    ex[idx] = r.exact;
                    sa[idx] = r.sampled;
                    cap += r.switched_cap;
                }
            }
            energy += cap * v_dd * v_dd;
            cap_per_step.push(cap);
            exact.push(ex);
            sampled.push(sa);
        }

        TileTrace {
            exact,
            sampled,
            approx,
            energy,
            switched_cap_per_step: cap_per_step,
        }
    }
}

impl TileTrace {
    /// Recombine the sampled sequence into the approximate GEMM result.
    pub fn approx_gemm(&self, prec: crate::arch::Precision) -> Vec<i64> {
        crate::gemm::recombine(&self.sampled, prec)
    }

    /// Recombine the exact sequence (must equal the integer GEMM).
    pub fn exact_gemm(&self, prec: crate::arch::Precision) -> Vec<i64> {
        crate::gemm::recombine(&self.exact, prec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Precision;
    use crate::gls::DelayModel;
    use crate::util::Prng;

    fn small_setup() -> (GlsContext, ArchConfig) {
        let arch = ArchConfig::tiny(); // [36, 4, 4]
        let ctx = GlsContext::new(
            arch.c_dim,
            arch.clk_period_ps() as f64,
            DelayModel::default(),
            11,
        );
        (ctx, arch)
    }

    fn rand_operands(
        rng: &mut Prng,
        arch: &ArchConfig,
        prec: Precision,
    ) -> (Vec<i32>, Vec<i32>, PackedPlanes, PackedPlanes) {
        let hi_a = (1i64 << (prec.a_bits - 1)) - 1;
        let hi_b = (1i64 << (prec.b_bits - 1)) - 1;
        let a: Vec<i32> = (0..arch.c_dim * arch.l_dim)
            .map(|_| rng.int_in(-hi_a - 1, hi_a) as i32)
            .collect();
        let b: Vec<i32> = (0..arch.k_dim * arch.c_dim)
            .map(|_| rng.int_in(-hi_b - 1, hi_b) as i32)
            .collect();
        let pa = PackedPlanes::from_a_matrix(&a, arch.c_dim, arch.l_dim, prec.a_bits);
        let pb = PackedPlanes::from_b_matrix(&b, arch.k_dim, arch.c_dim, prec.b_bits);
        (a, b, pa, pb)
    }

    #[test]
    fn fully_guarded_tile_is_exact() {
        let (ctx, arch) = small_setup();
        let prec = Precision::new(3, 3);
        let mut rng = Prng::new(5);
        let (a, b, pa, pb) = rand_operands(&mut rng, &arch, prec);
        let mut tg = TileGls::new(&ctx, arch.clone());
        let trace = tg.run_tile(&pa, &pb, &GavSchedule::all_guarded(prec));
        assert_eq!(trace.exact, trace.sampled);
        // And the recombined result equals the plain integer GEMM.
        let expect = crate::gemm::gemm_exact(&a, &b, arch.c_dim, arch.l_dim, arch.k_dim);
        assert_eq!(trace.approx_gemm(prec), expect);
        assert_eq!(trace.exact_gemm(prec), expect);
    }

    #[test]
    fn guarded_steps_within_mixed_schedule_are_exact() {
        let (ctx, arch) = small_setup();
        let prec = Precision::new(4, 4);
        let g = 3; // guard the top significances
        let sched = GavSchedule::two_level(prec, g);
        let mut rng = Prng::new(6);
        let (_, _, pa, pb) = rand_operands(&mut rng, &arch, prec);
        let mut tg = TileGls::new(&ctx, arch);
        let trace = tg.run_tile(&pa, &pb, &sched);
        for (t, &is_approx) in trace.approx.iter().enumerate() {
            if !is_approx {
                assert_eq!(
                    trace.exact[t], trace.sampled[t],
                    "guarded step {t} must be exact"
                );
            }
        }
    }

    #[test]
    fn error_decreases_with_g() {
        // VAR_NED of the recombined GEMM must shrink as G grows (Fig. 6a
        // shape) — checked on the tiny config with a modest sample.
        let (ctx, arch) = small_setup();
        let prec = Precision::new(4, 4);
        let mut rng = Prng::new(7);
        let (a, b, pa, pb) = rand_operands(&mut rng, &arch, prec);
        let exact = crate::gemm::gemm_exact(&a, &b, arch.c_dim, arch.l_dim, arch.k_dim);
        let mut tg = TileGls::new(&ctx, arch);
        let var_at = |tg: &mut TileGls, g: u32| {
            let trace = tg.run_tile(&pa, &pb, &GavSchedule::two_level(prec, g));
            crate::stats::var_ned(&exact, &trace.approx_gemm(prec))
        };
        let v0 = var_at(&mut tg, 0);
        let v_mid = var_at(&mut tg, 4);
        let v_max = var_at(&mut tg, prec.max_g());
        assert_eq!(v_max, 0.0, "fully guarded must be exact");
        assert!(
            v0 >= v_mid,
            "error must not grow with G: g0={v0} g4={v_mid}"
        );
        assert!(v0 > 0.0, "fully undervolted tiny tile should show errors");
    }

    #[test]
    fn undervolted_tile_consumes_less_energy() {
        let (ctx, arch) = small_setup();
        let prec = Precision::new(4, 4);
        let mut rng = Prng::new(8);
        let (_, _, pa, pb) = rand_operands(&mut rng, &arch, prec);
        let mut tg = TileGls::new(&ctx, arch);
        let e_guard = tg.run_tile(&pa, &pb, &GavSchedule::all_guarded(prec)).energy;
        let e_aprox = tg.run_tile(&pa, &pb, &GavSchedule::all_approx(prec)).energy;
        assert!(
            e_aprox < e_guard * 0.6,
            "undervolting must cut array energy: {e_aprox} vs {e_guard}"
        );
    }
}
