//! Event-driven, delay-annotated "gate-level simulation" (GLS) of the iPE
//! netlist under voltage scaling — the substitution for the paper's Cadence
//! GLS with post-layout SDF delays (DESIGN.md §Substitutions).
//!
//! ## Physics
//!
//! * **Voltage → delay**: alpha-power law. A gate's propagation delay at
//!   supply `V` scales by `d(V)/d(V_nom)` with `d(V) = V/(V−V_th)^α`.
//!   The library is "characterized" at `V_nom = V_guard` (as in §IV-A, the
//!   EDA flow closes timing at `V_guard` only), so the factor is 1 at
//!   `V_guard` and ≈2.3 at `V_aprox = 0.35 V` — the MSB carry chains blow
//!   through the 20 ns clock period while short LSB paths still settle.
//! * **Inertial delay**: each gate holds at most one pending output event;
//!   an input change that reverts the gate's target value before the event
//!   matures cancels it. This filters glitches — and, because slower gates
//!   filter *more* glitches, dynamic switching activity drops under
//!   undervolting beyond the V² factor, which is how the paper's ×3.5
//!   approximate-region power reduction (Fig. 6b) emerges from simulation
//!   instead of being hardcoded.
//! * **Clock-edge sampling**: outputs are sampled every `T_clk`; an output
//!   with a transition in flight inside the synchronizer's setup window
//!   resolves randomly (the 2-stage synchronizers of §III make the outcome
//!   clean but arbitrary). Signal state persists across cycles — late
//!   events from an undervolted step keep propagating into the next step,
//!   exactly like the real circuit ("previous value dependency", §IV-C).
//! * **Energy accounting**: every applied transition dissipates
//!   `cap(gate) · V²` (arbitrary capacitance units, calibrated to the
//!   paper's power numbers by [`crate::power`]).

pub mod tile;

pub use tile::TileGls;

use crate::netlist::Netlist;
use crate::util::Prng;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Heap events are packed `time << GATE_BITS | gate` (8-byte nodes sift
/// ~2x faster than 24-byte tuples; staleness is detected by comparing the
/// event time against the gate's current `pending_t`).
const GATE_BITS: u32 = 17;

/// Alpha-power-law voltage/delay model (12 nm-class parameters).
#[derive(Clone, Copy, Debug)]
pub struct DelayModel {
    /// Threshold voltage [V].
    pub v_th: f64,
    /// Velocity-saturation exponent.
    pub alpha: f64,
    /// Characterization voltage (delay factor 1.0 here).
    pub v_nom: f64,
}

impl Default for DelayModel {
    /// Calibrated so `V_aprox = 0.35 V` inflates delays ×≈1.37 — the
    /// paper's netlist demonstrably *functions* at 0.35 V with moderate
    /// error rates (Fig. 6a/7b show structured errors, not uniform
    /// garbage), which bounds how far past the clock its critical path
    /// can land. An LVT-class threshold reproduces that operating point;
    /// with the synthesis margin (0.93) the slowest ~25% of paths miss
    /// timing at `V_aprox`, so errors concentrate in the deep carry
    /// chains exactly as §IV-C describes.
    fn default() -> Self {
        Self {
            v_th: 0.10,
            alpha: 1.3,
            v_nom: 0.55,
        }
    }
}

impl DelayModel {
    /// Delay multiplier at supply `v` relative to `v_nom`.
    pub fn factor(&self, v: f64) -> f64 {
        assert!(
            v > self.v_th + 0.01,
            "supply {v} V too close to threshold {} V",
            self.v_th
        );
        let d = |x: f64| x / (x - self.v_th).powf(self.alpha);
        d(v) / d(self.v_nom)
    }
}

/// Result of simulating one clock cycle of one iPE.
#[derive(Clone, Copy, Debug)]
pub struct StepResult {
    /// The value the synchronizer stage sampled at the clock edge.
    pub sampled: u16,
    /// The exact (zero-delay) value for the same inputs.
    pub exact: u16,
    /// Switched capacitance this cycle (arbitrary units; × V² = energy).
    pub switched_cap: f64,
    /// Number of gate output transitions applied this cycle.
    pub n_transitions: u64,
}

/// Time unit: 1/16 ps (fixed-point, keeps the heap keys integral).
const TICKS_PER_PS: f64 = 16.0;

/// Fraction of `T_clk` the critical path occupies at `V_guard` (the
/// synthesis margin the EDA flow would leave).
pub const TIMING_MARGIN: f64 = 0.93;

/// Synchronizer setup window [ps]: a transition landing this close after
/// the clock edge makes the sample resolve randomly.
const SETUP_WINDOW_PS: f64 = 12.0;

/// Event-driven simulator state for one iPE instance.
///
/// The netlist and per-gate nominal delays are borrowed so a whole tile
/// ([`TileGls`]) shares them across its `K·L` iPEs.
pub struct GlsSim<'a> {
    nl: &'a Netlist,
    fanout_off: &'a [u32],
    fanout_idx: &'a [u32],
    /// Per-gate delay in ticks at `V_nom` (process variation included).
    delay_ticks: &'a [u64],
    /// Current net values.
    values: Vec<bool>,
    /// Pending-event bookkeeping: target value + maturity time per gate
    /// (inertial delay: at most one pending event per gate).
    pending_val: Vec<bool>,
    pending_t: Vec<u64>,
    has_pending: Vec<bool>,
    heap: BinaryHeap<Reverse<u64>>,
    /// Current absolute time in ticks.
    now: u64,
    clk_ticks: u64,
    model: DelayModel,
    rng: Prng,
    /// Accumulators for the current cycle.
    switched_cap: f64,
    n_transitions: u64,
}

/// Shared per-netlist context: delays calibrated against the clock.
pub struct GlsContext {
    pub nl: Netlist,
    /// CSR fanout: gate indices driven by net `n` are
    /// `fanout_idx[fanout_off[n]..fanout_off[n+1]]` (flat layout — one
    /// cache line instead of a Vec-of-Vecs pointer chase; §Perf).
    pub fanout_off: Vec<u32>,
    pub fanout_idx: Vec<u32>,
    pub delay_ticks: Vec<u64>,
    pub model: DelayModel,
    pub clk_period_ps: f64,
    /// Critical path at `V_nom` in ps (after calibration:
    /// `TIMING_MARGIN · clk_period`).
    pub critical_path_ps: f64,
}

impl GlsContext {
    /// Build and calibrate: per-gate delays get a global scale such that
    /// the slowest output settles at `TIMING_MARGIN · T_clk` under
    /// `V_nom` — i.e. the design just meets timing at `V_guard`, like the
    /// paper's backend flow.
    pub fn new(c_dim: usize, clk_period_ps: f64, model: DelayModel, seed: u64) -> Self {
        let nl = crate::netlist::build_ipe(c_dim);
        assert!(
            nl.gates.len() < (1 << GATE_BITS),
            "netlist too large for packed heap keys"
        );
        let mut rng = Prng::new(seed ^ 0x61_5f_67_6c_73);
        let raw = nl.gate_delays(0.08, &mut rng);
        let cp_raw = nl.critical_path(&raw);
        let scale = TIMING_MARGIN * clk_period_ps / cp_raw;
        let delay_ticks: Vec<u64> = raw
            .iter()
            .map(|d| ((d * scale * TICKS_PER_PS).round() as u64).max(1))
            .collect();
        let delays_ps: Vec<f64> = delay_ticks
            .iter()
            .map(|&t| t as f64 / TICKS_PER_PS)
            .collect();
        let critical_path_ps = nl.critical_path(&delays_ps);
        let fo = nl.fanout();
        let mut fanout_off = Vec::with_capacity(fo.len() + 1);
        let mut fanout_idx = Vec::new();
        fanout_off.push(0u32);
        for list in &fo {
            fanout_idx.extend_from_slice(list);
            fanout_off.push(fanout_idx.len() as u32);
        }
        Self {
            nl,
            fanout_off,
            fanout_idx,
            delay_ticks,
            model,
            clk_period_ps,
            critical_path_ps,
        }
    }

    /// Spawn one iPE simulator (its own signal state + RNG stream).
    pub fn spawn(&self, stream: u64) -> GlsSim<'_> {
        GlsSim {
            nl: &self.nl,
            fanout_off: &self.fanout_off,
            fanout_idx: &self.fanout_idx,
            delay_ticks: &self.delay_ticks,
            values: vec![false; self.nl.n_nets],
            pending_val: vec![false; self.nl.gates.len()],
            pending_t: vec![0; self.nl.gates.len()],
            has_pending: vec![false; self.nl.gates.len()],
            heap: BinaryHeap::with_capacity(1024),
            now: 0,
            clk_ticks: (self.clk_period_ps * TICKS_PER_PS) as u64,
            model: self.model,
            rng: Prng::new(0x1b9_d5b5 ^ stream.wrapping_mul(0x9E3779B97F4A7C15)),
            switched_cap: 0.0,
            n_transitions: 0,
        }
    }
}

impl<'a> GlsSim<'a> {
    /// Evaluate gate `gi` on current values.
    #[inline]
    fn eval_gate(&self, gi: usize) -> bool {
        let g = &self.nl.gates[gi];
        let a = self.values[g.inputs[0] as usize];
        let b = if g.kind.n_inputs() == 2 {
            self.values[g.inputs[1] as usize]
        } else {
            false
        };
        g.kind.eval(a, b)
    }

    /// Inertial-delay scheduling after net `net` changed at time `t`
    /// (ticks), with the current cycle's delay factor.
    #[inline]
    fn schedule_fanout(&mut self, net: u32, t: u64, vf: f64) {
        // Shared references with the context's lifetime: copying them out
        // releases the borrow on `self`.
        let (off, idx) = (self.fanout_off, self.fanout_idx);
        for &gi32 in &idx[off[net as usize] as usize..off[net as usize + 1] as usize] {
            let gi = gi32 as usize;
            let new_val = self.eval_gate(gi);
            let cur = self.values[self.nl.gates[gi].out as usize];
            if self.has_pending[gi] {
                if new_val == self.pending_val[gi] {
                    continue; // already heading there
                }
                if new_val == cur {
                    // Glitch filtered: cancel the pending event (the stale
                    // heap entry is skipped at pop via pending_t mismatch).
                    self.has_pending[gi] = false;
                    continue;
                }
                // Retarget: fall through and push a replacement event.
            } else if new_val == cur {
                continue;
            }
            let delay = (self.delay_ticks[gi] as f64 * vf) as u64;
            let t_ev = t + delay.max(1);
            self.has_pending[gi] = true;
            self.pending_val[gi] = new_val;
            self.pending_t[gi] = t_ev;
            self.heap.push(Reverse((t_ev << GATE_BITS) | gi32 as u64));
        }
    }

    /// Pop and apply all events with `time <= until`.
    fn run_until(&mut self, until: u64, vf: f64) {
        while let Some(&Reverse(key)) = self.heap.peek() {
            let t = key >> GATE_BITS;
            if t > until {
                break;
            }
            self.heap.pop();
            let gi = (key & ((1u64 << GATE_BITS) - 1)) as usize;
            if !self.has_pending[gi] || self.pending_t[gi] != t {
                continue; // stale (cancelled or retargeted)
            }
            self.has_pending[gi] = false;
            let out = self.nl.gates[gi].out;
            let v = self.pending_val[gi];
            if self.values[out as usize] != v {
                self.values[out as usize] = v;
                self.switched_cap += self.nl.gates[gi].kind.cap();
                self.n_transitions += 1;
                self.schedule_fanout(out, t, vf);
            }
        }
    }

    /// Simulate one clock cycle: apply the new input planes at the current
    /// clock edge, run the circuit for `T_clk` at supply `v_dd`, and sample
    /// the sum outputs at the next edge.
    pub fn step(&mut self, a_bits: &[bool], w_bits: &[bool], v_dd: f64) -> StepResult {
        debug_assert_eq!(a_bits.len(), self.nl.c_dim);
        debug_assert_eq!(w_bits.len(), self.nl.c_dim);
        let vf = self.model.factor(v_dd);
        self.switched_cap = 0.0;
        self.n_transitions = 0;

        let t0 = self.now;
        // Input registers launch the new operands at the clock edge.
        let c = self.nl.c_dim;
        for i in 0..c {
            if self.values[i] != a_bits[i] {
                self.values[i] = a_bits[i];
                self.schedule_fanout(i as u32, t0, vf);
            }
        }
        for i in 0..c {
            let net = c + i;
            if self.values[net] != w_bits[i] {
                self.values[net] = w_bits[i];
                self.schedule_fanout(net as u32, t0, vf);
            }
        }

        let ts = t0 + self.clk_ticks;
        self.run_until(ts, vf);

        // Sample at the edge; in-flight transitions within the setup
        // window resolve randomly in the synchronizer.
        let setup_ticks = (SETUP_WINDOW_PS * TICKS_PER_PS) as u64;
        let mut sampled: u16 = 0;
        for (i, &net) in self.nl.outputs.iter().enumerate() {
            let mut bit = self.values[net as usize];
            // Find the driving gate's pending event (outputs are gate
            // outputs; gate index = net - 2C offset is not direct, so we
            // check pending on the unique driver).
            let driver = (net as usize) - 2 * c; // gate gi drives net 2C+gi
            if self.has_pending[driver] && self.pending_t[driver] <= ts + setup_ticks {
                // In-flight transition maturing inside the setup window:
                // the synchronizer resolves to an arbitrary clean value.
                bit = self.rng.chance(0.5);
            }
            sampled |= (bit as u16) << i;
        }

        let exact = self.nl.eval(a_bits, w_bits) as u16;
        self.now = ts;
        StepResult {
            sampled,
            exact,
            switched_cap: self.switched_cap,
            n_transitions: self.n_transitions,
        }
    }

    /// Reset to the power-on state (all nets low, no pending events) —
    /// lets a long-lived simulator be reused across contexts without
    /// reallocating (§Perf: TileGls reuses `K·L` simulators).
    pub fn reset(&mut self) {
        self.values.iter_mut().for_each(|v| *v = false);
        self.has_pending.iter_mut().for_each(|v| *v = false);
        self.heap.clear();
        self.now = 0;
    }

    /// Let the circuit settle completely (used by tests and between
    /// contexts): processes every remaining event.
    pub fn settle(&mut self, v_dd: f64) {
        let vf = self.model.factor(v_dd);
        self.run_until(u64::MAX, vf);
        self.now = self.now.max(
            self.heap
                .iter()
                .map(|Reverse(k)| k >> GATE_BITS)
                .max()
                .unwrap_or(self.now),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchConfig;

    fn ctx(c: usize) -> GlsContext {
        let arch = ArchConfig::paper();
        GlsContext::new(c, arch.clk_period_ps() as f64, DelayModel::default(), 7)
    }

    #[test]
    fn delay_factor_shape() {
        let m = DelayModel::default();
        assert!((m.factor(0.55) - 1.0).abs() < 1e-12);
        let f35 = m.factor(0.35);
        assert!(f35 > 1.2 && f35 < 1.6, "factor(0.35V) = {f35}");
        assert!(m.factor(0.45) > 1.0 && m.factor(0.45) < f35);
        assert!(m.factor(0.70) < 1.0);
    }

    #[test]
    fn calibration_puts_critical_path_at_margin() {
        let c = ctx(576);
        let ratio = c.critical_path_ps / c.clk_period_ps;
        assert!(
            (ratio - TIMING_MARGIN).abs() < 0.02,
            "critical path ratio {ratio}"
        );
    }

    #[test]
    fn guarded_voltage_is_exact() {
        // At V_guard the design meets timing: every sample equals the
        // zero-delay value, for many random input planes.
        let ctx = ctx(128);
        let mut sim = ctx.spawn(0);
        let mut rng = Prng::new(42);
        for _ in 0..50 {
            let a: Vec<bool> = (0..128).map(|_| rng.chance(0.5)).collect();
            let w: Vec<bool> = (0..128).map(|_| rng.chance(0.5)).collect();
            let r = sim.step(&a, &w, 0.55);
            assert_eq!(r.sampled, r.exact, "guarded step must be exact");
        }
    }

    #[test]
    fn undervolting_causes_errors() {
        let ctx = ctx(576);
        let mut sim = ctx.spawn(0);
        let mut rng = Prng::new(43);
        let mut errors = 0;
        let n = 200;
        for i in 0..n {
            // Sweep density so the sums cross power-of-two boundaries —
            // that is where the deep final-CPA carry chains switch and
            // miss timing (§IV-C "locations near power-of-two values").
            let p = 0.05 + 0.9 * ((i % 25) as f64 / 24.0);
            let a: Vec<bool> = (0..576).map(|_| rng.chance(p)).collect();
            let w: Vec<bool> = (0..576).map(|_| rng.chance(0.9)).collect();
            let r = sim.step(&a, &w, 0.35);
            if r.sampled != r.exact {
                errors += 1;
            }
        }
        assert!(
            errors > n / 20,
            "aggressive undervolting produced only {errors}/{n} erroneous samples"
        );
    }

    #[test]
    fn errors_concentrate_in_deep_bits() {
        // The carry-chain physics (paper §IV-C "bit dependency"): under a
        // *moderate* undervolt only the deepest paths miss timing, so the
        // conditional error rate of a bit — flips divided by the steps
        // where that bit actually had to transition — must grow with
        // significance. (Unconditioned rates are dominated by how often a
        // bit toggles at all: with density-0.5 inputs the sums concentrate
        // around C/4 and the MSBs never move.)
        let ctx = ctx(576);
        let mut sim = ctx.spawn(1);
        let mut rng = Prng::new(44);
        let s_bits = ctx.nl.outputs.len();
        let mut toggles = vec![0u32; s_bits];
        let mut flips = vec![0u32; s_bits];
        let mut prev_exact = 0u16;
        for i in 0..600 {
            // Sweep input density so the exact sums cover the full 0..=C
            // range and every output bit gets exercised.
            let p = 0.05 + 0.9 * ((i % 20) as f64 / 19.0);
            let a: Vec<bool> = (0..576).map(|_| rng.chance(p)).collect();
            let w: Vec<bool> = (0..576).map(|_| rng.chance(0.9)).collect();
            let r = sim.step(&a, &w, 0.38);
            for bit in 0..s_bits {
                let need = ((r.exact ^ prev_exact) >> bit) & 1 == 1;
                let flip = ((r.exact ^ r.sampled) >> bit) & 1 == 1;
                toggles[bit] += need as u32;
                flips[bit] += flip as u32;
            }
            prev_exact = r.exact;
        }
        let cond = |b: usize| flips[b] as f64 / toggles[b].max(1) as f64;
        let low = (cond(0) + cond(1) + cond(2)) / 3.0;
        let high = (cond(s_bits - 3) + cond(s_bits - 2) + cond(s_bits - 1)) / 3.0;
        assert!(
            high > low + 0.02,
            "deep-bit conditional error rate {high:.4} must exceed shallow {low:.4} \
             (flips {flips:?} / toggles {toggles:?})"
        );
    }

    #[test]
    fn moderate_undervolt_less_errors_than_aggressive() {
        let ctx = ctx(576);
        let mut rng = Prng::new(45);
        let planes: Vec<(Vec<bool>, Vec<bool>)> = (0..80)
            .map(|_| {
                (
                    (0..576).map(|_| rng.chance(0.5)).collect(),
                    (0..576).map(|_| rng.chance(0.5)).collect(),
                )
            })
            .collect();
        let count_err = |v: f64| {
            let mut sim = ctx.spawn(2);
            planes
                .iter()
                .filter(|(a, w)| {
                    let r = sim.step(a, w, v);
                    r.sampled != r.exact
                })
                .count()
        };
        let e_45 = count_err(0.45);
        let e_35 = count_err(0.35);
        assert!(
            e_45 < e_35,
            "errors must grow as voltage drops: {e_45} @0.45V vs {e_35} @0.35V"
        );
    }

    #[test]
    fn switching_activity_does_not_grow_under_undervolting() {
        // Uniform delay scaling stretches glitch pulses along with gate
        // delays, so the transition count stays ~flat (it drops slightly
        // when the next input wave cancels unsettled events). The dynamic
        // energy saving is the V² factor; the paper's extra margin to
        // ×3.5 comes from leakage, modelled in `crate::power`.
        let ctx = ctx(576);
        let mut rng = Prng::new(46);
        let planes: Vec<(Vec<bool>, Vec<bool>)> = (0..60)
            .map(|_| {
                (
                    (0..576).map(|_| rng.chance(0.5)).collect(),
                    (0..576).map(|_| rng.chance(0.5)).collect(),
                )
            })
            .collect();
        let total_cap = |v: f64| {
            let mut sim = ctx.spawn(3);
            planes
                .iter()
                .map(|(a, w)| sim.step(a, w, v).switched_cap)
                .sum::<f64>()
        };
        let cap_guard = total_cap(0.55);
        let cap_aprox = total_cap(0.35);
        assert!(
            cap_aprox < cap_guard * 1.02,
            "switched cap must not grow: {cap_aprox} vs {cap_guard}"
        );
        // Dynamic energy (cap·V²) must drop by ~the V² ratio.
        let e_ratio = (cap_aprox * 0.35 * 0.35) / (cap_guard * 0.55 * 0.55);
        assert!(e_ratio < 0.45, "dynamic energy ratio {e_ratio}");
    }

    #[test]
    fn deterministic_given_stream() {
        let ctx = ctx(200);
        let mut rng = Prng::new(47);
        let planes: Vec<(Vec<bool>, Vec<bool>)> = (0..20)
            .map(|_| {
                (
                    (0..200).map(|_| rng.chance(0.5)).collect(),
                    (0..200).map(|_| rng.chance(0.5)).collect(),
                )
            })
            .collect();
        let run = || {
            let mut sim = ctx.spawn(9);
            planes
                .iter()
                .map(|(a, w)| sim.step(a, w, 0.35).sampled)
                .collect::<Vec<u16>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn zero_inputs_settle_to_zero() {
        let ctx = ctx(64);
        let mut sim = ctx.spawn(0);
        let z = vec![false; 64];
        let r = sim.step(&z, &z, 0.35);
        assert_eq!(r.sampled, 0);
        assert_eq!(r.exact, 0);
        assert_eq!(r.n_transitions, 0, "no activity for constant inputs");
    }
}
