//! Cycle-level simulator of the GAVINA accelerator (paper §III, Fig. 3).
//!
//! Models the controller FSM, the double-buffered A0/B0 plane memories,
//! the Parallel Array, the L0/L1 two-stage shift-accumulate, the P-memory
//! partial-sum accumulation across C-chunks, and the DVS module driving
//! the GAV schedule — at one-cycle granularity, with access counting for
//! the power model and an optional error-model hook for undervolted steps.
//!
//! ## Timing model
//!
//! * One bit-plane GEMM per cycle (the Parallel Array).
//! * A tile (context) takes `a_bits·b_bits` compute cycles; the next
//!   context's planes load into the shadow A0/B0 buffers concurrently
//!   (`max(a_bits, b_bits)` cycles ≤ steps, so loads are always hidden —
//!   "double-buffered to avoid stalls during context switches").
//! * `FILL` cycles at the start (first context load) and one `DRAIN`
//!   cycle at the end (final L0→L1 flush) are the only overheads, plus
//!   padding waste when the workload dimensions don't divide the array
//!   shape — this is what puts sustained throughput a few % under the
//!   Table I peak (Table II reports 1.774 of 1.84 TOP/s at a2w2).

use crate::arch::{ArchConfig, GavSchedule, VoltageMode};
use crate::errmodel::ErrorTables;
use crate::gemm;
use crate::power::PowerModel;
use crate::quant::{InterleavedPlanes, PackedPlanes};
use crate::util::{ceil_div, Prng};

/// A GEMM job: `P[K,L] = B[K,C] · A[C,L]` at a precision/schedule.
#[derive(Clone, Debug)]
pub struct GemmJob<'a> {
    /// Activations `[C, L]` row-major.
    pub a: &'a [i32],
    /// Weights `[K, C]` row-major.
    pub b: &'a [i32],
    pub c: usize,
    pub l: usize,
    pub k: usize,
    pub sched: GavSchedule,
}

/// Cycle/energy/throughput report of one simulated job.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Result `[K, L]` row-major.
    pub p: Vec<i64>,
    /// Total cycles including fill/drain.
    pub cycles: u64,
    /// Hardware tiles executed (including padded ones).
    pub n_tiles: u64,
    /// Undervolted / guarded compute steps.
    pub steps_approx: u64,
    pub steps_guarded: u64,
    /// A0/B0 plane reads (two per compute cycle).
    pub a0b0_reads: u64,
    /// Tile bursts (L1 flush + A1/B1/P traffic).
    pub tile_bursts: u64,
    /// iPE outputs modified by the error model.
    pub values_corrupted: u64,
    /// Useful MACs (the logical GEMM).
    pub useful_macs: u64,
    /// Executed MACs (including padding).
    pub executed_macs: u64,
}

impl SimReport {
    /// Sustained-throughput utilization vs the array peak: useful MACs per
    /// cycle over the peak MACs per cycle.
    pub fn utilization(&self, arch: &ArchConfig, sched: &GavSchedule) -> f64 {
        let peak_per_cycle = arch.macs_per_tile() as f64 / sched.precision().steps() as f64;
        (self.useful_macs as f64 / self.cycles as f64) / peak_per_cycle
    }

    /// Sustained TOP/s at the architecture clock.
    pub fn sustained_tops(&self, arch: &ArchConfig) -> f64 {
        2.0 * self.useful_macs as f64 / (self.cycles as f64 / arch.freq_hz) / 1e12
    }

    /// Energy for this job under a power model [mJ].
    pub fn energy_mj(&self, power: &PowerModel, sched: &GavSchedule) -> f64 {
        power.energy_mj(sched, self.cycles)
    }

    /// Observed step-error rate: iPE outputs the error model corrupted
    /// per undervolted step executed (0.0 when nothing ran undervolted).
    /// The canary estimator surfaces this per layer at serving time.
    pub fn step_error_rate(&self) -> f64 {
        if self.steps_approx == 0 {
            0.0
        } else {
            self.values_corrupted as f64 / self.steps_approx as f64
        }
    }
}

/// Where undervolting errors come from during approximate steps.
pub enum ErrorSource<'t> {
    /// Ideal (error-free) hardware even on approximate steps — used for
    /// throughput studies.
    None,
    /// The calibrated LUT error model (§IV-C) — the fast path.
    Tables(&'t ErrorTables),
    /// Full gate-level simulation of every tile (§IV-B, Fig. 5) — the
    /// ground truth, orders of magnitude slower.
    Gls(&'t crate::gls::GlsContext),
}

/// The cycle-level machine.
pub struct GavinaSim<'t> {
    pub arch: ArchConfig,
    errors: ErrorSource<'t>,
    rng: Prng,
}

/// Pipeline fill: first context load cannot be hidden.
fn fill_cycles(sched: &GavSchedule) -> u64 {
    let p = sched.precision();
    p.a_bits.max(p.b_bits) as u64
}

/// Final L0→L1 flush.
const DRAIN_CYCLES: u64 = 1;

impl<'t> GavinaSim<'t> {
    pub fn new(arch: ArchConfig, tables: Option<&'t ErrorTables>, seed: u64) -> Self {
        let errors = match tables {
            Some(t) => ErrorSource::Tables(t),
            None => ErrorSource::None,
        };
        Self {
            arch,
            errors,
            rng: Prng::new(seed ^ 0x9A51_A001),
        }
    }

    /// A machine whose undervolted steps are simulated by the gate-level
    /// timing simulator itself (the Fig. 5 exact+approximate-GLS setup).
    pub fn new_gls(arch: ArchConfig, ctx: &'t crate::gls::GlsContext, seed: u64) -> Self {
        assert_eq!(ctx.nl.c_dim, arch.c_dim, "GLS netlist must match the array C");
        Self {
            arch,
            errors: ErrorSource::Gls(ctx),
            rng: Prng::new(seed ^ 0x9A51_A001),
        }
    }

    /// Run one GEMM job through the tiled bit-serial pipeline.
    ///
    /// Convenience wrapper over [`Self::run_planes`] for raw integer
    /// operands (benches, CLI workloads): packs each matrix **once**,
    /// then carves hardware tiles out of the packed planes. Bit-identical
    /// to the old per-tile packing path.
    pub fn run_gemm(&mut self, job: &GemmJob) -> SimReport {
        let prec = job.sched.precision();
        assert_eq!(job.a.len(), job.c * job.l);
        assert_eq!(job.b.len(), job.k * job.c);
        let pa = PackedPlanes::from_a_matrix(job.a, job.c, job.l, prec.a_bits);
        let pb = PackedPlanes::from_b_matrix(job.b, job.k, job.c, prec.b_bits);
        self.run_planes(&pa, &pb, &job.sched)
    }

    /// Run one GEMM over **pre-packed** bit-planes — the compile-once
    /// data plane entry point. `a` is `[C, L]` (packed once per layer per
    /// request by the executor), `b` is `[K, C]` (packed once for the
    /// model's lifetime at `EngineBuilder::build()`). Hardware tiles are
    /// carved out word-wise with [`PackedPlanes::extract_tile`]; nothing
    /// is re-quantized or re-packed here.
    pub fn run_planes(
        &mut self,
        a: &PackedPlanes,
        b: &PackedPlanes,
        sched: &GavSchedule,
    ) -> SimReport {
        let arch = &self.arch;
        let prec = sched.precision();
        assert_eq!(a.c_dim, b.c_dim, "reduction axis mismatch");
        assert_eq!(
            (a.bits, b.bits),
            (prec.a_bits, prec.b_bits),
            "operand planes vs schedule precision"
        );
        let (c, l, k) = (a.c_dim, a.n_vecs, b.n_vecs);

        let (ct, lt, kt) = (
            ceil_div(c, arch.c_dim),
            ceil_div(l, arch.l_dim),
            ceil_div(k, arch.k_dim),
        );
        let steps = prec.steps() as u64;
        let approx_mask = sched.approx_mask();
        let n_approx_per_tile = approx_mask.iter().filter(|&&x| x).count() as u64;

        let mut p = vec![0i64; k * l];
        let mut n_tiles = 0u64;
        let mut corrupted = 0u64;

        // Resolved once per job: the fused micro-kernel retires every
        // guarded (exact) step of a tile in one pass over memory; only
        // steps that are undervolted — or feed the error model's `prev`
        // conditioning of an undervolted successor — still materialize a
        // per-step iPE output buffer.
        let guard_mask: Vec<bool> = approx_mask.iter().map(|&x| !x).collect();
        let need_step: Vec<bool> = (0..approx_mask.len())
            .map(|t| approx_mask[t] || approx_mask.get(t + 1).copied().unwrap_or(false))
            .collect();
        // One step buffer + one `prev` buffer, reused across every step
        // of every tile (tiles are always the full array shape).
        let tile_n = arch.k_dim * arch.l_dim;
        let mut cur = vec![0u16; tile_n];
        let mut prev = vec![0u16; tile_n];

        // Will any tile take a fused path? Fully guarded schedules always
        // do; with undervolted steps, GLS runs full step sequences and a
        // fully undervolted Tables schedule has no guarded steps left to
        // fuse — interleaved tile copies are built only when some fused
        // work will actually consume them.
        let n_guarded_per_tile = approx_mask.len() as u64 - n_approx_per_tile;
        let fusing = n_approx_per_tile == 0
            || match &self.errors {
                ErrorSource::None => true,
                ErrorSource::Tables(_) => n_guarded_per_tile > 0,
                ErrorSource::Gls(_) => false,
            };

        // Carve every operand tile exactly once: A tiles depend on
        // (lo, co) and are revisited every K-row, B tiles depend on
        // (ko, co) and are revisited every L-column. Fusing runs keep
        // each tile in both layouts — plane-major for the step-sequence
        // path, interleaved for the fused kernel — so the A-tile cache
        // costs up to twice the packed A matrix.
        let a_tiles: Vec<(PackedPlanes, Option<InterleavedPlanes>)> = (0..lt * ct)
            .map(|i| {
                let (lo, co) = (i / ct, i % ct);
                let t = a.extract_tile(co * arch.c_dim, arch.c_dim, lo * arch.l_dim, arch.l_dim);
                let ti = fusing.then(|| InterleavedPlanes::from_packed(&t));
                (t, ti)
            })
            .collect();

        // Controller loop: output tile (ko, lo) outer, C-chunk inner (the
        // P memory accumulates partial sums across C-chunks).
        for ko in 0..kt {
            let b_tiles: Vec<(PackedPlanes, Option<InterleavedPlanes>)> = (0..ct)
                .map(|co| {
                    let t =
                        b.extract_tile(co * arch.c_dim, arch.c_dim, ko * arch.k_dim, arch.k_dim);
                    let ti = fusing.then(|| InterleavedPlanes::from_packed(&t));
                    (t, ti)
                })
                .collect();
            for lo in 0..lt {
                for co in 0..ct {
                    n_tiles += 1;
                    let (pa, ia) = &a_tiles[lo * ct + co];
                    let (pb, ib) = &b_tiles[co];
                    // Every arm below that fuses runs only when `fusing`
                    // is true, i.e. the interleaved copies exist.
                    let inter = || {
                        (
                            ia.as_ref().expect("interleaved A tile on fusing path"),
                            ib.as_ref().expect("interleaved B tile on fusing path"),
                        )
                    };
                    let tile_p: Vec<i64> = if n_approx_per_tile == 0 {
                        // A fully guarded schedule is exact by definition
                        // — the whole significance loop fuses, whatever
                        // the error source (skipping a possibly very
                        // expensive GLS run).
                        let (ia, ib) = inter();
                        gemm::kernel::fused_gemm(ia, ib)
                    } else {
                        match &self.errors {
                            ErrorSource::None => {
                                let (ia, ib) = inter();
                                gemm::kernel::fused_gemm(ia, ib)
                            }
                            ErrorSource::Tables(tables) => {
                                let mut tile_rng = self.rng.fork(n_tiles);
                                // Guarded steps in one fused pass; the
                                // undervolted LSB combinations stream
                                // through the reused step buffer, with
                                // `prev` tracking the exact outputs the
                                // injection LUT conditions on.
                                let mut tile_p = if n_guarded_per_tile > 0 {
                                    let (ia, ib) = inter();
                                    gemm::kernel::fused_gemm_masked(ia, ib, &guard_mask)
                                } else {
                                    // Fully undervolted: every step is
                                    // materialized + injected below.
                                    vec![0i64; tile_n]
                                };
                                prev.fill(0);
                                for (t, (ba, bb)) in prec.step_order().enumerate() {
                                    if !need_step[t] {
                                        continue;
                                    }
                                    gemm::binary_plane_gemm(pa, ba, pb, bb, &mut cur);
                                    if approx_mask[t] {
                                        corrupted +=
                                            tables.inject_step(&mut cur, &mut prev, &mut tile_rng);
                                        // L1 shift-accumulate of the
                                        // (possibly corrupted) step.
                                        let w = prec.step_weight(ba, bb);
                                        for (pi, &s) in tile_p.iter_mut().zip(&cur) {
                                            *pi += w * s as i64;
                                        }
                                    } else {
                                        prev.copy_from_slice(&cur);
                                    }
                                }
                                tile_p
                            }
                            ErrorSource::Gls(ctx) => {
                                let mut tg = crate::gls::TileGls::new(ctx, self.arch.clone());
                                let trace = tg.run_tile(pa, pb, sched);
                                corrupted += trace
                                    .exact
                                    .iter()
                                    .zip(&trace.sampled)
                                    .flat_map(|(e, s)| e.iter().zip(s))
                                    .filter(|(e, s)| e != s)
                                    .count() as u64;
                                gemm::recombine(&trace.sampled, prec)
                            }
                        }
                    };
                    self.accumulate(&mut p, &tile_p, l, k, lo, ko);
                }
            }
        }

        let compute_cycles = n_tiles * steps;
        let cycles = fill_cycles(sched) + compute_cycles + DRAIN_CYCLES;
        SimReport {
            p,
            cycles,
            n_tiles,
            steps_approx: n_tiles * n_approx_per_tile,
            steps_guarded: n_tiles * (steps - n_approx_per_tile),
            a0b0_reads: 2 * compute_cycles,
            tile_bursts: n_tiles,
            values_corrupted: corrupted,
            useful_macs: (c * l * k) as u64,
            executed_macs: n_tiles * arch.macs_per_tile() as u64,
        }
    }

    /// P-memory accumulation of one tile's partial result into the
    /// `[K, L]` output (`l_dim`/`k_dim` are the full GEMM dims).
    fn accumulate(
        &self,
        p: &mut [i64],
        tile_p: &[i64],
        l_dim: usize,
        k_dim: usize,
        lo: usize,
        ko: usize,
    ) {
        let arch = &self.arch;
        let (l0, k0) = (lo * arch.l_dim, ko * arch.k_dim);
        for k in 0..arch.k_dim.min(k_dim - k0) {
            for l in 0..arch.l_dim.min(l_dim - l0) {
                p[(k0 + k) * l_dim + (l0 + l)] += tile_p[k * arch.l_dim + l];
            }
        }
    }
}

/// The DVS module's voltage trace for one tile (diagnostics / the Fig. 3
/// control-sequence rendering in the CLI).
pub fn dvs_trace(arch: &ArchConfig, sched: &GavSchedule) -> Vec<f64> {
    (0..sched.precision().steps())
        .map(|t| match sched.mode(t) {
            VoltageMode::Guarded => arch.v_guard,
            VoltageMode::Approximate => arch.v_aprox,
            VoltageMode::Level(_) => arch.v_aprox,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Precision;
    use crate::util::proptest::check;

    fn rand_mat(rng: &mut Prng, n: usize, bits: u8) -> Vec<i32> {
        let hi = (1i64 << (bits - 1)) - 1;
        (0..n).map(|_| rng.int_in(-hi - 1, hi) as i32).collect()
    }

    #[test]
    fn exact_mode_matches_reference_gemm() {
        check("cycle sim == exact GEMM (tiled)", 25, |rng| {
            let arch = ArchConfig::tiny(); // [36, 4, 4]
            let prec = Precision::new(rng.int_in(2, 5) as u8, rng.int_in(2, 5) as u8);
            // Dimensions deliberately NOT multiples of the array shape.
            let (c, l, k) = (
                rng.int_in(1, 90) as usize,
                rng.int_in(1, 11) as usize,
                rng.int_in(1, 11) as usize,
            );
            let a = rand_mat(rng, c * l, prec.a_bits);
            let b = rand_mat(rng, k * c, prec.b_bits);
            let job = GemmJob {
                a: &a,
                b: &b,
                c,
                l,
                k,
                sched: GavSchedule::all_guarded(prec),
            };
            let mut sim = GavinaSim::new(arch, None, 1);
            let rep = sim.run_gemm(&job);
            assert_eq!(rep.p, gemm::gemm_exact(&a, &b, c, l, k));
            assert_eq!(rep.values_corrupted, 0);
        });
    }

    #[test]
    fn approx_schedule_without_tables_is_still_exact() {
        let arch = ArchConfig::tiny();
        let prec = Precision::new(4, 4);
        let mut rng = Prng::new(2);
        let a = rand_mat(&mut rng, 36 * 4, 4);
        let b = rand_mat(&mut rng, 4 * 36, 4);
        let job = GemmJob {
            a: &a,
            b: &b,
            c: 36,
            l: 4,
            k: 4,
            sched: GavSchedule::all_approx(prec),
        };
        let mut sim = GavinaSim::new(arch, None, 3);
        let rep = sim.run_gemm(&job);
        assert_eq!(rep.p, gemm::gemm_exact(&a, &b, 36, 4, 4));
    }

    #[test]
    fn cycle_count_formula() {
        let arch = ArchConfig::tiny();
        let prec = Precision::new(3, 4);
        let sched = GavSchedule::all_guarded(prec);
        let mut rng = Prng::new(4);
        // 2x2x3 tiles exactly.
        let (c, l, k) = (72, 8, 12);
        let a = rand_mat(&mut rng, c * l, 3);
        let b = rand_mat(&mut rng, k * c, 4);
        let job = GemmJob {
            a: &a,
            b: &b,
            c,
            l,
            k,
            sched,
        };
        let mut sim = GavinaSim::new(arch, None, 5);
        let rep = sim.run_gemm(&job);
        assert_eq!(rep.n_tiles, 2 * 2 * 3);
        assert_eq!(rep.cycles, 4 + 12 * 12 + 1); // fill + tiles*steps + drain
        assert_eq!(rep.a0b0_reads, 2 * 12 * 12);
        assert_eq!(rep.tile_bursts, 12);
    }

    #[test]
    fn utilization_near_one_for_aligned_dims() {
        let arch = ArchConfig::tiny();
        let prec = Precision::new(2, 2);
        let sched = GavSchedule::all_guarded(prec);
        let mut rng = Prng::new(6);
        let (c, l, k) = (36 * 8, 4 * 8, 4 * 8); // large & aligned
        let a = rand_mat(&mut rng, c * l, 2);
        let b = rand_mat(&mut rng, k * c, 2);
        let job = GemmJob {
            a: &a,
            b: &b,
            c,
            l,
            k,
            sched: sched.clone(),
        };
        let mut sim = GavinaSim::new(arch.clone(), None, 7);
        let rep = sim.run_gemm(&job);
        let u = rep.utilization(&arch, &sched);
        assert!(u > 0.97 && u <= 1.0, "utilization {u}");
    }

    #[test]
    fn utilization_drops_with_padding() {
        let arch = ArchConfig::tiny();
        let prec = Precision::new(2, 2);
        let sched = GavSchedule::all_guarded(prec);
        let mut rng = Prng::new(8);
        let (c, l, k) = (37, 5, 5); // just over one tile everywhere
        let a = rand_mat(&mut rng, c * l, 2);
        let b = rand_mat(&mut rng, k * c, 2);
        let job = GemmJob {
            a: &a,
            b: &b,
            c,
            l,
            k,
            sched: sched.clone(),
        };
        let mut sim = GavinaSim::new(arch.clone(), None, 9);
        let rep = sim.run_gemm(&job);
        let u = rep.utilization(&arch, &sched);
        assert!(u < 0.5, "padding waste must show: {u}");
        assert!(rep.executed_macs > rep.useful_macs);
    }

    #[test]
    fn error_injection_corrupts_only_approx_steps() {
        use crate::errmodel::{ErrorTables, ModelParams};
        let arch = ArchConfig::tiny();
        let params = ModelParams::paper(arch.c_dim);
        let mut tables = ErrorTables::zeroed(params);
        // Heavy flips on bit 0 everywhere.
        for e in 0..=params.c_dim as u16 {
            for pb in 0..params.p_bins {
                for cd in 0..params.n_cond(0) {
                    tables.set_prob(0, e, pb, cd, 1.0);
                }
            }
        }
        let prec = Precision::new(4, 4);
        let mut rng = Prng::new(10);
        let a = rand_mat(&mut rng, 36 * 4, 4);
        let b = rand_mat(&mut rng, 4 * 36, 4);
        let exact = gemm::gemm_exact(&a, &b, 36, 4, 4);

        // Fully guarded: exact despite hot tables.
        let job_g = GemmJob {
            a: &a,
            b: &b,
            c: 36,
            l: 4,
            k: 4,
            sched: GavSchedule::all_guarded(prec),
        };
        let mut sim = GavinaSim::new(arch.clone(), Some(&tables), 11);
        assert_eq!(sim.run_gemm(&job_g).p, exact);

        // Fully undervolted: corrupted.
        let job_a = GemmJob {
            a: &a,
            b: &b,
            c: 36,
            l: 4,
            k: 4,
            sched: GavSchedule::all_approx(prec),
        };
        let rep = sim.run_gemm(&job_a);
        assert!(rep.values_corrupted > 0);
        assert_ne!(rep.p, exact);
        // The observed step-error rate is the serving-time control
        // signal: corrupted values per undervolted step, 0 when guarded.
        assert!(rep.step_error_rate() > 0.0);
        assert!(
            (rep.step_error_rate() - rep.values_corrupted as f64 / rep.steps_approx as f64).abs()
                < 1e-12
        );
        let mut sim2 = GavinaSim::new(arch.clone(), Some(&tables), 11);
        assert_eq!(sim2.run_gemm(&job_g).step_error_rate(), 0.0);
    }

    #[test]
    fn error_magnitude_decreases_with_g() {
        use crate::errmodel::{ErrorTables, ModelParams};
        let arch = ArchConfig::tiny();
        let params = ModelParams::paper(arch.c_dim);
        let mut tables = ErrorTables::zeroed(params);
        for bit in 0..params.s_bits {
            for e in 0..=params.c_dim as u16 {
                for pb in 0..params.p_bins {
                    for cd in 0..params.n_cond(bit) {
                        tables.set_prob(bit, e, pb, cd, 0.08);
                    }
                }
            }
        }
        let prec = Precision::new(4, 4);
        let mut rng = Prng::new(12);
        let (c, l, k) = (72, 8, 8);
        let a = rand_mat(&mut rng, c * l, 4);
        let b = rand_mat(&mut rng, k * c, 4);
        let exact = gemm::gemm_exact(&a, &b, c, l, k);
        let var_at = |g: u32, seed: u64| {
            let job = GemmJob {
                a: &a,
                b: &b,
                c,
                l,
                k,
                sched: GavSchedule::two_level(prec, g),
            };
            let mut sim = GavinaSim::new(arch.clone(), Some(&tables), seed);
            crate::stats::var_ned(&exact, &sim.run_gemm(&job).p)
        };
        let v0: f64 = (0..4).map(|s| var_at(0, 20 + s)).sum::<f64>() / 4.0;
        let v4: f64 = (0..4).map(|s| var_at(4, 30 + s)).sum::<f64>() / 4.0;
        let vmax = var_at(prec.max_g(), 40);
        assert!(v0 > v4, "VAR_NED must fall with G: {v0} vs {v4}");
        assert_eq!(vmax, 0.0);
    }

    #[test]
    fn dvs_trace_follows_schedule() {
        let arch = ArchConfig::paper();
        let prec = Precision::new(2, 2);
        let sched = GavSchedule::two_level(prec, 1);
        let trace = dvs_trace(&arch, &sched);
        assert_eq!(trace.len(), 4);
        // Step order (ba,bb): (0,0),(1,0),(0,1),(1,1); s_max=2, G=1 guards
        // s=2, i.e. only the (1,1) step.
        assert_eq!(trace, vec![0.35, 0.35, 0.35, 0.55]);
    }
}
