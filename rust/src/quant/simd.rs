//! SIMD activation-side quantize + bit-plane pack + robust range — the
//! prologue analogue of [`crate::gemm::simd`]: the same
//! [`KernelKind`] runtime dispatch, the same scalar-is-ground-truth
//! rule, applied to the three per-element operations the streaming fused
//! prologue (`dnn::exec::pack_a_fused`) performs on every activation:
//! `q = clamp(round(v / s))`, the two's-complement bit-plane pack, and
//! the robust range statistic that derives `s`.
//!
//! ## Exactness contract
//!
//! Every path here is **bit-identical** to the scalar expressions the
//! reference three-pass prologue uses (pinned by the tests below):
//!
//! * Quantization is exactly `((v / s).round() as i32).clamp(-hi, hi)`.
//!   Rust's `f32::round` is round-half-away-from-zero and `as i32`
//!   saturates (NaN → 0); x86's `cvtps2dq` rounds half-to-even and
//!   returns `i32::MIN` on overflow/NaN, so [`quant_pack8_avx2`] fixes
//!   up exactly the halfway, positive-overflow and NaN lanes. AArch64's
//!   `fcvtas` (`vcvtaq_s32_f32`) natively matches the Rust semantics —
//!   ties away from zero, saturating, NaN → 0 — and needs no fixup.
//! * [`robust_amax`] accumulates its f64 sums in a **canonical 4-lane
//!   blocked order** (element `i` feeds lane `i % 4`; lanes combine as
//!   `(l0 + l1) + (l2 + l3)`), which the scalar, AVX2 and NEON
//!   implementations all reproduce exactly — so the activation scale,
//!   and therefore every quantized integer, never depends on which
//!   kernel is active. (Inputs are finite activations; the statistic is
//!   meaningless on NaN.)
//!
//! The float work here lives outside `gemm::simd` on purpose: the GEMM
//! ISA files are integer-only by lint (`gavina-xtask`'s `float-accum`
//! rule), while this module is the activation/float side of the fence.

#[cfg(target_arch = "aarch64")]
use std::arch::aarch64::*;
#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

use crate::gemm::simd::{self, KernelKind};

/// The quantize lane width this module actually runs for a GEMM kernel
/// choice. All x86 tiers (AVX2 and both AVX-512 kinds) share the 8-wide
/// AVX2 quantize path — `cvtps2dq`/`vpmovmskb` cover it and every
/// AVX-512 host has AVX2 — but availability is still re-checked so a
/// forced kind on an impossible host degrades to scalar instead of UB.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum QuantPath {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Avx2,
    #[cfg(target_arch = "aarch64")]
    Neon,
}

fn quant_path(kind: KernelKind) -> QuantPath {
    #[cfg(target_arch = "x86_64")]
    {
        if matches!(
            kind,
            KernelKind::Avx2 | KernelKind::Avx512 | KernelKind::Avx512Hs
        ) && simd::is_available(KernelKind::Avx2)
        {
            return QuantPath::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if kind == KernelKind::Neon && simd::is_available(KernelKind::Neon) {
            return QuantPath::Neon;
        }
    }
    let _ = kind;
    QuantPath::Scalar
}

/// The scalar activation quantizer every SIMD path must match bit for
/// bit: `((v / s).round() as i32).clamp(-hi, hi)` — exactly the
/// expression the historical three-pass prologue inlined.
#[inline]
pub(crate) fn quantize_one(v: f32, s: f32, hi: f32) -> i32 {
    ((v / s).round() as i32).clamp(-hi as i32, hi as i32)
}

/// OR the `bits` two's-complement bit-planes of `q` into `acc` at bit
/// position `dc` — the single-value form of [`super::pack_chunk`].
#[inline]
fn pack_one(acc: &mut [u64; 8], dc: u32, q: i32, bits: u8) {
    debug_assert!(bits <= 8 && dc < 64);
    let mask = (1u32 << bits) - 1;
    let u = (q as u32) & mask;
    for (plane, word) in acc.iter_mut().enumerate().take(bits as usize) {
        *word |= (((u >> plane) & 1) as u64) << dc;
    }
}

/// Quantize 8 consecutive f32s and OR their bit-planes into `acc` at bit
/// offset `dc`: one `vdivps` + `cvtps2dq` + the documented fixups, then
/// one shift + `movmskps` per plane gathers 8 plane bits at once (lane 0
/// → bit `dc`). Assumes the default MXCSR rounding mode (round to
/// nearest even), which Rust guarantees.
///
/// # Safety
///
/// Caller has verified AVX2; `vals` must be valid for 8 f32 reads;
/// `dc ≤ 56` and `bits ≤ 8`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn quant_pack8_avx2(vals: *const f32, s: f32, hi: f32, bits: u8, dc: u32, acc: &mut [u64; 8]) {
    debug_assert!(dc <= 56 && bits <= 8);
    // SAFETY: `vals` is valid for 8 f32 reads (caller contract); all other
    // intrinsics are pure register arithmetic, unsafe only without AVX2,
    // which the caller verified (`target_feature` guarantees the body).
    unsafe {
        let q = _mm256_div_ps(_mm256_loadu_ps(vals), _mm256_set1_ps(s));
        // cvtps2dq rounds half to even; Rust rounds half away from zero.
        // A halfway case rounded toward even is off by exactly ±0.5 from
        // q (the subtraction is exact: halfway cases only exist below
        // 2^23, where f32 subtraction of `q − round(q)` is lossless), so
        // nudge exactly those lanes one step away from zero. Saturated
        // lanes (|q| ≥ 2^31) can't alias a halfway case: their diff is
        // astronomically larger than 0.5.
        let r = _mm256_cvtps_epi32(q);
        let diff = _mm256_sub_ps(q, _mm256_cvtepi32_ps(r));
        let zero = _mm256_setzero_ps();
        let one = _mm256_set1_epi32(1);
        let up = _mm256_and_si256(
            _mm256_castps_si256(_mm256_cmp_ps::<_CMP_EQ_OQ>(diff, _mm256_set1_ps(0.5))),
            _mm256_castps_si256(_mm256_cmp_ps::<_CMP_GT_OQ>(q, zero)),
        );
        let dn = _mm256_and_si256(
            _mm256_castps_si256(_mm256_cmp_ps::<_CMP_EQ_OQ>(diff, _mm256_set1_ps(-0.5))),
            _mm256_castps_si256(_mm256_cmp_ps::<_CMP_LT_OQ>(q, zero)),
        );
        let r = _mm256_add_epi32(r, _mm256_and_si256(up, one));
        let r = _mm256_sub_epi32(r, _mm256_and_si256(dn, one));
        // `as i32` saturates q ≥ 2^31 to i32::MAX where cvtps2dq returned
        // i32::MIN (negative overflow already matches), and maps NaN to 0
        // where cvtps2dq returned i32::MIN.
        let big = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_GE_OQ>(
            q,
            _mm256_set1_ps(2147483648.0),
        ));
        let r = _mm256_blendv_epi8(r, _mm256_set1_epi32(i32::MAX), big);
        let nan = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_UNORD_Q>(q, q));
        let r = _mm256_andnot_si256(nan, r);
        let hiv = _mm256_set1_epi32(hi as i32);
        let q32 = _mm256_min_epi32(
            _mm256_max_epi32(r, _mm256_sub_epi32(_mm256_setzero_si256(), hiv)),
            hiv,
        );
        // Pack: slide bit `plane` of every lane to bit 31, movmskps reads
        // the 8 sign bits as one byte — LSB is lane 0, i.e. vals[0], so
        // the byte drops into the plane word at `dc` in pack_chunk order.
        for plane in 0..bits as i32 {
            let m = _mm256_movemask_ps(_mm256_castsi256_ps(_mm256_sll_epi32(
                q32,
                _mm_cvtsi32_si128(31 - plane),
            )));
            acc[plane as usize] |= ((m as u32) as u64) << dc;
        }
    }
}

/// Quantize 4 consecutive f32s: `fdiv` + `fcvtas`, which already rounds
/// ties away from zero, saturates, and maps NaN to 0 — exactly the Rust
/// scalar semantics, so no fixups. The 4 integers are packed by the
/// shared scalar bit loop (4 values don't amortize a vector transpose).
///
/// # Safety
///
/// Caller has verified NEON; `vals` must be valid for 4 f32 reads.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn quantize4_neon(vals: *const f32, s: f32, hi: f32) -> [i32; 4] {
    // SAFETY: `vals` is valid for 4 f32 reads (caller contract); the rest
    // is register arithmetic guarded by the verified `neon` feature.
    unsafe {
        let q = vdivq_f32(vld1q_f32(vals), vdupq_n_f32(s));
        let r = vcvtaq_s32_f32(q);
        let hiv = vdupq_n_s32(hi as i32);
        let c = vminq_s32(vmaxq_s32(r, vnegq_s32(hiv)), hiv);
        let mut out = [0i32; 4];
        vst1q_s32(out.as_mut_ptr(), c);
        out
    }
}

/// Streaming quantize-and-pack cursor over one packed vector (one im2col
/// column) of an interleaved A operand: the caller feeds the column's C
/// axis as contiguous f32 runs and zero-padding gaps, and the packer
/// quantizes each value with the column's scale and ORs its bit-planes
/// into the column's `words · bits` chunk words — no f32 or i32 staging
/// buffer ever exists. `out` must be the column's (pre-zeroed) span in
/// [`super::InterleavedPlanes`] chunk layout: chunk `w` of the column at
/// `out[w·bits .. (w+1)·bits]`, plane words LSB = C position `64·w`.
pub(crate) struct RunPacker<'a> {
    out: &'a mut [u64],
    bits: u8,
    s: f32,
    hi: f32,
    path: QuantPath,
    /// Next C position: bit `c % 64` of chunk `c / 64`.
    c: usize,
    /// Plane words of the current (possibly partial) 64-element chunk.
    acc: [u64; 8],
}

impl<'a> RunPacker<'a> {
    pub(crate) fn new(out: &'a mut [u64], bits: u8, s: f32, hi: f32, kind: KernelKind) -> Self {
        debug_assert!(bits >= 1 && bits <= 8);
        Self {
            out,
            bits,
            s,
            hi,
            path: quant_path(kind),
            c: 0,
            acc: [0u64; 8],
        }
    }

    /// Store the just-completed chunk's plane words and reset the
    /// accumulator.
    #[inline]
    fn flush_chunk(&mut self) {
        debug_assert!(self.c % 64 == 0 && self.c > 0);
        let base = (self.c / 64 - 1) * self.bits as usize;
        self.out[base..base + self.bits as usize].copy_from_slice(&self.acc[..self.bits as usize]);
        self.acc = [0u64; 8];
    }

    /// Append `n` zero-padding values (all planes of a 0 are 0, so this
    /// only advances the cursor and flushes chunk boundaries it crosses).
    pub(crate) fn push_zeros(&mut self, mut n: usize) {
        while n > 0 {
            let take = (64 - self.c % 64).min(n);
            self.c += take;
            n -= take;
            if self.c % 64 == 0 {
                self.flush_chunk();
            }
        }
    }

    /// Quantize and append one contiguous run of values.
    pub(crate) fn push_run(&mut self, vals: &[f32]) {
        let mut i = 0;
        while i < vals.len() {
            let dc = self.c % 64;
            let room = 64 - dc;
            let left = vals.len() - i;
            #[cfg(target_arch = "x86_64")]
            if self.path == QuantPath::Avx2 && room >= 8 && left >= 8 {
                // SAFETY: AVX2 was verified when `path` was selected;
                // `i + 8 <= vals.len()` so the 8 reads are in bounds;
                // `room >= 8` gives `dc <= 56`; `bits <= 8` by `new`.
                unsafe {
                    quant_pack8_avx2(
                        vals.as_ptr().add(i),
                        self.s,
                        self.hi,
                        self.bits,
                        dc as u32,
                        &mut self.acc,
                    );
                }
                self.c += 8;
                i += 8;
                if self.c % 64 == 0 {
                    self.flush_chunk();
                }
                continue;
            }
            #[cfg(target_arch = "aarch64")]
            if self.path == QuantPath::Neon && room >= 4 && left >= 4 {
                // SAFETY: NEON was verified when `path` was selected and
                // `i + 4 <= vals.len()` keeps the 4 reads in bounds.
                let q4 = unsafe { quantize4_neon(vals.as_ptr().add(i), self.s, self.hi) };
                for (k, &q) in q4.iter().enumerate() {
                    pack_one(&mut self.acc, (dc + k) as u32, q, self.bits);
                }
                self.c += 4;
                i += 4;
                if self.c % 64 == 0 {
                    self.flush_chunk();
                }
                continue;
            }
            let _ = (room, left);
            let q = quantize_one(vals[i], self.s, self.hi);
            pack_one(&mut self.acc, dc as u32, q, self.bits);
            self.c += 1;
            i += 1;
            if self.c % 64 == 0 {
                self.flush_chunk();
            }
        }
    }

    /// Flush a trailing partial chunk. Returns the total number of C
    /// positions pushed, so callers can assert full coverage.
    pub(crate) fn finish(mut self) -> usize {
        if self.c % 64 != 0 {
            let base = (self.c / 64) * self.bits as usize;
            self.out[base..base + self.bits as usize]
                .copy_from_slice(&self.acc[..self.bits as usize]);
        }
        self.c
    }
}

/// Combine the canonical 4-lane partial sums and apply the robust-range
/// epilogue: `min(max|x|, mean|x| + 6·std|x|)` over f64 statistics.
fn finish_amax(n: usize, maxa: f64, sum: [f64; 4], sum2: [f64; 4]) -> f32 {
    let n = n as f64;
    let s = (sum[0] + sum[1]) + (sum[2] + sum[3]);
    let s2 = (sum2[0] + sum2[1]) + (sum2[2] + sum2[3]);
    let mu = s / n;
    let var = (s2 / n - mu * mu).max(0.0);
    (maxa.min(mu + 6.0 * var.sqrt())) as f32
}

/// The canonical accumulation every SIMD path reproduces bit for bit:
/// element `i` feeds f64 lane `i % 4` (a trailing partial block fills
/// lanes `0..r`), the max folds sequentially (order-insensitive for the
/// finite inputs this statistic is defined on).
fn robust_amax_scalar(data: &[f32]) -> f32 {
    let mut sum = [0.0f64; 4];
    let mut sum2 = [0.0f64; 4];
    let mut maxa = 0.0f64;
    let mut blocks = data.chunks_exact(4);
    for b in &mut blocks {
        for (j, &v) in b.iter().enumerate() {
            let a = (v as f64).abs();
            maxa = maxa.max(a);
            sum[j] += a;
            sum2[j] += a * a;
        }
    }
    for (j, &v) in blocks.remainder().iter().enumerate() {
        let a = (v as f64).abs();
        maxa = maxa.max(a);
        sum[j] += a;
        sum2[j] += a * a;
    }
    finish_amax(data.len(), maxa, sum, sum2)
}

/// AVX2 lanes of the canonical accumulation: 4 f32s widen to 4 f64 lanes
/// per step, so vector lane `j` receives exactly the elements scalar
/// lane `j` receives, in the same order.
///
/// # Safety
///
/// Caller has verified AVX2.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn robust_amax_avx2(data: &[f32]) -> f32 {
    // SAFETY: every `data.as_ptr().add(i)` load reads 4 f32s at
    // `i <= n4 - 4 <= data.len() - 4`, in bounds; the stores target local
    // `[f64; 4]` arrays; the rest is register arithmetic guarded by the
    // verified `avx2` feature.
    unsafe {
        let absmask = _mm256_castsi256_pd(_mm256_set1_epi64x(0x7fff_ffff_ffff_ffff));
        let mut vmax = _mm256_setzero_pd();
        let mut vsum = _mm256_setzero_pd();
        let mut vsum2 = _mm256_setzero_pd();
        let n4 = data.len() / 4 * 4;
        let mut i = 0;
        while i < n4 {
            let a = _mm256_and_pd(_mm256_cvtps_pd(_mm_loadu_ps(data.as_ptr().add(i))), absmask);
            vmax = _mm256_max_pd(vmax, a);
            vsum = _mm256_add_pd(vsum, a);
            vsum2 = _mm256_add_pd(vsum2, _mm256_mul_pd(a, a));
            i += 4;
        }
        let mut sum = [0.0f64; 4];
        let mut sum2 = [0.0f64; 4];
        let mut mx = [0.0f64; 4];
        _mm256_storeu_pd(sum.as_mut_ptr(), vsum);
        _mm256_storeu_pd(sum2.as_mut_ptr(), vsum2);
        _mm256_storeu_pd(mx.as_mut_ptr(), vmax);
        let mut maxa = mx[0].max(mx[1]).max(mx[2]).max(mx[3]);
        for (j, &v) in data[n4..].iter().enumerate() {
            let a = (v as f64).abs();
            maxa = maxa.max(a);
            sum[j] += a;
            sum2[j] += a * a;
        }
        finish_amax(data.len(), maxa, sum, sum2)
    }
}

/// NEON lanes of the canonical accumulation: lanes 0–1 live in one
/// float64x2, lanes 2–3 in another, fed from the low/high halves of each
/// 4-wide f32 load — the same element→lane map as the scalar form.
///
/// # Safety
///
/// Caller has verified NEON.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn robust_amax_neon(data: &[f32]) -> f32 {
    // SAFETY: every `data.as_ptr().add(i)` load reads 4 f32s at
    // `i <= n4 - 4`, in bounds; the rest is register arithmetic guarded
    // by the verified `neon` feature.
    unsafe {
        let mut vmax = [vdupq_n_f64(0.0); 2];
        let mut vsum = [vdupq_n_f64(0.0); 2];
        let mut vsum2 = [vdupq_n_f64(0.0); 2];
        let n4 = data.len() / 4 * 4;
        let mut i = 0;
        while i < n4 {
            let v = vld1q_f32(data.as_ptr().add(i));
            let lo = vabsq_f64(vcvt_f64_f32(vget_low_f32(v)));
            let hi = vabsq_f64(vcvt_high_f64_f32(v));
            vmax[0] = vmaxq_f64(vmax[0], lo);
            vmax[1] = vmaxq_f64(vmax[1], hi);
            vsum[0] = vaddq_f64(vsum[0], lo);
            vsum[1] = vaddq_f64(vsum[1], hi);
            vsum2[0] = vaddq_f64(vsum2[0], vmulq_f64(lo, lo));
            vsum2[1] = vaddq_f64(vsum2[1], vmulq_f64(hi, hi));
            i += 4;
        }
        let mut sum = [0.0f64; 4];
        let mut sum2 = [0.0f64; 4];
        let mut mx = [0.0f64; 4];
        for h in 0..2 {
            vst1q_f64(sum.as_mut_ptr().add(h * 2), vsum[h]);
            vst1q_f64(sum2.as_mut_ptr().add(h * 2), vsum2[h]);
            vst1q_f64(mx.as_mut_ptr().add(h * 2), vmax[h]);
        }
        let mut maxa = mx[0].max(mx[1]).max(mx[2]).max(mx[3]);
        for (j, &v) in data[n4..].iter().enumerate() {
            let a = (v as f64).abs();
            maxa = maxa.max(a);
            sum[j] += a;
            sum2[j] += a * a;
        }
        finish_amax(data.len(), maxa, sum, sum2)
    }
}

/// Robust activation range `min(max|x|, mean|x| + 6·std|x|)` on the
/// kernel `kind` would use — all implementations produce identical bits
/// (canonical lane order, pinned below), so this only picks the fast
/// path, never the answer. Empty input falls back to `1e-8`.
pub fn robust_amax_with(kind: KernelKind, data: &[f32]) -> f32 {
    if data.is_empty() {
        return 1e-8;
    }
    match quant_path(kind) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `quant_path` only returns `Avx2` after verifying AVX2
        // availability on this host.
        QuantPath::Avx2 => unsafe { robust_amax_avx2(data) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `quant_path` only returns `Neon` after verifying NEON
        // availability on this host.
        QuantPath::Neon => unsafe { robust_amax_neon(data) },
        QuantPath::Scalar => robust_amax_scalar(data),
    }
}

/// [`robust_amax_with`] on the process's active kernel — the single
/// slice-based implementation behind [`crate::dnn::tensor::robust_amax_slice`]
/// and [`crate::dnn::tensor::Tensor::robust_amax`].
pub fn robust_amax(data: &[f32]) -> f32 {
    robust_amax_with(simd::active(), data)
}

#[cfg(test)]
mod tests {
    use super::super::pack_chunk;
    use super::*;
    use crate::util::proptest::check;
    use crate::util::Prng;

    /// Values that hit every fixup path of the SIMD quantizer: exact
    /// halfway cases of both signs, clamp saturation, ±overflow past
    /// i32, NaN, ±inf and signed zero.
    fn adversarial_vals() -> Vec<f32> {
        vec![
            0.5, -0.5, 1.5, -1.5, 2.5, -2.5, 3.5, -3.5, 126.5, -126.5, 127.5, -127.5, 200.0,
            -200.0, 1e20, -1e20, 2147483648.0, -2147483904.0, f32::NAN, f32::INFINITY,
            f32::NEG_INFINITY, 0.0, -0.0, 0.49999997, -0.49999997, 8388608.5, 16777215.0,
        ]
    }

    /// Reference pack of one column through the historical two-buffer
    /// path: scalar-quantize everything into a staging vector, then
    /// `pack_chunk` per 64-element chunk.
    fn reference_pack(vals: &[f32], s: f32, hi: f32, bits: u8) -> Vec<u64> {
        let q: Vec<i32> = vals.iter().map(|&v| quantize_one(v, s, hi)).collect();
        let words = vals.len().div_ceil(64).max(1);
        let mut out = vec![0u64; words * bits as usize];
        for w in 0..words {
            let c0 = w * 64;
            let cn = 64.min(vals.len().saturating_sub(c0));
            let acc = pack_chunk(q[c0..c0 + cn].iter().copied(), bits);
            out[w * bits as usize..(w + 1) * bits as usize]
                .copy_from_slice(&acc[..bits as usize]);
        }
        out
    }

    #[test]
    fn run_packer_matches_reference_on_every_available_kernel() {
        check("RunPacker == quantize+pack_chunk", 40, |rng| {
            let bits = rng.int_in(2, 8) as u8;
            let hi = ((1i32 << (bits - 1)) - 1) as f32;
            let n = rng.int_in(1, 200) as usize;
            let s = (rng.next_f32() * 0.5 + 1e-3).max(1e-4);
            let vals: Vec<f32> = (0..n).map(|_| rng.next_f32() * 8.0 - 4.0).collect();
            let expect = reference_pack(&vals, s, hi, bits);
            for kind in simd::available() {
                // Feed the run in irregular pieces (including zero gaps
                // replaced by literal 0.0 in the reference input).
                let mut out = vec![0u64; expect.len()];
                let mut p = RunPacker::new(&mut out, bits, s, hi, kind);
                let mut i = 0;
                while i < n {
                    let take = (rng.int_in(1, 23) as usize).min(n - i);
                    p.push_run(&vals[i..i + take]);
                    i += take;
                }
                assert_eq!(p.finish(), n);
                assert_eq!(out, expect, "kind={kind} n={n} bits={bits}");
            }
        });
    }

    #[test]
    fn push_zeros_equals_pushing_zero_values() {
        check("push_zeros == push_run(0.0)", 30, |rng| {
            let bits = rng.int_in(2, 8) as u8;
            let hi = ((1i32 << (bits - 1)) - 1) as f32;
            let s = rng.next_f32() * 0.5 + 1e-3;
            // Alternate runs and gaps over an odd C length.
            let n = rng.int_in(60, 190) as usize;
            let mut vals = vec![0.0f32; n];
            let mut mask = vec![false; n];
            for (i, v) in vals.iter_mut().enumerate() {
                if rng.next_f32() < 0.6 {
                    *v = rng.next_f32() * 4.0 - 2.0;
                    mask[i] = true;
                }
            }
            let expect = reference_pack(&vals, s, hi, bits);
            for kind in simd::available() {
                let mut out = vec![0u64; expect.len()];
                let mut p = RunPacker::new(&mut out, bits, s, hi, kind);
                let mut i = 0;
                while i < n {
                    let mut j = i;
                    while j < n && mask[j] == mask[i] {
                        j += 1;
                    }
                    if mask[i] {
                        p.push_run(&vals[i..j]);
                    } else {
                        p.push_zeros(j - i);
                    }
                    i = j;
                }
                assert_eq!(p.finish(), n);
                assert_eq!(out, expect, "kind={kind} n={n} bits={bits}");
            }
        });
    }

    #[test]
    fn simd_quantize_matches_scalar_on_adversarial_values() {
        // Halfway ties, clamp, ±overflow, NaN, ±inf: every lane fixup in
        // quant_pack8_avx2 (and the fixup-free NEON path) must reproduce
        // the scalar `round() as i32` semantics bit for bit.
        let vals = adversarial_vals();
        for &s in &[1.0f32, 0.25, 3.0, 1e-6] {
            for bits in [2u8, 4, 8] {
                let hi = ((1i32 << (bits - 1)) - 1) as f32;
                let expect = reference_pack(&vals, s, hi, bits);
                for kind in simd::available() {
                    let mut out = vec![0u64; expect.len()];
                    let mut p = RunPacker::new(&mut out, bits, s, hi, kind);
                    p.push_run(&vals);
                    assert_eq!(p.finish(), vals.len());
                    assert_eq!(out, expect, "kind={kind} s={s} bits={bits}");
                }
            }
        }
    }

    #[test]
    fn misaligned_runs_cross_chunk_boundaries_correctly() {
        // Runs deliberately straddling the 64-bit chunk boundary at every
        // phase, with partial final chunks (c = 65 and 130).
        for &n in &[65usize, 130] {
            let mut rng = Prng::new(0xC0DE + n as u64);
            let vals: Vec<f32> = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
            let (s, bits) = (0.02f32, 4u8);
            let hi = 7.0f32;
            let expect = reference_pack(&vals, s, hi, bits);
            for kind in simd::available() {
                for phase in [1usize, 3, 7, 8, 61, 63] {
                    let mut out = vec![0u64; expect.len()];
                    let mut p = RunPacker::new(&mut out, bits, s, hi, kind);
                    p.push_run(&vals[..phase.min(n)]);
                    if phase < n {
                        p.push_run(&vals[phase..]);
                    }
                    assert_eq!(p.finish(), n);
                    assert_eq!(out, expect, "kind={kind} n={n} phase={phase}");
                }
            }
        }
    }

    #[test]
    fn robust_amax_is_bitwise_identical_across_kernels() {
        check("robust_amax kernel-invariant", 40, |rng| {
            let n = rng.int_in(0, 300) as usize;
            let data: Vec<f32> = (0..n).map(|_| rng.next_f32() * 10.0 - 5.0).collect();
            let scalar = robust_amax_with(KernelKind::Scalar, &data);
            for kind in simd::available() {
                let got = robust_amax_with(kind, &data);
                assert_eq!(got.to_bits(), scalar.to_bits(), "kind={kind} n={n}");
            }
            assert_eq!(robust_amax(&data).to_bits(), scalar.to_bits());
        });
    }

    #[test]
    fn robust_amax_keeps_the_statistic() {
        // The canonical lane-blocked order is a reassociation of the same
        // f64 sums: the statistic itself must match a plain sequential
        // accumulation to fp tolerance, and the outlier cap must bite.
        let mut rng = Prng::new(77);
        let data: Vec<f32> = (0..1000).map(|_| rng.next_f32()).collect();
        let seq = {
            let n = data.len() as f64;
            let (mut maxa, mut s, mut s2) = (0.0f64, 0.0f64, 0.0f64);
            for &v in &data {
                let a = (v as f64).abs();
                maxa = maxa.max(a);
                s += a;
                s2 += a * a;
            }
            let mu = s / n;
            let var = (s2 / n - mu * mu).max(0.0);
            (maxa.min(mu + 6.0 * var.sqrt())) as f32
        };
        let got = robust_amax_scalar(&data);
        assert!((got - seq).abs() <= 1e-6 * seq.abs().max(1.0), "{got} vs {seq}");
        assert_eq!(robust_amax(&[]), 1e-8);
        let mut outliers = vec![0.1f32; 1000];
        outliers.push(100.0);
        let capped = robust_amax(&outliers);
        assert!(capped < 50.0 && capped > 0.1, "cap must bite: {capped}");
    }
}
