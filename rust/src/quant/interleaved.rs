//! Plane-interleaved bit-packed storage — the operand layout of the fused
//! bit-serial micro-kernel ([`crate::gemm::kernel`]).
//!
//! [`PackedPlanes`] stores `data[plane][vec][word]`: perfect for the
//! step-sequence compute path (one significance plane per simulated
//! cycle), but the exact software path walks **all** `a_bits × b_bits`
//! plane combinations, so the plane-major layout forces one full pass
//! over memory per combination. [`InterleavedPlanes`] transposes the
//! layout to `data[vec][word][plane]`: every plane of one 64-element
//! C-chunk sits in adjacent words, so the fused kernel loads each chunk's
//! plane words once and retires the whole significance loop out of
//! registers — one pass over memory total.
//!
//! The bit content is identical to [`PackedPlanes`] (same word-wise pack,
//! LSB = lowest `c`, zero padding past `C`); the two layouts convert
//! losslessly in either direction (property-tested below).
//!
//! ## Alignment / padding contract (what the SIMD kernels rely on)
//!
//! * The backing store is one contiguous `Vec<u64>`, so every chunk and
//!   every plane word is 8-byte aligned; the vector kernels use unaligned
//!   loads (`loadu` / `vld1q`) and need nothing stronger.
//! * [`InterleavedPlanes::TAIL_PAD_WORDS`] zero words are appended past
//!   the last logical word. A vector load of `LANES` plane words that
//!   starts at the final chunk of the final vector may read up to
//!   `LANES − 1` words past the logical end (`LANES ≤ 8`); the pad keeps
//!   those reads inside the allocation, and because pad words are zero
//!   they contribute nothing to any AND+popcount.
//! * Padding — both the tail pad and the unused high bits of a partial
//!   final chunk — is always zero. [`InterleavedPlanes::zeroed`] zeroes
//!   everything up front and the packing paths only OR bits in; the
//!   reuse path ([`InterleavedPlanes::repack_a`]) re-zeroes before
//!   packing. Asserted by the layout tests below.

use super::{pack_chunk, PackedPlanes};

/// Bit-planes of one integer matrix, packed along the reduction axis and
/// stored plane-interleaved: `data[vec][word][plane]`, flattened
/// row-major.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InterleavedPlanes {
    /// Number of bit-planes (the operand precision).
    pub bits: u8,
    /// Number of packed vectors (L for activations, K for weights).
    pub n_vecs: usize,
    /// Logical length of the reduction axis (C).
    pub c_dim: usize,
    /// u64 words per packed vector per plane: `ceil(C / 64)`.
    pub words: usize,
    data: Vec<u64>,
}

impl InterleavedPlanes {
    /// Zero words appended past the last logical word so the SIMD
    /// kernels' widest partial-chunk load (8 lanes → up to 7 words of
    /// overread) stays inside the allocation. Always zero; see the
    /// layout contract in the module docs.
    pub const TAIL_PAD_WORDS: usize = 7;

    /// All-zero planes (including the tail pad).
    pub fn zeroed(bits: u8, n_vecs: usize, c_dim: usize) -> Self {
        let words = c_dim.div_ceil(64);
        Self {
            bits,
            n_vecs,
            c_dim,
            words,
            data: vec![0u64; n_vecs * words * bits as usize + Self::TAIL_PAD_WORDS],
        }
    }

    #[inline]
    fn chunk_index(&self, vec: usize, word: usize) -> usize {
        (vec * self.words + word) * self.bits as usize
    }

    /// Pack an activation matrix `A[C, L]` (row-major, C rows) directly
    /// into interleaved per-column planes — same word-wise pack as
    /// [`PackedPlanes::from_a_matrix`], different store layout, so the
    /// executor's scratch arena never materializes the plane-major form.
    pub fn from_a_matrix(a: &[i32], c_dim: usize, l_dim: usize, bits: u8) -> Self {
        let mut p = Self::zeroed(bits, l_dim, c_dim);
        p.fill_a(a);
        p
    }

    /// Re-pack an activation matrix into this value, reusing its
    /// allocation — the executor's per-layer scratch path. Equivalent to
    /// `*self = Self::from_a_matrix(a, c_dim, l_dim, bits)` without the
    /// allocation churn (property-tested below, including shape changes
    /// and dirty prior contents).
    pub fn repack_a(&mut self, a: &[i32], c_dim: usize, l_dim: usize, bits: u8) {
        self.reshape_zeroed(bits, l_dim, c_dim);
        self.fill_a(a);
    }

    /// Reshape for a new operand and zero every retained word (stale bits
    /// from a previous, larger layer must not survive), keeping the
    /// allocation's capacity. The shared reuse prologue of
    /// [`Self::repack_a`] and the fused streaming pack
    /// (`dnn::exec::pack_a_fused`), which fills the zeroed store through
    /// [`Self::logical_mut`] instead of an i32 staging matrix.
    pub(crate) fn reshape_zeroed(&mut self, bits: u8, n_vecs: usize, c_dim: usize) {
        self.bits = bits;
        self.n_vecs = n_vecs;
        self.c_dim = c_dim;
        self.words = c_dim.div_ceil(64);
        self.data.clear();
        self.data
            .resize(n_vecs * self.words * bits as usize + Self::TAIL_PAD_WORDS, 0);
    }

    /// The logical (pad-free) backing words, mutably: vector `v` owns the
    /// disjoint contiguous range `[v·words·bits, (v+1)·words·bits)`, which
    /// is what lets the fused prologue's workers pack disjoint L-blocks
    /// concurrently via `util::parallel::parallel_chunks_mut` without
    /// touching the shared tail pad.
    #[inline]
    pub(crate) fn logical_mut(&mut self) -> &mut [u64] {
        let n = self.n_vecs * self.words * self.bits as usize;
        &mut self.data[..n]
    }

    /// The shared `A[C, L]` packing loop of [`Self::from_a_matrix`] /
    /// [`Self::repack_a`]; `self` must be correctly shaped and all-zero.
    fn fill_a(&mut self, a: &[i32]) {
        assert_eq!(a.len(), self.c_dim * self.n_vecs);
        let (c_dim, l_dim, bits) = (self.c_dim, self.n_vecs, self.bits);
        for l in 0..l_dim {
            for w in 0..self.words {
                let c0 = w * 64;
                let cn = 64.min(c_dim - c0);
                let acc = pack_chunk((0..cn).map(|dc| a[(c0 + dc) * l_dim + l]), bits);
                let base = self.chunk_index(l, w);
                self.data[base..base + bits as usize].copy_from_slice(&acc[..bits as usize]);
            }
        }
    }

    /// Pack a weight matrix `B[K, C]` (row-major, K rows) directly into
    /// interleaved per-row planes.
    pub fn from_b_matrix(b: &[i32], k_dim: usize, c_dim: usize, bits: u8) -> Self {
        assert_eq!(b.len(), k_dim * c_dim);
        let mut p = Self::zeroed(bits, k_dim, c_dim);
        for k in 0..k_dim {
            let row = &b[k * c_dim..(k + 1) * c_dim];
            for w in 0..p.words {
                let c0 = w * 64;
                let cn = 64.min(c_dim - c0);
                let acc = pack_chunk(row[c0..c0 + cn].iter().copied(), bits);
                let base = p.chunk_index(k, w);
                p.data[base..base + bits as usize].copy_from_slice(&acc[..bits as usize]);
            }
        }
        p
    }

    /// Re-lay plane-major planes into the interleaved form (one linear
    /// pass; the bit content is untouched).
    pub fn from_packed(p: &PackedPlanes) -> Self {
        let mut out = Self::zeroed(p.bits, p.n_vecs, p.c_dim);
        for vec in 0..p.n_vecs {
            for plane in 0..p.bits {
                let src = p.vec_words(plane, vec);
                for (w, &word) in src.iter().enumerate() {
                    let idx = out.chunk_index(vec, w) + plane as usize;
                    out.data[idx] = word;
                }
            }
        }
        out
    }

    /// Convert back to the plane-major layout (the step-sequence path and
    /// the simulator's tile carving consume that form).
    pub fn to_packed(&self) -> PackedPlanes {
        let mut out = PackedPlanes::zeroed(self.bits, self.n_vecs, self.c_dim);
        for vec in 0..self.n_vecs {
            for w in 0..self.words {
                let base = self.chunk_index(vec, w);
                for plane in 0..self.bits {
                    out.set_word(plane, vec, w, self.data[base + plane as usize]);
                }
            }
        }
        out
    }

    /// The packed words of one vector, chunk-major: chunk `w` holds the
    /// `bits` plane words of C positions `64·w .. 64·w+63` at
    /// `[w·bits .. (w+1)·bits]` (length `words · bits`).
    #[inline]
    pub fn vec_words(&self, vec: usize) -> &[u64] {
        let start = self.chunk_index(vec, 0);
        &self.data[start..start + self.words * self.bits as usize]
    }

    /// Read back a single logical bit (tests).
    #[inline]
    pub fn bit(&self, plane: u8, vec: usize, c: usize) -> u32 {
        let w = self.data[self.chunk_index(vec, c / 64) + plane as usize];
        ((w >> (c % 64)) & 1) as u32
    }

    /// Logical memory footprint of the packed planes in bytes (excluding
    /// the constant tail pad).
    pub fn nbytes(&self) -> usize {
        (self.data.len() - Self::TAIL_PAD_WORDS) * 8
    }

    /// The full padded backing store — **including** the
    /// [`Self::TAIL_PAD_WORDS`] trailing zero words. The SIMD kernels
    /// derive their pointers from this slice rather than from
    /// [`Self::vec_words`], so a partial-chunk vector load that runs past
    /// a vector's last plane word stays inside one live borrow of one
    /// allocation (in bounds and Miri-clean by construction).
    #[inline]
    pub(crate) fn raw(&self) -> &[u64] {
        debug_assert_eq!(
            self.data.len(),
            self.n_vecs * self.words * self.bits as usize + Self::TAIL_PAD_WORDS
        );
        &self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::Prng;

    fn rand_mat(rng: &mut Prng, n: usize, bits: u8) -> Vec<i32> {
        let hi = (1i64 << (bits - 1)) - 1;
        (0..n).map(|_| rng.int_in(-hi - 1, hi) as i32).collect()
    }

    #[test]
    fn direct_pack_equals_conversion_from_packed() {
        check("interleaved direct pack == from_packed", 50, |rng| {
            let bits = rng.int_in(2, 8) as u8;
            let (c, l) = (rng.int_in(1, 200) as usize, rng.int_in(1, 9) as usize);
            let a = rand_mat(rng, c * l, bits);
            let direct = InterleavedPlanes::from_a_matrix(&a, c, l, bits);
            let via = InterleavedPlanes::from_packed(&PackedPlanes::from_a_matrix(&a, c, l, bits));
            assert_eq!(direct, via, "A c={c} l={l} bits={bits}");
            let (k, c) = (rng.int_in(1, 9) as usize, rng.int_in(1, 200) as usize);
            let b = rand_mat(rng, k * c, bits);
            let direct = InterleavedPlanes::from_b_matrix(&b, k, c, bits);
            let via = InterleavedPlanes::from_packed(&PackedPlanes::from_b_matrix(&b, k, c, bits));
            assert_eq!(direct, via, "B k={k} c={c} bits={bits}");
        });
    }

    #[test]
    fn roundtrips_to_packed_losslessly() {
        check("interleaved <-> packed roundtrip", 50, |rng| {
            let bits = rng.int_in(2, 8) as u8;
            let (c, l) = (rng.int_in(1, 200) as usize, rng.int_in(1, 9) as usize);
            let a = rand_mat(rng, c * l, bits);
            let packed = PackedPlanes::from_a_matrix(&a, c, l, bits);
            let inter = InterleavedPlanes::from_packed(&packed);
            assert_eq!(inter.to_packed(), packed, "c={c} l={l} bits={bits}");
        });
    }

    #[test]
    fn layout_is_plane_interleaved_per_chunk() {
        // All planes of one 64-element C-chunk must be adjacent: chunk w
        // of vec v sits at vec_words(v)[w*bits .. (w+1)*bits].
        let mut rng = Prng::new(7);
        let (c, l, bits) = (130, 3, 4); // 3 words, last one partial
        let a = rand_mat(&mut rng, c * l, bits);
        let packed = PackedPlanes::from_a_matrix(&a, c, l, bits);
        let inter = InterleavedPlanes::from_a_matrix(&a, c, l, bits);
        assert_eq!(inter.words, 3);
        for v in 0..l {
            let vw = inter.vec_words(v);
            assert_eq!(vw.len(), inter.words * bits as usize);
            for w in 0..inter.words {
                for plane in 0..bits {
                    assert_eq!(
                        vw[w * bits as usize + plane as usize],
                        packed.vec_words(plane, v)[w],
                        "v={v} w={w} plane={plane}"
                    );
                }
            }
        }
        // Bit readback agrees with the plane-major form.
        for v in 0..l {
            for ci in 0..c {
                for plane in 0..bits {
                    assert_eq!(inter.bit(plane, v, ci), packed.bit(plane, v, ci));
                }
            }
        }
    }

    #[test]
    fn zeroed_shapes() {
        let z = InterleavedPlanes::zeroed(3, 4, 70);
        assert_eq!(z.words, 2);
        assert_eq!(z.nbytes(), 4 * 2 * 3 * 8);
        assert_eq!(z.vec_words(3).len(), 6);
        assert!(z.vec_words(0).iter().all(|&w| w == 0));
    }

    #[test]
    fn tail_pad_is_present_and_zero() {
        let mut rng = Prng::new(11);
        let (c, l, bits) = (130, 3, 5);
        let a = rand_mat(&mut rng, c * l, bits);
        let p = InterleavedPlanes::from_a_matrix(&a, c, l, bits);
        let raw = p.raw();
        let logical = p.n_vecs * p.words * p.bits as usize;
        assert_eq!(raw.len(), logical + InterleavedPlanes::TAIL_PAD_WORDS);
        assert!(raw[logical..].iter().all(|&w| w == 0), "pad must be zero");
        // Partial final chunk: bits past C are zero too.
        for plane in 0..bits {
            for v in 0..l {
                let last = p.vec_words(v)[2 * bits as usize + plane as usize];
                assert_eq!(last >> (c - 128), 0, "high bits past C must be zero");
            }
        }
    }

    #[test]
    fn repack_matches_fresh_pack_across_shape_changes() {
        check("repack_a == from_a_matrix", 40, |rng| {
            let mut buf = InterleavedPlanes::zeroed(2, 0, 0);
            for _ in 0..3 {
                let bits = rng.int_in(2, 8) as u8;
                let (c, l) = (rng.int_in(1, 200) as usize, rng.int_in(1, 9) as usize);
                let a = rand_mat(rng, c * l, bits);
                buf.repack_a(&a, c, l, bits);
                let fresh = InterleavedPlanes::from_a_matrix(&a, c, l, bits);
                assert_eq!(buf, fresh, "c={c} l={l} bits={bits}");
            }
        });
    }
}
