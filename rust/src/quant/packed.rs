//! Bit-packed bit-plane storage — the software image of GAVINA's A0/B0
//! memories.
//!
//! The ASIC stores operands "bit-serial": one binary `[C, L]` (or `[K, C]`)
//! matrix per significance, fetched per cycle. Here each plane packs its C
//! (reduction) axis into `u64` words so one iPE inner product becomes a
//! word-wise `AND` + `popcount` loop — the L3 hot path (see
//! [`crate::gemm`]).
//!
//! Layout: `data[plane][vec][word]`, flattened row-major; `vec` is the
//! non-reduced index (a column `l` of A, or a row `k` of B); `word` packs
//! 64 consecutive `c` positions, LSB = lowest `c`. Trailing bits of the
//! last word are zero (AND with zeros contributes nothing to popcount).

use super::pack_chunk;

/// Bit-planes of one integer matrix, packed along the reduction axis.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedPlanes {
    /// Number of bit-planes (the operand precision).
    pub bits: u8,
    /// Number of packed vectors (L for activations, K for weights).
    pub n_vecs: usize,
    /// Logical length of the reduction axis (C).
    pub c_dim: usize,
    /// u64 words per packed vector: `ceil(C / 64)`.
    pub words: usize,
    data: Vec<u64>,
}

impl PackedPlanes {
    /// Pack an activation matrix `A[C, L]` (row-major, C rows) into
    /// per-column planes.
    pub fn from_a_matrix(a: &[i32], c_dim: usize, l_dim: usize, bits: u8) -> Self {
        assert_eq!(a.len(), c_dim * l_dim);
        let mut p = Self::zeroed(bits, l_dim, c_dim);
        // Word-wise pack ([`pack_chunk`]): one register-built store per
        // (plane, vec, word) — ~10x faster than per-bit RMW (§Perf).
        for l in 0..l_dim {
            for w in 0..p.words {
                let c0 = w * 64;
                let cn = 64.min(c_dim - c0);
                let acc = pack_chunk((0..cn).map(|dc| a[(c0 + dc) * l_dim + l]), bits);
                for plane in 0..bits {
                    let idx = p.word_index(plane, l, w);
                    p.data[idx] = acc[plane as usize];
                }
            }
        }
        p
    }

    /// Pack a weight matrix `B[K, C]` (row-major, K rows) into per-row
    /// planes.
    pub fn from_b_matrix(b: &[i32], k_dim: usize, c_dim: usize, bits: u8) -> Self {
        assert_eq!(b.len(), k_dim * c_dim);
        let mut p = Self::zeroed(bits, k_dim, c_dim);
        for k in 0..k_dim {
            let row = &b[k * c_dim..(k + 1) * c_dim];
            for w in 0..p.words {
                let c0 = w * 64;
                let cn = 64.min(c_dim - c0);
                let acc = pack_chunk(row[c0..c0 + cn].iter().copied(), bits);
                for plane in 0..bits {
                    let idx = p.word_index(plane, k, w);
                    p.data[idx] = acc[plane as usize];
                }
            }
        }
        p
    }

    /// All-zero planes.
    pub fn zeroed(bits: u8, n_vecs: usize, c_dim: usize) -> Self {
        let words = c_dim.div_ceil(64);
        Self {
            bits,
            n_vecs,
            c_dim,
            words,
            data: vec![0u64; bits as usize * n_vecs * words],
        }
    }

    #[inline]
    fn word_index(&self, plane: u8, vec: usize, word: usize) -> usize {
        (plane as usize * self.n_vecs + vec) * self.words + word
    }

    /// Overwrite one packed word (the interleaved↔plane-major layout
    /// conversion in [`crate::quant::InterleavedPlanes`] writes through
    /// this; the packing constructors keep their batched stores).
    #[inline]
    pub(crate) fn set_word(&mut self, plane: u8, vec: usize, word: usize, value: u64) {
        let idx = self.word_index(plane, vec, word);
        self.data[idx] = value;
    }

    /// The packed words of one vector of one plane (length [`Self::words`]).
    #[inline]
    pub fn vec_words(&self, plane: u8, vec: usize) -> &[u64] {
        let start = self.word_index(plane, vec, 0);
        &self.data[start..start + self.words]
    }

    /// The packed words of one whole plane (`n_vecs · words`), vec-major.
    #[inline]
    pub fn plane_words(&self, plane: u8) -> &[u64] {
        let start = self.word_index(plane, 0, 0);
        &self.data[start..start + self.n_vecs * self.words]
    }

    /// Extract one zero-padded hardware tile from whole-matrix planes:
    /// vectors `v0..v0+tile_v` windowed to reduction range
    /// `c0..c0+tile_c`, in a fresh `PackedPlanes` of exactly the tile
    /// shape. Out-of-range vectors and reduction positions read as zero
    /// (what the A1→A0 / B1→B0 tile loaders do with edge tiles).
    ///
    /// Bit-identical to packing the zero-padded dense tile through
    /// [`Self::from_a_matrix`]/[`Self::from_b_matrix`] (property-tested
    /// below), but word-wise: ~64× less work per tile, and no dense
    /// intermediate. This is how the cycle simulator consumes the
    /// compile-once data plane — operands packed once per matrix, tiles
    /// carved out per context.
    pub fn extract_tile(&self, c0: usize, tile_c: usize, v0: usize, tile_v: usize) -> Self {
        let mut t = Self::zeroed(self.bits, tile_v, tile_c);
        let vn = tile_v.min(self.n_vecs.saturating_sub(v0));
        let cn = tile_c.min(self.c_dim.saturating_sub(c0));
        if cn == 0 || vn == 0 {
            return t;
        }
        let shift = (c0 % 64) as u32;
        let w0 = c0 / 64;
        for plane in 0..self.bits {
            for dv in 0..vn {
                let src = self.vec_words(plane, v0 + dv);
                for w in 0..t.words {
                    let lo = w0 + w;
                    let mut word = if lo < src.len() { src[lo] >> shift } else { 0 };
                    if shift != 0 && lo + 1 < src.len() {
                        word |= src[lo + 1] << (64 - shift);
                    }
                    // Zero everything past the valid reduction window
                    // (edge tiles; also keeps popcount padding-safe).
                    let base = w * 64;
                    if base + 64 > cn {
                        word &= if base >= cn {
                            0
                        } else {
                            u64::MAX >> (64 - (cn - base) as u32)
                        };
                    }
                    let idx = t.word_index(plane, dv, w);
                    t.data[idx] = word;
                }
            }
        }
        t
    }

    /// Read back a single logical bit (for tests / the cycle simulator).
    #[inline]
    pub fn bit(&self, plane: u8, vec: usize, c: usize) -> u32 {
        let w = self.data[self.word_index(plane, vec, c / 64)];
        ((w >> (c % 64)) & 1) as u32
    }

    /// Reassemble the signed integer at `(vec, c)` from its planes.
    pub fn value(&self, vec: usize, c: usize) -> i32 {
        let bits: Vec<u32> = (0..self.bits).map(|p| self.bit(p, vec, c)).collect();
        super::from_bits(&bits)
    }

    /// Unpack one plane into a dense `{0,1}` matrix, `[n_vecs, c_dim]`
    /// row-major (used to feed the PJRT artifacts and the GLS).
    pub fn unpack_plane(&self, plane: u8) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n_vecs * self.c_dim];
        for v in 0..self.n_vecs {
            for c in 0..self.c_dim {
                out[v * self.c_dim + c] = self.bit(plane, v, c) as f32;
            }
        }
        out
    }

    /// Total memory footprint of the packed planes in bytes.
    pub fn nbytes(&self) -> usize {
        self.data.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::Prng;

    fn rand_mat(rng: &mut Prng, n: usize, bits: u8) -> Vec<i32> {
        let hi = (1i64 << (bits - 1)) - 1;
        (0..n).map(|_| rng.int_in(-hi - 1, hi) as i32).collect()
    }

    #[test]
    fn pack_roundtrip_a() {
        check("packed A roundtrip", 50, |rng| {
            let bits = rng.int_in(2, 8) as u8;
            let (c, l) = (rng.int_in(1, 140) as usize, rng.int_in(1, 9) as usize);
            let a = rand_mat(rng, c * l, bits);
            let p = PackedPlanes::from_a_matrix(&a, c, l, bits);
            for ci in 0..c {
                for li in 0..l {
                    assert_eq!(p.value(li, ci), a[ci * l + li]);
                }
            }
        });
    }

    #[test]
    fn pack_roundtrip_b() {
        check("packed B roundtrip", 50, |rng| {
            let bits = rng.int_in(2, 8) as u8;
            let (k, c) = (rng.int_in(1, 17) as usize, rng.int_in(1, 140) as usize);
            let b = rand_mat(rng, k * c, bits);
            let p = PackedPlanes::from_b_matrix(&b, k, c, bits);
            for ki in 0..k {
                for ci in 0..c {
                    assert_eq!(p.value(ki, ci), b[ki * c + ci]);
                }
            }
        });
    }

    #[test]
    fn trailing_bits_are_zero() {
        // C not a multiple of 64: padding must be zero so popcount is safe.
        let c = 70;
        let a = vec![-1i32; c]; // all bits set in 2-bit two's complement
        let p = PackedPlanes::from_a_matrix(&a, c, 1, 2);
        for plane in 0..2 {
            let w = p.vec_words(plane, 0);
            assert_eq!(w.len(), 2);
            // bits 6..64 of the last word must be clear
            assert_eq!(w[1] >> (c - 64), 0);
            assert_eq!(w[0].count_ones() + w[1].count_ones(), c as u32);
        }
    }

    #[test]
    fn extract_tile_matches_per_tile_packing_a() {
        // Carving a tile out of whole-matrix planes must be bit-identical
        // to the legacy path: zero-pad the dense i32 tile, then pack it.
        check("extract_tile == pad+pack (A)", 40, |rng| {
            let bits = rng.int_in(2, 8) as u8;
            let (c, l) = (rng.int_in(1, 200) as usize, rng.int_in(1, 10) as usize);
            let (tc, tv) = (rng.int_in(1, 90) as usize, rng.int_in(1, 6) as usize);
            let a = rand_mat(rng, c * l, bits);
            let full = PackedPlanes::from_a_matrix(&a, c, l, bits);
            for co in 0..c.div_ceil(tc) {
                for lo in 0..l.div_ceil(tv) {
                    let (c0, l0) = (co * tc, lo * tv);
                    let mut tile = vec![0i32; tc * tv];
                    for dc in 0..tc.min(c - c0) {
                        for dl in 0..tv.min(l - l0) {
                            tile[dc * tv + dl] = a[(c0 + dc) * l + (l0 + dl)];
                        }
                    }
                    let legacy = PackedPlanes::from_a_matrix(&tile, tc, tv, bits);
                    assert_eq!(
                        full.extract_tile(c0, tc, l0, tv),
                        legacy,
                        "c={c} l={l} tc={tc} tv={tv} co={co} lo={lo}"
                    );
                }
            }
        });
    }

    #[test]
    fn extract_tile_matches_per_tile_packing_b() {
        check("extract_tile == pad+pack (B)", 40, |rng| {
            let bits = rng.int_in(2, 8) as u8;
            let (k, c) = (rng.int_in(1, 10) as usize, rng.int_in(1, 200) as usize);
            let (tc, tk) = (rng.int_in(1, 90) as usize, rng.int_in(1, 6) as usize);
            let b = rand_mat(rng, k * c, bits);
            let full = PackedPlanes::from_b_matrix(&b, k, c, bits);
            for co in 0..c.div_ceil(tc) {
                for ko in 0..k.div_ceil(tk) {
                    let (c0, k0) = (co * tc, ko * tk);
                    let mut tile = vec![0i32; tk * tc];
                    for dk in 0..tk.min(k - k0) {
                        for dc in 0..tc.min(c - c0) {
                            tile[dk * tc + dc] = b[(k0 + dk) * c + (c0 + dc)];
                        }
                    }
                    let legacy = PackedPlanes::from_b_matrix(&tile, tk, tc, bits);
                    assert_eq!(
                        full.extract_tile(c0, tc, k0, tk),
                        legacy,
                        "k={k} c={c} tc={tc} tk={tk} co={co} ko={ko}"
                    );
                }
            }
        });
    }

    #[test]
    fn extract_tile_beyond_range_is_all_zero() {
        let a = vec![-1i32; 70 * 2];
        let p = PackedPlanes::from_a_matrix(&a, 70, 2, 3);
        let t = p.extract_tile(128, 64, 0, 2); // fully past C
        assert_eq!(t, PackedPlanes::zeroed(3, 2, 64));
        let t = p.extract_tile(0, 64, 2, 2); // fully past vecs
        assert_eq!(t, PackedPlanes::zeroed(3, 2, 64));
    }

    #[test]
    fn unpack_plane_matches_bits() {
        let mut rng = Prng::new(9);
        let (c, k, bits) = (100, 3, 4);
        let b = rand_mat(&mut rng, k * c, bits);
        let p = PackedPlanes::from_b_matrix(&b, k, c, bits);
        for plane in 0..bits {
            let dense = p.unpack_plane(plane);
            for ki in 0..k {
                for ci in 0..c {
                    assert_eq!(dense[ki * c + ci] as u32, p.bit(plane, ki, ci));
                }
            }
        }
    }
}
