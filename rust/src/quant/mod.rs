//! Quantization substrate: uniform symmetric quantization (§IV-B, ref.
//! [27]) and the bit-serial data layout of GAVINA's A0/B0 memories —
//! two's-complement bit-plane slicing and bit-packed planes for the u64
//! popcount hot path, in two layouts: plane-major [`PackedPlanes`] (the
//! step-sequence/simulator form) and plane-interleaved
//! [`InterleavedPlanes`] (the fused exact kernel's form).
//!
//! Conventions (shared with `python/compile/kernels/ref.py`):
//! * Symmetric signed range for `bits`: `[-(2^(b-1)-1), 2^(b-1)-1]`
//!   (narrow range — the most negative code is dropped).
//! * Bit-plane `i` holds bit `i` of the two's-complement encoding over
//!   `bits` bits (LSB first); the MSB plane carries weight `-2^(bits-1)`.

pub mod interleaved;
pub mod packed;
pub mod simd;

pub use interleaved::InterleavedPlanes;
pub use packed::PackedPlanes;

/// Word-wise bit-plane slice of one ≤64-element reduction chunk: returns
/// `acc` with `acc[plane]` holding bit `plane` of each value, LSB of the
/// word = first value. The single packing inner loop shared by both
/// storage layouts ([`PackedPlanes`], [`InterleavedPlanes`]) and both
/// operand orientations — ~10× faster than per-bit read-modify-write
/// because each plane word is built in a register and stored once.
#[inline]
pub(crate) fn pack_chunk(vals: impl Iterator<Item = i32>, bits: u8) -> [u64; 8] {
    let mask = if bits >= 32 {
        u32::MAX
    } else {
        (1u32 << bits) - 1
    };
    let mut acc = [0u64; 8]; // bits ≤ 8
    for (dc, v) in vals.enumerate() {
        debug_assert!(fits(v, bits), "{v} does not fit in {bits} bits");
        debug_assert!(dc < 64);
        let u = (v as u32) & mask;
        for (plane, word) in acc.iter_mut().enumerate().take(bits as usize) {
            *word |= (((u >> plane) & 1) as u64) << dc;
        }
    }
    acc
}

/// Symmetric signed integer range for `bits` bits.
pub fn quant_range(bits: u8) -> (i32, i32) {
    let hi = (1i32 << (bits - 1)) - 1;
    (-hi, hi)
}

/// Uniform symmetric per-tensor quantization. Returns `(q, scale)` with
/// `x ≈ q · scale` and `q` clamped to the symmetric range.
pub fn quantize_sym(x: &[f32], bits: u8) -> (Vec<i32>, f32) {
    let (lo, hi) = quant_range(bits);
    let amax = x.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-8);
    let scale = amax / hi as f32;
    let q = x
        .iter()
        .map(|&v| ((v / scale).round() as i32).clamp(lo, hi))
        .collect();
    (q, scale)
}

/// Dequantize back to f32.
pub fn dequantize(q: &[i32], scale: f32) -> Vec<f32> {
    q.iter().map(|&v| v as f32 * scale).collect()
}

/// Extract bit `i` of the two's-complement encoding of `v` over `bits`
/// bits. `v` must be representable in `bits` bits.
#[inline]
pub fn tc_bit(v: i32, bits: u8, i: u8) -> u32 {
    debug_assert!(fits(v, bits), "{v} does not fit in {bits} bits");
    let mask = if bits >= 32 {
        u32::MAX
    } else {
        (1u32 << bits) - 1
    };
    ((v as u32) & mask) >> i & 1
}

/// Does `v` fit in `bits` two's-complement bits?
#[inline]
pub fn fits(v: i32, bits: u8) -> bool {
    if bits >= 32 {
        return true;
    }
    let lo = -(1i64 << (bits - 1));
    let hi = (1i64 << (bits - 1)) - 1;
    (v as i64) >= lo && (v as i64) <= hi
}

/// Reassemble a signed value from its two's-complement bits (LSB first).
pub fn from_bits(bits_lsb_first: &[u32]) -> i32 {
    let b = bits_lsb_first.len();
    let mut v: i64 = 0;
    for (i, &bit) in bits_lsb_first.iter().enumerate() {
        debug_assert!(bit <= 1);
        let w = if i == b - 1 {
            -(1i64 << i)
        } else {
            1i64 << i
        };
        v += w * bit as i64;
    }
    v as i32
}

/// The per-step weight of bit-plane `i` of a `bits`-bit operand
/// (`-2^(bits-1)` for the MSB, `2^i` otherwise).
#[inline]
pub fn plane_weight(i: u8, bits: u8) -> i64 {
    if i == bits - 1 {
        -(1i64 << i)
    } else {
        1i64 << i
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn quant_range_symmetric() {
        assert_eq!(quant_range(2), (-1, 1));
        assert_eq!(quant_range(4), (-7, 7));
        assert_eq!(quant_range(8), (-127, 127));
    }

    #[test]
    fn quantize_hits_extremes() {
        let x = [1.0f32, -1.0, 0.5, 0.0];
        let (q, s) = quantize_sym(&x, 4);
        assert_eq!(q[0], 7);
        assert_eq!(q[1], -7);
        assert_eq!(q[3], 0);
        assert!((s - 1.0 / 7.0).abs() < 1e-6);
    }

    #[test]
    fn quantize_dequantize_error_bound() {
        check("quant roundtrip bounded", 50, |rng| {
            let bits = rng.int_in(2, 8) as u8;
            let n = rng.int_in(1, 64) as usize;
            let x: Vec<f32> = (0..n).map(|_| (rng.next_f32() - 0.5) * 4.0).collect();
            let (q, s) = quantize_sym(&x, bits);
            let xd = dequantize(&q, s);
            // Max quantization error is scale/2 (plus clamp at amax which
            // cannot occur for symmetric quantization of the max element).
            for (a, b) in x.iter().zip(&xd) {
                assert!((a - b).abs() <= s * 0.5 + 1e-6, "bits={bits} {a} vs {b}");
            }
        });
    }

    #[test]
    fn twos_complement_roundtrip() {
        check("tc bits roundtrip", 200, |rng| {
            let bits = rng.int_in(2, 8) as u8;
            let (lo, hi) = quant_range(bits);
            let v = rng.int_in(lo as i64 - 1, hi as i64) as i32; // incl. -2^(b-1)
            let planes: Vec<u32> = (0..bits).map(|i| tc_bit(v, bits, i)).collect();
            assert_eq!(from_bits(&planes), v, "v={v} bits={bits}");
        });
    }

    #[test]
    fn plane_weights_sum_to_value() {
        // v = sum_i weight(i) * bit_i — the identity the bit-serial GEMM
        // relies on.
        for bits in 2u8..=8 {
            let (lo, hi) = quant_range(bits);
            for v in lo - 1..=hi {
                let mut acc = 0i64;
                for i in 0..bits {
                    acc += plane_weight(i, bits) * tc_bit(v, bits, i) as i64;
                }
                assert_eq!(acc, v as i64);
            }
        }
    }
}
