//! Evaluation metrics: the paper's VAR_NED (Eq. 1), MSE, classification
//! accuracy and small histogram helpers used by the benches.

/// Normalized error distances of a batch: `NED_i = (E_i − A_i) / E_max`
/// with `E_max = max |E_i|` (paper Eq. 1 text).
pub fn ned(exact: &[i64], approx: &[i64]) -> Vec<f64> {
    assert_eq!(exact.len(), approx.len());
    let e_max = exact
        .iter()
        .map(|&v| (v as f64).abs())
        .fold(0.0f64, f64::max)
        .max(1e-12);
    exact
        .iter()
        .zip(approx)
        .map(|(&e, &a)| (e - a) as f64 / e_max)
        .collect()
}

/// The paper's error metric (Eq. 1): variance of the normalized error
/// distance. Zero iff the computation is exact (constant-offset errors do
/// not occur in this setting).
pub fn var_ned(exact: &[i64], approx: &[i64]) -> f64 {
    let neds = ned(exact, approx);
    variance(&neds)
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Mean squared error between two f32 vectors (the §IV-D perturbation
/// metric on network outputs).
pub fn mse_f32(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Top-1 classification accuracy from logits (`[n, classes]` row-major).
pub fn accuracy(logits: &[f32], labels: &[i32], classes: usize) -> f64 {
    assert_eq!(logits.len(), labels.len() * classes);
    if labels.is_empty() {
        return 0.0;
    }
    let correct = labels
        .iter()
        .enumerate()
        .filter(|&(i, &y)| {
            let row = &logits[i * classes..(i + 1) * classes];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap();
            argmax == y as usize
        })
        .count();
    correct as f64 / labels.len() as f64
}

/// Fraction of positions that differ (raw error rate, used by the
/// model-vs-GLS comparison in Fig. 7).
pub fn mismatch_rate(a: &[u16], b: &[u16]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).filter(|(x, y)| x != y).count() as f64 / a.len() as f64
}

/// Per-bit flip rates between exact and approximate iPE outputs
/// (`s_bits` long, LSB first) — the Fig. 7b/c error maps.
pub fn bit_flip_rates(exact: &[u16], approx: &[u16], s_bits: usize) -> Vec<f64> {
    assert_eq!(exact.len(), approx.len());
    let mut flips = vec![0usize; s_bits];
    for (&e, &a) in exact.iter().zip(approx) {
        let x = e ^ a;
        for (bit, f) in flips.iter_mut().enumerate() {
            *f += ((x >> bit) & 1) as usize;
        }
    }
    flips
        .into_iter()
        .map(|f| f as f64 / exact.len().max(1) as f64)
        .collect()
}

/// A fixed-width histogram over `[lo, hi)` used by the workload generator
/// tests and the bench reports.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x >= lo && x < hi {
            h[((x - lo) / w) as usize] += 1;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn var_ned_zero_for_exact() {
        let e = vec![5, -3, 100, 0];
        assert_eq!(var_ned(&e, &e), 0.0);
    }

    #[test]
    fn var_ned_scale_invariant() {
        // VAR_NED normalizes by E_max: scaling both vectors by 2 in the
        // integer domain keeps it identical.
        let e = vec![10, -20, 30, 5];
        let a = vec![11, -20, 28, 5];
        let e2: Vec<i64> = e.iter().map(|v| v * 2).collect();
        let a2: Vec<i64> = a.iter().map(|v| v * 2).collect();
        assert!((var_ned(&e, &a) - var_ned(&e2, &a2)).abs() < 1e-15);
    }

    #[test]
    fn var_ned_grows_with_error_magnitude() {
        let e = vec![100i64; 64];
        let small: Vec<i64> = e.iter().enumerate().map(|(i, v)| v + (i % 2) as i64).collect();
        let big: Vec<i64> = e.iter().enumerate().map(|(i, v)| v + 10 * (i % 2) as i64).collect();
        assert!(var_ned(&e, &big) > var_ned(&e, &small));
    }

    #[test]
    fn variance_matches_definition() {
        check("variance non-negative & shift-invariant", 50, |rng| {
            let n = rng.int_in(1, 100) as usize;
            let xs: Vec<f64> = (0..n).map(|_| rng.next_f64() * 10.0).collect();
            let v = variance(&xs);
            assert!(v >= 0.0);
            let shifted: Vec<f64> = xs.iter().map(|x| x + 5.0).collect();
            assert!((variance(&shifted) - v).abs() < 1e-9);
        });
    }

    #[test]
    fn accuracy_basics() {
        // 2 samples, 3 classes.
        let logits = vec![0.1, 0.9, 0.0, /* -> 1 */ 0.5, 0.2, 0.3 /* -> 0 */];
        assert_eq!(accuracy(&logits, &[1, 0], 3), 1.0);
        assert_eq!(accuracy(&logits, &[0, 0], 3), 0.5);
        assert_eq!(accuracy(&logits, &[0, 1], 3), 0.0);
    }

    #[test]
    fn bit_flip_rates_localized() {
        let exact = vec![0u16; 100];
        let approx: Vec<u16> = (0..100).map(|i| if i < 50 { 4 } else { 0 }).collect();
        let rates = bit_flip_rates(&exact, &approx, 4);
        assert_eq!(rates, vec![0.0, 0.0, 0.5, 0.0]);
    }

    #[test]
    fn histogram_counts() {
        let xs = vec![0.1, 0.2, 0.5, 0.9, 1.5];
        let h = histogram(&xs, 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 2]); // 1.5 outside
    }

    #[test]
    fn mse_zero_iff_equal() {
        let a = vec![1.0f32, 2.0, 3.0];
        assert_eq!(mse_f32(&a, &a), 0.0);
        let b = vec![1.0f32, 2.0, 4.0];
        assert!((mse_f32(&a, &b) - 1.0 / 3.0).abs() < 1e-9);
    }
}
