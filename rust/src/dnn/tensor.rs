//! Minimal dense NHWC tensor for the DNN substrate. No autograd, no
//! broadcasting zoo — inference only, shaped exactly for the quantized
//! ResNet path (`exec.rs`).

/// Dense f32 tensor, row-major over its dims.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Self { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        Self {
            dims,
            data: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// NHWC accessor (debug/test use; hot paths index `data` directly).
    #[inline]
    pub fn at4(&self, n: usize, h: usize, w: usize, c: usize) -> f32 {
        debug_assert_eq!(self.dims.len(), 4);
        let (dh, dw, dc) = (self.dims[1], self.dims[2], self.dims[3]);
        self.data[((n * dh + h) * dw + w) * dc + c]
    }

    /// Robust activation range: `min(max|x|, mean|x| + 6·std|x|)` — the
    /// same statistic as `python/compile/model.py::act_amax` so both
    /// executors quantize with the same scales.
    pub fn robust_amax(&self) -> f32 {
        robust_amax_slice(&self.data)
    }

    /// Element-wise ReLU in place.
    pub fn relu_inplace(&mut self) {
        for v in &mut self.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    /// Element-wise add (residual connections).
    pub fn add_inplace(&mut self, other: &Tensor) {
        assert_eq!(self.dims, other.dims);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Global average pool NHWC → `[N, C]`.
    pub fn global_avg_pool(&self) -> Tensor {
        let (n, h, w, c) = (self.dims[0], self.dims[1], self.dims[2], self.dims[3]);
        let mut out = vec![0.0f32; n * c];
        let inv = 1.0 / (h * w) as f32;
        for ni in 0..n {
            for hi in 0..h {
                for wi in 0..w {
                    let base = ((ni * h + hi) * w + wi) * c;
                    for ci in 0..c {
                        out[ni * c + ci] += self.data[base + ci] * inv;
                    }
                }
            }
        }
        Tensor::new(vec![n, c], out)
    }
}

/// Slice form of [`Tensor::robust_amax`], exposed so per-image
/// activation quantization (`dnn::exec::forward_rows`) can scale each
/// image's sub-slice with bit-identical arithmetic to the whole-tensor
/// path. Both forms are one implementation — the SIMD-dispatched
/// [`crate::quant::simd::robust_amax`], whose canonical 4-lane-blocked
/// f64 accumulation produces identical bits on every kernel — so the
/// activation scale can never depend on the code path that computed it.
/// Same `min(max|x|, mean|x| + 6·std|x|)` cap, same `1e-8` empty
/// fallback as before.
pub fn robust_amax_slice(data: &[f32]) -> f32 {
    crate::quant::simd::robust_amax(data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn robust_amax_caps_outliers() {
        // 1000 small values + one huge outlier: the cap must bite.
        let mut data = vec![0.1f32; 1000];
        data.push(100.0);
        let t = Tensor::new(vec![1001], data);
        let amax = t.robust_amax();
        assert!(amax < 50.0, "outlier must be capped: {amax}");
        assert!(amax > 0.1);
    }

    #[test]
    fn robust_amax_equals_max_for_tame_data() {
        let t = Tensor::new(vec![4], vec![0.5, -1.0, 0.75, 0.25]);
        // std is large relative to the spread: cap doesn't bite.
        assert!((t.robust_amax() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn robust_amax_slice_matches_tensor_form() {
        let data = vec![0.3f32, -2.0, 0.9, 4.5, -0.1, 0.0, 1.25];
        let t = Tensor::new(vec![7], data.clone());
        assert_eq!(t.robust_amax().to_bits(), robust_amax_slice(&data).to_bits());
        assert_eq!(robust_amax_slice(&[]), 1e-8);
    }

    #[test]
    fn gap_means() {
        // [1, 2, 2, 1] with values 1,2,3,4 -> mean 2.5.
        let t = Tensor::new(vec![1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let g = t.global_avg_pool();
        assert_eq!(g.dims, vec![1, 1]);
        assert!((g.data[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn relu_and_add() {
        let mut t = Tensor::new(vec![3], vec![-1.0, 0.5, 2.0]);
        t.relu_inplace();
        assert_eq!(t.data, vec![0.0, 0.5, 2.0]);
        t.add_inplace(&Tensor::new(vec![3], vec![1.0, 1.0, 1.0]));
        assert_eq!(t.data, vec![1.0, 1.5, 3.0]);
    }
}
