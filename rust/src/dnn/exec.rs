//! Quantized ResNet-18 inference on GAVINA (paper §IV-D).
//!
//! Mirrors `python/compile/model.py::resnet18_apply` layer-for-layer: the
//! same CIFAR topology (conv0 + 4 stages × 2 basic blocks + GAP + fc), the
//! same uniform symmetric quantization (robust per-tensor activation
//! range, per-output-channel weight ranges), the same SAME padding and BN
//! application — so the QAT weights trained at build time produce the same
//! accuracy here, and every convolution runs as an integer GEMM through
//! the cycle-level GAVINA simulator with per-layer GAV schedules.
//!
//! Execution is delegated to a pluggable [`ExecBackend`]
//! (see [`crate::engine::backend`]): the exact fake-quant reference
//! ([`crate::engine::FloatBackend`]), the cycle-level simulator with
//! optional undervolting error injection ([`crate::engine::GavinaBackend`]),
//! or full gate-level simulation of undervolted tiles
//! ([`crate::engine::GlsBackend`]). Most callers should not construct an
//! `Executor` directly — use [`crate::engine::EngineBuilder`], the
//! validated facade over this type.

use super::lower::{col2im, im2col, weights_to_b, ConvGeom};
use super::tensor::Tensor;
use super::weights::{AnyTensor, TensorMap};
use crate::arch::{GavSchedule, Precision};
use crate::engine::backend::{ExecBackend, LayerGemm};

/// Elements of one 32×32×3 input image.
pub const IMAGE_LEN: usize = 32 * 32 * 3;

/// ResNet-18 stage table: (base channels, first-block stride); actual
/// widths are `max(8, base · width_mult)` (matches the Python model).
pub const STAGES: [(usize, usize); 4] = [(64, 1), (128, 2), (256, 2), (512, 2)];
pub const BLOCKS_PER_STAGE: usize = 2;

/// Channel width at a multiplier.
pub fn ch(base: usize, width_mult: f64) -> usize {
    ((base as f64 * width_mult) as usize).max(8)
}

/// Names of all conv layers in execution order (the per-layer G vector
/// and the Fig. 8a x-axis index into this).
pub fn conv_layer_names() -> Vec<String> {
    let mut names = vec!["conv0".to_string()];
    let mut cin = 64;
    for (si, (c, stride)) in STAGES.iter().enumerate() {
        for bi in 0..BLOCKS_PER_STAGE {
            let s = if bi == 0 { *stride } else { 1 };
            let p = format!("s{si}b{bi}");
            names.push(format!("{p}/conv1"));
            names.push(format!("{p}/conv2"));
            if s != 1 || cin != *c {
                names.push(format!("{p}/down"));
            }
            cin = *c;
        }
    }
    names
}

/// Aggregated hardware counters of one forward pass.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ForwardStats {
    pub cycles: u64,
    pub tiles: u64,
    pub corrupted: u64,
    pub useful_macs: u64,
    pub executed_macs: u64,
    /// Per-conv-layer useful MACs (the ILP operation weights).
    pub layer_macs: Vec<u64>,
    /// Per-conv-layer (C, L, K) GEMM dims.
    pub layer_dims: Vec<(usize, usize, usize)>,
}

impl ForwardStats {
    /// Accumulate another pass's counters. The per-layer tables are
    /// copied from the first non-empty source only: they describe that
    /// pass's per-layer shape (layer MACs scale with its batch size), so
    /// treat them as representative geometry, not accumulated totals.
    pub fn absorb(&mut self, other: &ForwardStats) {
        self.cycles += other.cycles;
        self.tiles += other.tiles;
        self.corrupted += other.corrupted;
        self.useful_macs += other.useful_macs;
        self.executed_macs += other.executed_macs;
        if self.layer_macs.is_empty() {
            self.layer_macs = other.layer_macs.clone();
            self.layer_dims = other.layer_dims.clone();
        }
    }
}

/// One forward pass result.
#[derive(Clone, Debug)]
pub struct ForwardResult {
    /// Logits `[N, classes]` row-major.
    pub logits: Vec<f32>,
    pub n: usize,
    pub classes: usize,
    pub stats: ForwardStats,
}

/// The executor. `layer_gs[i]` is the GAV `G` for conv layer `i`; use
/// `prec.max_g()` everywhere for exact operation.
pub struct Executor<'a> {
    pub weights: &'a TensorMap,
    pub width_mult: f64,
    pub prec: Precision,
    pub backend: &'a dyn ExecBackend,
    pub layer_gs: Vec<u32>,
    /// Deterministic sub-batch stream id mixed into the backend's
    /// per-layer seed (serving shards); `0` for standalone runs.
    pub stream: u64,
}

impl<'a> Executor<'a> {
    pub fn new(
        weights: &'a TensorMap,
        width_mult: f64,
        prec: Precision,
        backend: &'a dyn ExecBackend,
    ) -> Self {
        let n_layers = conv_layer_names().len();
        Self {
            weights,
            width_mult,
            prec,
            backend,
            layer_gs: vec![prec.max_g(); n_layers],
            stream: 0,
        }
    }

    /// Set a uniform G on every layer.
    pub fn with_uniform_g(mut self, g: u32) -> Self {
        for x in &mut self.layer_gs {
            *x = g;
        }
        self
    }

    fn wf32(&self, name: &str) -> (&[usize], &[f32]) {
        self.weights
            .get(name)
            .and_then(AnyTensor::as_f32)
            .unwrap_or_else(|| panic!("missing f32 weight '{name}'"))
    }

    /// Quantize + integer-GEMM one conv; returns the dequantized output
    /// (pre-BN).
    fn qconv(
        &self,
        x: &Tensor,
        conv: &str,
        stride: usize,
        layer_idx: usize,
        stats: &mut ForwardStats,
    ) -> Tensor {
        let (wdims, wdata) = self.wf32(&format!("{conv}/w"));
        let g = ConvGeom::new(x, wdims, stride);
        let (c_dim, l_dim, k_dim) = (g.c_dim(), g.l_dim(), g.k_dim());

        // --- activation quantization (per tensor, robust range) ---
        let hi_a = ((1i32 << (self.prec.a_bits - 1)) - 1) as f32;
        let sa = x.robust_amax().max(1e-8) / hi_a;
        let a_f = im2col(x, &g);
        let qa: Vec<i32> = a_f
            .iter()
            .map(|&v| ((v / sa).round() as i32).clamp(-hi_a as i32, hi_a as i32))
            .collect();

        // --- weight quantization (per output channel) ---
        let hi_w = ((1i32 << (self.prec.b_bits - 1)) - 1) as f32;
        let b_f = weights_to_b(wdims, wdata);
        let mut sw = vec![0.0f32; k_dim];
        for k in 0..k_dim {
            let amax = b_f[k * c_dim..(k + 1) * c_dim]
                .iter()
                .fold(0.0f32, |m, v| m.max(v.abs()))
                .max(1e-8);
            sw[k] = amax / hi_w;
        }
        let qb: Vec<i32> = b_f
            .iter()
            .enumerate()
            .map(|(i, &v)| {
                let k = i / c_dim;
                ((v / sw[k]).round() as i32).clamp(-hi_w as i32, hi_w as i32)
            })
            .collect();

        // --- integer GEMM (pluggable backend) ---
        let out = self.backend.run_layer_gemm(&LayerGemm {
            a: &qa,
            b: &qb,
            c: c_dim,
            l: l_dim,
            k: k_dim,
            sched: GavSchedule::two_level(self.prec, self.layer_gs[layer_idx]),
            layer_idx,
            stream: self.stream,
        });
        stats.cycles += out.counters.cycles;
        stats.tiles += out.counters.tiles;
        stats.corrupted += out.counters.corrupted;
        stats.executed_macs += out.counters.executed_macs;
        let p_int = out.p;
        stats.useful_macs += g.macs();
        if stats.layer_macs.len() <= layer_idx {
            stats.layer_macs.resize(layer_idx + 1, 0);
            stats.layer_dims.resize(layer_idx + 1, (0, 0, 0));
        }
        stats.layer_macs[layer_idx] = g.macs();
        stats.layer_dims[layer_idx] = (c_dim, l_dim, k_dim);

        // --- dequantize ---
        let mut p = vec![0.0f32; k_dim * l_dim];
        for k in 0..k_dim {
            let s = sa * sw[k];
            for l in 0..l_dim {
                p[k * l_dim + l] = p_int[k * l_dim + l] as f32 * s;
            }
        }
        col2im(&p, &g)
    }

    /// BN (inference form) per channel.
    fn bn(&self, x: &mut Tensor, bn: &str) {
        let (_, scale) = self.wf32(&format!("{bn}/scale"));
        let (_, bias) = self.wf32(&format!("{bn}/bias"));
        let (_, mean) = self.wf32(&format!("{bn}/mean"));
        let (_, var) = self.wf32(&format!("{bn}/var"));
        let c = *x.dims.last().unwrap();
        assert_eq!(scale.len(), c);
        // Precompute per-channel affine.
        let mul: Vec<f32> = (0..c)
            .map(|i| scale[i] / (var[i] + 1e-5).sqrt())
            .collect();
        for (i, v) in x.data.iter_mut().enumerate() {
            let ci = i % c;
            *v = (*v - mean[ci]) * mul[ci] + bias[ci];
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn qconv_bn(
        &self,
        x: &Tensor,
        conv: &str,
        bnn: &str,
        stride: usize,
        relu: bool,
        layer: &mut usize,
        stats: &mut ForwardStats,
    ) -> Tensor {
        let mut y = self.qconv(x, conv, stride, *layer, stats);
        *layer += 1;
        self.bn(&mut y, bnn);
        if relu {
            y.relu_inplace();
        }
        y
    }

    /// Forward one batch of NHWC images in `[0, 1]`.
    pub fn forward(&self, images: &[f32], n: usize) -> ForwardResult {
        assert_eq!(images.len(), n * IMAGE_LEN);
        let mut stats = ForwardStats::default();
        let mut layer = 0usize;
        let mut x = Tensor::new(vec![n, 32, 32, 3], images.to_vec());

        x = self.qconv_bn(&x, "conv0", "bn0", 1, true, &mut layer, &mut stats);
        let mut cin = ch(64, self.width_mult);
        for (si, (c, stride)) in STAGES.iter().enumerate() {
            let cout = ch(*c, self.width_mult);
            for bi in 0..BLOCKS_PER_STAGE {
                let s = if bi == 0 { *stride } else { 1 };
                let p = format!("s{si}b{bi}");
                let y = self.qconv_bn(
                    &x,
                    &format!("{p}/conv1"),
                    &format!("{p}/bn1"),
                    s,
                    true,
                    &mut layer,
                    &mut stats,
                );
                let mut y = self.qconv_bn(
                    &y,
                    &format!("{p}/conv2"),
                    &format!("{p}/bn2"),
                    1,
                    false,
                    &mut layer,
                    &mut stats,
                );
                let sc = if self.weights.contains_key(&format!("{p}/down/w")) {
                    self.qconv_bn(
                        &x,
                        &format!("{p}/down"),
                        &format!("{p}/dbn"),
                        s,
                        false,
                        &mut layer,
                        &mut stats,
                    )
                } else {
                    x.clone()
                };
                y.add_inplace(&sc);
                y.relu_inplace();
                x = y;
                cin = cout;
            }
        }
        let _ = cin;

        // GAP -> fake-quant -> fc (fc itself stays in float, as in Python).
        let mut gap = x.global_avg_pool();
        let hi_a = ((1i32 << (self.prec.a_bits - 1)) - 1) as f32;
        let sa = gap.robust_amax().max(1e-8) / hi_a;
        for v in &mut gap.data {
            *v = ((*v / sa).round()).clamp(-hi_a, hi_a) * sa;
        }
        let (fdims, fw) = self.wf32("fc/w");
        let (_, fb) = self.wf32("fc/b");
        let (cin_fc, classes) = (fdims[0], fdims[1]);
        assert_eq!(gap.dims, vec![n, cin_fc]);
        let mut logits = vec![0.0f32; n * classes];
        for ni in 0..n {
            for k in 0..classes {
                let mut acc = fb[k];
                for ci in 0..cin_fc {
                    acc += gap.data[ni * cin_fc + ci] * fw[ci * classes + k];
                }
                logits[ni * classes + k] = acc;
            }
        }
        ForwardResult {
            logits,
            n,
            classes,
            stats,
        }
    }

    /// Forward a large set in internal mini-batches (bounds im2col memory).
    pub fn forward_batched(&self, images: &[f32], n: usize, batch: usize) -> ForwardResult {
        let mut logits = Vec::new();
        let mut stats = ForwardStats::default();
        let mut classes = 0;
        let img_len = IMAGE_LEN;
        let mut i = 0;
        while i < n {
            let bn = batch.min(n - i);
            let r = self.forward(&images[i * img_len..(i + bn) * img_len], bn);
            logits.extend_from_slice(&r.logits);
            classes = r.classes;
            stats.absorb(&r.stats);
            i += bn;
        }
        ForwardResult {
            logits,
            n,
            classes,
            stats,
        }
    }
}


/// Synthetic-weight support: a random-but-valid weight map with the exact
/// key/shape structure of the trained artifacts — lets tests, benches and
/// the quickstart run without `make artifacts`.
pub mod synth {
    use super::*;
    use crate::util::Prng;
    use crate::dnn::weights::AnyTensor;

    /// Build a random-but-valid weight map for a narrow model (tests run
    /// without artifacts).
    pub fn synthetic_weights(width_mult: f64, seed: u64) -> TensorMap {
        let mut rng = Prng::new(seed);
        let mut m = TensorMap::new();
        let conv = |m: &mut TensorMap,
                    name: &str,
                    kh: usize,
                    cin: usize,
                    cout: usize,
                    rng: &mut Prng| {
            let n = kh * kh * cin * cout;
            let std = (2.0 / (kh * kh * cin) as f64).sqrt();
            m.insert(
                format!("{name}/w"),
                AnyTensor::F32(
                    vec![kh, kh, cin, cout],
                    (0..n).map(|_| (rng.normal() * std) as f32).collect(),
                ),
            );
        };
        let bn = |m: &mut TensorMap, name: &str, c: usize| {
            m.insert(format!("{name}/scale"), AnyTensor::F32(vec![c], vec![1.0; c]));
            m.insert(format!("{name}/bias"), AnyTensor::F32(vec![c], vec![0.0; c]));
            m.insert(format!("{name}/mean"), AnyTensor::F32(vec![c], vec![0.0; c]));
            m.insert(format!("{name}/var"), AnyTensor::F32(vec![c], vec![1.0; c]));
        };
        let c0 = ch(64, width_mult);
        conv(&mut m, "conv0", 3, 3, c0, &mut rng);
        bn(&mut m, "bn0", c0);
        let mut cin = c0;
        for (si, (c, stride)) in STAGES.iter().enumerate() {
            let cout = ch(*c, width_mult);
            for bi in 0..BLOCKS_PER_STAGE {
                let s = if bi == 0 { *stride } else { 1 };
                let p = format!("s{si}b{bi}");
                conv(&mut m, &format!("{p}/conv1"), 3, cin, cout, &mut rng);
                bn(&mut m, &format!("{p}/bn1"), cout);
                conv(&mut m, &format!("{p}/conv2"), 3, cout, cout, &mut rng);
                bn(&mut m, &format!("{p}/bn2"), cout);
                if s != 1 || cin != cout {
                    conv(&mut m, &format!("{p}/down"), 1, cin, cout, &mut rng);
                    bn(&mut m, &format!("{p}/dbn"), cout);
                }
                cin = cout;
            }
        }
        let classes = 10;
        m.insert(
            "fc/w".into(),
            AnyTensor::F32(
                vec![cin, classes],
                (0..cin * classes)
                    .map(|_| (rng.normal() * 0.1) as f32)
                    .collect(),
            ),
        );
        m.insert("fc/b".into(), AnyTensor::F32(vec![classes], vec![0.0; classes]));
        m
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use super::synth::synthetic_weights;
    use crate::arch::ArchConfig;
    use crate::engine::backend::{FloatBackend, GavinaBackend};
    use crate::util::Prng;
    use std::sync::Arc;

    fn rand_images(rng: &mut Prng, n: usize) -> Vec<f32> {
        (0..n * 32 * 32 * 3).map(|_| rng.next_f32()).collect()
    }

    #[test]
    fn layer_names_count() {
        // conv0 + 8 blocks × 2 convs + 3 downsamples = 20 conv layers.
        let names = conv_layer_names();
        assert_eq!(names.len(), 20, "{names:?}");
        assert_eq!(names[0], "conv0");
        assert!(names.contains(&"s1b0/down".to_string()));
        assert!(!names.contains(&"s0b0/down".to_string())); // stride 1, cin==cout
    }

    #[test]
    fn float_and_guarded_gavina_agree() {
        // The cycle-level integer path with a fully guarded schedule must
        // produce the same logits as the float fake-quant reference.
        let wm = 0.125; // narrow: fast
        let weights = synthetic_weights(wm, 1);
        let mut rng = Prng::new(2);
        let imgs = rand_images(&mut rng, 2);
        let prec = Precision::new(4, 4);

        let ex_f = Executor::new(&weights, wm, prec, &FloatBackend);
        let rf = ex_f.forward(&imgs, 2);

        let sim = GavinaBackend {
            arch: ArchConfig::tiny(),
            tables: None,
            seed: 3,
        };
        let ex_g = Executor::new(&weights, wm, prec, &sim);
        let rg = ex_g.forward(&imgs, 2);

        assert_eq!(rf.logits.len(), rg.logits.len());
        for (a, b) in rf.logits.iter().zip(&rg.logits) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        assert!(rg.stats.cycles > 0);
        assert_eq!(rg.stats.corrupted, 0);
        assert_eq!(rg.stats.layer_macs.len(), 20);
    }

    #[test]
    fn error_injection_perturbs_logits() {
        use crate::errmodel::{ErrorTables, ModelParams};
        let wm = 0.125;
        let weights = synthetic_weights(wm, 4);
        let mut rng = Prng::new(5);
        let imgs = rand_images(&mut rng, 1);
        let prec = Precision::new(4, 4);
        let arch = ArchConfig::tiny();

        let params = ModelParams::paper(arch.c_dim);
        let mut tables = ErrorTables::zeroed(params);
        for bit in 0..params.s_bits {
            for e in 0..=params.c_dim as u16 {
                for pb in 0..params.p_bins {
                    for cd in 0..params.n_cond(bit) {
                        tables.set_prob(bit, e, pb, cd, 0.05);
                    }
                }
            }
        }

        let exact = Executor::new(&weights, wm, prec, &FloatBackend).forward(&imgs, 1);
        let sim = GavinaBackend {
            arch,
            tables: Some(Arc::new(tables)),
            seed: 6,
        };
        let uv = Executor::new(&weights, wm, prec, &sim)
            .with_uniform_g(0)
            .forward(&imgs, 1);
        assert!(uv.stats.corrupted > 0);
        let mse = crate::stats::mse_f32(&exact.logits, &uv.logits);
        assert!(mse > 0.0, "undervolting must perturb logits");
    }

    #[test]
    fn per_layer_g_only_affects_that_layer() {
        use crate::errmodel::{ErrorTables, ModelParams};
        let wm = 0.125;
        let weights = synthetic_weights(wm, 7);
        let mut rng = Prng::new(8);
        let imgs = rand_images(&mut rng, 1);
        let prec = Precision::new(2, 2);
        let arch = ArchConfig::tiny();
        let params = ModelParams::paper(arch.c_dim);
        let mut tables = ErrorTables::zeroed(params);
        // Only the MSB flips, always: big perturbation when undervolted.
        let msb = params.s_bits - 1;
        for e in 0..=params.c_dim as u16 {
            for pb in 0..params.p_bins {
                tables.set_prob(msb, e, pb, 0, 1.0);
            }
        }
        let sim = GavinaBackend {
            arch,
            tables: Some(Arc::new(tables)),
            seed: 9,
        };
        let mk = |gs: Vec<u32>| {
            let mut ex = Executor::new(&weights, wm, prec, &sim);
            ex.layer_gs = gs;
            ex.forward(&imgs, 1)
        };
        let all_guard = mk(vec![prec.max_g(); 20]);
        assert_eq!(all_guard.stats.corrupted, 0);
        let mut gs = vec![prec.max_g(); 20];
        gs[5] = 0;
        let one_uv = mk(gs);
        assert!(one_uv.stats.corrupted > 0);
    }
}
