//! Quantized ResNet-18 inference on GAVINA (paper §IV-D).
//!
//! Mirrors `python/compile/model.py::resnet18_apply` layer-for-layer: the
//! same CIFAR topology (conv0 + 4 stages × 2 basic blocks + GAP + fc), the
//! same uniform symmetric quantization (robust per-tensor activation
//! range, per-output-channel weight ranges), the same SAME padding and BN
//! application — so the QAT weights trained at build time produce the same
//! accuracy here, and every convolution runs as an integer GEMM through
//! the cycle-level GAVINA simulator with per-layer GAV schedules.
//!
//! The data plane is **compile-once** (see [`crate::dnn::plan`]): the
//! network is lowered into per-layer [`LayerPlan`]s — quantized weights
//! pre-packed as bit-planes, BN folded, geometry and GAV schedule
//! resolved — either at `EngineBuilder::build()` or in
//! [`Executor::new`]. A request then only pays for activation work: one
//! **streaming fused prologue** per layer ([`pack_a_fused`] — patch
//! gather, robust-scale quantization and bit-plane interleave in a
//! single multi-threaded pass over the input, no materialized im2col
//! matrix), and the backend GEMM.
//!
//! Execution is delegated to a pluggable [`ExecBackend`]
//! (see [`crate::engine::backend`]): the exact fake-quant reference
//! ([`crate::engine::FloatBackend`]), the cycle-level simulator with
//! optional undervolting error injection ([`crate::engine::GavinaBackend`]),
//! or full gate-level simulation of undervolted tiles
//! ([`crate::engine::GlsBackend`]). Most callers should not construct an
//! `Executor` directly — use [`crate::engine::EngineBuilder`], the
//! validated facade over this type.

use std::borrow::Cow;
use std::cell::RefCell;

use super::lower::{im2col_into, visit_col_runs, ColRun, ConvGeom};
use super::plan::{LayerPlan, PlannedModel};
use super::tensor::{robust_amax_slice, Tensor};
use super::weights::TensorMap;
use crate::arch::Precision;
use crate::engine::backend::{ExecBackend, LayerGemm};
use crate::gemm::simd::{self, KernelKind};
use crate::quant::simd::RunPacker;
use crate::quant::InterleavedPlanes;
use crate::util::parallel::parallel_chunks_mut;

/// Elements of one 32×32×3 input image.
pub const IMAGE_LEN: usize = 32 * 32 * 3;

/// ResNet-18 stage table: (base channels, first-block stride); actual
/// widths are `max(8, base · width_mult)` (matches the Python model).
pub const STAGES: [(usize, usize); 4] = [(64, 1), (128, 2), (256, 2), (512, 2)];
pub const BLOCKS_PER_STAGE: usize = 2;

/// Channel width at a multiplier.
pub fn ch(base: usize, width_mult: f64) -> usize {
    ((base as f64 * width_mult) as usize).max(8)
}

/// Names of all conv layers in execution order (the per-layer G vector
/// and the Fig. 8a x-axis index into this).
pub fn conv_layer_names() -> Vec<String> {
    let mut names = vec!["conv0".to_string()];
    let mut cin = 64;
    for (si, (c, stride)) in STAGES.iter().enumerate() {
        for bi in 0..BLOCKS_PER_STAGE {
            let s = if bi == 0 { *stride } else { 1 };
            let p = format!("s{si}b{bi}");
            names.push(format!("{p}/conv1"));
            names.push(format!("{p}/conv2"));
            if s != 1 || cin != *c {
                names.push(format!("{p}/down"));
            }
            cin = *c;
        }
    }
    names
}

/// Aggregated hardware counters of one forward pass.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ForwardStats {
    pub cycles: u64,
    pub tiles: u64,
    pub corrupted: u64,
    pub useful_macs: u64,
    pub executed_macs: u64,
    /// Significance steps executed undervolted (error injection armed).
    pub steps_approx: u64,
    /// Significance steps executed guarded (always exact).
    pub steps_guarded: u64,
    /// Per-conv-layer useful MACs (the ILP operation weights).
    pub layer_macs: Vec<u64>,
    /// Per-conv-layer (C, L, K) GEMM dims.
    pub layer_dims: Vec<(usize, usize, usize)>,
    /// Per-conv-layer corrupted-value counts from the simulator's
    /// per-step injection path (accumulated, unlike the geometry tables).
    pub layer_corrupted: Vec<u64>,
    /// Per-conv-layer undervolted step counts — the denominator of the
    /// observed per-layer step-error rate `layer_corrupted / layer_steps`.
    pub layer_steps: Vec<u64>,
}

impl ForwardStats {
    /// Grow every per-layer table so index `idx` is valid — the one place
    /// that keeps `layer_macs`, `layer_dims`, `layer_corrupted` and
    /// `layer_steps` the same length (they used to be resized
    /// independently at every record site).
    pub fn ensure_layer(&mut self, idx: usize) {
        if self.layer_macs.len() <= idx {
            self.layer_macs.resize(idx + 1, 0);
            self.layer_dims.resize(idx + 1, (0, 0, 0));
        }
        if self.layer_corrupted.len() <= idx {
            self.layer_corrupted.resize(idx + 1, 0);
            self.layer_steps.resize(idx + 1, 0);
        }
    }

    /// Record one layer's geometry (MACs + GEMM dims) at `idx`.
    pub fn record_layer(&mut self, idx: usize, macs: u64, dims: (usize, usize, usize)) {
        self.ensure_layer(idx);
        self.layer_macs[idx] = macs;
        self.layer_dims[idx] = dims;
    }

    /// Accumulate one layer's observed injection counters at `idx`:
    /// corrupted values and undervolted steps, summed (a layer can run
    /// more than once per pass when batches are chunked across threads).
    pub fn record_layer_errors(&mut self, idx: usize, corrupted: u64, steps_approx: u64) {
        self.ensure_layer(idx);
        self.layer_corrupted[idx] += corrupted;
        self.layer_steps[idx] += steps_approx;
    }

    /// Observed per-layer step-error rate: corrupted values per
    /// undervolted step (0.0 for fully guarded layers).
    pub fn layer_step_error_rates(&self) -> Vec<f64> {
        self.layer_corrupted
            .iter()
            .zip(&self.layer_steps)
            .map(|(&c, &s)| if s == 0 { 0.0 } else { c as f64 / s as f64 })
            .collect()
    }

    /// Accumulate another pass's counters. The geometry tables are copied
    /// from the first non-empty source only: they describe that pass's
    /// per-layer shape (layer MACs scale with its batch size), so treat
    /// them as representative geometry, not accumulated totals. The
    /// per-layer error counters, in contrast, are true totals and are
    /// summed element-wise (chunked parallel batches must not drop the
    /// other chunks' injections).
    pub fn absorb(&mut self, other: &ForwardStats) {
        self.cycles += other.cycles;
        self.tiles += other.tiles;
        self.corrupted += other.corrupted;
        self.useful_macs += other.useful_macs;
        self.executed_macs += other.executed_macs;
        self.steps_approx += other.steps_approx;
        self.steps_guarded += other.steps_guarded;
        // Both geometry tables travel together (ensure_layer keeps them
        // the same length), so guard on both before adopting the source.
        if self.layer_macs.is_empty() && self.layer_dims.is_empty() {
            self.layer_macs.clone_from(&other.layer_macs);
            self.layer_dims.clone_from(&other.layer_dims);
        }
        if self.layer_corrupted.len() < other.layer_corrupted.len() {
            self.layer_corrupted.resize(other.layer_corrupted.len(), 0);
            self.layer_steps.resize(other.layer_steps.len(), 0);
        }
        for (i, (&c, &s)) in other.layer_corrupted.iter().zip(&other.layer_steps).enumerate() {
            self.layer_corrupted[i] += c;
            self.layer_steps[i] += s;
        }
    }
}

/// Granularity of the activation quantization scale.
///
/// `PerBatch` is the historical path: one robust range over the whole
/// batch tensor, so an image's integers depend on which images share its
/// batch. `PerImage` derives an independent scale per image, which makes
/// batching **bit-transparent**: a row packed into a cross-request batch
/// quantizes to exactly the integers it would get alone, so a packed
/// guarded GEMM equals per-request execution row for row (GEMM columns
/// never mix images). The serve plane's continuous batcher rides on
/// `PerImage` ([`Executor::forward_rows`]); `forward` keeps `PerBatch`
/// so standalone numerics are bit-identical to every earlier release.
/// For `n == 1` the two are the same computation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ActQuant {
    PerBatch,
    PerImage,
}

/// One forward pass result.
#[derive(Clone, Debug)]
pub struct ForwardResult {
    /// Logits `[N, classes]` row-major.
    pub logits: Vec<f32>,
    pub n: usize,
    pub classes: usize,
    pub stats: ForwardStats,
}

/// Reusable scratch: just the packed A-side planes. The fused streaming
/// prologue ([`pack_a_fused`]) quantizes and packs straight from the
/// input tensor, so the f32 im2col matrix and the i32 staging vector
/// that used to live here no longer exist on the hot path (they survive
/// only as locals of the property-test reference, [`pack_a_reference`]).
struct Scratch {
    /// A-side planes packed straight into the fused kernel's interleaved
    /// layout, one reused allocation across layers and requests
    /// ([`InterleavedPlanes::reshape_zeroed`]).
    ia: InterleavedPlanes,
}

impl Default for Scratch {
    fn default() -> Self {
        Self {
            ia: InterleavedPlanes::zeroed(2, 0, 0),
        }
    }
}

thread_local! {
    /// One scratch arena per OS thread, re-used across layers, forward
    /// passes AND executors — the engine/serve path constructs a fresh
    /// short-lived `Executor` per request, so per-executor buffers would
    /// re-allocate on every call; per-thread buffers amortize to zero on
    /// a long-lived serving worker. Backends never re-enter the executor,
    /// so the `RefCell` borrow is never contended.
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::default());
}

/// The executor: walks the ResNet topology over a [`PlannedModel`] —
/// borrowed from an `Engine` (the serve path, lowered exactly once at
/// `build()`) or owned (standalone construction from raw weights) —
/// packing activation planes once per layer and delegating every GEMM to
/// the backend. Weights are never touched at request time.
pub struct Executor<'a> {
    model: Cow<'a, PlannedModel>,
    pub backend: &'a dyn ExecBackend,
    /// Deterministic sub-batch stream id mixed into the backend's
    /// per-layer seed (serving shards); `0` for standalone runs.
    pub stream: u64,
    /// Worker threads for the fused activation prologue (`0` = one per
    /// core, `1` = serial). Purely a speed knob: prologue workers own
    /// disjoint column spans of the interleaved A buffer and each column
    /// is packed by exactly the serial arithmetic, so any value produces
    /// bit-identical planes — and therefore bit-identical logits.
    pub threads: usize,
}

impl<'a> Executor<'a> {
    /// Lower `weights` on the spot (fully guarded schedules) and wrap an
    /// executor around the result — the standalone/offline entry point.
    /// The serve path lowers once at `EngineBuilder::build()` and uses
    /// [`Executor::planned`] instead.
    pub fn new(
        weights: &TensorMap,
        width_mult: f64,
        prec: Precision,
        backend: &'a dyn ExecBackend,
    ) -> Self {
        let gs = vec![prec.max_g(); conv_layer_names().len()];
        Self {
            model: Cow::Owned(PlannedModel::lower(weights, width_mult, prec, &gs)),
            backend,
            stream: 0,
            threads: 1,
        }
    }

    /// An executor over an already-compiled model (no lowering, no
    /// packing — the per-request path).
    pub fn planned(model: &'a PlannedModel, backend: &'a dyn ExecBackend) -> Self {
        Self {
            model: Cow::Borrowed(model),
            backend,
            stream: 0,
            threads: 1,
        }
    }

    /// The compiled model this executor runs.
    pub fn model(&self) -> &PlannedModel {
        &self.model
    }

    /// Set a uniform G on every layer (cheap: schedules are re-resolved,
    /// packed weights are shared).
    pub fn with_uniform_g(self, g: u32) -> Self {
        let n = self.model().plans().len();
        self.with_layer_gs(vec![g; n])
    }

    /// Replace the per-layer G vector (builder style).
    pub fn with_layer_gs(mut self, gs: Vec<u32>) -> Self {
        self.set_layer_gs(gs);
        self
    }

    /// Replace the per-layer G vector in place.
    pub fn set_layer_gs(&mut self, gs: Vec<u32>) {
        let rescheduled = self.model().with_layer_gs(&gs);
        self.model = Cow::Owned(rescheduled);
    }

    /// Quantize activations, run one planned conv through the backend,
    /// and apply the fused dequant + folded-BN (+ ReLU) epilogue. With
    /// [`ActQuant::PerBatch`] the arithmetic matches the old per-request
    /// path bit for bit: same quantization expressions, same f32
    /// operation order per element. With [`ActQuant::PerImage`] each
    /// image gets its own robust scale (same expressions applied to its
    /// sub-slice), so the result per image is independent of the batch.
    fn qconv(
        &self,
        x: &Tensor,
        plan: &LayerPlan,
        relu: bool,
        stats: &mut ForwardStats,
        q: ActQuant,
    ) -> Tensor {
        let prec = self.model().prec();
        let g = plan.geom(x.dims[0]);
        debug_assert_eq!(
            [x.dims[1], x.dims[2], x.dims[3]],
            [g.h, g.w, g.cin],
            "input shape vs plan '{}' geometry",
            plan.name()
        );
        let (c_dim, l_dim, k_dim) = (g.c_dim(), g.l_dim(), g.k_dim());
        // Output pixels per image: column `l = (n·oh + ohi)·ow + owi` of
        // the im2col matrix belongs to image `l / ohw`.
        let ohw = g.oh * g.ow;

        // --- activation quantization (robust range; one scale for the
        //     whole batch, or one per image) ---
        let hi_a = ((1i32 << (prec.a_bits - 1)) - 1) as f32;
        let sa: Vec<f32> = match q {
            ActQuant::PerBatch => vec![x.robust_amax().max(1e-8) / hi_a],
            ActQuant::PerImage => {
                let per = x.data.len() / g.n;
                (0..g.n)
                    .map(|i| robust_amax_slice(&x.data[i * per..(i + 1) * per]).max(1e-8) / hi_a)
                    .collect()
            }
        };
        let out = SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            let Scratch { ia } = &mut *scratch;
            // One streaming pass over the input: every prologue worker
            // gathers its columns' patch runs (or takes the 1×1 strided
            // view), quantizes with the owning image's scale, and packs
            // bit-planes directly into its disjoint span of the reused
            // interleaved A allocation — no f32 im2col matrix, no i32
            // staging vector. B was packed (in both layouts) at build()
            // and lives in the plan. Then the integer GEMM through the
            // pluggable backend.
            pack_a_fused(x, &g, &sa, hi_a, prec.a_bits, self.threads, ia);
            self.backend.run_layer_gemm(&LayerGemm {
                a: ia,
                plan,
                stream: self.stream,
            })
        });
        stats.cycles += out.counters.cycles;
        stats.tiles += out.counters.tiles;
        stats.corrupted += out.counters.corrupted;
        stats.executed_macs += out.counters.executed_macs;
        stats.steps_approx += out.counters.steps_approx;
        stats.steps_guarded += out.counters.steps_guarded;
        stats.useful_macs += g.macs();
        stats.record_layer(plan.layer_idx(), g.macs(), (c_dim, l_dim, k_dim));
        stats.record_layer_errors(
            plan.layer_idx(),
            out.counters.corrupted,
            out.counters.steps_approx,
        );

        // --- fused dequant + folded BN (+ ReLU), written straight into
        //     the NHWC output tensor ---
        let sw = plan.wscales();
        let bn = plan.bn();
        let mut y = Tensor::zeros(vec![g.n, g.oh, g.ow, g.cout]);
        for k in 0..k_dim {
            match q {
                ActQuant::PerBatch => {
                    let s = sa[0] * sw[k];
                    for l in 0..l_dim {
                        let v = bn.apply(k, out.p[k * l_dim + l] as f32 * s);
                        // l = (n·oh + ohi)·ow + owi ; NHWC index = l·cout + k.
                        y.data[l * g.cout + k] = if relu && v < 0.0 { 0.0 } else { v };
                    }
                }
                ActQuant::PerImage => {
                    for l in 0..l_dim {
                        let s = sa[l / ohw] * sw[k];
                        let v = bn.apply(k, out.p[k * l_dim + l] as f32 * s);
                        y.data[l * g.cout + k] = if relu && v < 0.0 { 0.0 } else { v };
                    }
                }
            }
        }
        y
    }

    /// Forward one batch of NHWC images in `[0, 1]`, with the historical
    /// per-batch activation scales (an image's integers depend on its
    /// batch mates — bit-identical to every earlier release).
    pub fn forward(&self, images: &[f32], n: usize) -> ForwardResult {
        assert_eq!(images.len(), n * IMAGE_LEN);
        let x = Tensor::new(vec![n, 32, 32, 3], images.to_vec());
        self.forward_tensor(x, n, ActQuant::PerBatch)
    }

    /// Forward a cross-request packed batch: one GEMM A-side over all
    /// rows, but **per-image** activation scales, so every row's logits
    /// are bit-identical to forwarding that row alone (under a
    /// deterministic backend — guarded schedules or the float
    /// reference). This is the serve plane's continuous-batching entry
    /// point: requests from different sessions can share a batch without
    /// coupling their numerics.
    pub fn forward_rows(&self, rows: &[&[f32]]) -> ForwardResult {
        let n = rows.len();
        assert!(n > 0, "forward_rows needs at least one row");
        let mut data = Vec::with_capacity(n * IMAGE_LEN);
        for r in rows {
            assert_eq!(r.len(), IMAGE_LEN);
            data.extend_from_slice(r);
        }
        let x = Tensor::new(vec![n, 32, 32, 3], data);
        self.forward_tensor(x, n, ActQuant::PerImage)
    }

    fn forward_tensor(&self, mut x: Tensor, n: usize, q: ActQuant) -> ForwardResult {
        let model = self.model();
        let plans = model.plans();
        let mut stats = ForwardStats::default();
        let mut layer = 0usize;

        x = self.qconv(&x, &plans[layer], true, &mut stats, q);
        layer += 1;
        for _si in 0..STAGES.len() {
            for _bi in 0..BLOCKS_PER_STAGE {
                let y = self.qconv(&x, &plans[layer], true, &mut stats, q);
                layer += 1;
                let mut y = self.qconv(&y, &plans[layer], false, &mut stats, q);
                layer += 1;
                // The lowering emits a `…/down` plan right after conv2
                // exactly when the block has a projection shortcut.
                let sc = if plans.get(layer).is_some_and(|p| p.name().ends_with("/down")) {
                    let sc = self.qconv(&x, &plans[layer], false, &mut stats, q);
                    layer += 1;
                    sc
                } else {
                    x.clone()
                };
                y.add_inplace(&sc);
                y.relu_inplace();
                x = y;
            }
        }
        debug_assert_eq!(layer, plans.len());

        // GAP -> fake-quant -> fc (fc itself stays in float, as in Python).
        let mut gap = x.global_avg_pool();
        let hi_a = ((1i32 << (model.prec().a_bits - 1)) - 1) as f32;
        match q {
            ActQuant::PerBatch => {
                let sa = gap.robust_amax().max(1e-8) / hi_a;
                for v in &mut gap.data {
                    *v = ((*v / sa).round()).clamp(-hi_a, hi_a) * sa;
                }
            }
            ActQuant::PerImage => {
                let c = gap.dims[1];
                for i in 0..n {
                    let sa = robust_amax_slice(&gap.data[i * c..(i + 1) * c]).max(1e-8) / hi_a;
                    for v in &mut gap.data[i * c..(i + 1) * c] {
                        *v = ((*v / sa).round()).clamp(-hi_a, hi_a) * sa;
                    }
                }
            }
        }
        let fc = &model.fc;
        let (cin_fc, classes) = (fc.fc_in, fc.classes);
        assert_eq!(gap.dims, vec![n, cin_fc]);
        // Register-blocked head on the same micro-kernel blocking as the
        // conv GEMMs — bit-identical to the scalar triple loop (each
        // logit still accumulates in ascending-ci order from its bias).
        let logits = crate::gemm::kernel::dense_affine(&gap.data, &fc.w, &fc.b, n, cin_fc, classes);
        ForwardResult {
            logits,
            n,
            classes,
            stats,
        }
    }

    /// Forward a large set in internal mini-batches (bounds im2col memory).
    pub fn forward_batched(&self, images: &[f32], n: usize, batch: usize) -> ForwardResult {
        let mut logits = Vec::new();
        let mut stats = ForwardStats::default();
        let mut classes = 0;
        let img_len = IMAGE_LEN;
        let mut i = 0;
        while i < n {
            let bn = batch.min(n - i);
            let r = self.forward(&images[i * img_len..(i + bn) * img_len], bn);
            logits.extend_from_slice(&r.logits);
            classes = r.classes;
            stats.absorb(&r.stats);
            i += bn;
        }
        ForwardResult {
            logits,
            n,
            classes,
            stats,
        }
    }
}


/// The fused activation prologue on the process's active kernel: one
/// streaming, multi-threaded im2col → quantize → bit-plane-interleave
/// pass. See [`pack_a_fused_with`].
pub fn pack_a_fused(
    x: &Tensor,
    g: &ConvGeom,
    sa: &[f32],
    hi_a: f32,
    bits: u8,
    threads: usize,
    ia: &mut InterleavedPlanes,
) {
    pack_a_fused_with(simd::active(), x, g, sa, hi_a, bits, threads, ia);
}

/// Build the interleaved A-side planes for one conv in **one streaming
/// pass**: the im2col L axis is partitioned into contiguous column
/// blocks over `threads` workers, and each worker walks its columns'
/// patch runs ([`visit_col_runs`] — for a 1×1/fc geometry each column is
/// a single strided view of the input, nothing is gathered), quantizes
/// every value with the owning image's scale on the `kind` SIMD path,
/// and packs bit-planes directly into the column's disjoint chunk range
/// of `ia` (`[l·words·bits, (l+1)·words·bits)`). No f32 im2col matrix or
/// i32 staging vector is ever materialized.
///
/// `sa` holds either one scale for the whole batch or one per image
/// (column `l` belongs to image `l / (oh·ow)`). Bit-identical to
/// [`pack_a_reference`] for every kernel kind and thread count
/// (property-tested below): each column's values are quantized by
/// exactly the scalar expression `((v / s).round() as i32).clamp(…)`
/// and packed in C order, and zero-padding taps pack to all-zero planes
/// just as quantized `0.0` does.
#[allow(clippy::too_many_arguments)]
pub fn pack_a_fused_with(
    kind: KernelKind,
    x: &Tensor,
    g: &ConvGeom,
    sa: &[f32],
    hi_a: f32,
    bits: u8,
    threads: usize,
    ia: &mut InterleavedPlanes,
) {
    let (c_dim, l_dim) = (g.c_dim(), g.l_dim());
    assert!(sa.len() == 1 || sa.len() == g.n, "one scale, or one per image");
    ia.reshape_zeroed(bits, l_dim, c_dim);
    if c_dim == 0 || l_dim == 0 {
        return;
    }
    let row = ia.words * bits as usize;
    let ohw = g.oh * g.ow;
    parallel_chunks_mut(ia.logical_mut(), row, threads, |l, chunk| {
        let s = if sa.len() == 1 { sa[0] } else { sa[l / ohw] };
        let mut p = RunPacker::new(chunk, bits, s, hi_a, kind);
        visit_col_runs(x, g, l, |r| match r {
            ColRun::Data(run) => p.push_run(run),
            ColRun::Zeros(z) => p.push_zeros(z),
        });
        let pushed = p.finish();
        debug_assert_eq!(pushed, c_dim, "column {l} must cover the C axis");
    });
}

/// The retained three-pass reference prologue: materialize the f32
/// im2col matrix, scalar-quantize it into an i32 staging vector
/// (resize + indexed writes — no `clear`/`extend` reallocation churn),
/// then re-pack into the interleaved layout. Serial by construction.
/// This is the ground truth [`pack_a_fused_with`] is property-tested
/// against, and the baseline the prologue benchmark times the fused
/// path over.
#[allow(clippy::too_many_arguments)]
pub fn pack_a_reference(
    x: &Tensor,
    g: &ConvGeom,
    sa: &[f32],
    hi_a: f32,
    bits: u8,
    af: &mut Vec<f32>,
    qa: &mut Vec<i32>,
    ia: &mut InterleavedPlanes,
) {
    let (c_dim, l_dim) = (g.c_dim(), g.l_dim());
    assert!(sa.len() == 1 || sa.len() == g.n, "one scale, or one per image");
    let ohw = g.oh * g.ow;
    im2col_into(x, g, af);
    qa.resize(af.len(), 0);
    if sa.len() == 1 {
        let s = sa[0];
        for (dst, &v) in qa.iter_mut().zip(af.iter()) {
            *dst = ((v / s).round() as i32).clamp(-hi_a as i32, hi_a as i32);
        }
    } else {
        // A is `[C, L]` row-major (`a[c·L + l]`), so the image owning
        // element `idx` is `(idx % l_dim) / ohw`.
        for (idx, (dst, &v)) in qa.iter_mut().zip(af.iter()).enumerate() {
            let s = sa[(idx % l_dim) / ohw];
            *dst = ((v / s).round() as i32).clamp(-hi_a as i32, hi_a as i32);
        }
    }
    ia.repack_a(qa, c_dim, l_dim, bits);
}

/// Synthetic-weight support: a random-but-valid weight map with the exact
/// key/shape structure of the trained artifacts — lets tests, benches and
/// the quickstart run without `make artifacts`.
pub mod synth {
    use super::*;
    use crate::dnn::weights::AnyTensor;
    use crate::util::Prng;

    /// Build a random-but-valid weight map for a narrow model (tests run
    /// without artifacts).
    pub fn synthetic_weights(width_mult: f64, seed: u64) -> TensorMap {
        let mut rng = Prng::new(seed);
        let mut m = TensorMap::new();
        let conv = |m: &mut TensorMap,
                    name: &str,
                    kh: usize,
                    cin: usize,
                    cout: usize,
                    rng: &mut Prng| {
            let n = kh * kh * cin * cout;
            let std = (2.0 / (kh * kh * cin) as f64).sqrt();
            m.insert(
                format!("{name}/w"),
                AnyTensor::F32(
                    vec![kh, kh, cin, cout],
                    (0..n).map(|_| (rng.normal() * std) as f32).collect(),
                ),
            );
        };
        let bn = |m: &mut TensorMap, name: &str, c: usize| {
            m.insert(format!("{name}/scale"), AnyTensor::F32(vec![c], vec![1.0; c]));
            m.insert(format!("{name}/bias"), AnyTensor::F32(vec![c], vec![0.0; c]));
            m.insert(format!("{name}/mean"), AnyTensor::F32(vec![c], vec![0.0; c]));
            m.insert(format!("{name}/var"), AnyTensor::F32(vec![c], vec![1.0; c]));
        };
        let c0 = ch(64, width_mult);
        conv(&mut m, "conv0", 3, 3, c0, &mut rng);
        bn(&mut m, "bn0", c0);
        let mut cin = c0;
        for (si, (c, stride)) in STAGES.iter().enumerate() {
            let cout = ch(*c, width_mult);
            for bi in 0..BLOCKS_PER_STAGE {
                let s = if bi == 0 { *stride } else { 1 };
                let p = format!("s{si}b{bi}");
                conv(&mut m, &format!("{p}/conv1"), 3, cin, cout, &mut rng);
                bn(&mut m, &format!("{p}/bn1"), cout);
                conv(&mut m, &format!("{p}/conv2"), 3, cout, cout, &mut rng);
                bn(&mut m, &format!("{p}/bn2"), cout);
                if s != 1 || cin != cout {
                    conv(&mut m, &format!("{p}/down"), 1, cin, cout, &mut rng);
                    bn(&mut m, &format!("{p}/dbn"), cout);
                }
                cin = cout;
            }
        }
        let classes = 10;
        m.insert(
            "fc/w".into(),
            AnyTensor::F32(
                vec![cin, classes],
                (0..cin * classes)
                    .map(|_| (rng.normal() * 0.1) as f32)
                    .collect(),
            ),
        );
        m.insert("fc/b".into(), AnyTensor::F32(vec![classes], vec![0.0; classes]));
        m
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use super::synth::synthetic_weights;
    use crate::arch::ArchConfig;
    use crate::engine::backend::{FloatBackend, GavinaBackend};
    use crate::util::Prng;
    use std::sync::Arc;

    fn rand_images(rng: &mut Prng, n: usize) -> Vec<f32> {
        (0..n * 32 * 32 * 3).map(|_| rng.next_f32()).collect()
    }

    #[test]
    fn layer_names_count() {
        // conv0 + 8 blocks × 2 convs + 3 downsamples = 20 conv layers.
        let names = conv_layer_names();
        assert_eq!(names.len(), 20, "{names:?}");
        assert_eq!(names[0], "conv0");
        assert!(names.contains(&"s1b0/down".to_string()));
        assert!(!names.contains(&"s0b0/down".to_string())); // stride 1, cin==cout
    }

    #[test]
    fn float_and_guarded_gavina_agree() {
        // The cycle-level integer path with a fully guarded schedule must
        // produce the same logits as the float fake-quant reference.
        let wm = 0.125; // narrow: fast
        let weights = synthetic_weights(wm, 1);
        let mut rng = Prng::new(2);
        let imgs = rand_images(&mut rng, 2);
        let prec = Precision::new(4, 4);

        let ex_f = Executor::new(&weights, wm, prec, &FloatBackend);
        let rf = ex_f.forward(&imgs, 2);

        let sim = GavinaBackend {
            arch: ArchConfig::tiny(),
            tables: None,
            seed: 3,
        };
        let ex_g = Executor::new(&weights, wm, prec, &sim);
        let rg = ex_g.forward(&imgs, 2);

        assert_eq!(rf.logits.len(), rg.logits.len());
        for (a, b) in rf.logits.iter().zip(&rg.logits) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        assert!(rg.stats.cycles > 0);
        assert_eq!(rg.stats.corrupted, 0);
        assert_eq!(rg.stats.layer_macs.len(), 20);
    }

    #[test]
    fn planned_executor_reuses_the_compiled_model() {
        // Executor::planned over a shared PlannedModel must equal the
        // standalone lower-on-construction path bit for bit, and repeat
        // calls (scratch reuse) must stay deterministic.
        let wm = 0.125;
        let weights = synthetic_weights(wm, 11);
        let mut rng = Prng::new(12);
        let imgs = rand_images(&mut rng, 2);
        let prec = Precision::new(2, 2);
        let sim = GavinaBackend {
            arch: ArchConfig::tiny(),
            tables: None,
            seed: 13,
        };
        let gs = vec![prec.max_g(); conv_layer_names().len()];
        let model = PlannedModel::lower(&weights, wm, prec, &gs);
        let planned = Executor::planned(&model, &sim);
        let a = planned.forward(&imgs, 2);
        let b = Executor::new(&weights, wm, prec, &sim).forward(&imgs, 2);
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.stats, b.stats);
        // Second call on the same executor: scratch buffers are reused,
        // results must not drift.
        let again = planned.forward(&imgs, 2);
        assert_eq!(a.logits, again.logits);
        assert_eq!(a.stats, again.stats);
    }

    #[test]
    fn forward_rows_singleton_matches_forward_bit_for_bit() {
        // For n == 1 the per-image and per-batch scale are the same
        // computation, so the packed-rows entry point must be exactly the
        // standalone path.
        let wm = 0.125;
        let weights = synthetic_weights(wm, 21);
        let mut rng = Prng::new(22);
        let imgs = rand_images(&mut rng, 1);
        let sim = GavinaBackend {
            arch: ArchConfig::tiny(),
            tables: None,
            seed: 23,
        };
        let ex = Executor::new(&weights, wm, Precision::new(4, 4), &sim);
        let alone = ex.forward(&imgs, 1);
        let packed = ex.forward_rows(&[&imgs]);
        assert_eq!(alone.logits, packed.logits);
    }

    #[test]
    fn forward_rows_packed_batch_equals_per_row_results() {
        // The whole point of per-image activation scales: a cross-request
        // packed batch must produce, row for row, exactly the logits each
        // row gets on its own — under a deterministic (guarded) backend.
        let wm = 0.125;
        let weights = synthetic_weights(wm, 31);
        let mut rng = Prng::new(32);
        let rows: Vec<Vec<f32>> = (0..3).map(|_| rand_images(&mut rng, 1)).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let sim = GavinaBackend {
            arch: ArchConfig::tiny(),
            tables: None,
            seed: 33,
        };
        let ex = Executor::new(&weights, wm, Precision::new(2, 2), &sim);
        let packed = ex.forward_rows(&refs);
        assert_eq!(packed.n, 3);
        let classes = packed.classes;
        for (i, row) in rows.iter().enumerate() {
            let alone = ex.forward(row, 1);
            assert_eq!(
                packed.logits[i * classes..(i + 1) * classes],
                alone.logits[..],
                "row {i} must be unaffected by its batch mates"
            );
        }
    }

    #[test]
    fn fused_prologue_matches_reference_three_pass() {
        // The tentpole contract: the streaming multi-threaded single-pass
        // prologue must produce bit-identical interleaved planes to the
        // retained three-pass reference, across per-batch vs per-image
        // scales, 1×1 (pointwise fast path) vs general geometry, partial
        // final C-words (c = 65, 130, 135), every available SIMD kind,
        // and thread counts 1 / 2 / 64.
        let geoms: &[(usize, usize, usize, usize, usize, usize)] = &[
            // (n, h, w, cin, k, stride)
            (2, 6, 5, 3, 3, 1),   // general 3×3, SAME pad
            (2, 7, 7, 15, 3, 2),  // strided 3×3, c = 135 (2 words + 7 bits)
            (1, 4, 4, 65, 1, 1),  // pointwise, c = 65 (one spill bit)
            (3, 5, 5, 130, 1, 2), // strided pointwise, c = 130
            (2, 8, 8, 8, 1, 1),   // pointwise, c = 8 (sub-word)
        ];
        let mut rng = Prng::new(0xF0CC);
        for &(n, h, w, cin, k, stride) in geoms {
            let g = crate::dnn::lower::ConvGeom::from_dims(n, h, w, &[k, k, cin, 4], stride);
            let x = Tensor::new(
                vec![n, h, w, cin],
                (0..n * h * w * cin)
                    .map(|_| rng.next_f32() * 2.0 - 1.0)
                    .collect(),
            );
            for bits in [2u8, 4, 8] {
                let hi_a = ((1i32 << (bits - 1)) - 1) as f32;
                let per = x.data.len() / n;
                let sa_batch = vec![x.robust_amax().max(1e-8) / hi_a];
                let sa_image: Vec<f32> = (0..n)
                    .map(|i| robust_amax_slice(&x.data[i * per..(i + 1) * per]).max(1e-8) / hi_a)
                    .collect();
                // s = 1.0 exposes exact-halfway quantization inputs.
                let sa_unit = vec![1.0f32];
                for sa in [&sa_batch, &sa_image, &sa_unit] {
                    let mut reference = InterleavedPlanes::zeroed(2, 0, 0);
                    let (mut af, mut qa) = (Vec::new(), Vec::new());
                    pack_a_reference(&x, &g, sa, hi_a, bits, &mut af, &mut qa, &mut reference);
                    for kind in simd::available() {
                        for threads in [1usize, 2, 64] {
                            let mut fused = InterleavedPlanes::zeroed(2, 0, 0);
                            pack_a_fused_with(kind, &x, &g, sa, hi_a, bits, threads, &mut fused);
                            assert_eq!(
                                fused, reference,
                                "k={k} s={stride} cin={cin} bits={bits} \
                                 scales={} kind={kind} threads={threads}",
                                sa.len()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn executor_threads_do_not_change_logits() {
        // The prologue thread count is a pure speed knob: any value must
        // produce bit-identical logits (disjoint span writes of identical
        // values), including 0 = auto.
        let wm = 0.125;
        let weights = synthetic_weights(wm, 41);
        let mut rng = Prng::new(42);
        let imgs = rand_images(&mut rng, 2);
        let sim = GavinaBackend {
            arch: ArchConfig::tiny(),
            tables: None,
            seed: 43,
        };
        let mut ex = Executor::new(&weights, wm, Precision::new(4, 4), &sim);
        let serial = ex.forward(&imgs, 2);
        for threads in [2usize, 3, 0] {
            ex.threads = threads;
            let par = ex.forward(&imgs, 2);
            assert_eq!(serial.logits, par.logits, "threads={threads}");
            assert_eq!(serial.stats, par.stats, "threads={threads}");
        }
    }

    #[test]
    fn error_injection_perturbs_logits() {
        use crate::errmodel::{ErrorTables, ModelParams};
        let wm = 0.125;
        let weights = synthetic_weights(wm, 4);
        let mut rng = Prng::new(5);
        let imgs = rand_images(&mut rng, 1);
        let prec = Precision::new(4, 4);
        let arch = ArchConfig::tiny();

        let params = ModelParams::paper(arch.c_dim);
        let mut tables = ErrorTables::zeroed(params);
        for bit in 0..params.s_bits {
            for e in 0..=params.c_dim as u16 {
                for pb in 0..params.p_bins {
                    for cd in 0..params.n_cond(bit) {
                        tables.set_prob(bit, e, pb, cd, 0.05);
                    }
                }
            }
        }

        let exact = Executor::new(&weights, wm, prec, &FloatBackend).forward(&imgs, 1);
        let sim = GavinaBackend {
            arch,
            tables: Some(Arc::new(tables)),
            seed: 6,
        };
        let uv = Executor::new(&weights, wm, prec, &sim)
            .with_uniform_g(0)
            .forward(&imgs, 1);
        assert!(uv.stats.corrupted > 0);
        let mse = crate::stats::mse_f32(&exact.logits, &uv.logits);
        assert!(mse > 0.0, "undervolting must perturb logits");
    }

    #[test]
    fn per_layer_g_only_affects_that_layer() {
        use crate::errmodel::{ErrorTables, ModelParams};
        let wm = 0.125;
        let weights = synthetic_weights(wm, 7);
        let mut rng = Prng::new(8);
        let imgs = rand_images(&mut rng, 1);
        let prec = Precision::new(2, 2);
        let arch = ArchConfig::tiny();
        let params = ModelParams::paper(arch.c_dim);
        let mut tables = ErrorTables::zeroed(params);
        // Only the MSB flips, always: big perturbation when undervolted.
        let msb = params.s_bits - 1;
        for e in 0..=params.c_dim as u16 {
            for pb in 0..params.p_bins {
                tables.set_prob(msb, e, pb, 0, 1.0);
            }
        }
        let sim = GavinaBackend {
            arch,
            tables: Some(Arc::new(tables)),
            seed: 9,
        };
        let mk = |gs: Vec<u32>| {
            Executor::new(&weights, wm, prec, &sim).with_layer_gs(gs).forward(&imgs, 1)
        };
        let all_guard = mk(vec![prec.max_g(); 20]);
        assert_eq!(all_guard.stats.corrupted, 0);
        assert_eq!(all_guard.stats.steps_approx, 0);
        assert!(all_guard.stats.steps_guarded > 0);
        let mut gs = vec![prec.max_g(); 20];
        gs[5] = 0;
        let one_uv = mk(gs);
        assert!(one_uv.stats.corrupted > 0);
        // The per-layer error counters localize the injections to the one
        // undervolted layer — the canary estimator's per-layer signal.
        assert!(one_uv.stats.layer_corrupted[5] > 0);
        assert!(one_uv.stats.layer_steps[5] > 0);
        let rates = one_uv.stats.layer_step_error_rates();
        assert!(rates[5] > 0.0);
        for (i, r) in rates.iter().enumerate() {
            if i != 5 {
                assert_eq!(*r, 0.0, "layer {i} is guarded, must observe no errors");
            }
        }
    }

    #[test]
    fn ensure_layer_keeps_tables_in_lockstep() {
        let mut s = ForwardStats::default();
        s.record_layer(4, 7, (1, 2, 3));
        assert_eq!(s.layer_macs.len(), 5);
        assert_eq!(s.layer_dims.len(), 5);
        assert_eq!(s.layer_macs[4], 7);
        assert_eq!(s.layer_dims[4], (1, 2, 3));
        // Absorb adopts the first non-empty geometry only.
        let mut t = ForwardStats::default();
        t.absorb(&s);
        assert_eq!(t.layer_macs, s.layer_macs);
        assert_eq!(t.layer_dims, s.layer_dims);
        let mut u = ForwardStats::default();
        u.record_layer(0, 99, (9, 9, 9));
        u.absorb(&s);
        assert_eq!(u.layer_macs, vec![99]);
    }

    #[test]
    fn absorb_sums_per_layer_error_counters() {
        // Geometry is adopt-first (representative shape), but injection
        // counters are true totals: chunked parallel batches must sum.
        let mut a = ForwardStats::default();
        a.record_layer_errors(2, 3, 10);
        let mut b = ForwardStats::default();
        b.record_layer_errors(2, 5, 20);
        b.record_layer_errors(4, 1, 8);
        a.absorb(&b);
        assert_eq!(a.layer_corrupted, vec![0, 0, 8, 0, 1]);
        assert_eq!(a.layer_steps, vec![0, 0, 30, 0, 8]);
        let rates = a.layer_step_error_rates();
        assert!((rates[2] - 8.0 / 30.0).abs() < 1e-12);
        assert_eq!(rates[0], 0.0);
    }
}
