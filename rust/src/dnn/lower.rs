//! Conv → GEMM lowering (im2col), mapping every convolution onto the
//! GAVINA GEMM shape of Listing 1: activations `A[C, L]`, weights
//! `B[K, C]`, product `P[K, L]` with
//!
//! * `C = kh·kw·cin` — the reduction axis (the paper sizes the array with
//!   `C` a multiple of 9 exactly because of 3×3 kernels, §IV-A),
//! * `L = n·oh·ow` — output pixels,
//! * `K = cout`.
//!
//! Padding follows jax/TF `SAME` semantics (`lo = total/2`, extra on the
//! high side) so the Rust executor reproduces the Python QAT graph
//! bit-for-bit after quantization.

use super::tensor::Tensor;

/// SAME-padding geometry for one spatial axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SamePad {
    pub out: usize,
    pub lo: usize,
}

/// TF/jax `SAME`: `out = ceil(in / stride)`,
/// `total = max((out-1)·stride + k − in, 0)`, `lo = total / 2`.
pub fn same_pad(input: usize, k: usize, stride: usize) -> SamePad {
    let out = input.div_ceil(stride);
    let total = ((out - 1) * stride + k).saturating_sub(input);
    SamePad { out, lo: total / 2 }
}

/// Geometry of one lowered conv.
#[derive(Clone, Copy, Debug)]
pub struct ConvGeom {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    pub kh: usize,
    pub kw: usize,
    pub cout: usize,
    pub stride: usize,
    pub oh: usize,
    pub ow: usize,
    pub pad_h: usize,
    pub pad_w: usize,
}

impl ConvGeom {
    pub fn new(x: &Tensor, wdims: &[usize], stride: usize) -> Self {
        let g = Self::from_dims(x.dims[0], x.dims[1], x.dims[2], wdims, stride);
        assert_eq!(x.dims[3], g.cin, "channel mismatch");
        g
    }

    /// Geometry from raw dimensions (no input tensor yet) — used by the
    /// build-time lowering, where only the batch axis is unknown until
    /// request time.
    pub fn from_dims(n: usize, h: usize, w: usize, wdims: &[usize], stride: usize) -> Self {
        let (kh, kw, cin, cout) = (wdims[0], wdims[1], wdims[2], wdims[3]);
        let ph = same_pad(h, kh, stride);
        let pw = same_pad(w, kw, stride);
        Self {
            n,
            h,
            w,
            cin,
            kh,
            kw,
            cout,
            stride,
            oh: ph.out,
            ow: pw.out,
            pad_h: ph.lo,
            pad_w: pw.lo,
        }
    }

    /// GEMM reduction dimension `C`.
    pub fn c_dim(&self) -> usize {
        self.kh * self.kw * self.cin
    }

    /// GEMM column dimension `L`.
    pub fn l_dim(&self) -> usize {
        self.n * self.oh * self.ow
    }

    /// GEMM row dimension `K`.
    pub fn k_dim(&self) -> usize {
        self.cout
    }

    /// Useful MACs of this conv.
    pub fn macs(&self) -> u64 {
        (self.c_dim() * self.l_dim() * self.k_dim()) as u64
    }

    /// Decompose a GEMM column index `l = (n·oh + ohi)·ow + owi` into
    /// `(n, ohi, owi)` — the inverse of the [`im2col`] column map.
    #[inline]
    pub fn col_coords(&self, l: usize) -> (usize, usize, usize) {
        let ohw = self.oh * self.ow;
        (l / ohw, (l % ohw) / self.ow, l % self.ow)
    }

    /// 1×1 kernel with no padding: every im2col column is one contiguous
    /// `cin`-length slice of the NHWC input (a strided view — nothing to
    /// gather). SAME padding of a 1×1 kernel is always 0, so this covers
    /// all pointwise convs and the fc head at any stride.
    #[inline]
    pub fn is_pointwise(&self) -> bool {
        self.kh == 1 && self.kw == 1 && self.pad_h == 0 && self.pad_w == 0
    }
}

/// One maximal contiguous piece of an im2col column, as streamed by
/// [`visit_col_runs`]: either a run of input values that are consecutive
/// in NHWC memory, or a run of zero-padding taps.
pub enum ColRun<'a> {
    Data(&'a [f32]),
    Zeros(usize),
}

/// Stream im2col column `l` as contiguous runs, in C order (`c =
/// (khi·kw + kwi)·cin + ci`), without materializing anything: each
/// in-bounds `(khi, kwi)` tap of the patch is one `cin`-length contiguous
/// NHWC slice, each out-of-bounds tap is `Zeros(cin)` (whole padded rows
/// collapse to `Zeros(kw·cin)`), and a pointwise geometry is a single
/// `cin`-length run. Concatenating the runs reproduces column `l` of
/// [`im2col`] exactly (property-tested below) — this is the traversal the
/// fused streaming prologue (`dnn::exec::pack_a_fused`) quantizes and
/// packs per-column instead of building the `A[C, L]` matrix.
pub fn visit_col_runs(x: &Tensor, g: &ConvGeom, l: usize, mut f: impl FnMut(ColRun<'_>)) {
    let (ni, ohi, owi) = g.col_coords(l);
    if g.is_pointwise() {
        let base = ((ni * g.h + ohi * g.stride) * g.w + owi * g.stride) * g.cin;
        f(ColRun::Data(&x.data[base..base + g.cin]));
        return;
    }
    for khi in 0..g.kh {
        let hi = (ohi * g.stride + khi) as isize - g.pad_h as isize;
        if hi < 0 || hi >= g.h as isize {
            f(ColRun::Zeros(g.kw * g.cin));
            continue;
        }
        for kwi in 0..g.kw {
            let wi = (owi * g.stride + kwi) as isize - g.pad_w as isize;
            if wi < 0 || wi >= g.w as isize {
                f(ColRun::Zeros(g.cin));
                continue;
            }
            let base = ((ni * g.h + hi as usize) * g.w + wi as usize) * g.cin;
            f(ColRun::Data(&x.data[base..base + g.cin]));
        }
    }
}

/// im2col: build the `A[C, L]` patch matrix (row-major `a[c·L + l]`) from
/// an NHWC input. Out-of-bounds taps read 0 (zero padding).
pub fn im2col(x: &Tensor, g: &ConvGeom) -> Vec<f32> {
    let mut a = Vec::new();
    im2col_into(x, g, &mut a);
    a
}

/// [`im2col`] into a caller-owned buffer (the executor's scratch arena):
/// cleared, zero-filled to `C·L` and written in place, so steady-state
/// inference re-uses one allocation per executor instead of one per
/// layer per request.
pub fn im2col_into(x: &Tensor, g: &ConvGeom, a: &mut Vec<f32>) {
    let (c_dim, l_dim) = (g.c_dim(), g.l_dim());
    a.clear();
    a.resize(c_dim * l_dim, 0.0);
    for ni in 0..g.n {
        for ohi in 0..g.oh {
            for owi in 0..g.ow {
                let l = (ni * g.oh + ohi) * g.ow + owi;
                for khi in 0..g.kh {
                    let hi = (ohi * g.stride + khi) as isize - g.pad_h as isize;
                    if hi < 0 || hi >= g.h as isize {
                        continue;
                    }
                    for kwi in 0..g.kw {
                        let wi = (owi * g.stride + kwi) as isize - g.pad_w as isize;
                        if wi < 0 || wi >= g.w as isize {
                            continue;
                        }
                        let xbase = ((ni * g.h + hi as usize) * g.w + wi as usize) * g.cin;
                        let cbase = (khi * g.kw + kwi) * g.cin;
                        for ci in 0..g.cin {
                            a[(cbase + ci) * l_dim + l] = x.data[xbase + ci];
                        }
                    }
                }
            }
        }
    }
}

/// Reshape HWIO conv weights into the `B[K, C]` GEMM operand (row-major
/// `b[k·C + c]`, `c = (kh·kw + kw)·cin + ci` matching [`im2col`]).
pub fn weights_to_b(wdims: &[usize], wdata: &[f32]) -> Vec<f32> {
    let (kh, kw, cin, cout) = (wdims[0], wdims[1], wdims[2], wdims[3]);
    let c_dim = kh * kw * cin;
    let mut b = vec![0.0f32; cout * c_dim];
    for khi in 0..kh {
        for kwi in 0..kw {
            for ci in 0..cin {
                let c = (khi * kw + kwi) * cin + ci;
                for k in 0..cout {
                    b[k * c_dim + c] = wdata[((khi * kw + kwi) * cin + ci) * cout + k];
                }
            }
        }
    }
    b
}

/// Fold a `P[K, L]` GEMM result back into an NHWC output tensor.
pub fn col2im(p: &[f32], g: &ConvGeom) -> Tensor {
    let l_dim = g.l_dim();
    assert_eq!(p.len(), g.k_dim() * l_dim);
    let mut out = Tensor::zeros(vec![g.n, g.oh, g.ow, g.cout]);
    for k in 0..g.cout {
        for l in 0..l_dim {
            // l = (n·oh + ohi)·ow + owi ; NHWC index = l·cout + k.
            out.data[l * g.cout + k] = p[k * l_dim + l];
        }
    }
    out
}

/// Direct f32 convolution (reference for the lowering tests).
pub fn conv2d_ref(x: &Tensor, wdims: &[usize], wdata: &[f32], stride: usize) -> Tensor {
    let g = ConvGeom::new(x, wdims, stride);
    let mut out = Tensor::zeros(vec![g.n, g.oh, g.ow, g.cout]);
    for ni in 0..g.n {
        for ohi in 0..g.oh {
            for owi in 0..g.ow {
                for k in 0..g.cout {
                    let mut acc = 0.0f32;
                    for khi in 0..g.kh {
                        let hi = (ohi * g.stride + khi) as isize - g.pad_h as isize;
                        if hi < 0 || hi >= g.h as isize {
                            continue;
                        }
                        for kwi in 0..g.kw {
                            let wi = (owi * g.stride + kwi) as isize - g.pad_w as isize;
                            if wi < 0 || wi >= g.w as isize {
                                continue;
                            }
                            for ci in 0..g.cin {
                                acc += x.at4(ni, hi as usize, wi as usize, ci)
                                    * wdata[((khi * g.kw + kwi) * g.cin + ci) * g.cout + k];
                            }
                        }
                    }
                    out.data[((ni * g.oh + ohi) * g.ow + owi) * g.cout + k] = acc;
                }
            }
        }
    }
    out
}

/// f32 GEMM `P[K,L] = B[K,C]·A[C,L]` (the float backend's inner product).
pub fn gemm_f32(a: &[f32], b: &[f32], c_dim: usize, l_dim: usize, k_dim: usize) -> Vec<f32> {
    let mut p = vec![0.0f32; k_dim * l_dim];
    for k in 0..k_dim {
        for c in 0..c_dim {
            let bv = b[k * c_dim + c];
            if bv == 0.0 {
                continue;
            }
            let arow = &a[c * l_dim..(c + 1) * l_dim];
            let prow = &mut p[k * l_dim..(k + 1) * l_dim];
            for l in 0..l_dim {
                prow[l] += bv * arow[l];
            }
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::Prng;

    #[test]
    fn same_pad_matches_tf_rules() {
        // 32x32, k3 s1 -> 32 out, pad 1|1 (lo=1).
        assert_eq!(same_pad(32, 3, 1), SamePad { out: 32, lo: 1 });
        // 32x32, k3 s2 -> 16 out, total 1, lo 0 (extra on high side).
        assert_eq!(same_pad(32, 3, 2), SamePad { out: 16, lo: 0 });
        // 1x1 s1: no padding.
        assert_eq!(same_pad(16, 1, 1), SamePad { out: 16, lo: 0 });
        // 1x1 s2 on 16 -> 8 out, total 0.
        assert_eq!(same_pad(16, 1, 2), SamePad { out: 8, lo: 0 });
    }

    #[test]
    fn im2col_gemm_equals_direct_conv() {
        check("im2col+GEMM == conv2d", 20, |rng| {
            let n = rng.int_in(1, 2) as usize;
            let h = rng.int_in(4, 10) as usize;
            let w = rng.int_in(4, 10) as usize;
            let cin = rng.int_in(1, 5) as usize;
            let cout = rng.int_in(1, 6) as usize;
            let k = *[1usize, 3].get(rng.index(2)).unwrap();
            let stride = rng.int_in(1, 2) as usize;
            let x = Tensor::new(
                vec![n, h, w, cin],
                (0..n * h * w * cin)
                    .map(|_| rng.next_f32() * 2.0 - 1.0)
                    .collect(),
            );
            let wdims = vec![k, k, cin, cout];
            let wdata: Vec<f32> = (0..k * k * cin * cout)
                .map(|_| rng.next_f32() * 2.0 - 1.0)
                .collect();

            let direct = conv2d_ref(&x, &wdims, &wdata, stride);

            let g = ConvGeom::new(&x, &wdims, stride);
            let a = im2col(&x, &g);
            let b = weights_to_b(&wdims, &wdata);
            let p = gemm_f32(&a, &b, g.c_dim(), g.l_dim(), g.k_dim());
            let folded = col2im(&p, &g);

            assert_eq!(folded.dims, direct.dims);
            for (i, (x1, x2)) in folded.data.iter().zip(&direct.data).enumerate() {
                assert!(
                    (x1 - x2).abs() < 1e-4,
                    "mismatch at {i}: {x1} vs {x2} (k={k} s={stride})"
                );
            }
        });
    }

    #[test]
    fn col_runs_concatenate_to_im2col_columns() {
        check("visit_col_runs == im2col column", 20, |rng| {
            let n = rng.int_in(1, 2) as usize;
            let h = rng.int_in(3, 9) as usize;
            let w = rng.int_in(3, 9) as usize;
            let cin = rng.int_in(1, 6) as usize;
            let k = *[1usize, 3].get(rng.index(2)).unwrap();
            let stride = rng.int_in(1, 2) as usize;
            let x = Tensor::new(
                vec![n, h, w, cin],
                (0..n * h * w * cin)
                    .map(|_| rng.next_f32() * 2.0 - 1.0)
                    .collect(),
            );
            let g = ConvGeom::new(&x, &[k, k, cin, 4], stride);
            let a = im2col(&x, &g);
            let (c_dim, l_dim) = (g.c_dim(), g.l_dim());
            for l in 0..l_dim {
                let mut col = Vec::with_capacity(c_dim);
                visit_col_runs(&x, &g, l, |r| match r {
                    ColRun::Data(run) => col.extend_from_slice(run),
                    ColRun::Zeros(z) => col.extend(std::iter::repeat(0.0f32).take(z)),
                });
                assert_eq!(col.len(), c_dim, "k={k} s={stride} l={l}");
                for (c, &v) in col.iter().enumerate() {
                    assert_eq!(
                        v.to_bits(),
                        a[c * l_dim + l].to_bits(),
                        "k={k} s={stride} l={l} c={c}"
                    );
                }
            }
        });
    }

    #[test]
    fn pointwise_predicate_and_coords() {
        // 1x1 at any stride has zero SAME padding -> pointwise fast path.
        for stride in [1usize, 2] {
            let g = ConvGeom::from_dims(2, 8, 6, &[1, 1, 3, 4], stride);
            assert!(g.is_pointwise(), "stride={stride}");
        }
        let g3 = ConvGeom::from_dims(1, 8, 8, &[3, 3, 3, 4], 1);
        assert!(!g3.is_pointwise());
        let g = ConvGeom::from_dims(2, 8, 6, &[3, 3, 3, 4], 2);
        for l in 0..g.l_dim() {
            let (ni, ohi, owi) = g.col_coords(l);
            assert_eq!((ni * g.oh + ohi) * g.ow + owi, l);
            assert!(ni < g.n && ohi < g.oh && owi < g.ow);
        }
    }

    #[test]
    fn resnet_inner_layer_c_is_multiple_of_9() {
        // The §IV-A design motivation: 3x3 kernels make C divisible by 9.
        let x = Tensor::zeros(vec![1, 8, 8, 64]);
        let g = ConvGeom::new(&x, &[3, 3, 64, 64], 1);
        assert_eq!(g.c_dim(), 576); // exactly the paper's array C!
        assert_eq!(g.c_dim() % 9, 0);
    }

    #[test]
    fn geom_macs() {
        let x = Tensor::zeros(vec![2, 4, 4, 3]);
        let g = ConvGeom::new(&x, &[3, 3, 3, 8], 1);
        assert_eq!(g.macs(), (27 * 2 * 16 * 8) as u64);
    }
}
