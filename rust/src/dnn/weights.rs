//! GVNT tensor-container reader — the Rust side of
//! `python/compile/tensorio.py`. Loads the QAT-trained ResNet weights and
//! the exported evaluation dataset from `artifacts/`.
//!
//! Layout (little-endian):
//! ```text
//! magic b"GVNT" | version u32 (=1) | count u32
//! count × [ name_len u32 | name utf8 | dtype u8 | ndim u32 | dims u32×ndim
//!           | raw data ]
//! dtype: 0 = f32, 1 = i32, 2 = u8.
//! ```

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

/// A loaded tensor of any supported dtype.
#[derive(Clone, Debug)]
pub enum AnyTensor {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
    U8(Vec<usize>, Vec<u8>),
}

impl AnyTensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            AnyTensor::F32(d, _) | AnyTensor::I32(d, _) | AnyTensor::U8(d, _) => d,
        }
    }

    pub fn as_f32(&self) -> Option<(&[usize], &[f32])> {
        match self {
            AnyTensor::F32(d, v) => Some((d, v)),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<(&[usize], &[i32])> {
        match self {
            AnyTensor::I32(d, v) => Some((d, v)),
            _ => None,
        }
    }

    pub fn as_u8(&self) -> Option<(&[usize], &[u8])> {
        match self {
            AnyTensor::U8(d, v) => Some((d, v)),
            _ => None,
        }
    }
}

/// Ordered name → tensor map.
pub type TensorMap = BTreeMap<String, AnyTensor>;

fn bad(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Load a GVNT container.
pub fn load_tensors(path: &Path) -> std::io::Result<TensorMap> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != b"GVNT" {
        return Err(bad(format!("bad magic in {}", path.display())));
    }
    let mut b4 = [0u8; 4];
    let mut read_u32 = |f: &mut dyn Read| -> std::io::Result<u32> {
        f.read_exact(&mut b4)?;
        Ok(u32::from_le_bytes(b4))
    };
    let version = read_u32(&mut f)?;
    if version != 1 {
        return Err(bad(format!("unsupported GVNT version {version}")));
    }
    let count = read_u32(&mut f)?;
    let mut out = TensorMap::new();
    for _ in 0..count {
        let nlen = read_u32(&mut f)? as usize;
        let mut name = vec![0u8; nlen];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).map_err(|e| bad(e.to_string()))?;
        let mut b1 = [0u8; 1];
        f.read_exact(&mut b1)?;
        let dtype = b1[0];
        let ndim = read_u32(&mut f)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut f)? as usize);
        }
        let n: usize = dims.iter().product::<usize>().max(1);
        let t = match dtype {
            0 => {
                let mut buf = vec![0u8; n * 4];
                f.read_exact(&mut buf)?;
                AnyTensor::F32(
                    dims,
                    buf.chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            1 => {
                let mut buf = vec![0u8; n * 4];
                f.read_exact(&mut buf)?;
                AnyTensor::I32(
                    dims,
                    buf.chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            2 => {
                let mut buf = vec![0u8; n];
                f.read_exact(&mut buf)?;
                AnyTensor::U8(dims, buf)
            }
            d => return Err(bad(format!("unknown dtype code {d} for '{name}'"))),
        };
        out.insert(name, t);
    }
    Ok(out)
}

/// The evaluation dataset exported by `compile.train`.
pub struct EvalSet {
    /// `[N, 32, 32, 3]` images in `[0, 1]`.
    pub images: Vec<f32>,
    pub n: usize,
    pub labels: Vec<i32>,
}

/// Load `artifacts/dataset_eval.bin`.
pub fn load_eval_set(path: &Path) -> std::io::Result<EvalSet> {
    let m = load_tensors(path)?;
    let (idims, img) = m
        .get("images")
        .and_then(AnyTensor::as_u8)
        .ok_or_else(|| bad("missing u8 'images'".into()))?;
    let (_, labels) = m
        .get("labels")
        .and_then(AnyTensor::as_i32)
        .ok_or_else(|| bad("missing i32 'labels'".into()))?;
    if idims.len() != 4 || idims[1] != 32 || idims[2] != 32 || idims[3] != 3 {
        return Err(bad(format!("unexpected image dims {idims:?}")));
    }
    Ok(EvalSet {
        images: img.iter().map(|&b| b as f32 / 255.0).collect(),
        n: idims[0],
        labels: labels.to_vec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_container(path: &Path) {
        // Hand-roll a tiny GVNT file: one f32 [2,2], one i32 [3], one u8 [2].
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(b"GVNT").unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&3u32.to_le_bytes()).unwrap();
        let mut tensor = |name: &str, dtype: u8, dims: &[u32], raw: &[u8]| {
            f.write_all(&(name.len() as u32).to_le_bytes()).unwrap();
            f.write_all(name.as_bytes()).unwrap();
            f.write_all(&[dtype]).unwrap();
            f.write_all(&(dims.len() as u32).to_le_bytes()).unwrap();
            for d in dims {
                f.write_all(&d.to_le_bytes()).unwrap();
            }
            f.write_all(raw).unwrap();
        };
        let fdata: Vec<u8> = [1.0f32, 2.0, -3.0, 0.5]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        tensor("w", 0, &[2, 2], &fdata);
        let idata: Vec<u8> = [7i32, -1, 0].iter().flat_map(|v| v.to_le_bytes()).collect();
        tensor("labels", 1, &[3], &idata);
        tensor("bytes", 2, &[2], &[200u8, 5]);
    }

    #[test]
    fn roundtrip_handwritten_container() {
        let dir = std::env::temp_dir().join("gavina_gvnt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        write_container(&path);
        let m = load_tensors(&path).unwrap();
        let (d, v) = m["w"].as_f32().unwrap();
        assert_eq!(d, &[2, 2]);
        assert_eq!(v, &[1.0, 2.0, -3.0, 0.5]);
        let (_, l) = m["labels"].as_i32().unwrap();
        assert_eq!(l, &[7, -1, 0]);
        let (_, b) = m["bytes"].as_u8().unwrap();
        assert_eq!(b, &[200, 5]);
    }

    #[test]
    fn reads_python_written_artifacts_if_present() {
        // Integration hook: when `make artifacts` has run, verify the real
        // weight container parses and has the expected key structure.
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/weights_a4w4.bin");
        if !path.exists() {
            return; // artifacts not built in this environment
        }
        let m = load_tensors(&path).unwrap();
        assert!(m.contains_key("conv0/w"));
        assert!(m.contains_key("fc/w"));
        let (d, _) = m["conv0/w"].as_f32().unwrap();
        assert_eq!(d.len(), 4);
        assert_eq!(d[0], 3); // 3x3 kernel
    }
}
