//! DNN substrate: tensors, GVNT weight loading, conv→GEMM lowering and
//! the quantized ResNet-18 executor that maps every convolution onto the
//! GAVINA accelerator (paper §IV-D).

pub mod exec;
pub mod lower;
pub mod plan;
pub mod tensor;
pub mod weights;

pub use exec::{conv_layer_names, Executor, ForwardResult, ForwardStats, IMAGE_LEN};
pub use plan::{BnFold, LayerPlan, PlannedModel, MAX_REDUCTION_DIM};
pub use tensor::Tensor;
pub use weights::{load_eval_set, load_tensors, EvalSet, TensorMap};
