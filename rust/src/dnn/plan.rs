//! Compile-once lowering of the quantized network into per-layer
//! [`LayerPlan`]s — the software analogue of flashing GAVINA's static
//! weight bit-planes into the B0 memory.
//!
//! GAVINA's weights are static: the ASIC streams pre-packed weight
//! bit-planes from the B0 memory every cycle, and nothing about them
//! changes between inferences. The old software data plane nevertheless
//! re-quantized, re-scaled and re-bit-plane-packed the same f32 weights
//! inside the executor on **every** `infer()` call, and re-derived the
//! BN constants per layer per request. [`PlannedModel::lower`] moves all
//! of that to build time:
//!
//! * per-output-channel weight quantization + [`PackedPlanes`] packing
//!   (the B-side of every conv GEMM),
//! * BN folded into a per-channel affine ([`BnFold`]) with the
//!   `1/sqrt(var + eps)` term resolved once,
//! * the conv→GEMM geometry ([`ConvGeom`]) of every layer,
//! * the resolved [`GavSchedule`] for the layer's G.
//!
//! Request time then only pays for activation work: im2col, activation
//! quantization, packing the A-side planes once per layer, and the
//! backend GEMM. The arithmetic is kept **bit-identical** to the old
//! per-request path (same quantization expressions, same f32 operation
//! order for dequant + BN) — `tests/engine_parity.rs` pins it.

use std::sync::Arc;

use super::exec::{conv_layer_names, BLOCKS_PER_STAGE, STAGES};
use super::lower::{weights_to_b, ConvGeom};
use super::weights::{AnyTensor, TensorMap};
use crate::arch::{GavSchedule, Precision};
use crate::quant::{InterleavedPlanes, PackedPlanes};

/// Hard ceiling on the reduction axis `C = k·k·cin` of one lowered GEMM:
/// one iPE output is a popcount over C, carried in `u16` step buffers by
/// the reference kernel and the cycle simulator — a larger C would
/// silently truncate into wrong logits. `EngineBuilder::build()` rejects
/// oversized reductions with a typed error; the kernels debug-assert it.
pub const MAX_REDUCTION_DIM: usize = u16::MAX as usize;

/// Batch-norm constants folded to a per-channel affine at build time.
///
/// Application order is exactly the legacy `Executor::bn` pass —
/// `(v - mean[c]) * mul[c] + bias[c]` with `mul = scale / sqrt(var + 1e-5)`
/// — so folded execution is bit-identical to the old separate BN pass
/// (property-tested below).
#[derive(Clone, Debug, PartialEq)]
pub struct BnFold {
    /// `scale / sqrt(var + 1e-5)`, per channel (the expensive part,
    /// resolved once).
    pub mul: Vec<f32>,
    /// Running mean, per channel.
    pub mean: Vec<f32>,
    /// Learned shift, per channel.
    pub bias: Vec<f32>,
}

impl BnFold {
    /// Fold raw BN tensors. All four slices must have equal length.
    pub fn fold(scale: &[f32], bias: &[f32], mean: &[f32], var: &[f32]) -> Self {
        assert_eq!(scale.len(), bias.len());
        assert_eq!(scale.len(), mean.len());
        assert_eq!(scale.len(), var.len());
        let mul: Vec<f32> = scale
            .iter()
            .zip(var)
            .map(|(&s, &v)| s / (v + 1e-5).sqrt())
            .collect();
        Self {
            mul,
            mean: mean.to_vec(),
            bias: bias.to_vec(),
        }
    }

    /// The no-op fold (GEMM-only plans).
    pub fn identity(channels: usize) -> Self {
        Self {
            mul: vec![1.0; channels],
            mean: vec![0.0; channels],
            bias: vec![0.0; channels],
        }
    }

    /// Apply the folded affine to one value of channel `c` — the same
    /// f32 expression, in the same order, as the legacy separate pass.
    #[inline]
    pub fn apply(&self, c: usize, v: f32) -> f32 {
        (v - self.mean[c]) * self.mul[c] + self.bias[c]
    }
}

/// The immutable build-time artifacts of one conv layer, shared (behind
/// an `Arc`) by every re-scheduled [`LayerPlan`] so policy changes never
/// re-pack weights.
#[derive(Clone, Debug)]
struct LayerData {
    /// Layer name in execution order (`conv0`, `s2b1/conv1`, …).
    name: String,
    /// Conv→GEMM geometry at batch size 1; [`LayerPlan::geom`] rescales
    /// the batch-dependent `n`/`L` axis per request.
    geom1: ConvGeom,
    /// Quantized weights `B[K, C]` packed as bit-planes — the B0 image
    /// (the step-sequence form the simulator carves tiles from).
    packed_b: PackedPlanes,
    /// The same planes re-laid plane-interleaved for the fused exact
    /// kernel ([`crate::gemm::kernel`]) — built once here so the exact
    /// path never converts at request time.
    inter_b: InterleavedPlanes,
    /// Per-output-channel weight quantization scales.
    wscales: Vec<f32>,
    /// Folded BN constants.
    bn: BnFold,
}

/// The compiled form of one conv/linear layer: pre-packed weight
/// bit-planes, per-channel scales, folded BN, geometry, and the resolved
/// voltage schedule. Produced by [`PlannedModel::lower`] at
/// `EngineBuilder::build()` time; consumed by every
/// [`ExecBackend`](crate::engine::ExecBackend) via
/// [`LayerGemm`](crate::engine::backend::LayerGemm).
#[derive(Clone, Debug)]
pub struct LayerPlan {
    layer_idx: usize,
    sched: GavSchedule,
    data: Arc<LayerData>,
}

impl LayerPlan {
    /// A GEMM-only plan over an already-quantized `B[K, C]` matrix, with
    /// degenerate 1×1 geometry, unit weight scales and identity BN — for
    /// backend-level tests and benches that have no conv around their
    /// GEMM.
    pub fn for_gemm(
        b: &[i32],
        k_dim: usize,
        c_dim: usize,
        sched: GavSchedule,
        layer_idx: usize,
    ) -> Self {
        let packed_b = PackedPlanes::from_b_matrix(b, k_dim, c_dim, sched.precision().b_bits);
        let inter_b = InterleavedPlanes::from_packed(&packed_b);
        let geom1 = ConvGeom::from_dims(1, 1, 1, &[1, 1, c_dim, k_dim], 1);
        Self {
            layer_idx,
            sched,
            data: Arc::new(LayerData {
                name: "gemm".into(),
                geom1,
                packed_b,
                inter_b,
                wscales: vec![1.0; k_dim],
                bn: BnFold::identity(k_dim),
            }),
        }
    }

    /// The same plan re-resolved at a different G (weight data shared,
    /// nothing re-packed).
    pub fn with_g(&self, g: u32) -> Self {
        Self {
            layer_idx: self.layer_idx,
            sched: GavSchedule::two_level(self.sched.precision(), g),
            data: Arc::clone(&self.data),
        }
    }

    /// Index of this layer in execution order (seeds the backend's
    /// per-layer RNG stream).
    pub fn layer_idx(&self) -> usize {
        self.layer_idx
    }

    /// The resolved GAV voltage schedule for this layer's G.
    pub fn sched(&self) -> &GavSchedule {
        &self.sched
    }

    /// Layer name in execution order.
    pub fn name(&self) -> &str {
        &self.data.name
    }

    /// The pre-packed weight bit-planes `B[K, C]` (plane-major — the
    /// simulator's tile-carving form).
    pub fn packed_b(&self) -> &PackedPlanes {
        &self.data.packed_b
    }

    /// The same weight planes in the plane-interleaved layout the fused
    /// exact kernel consumes (built once at lowering).
    pub fn interleaved_b(&self) -> &InterleavedPlanes {
        &self.data.inter_b
    }

    /// Per-output-channel weight quantization scales.
    pub fn wscales(&self) -> &[f32] {
        &self.data.wscales
    }

    /// The folded BN affine.
    pub fn bn(&self) -> &BnFold {
        &self.data.bn
    }

    /// Conv→GEMM geometry for a batch of `n` images (only the batch axis
    /// varies per request; everything else was fixed at lowering).
    pub fn geom(&self, n: usize) -> ConvGeom {
        ConvGeom {
            n,
            ..self.data.geom1
        }
    }
}

/// The float classifier head (GAP → fc), `Arc`-shared by every
/// re-scheduled copy of a model.
#[derive(Clone, Debug)]
pub(crate) struct FcHead {
    /// Classifier input width (`fc/w` is `[fc_in, classes]` row-major).
    pub(crate) fc_in: usize,
    pub(crate) classes: usize,
    pub(crate) w: Vec<f32>,
    pub(crate) b: Vec<f32>,
}

/// The fully lowered network: one [`LayerPlan`] per conv layer in
/// execution order plus the (float) classifier head. Built once by
/// `EngineBuilder::build()`; shared immutably by every request.
#[derive(Clone, Debug)]
pub struct PlannedModel {
    prec: Precision,
    width_mult: f64,
    plans: Vec<LayerPlan>,
    pub(crate) fc: Arc<FcHead>,
}

fn wf32<'m>(weights: &'m TensorMap, name: &str) -> (&'m [usize], &'m [f32]) {
    weights
        .get(name)
        .and_then(AnyTensor::as_f32)
        .unwrap_or_else(|| panic!("missing f32 weight '{name}'"))
}

/// Lower one conv layer: quantize the weights per output channel (the
/// exact arithmetic of the old per-request path), pack the bit-planes,
/// fold BN, and resolve the schedule.
#[allow(clippy::too_many_arguments)]
fn lower_layer(
    weights: &TensorMap,
    prec: Precision,
    g: u32,
    layer_idx: usize,
    conv: &str,
    bn_name: &str,
    h: usize,
    w: usize,
    stride: usize,
) -> LayerPlan {
    let (wdims, wdata) = wf32(weights, &format!("{conv}/w"));
    let geom1 = ConvGeom::from_dims(1, h, w, wdims, stride);
    let (c_dim, k_dim) = (geom1.c_dim(), geom1.k_dim());

    let hi_w = ((1i32 << (prec.b_bits - 1)) - 1) as f32;
    let b_f = weights_to_b(wdims, wdata);
    let mut sw = vec![0.0f32; k_dim];
    for (k, s) in sw.iter_mut().enumerate() {
        let amax = b_f[k * c_dim..(k + 1) * c_dim]
            .iter()
            .fold(0.0f32, |m, v| m.max(v.abs()))
            .max(1e-8);
        *s = amax / hi_w;
    }
    let qb: Vec<i32> = b_f
        .iter()
        .enumerate()
        .map(|(i, &v)| {
            let k = i / c_dim;
            ((v / sw[k]).round() as i32).clamp(-hi_w as i32, hi_w as i32)
        })
        .collect();
    // The engine builder pre-validates this with a typed error; lowering
    // re-asserts it so standalone `Executor::new` users cannot silently
    // truncate iPE popcounts either.
    assert!(
        c_dim <= MAX_REDUCTION_DIM,
        "{conv}: reduction axis {c_dim} exceeds the bit-serial data path's {MAX_REDUCTION_DIM}"
    );
    let packed_b = PackedPlanes::from_b_matrix(&qb, k_dim, c_dim, prec.b_bits);
    let inter_b = InterleavedPlanes::from_packed(&packed_b);

    let (_, scale) = wf32(weights, &format!("{bn_name}/scale"));
    let (_, bias) = wf32(weights, &format!("{bn_name}/bias"));
    let (_, mean) = wf32(weights, &format!("{bn_name}/mean"));
    let (_, var) = wf32(weights, &format!("{bn_name}/var"));
    assert_eq!(scale.len(), k_dim, "{bn_name} width vs {conv} cout");
    let bn = BnFold::fold(scale, bias, mean, var);

    LayerPlan {
        layer_idx,
        sched: GavSchedule::two_level(prec, g),
        data: Arc::new(LayerData {
            name: conv.to_string(),
            geom1,
            packed_b,
            inter_b,
            wscales: sw,
            bn,
        }),
    }
}

impl PlannedModel {
    /// Lower a weight map into the compiled data plane. `layer_gs[i]` is
    /// the GAV `G` of conv layer `i` in execution order (length must
    /// equal [`conv_layer_names`]`().len()`).
    ///
    /// Panics on a structurally invalid weight map — the engine builder
    /// validates the map before lowering, so library users go through
    /// `EngineBuilder::build()` and get a typed error instead.
    pub fn lower(weights: &TensorMap, width_mult: f64, prec: Precision, layer_gs: &[u32]) -> Self {
        let n_layers = conv_layer_names().len();
        assert_eq!(layer_gs.len(), n_layers, "layer_gs length vs conv layer count");
        let mut plans: Vec<LayerPlan> = Vec::with_capacity(n_layers);
        // Walk the topology tracking the activation shape, asserting the
        // channel chain on every layer (the legacy per-request path
        // asserted `cin == wcin` on every call, release builds included
        // — lowering must be at least as strict).
        let (mut h, mut w) = (32usize, 32usize);
        let mut cin = 3usize;
        let idx = plans.len();
        let p0 = lower_layer(weights, prec, layer_gs[idx], idx, "conv0", "bn0", h, w, 1);
        assert_eq!(p0.data.geom1.cin, cin, "conv0 input channel mismatch");
        (h, w) = (p0.data.geom1.oh, p0.data.geom1.ow);
        cin = p0.data.geom1.cout;
        plans.push(p0);
        for (si, (_, stride)) in STAGES.iter().enumerate() {
            for bi in 0..BLOCKS_PER_STAGE {
                let s = if bi == 0 { *stride } else { 1 };
                let p = format!("s{si}b{bi}");
                let idx = plans.len();
                let c1 = lower_layer(
                    weights,
                    prec,
                    layer_gs[idx],
                    idx,
                    &format!("{p}/conv1"),
                    &format!("{p}/bn1"),
                    h,
                    w,
                    s,
                );
                assert_eq!(c1.data.geom1.cin, cin, "{p}/conv1 input channel mismatch");
                let (h1, w1) = (c1.data.geom1.oh, c1.data.geom1.ow);
                let cout = c1.data.geom1.cout;
                plans.push(c1);
                let idx = plans.len();
                let c2 = lower_layer(
                    weights,
                    prec,
                    layer_gs[idx],
                    idx,
                    &format!("{p}/conv2"),
                    &format!("{p}/bn2"),
                    h1,
                    w1,
                    1,
                );
                assert_eq!(
                    (c2.data.geom1.cin, c2.data.geom1.cout),
                    (cout, cout),
                    "{p}/conv2 channel mismatch"
                );
                plans.push(c2);
                if weights.contains_key(&format!("{p}/down/w")) {
                    let idx = plans.len();
                    let down = lower_layer(
                        weights,
                        prec,
                        layer_gs[idx],
                        idx,
                        &format!("{p}/down"),
                        &format!("{p}/dbn"),
                        h,
                        w,
                        s,
                    );
                    assert_eq!(
                        (down.data.geom1.cin, down.data.geom1.cout),
                        (cin, cout),
                        "{p}/down channel mismatch"
                    );
                    plans.push(down);
                } else {
                    // Identity shortcut: the residual add requires the
                    // block to preserve shape.
                    assert_eq!((s, cin), (1, cout), "{p} identity shortcut shape mismatch");
                }
                (h, w) = (h1, w1);
                cin = cout;
            }
        }
        assert_eq!(plans.len(), n_layers, "lowering walk vs conv_layer_names");
        let (fdims, fw) = wf32(weights, "fc/w");
        let (_, fb) = wf32(weights, "fc/b");
        assert_eq!(fdims.len(), 2, "fc/w must be [cin, classes]");
        Self {
            prec,
            width_mult,
            plans,
            fc: Arc::new(FcHead {
                fc_in: fdims[0],
                classes: fdims[1],
                w: fw.to_vec(),
                b: fb.to_vec(),
            }),
        }
    }

    /// The same model re-resolved under a different per-layer G vector.
    /// Cheap: schedules are rebuilt, the packed weight planes and folded
    /// BN constants are shared via `Arc`.
    pub fn with_layer_gs(&self, layer_gs: &[u32]) -> Self {
        assert_eq!(layer_gs.len(), self.plans.len(), "layer_gs length");
        Self {
            prec: self.prec,
            width_mult: self.width_mult,
            plans: self
                .plans
                .iter()
                .zip(layer_gs)
                .map(|(p, &g)| p.with_g(g))
                .collect(),
            fc: Arc::clone(&self.fc),
        }
    }

    /// The per-layer plans in execution order.
    pub fn plans(&self) -> &[LayerPlan] {
        &self.plans
    }

    /// The `aXwY` precision the model was lowered at.
    pub fn prec(&self) -> Precision {
        self.prec
    }

    /// ResNet width multiplier the weights were trained at.
    pub fn width_mult(&self) -> f64 {
        self.width_mult
    }

    /// The resolved per-layer G vector (`None` entries never occur for
    /// models lowered through the two-level policy).
    pub fn layer_gs(&self) -> Vec<u32> {
        self.plans
            .iter()
            .map(|p| p.sched.g().expect("lowered plans use the two-level policy"))
            .collect()
    }

    /// Total bytes of pre-packed weight bit-planes (the B0 image size).
    pub fn packed_weight_bytes(&self) -> usize {
        self.plans.iter().map(|p| p.packed_b().nbytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::exec::synth::synthetic_weights;
    use crate::gemm::{bitserial_gemm, gemm_exact};
    use crate::util::proptest::check;

    #[test]
    fn lowering_walk_matches_layer_names() {
        let prec = Precision::new(2, 2);
        let weights = synthetic_weights(0.125, 1);
        let gs = vec![prec.max_g(); conv_layer_names().len()];
        let model = PlannedModel::lower(&weights, 0.125, prec, &gs);
        let names: Vec<&str> = model.plans().iter().map(|p| p.name()).collect();
        let expect = conv_layer_names();
        assert_eq!(names, expect.iter().map(String::as_str).collect::<Vec<_>>());
        assert_eq!(model.layer_gs(), gs);
        assert!(model.packed_weight_bytes() > 0);
        // Geometry: conv0 consumes 32×32×3; the batch axis rescales.
        let g1 = model.plans()[0].geom(1);
        assert_eq!((g1.h, g1.w, g1.cin, g1.n), (32, 32, 3, 1));
        let g4 = model.plans()[0].geom(4);
        assert_eq!(g4.l_dim(), 4 * g1.l_dim());
        assert_eq!(g4.c_dim(), g1.c_dim());
    }

    #[test]
    fn with_layer_gs_shares_packed_weights() {
        let prec = Precision::new(2, 2);
        let weights = synthetic_weights(0.125, 2);
        let gs = vec![prec.max_g(); conv_layer_names().len()];
        let model = PlannedModel::lower(&weights, 0.125, prec, &gs);
        let uv = model.with_layer_gs(&vec![0; gs.len()]);
        for (a, b) in model.plans().iter().zip(uv.plans()) {
            // Re-scheduling must not touch (or copy) the packed planes.
            assert!(Arc::ptr_eq(&a.data, &b.data));
            assert_eq!(b.sched().g(), Some(0));
        }
        // The classifier head is shared too — rescheduling allocates
        // nothing beyond the schedule vectors.
        assert!(Arc::ptr_eq(&model.fc, &uv.fc));
    }

    #[test]
    fn plan_weight_quantization_matches_legacy_per_request_path() {
        // The build-time quantization must produce exactly the integers
        // the old per-request `Executor::qconv` derived, for every layer.
        check("plan quant == legacy quant", 3, |rng| {
            let prec = Precision::new(rng.int_in(2, 8) as u8, rng.int_in(2, 8) as u8);
            let weights = synthetic_weights(0.125, rng.int_in(0, 1 << 20) as u64);
            let gs = vec![prec.max_g(); conv_layer_names().len()];
            let model = PlannedModel::lower(&weights, 0.125, prec, &gs);
            for (plan, name) in model.plans().iter().zip(conv_layer_names()) {
                let (wdims, wdata) = wf32(&weights, &format!("{name}/w"));
                let hi_w = ((1i32 << (prec.b_bits - 1)) - 1) as f32;
                let b_f = weights_to_b(wdims, wdata);
                let g = plan.geom(1);
                let (c_dim, k_dim) = (g.c_dim(), g.k_dim());
                // Every scale, a strided sample of packed values (full
                // coverage of every value is O(model) and slow in debug).
                let cstep = (c_dim / 37).max(1);
                for k in 0..k_dim {
                    let amax = b_f[k * c_dim..(k + 1) * c_dim]
                        .iter()
                        .fold(0.0f32, |m, v| m.max(v.abs()))
                        .max(1e-8);
                    assert_eq!(plan.wscales()[k], amax / hi_w, "{name} sw[{k}]");
                    for c in (0..c_dim).step_by(cstep) {
                        let q = ((b_f[k * c_dim + c] / plan.wscales()[k]).round() as i32)
                            .clamp(-hi_w as i32, hi_w as i32);
                        assert_eq!(plan.packed_b().value(k, c), q, "{name} qb[{k},{c}]");
                    }
                }
            }
        });
    }

    #[test]
    fn interleaved_b_is_the_packed_b_relaid() {
        // Both weight-plane layouts are built at lowering from the same
        // quantized integers; they must stay bit-equivalent.
        let prec = Precision::new(3, 3);
        let weights = synthetic_weights(0.125, 5);
        let gs = vec![prec.max_g(); conv_layer_names().len()];
        let model = PlannedModel::lower(&weights, 0.125, prec, &gs);
        for plan in model.plans() {
            assert_eq!(
                plan.interleaved_b(),
                &InterleavedPlanes::from_packed(plan.packed_b()),
                "{}",
                plan.name()
            );
        }
    }

    #[test]
    fn plan_packing_bitserial_equals_exact_gemm() {
        // LayerPlan weight packing + bitserial_gemm == gemm_exact for
        // random shapes and precisions (the compiled B-side must be a
        // faithful GEMM operand).
        check("plan packed B: bitserial == exact", 40, |rng| {
            let prec = Precision::new(rng.int_in(2, 8) as u8, rng.int_in(2, 8) as u8);
            let c = rng.int_in(1, 130) as usize;
            let l = rng.int_in(1, 9) as usize;
            let k = rng.int_in(1, 17) as usize;
            let hi_a = (1i64 << (prec.a_bits - 1)) - 1;
            let hi_b = (1i64 << (prec.b_bits - 1)) - 1;
            let a: Vec<i32> = (0..c * l).map(|_| rng.int_in(-hi_a - 1, hi_a) as i32).collect();
            let b: Vec<i32> = (0..k * c).map(|_| rng.int_in(-hi_b - 1, hi_b) as i32).collect();
            let plan = LayerPlan::for_gemm(&b, k, c, GavSchedule::all_guarded(prec), 0);
            let pa = PackedPlanes::from_a_matrix(&a, c, l, prec.a_bits);
            assert_eq!(
                bitserial_gemm(&pa, plan.packed_b()),
                gemm_exact(&a, &b, c, l, k),
                "{prec} c={c} l={l} k={k}"
            );
        });
    }

    #[test]
    fn bn_fold_identity_with_old_separate_pass() {
        // Folded BN must be bit-identical to the legacy separate pass:
        // mul derived per request as scale / sqrt(var + 1e-5), then
        // (v - mean) * mul + bias, in that order.
        check("BnFold == legacy bn()", 50, |rng| {
            let c = rng.int_in(1, 40) as usize;
            let scale: Vec<f32> = (0..c).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
            let bias: Vec<f32> = (0..c).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
            let mean: Vec<f32> = (0..c).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
            let var: Vec<f32> = (0..c).map(|_| rng.next_f32()).collect();
            let fold = BnFold::fold(&scale, &bias, &mean, &var);
            for _ in 0..32 {
                let ci = rng.index(c);
                let v = rng.next_f32() * 8.0 - 4.0;
                let mul = scale[ci] / (var[ci] + 1e-5).sqrt();
                let legacy = (v - mean[ci]) * mul + bias[ci];
                assert_eq!(fold.apply(ci, v).to_bits(), legacy.to_bits(), "ci={ci}");
            }
        });
    }
}
