//! Per-tier sliding-window drift statistics.
//!
//! Every canary-sampled request contributes one [`DriftSample`]: did the
//! top-1 class flip vs the bit-exact reference, and how far did the
//! logits move in L∞? The estimator keeps a bounded window of recent
//! samples (the governor reacts to *current* conditions, not the whole
//! history), cumulative totals for reporting, the sampled-set
//! fingerprint (determinism pin) and per-layer observed step-error
//! counters surfaced from the simulator's injection path on the *served*
//! batches themselves.

use std::collections::VecDeque;

use crate::dnn::ForwardStats;

/// One canary observation: served output vs exact reference.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DriftSample {
    /// The served top-1 class differs from the reference's.
    pub top1_flip: bool,
    /// `max_k |served_k - reference_k|` over the logits.
    pub linf: f64,
}

/// A snapshot of one tier's drift state, safe to hand across threads.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DriftStats {
    /// Samples currently in the sliding window.
    pub window_len: usize,
    /// Top-1 flip rate over the window — the feedback signal.
    pub flip_rate: f64,
    /// 95% normal-approximation confidence half-width on `flip_rate`
    /// (`1.96·sqrt(p(1-p)/n)`; 0 when the window is empty).
    pub flip_ci: f64,
    /// Mean / max logit L∞ drift over the window.
    pub mean_linf: f64,
    pub max_linf: f64,
    /// Cumulative totals since the service started.
    pub sampled_total: u64,
    pub flips_total: u64,
    /// XOR fingerprint of every sampled `(stream, row)` hash — two runs
    /// sampled identical sets iff these match.
    pub fingerprint: u64,
    /// Observed per-conv-layer step-error rate (corrupted values per
    /// undervolted step) accumulated from served batches' counters.
    pub layer_step_error_rates: Vec<f64>,
}

/// The mutable estimator behind one tier's `Mutex`.
#[derive(Debug)]
pub struct DriftEstimator {
    window: VecDeque<DriftSample>,
    cap: usize,
    sampled_total: u64,
    flips_total: u64,
    fingerprint: u64,
    layer_corrupted: Vec<u64>,
    layer_steps: Vec<u64>,
}

impl DriftEstimator {
    pub fn new(window: usize) -> Self {
        Self {
            window: VecDeque::with_capacity(window.max(1)),
            cap: window.max(1),
            sampled_total: 0,
            flips_total: 0,
            fingerprint: 0,
            layer_corrupted: Vec::new(),
            layer_steps: Vec::new(),
        }
    }

    /// Record one canary comparison plus its sampled-set fingerprint
    /// contribution (`sampler::row_hash(stream, row)`).
    pub fn observe(&mut self, sample: DriftSample, row_hash: u64) {
        if self.window.len() == self.cap {
            self.window.pop_front();
        }
        self.window.push_back(sample);
        self.sampled_total += 1;
        self.flips_total += sample.top1_flip as u64;
        self.fingerprint ^= row_hash;
    }

    /// Fold a served batch's per-layer injection counters in. This runs
    /// for every batch of an observed tier (not only sampled ones): the
    /// counters are already collected by the executor, so the per-layer
    /// signal is free and converges much faster than the sampled one.
    pub fn observe_layers(&mut self, stats: &ForwardStats) {
        if self.layer_corrupted.len() < stats.layer_corrupted.len() {
            self.layer_corrupted.resize(stats.layer_corrupted.len(), 0);
            self.layer_steps.resize(stats.layer_steps.len(), 0);
        }
        for (i, (&c, &s)) in stats.layer_corrupted.iter().zip(&stats.layer_steps).enumerate() {
            self.layer_corrupted[i] += c;
            self.layer_steps[i] += s;
        }
    }

    /// Current snapshot.
    pub fn stats(&self) -> DriftStats {
        let n = self.window.len();
        let flips = self.window.iter().filter(|s| s.top1_flip).count();
        let p = if n == 0 { 0.0 } else { flips as f64 / n as f64 };
        let ci = if n == 0 {
            0.0
        } else {
            1.96 * (p * (1.0 - p) / n as f64).sqrt()
        };
        let (mut sum, mut max) = (0.0f64, 0.0f64);
        for s in &self.window {
            sum += s.linf;
            max = max.max(s.linf);
        }
        DriftStats {
            window_len: n,
            flip_rate: p,
            flip_ci: ci,
            mean_linf: if n == 0 { 0.0 } else { sum / n as f64 },
            max_linf: max,
            sampled_total: self.sampled_total,
            flips_total: self.flips_total,
            fingerprint: self.fingerprint,
            layer_step_error_rates: self
                .layer_corrupted
                .iter()
                .zip(&self.layer_steps)
                .map(|(&c, &s)| if s == 0 { 0.0 } else { c as f64 / s as f64 })
                .collect(),
        }
    }
}

/// Compare one served row against its exact re-run: top-1 flip (ties
/// break to the first maximum on both sides, so identical logits never
/// flip) and L∞ logit drift.
pub fn compare_row(served: &[f32], reference: &[f32]) -> DriftSample {
    debug_assert_eq!(served.len(), reference.len());
    let argmax = |v: &[f32]| {
        let mut best = 0usize;
        for (i, &x) in v.iter().enumerate() {
            if x > v[best] {
                best = i;
            }
        }
        best
    };
    let linf = served
        .iter()
        .zip(reference)
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0f64, f64::max);
    DriftSample {
        top1_flip: argmax(served) != argmax(reference),
        linf,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_estimator_is_all_zero() {
        let e = DriftEstimator::new(8);
        let s = e.stats();
        assert_eq!(s.window_len, 0);
        assert_eq!(s.flip_rate, 0.0);
        assert_eq!(s.flip_ci, 0.0);
        assert_eq!(s.mean_linf, 0.0);
        assert_eq!(s.sampled_total, 0);
        assert_eq!(s.fingerprint, 0);
        assert!(s.layer_step_error_rates.is_empty());
    }

    #[test]
    fn window_slides_and_totals_accumulate() {
        let mut e = DriftEstimator::new(4);
        for i in 0..10 {
            e.observe(
                DriftSample {
                    top1_flip: i % 2 == 0,
                    linf: i as f64,
                },
                1 << i,
            );
        }
        let s = e.stats();
        assert_eq!(s.window_len, 4, "window is bounded");
        // Window holds samples 6..=9: flips at 6 and 8.
        assert!((s.flip_rate - 0.5).abs() < 1e-12);
        assert!((s.mean_linf - 7.5).abs() < 1e-12);
        assert_eq!(s.max_linf, 9.0);
        assert_eq!(s.sampled_total, 10, "totals outlive the window");
        assert_eq!(s.flips_total, 5);
        assert_eq!(s.fingerprint, (1 << 10) - 1, "XOR of all row hashes");
        // CI shrinks as the window fills: p=0.5, n=4 → 1.96·0.25.
        assert!((s.flip_ci - 1.96 * 0.25).abs() < 1e-12);
    }

    #[test]
    fn layer_counters_sum_across_batches() {
        let mut e = DriftEstimator::new(4);
        let mut a = ForwardStats::default();
        a.record_layer_errors(1, 2, 10);
        let mut b = ForwardStats::default();
        b.record_layer_errors(1, 4, 10);
        b.record_layer_errors(3, 1, 5);
        e.observe_layers(&a);
        e.observe_layers(&b);
        let rates = e.stats().layer_step_error_rates;
        assert_eq!(rates.len(), 4);
        assert!((rates[1] - 6.0 / 20.0).abs() < 1e-12);
        assert!((rates[3] - 0.2).abs() < 1e-12);
        assert_eq!(rates[0], 0.0);
    }

    #[test]
    fn compare_row_detects_flips_and_linf() {
        let s = compare_row(&[0.1, 0.9, 0.0], &[0.1, 0.9, 0.0]);
        assert!(!s.top1_flip);
        assert_eq!(s.linf, 0.0);
        let s = compare_row(&[0.95, 0.9, 0.0], &[0.1, 0.9, 0.0]);
        assert!(s.top1_flip);
        assert!((s.linf - 0.85).abs() < 1e-6);
        // Identical logits with ties: same first-max on both sides.
        let s = compare_row(&[0.5, 0.5], &[0.5, 0.5]);
        assert!(!s.top1_flip);
    }
}
