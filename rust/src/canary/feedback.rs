//! The feedback law that closes the governor loop on measured drift.
//!
//! The load/power governor keeps its historical behavior as a *ceiling*;
//! this module adds the second input: when the observed top-1 flip rate
//! of the governed tier crosses the **high watermark**, the ladder steps
//! toward guarded ([`StepTrigger::Drift`]) and a **dwell** counter arms.
//! The ladder may not re-descend toward aggressive until the flip rate
//! has fallen to the **low watermark** *and* the dwell ticks have run
//! out — oscillating load cannot flap the schedule while drift is hot.
//!
//! Everything here is a pure state machine over snapshots — no clocks,
//! no threads — so the hysteresis contract is pinned by deterministic
//! unit tests and the governor thread just calls [`Feedback::advise`] +
//! [`decide`] once per tick.

use std::fmt;

use super::estimator::DriftStats;
use super::CanaryOptions;

/// Why a governor trajectory entry holds its rung — the signal that
/// produced (or blocked) the transition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepTrigger {
    /// No signal asked for a change.
    Steady,
    /// The admission-load signal moved the ladder (the historical path).
    Load,
    /// The power-budget ceiling pulled the rung back toward aggressive.
    PowerBudget,
    /// Observed flip rate crossed the high watermark: step to guarded.
    Drift,
    /// Drift hysteresis blocked a load-driven descent (watermark band or
    /// unexpired dwell).
    DwellHold,
}

impl fmt::Display for StepTrigger {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            StepTrigger::Steady => "steady",
            StepTrigger::Load => "load",
            StepTrigger::PowerBudget => "power-budget",
            StepTrigger::Drift => "drift",
            StepTrigger::DwellHold => "dwell-hold",
        })
    }
}

/// What the drift signal asks of this governor tick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriftAdvice {
    /// Flip rate at/above the high watermark: step toward guarded now.
    Escalate,
    /// In the hysteresis band or dwelling: hold — no descent allowed.
    Hold,
    /// Below the low watermark with dwell expired: load rules again.
    Clear,
}

/// The per-governor feedback state: just the dwell countdown.
#[derive(Debug, Default)]
pub struct Feedback {
    dwell_remaining: u32,
}

impl Feedback {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ticks left before a descent can be considered (diagnostics).
    pub fn dwell_remaining(&self) -> u32 {
        self.dwell_remaining
    }

    /// One governor tick's worth of drift advice. `stats` is `None` when
    /// canary is disabled or the tier has no estimator — the dwell still
    /// drains so a canary torn down mid-dwell cannot pin the ladder
    /// forever.
    pub fn advise(&mut self, stats: Option<&DriftStats>, opts: &CanaryOptions) -> DriftAdvice {
        if let Some(s) = stats {
            let confident = s.window_len >= opts.min_samples;
            if confident && s.flip_rate >= opts.high_watermark {
                self.dwell_remaining = opts.dwell_ticks;
                return DriftAdvice::Escalate;
            }
            if confident && s.flip_rate > opts.low_watermark {
                // Hysteresis band: neither escalate nor consume dwell.
                return DriftAdvice::Hold;
            }
        }
        if self.dwell_remaining > 0 {
            self.dwell_remaining -= 1;
            return DriftAdvice::Hold;
        }
        DriftAdvice::Clear
    }
}

/// Combine the drift advice with the historical load signal into the next
/// ladder rung. Rung 0 is the most aggressive schedule, `n_rungs - 1`
/// fully guarded (the ladder orientation of `serve::governor`). Drift has
/// priority: an escalation steps toward guarded regardless of load, and a
/// hold vetoes the high-load descent while still allowing low-load ascent
/// (moving toward guarded is always drift-safe). The power budget is NOT
/// applied here — the governor applies it after, as a ceiling, tagging
/// the entry [`StepTrigger::PowerBudget`] when it wins.
pub fn decide(
    cur: usize,
    n_rungs: usize,
    advice: DriftAdvice,
    load: f64,
    low_load: f64,
    high_load: f64,
) -> (usize, StepTrigger) {
    debug_assert!(n_rungs > 0 && cur < n_rungs);
    let ascent = (cur + 1).min(n_rungs - 1); // toward guarded
    match advice {
        DriftAdvice::Escalate => (ascent, StepTrigger::Drift),
        DriftAdvice::Hold => {
            if load <= low_load && ascent != cur {
                (ascent, StepTrigger::Load)
            } else {
                (cur, StepTrigger::DwellHold)
            }
        }
        DriftAdvice::Clear => {
            if load >= high_load && cur > 0 {
                (cur - 1, StepTrigger::Load)
            } else if load <= low_load && ascent != cur {
                (ascent, StepTrigger::Load)
            } else {
                (cur, StepTrigger::Steady)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> CanaryOptions {
        CanaryOptions {
            sample_rate: 0.25,
            window: 64,
            high_watermark: 0.10,
            low_watermark: 0.02,
            dwell_ticks: 3,
            min_samples: 4,
        }
    }

    fn stats(window_len: usize, flip_rate: f64) -> DriftStats {
        DriftStats {
            window_len,
            flip_rate,
            ..DriftStats::default()
        }
    }

    #[test]
    fn spike_escalates_within_one_tick_once_confident() {
        let o = opts();
        let mut fb = Feedback::new();
        // Too few samples: no reaction, even at 100% flips.
        assert_eq!(fb.advise(Some(&stats(3, 1.0)), &o), DriftAdvice::Clear);
        // One tick after the window reaches min_samples: escalate.
        assert_eq!(fb.advise(Some(&stats(4, 0.5)), &o), DriftAdvice::Escalate);
        assert_eq!(fb.dwell_remaining(), 3);
    }

    #[test]
    fn dwell_blocks_redescent_until_expiry() {
        let o = opts();
        let mut fb = Feedback::new();
        assert_eq!(fb.advise(Some(&stats(8, 0.5)), &o), DriftAdvice::Escalate);
        // Flip rate back below the low watermark: still held for
        // exactly dwell_ticks ticks, then clear.
        for i in 0..o.dwell_ticks {
            assert_eq!(
                fb.advise(Some(&stats(8, 0.0)), &o),
                DriftAdvice::Hold,
                "tick {i} must still dwell"
            );
        }
        assert_eq!(fb.advise(Some(&stats(8, 0.0)), &o), DriftAdvice::Clear);
    }

    #[test]
    fn hysteresis_band_holds_without_consuming_dwell() {
        let o = opts();
        let mut fb = Feedback::new();
        assert_eq!(fb.advise(Some(&stats(8, 0.5)), &o), DriftAdvice::Escalate);
        // Between the watermarks: hold indefinitely, dwell untouched.
        for _ in 0..10 {
            assert_eq!(fb.advise(Some(&stats(8, 0.05)), &o), DriftAdvice::Hold);
        }
        assert_eq!(fb.dwell_remaining(), o.dwell_ticks);
        // A fresh spike re-arms rather than draining.
        assert_eq!(fb.advise(Some(&stats(8, 0.2)), &o), DriftAdvice::Escalate);
        assert_eq!(fb.dwell_remaining(), o.dwell_ticks);
    }

    #[test]
    fn missing_stats_drain_the_dwell() {
        let o = opts();
        let mut fb = Feedback::new();
        fb.advise(Some(&stats(8, 0.5)), &o);
        for _ in 0..o.dwell_ticks {
            assert_eq!(fb.advise(None, &o), DriftAdvice::Hold);
        }
        assert_eq!(fb.advise(None, &o), DriftAdvice::Clear);
    }

    #[test]
    fn decide_gives_drift_priority_over_load() {
        // High load wants to descend; escalation overrides it.
        assert_eq!(
            decide(2, 5, DriftAdvice::Escalate, 0.9, 0.2, 0.7),
            (3, StepTrigger::Drift)
        );
        // Already fully guarded: stay, still drift-tagged.
        assert_eq!(
            decide(4, 5, DriftAdvice::Escalate, 0.9, 0.2, 0.7),
            (4, StepTrigger::Drift)
        );
    }

    #[test]
    fn hold_vetoes_descent_but_allows_guarded_ascent() {
        // Oscillating load during a hold: the high-load descent is
        // blocked and tagged, so the ladder cannot flap.
        assert_eq!(
            decide(2, 5, DriftAdvice::Hold, 0.9, 0.2, 0.7),
            (2, StepTrigger::DwellHold)
        );
        assert_eq!(
            decide(2, 5, DriftAdvice::Hold, 0.5, 0.2, 0.7),
            (2, StepTrigger::DwellHold)
        );
        // Low load still ascends toward guarded — always drift-safe.
        assert_eq!(
            decide(2, 5, DriftAdvice::Hold, 0.1, 0.2, 0.7),
            (3, StepTrigger::Load)
        );
    }

    #[test]
    fn clear_restores_the_historical_load_law() {
        assert_eq!(
            decide(2, 5, DriftAdvice::Clear, 0.9, 0.2, 0.7),
            (1, StepTrigger::Load)
        );
        assert_eq!(
            decide(2, 5, DriftAdvice::Clear, 0.1, 0.2, 0.7),
            (3, StepTrigger::Load)
        );
        assert_eq!(
            decide(2, 5, DriftAdvice::Clear, 0.5, 0.2, 0.7),
            (2, StepTrigger::Steady)
        );
        // Boundary rungs clamp instead of wrapping.
        assert_eq!(
            decide(0, 5, DriftAdvice::Clear, 0.9, 0.2, 0.7),
            (0, StepTrigger::Steady)
        );
        assert_eq!(
            decide(4, 5, DriftAdvice::Clear, 0.1, 0.2, 0.7),
            (4, StepTrigger::Steady)
        );
    }
}
