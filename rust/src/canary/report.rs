//! Operator-facing canary reports: one per observed tier, carried on the
//! final `ServeReport` and printed by `gavina serve`, `examples/serve.rs`
//! and `benches/serve.rs` (whose `observed_flip_rate` line is grepped as
//! a blocking CI artifact check).

use super::estimator::DriftStats;

/// One tier's canary summary at shutdown (or snapshot time).
#[derive(Clone, Debug)]
pub struct CanaryTierReport {
    pub tier: String,
    /// Requests re-executed on the exact reference since start.
    pub sampled: u64,
    /// Top-1 flips observed since start.
    pub flips: u64,
    /// Flip rate over the sliding window (the feedback signal) and its
    /// 95% confidence half-width.
    pub observed_flip_rate: f64,
    pub flip_ci: f64,
    /// Samples currently in the window.
    pub window_len: usize,
    /// Logit L∞ drift over the window.
    pub mean_linf: f64,
    pub max_linf: f64,
    /// XOR fingerprint of the sampled set (replay determinism pin).
    pub fingerprint: u64,
    /// Observed per-conv-layer step-error rates from served batches.
    pub layer_step_error_rates: Vec<f64>,
}

impl CanaryTierReport {
    pub fn from_stats(tier: &str, s: &DriftStats) -> Self {
        Self {
            tier: tier.to_string(),
            sampled: s.sampled_total,
            flips: s.flips_total,
            observed_flip_rate: s.flip_rate,
            flip_ci: s.flip_ci,
            window_len: s.window_len,
            mean_linf: s.mean_linf,
            max_linf: s.max_linf,
            fingerprint: s.fingerprint,
            layer_step_error_rates: s.layer_step_error_rates.clone(),
        }
    }

    /// The canonical one-line rendering. Every reporter prints this same
    /// form, so the CI grep for `observed_flip_rate` pins all of them.
    pub fn summary_line(&self) -> String {
        format!(
            "tier {:10} canary: sampled {:5} ({} flips)  observed_flip_rate {:.4} ±{:.4} \
             (window {})  linf mean {:.3e} max {:.3e}",
            self.tier,
            self.sampled,
            self.flips,
            self.observed_flip_rate,
            self.flip_ci,
            self.window_len,
            self.mean_linf,
            self.max_linf,
        )
    }

    /// Non-zero per-layer step-error rates as `layer:rate` pairs — empty
    /// string when every layer ran clean (or guarded).
    pub fn hot_layers(&self) -> String {
        self.layer_step_error_rates
            .iter()
            .enumerate()
            .filter(|(_, r)| **r > 0.0)
            .map(|(i, r)| format!("{i}:{r:.2e}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_line_carries_the_grepped_fields() {
        let r = CanaryTierReport {
            tier: "aggressive".into(),
            sampled: 12,
            flips: 3,
            observed_flip_rate: 0.25,
            flip_ci: 0.1,
            window_len: 12,
            mean_linf: 0.5,
            max_linf: 2.0,
            fingerprint: 0xABCD,
            layer_step_error_rates: vec![0.0, 0.125, 0.0],
        };
        let line = r.summary_line();
        assert!(line.contains("observed_flip_rate 0.2500"), "{line}");
        assert!(line.contains("tier aggressive"), "{line}");
        assert!(line.contains("(3 flips)"), "{line}");
        assert_eq!(r.hot_layers(), "1:1.25e-1");
    }

    #[test]
    fn from_stats_copies_every_field() {
        let s = DriftStats {
            window_len: 5,
            flip_rate: 0.2,
            flip_ci: 0.05,
            mean_linf: 1.0,
            max_linf: 3.0,
            sampled_total: 40,
            flips_total: 8,
            fingerprint: 77,
            layer_step_error_rates: vec![0.5],
        };
        let r = CanaryTierReport::from_stats("exact", &s);
        assert_eq!(r.tier, "exact");
        assert_eq!(r.sampled, 40);
        assert_eq!(r.flips, 8);
        assert_eq!(r.observed_flip_rate, 0.2);
        assert_eq!(r.fingerprint, 77);
        assert_eq!(r.layer_step_error_rates, vec![0.5]);
    }
}
