//! `gavina::canary` — online error observability for the serving stack.
//!
//! The §IV error model is calibrated offline; at serving time the
//! governor historically stepped the G-schedule ladder on admission load
//! against a *modeled* power budget, blind to what undervolting was
//! actually doing to logits. This subsystem turns injected-error
//! telemetry into a closed loop:
//!
//! ```text
//!   served batches ──► sampler ──► exact re-run ──► estimator ──► feedback ──► ladder
//!   (stream, row)      (pure fn)   (Exact replica,  (per-tier      (watermarks   (governor
//!                                   no admission)    window stats)   + dwell)      rung)
//! ```
//!
//! * [`sampler`] deterministically selects a configured fraction of
//!   in-flight rows per tier, keyed by the batch's injection stream — so
//!   replays reproduce the exact sampled set.
//! * Sampled rows are re-executed on a bit-exact [`GavPolicy::Exact`]
//!   replica via [`Engine::canary_rerun`], which sits *below* the serve
//!   stack: re-runs never touch admission permits or dispatch queues.
//! * [`estimator`] maintains per-tier sliding-window drift statistics
//!   (top-1 flip rate with a confidence interval, logit L∞ drift,
//!   per-layer observed step-error rates from the served batches' own
//!   simulator counters).
//! * [`feedback`] extends the governor with the measured signal:
//!   flip-rate above the high watermark steps the ladder toward guarded
//!   ([`StepTrigger::Drift`]); hysteresis (low watermark + dwell ticks)
//!   blocks re-descent; load/power stay in force as a ceiling.
//! * [`report`] renders the per-tier summaries carried on `ServeReport`.
//!
//! Configured through `[serve.canary]` (see
//! [`ServeOptions::from_config`](crate::serve::ServeOptions::from_config)).
//!
//! [`GavPolicy::Exact`]: crate::engine::GavPolicy::Exact
//! [`Engine::canary_rerun`]: crate::engine::Engine::canary_rerun

pub mod estimator;
pub mod feedback;
pub mod report;
pub mod sampler;

use std::sync::{Arc, Mutex};

use crate::dnn::ForwardResult;
use crate::engine::{Engine, GavinaError};

pub use estimator::{DriftEstimator, DriftSample, DriftStats};
pub use feedback::{decide, DriftAdvice, Feedback, StepTrigger};
pub use report::CanaryTierReport;

/// `[serve.canary]` configuration. A bare `[serve.canary]` section
/// enables the defaults.
#[derive(Clone, Debug)]
pub struct CanaryOptions {
    /// Fraction of served requests re-executed on the exact reference,
    /// in `(0, 1]`.
    pub sample_rate: f64,
    /// Sliding-window size (samples) behind the drift estimates.
    pub window: usize,
    /// Flip rate at/above which the ladder steps toward guarded.
    pub high_watermark: f64,
    /// Flip rate the window must fall to before a descent is considered.
    pub low_watermark: f64,
    /// Governor ticks the ladder must hold after the flip rate clears
    /// the low watermark before re-descending.
    pub dwell_ticks: u32,
    /// Minimum window occupancy before the feedback acts (confidence
    /// gate — one early flip must not swing the schedule).
    pub min_samples: usize,
}

impl Default for CanaryOptions {
    fn default() -> Self {
        Self {
            sample_rate: 0.05,
            window: 256,
            high_watermark: 0.05,
            low_watermark: 0.01,
            dwell_ticks: 8,
            min_samples: 16,
        }
    }
}

impl CanaryOptions {
    pub fn validate(&self) -> Result<(), GavinaError> {
        let bad = |msg: String| Err(GavinaError::Config(format!("[serve.canary]: {msg}")));
        if !(self.sample_rate > 0.0 && self.sample_rate <= 1.0) {
            return bad(format!(
                "sample_rate must be in (0, 1], got {}",
                self.sample_rate
            ));
        }
        if self.window == 0 {
            return bad("window must be >= 1".into());
        }
        if self.min_samples == 0 {
            return bad("min_samples must be >= 1".into());
        }
        if self.min_samples > self.window {
            return bad(format!(
                "min_samples ({}) cannot exceed window ({})",
                self.min_samples, self.window
            ));
        }
        if !(self.high_watermark > 0.0 && self.high_watermark <= 1.0) {
            return bad(format!(
                "high_watermark must be in (0, 1], got {}",
                self.high_watermark
            ));
        }
        if !(self.low_watermark >= 0.0 && self.low_watermark < self.high_watermark) {
            return bad(format!(
                "low_watermark must be in [0, high_watermark), got {} vs {}",
                self.low_watermark, self.high_watermark
            ));
        }
        Ok(())
    }
}

/// One observed tier's estimator slot.
struct TierCanary {
    /// Exact-policy tiers are the reference itself — never observed.
    observed: bool,
    estimator: Mutex<DriftEstimator>,
}

/// The shared canary runtime: the exact reference replica plus one
/// estimator per observed tier. Workers call [`CanaryRuntime::pick_rows`]
/// before responding (pure decision) and
/// [`CanaryRuntime::observe_batch`] after — the re-run happens inline on
/// the worker thread, off the request critical path, and through
/// [`Engine::canary_rerun`] only, so it can never consume an admission
/// permit or occupy a dispatch lane.
pub struct CanaryRuntime {
    opts: CanaryOptions,
    threshold: u64,
    reference: Arc<Engine>,
    tiers: Vec<TierCanary>,
}

impl CanaryRuntime {
    /// `observed[t]` says whether tier `t` is canary-observed (serve
    /// passes `false` for Exact-policy tiers).
    pub fn new(opts: CanaryOptions, reference: Arc<Engine>, observed: Vec<bool>) -> Self {
        let threshold = sampler::sample_threshold(opts.sample_rate);
        let tiers = observed
            .into_iter()
            .map(|o| TierCanary {
                observed: o,
                estimator: Mutex::new(DriftEstimator::new(opts.window)),
            })
            .collect();
        Self {
            opts,
            threshold,
            reference,
            tiers,
        }
    }

    pub fn options(&self) -> &CanaryOptions {
        &self.opts
    }

    /// The bit-exact reference replica (shared packed planes).
    pub fn reference(&self) -> &Arc<Engine> {
        &self.reference
    }

    /// Whether `tier` is canary-observed.
    pub fn observes(&self, tier: usize) -> bool {
        self.tiers.get(tier).is_some_and(|t| t.observed)
    }

    /// The rows of an `n`-row batch on `tier` to sample — pure in
    /// `(stream, n)`; empty for unobserved tiers.
    pub fn pick_rows(&self, tier: usize, stream: u64, n: usize) -> Vec<usize> {
        if !self.observes(tier) {
            return Vec::new();
        }
        sampler::pick_rows(stream, n, self.threshold)
    }

    /// Fold one served batch into the tier's estimator: the batch's own
    /// per-layer injection counters (every batch), plus the exact re-run
    /// comparison of the sampled rows (`picked` pairs each sampled row
    /// index with a clone of its image, taken before the response was
    /// sent). Returns the number of samples recorded.
    pub fn observe_batch(
        &self,
        tier: usize,
        stream: u64,
        picked: &[(usize, Vec<f32>)],
        served: &ForwardResult,
    ) -> usize {
        if !self.observes(tier) {
            return 0;
        }
        let samples: Vec<(usize, DriftSample)> = if picked.is_empty() {
            Vec::new()
        } else {
            let rows: Vec<&[f32]> = picked.iter().map(|(_, img)| img.as_slice()).collect();
            match self.reference.canary_rerun(&rows) {
                // A rerun failure (malformed row) cannot corrupt the
                // estimate — the batch simply contributes no samples.
                Err(_) => Vec::new(),
                Ok(reference) => picked
                    .iter()
                    .enumerate()
                    .map(|(j, (row, _))| {
                        let c = served.classes;
                        let s = &served.logits[row * c..(row + 1) * c];
                        let r = &reference.logits[j * c..(j + 1) * c];
                        (*row, estimator::compare_row(s, r))
                    })
                    .collect(),
            }
        };
        let mut est = self.tiers[tier].estimator.lock().unwrap();
        est.observe_layers(&served.stats);
        let n = samples.len();
        for (row, sample) in samples {
            est.observe(sample, sampler::row_hash(stream, row as u64));
        }
        n
    }

    /// Current drift snapshot for `tier` (`None` when unobserved) — the
    /// governor's second input.
    pub fn tier_stats(&self, tier: usize) -> Option<DriftStats> {
        let t = self.tiers.get(tier)?;
        if !t.observed {
            return None;
        }
        Some(t.estimator.lock().unwrap().stats())
    }

    /// Shutdown/snapshot reports for every observed tier, labelled with
    /// `names` (parallel to the tier indices).
    pub fn reports(&self, names: &[&str]) -> Vec<CanaryTierReport> {
        self.tiers
            .iter()
            .zip(names)
            .filter(|(t, _)| t.observed)
            .map(|(t, name)| CanaryTierReport::from_stats(name, &t.estimator.lock().unwrap().stats()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchConfig, Precision};
    use crate::engine::{EngineBuilder, GavPolicy};
    use crate::errmodel::{ErrorTables, ModelParams};
    use crate::util::Prng;

    #[test]
    fn options_validation() {
        assert!(CanaryOptions::default().validate().is_ok());
        let bad = |f: fn(&mut CanaryOptions)| {
            let mut o = CanaryOptions::default();
            f(&mut o);
            o.validate().is_err()
        };
        assert!(bad(|o| o.sample_rate = 0.0));
        assert!(bad(|o| o.sample_rate = 1.5));
        assert!(bad(|o| o.window = 0));
        assert!(bad(|o| o.min_samples = 0));
        assert!(bad(|o| {
            o.window = 4;
            o.min_samples = 5;
        }));
        assert!(bad(|o| o.high_watermark = 0.0));
        assert!(bad(|o| o.low_watermark = o.high_watermark));
        let ok = CanaryOptions {
            sample_rate: 1.0,
            low_watermark: 0.0,
            ..CanaryOptions::default()
        };
        assert!(ok.validate().is_ok());
    }

    /// MSB-always-flips tables: undervolted steps corrupt loudly.
    fn hot_tables(arch: &ArchConfig) -> ErrorTables {
        let params = ModelParams::paper(arch.c_dim);
        let mut tables = ErrorTables::zeroed(params);
        let msb = params.s_bits - 1;
        for e in 0..=params.c_dim as u16 {
            for pb in 0..params.p_bins {
                tables.set_prob(msb, e, pb, 0, 1.0);
            }
        }
        tables
    }

    #[test]
    fn runtime_observes_drift_on_an_aggressive_engine() {
        let arch = ArchConfig::tiny();
        let engine = Arc::new(
            EngineBuilder::new()
                .synthetic_weights(0.125, 41)
                .precision(Precision::new(2, 2))
                .arch(arch.clone())
                .tables(Arc::new(hot_tables(&arch)))
                .policy(GavPolicy::Uniform(0))
                .seed(42)
                .build()
                .expect("engine"),
        );
        let reference = Arc::new(engine.exact_reference().expect("exact replica"));
        let opts = CanaryOptions {
            sample_rate: 1.0,
            window: 32,
            ..CanaryOptions::default()
        };
        let rt = CanaryRuntime::new(opts, Arc::clone(&reference), vec![true, false]);
        assert!(rt.observes(0));
        assert!(!rt.observes(1), "exact tiers are never observed");
        assert!(rt.pick_rows(1, 7, 8).is_empty());

        let mut rng = Prng::new(43);
        let images: Vec<f32> = (0..4 * crate::dnn::IMAGE_LEN).map(|_| rng.next_f32()).collect();
        let rows: Vec<&[f32]> = images.chunks(crate::dnn::IMAGE_LEN).collect();
        let stream = 0x5EED;
        let served = engine.infer_rows(&rows, stream).expect("served batch");
        assert!(served.stats.corrupted > 0, "tables must inject");

        let picked_idx = rt.pick_rows(0, stream, rows.len());
        assert_eq!(picked_idx.len(), 4, "rate 1.0 samples every row");
        let picked: Vec<(usize, Vec<f32>)> =
            picked_idx.iter().map(|&i| (i, rows[i].to_vec())).collect();
        let n = rt.observe_batch(0, stream, &picked, &served);
        assert_eq!(n, 4);

        let stats = rt.tier_stats(0).expect("observed tier has stats");
        assert_eq!(stats.window_len, 4);
        assert_eq!(stats.sampled_total, 4);
        assert!(stats.max_linf > 0.0, "MSB flips must move logits");
        assert!(
            stats.layer_step_error_rates.iter().any(|r| *r > 0.0),
            "per-layer counters must surface the injections"
        );
        // The reference itself observes zero drift against itself.
        let ref_served = reference.infer_rows(&rows, stream).expect("reference batch");
        let rt2 = CanaryRuntime::new(
            CanaryOptions {
                sample_rate: 1.0,
                ..CanaryOptions::default()
            },
            Arc::clone(&reference),
            vec![true],
        );
        rt2.observe_batch(0, stream, &picked, &ref_served);
        let s2 = rt2.tier_stats(0).unwrap();
        assert_eq!(s2.flips_total, 0);
        assert_eq!(s2.max_linf, 0.0, "exact vs exact is bit-identical");

        let reports = rt.reports(&["aggressive", "exact"]);
        assert_eq!(reports.len(), 1);
        assert_eq!(reports[0].tier, "aggressive");
        assert!(reports[0].summary_line().contains("observed_flip_rate"));
    }

    #[test]
    fn sampling_and_fingerprint_replay_identically() {
        let arch = ArchConfig::tiny();
        let engine = Arc::new(
            EngineBuilder::new()
                .synthetic_weights(0.125, 51)
                .precision(Precision::new(2, 2))
                .arch(arch)
                .policy(GavPolicy::Exact)
                .seed(52)
                .build()
                .expect("engine"),
        );
        let opts = CanaryOptions {
            sample_rate: 0.5,
            ..CanaryOptions::default()
        };
        let mk = || {
            CanaryRuntime::new(opts.clone(), Arc::clone(&engine), vec![true])
        };
        let (a, b) = (mk(), mk());
        let mut rng = Prng::new(53);
        let images: Vec<f32> = (0..6 * crate::dnn::IMAGE_LEN).map(|_| rng.next_f32()).collect();
        let rows: Vec<&[f32]> = images.chunks(crate::dnn::IMAGE_LEN).collect();
        for stream in [1u64, 2, 3] {
            let served = engine.infer_rows(&rows, stream).unwrap();
            for rt in [&a, &b] {
                let picked: Vec<(usize, Vec<f32>)> = rt
                    .pick_rows(0, stream, rows.len())
                    .into_iter()
                    .map(|i| (i, rows[i].to_vec()))
                    .collect();
                rt.observe_batch(0, stream, &picked, &served);
            }
        }
        let (sa, sb) = (a.tier_stats(0).unwrap(), b.tier_stats(0).unwrap());
        assert_eq!(sa, sb, "replay must reproduce the estimate exactly");
        assert_ne!(sa.sampled_total, 0, "rate 0.5 over 18 rows must sample");
        assert_eq!(sa.fingerprint, sb.fingerprint);
    }
}
