//! Deterministic canary sampling.
//!
//! The sampling decision is a **pure function** of the batch's injection
//! stream (the PR 8 `batch_seq`-derived seed already carried by every
//! served batch) and the row's index within the batch — no RNG state, no
//! clock, no per-worker mutation. Replaying the same request stream
//! therefore reproduces the exact same sampled set, which is what makes
//! canary drift estimates comparable across runs and pinnable in tests.

/// SplitMix64-style finalizer over `(stream, row)`. The constants are the
/// standard SplitMix64 multipliers; `stream` already encodes
/// `(batch_seq, worker)` so mixing the row index in is enough to give
/// every row of every batch an independent, uniformly distributed hash.
pub fn row_hash(stream: u64, row: u64) -> u64 {
    let mut z = stream ^ row.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Map a sampling rate in `[0, 1]` onto a threshold in u64 hash space.
/// `1.0` maps to "always sample" (see [`sampled`] — a plain `<` against
/// `u64::MAX` would exclude the one row hashing to the maximum).
pub fn sample_threshold(rate: f64) -> u64 {
    let r = rate.clamp(0.0, 1.0);
    if r >= 1.0 {
        u64::MAX
    } else {
        (r * u64::MAX as f64) as u64
    }
}

/// Whether `(stream, row)` is canary-sampled at `threshold`.
pub fn sampled(stream: u64, row: usize, threshold: u64) -> bool {
    threshold == u64::MAX || row_hash(stream, row as u64) < threshold
}

/// The row indices of one batch selected at `threshold` — the worker
/// clones exactly these images before responding.
pub fn pick_rows(stream: u64, n: usize, threshold: u64) -> Vec<usize> {
    (0..n).filter(|&i| sampled(stream, i, threshold)).collect()
}

/// Order-independent fingerprint of a sampled set: XOR of the row hashes.
/// Two runs sampled the same `(stream, row)` pairs iff (up to XOR
/// collisions) their fingerprints match — the determinism pin used by
/// `tests/serve_qos.rs`.
pub fn fold_fingerprint(acc: u64, stream: u64, row: usize) -> u64 {
    acc ^ row_hash(stream, row as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_is_a_pure_function_of_stream_and_row() {
        let t = sample_threshold(0.25);
        for stream in [0u64, 1, 0xDEAD_BEEF, u64::MAX] {
            for row in 0..64 {
                assert_eq!(sampled(stream, row, t), sampled(stream, row, t));
            }
        }
        assert_eq!(pick_rows(42, 32, t), pick_rows(42, 32, t));
    }

    #[test]
    fn rate_bounds_sample_everything_or_nothing() {
        assert_eq!(pick_rows(7, 16, sample_threshold(1.0)).len(), 16);
        assert_eq!(pick_rows(7, 16, sample_threshold(0.0)).len(), 0);
        // Out-of-range rates clamp instead of wrapping.
        assert_eq!(pick_rows(7, 16, sample_threshold(2.5)).len(), 16);
        assert_eq!(pick_rows(7, 16, sample_threshold(-1.0)).len(), 0);
    }

    #[test]
    fn observed_rate_tracks_the_configured_rate() {
        // Over many (stream, row) pairs the hit fraction must approach
        // the configured rate — the hash is uniform enough for control.
        let t = sample_threshold(0.1);
        let n = 20_000u64;
        let hits = (0..n).filter(|&i| sampled(i * 31 + 7, (i % 13) as usize, t)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.1).abs() < 0.02, "observed {rate}");
    }

    #[test]
    fn different_streams_pick_different_rows() {
        let t = sample_threshold(0.5);
        let a = pick_rows(1, 256, t);
        let b = pick_rows(2, 256, t);
        assert_ne!(a, b, "stream must perturb the sampled set");
    }

    #[test]
    fn fingerprint_is_order_independent_and_set_sensitive() {
        let mut f1 = 0u64;
        for r in [0usize, 3, 5] {
            f1 = fold_fingerprint(f1, 9, r);
        }
        let mut f2 = 0u64;
        for r in [5usize, 0, 3] {
            f2 = fold_fingerprint(f2, 9, r);
        }
        assert_eq!(f1, f2);
        assert_ne!(f1, fold_fingerprint(f1, 9, 7), "extra row must show");
    }
}
