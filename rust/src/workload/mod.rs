//! Workload generators for the evaluation pipeline.
//!
//! * [`uniform_ip_matrices`] — the §IV-B error-characterization workload:
//!   *"random matrices … generated using a probability distribution that
//!   forces both the inner-products computed by the GEMM and one of the
//!   input operands to follow an approximately uniform distribution"* —
//!   i.e. the GEMM outputs sweep the full dynamic range instead of
//!   concentrating around 0 like iid operands would.
//! * [`gemm_workload`] — sized random GEMMs for throughput benches.
//! * Synthetic-CIFAR evaluation images come from
//!   `artifacts/dataset_eval.bin` ([`crate::dnn::load_eval_set`]), exported
//!   by the Python build so both executors score identical pixels.

use crate::arch::Precision;
use crate::quant::quant_range;
use crate::util::Prng;

/// The paper's error-analysis operand pair: `A[C_total, L]`,
/// `B[K, C_total]` quantized to the given precision, with per-column
/// amplitude modulation of `A` so inner products spread ~uniformly over
/// the representable range, and `B` itself ~uniform.
pub fn uniform_ip_matrices(
    c_total: usize,
    l: usize,
    k: usize,
    prec: Precision,
    rng: &mut Prng,
) -> (Vec<i32>, Vec<i32>) {
    let (lo_a, hi_a) = quant_range(prec.a_bits);
    let (lo_b, hi_b) = quant_range(prec.b_bits);
    // B: iid uniform over the full range (the "one of the input operands"
    // clause).
    let b: Vec<i32> = (0..k * c_total)
        .map(|_| rng.int_in(lo_b as i64, hi_b as i64) as i32)
        .collect();
    // A: correlated with B so inner products sweep the range. Any A drawn
    // independently of a zero-mean B gives E[P] = 0 with concentration
    // around it, so uniform outputs *require* operand correlation: column
    // l aligns with row `l mod K` of B at strength u_l ∈ [-1, 1]. The
    // aligned output is ≈ u_l·0.7·hi_a·hi_b·C/3 — uniform in u_l over the
    // full dynamic range — while unaligned outputs stay small, together
    // spreading the output distribution (the paper's stated goal: observe
    // the full dynamic range of a GEMM).
    let mut a = vec![0i32; c_total * l];
    for col in 0..l {
        // Stratified alignment strength: columns sweep u ∈ (-1, 1)
        // deterministically (plus jitter) so every output-range octile is
        // guaranteed coverage regardless of L.
        let u = -1.0 + 2.0 * (col as f64 + 0.2 + 0.6 * rng.next_f64()) / l as f64;
        let k0 = col % k;
        for row in 0..c_total {
            let base = b[k0 * c_total + row] as f64 / hi_b.max(1) as f64;
            let noise = 2.0 * rng.next_f64() - 1.0;
            let val = u * (0.7 * base + 0.3 * noise) * hi_a as f64;
            a[row * l + col] = (val.round() as i64).clamp(lo_a as i64, hi_a as i64) as i32;
        }
    }
    (a, b)
}

/// Random fully-iid GEMM operands (throughput benches; value statistics
/// don't matter there).
pub fn gemm_workload(
    c_total: usize,
    l: usize,
    k: usize,
    prec: Precision,
    rng: &mut Prng,
) -> (Vec<i32>, Vec<i32>) {
    let (lo_a, hi_a) = quant_range(prec.a_bits);
    let (lo_b, hi_b) = quant_range(prec.b_bits);
    let a = (0..c_total * l)
        .map(|_| rng.int_in(lo_a as i64, hi_a as i64) as i32)
        .collect();
    let b = (0..k * c_total)
        .map(|_| rng.int_in(lo_b as i64, hi_b as i64) as i32)
        .collect();
    (a, b)
}

/// The paper's Fig. 5 / §IV-B characterization shape: `[4608, 64] ×
/// [64, 4608]` (8 C-tiles × 8 L-tiles × 4 K-tiles of the hardware array).
pub const ERROR_ANALYSIS_SHAPE: (usize, usize, usize) = (4608, 64, 64);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::gemm_exact;
    use crate::stats::histogram;

    #[test]
    fn uniform_ip_spreads_the_output_range() {
        // Inner products must cover the dynamic range much more uniformly
        // than iid operands (which concentrate around 0).
        let mut rng = Prng::new(1);
        let prec = Precision::new(4, 4);
        let (c, l, k) = (576, 64, 16);

        let (a, b) = uniform_ip_matrices(c, l, k, prec, &mut rng);
        let p = gemm_exact(&a, &b, c, l, k);
        let maxabs = p.iter().map(|&v| (v as f64).abs()).fold(0.0, f64::max);
        let vals: Vec<f64> = p.iter().map(|&v| v as f64 / maxabs).collect();
        let h = histogram(&vals, -1.0, 1.0001, 8);
        // Every octile of the normalized output range is populated with at
        // least ~1% of the outputs (iid operands leave the tails empty).
        let min_bin = *h.iter().min().unwrap();
        assert!(
            min_bin as f64 > p.len() as f64 * 0.004,
            "output histogram too concentrated: {h:?}"
        );

        // Contrast: iid operands never reach the representable extremes —
        // their max inner product stays far below the uniform-ip one
        // relative to the theoretical bound C·hi_a·hi_b.
        let bound = (c as f64) * 7.0 * 7.0;
        let (a2, b2) = gemm_workload(c, l, k, prec, &mut rng);
        let p2 = gemm_exact(&a2, &b2, c, l, k);
        let maxabs2 = p2.iter().map(|&v| (v as f64).abs()).fold(0.0, f64::max);
        assert!(
            maxabs / bound > 2.0 * maxabs2 / bound,
            "uniform-ip must reach further into the dynamic range: \
             {maxabs:.0} vs iid {maxabs2:.0} of bound {bound:.0}"
        );
    }

    #[test]
    fn operands_respect_quant_range() {
        let mut rng = Prng::new(2);
        for prec in Precision::EVAL_SET {
            let (a, b) = uniform_ip_matrices(100, 8, 4, prec, &mut rng);
            let (lo_a, hi_a) = quant_range(prec.a_bits);
            let (lo_b, hi_b) = quant_range(prec.b_bits);
            assert!(a.iter().all(|&v| v >= lo_a && v <= hi_a));
            assert!(b.iter().all(|&v| v >= lo_b && v <= hi_b));
        }
    }

    #[test]
    fn b_operand_is_roughly_uniform() {
        let mut rng = Prng::new(3);
        let prec = Precision::new(8, 8);
        let (_, b) = uniform_ip_matrices(500, 4, 8, prec, &mut rng);
        let vals: Vec<f64> = b.iter().map(|&v| v as f64).collect();
        let h = histogram(&vals, -127.0, 128.0, 8);
        let n = b.len() as f64;
        for (i, &count) in h.iter().enumerate() {
            let frac = count as f64 / n;
            assert!(
                (frac - 0.125).abs() < 0.04,
                "B bin {i} fraction {frac} not uniform ({h:?})"
            );
        }
    }

    #[test]
    fn error_analysis_shape_tiles_the_array() {
        let arch = crate::arch::ArchConfig::paper();
        let (c, l, k) = ERROR_ANALYSIS_SHAPE;
        assert_eq!(c % arch.c_dim, 0);
        assert_eq!(l % arch.l_dim, 0);
        assert_eq!(k % arch.k_dim, 0);
    }
}
