//! # GAVINA — Guarded Aggressive underVolting mixed-precision accelerator
//!
//! Full-system reproduction of *"GAVINA: flexible aggressive undervolting
//! for bit-serial mixed-precision DNN acceleration"* (Fornt et al., 2025).
//!
//! The crate is the Layer-3 coordinator of a three-layer Rust + JAX + Pallas
//! stack (see `DESIGN.md`):
//!
//! * [`arch`] — architectural parameters, precision configs and the GAV
//!   voltage schedule (paper Fig. 2).
//! * [`quant`] — uniform symmetric quantization and bit-plane packing
//!   (the bit-serial data layout of A0/B0 Mem).
//! * [`netlist`] — gate-level elaboration of an inner-product element
//!   (AND array + adder tree), substituting the paper's 12 nm netlist.
//! * [`gls`] — event-driven delay-annotated simulation ("gate-level
//!   simulation") with an alpha-power-law voltage/delay model; the
//!   ground truth for undervolting errors.
//! * [`errmodel`] — the paper's heuristic LUT error model (§IV-C):
//!   calibration against [`gls`] traces and fast sampling.
//! * [`power`] — CV²f power/energy model calibrated on Table I / Fig. 4b.
//! * [`simulator`] — cycle-level GAVINA simulator (controller, memories,
//!   Parallel Array, L0/L1 accumulators, DVS).
//! * [`gemm`] — the bit-packed binary-GEMM hot path (u64 AND+popcount).
//! * [`dnn`] — DNN substrate: tensors, conv-to-GEMM lowering, the
//!   quantized ResNet-18 benchmark graph.
//! * [`engine`] — **the public API**: `EngineBuilder` → validated,
//!   `Arc`-shareable `Engine` with typed `GavinaError`s, pluggable
//!   `ExecBackend`s and first-class `GavPolicy` G allocation.
//! * [`ilp`] — branch-and-bound ILP for per-layer G allocation (§IV-D).
//! * [`stats`] — VAR_NED (Eq. 1), MSE, accuracy metrics.
//! * [`workload`] — synthetic GEMM/DNN workload generators (§IV-B
//!   uniform-inner-product distribution).
//! * [`baseline`] — state-of-the-art comparison data + simplified TED /
//!   fixed-LSB TEP baseline accelerator models (Table II, Fig. 1).
//! * [`runtime`] — PJRT runtime loading the AOT `artifacts/*.hlo.txt`.
//! * [`serve`] — the QoS serving layer: `Session`/`Ticket` request API,
//!   bounded admission, per-request energy tiers, load-adaptive
//!   undervolting governor, per-tier metrics.
//! * [`canary`] — online error observability: deterministic canary
//!   sampling of in-flight requests, exact-replica re-execution, per-tier
//!   drift estimation and the feedback law that closes the governor loop
//!   on *measured* flip rate.
//! * [`config`] — TOML-subset run-configuration parser (no external deps).
//! * [`util`] — deterministic PRNG and small shared helpers.
//!
//! Python (JAX + Pallas) exists only on the compile path: `make artifacts`
//! AOT-lowers the L1/L2 kernels to HLO text and trains the benchmark
//! weights; the binary in `rust/src/main.rs` is self-contained afterwards.

// Every unsafe operation must sit in an explicit `unsafe {}` block with
// its own `// SAFETY:` justification, even inside `unsafe fn` bodies —
// enforced here, by clippy's `undocumented_unsafe_blocks` in CI, and by
// `gavina-xtask check` (rules `unsafe-doc` / `unsafe-scope`).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod arch;
pub mod baseline;
pub mod canary;
pub mod config;
pub mod dnn;
pub mod engine;
pub mod errmodel;
pub mod gemm;
pub mod gls;
pub mod ilp;
pub mod netlist;
pub mod power;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod simulator;
pub mod stats;
pub mod util;
pub mod workload;

pub use arch::{ArchConfig, GavSchedule, Precision};
pub use engine::{Engine, EngineBuilder, GavPolicy, GavinaError};
pub use errmodel::ErrorTables;
pub use power::PowerModel;
pub use serve::{ServeOptions, Service, Session};
