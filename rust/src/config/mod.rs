//! Run-configuration system: a TOML-subset parser (the vendored crate set
//! has no `toml`/`serde` stack) plus the typed [`RunConfig`] the CLI
//! consumes. The `[engine]` and `[serve]` sections feed the typed loaders
//! [`crate::engine::EngineBuilder::apply_config`] and
//! [`crate::serve::ServeOptions::from_config`]; duplicate keys are
//! parse errors, and unknown keys in those sections are config errors
//! that name the offending config line (see [`Config::line_of`]).
//!
//! Supported syntax: `[section]` headers — including dotted sub-tables
//! like `[serve.tier.exact]`, whose keys become `serve.tier.exact.*` —
//! `key = value` with string (`"…"`), integer, float, boolean and flat
//! array values, and `#` comments. That covers every config this project
//! ships; array-of-table headers (`[[x]]`) are rejected with a clear
//! error.

use std::collections::BTreeMap;

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// `section.key → value` map.
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub values: BTreeMap<String, Value>,
    /// The config-file line each key was defined on (for loader errors
    /// that point back at the offending line, like the parser's own
    /// duplicate-key errors).
    lines: BTreeMap<String, usize>,
    /// Every `[section]` header seen (name → line), including empty
    /// sections — so a bare `[serve.governor]` header is observable even
    /// though it contributes no keys.
    sections: BTreeMap<String, usize>,
}

/// Parse error with line number.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

fn parse_scalar(tok: &str, line: usize) -> Result<Value, ParseError> {
    let t = tok.trim();
    if let Some(stripped) = t.strip_prefix('"') {
        let inner = stripped.strip_suffix('"').ok_or(ParseError {
            line,
            message: format!("unterminated string: {t}"),
        })?;
        return Ok(Value::Str(inner.to_string()));
    }
    match t {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = t.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = t.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(ParseError {
        line,
        message: format!("cannot parse value: {t}"),
    })
}

/// Parse TOML-subset text.
pub fn parse(text: &str) -> Result<Config, ParseError> {
    let mut cfg = Config::default();
    let mut section = String::new();
    for (i, raw) in text.lines().enumerate() {
        let line_no = i + 1;
        let line = match raw.find('#') {
            // Only strip comments outside strings (strings in our configs
            // never contain '#'; keep the parser simple and strict).
            Some(pos) if !raw[..pos].contains('"') || raw[..pos].matches('"').count() % 2 == 0 => {
                &raw[..pos]
            }
            _ => raw,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let name = inner.strip_suffix(']').ok_or(ParseError {
                line: line_no,
                message: "unterminated section header".into(),
            })?;
            if name.contains('[') || name.contains(']') {
                return Err(ParseError {
                    line: line_no,
                    message: format!("array-of-table headers not supported: [{name}]"),
                });
            }
            // Dotted sub-tables ([serve.tier.exact]) are allowed; their
            // keys land under the full dotted prefix. Every path segment
            // must be non-empty.
            let name = name.trim();
            if name.is_empty() || name.split('.').any(|seg| seg.trim().is_empty()) {
                return Err(ParseError {
                    line: line_no,
                    message: format!("empty section name segment: [{name}]"),
                });
            }
            section = name.to_string();
            cfg.sections.entry(section.clone()).or_insert(line_no);
            continue;
        }
        let (key, val) = line.split_once('=').ok_or(ParseError {
            line: line_no,
            message: format!("expected key = value: {line}"),
        })?;
        let key = key.trim();
        let val = val.trim();
        let parsed = if let Some(stripped) = val.strip_prefix('[') {
            let inner = stripped.strip_suffix(']').ok_or(ParseError {
                line: line_no,
                message: "unterminated array (arrays must be single-line)".into(),
            })?;
            let items: Result<Vec<Value>, ParseError> = inner
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| parse_scalar(s, line_no))
                .collect();
            Value::Array(items?)
        } else {
            parse_scalar(val, line_no)?
        };
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        if cfg.values.insert(full_key.clone(), parsed).is_some() {
            // Silent last-write-wins hides typos and merge accidents;
            // duplicates are a hard parse error with the offending line.
            return Err(ParseError {
                line: line_no,
                message: format!("duplicate key '{full_key}'"),
            });
        }
        cfg.lines.insert(full_key, line_no);
    }
    Ok(cfg)
}

impl Config {
    pub fn from_file(path: &std::path::Path) -> Result<Self, Box<dyn std::error::Error>> {
        let text = std::fs::read_to_string(path)?;
        Ok(parse(&text)?)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    /// The config-file line `key` was defined on (`None` for keys that
    /// were never parsed from text — e.g. a hand-built `Config`). Section
    /// loaders use this so an unknown-key error names the offending line,
    /// matching the parser's own duplicate-key diagnostics.
    pub fn line_of(&self, key: &str) -> Option<usize> {
        self.lines.get(key).copied()
    }

    /// Whether a `[name]` section header appeared, even with no keys
    /// under it (e.g. a bare `[serve.governor]` enabling the governor
    /// with all defaults).
    pub fn has_section(&self, name: &str) -> bool {
        self.sections.contains_key(name)
    }

    /// Iterate `(suffix, first line)` over every section header starting
    /// with `prefix` (e.g. `sections_with_prefix("serve.")` yields
    /// `("tier.exact", 12)` for `[serve.tier.exact]`). Lets loaders
    /// reject typoed sub-section names and see empty sections.
    pub fn sections_with_prefix<'a>(
        &'a self,
        prefix: &'a str,
    ) -> impl Iterator<Item = (&'a str, usize)> {
        self.sections
            .iter()
            .filter_map(move |(s, &line)| s.strip_prefix(prefix).map(|rest| (rest, line)))
    }

    /// Iterate `(suffix, value)` over every key starting with `prefix`
    /// (e.g. `keys_with_prefix("engine.")` yields `("g", …)` for
    /// `engine.g`). Section loaders use this to reject unknown keys
    /// instead of silently defaulting on typos.
    pub fn keys_with_prefix<'a>(
        &'a self,
        prefix: &'a str,
    ) -> impl Iterator<Item = (&'a str, &'a Value)> {
        self.values
            .iter()
            .filter_map(move |(k, v)| k.strip_prefix(prefix).map(|s| (s, v)))
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }
}

/// Typed run configuration shared by the CLI and the serving layer.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// `aXwY`.
    pub precision: crate::arch::Precision,
    /// Two-level GAV parameter.
    pub g: u32,
    /// Artifacts directory (weights, caltables, HLO).
    pub artifacts_dir: std::path::PathBuf,
    /// ResNet width multiplier (must match training).
    pub width_mult: f64,
    /// Evaluation subset size (0 = all).
    pub n_eval: usize,
    /// Serving-layer batch size.
    pub batch: usize,
    /// Intra-batch worker threads for the serving layer (`serve`
    /// subcommand; `0` = one per available core, `1` = serial). The
    /// GEMM benches take their own `--threads` flag.
    pub threads: usize,
    /// Deterministic seed.
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            precision: crate::arch::Precision::new(4, 4),
            g: 0,
            artifacts_dir: "artifacts".into(),
            width_mult: 0.25,
            n_eval: 128,
            batch: 16,
            threads: 1,
            seed: 2025,
        }
    }
}

impl RunConfig {
    /// Load from a parsed config. The canonical section for
    /// model/accelerator knobs is `[engine]` (the same surface
    /// [`crate::engine::EngineBuilder::apply_config`] consumes); legacy
    /// `[run]` keys are honored as a fallback so existing configs keep
    /// working. CLI-only keys (`artifacts_dir`, `n_eval`, `batch`) live
    /// under `[run]`.
    pub fn from_config(cfg: &Config) -> Self {
        let d = Self::default();
        // `engine.key` wins over legacy `run.key`.
        let pick = |key: &str| {
            cfg.get(&format!("engine.{key}"))
                .or_else(|| cfg.get(&format!("run.{key}")))
        };
        let precision = pick("precision")
            .and_then(Value::as_str)
            .and_then(crate::arch::Precision::parse)
            .unwrap_or(d.precision);
        Self {
            precision,
            g: pick("g").and_then(Value::as_int).unwrap_or(d.g as i64).max(0) as u32,
            artifacts_dir: cfg.str_or("run.artifacts_dir", "artifacts").into(),
            width_mult: pick("width_mult")
                .and_then(Value::as_float)
                .unwrap_or(d.width_mult),
            n_eval: cfg.int_or("run.n_eval", d.n_eval as i64).max(0) as usize,
            batch: cfg.int_or("run.batch", d.batch as i64).max(1) as usize,
            // Negative = invalid -> serial (1); explicit 0 stays "auto".
            threads: pick("threads")
                .and_then(Value::as_int)
                .unwrap_or(d.threads as i64)
                .try_into()
                .unwrap_or(1),
            seed: pick("seed").and_then(Value::as_int).unwrap_or(d.seed as i64) as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# GAVINA run config
[run]
precision = "a4w4"   # paper reference point
g = 3
artifacts_dir = "artifacts"
width_mult = 0.25
n_eval = 64
batch = 8
threads = 2
seed = 7

[sweep]
g_values = [0, 2, 4, 6]
voltages = [0.35, 0.45]
enabled = true
"#;

    #[test]
    fn parses_sample() {
        let cfg = parse(SAMPLE).unwrap();
        assert_eq!(cfg.str_or("run.precision", ""), "a4w4");
        assert_eq!(cfg.int_or("run.g", -1), 3);
        assert_eq!(cfg.float_or("run.width_mult", 0.0), 0.25);
        assert!(cfg.bool_or("sweep.enabled", false));
        match cfg.get("sweep.g_values").unwrap() {
            Value::Array(xs) => {
                assert_eq!(xs.len(), 4);
                assert_eq!(xs[2].as_int(), Some(4));
            }
            other => panic!("expected array, got {other:?}"),
        }
        match cfg.get("sweep.voltages").unwrap() {
            Value::Array(xs) => assert_eq!(xs[0].as_float(), Some(0.35)),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn run_config_from_sample() {
        let cfg = parse(SAMPLE).unwrap();
        let rc = RunConfig::from_config(&cfg);
        assert_eq!(rc.precision, crate::arch::Precision::new(4, 4));
        assert_eq!(rc.g, 3);
        assert_eq!(rc.n_eval, 64);
        assert_eq!(rc.threads, 2);
        assert_eq!(rc.seed, 7);
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let rc = RunConfig::from_config(&parse("[run]\ng = 1\n").unwrap());
        assert_eq!(rc.g, 1);
        assert_eq!(rc.width_mult, 0.25);
        assert_eq!(rc.batch, 16);
        assert_eq!(rc.threads, 1);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse("[run]\nbad line without equals\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err = parse("[run\n").unwrap_err();
        assert_eq!(err.line, 1);
        let err = parse("[[a]]\n").unwrap_err();
        assert!(err.message.contains("array-of-table"));
        let err = parse("[a..b]\n").unwrap_err();
        assert!(err.message.contains("empty section name"));
    }

    #[test]
    fn dotted_sections_become_dotted_key_prefixes() {
        let cfg = parse(
            "[serve]\nworkers = 2\n[serve.tier.exact]\npolicy = \"exact\"\nmax_batch = 1\n",
        )
        .unwrap();
        assert_eq!(cfg.int_or("serve.workers", 0), 2);
        assert_eq!(cfg.str_or("serve.tier.exact.policy", ""), "exact");
        assert_eq!(cfg.int_or("serve.tier.exact.max_batch", 0), 1);
        // Duplicates across a re-opened dotted section are still errors.
        let err =
            parse("[serve.tier.a]\ng = 1\n[serve.tier.a]\ng = 2\n").unwrap_err();
        assert_eq!(err.line, 4);
    }

    #[test]
    fn line_of_tracks_key_definitions() {
        let cfg = parse("[serve]\nworkers = 2\n\n[serve.governor]\nperiod_ms = 50\n").unwrap();
        assert_eq!(cfg.line_of("serve.workers"), Some(2));
        assert_eq!(cfg.line_of("serve.governor.period_ms"), Some(5));
        assert_eq!(cfg.line_of("serve.nope"), None);
        assert_eq!(Config::default().line_of("x"), None);
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let cfg = parse("# top\n\nx = 1 # trailing\n").unwrap();
        assert_eq!(cfg.int_or("x", 0), 1);
    }

    #[test]
    fn duplicate_keys_are_line_numbered_errors() {
        // Same key twice in one section: the old parser silently kept the
        // last write; now it is a hard error naming the line.
        let err = parse("[run]\ng = 1\nseed = 2\ng = 3\n").unwrap_err();
        assert_eq!(err.line, 4);
        assert!(err.message.contains("duplicate key 'run.g'"), "{}", err.message);
        // Same key reached through a re-opened section header.
        let err = parse("[a]\nx = 1\n[b]\ny = 2\n[a]\nx = 9\n").unwrap_err();
        assert_eq!(err.line, 6);
        // Same bare key outside any section.
        let err = parse("x = 1\nx = 2\n").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(err.message.contains("duplicate key 'x'"));
    }

    #[test]
    fn keys_with_prefix_strips_and_filters() {
        let cfg = parse(SAMPLE).unwrap();
        let sweep: Vec<&str> = cfg.keys_with_prefix("sweep.").map(|(k, _)| k).collect();
        assert_eq!(sweep, vec!["enabled", "g_values", "voltages"]);
        let none: Vec<_> = cfg.keys_with_prefix("nosuch.").collect();
        assert!(none.is_empty());
        // Values come through with the suffix key.
        let (k, v) = cfg
            .keys_with_prefix("sweep.")
            .find(|(k, _)| *k == "enabled")
            .unwrap();
        assert_eq!(k, "enabled");
        assert_eq!(v.as_bool(), Some(true));
    }

    #[test]
    fn engine_section_overrides_legacy_run_keys() {
        let cfg = parse("[run]\ng = 1\nseed = 2\n[engine]\ng = 5\nthreads = 4\n").unwrap();
        let rc = RunConfig::from_config(&cfg);
        assert_eq!(rc.g, 5); // engine.* wins
        assert_eq!(rc.seed, 2); // run.* fallback still honored
        assert_eq!(rc.threads, 4);
    }
}
