//! Analytical power/energy model of GAVINA, calibrated against the paper's
//! post-layout numbers (Table I, Fig. 4b, Fig. 6b) — the substitution for
//! the Cadence power reports (DESIGN.md §Substitutions).
//!
//! ## Structure
//!
//! Per-module `P = α·C_eff·V²·f` dynamic power plus a voltage-dependent
//! leakage term, over the three power domains of §III:
//!
//! * **approximate region** (Parallel Array + input registers) at
//!   `V_guard`/`V_aprox` under GAV control — dynamic part scales with V²,
//!   leakage with the subthreshold factor; at `V_aprox = 0.35 V` the
//!   combined region power drops ×≈3.4 (paper Fig. 6b: up to ×3.5).
//! * **memory region** (A0/B0/A1/B1/P SCMs) at a constant `V_mem = 0.40 V`
//!   (no timing violations). A0/B0 stream one plane pair per cycle; the
//!   A1/B1/P + L1-accumulator traffic bursts once per tile, i.e. its
//!   average power scales with `1/(a_bits·b_bits)` — this is what makes
//!   low precisions draw slightly *more* total power (Table I/II).
//! * **protected region** (controller, sync, L0 accumulator) at `V_prot`.
//!
//! ## Calibration
//!
//! Constants are solved from the paper's own anchor points: 38.67 mW total
//! at a2w2/V_guard, 19.86 mW at the most aggressive a2w2 configuration
//! (×1.95 system boost), 31.2 mW at a8w8/V_guard (Table II: 0.111 TOP/s at
//! 3.56 TOP/sW), leakage fraction set so the approximate-region ratio hits
//! ×≈3.45. The model then *predicts* all other points (a4w4/a3w3 totals,
//! Fig. 4b breakdown shares, Fig. 6b trajectories, Table II TOP/sW
//! ranges); EXPERIMENTS.md records predicted vs paper.

use crate::arch::{ArchConfig, GavSchedule, Precision};

/// Subthreshold slope for the leakage model: one decade per this many
/// volts (12 nm-class with DIBL).
const LEAK_DECADE_V: f64 = 0.20;

/// Per-module power breakdown in mW (the Fig. 4b bars).
#[derive(Clone, Copy, Debug, Default)]
pub struct PowerBreakdown {
    /// Parallel Array + input registers (the approximate region).
    pub array_mw: f64,
    /// A0/B0 plane-streaming memories.
    pub a0b0_mw: f64,
    /// A1/B1/P memories + L1 accumulator (per-tile burst traffic).
    pub tile_mw: f64,
    /// Controller + synchronizers + L0 accumulator.
    pub ctrl_mw: f64,
}

impl PowerBreakdown {
    pub fn total_mw(&self) -> f64 {
        self.array_mw + self.a0b0_mw + self.tile_mw + self.ctrl_mw
    }
}

/// Calibrated GAVINA power model.
#[derive(Clone, Debug)]
pub struct PowerModel {
    pub arch: ArchConfig,
    /// Approximate-region power at `V_guard`, activity 1.0 [mW].
    pub array_ref_mw: f64,
    /// Fraction of `array_ref_mw` that is leakage (at `V_guard`).
    pub array_leak_frac: f64,
    /// A0/B0 streaming power at `V_mem` [mW].
    pub a0b0_mw: f64,
    /// Tile-burst power at tile rate 1 (one tile per cycle) [mW].
    pub tile_burst_mw: f64,
    /// Controller + sync + L0 power [mW].
    pub ctrl_mw: f64,
    /// Relative switching activity of the Parallel Array (1.0 = the
    /// §IV-B random-matrix workload; GLS measurements can override).
    pub activity: f64,
}

impl PowerModel {
    /// The paper-calibrated model (see module docs for the anchors).
    pub fn paper_calibrated() -> Self {
        let arch = ArchConfig::paper();
        // Solve the two a2w2 anchors: array·r + rest = 19.86,
        // array + rest = 38.67, with r the V_aprox region ratio.
        let model_tmp = Self {
            arch: arch.clone(),
            array_ref_mw: 1.0,
            array_leak_frac: 1.0 / 3.0,
            a0b0_mw: 0.0,
            tile_burst_mw: 0.0,
            ctrl_mw: 0.0,
            activity: 1.0,
        };
        let r = model_tmp.array_scale(arch.v_aprox); // ≈ 0.29
        let total_g = 38.67;
        let total_a = 19.86;
        let array = (total_g - total_a) / (1.0 - r);
        let rest = total_g - array;
        // Split `rest` using the a8w8 anchor (31.2 mW total): the
        // tile-rate component explains the precision dependence.
        // rest = a0b0 + ctrl + q/4 (a2w2); a8w8: array + a0b0 + ctrl +
        // q/64 = 31.2.
        let q = (total_g - 31.2) / (1.0 / 4.0 - 1.0 / 64.0);
        let a0b0_plus_ctrl = rest - q / 4.0;
        // A0/B0 streams dominate the static share ~2:1 over control.
        let a0b0 = a0b0_plus_ctrl * 2.0 / 3.0;
        let ctrl = a0b0_plus_ctrl / 3.0;
        Self {
            arch,
            array_ref_mw: array,
            array_leak_frac: 1.0 / 3.0,
            a0b0_mw: a0b0,
            tile_burst_mw: q,
            ctrl_mw: ctrl,
            activity: 1.0,
        }
    }

    /// Override the array switching activity (e.g. from GLS switched-cap
    /// measurements of a real workload, relative to the calibration
    /// workload).
    pub fn with_activity(mut self, activity: f64) -> Self {
        self.activity = activity;
        self
    }

    /// SCM → SRAM ablation (paper §IV-A: *"using SCMs instead of SRAMs
    /// results in a power reduction of about ×4"*): what the system would
    /// look like with SRAM memories instead of standard-cell memories.
    pub fn with_sram_memories(mut self) -> Self {
        self.a0b0_mw *= 4.0;
        self.tile_burst_mw *= 4.0;
        self
    }

    /// Time-averaged approximate-region power of a schedule with explicit
    /// per-level voltages (the multi-level GAV extension): `voltages[i]`
    /// is the supply of `VoltageMode::Level(i)`.
    pub fn array_avg_power_multi(
        &self,
        sched: &crate::arch::GavSchedule,
        voltages: &[f64],
    ) -> f64 {
        use crate::arch::VoltageMode;
        let steps = sched.precision().steps();
        let mut total = 0.0;
        for t in 0..steps {
            let v = match sched.mode(t) {
                VoltageMode::Guarded => self.arch.v_guard,
                VoltageMode::Approximate => self.arch.v_aprox,
                VoltageMode::Level(i) => voltages[i as usize],
            };
            total += self.array_power_mw(v);
        }
        total / steps as f64
    }

    /// Leakage scale factor at supply `v` relative to `V_guard`
    /// (subthreshold current decade + linear V).
    pub fn leak_scale(&self, v: f64) -> f64 {
        let vg = self.arch.v_guard;
        10f64.powf((v - vg) / LEAK_DECADE_V) * (v / vg)
    }

    /// Approximate-region power scale at supply `v` relative to `V_guard`
    /// (dynamic V² + leakage), activity held constant.
    pub fn array_scale(&self, v: f64) -> f64 {
        let vg = self.arch.v_guard;
        let dyn_part = (1.0 - self.array_leak_frac) * (v / vg).powi(2);
        let leak_part = self.array_leak_frac * self.leak_scale(v);
        dyn_part + leak_part
    }

    /// Approximate-region power [mW] while computing at supply `v`.
    pub fn array_power_mw(&self, v: f64) -> f64 {
        // Activity scales only the dynamic part.
        let vg = self.arch.v_guard;
        let dyn_mw = self.array_ref_mw * (1.0 - self.array_leak_frac) * self.activity
            * (v / vg).powi(2);
        let leak_mw = self.array_ref_mw * self.array_leak_frac * self.leak_scale(v);
        dyn_mw + leak_mw
    }

    /// Time-averaged approximate-region power under a GAV schedule [mW]
    /// (the Fig. 6b x-axis).
    pub fn array_avg_power_mw(&self, sched: &GavSchedule) -> f64 {
        let f = sched.approx_fraction();
        f * self.array_power_mw(self.arch.v_aprox) + (1.0 - f) * self.array_power_mw(self.arch.v_guard)
    }

    /// Full-system breakdown for a precision + schedule (Fig. 4b uses the
    /// all-guarded schedule).
    pub fn system_breakdown(&self, sched: &GavSchedule) -> PowerBreakdown {
        let prec = sched.precision();
        PowerBreakdown {
            array_mw: self.array_avg_power_mw(sched),
            a0b0_mw: self.a0b0_mw,
            tile_mw: self.tile_burst_mw / prec.steps() as f64,
            ctrl_mw: self.ctrl_mw,
        }
    }

    /// Total system power [mW].
    pub fn system_power_mw(&self, sched: &GavSchedule) -> f64 {
        self.system_breakdown(sched).total_mw()
    }

    /// Energy efficiency in TOP/sW at the given utilization (Table II).
    pub fn tops_per_watt(&self, sched: &GavSchedule, utilization: f64) -> f64 {
        let prec = sched.precision();
        let tops = self.arch.peak_tops(prec) * utilization;
        tops / (self.system_power_mw(sched) * 1e-3)
    }

    /// The undervolting energy-efficiency boost: all-approx vs all-guarded
    /// at the same precision (throughput unchanged — §III).
    pub fn undervolting_boost(&self, prec: Precision) -> f64 {
        self.system_power_mw(&GavSchedule::all_guarded(prec))
            / self.system_power_mw(&GavSchedule::all_approx(prec))
    }

    /// Energy for a run of `cycles` at average power [mJ].
    pub fn energy_mj(&self, sched: &GavSchedule, cycles: u64) -> f64 {
        self.system_power_mw(sched) * 1e-3 * (cycles as f64 / self.arch.freq_hz) * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> PowerModel {
        PowerModel::paper_calibrated()
    }

    #[test]
    fn table1_anchor_points() {
        let m = model();
        let p22 = Precision::new(2, 2);
        let guarded = m.system_power_mw(&GavSchedule::all_guarded(p22));
        let aggressive = m.system_power_mw(&GavSchedule::all_approx(p22));
        assert!((guarded - 38.67).abs() < 0.05, "a2w2 guarded {guarded}");
        assert!((aggressive - 19.86).abs() < 0.05, "a2w2 aggressive {aggressive}");
    }

    #[test]
    fn system_boost_matches_paper() {
        let m = model();
        let boost = m.undervolting_boost(Precision::new(2, 2));
        assert!((boost - 1.95).abs() < 0.02, "×{boost:.3} system boost");
    }

    #[test]
    fn array_reduction_near_3_5x() {
        let m = model();
        let ratio = m.array_power_mw(0.55) / m.array_power_mw(0.35);
        assert!(
            (3.1..3.8).contains(&ratio),
            "approximate-region reduction ×{ratio:.2} (paper: up to ×3.5)"
        );
    }

    #[test]
    fn a8w8_anchor() {
        let m = model();
        let p = m.system_power_mw(&GavSchedule::all_guarded(Precision::new(8, 8)));
        assert!((p - 31.2).abs() < 0.3, "a8w8 guarded {p}");
    }

    #[test]
    fn table2_efficiency_ranges() {
        // Paper Table II: a2w2 45.87 – 89.32 TOP/sW; a8w8 3.56 – 6.52.
        let m = model();
        let util = 0.96;
        let p22 = Precision::new(2, 2);
        let lo = m.tops_per_watt(&GavSchedule::all_guarded(p22), util);
        let hi = m.tops_per_watt(&GavSchedule::all_approx(p22), util);
        assert!((lo - 45.87).abs() < 2.0, "a2w2 guarded {lo:.2} TOP/sW");
        assert!((hi - 89.32).abs() < 4.0, "a2w2 aggressive {hi:.2} TOP/sW");
        let p88 = Precision::new(8, 8);
        let lo8 = m.tops_per_watt(&GavSchedule::all_guarded(p88), util);
        assert!((lo8 - 3.56).abs() < 0.3, "a8w8 guarded {lo8:.2}");
    }

    #[test]
    fn precision_scaling_energy_boost() {
        // "from its highest precision (8-bit) to the lowest (2-bit),
        // GAVINA gets a ×18 energy efficiency boost" (§V) — guarded a8w8
        // to most-aggressive a2w2 spans ×12–25 in this model.
        let m = model();
        let util = 0.96;
        let lo = m.tops_per_watt(&GavSchedule::all_guarded(Precision::new(8, 8)), util);
        let hi = m.tops_per_watt(&GavSchedule::all_approx(Precision::new(2, 2)), util);
        let x = hi / lo;
        assert!((12.0..30.0).contains(&x), "8b→2b total boost ×{x:.1}");
    }

    #[test]
    fn fig6b_power_monotone_in_g() {
        // More guarding -> more array power, monotonically.
        let m = model();
        let prec = Precision::new(4, 4);
        let mut last = -1.0;
        for g in 0..=prec.max_g() {
            let p = m.array_avg_power_mw(&GavSchedule::two_level(prec, g));
            assert!(p >= last, "array power must grow with G (g={g}: {p} < {last})");
            last = p;
        }
    }

    #[test]
    fn fig4b_memories_dominate_after_undervolt() {
        // Paper: "other elements in the system (especially the memories)
        // end up dominating when the main compute power is reduced".
        let m = model();
        let bd = m.system_breakdown(&GavSchedule::all_approx(Precision::new(2, 2)));
        let mem = bd.a0b0_mw + bd.tile_mw;
        assert!(
            mem > bd.array_mw,
            "memories {mem:.2} mW must dominate array {:.2} mW",
            bd.array_mw
        );
        // Whereas fully guarded the array dominates.
        let bd_g = m.system_breakdown(&GavSchedule::all_guarded(Precision::new(2, 2)));
        assert!(bd_g.array_mw > bd_g.a0b0_mw + bd_g.tile_mw);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let m = model();
        for prec in Precision::EVAL_SET {
            let s = GavSchedule::two_level(prec, 1);
            let bd = m.system_breakdown(&s);
            assert!((bd.total_mw() - m.system_power_mw(&s)).abs() < 1e-12);
        }
    }

    #[test]
    fn energy_consistent_with_power() {
        let m = model();
        let s = GavSchedule::all_guarded(Precision::new(4, 4));
        // 50e6 cycles at 50 MHz = 1 s -> energy mJ == power mW.
        let e = m.energy_mj(&s, 50_000_000);
        assert!((e - m.system_power_mw(&s)).abs() < 1e-9);
    }
}
