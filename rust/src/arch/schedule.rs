//! The GAV voltage schedule (paper §II, Fig. 2).
//!
//! GAV modulates the approximate-region supply per bit-serial step. The
//! paper's evaluated policy uses two levels — the *guarded* voltage
//! `V_guard` and the *approximate* voltage `V_aprox` — selected by a single
//! integer `G`: a step computing partial-product significance
//! `s = ba + bb` runs guarded iff `s > s_max − G` (the `G` most significant
//! significance values are protected), and undervolted otherwise.
//!
//! `G = 0` undervolts every step; `G = s_max + 1` guards everything.
//!
//! [`GavSchedule`] also supports the generalised multi-level policy the
//! paper mentions ("can be extended to any number of discrete voltage
//! levels"): an arbitrary map from significance to voltage mode.

use super::Precision;

/// Which supply the DVS module drives onto the approximate region during
/// one bit-serial step.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum VoltageMode {
    /// `V_guard`: timing met, exact computation.
    Guarded,
    /// `V_aprox`: aggressive undervolting, timing violations allowed.
    Approximate,
    /// An extension level (index into a user voltage table); used by the
    /// multi-level policy ablation, never by the paper's two-level runs.
    Level(u8),
}

/// A per-step voltage schedule for one `(a_bits, b_bits)` GEMM.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GavSchedule {
    precision: Precision,
    /// One mode per (bb outer, ba inner) step.
    modes: Vec<VoltageMode>,
    /// The G value that generated this schedule (None for custom policies).
    g: Option<u32>,
}

impl GavSchedule {
    /// The paper's two-level policy for a given `G` (Fig. 2).
    ///
    /// Panics if `G > s_max + 1`.
    pub fn two_level(precision: Precision, g: u32) -> Self {
        assert!(
            g <= precision.max_g(),
            "G={g} out of range for {precision} (max {})",
            precision.max_g()
        );
        let s_max = precision.s_max();
        let modes = precision
            .step_order()
            .map(|(ba, bb)| {
                let s = ba as u32 + bb as u32;
                // Guard iff s > s_max - G  <=>  s + G > s_max.
                if s + g > s_max {
                    VoltageMode::Guarded
                } else {
                    VoltageMode::Approximate
                }
            })
            .collect();
        Self {
            precision,
            modes,
            g: Some(g),
        }
    }

    /// Fully guarded operation (no undervolting) — the exact baseline.
    pub fn all_guarded(precision: Precision) -> Self {
        Self::two_level(precision, precision.max_g())
    }

    /// Fully undervolted operation (most aggressive configuration).
    pub fn all_approx(precision: Precision) -> Self {
        Self::two_level(precision, 0)
    }

    /// A custom policy from a significance → mode function (multi-level
    /// extension).
    pub fn custom(precision: Precision, f: impl Fn(u32) -> VoltageMode) -> Self {
        let modes = precision
            .step_order()
            .map(|(ba, bb)| f(ba as u32 + bb as u32))
            .collect();
        Self {
            precision,
            modes,
            g: None,
        }
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// The layer-unweighted mean of a per-layer G vector — the single
    /// definition shared by [`GavSchedule::representative`], the serving
    /// governor's ladder, and energy reporting, so "the schedule that
    /// best represents this allocation" can never diverge between them.
    pub fn mean_g(layer_gs: &[u32]) -> f64 {
        layer_gs.iter().map(|&g| g as f64).sum::<f64>() / layer_gs.len().max(1) as f64
    }

    /// The uniform two-level schedule that best represents a per-layer G
    /// allocation (exact when the allocation is uniform; the rounded
    /// [`GavSchedule::mean_g`] otherwise) — what energy/TOP-per-W
    /// modelling of that allocation's traffic should use.
    pub fn representative(precision: Precision, layer_gs: &[u32]) -> Self {
        let g = (Self::mean_g(layer_gs).round() as u32).min(precision.max_g());
        Self::two_level(precision, g)
    }

    /// The G value, if this schedule came from the two-level policy.
    pub fn g(&self) -> Option<u32> {
        self.g
    }

    /// Mode of step `t` in controller order.
    pub fn mode(&self, t: usize) -> VoltageMode {
        self.modes[t]
    }

    /// Per-step mask: `true` where the step is undervolted.
    pub fn approx_mask(&self) -> Vec<bool> {
        self.modes
            .iter()
            .map(|m| !matches!(m, VoltageMode::Guarded))
            .collect()
    }

    /// Number of undervolted steps.
    pub fn n_approx(&self) -> usize {
        self.approx_mask().iter().filter(|&&b| b).count()
    }

    /// Fraction of steps that run undervolted (drives the power model).
    pub fn approx_fraction(&self) -> f64 {
        self.n_approx() as f64 / self.modes.len() as f64
    }

    /// Render the schedule as the Fig. 2-style matrix (rows = bb, cols =
    /// ba; `A` approximate, `G` guarded) for the `gavina schedule` CLI.
    pub fn render(&self) -> String {
        let p = self.precision;
        let mut out = String::new();
        out.push_str("      ");
        for ba in 0..p.a_bits {
            out.push_str(&format!("ba={ba} "));
        }
        out.push('\n');
        for bb in 0..p.b_bits {
            out.push_str(&format!("bb={bb} |"));
            for ba in 0..p.a_bits {
                let t = bb as usize * p.a_bits as usize + ba as usize;
                let c = match self.modes[t] {
                    VoltageMode::Guarded => "  G  ",
                    VoltageMode::Approximate => "  A  ",
                    VoltageMode::Level(l) => return format!("L{l}"),
                };
                out.push_str(c);
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn representative_schedule_rounds_and_clamps_mean_g() {
        let p = Precision::new(2, 2); // max_g = 3
        assert_eq!(GavSchedule::mean_g(&[]), 0.0);
        assert!((GavSchedule::mean_g(&[1, 2, 3]) - 2.0).abs() < 1e-12);
        // Uniform allocations are represented exactly.
        assert_eq!(GavSchedule::representative(p, &[2; 20]).g(), Some(2));
        // Non-uniform: the rounded mean ((1·18 + 2·2)/20 = 1.1 -> 1).
        let mut gs = vec![1u32; 20];
        gs[0] = 2;
        gs[19] = 2;
        assert_eq!(GavSchedule::representative(p, &gs).g(), Some(1));
        // Means above G_max clamp instead of panicking in two_level.
        assert_eq!(GavSchedule::representative(p, &[9; 4]).g(), Some(3));
    }

    #[test]
    fn g0_all_approx_gmax_all_guarded() {
        for p in Precision::EVAL_SET {
            let s0 = GavSchedule::two_level(p, 0);
            assert_eq!(s0.n_approx(), p.steps());
            let sg = GavSchedule::two_level(p, p.max_g());
            assert_eq!(sg.n_approx(), 0);
        }
    }

    #[test]
    fn monotone_in_g() {
        // Increasing G can only guard more steps.
        let p = Precision::new(4, 4);
        let mut prev = p.steps() + 1;
        for g in 0..=p.max_g() {
            let n = GavSchedule::two_level(p, g).n_approx();
            assert!(n < prev, "n_approx must strictly decrease: g={g}");
            prev = n;
        }
    }

    #[test]
    fn guards_highest_significance_first() {
        // G=1 on a4w4 must guard exactly the (3,3) step (s=6=s_max).
        let p = Precision::new(4, 4);
        let s = GavSchedule::two_level(p, 1);
        for (t, (ba, bb)) in p.step_order().enumerate() {
            let guarded = matches!(s.mode(t), VoltageMode::Guarded);
            assert_eq!(guarded, (ba, bb) == (3, 3), "step ({ba},{bb})");
        }
    }

    #[test]
    fn matches_python_gav_schedule_semantics() {
        // python: undervolted iff (ba+bb) <= s_max - g.
        for p in [Precision::new(4, 4), Precision::new(2, 3)] {
            for g in 0..=p.max_g() {
                let mask = GavSchedule::two_level(p, g).approx_mask();
                for (t, (ba, bb)) in p.step_order().enumerate() {
                    let expect = (ba as i64 + bb as i64) <= p.s_max() as i64 - g as i64;
                    assert_eq!(mask[t], expect, "p={p} g={g} t={t}");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn g_out_of_range_panics() {
        let p = Precision::new(2, 2);
        GavSchedule::two_level(p, p.max_g() + 1);
    }

    #[test]
    fn approx_fraction_bounds() {
        let p = Precision::new(3, 3);
        for g in 0..=p.max_g() {
            let f = GavSchedule::two_level(p, g).approx_fraction();
            assert!((0.0..=1.0).contains(&f));
        }
    }

    #[test]
    fn render_contains_grid() {
        let s = GavSchedule::two_level(Precision::new(2, 2), 1);
        let r = s.render();
        assert!(r.contains("ba=0") && r.contains("bb=1"));
        assert!(r.contains('A') && r.contains('G'));
    }
}
