//! Architectural parameters of the GAVINA accelerator (paper §III, §IV-A)
//! and the GAV voltage schedule (paper Fig. 2).
//!
//! Everything downstream — the cycle-level simulator, the power model, the
//! GLS calibration and the DNN executor — agrees on the conventions fixed
//! here:
//!
//! * Matrices follow Listing 1: `A` is `[C, L]` (activations), `B` is
//!   `[K, C]` (weights), the product `P = B·A` is `[K, L]`.
//! * The controller schedules the bit-significance loop with `bb`
//!   (weight bit) outer and `ba` (activation bit) inner (Fig. 3 example).
//! * Two's-complement operands: the MSB plane carries negative weight, so
//!   a step's partial product is negated iff exactly one of `(ba, bb)`
//!   indexes its operand's MSB.

pub mod schedule;

pub use schedule::{GavSchedule, VoltageMode};

/// Bit precision of one GEMM (activations × weights), the paper's `aXwY`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Precision {
    /// Activation bits (2..=8 supported by GAVINA).
    pub a_bits: u8,
    /// Weight bits (2..=8).
    pub b_bits: u8,
}

impl Precision {
    pub const fn new(a_bits: u8, b_bits: u8) -> Self {
        Self { a_bits, b_bits }
    }

    /// The paper's shorthand, e.g. `a4w4`.
    pub fn tag(&self) -> String {
        format!("a{}w{}", self.a_bits, self.b_bits)
    }

    /// Parse `aXwY`.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim();
        let rest = s.strip_prefix('a')?;
        let (a, b) = rest.split_once('w')?;
        let a: u8 = a.parse().ok()?;
        let b: u8 = b.parse().ok()?;
        if (2..=8).contains(&a) && (2..=8).contains(&b) {
            Some(Self::new(a, b))
        } else {
            None
        }
    }

    /// Bit-serial steps per tile: `a_bits · b_bits` cycles (§III).
    pub fn steps(&self) -> usize {
        self.a_bits as usize * self.b_bits as usize
    }

    /// Highest partial-product significance, `s_max = a_bits + b_bits − 2`.
    pub fn s_max(&self) -> u32 {
        self.a_bits as u32 + self.b_bits as u32 - 2
    }

    /// Largest meaningful G value (everything guarded): `s_max + 1`.
    pub fn max_g(&self) -> u32 {
        self.s_max() + 1
    }

    /// All `(bb, ba)` steps in controller order (bb outer, ba inner).
    pub fn step_order(&self) -> impl Iterator<Item = (u8, u8)> + '_ {
        let (ab, bb) = (self.a_bits, self.b_bits);
        (0..bb).flat_map(move |wb| (0..ab).map(move |ab_| (ab_, wb)))
    }

    /// Sign of step `(ba, bb)` under two's complement: −1 iff exactly one
    /// of the indices is its operand's MSB.
    pub fn step_sign(&self, ba: u8, bb: u8) -> i64 {
        if (ba == self.a_bits - 1) != (bb == self.b_bits - 1) {
            -1
        } else {
            1
        }
    }

    /// Signed shift-weight of step `(ba, bb)`:
    /// [`Self::step_sign`]` · 2^(ba+bb)` — the factor the L0/L1
    /// shift-accumulate applies to that step's iPE outputs. The single
    /// definition shared by `recombine`, the reference kernels, the fused
    /// kernel's step table and the simulator's streamed accumulate.
    pub fn step_weight(&self, ba: u8, bb: u8) -> i64 {
        self.step_sign(ba, bb) << (ba as u32 + bb as u32)
    }

    /// The four precisions evaluated throughout the paper.
    pub const EVAL_SET: [Precision; 4] = [
        Precision::new(2, 2),
        Precision::new(3, 3),
        Precision::new(4, 4),
        Precision::new(8, 8),
    ];
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "a{}w{}", self.a_bits, self.b_bits)
    }
}

/// Static architecture configuration (paper Table I defaults).
#[derive(Clone, Debug)]
pub struct ArchConfig {
    /// Input-channel (reduction) dimension of the Parallel Array.
    pub c_dim: usize,
    /// Activation column dimension.
    pub l_dim: usize,
    /// Weight row (output channel) dimension.
    pub k_dim: usize,
    /// Clock frequency in Hz (Table I: 50 MHz → 20 ns period).
    pub freq_hz: f64,
    /// Guarded supply voltage of the approximate region [V].
    pub v_guard: f64,
    /// Aggressive (undervolted) supply of the approximate region [V].
    pub v_aprox: f64,
    /// Memory-region supply [V] (no timing violations allowed).
    pub v_mem: f64,
    /// Protected-region (controller/accumulator) supply [V].
    pub v_prot: f64,
}

impl Default for ArchConfig {
    fn default() -> Self {
        Self::paper()
    }
}

impl ArchConfig {
    /// The physical-design point of §IV-A / Table I:
    /// `[C, L, K] = [576, 8, 16]`, 50 MHz, 0.55 / 0.35 / 0.40 V.
    pub fn paper() -> Self {
        Self {
            c_dim: 576,
            l_dim: 8,
            k_dim: 16,
            freq_hz: 50.0e6,
            v_guard: 0.55,
            v_aprox: 0.35,
            v_mem: 0.40,
            v_prot: 0.55,
        }
    }

    /// A small configuration for fast unit tests ([C,L,K] = [36,4,4]).
    pub fn tiny() -> Self {
        Self {
            c_dim: 36,
            l_dim: 4,
            k_dim: 4,
            ..Self::paper()
        }
    }

    /// Clock period in seconds.
    pub fn clk_period_s(&self) -> f64 {
        1.0 / self.freq_hz
    }

    /// Clock period in picoseconds (the GLS time unit).
    pub fn clk_period_ps(&self) -> u64 {
        (1.0e12 / self.freq_hz).round() as u64
    }

    /// Width of one iPE output in bits: `ceil(log2(C+1))` (§III).
    pub fn sum_bits(&self) -> usize {
        crate::util::bits_for(self.c_dim as u64) as usize
    }

    /// MACs retired per tile (`L·C·K`), once every `a_bits·b_bits` cycles.
    pub fn macs_per_tile(&self) -> usize {
        self.l_dim * self.c_dim * self.k_dim
    }

    /// Peak throughput in MAC/s for a precision (§III):
    /// `L·C·K / (A_bits·B_bits)` MACs per cycle.
    pub fn peak_macs_per_s(&self, p: Precision) -> f64 {
        self.macs_per_tile() as f64 / p.steps() as f64 * self.freq_hz
    }

    /// Peak throughput in TOP/s (1 MAC = 2 OPs, the paper's convention —
    /// Table I lists 1.84 TOP/s for a2w2 at 50 MHz).
    pub fn peak_tops(&self, p: Precision) -> f64 {
        2.0 * self.peak_macs_per_s(p) / 1e12
    }

    /// Total number of iPEs in the Parallel Array (`K·L`).
    pub fn n_ipes(&self) -> usize {
        self.k_dim * self.l_dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_tags_roundtrip() {
        for p in Precision::EVAL_SET {
            assert_eq!(Precision::parse(&p.tag()), Some(p));
        }
        assert_eq!(Precision::parse("a4w2"), Some(Precision::new(4, 2)));
        assert_eq!(Precision::parse("a1w4"), None);
        assert_eq!(Precision::parse("a9w4"), None);
        assert_eq!(Precision::parse("w4a4"), None);
    }

    #[test]
    fn step_order_is_bb_outer_ba_inner() {
        let p = Precision::new(2, 3);
        let order: Vec<(u8, u8)> = p.step_order().collect();
        assert_eq!(
            order,
            vec![(0, 0), (1, 0), (0, 1), (1, 1), (0, 2), (1, 2)]
        );
        assert_eq!(order.len(), p.steps());
    }

    #[test]
    fn step_sign_twos_complement_rule() {
        let p = Precision::new(4, 4);
        assert_eq!(p.step_sign(3, 0), -1); // a MSB only
        assert_eq!(p.step_sign(0, 3), -1); // b MSB only
        assert_eq!(p.step_sign(3, 3), 1); // both MSBs: negatives cancel
        assert_eq!(p.step_sign(1, 2), 1);
        // step_weight folds the sign with the significance shift.
        assert_eq!(p.step_weight(3, 0), -8);
        assert_eq!(p.step_weight(0, 3), -8);
        assert_eq!(p.step_weight(3, 3), 64);
        assert_eq!(p.step_weight(1, 2), 8);
    }

    #[test]
    fn paper_table1_throughput() {
        let arch = ArchConfig::paper();
        // Table I: max throughput (a2w2) = 1.84 TOP/s.
        let tops = arch.peak_tops(Precision::new(2, 2));
        assert!((tops - 1.84).abs() < 0.01, "a2w2 peak = {tops}");
        // Table II: a8w8 0.111, a4w4 0.443, a3w3 0.776 TOP/s.
        assert!((arch.peak_tops(Precision::new(8, 8)) - 0.115).abs() < 0.005);
        assert!((arch.peak_tops(Precision::new(4, 4)) - 0.461).abs() < 0.02);
        assert!((arch.peak_tops(Precision::new(3, 3)) - 0.819).abs() < 0.05);
    }

    #[test]
    fn sum_bits_matches_paper() {
        assert_eq!(ArchConfig::paper().sum_bits(), 10);
        assert_eq!(ArchConfig::tiny().sum_bits(), 6);
    }
}
