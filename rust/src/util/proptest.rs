//! Minimal property-testing harness (the vendored crate set has no
//! `proptest`). Runs a property over many PRNG-generated cases and reports
//! the failing seed so a failure is reproducible by construction.
//!
//! Usage:
//! ```
//! use gavina::util::proptest::check;
//! check("add commutes", 100, |rng| {
//!     let (a, b) = (rng.int_in(-100, 100), rng.int_in(-100, 100));
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::prng::Prng;

/// Run `cases` random test cases of `prop`, panicking with the failing
/// seed if any case fails an assertion.
///
/// Under Miri, each property runs at most 2 cases: the interpreter is
/// ~100× slower than native and the CI Miri job is after UB (pointer
/// provenance, overreads), not statistical coverage — case 0 of every
/// property already walks all the `unsafe` paths.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Prng) + std::panic::RefUnwindSafe) {
    let cases = if cfg!(miri) { cases.min(2) } else { cases };
    for case in 0..cases {
        // Derive the case seed from the property name so independent
        // properties explore independent sequences.
        let seed = name
            .bytes()
            .fold(0xcbf29ce484222325u64, |h, b| {
                (h ^ b as u64).wrapping_mul(0x100000001b3)
            })
            .wrapping_add(case);
        let mut rng = Prng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at case {case} (seed {seed}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", 50, |rng| {
            let x = rng.int_in(0, 10);
            assert!((0..=10).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property 'falsum' failed")]
    fn failing_property_reports_seed() {
        check("falsum", 50, |rng| {
            assert!(rng.int_in(0, 10) > 10);
        });
    }
}
