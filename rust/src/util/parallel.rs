//! Scoped worker-pool helpers over `std::thread::scope` (the vendored
//! crate set has no `rayon`; the hot paths here are embarrassingly
//! parallel and need nothing fancier).
//!
//! Design rules, shared by every consumer:
//!
//! * **Core-count aware**: a request of `0` threads resolves to
//!   [`std::thread::available_parallelism`].
//! * **Deterministic reduction order**: work is split into *contiguous*
//!   chunks in input order and results are joined in spawn order, so the
//!   output of a parallel run is byte-identical to the serial run — the
//!   property the bit-exactness tests in [`crate::gemm`] pin down.
//! * **No shared mutable state**: workers either return owned results
//!   ([`parallel_map`]) or own disjoint `&mut` spans of the output buffer
//!   ([`parallel_spans_mut`]).

use std::num::NonZeroUsize;

/// Resolve a thread-count request: `0` means one worker per available
/// core, anything else is taken literally.
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism()
            .map(NonZeroUsize::get)
            .unwrap_or(1)
    }
}

/// Map `f(index, item)` over `items` on up to `threads` scoped workers.
///
/// Items are split into contiguous chunks (one per worker) and results are
/// concatenated in input order, so the output equals the serial
/// `items.iter().enumerate().map(f)` exactly. Panics in `f` propagate.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = resolve_threads(threads).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = items.len().div_ceil(threads);
    let mut out = Vec::with_capacity(items.len());
    std::thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks(chunk)
            .enumerate()
            .map(|(ci, slab)| {
                let f = &f;
                s.spawn(move || {
                    slab.iter()
                        .enumerate()
                        .map(|(i, t)| f(ci * chunk + i, t))
                        .collect::<Vec<R>>()
                })
            })
            .collect();
        for h in handles {
            out.extend(h.join().expect("parallel_map worker panicked"));
        }
    });
    out
}

/// Split `data` into at most `threads` contiguous spans whose lengths are
/// multiples of `align` and run `f(span_start, span)` on scoped workers.
///
/// `align` is the row stride: spans never split a row, so a worker that
/// owns `span` owns output rows `span_start / align ..` exclusively. The
/// partition depends only on `(data.len(), align, threads)` — determinism
/// comes from each element being written by exactly one worker with the
/// same values as the serial code would produce.
///
/// Panics if `data.len()` is not a multiple of `align`.
pub fn parallel_spans_mut<T, F>(data: &mut [T], align: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    assert!(align > 0, "align must be positive");
    assert_eq!(
        data.len() % align,
        0,
        "data length {} not a multiple of align {align}",
        data.len()
    );
    let n_units = data.len() / align;
    let threads = resolve_threads(threads).min(n_units);
    if threads <= 1 {
        f(0, data);
        return;
    }
    let span_units = n_units.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = data;
        let mut start = 0usize;
        while !rest.is_empty() {
            let take = (span_units * align).min(rest.len());
            let (span, tail) = std::mem::take(&mut rest).split_at_mut(take);
            let f = &f;
            let begin = start;
            s.spawn(move || f(begin, span));
            start += take;
            rest = tail;
        }
    });
}

/// Run `f(row_index, row)` on every `align`-length row of `data`, with
/// rows partitioned over at most `threads` scoped workers.
///
/// A per-row convenience over [`parallel_spans_mut`] for consumers that
/// think in rows rather than spans — the fused activation prologue packs
/// one im2col column per row this way. Inherits the parent's guarantees:
/// contiguous row ranges per worker, every row visited exactly once, and
/// a partition that depends only on `(data.len(), align, threads)`.
///
/// Panics if `data.len()` is not a multiple of `align`.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], align: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    parallel_spans_mut(data, align, threads, |start, span| {
        for (i, row) in span.chunks_exact_mut(align).enumerate() {
            f(start / align + i, row);
        }
    });
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicUsize, Ordering};

    use super::*;
    use crate::util::Prng;

    #[test]
    fn resolve_threads_auto_and_literal() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(1), 1);
    }

    #[test]
    fn map_empty_input() {
        let out: Vec<u64> = parallel_map(&[] as &[u64], 4, |_, &x| x * 2);
        assert!(out.is_empty());
    }

    #[test]
    fn map_single_item() {
        let out = parallel_map(&[21u64], 8, |i, &x| x * 2 + i as u64);
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn map_fewer_items_than_threads() {
        let items = [1u64, 2, 3];
        let out = parallel_map(&items, 16, |i, &x| (i, x * x));
        assert_eq!(out, vec![(0, 1), (1, 4), (2, 9)]);
    }

    #[test]
    fn map_matches_serial_deterministically() {
        let mut rng = Prng::new(0x9A9);
        let items: Vec<i64> = (0..257).map(|_| rng.int_in(-1000, 1000)).collect();
        let serial: Vec<i64> = items
            .iter()
            .enumerate()
            .map(|(i, &x)| x * 3 - i as i64)
            .collect();
        for threads in [1, 2, 3, 4, 7, 64] {
            let par = parallel_map(&items, threads, |i, &x| x * 3 - i as i64);
            assert_eq!(par, serial, "threads={threads}");
        }
        // Two identical runs agree bit-for-bit (deterministic order).
        let a = parallel_map(&items, 4, |i, &x| x.wrapping_mul(i as i64));
        let b = parallel_map(&items, 4, |i, &x| x.wrapping_mul(i as i64));
        assert_eq!(a, b);
    }

    #[test]
    fn spans_cover_disjointly_and_match_serial() {
        // Each worker writes start+offset into its span; the result must
        // equal the serial fill regardless of thread count.
        let n_rows = 37;
        let align = 5;
        let expect: Vec<usize> = (0..n_rows * align).collect();
        for threads in [1, 2, 3, 8, 64] {
            let mut data = vec![0usize; n_rows * align];
            parallel_spans_mut(&mut data, align, threads, |start, span| {
                for (i, v) in span.iter_mut().enumerate() {
                    *v = start + i;
                }
            });
            assert_eq!(data, expect, "threads={threads}");
        }
    }

    #[test]
    fn spans_empty_and_single_row() {
        let mut empty: Vec<u32> = Vec::new();
        parallel_spans_mut(&mut empty, 4, 8, |_, _| panic!("must not run"));
        let mut one = vec![0u32; 6];
        parallel_spans_mut(&mut one, 6, 8, |start, span| {
            assert_eq!(start, 0);
            span.fill(7);
        });
        assert_eq!(one, vec![7; 6]);
    }

    #[test]
    fn map_order_is_deterministic_under_jittered_interleavings() {
        // Loom-style interleaving stress (this also runs under the CI
        // ThreadSanitizer job): a spin delay keyed off the item value and
        // the round makes workers finish in a different real-time order
        // every run, yet the join-in-spawn-order reduction must keep
        // every run byte-equal to the serial map.
        let items: Vec<u64> = (0..97).collect();
        let serial: Vec<u64> = items.iter().map(|&x| x.wrapping_mul(0x9E37)).collect();
        for round in 0..16u64 {
            let completions = AtomicUsize::new(0);
            let out = parallel_map(&items, 8, |_, &x| {
                let spins = (x.wrapping_mul(round + 1) % 64) * 50;
                for _ in 0..spins {
                    std::hint::spin_loop();
                }
                completions.fetch_add(1, Ordering::SeqCst);
                x.wrapping_mul(0x9E37)
            });
            assert_eq!(completions.load(Ordering::SeqCst), items.len());
            assert_eq!(out, serial, "round={round}");
        }
    }

    #[test]
    fn spans_race_stress_writes_every_cell_exactly_once() {
        // Disjoint-ownership stress (this also runs under the CI
        // ThreadSanitizer job): every worker bumps a shared counter and
        // increments each cell of its span under jittered timing. After
        // the scope joins, each cell must have been written exactly once
        // and the counter must equal the span count — no lost updates,
        // no overlapping spans.
        let align = 8;
        let rows = 61;
        for round in 0..16usize {
            let spans_run = AtomicUsize::new(0);
            let mut data = vec![0u32; rows * align];
            parallel_spans_mut(&mut data, align, 8, |start, span| {
                let spins = (start.wrapping_mul(round + 1) % 64) * 50;
                for _ in 0..spins {
                    std::hint::spin_loop();
                }
                spans_run.fetch_add(1, Ordering::SeqCst);
                for v in span.iter_mut() {
                    *v += 1;
                }
            });
            // 61 rows over 8 workers -> ceil(61 / 8) = 8 spans.
            assert_eq!(spans_run.load(Ordering::SeqCst), 8);
            assert!(data.iter().all(|&v| v == 1), "each cell exactly once");
        }
    }

    #[test]
    fn chunks_visit_every_row_exactly_once_and_match_serial() {
        // Row-granular variant of the span tests (also runs under the CI
        // ThreadSanitizer job): each worker stamps its rows with a value
        // derived from the row index; any thread count must reproduce the
        // serial stamping bit for bit, with each row visited once.
        let align = 6;
        let rows = 41;
        let expect: Vec<u64> = (0..rows * align)
            .map(|i| (i / align) as u64 * 1000 + (i % align) as u64)
            .collect();
        for threads in [1, 2, 3, 8, 64] {
            let visits = AtomicUsize::new(0);
            let mut data = vec![0u64; rows * align];
            parallel_chunks_mut(&mut data, align, threads, |row, span| {
                assert_eq!(span.len(), align);
                visits.fetch_add(1, Ordering::SeqCst);
                for (j, v) in span.iter_mut().enumerate() {
                    *v = row as u64 * 1000 + j as u64;
                }
            });
            assert_eq!(visits.load(Ordering::SeqCst), rows, "threads={threads}");
            assert_eq!(data, expect, "threads={threads}");
        }
    }

    #[test]
    fn spans_start_is_row_aligned() {
        let mut data = vec![0usize; 12 * 4];
        parallel_spans_mut(&mut data, 4, 5, |start, span| {
            assert_eq!(start % 4, 0, "span start must sit on a row boundary");
            assert_eq!(span.len() % 4, 0, "span length must be whole rows");
            span.fill(1);
        });
        assert!(data.iter().all(|&v| v == 1), "every cell written once");
    }
}
