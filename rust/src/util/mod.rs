//! Small shared utilities: deterministic PRNG, timing helpers, a scoped
//! worker pool, and a minimal property-testing harness (the vendored
//! crate set has no `rand`/`proptest`/`rayon`, so we carry our own — see
//! DESIGN.md §Substitutions).

pub mod parallel;
pub mod prng;
pub mod proptest;

pub use prng::Prng;

/// Wall-clock a closure, returning (result, seconds).
pub fn timeit<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = std::time::Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// ceil(a / b) for positive integers.
pub fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Number of bits needed to represent `v` (ceil(log2(v+1))).
pub fn bits_for(v: u64) -> u32 {
    64 - v.leading_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 576), 1);
    }

    #[test]
    fn bits_for_matches_paper_sum_width() {
        // ceil(log2(C+1)) for C=576 -> 10-bit iPE outputs (Sec. III).
        assert_eq!(bits_for(576), 10);
        assert_eq!(bits_for(0), 0);
        assert_eq!(bits_for(1), 1);
        assert_eq!(bits_for(1023), 10);
        assert_eq!(bits_for(1024), 11);
    }
}
