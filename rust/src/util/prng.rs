//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! The vendored crate set has no `rand`, and determinism across the whole
//! evaluation pipeline (GLS calibration, error sampling, workload
//! generation) matters more than cryptographic quality here. xoshiro256**
//! passes BigCrush and is the same generator family `rand` uses for its
//! small RNGs.

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Seed deterministically from a u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-layer / per-tile RNGs).
    pub fn fork(&mut self, stream: u64) -> Prng {
        Prng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean() {
        let mut p = Prng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| p.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn int_in_bounds_and_coverage() {
        let mut p = Prng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = p.int_in(-3, 6);
            assert!((-3..=6).contains(&v));
            seen[(v + 3) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        p.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
