//! `gavina` — the leader binary: CLI over the full GAVINA stack.
//!
//! Subcommands (all self-contained after `make artifacts`):
//!
//! ```text
//! gavina table1                      print the Table I specification sheet
//! gavina schedule  -p a4w4 -g 3      render the Fig. 2 GAV schedule + DVS trace
//! gavina calibrate [--quick]         GLS-calibrate error tables -> artifacts/
//! gavina eval      -p a4w4 -g 3      ResNet-18 accuracy under GAV
//! gavina allocate  -p a4w4 --gtar 4  ILP per-layer G allocation (§IV-D)
//! gavina serve     -n 64             run the QoS serving demo (tiers + governor)
//! gavina selfcheck                   PJRT artifacts vs native cross-check
//! ```
//!
//! `--config run.toml` pre-loads defaults from the `[engine]` (and
//! legacy `[run]`) sections; `serve` also honors `[serve]`.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use gavina::arch::{ArchConfig, GavSchedule, Precision};
use gavina::config::{Config, RunConfig};
use gavina::dnn;
use gavina::engine::{EngineBuilder, GavPolicy, GavinaError};
use gavina::errmodel::{self, CalibrationConfig};
use gavina::gls::{DelayModel, GlsContext};
use gavina::power::PowerModel;
use gavina::serve::ServeOptions;
use gavina::simulator::dvs_trace;

fn usage() -> ! {
    eprintln!(
        "usage: gavina [--config FILE] <table1|schedule|calibrate|eval|allocate|serve|selfcheck> \
         [-p aXwY] [-g G] [--gtar G] [--quick] [-n N] [--threads N] [--artifacts DIR]"
    );
    std::process::exit(2)
}

fn or_die<T>(r: Result<T, GavinaError>) -> T {
    r.unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(1)
    })
}

struct Args {
    cmd: String,
    run: RunConfig,
    /// Parsed `--config` file (the `[engine]`/`[serve]` surface), kept so
    /// subcommands can apply their sections through the typed loaders.
    cfg: Option<Config>,
    gtar: f64,
    /// `-g` given explicitly on the command line (wins over `[engine]`
    /// policy from the config file).
    g_set: bool,
    /// `--gtar` given explicitly on the command line.
    gtar_set: bool,
    quick: bool,
    n: usize,
}

fn parse_args() -> Args {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg_file: Option<Config> = None;
    let mut cmd = String::new();
    let mut gtar = 4.0;
    let mut quick = false;
    let mut n = 64;
    let mut gtar_set = false;
    // Explicit CLI flags are collected first and applied on top of the
    // config afterwards, so `-g 3 --config run.toml` and
    // `--config run.toml -g 3` mean the same thing.
    let mut cli_precision: Option<Precision> = None;
    let mut cli_g: Option<u32> = None;
    let mut cli_threads: Option<usize> = None;
    let mut cli_artifacts: Option<PathBuf> = None;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--config" => {
                i += 1;
                let cfg = Config::from_file(Path::new(argv.get(i).unwrap_or_else(|| usage())))
                    .unwrap_or_else(|e| {
                        eprintln!("config error: {e}");
                        std::process::exit(2)
                    });
                cfg_file = Some(cfg);
            }
            "-p" | "--precision" => {
                i += 1;
                cli_precision = Some(
                    Precision::parse(argv.get(i).map(String::as_str).unwrap_or(""))
                        .unwrap_or_else(|| usage()),
                );
            }
            "-g" => {
                i += 1;
                cli_g =
                    Some(argv.get(i).and_then(|s| s.parse().ok()).unwrap_or_else(|| usage()));
            }
            "--gtar" => {
                i += 1;
                gtar = argv.get(i).and_then(|s| s.parse().ok()).unwrap_or_else(|| usage());
                gtar_set = true;
            }
            "--quick" => quick = true,
            "-n" => {
                i += 1;
                n = argv.get(i).and_then(|s| s.parse().ok()).unwrap_or_else(|| usage());
            }
            "--threads" => {
                i += 1;
                cli_threads =
                    Some(argv.get(i).and_then(|s| s.parse().ok()).unwrap_or_else(|| usage()));
            }
            "--artifacts" => {
                i += 1;
                cli_artifacts = Some(PathBuf::from(argv.get(i).unwrap_or_else(|| usage())));
            }
            s if cmd.is_empty() && !s.starts_with('-') => cmd = s.to_string(),
            _ => usage(),
        }
        i += 1;
    }
    if cmd.is_empty() {
        usage();
    }
    let mut run = match &cfg_file {
        Some(cfg) => RunConfig::from_config(cfg),
        None => RunConfig::default(),
    };
    if let Some(p) = cli_precision {
        run.precision = p;
    }
    if let Some(t) = cli_threads {
        run.threads = t;
    }
    if let Some(dir) = cli_artifacts {
        run.artifacts_dir = dir;
    }
    let g_set = cli_g.is_some();
    // CLI -g wins; else a g from the config file survives (RunConfig
    // already loaded it); else the fully-guarded default.
    let config_has_g = cfg_file
        .as_ref()
        .is_some_and(|c| c.get("engine.g").is_some() || c.get("run.g").is_some());
    run.g = match cli_g {
        Some(g) => g,
        None if config_has_g => run.g,
        None => run.precision.max_g(),
    };
    Args {
        cmd,
        run,
        cfg: cfg_file,
        gtar,
        g_set,
        gtar_set,
        quick,
        n,
    }
}

/// The one place CLI state meets the engine facade. Precedence, lowest to
/// highest: built-in default (fully guarded — `Exact` ≡ uniform G_max) <
/// the `[engine]` config section (via `apply_config`, which also rejects
/// unknown keys) < explicit CLI flags (`-g` replaces the policy;
/// `-p`/`--threads` — `run` already holds the config-then-CLI merge for
/// the scalar knobs, so they are re-applied on top).
fn engine_builder(
    args: &Args,
    weights: Arc<dnn::TensorMap>,
    tables: Option<Arc<errmodel::ErrorTables>>,
) -> EngineBuilder {
    let run = &args.run;
    let mut b = EngineBuilder::new();
    if let Some(cfg) = &args.cfg {
        b = or_die(b.apply_config(cfg));
    }
    if args.g_set {
        b = b.policy(GavPolicy::Uniform(run.g));
    }
    b.weights(weights)
        .precision(run.precision)
        .width_mult(run.width_mult)
        .arch(ArchConfig::paper())
        .seed(run.seed)
        .threads(run.threads)
        .tables_opt(tables)
}

fn caltables_path(run: &RunConfig) -> PathBuf {
    run.artifacts_dir.join("caltables_v035.bin")
}

fn load_or_calibrate_tables(run: &RunConfig, quick: bool) -> errmodel::ErrorTables {
    let path = caltables_path(run);
    if let Ok((tables, v)) = errmodel::io::load(&path) {
        eprintln!("loaded error tables from {} (V_aprox={v} V)", path.display());
        return tables;
    }
    eprintln!(
        "no calibrated tables at {}; running GLS calibration…",
        path.display()
    );
    calibrate(run, quick)
}

fn calibrate(run: &RunConfig, quick: bool) -> errmodel::ErrorTables {
    let arch = ArchConfig::paper();
    let ctx = GlsContext::new(
        arch.c_dim,
        arch.clk_period_ps() as f64,
        DelayModel::default(),
        run.seed,
    );
    let cfg = if quick {
        CalibrationConfig {
            n_streams: 96,
            seq_len: 32,
            ..Default::default()
        }
    } else {
        CalibrationConfig::default()
    };
    let (tables, stats) = errmodel::calibrate(&ctx, cfg);
    eprintln!(
        "calibration: {} samples in {:.1}s GLS; per-bit flip rates {:?}",
        stats.samples,
        stats.gls_seconds,
        stats
            .flip_rate_per_bit
            .iter()
            .map(|r| format!("{r:.3}"))
            .collect::<Vec<_>>()
    );
    eprintln!(
        "back-off level fractions (full→marginal): {:?}",
        stats
            .level_fractions
            .iter()
            .map(|f| format!("{f:.3}"))
            .collect::<Vec<_>>()
    );
    std::fs::create_dir_all(&run.artifacts_dir).ok();
    errmodel::io::save(&caltables_path(run), &tables, cfg.v_aprox).expect("saving tables");
    eprintln!("saved {}", caltables_path(run).display());
    tables
}

fn cmd_table1() {
    let arch = ArchConfig::paper();
    let power = PowerModel::paper_calibrated();
    let p22 = Precision::new(2, 2);
    println!("GAVINA specifications (post-layout model; paper Table I)");
    println!("---------------------------------------------------------");
    println!(
        "Parallel Array Size (CxLxK)  {} ({}x{}x{})",
        arch.macs_per_tile(),
        arch.c_dim,
        arch.l_dim,
        arch.k_dim
    );
    println!(
        "Clock Period / Frequency     {:.1} ns / {:.0} MHz",
        1e9 / arch.freq_hz,
        arch.freq_hz / 1e6
    );
    println!("Max. Throughput (a2w2)       {:.2} TOP/s", arch.peak_tops(p22));
    println!("V_mem                        {:.2} V", arch.v_mem);
    println!(
        "V_guard | V_aprox            {:.2} V | {:.2} V",
        arch.v_guard, arch.v_aprox
    );
    println!(
        "Avg. Power @ Peak TOP/s      {:.2} mW (guarded) | {:.2} mW (aggressive)",
        power.system_power_mw(&GavSchedule::all_guarded(p22)),
        power.system_power_mw(&GavSchedule::all_approx(p22))
    );
    println!();
    println!("TOP/s and TOP/sW per precision (util 0.96; Table II rows):");
    for prec in Precision::EVAL_SET {
        let lo = power.tops_per_watt(&GavSchedule::all_guarded(prec), 0.96);
        let hi = power.tops_per_watt(&GavSchedule::all_approx(prec), 0.96);
        println!(
            "  {prec}: {:.3} TOP/s   {:.2} – {:.2} TOP/sW",
            arch.peak_tops(prec) * 0.96,
            lo,
            hi
        );
    }
}

fn cmd_schedule(run: &RunConfig) {
    let prec = run.precision;
    let sched = GavSchedule::two_level(prec, run.g);
    let arch = ArchConfig::paper();
    println!(
        "GAV schedule for {prec}, G = {} (A = V_aprox, G = V_guard):",
        run.g
    );
    print!("{}", sched.render());
    println!(
        "undervolted steps: {}/{} ({:.0}% of compute cycles)",
        sched.n_approx(),
        prec.steps(),
        100.0 * sched.approx_fraction()
    );
    let trace = dvs_trace(&arch, &sched);
    println!(
        "DVS trace [V]: {}",
        trace
            .iter()
            .map(|v| format!("{v:.2}"))
            .collect::<Vec<_>>()
            .join(" ")
    );
    let power = PowerModel::paper_calibrated();
    println!(
        "approx-region power {:.2} mW; system {:.2} mW; {:.2} TOP/sW",
        power.array_avg_power_mw(&sched),
        power.system_power_mw(&sched),
        power.tops_per_watt(&sched, 0.96)
    );
}

fn load_weights(run: &RunConfig) -> dnn::TensorMap {
    let path = run
        .artifacts_dir
        .join(format!("weights_{}.bin", run.precision.tag()));
    let fallback = run.artifacts_dir.join("weights_a4w4.bin");
    let p = if path.exists() { path } else { fallback };
    dnn::load_tensors(&p).unwrap_or_else(|e| {
        eprintln!("cannot load weights ({e}); run `make artifacts` first — using synthetic weights");
        dnn::exec::synth::synthetic_weights(run.width_mult, run.seed)
    })
}

fn load_images(run: &RunConfig, n: usize) -> (Vec<f32>, Vec<i32>, usize) {
    match dnn::load_eval_set(&run.artifacts_dir.join("dataset_eval.bin")) {
        Ok(es) => {
            let take = if n == 0 { es.n } else { n.min(es.n) };
            (
                es.images[..take * 32 * 32 * 3].to_vec(),
                es.labels[..take].to_vec(),
                take,
            )
        }
        Err(e) => {
            eprintln!("no eval set ({e}); generating random images");
            let mut rng = gavina::util::Prng::new(run.seed);
            let take = if n == 0 { 32 } else { n };
            (
                (0..take * 32 * 32 * 3).map(|_| rng.next_f32()).collect(),
                vec![0; take],
                take,
            )
        }
    }
}

fn cmd_eval(args: &Args) {
    let run = &args.run;
    let weights = Arc::new(load_weights(run));
    let (images, labels, n) = load_images(run, run.n_eval);
    let tables = Arc::new(load_or_calibrate_tables(run, args.quick));
    let arch = ArchConfig::paper();
    // The profile set only matters when the config selected an ILP
    // policy; attach it (small) only then, so plain eval never copies
    // images.
    let mut builder = engine_builder(args, weights, Some(tables));
    if matches!(builder.policy_ref(), GavPolicy::IlpBudget { .. }) {
        let n_prof = n.min(if args.quick { 8 } else { 24 });
        builder = builder.profile_set(&images[..n_prof * 3072], n_prof, run.batch);
    }
    let engine = or_die(builder.build());
    eprintln!("engine: {} backend, {}", engine.backend_name(), engine.policy().describe());
    let (res, secs) =
        gavina::util::timeit(|| or_die(engine.infer_batched(&images, n, run.batch)));
    let acc = gavina::stats::accuracy(&res.logits, &labels, res.classes);
    // Energy is modelled on the uniform-G schedule matching the engine's
    // *resolved* allocation (config G included), not the CLI default.
    let sched = engine.effective_schedule();
    let power = PowerModel::paper_calibrated();
    println!(
        "eval {} ({}) on {} images: accuracy {:.4}",
        run.precision,
        engine.policy().describe(),
        n,
        acc
    );
    println!(
        "  sim: {} cycles ({} tiles, {} corrupted values), hw time {:.3} ms, energy {:.3} mJ",
        res.stats.cycles,
        res.stats.tiles,
        res.stats.corrupted,
        res.stats.cycles as f64 / arch.freq_hz * 1e3,
        power.energy_mj(&sched, res.stats.cycles)
    );
    println!(
        "  host: {:.2} s ({:.1} ms/image) — paper's GPU model: 200 ms/image (a4w4)",
        secs,
        secs * 1e3 / n as f64
    );
}

fn cmd_allocate(args: &Args) {
    let run = &args.run;
    let weights = Arc::new(load_weights(run));
    let (images, _, n) = load_images(run, if args.quick { 8 } else { 24 });
    let tables = Arc::new(load_or_calibrate_tables(run, args.quick));
    let prec = run.precision;
    let names = dnn::conv_layer_names();
    // --gtar on the CLI wins; otherwise an `engine.gtar` from the config
    // file; otherwise the built-in default.
    let gtar = if args.gtar_set {
        args.gtar
    } else {
        args.cfg
            .as_ref()
            .and_then(|c| c.get("engine.gtar"))
            .and_then(gavina::config::Value::as_float)
            .unwrap_or(args.gtar)
    };

    // The ILP is a policy now: profiling (Fig. 8a) + branch-and-bound all
    // happen inside EngineBuilder::build, and the report hangs off the
    // engine.
    eprintln!("profiling per-layer sensitivity on {n} images…");
    let engine = or_die(
        engine_builder(args, weights, Some(tables))
            .policy(GavPolicy::IlpBudget { gtar })
            .profile_set(&images, n, run.batch)
            .build(),
    );
    let report = engine.ilp_report().expect("IlpBudget engines carry a report");
    for (li, name) in names.iter().enumerate() {
        eprintln!(
            "layer {li:2} {name:12} MSE(G): {:?}",
            report.choices[li]
                .cost
                .iter()
                .map(|c| format!("{c:.2e}"))
                .collect::<Vec<_>>()
        );
    }
    let alloc = &report.allocation;
    println!("ILP allocation for {prec}, G_tar = {gtar}:");
    for (li, name) in names.iter().enumerate() {
        println!("  {name:12} G = {}", alloc.gs[li]);
    }
    println!(
        "  op-weighted avg G = {:.3}, total output MSE bound = {:.3e}",
        alloc.avg_g, alloc.cost
    );
}

fn cmd_serve(args: &Args) {
    let run = &args.run;
    let weights = Arc::new(load_weights(run));
    let tables = Arc::new(load_or_calibrate_tables(run, true));
    // Load the request stream before the service starts so the metrics
    // throughput window (service start → last batch) measures serving,
    // not disk I/O.
    let (images, _, n_imgs) = load_images(run, args.n);
    let mut builder = engine_builder(args, weights, Some(tables));
    if matches!(builder.policy_ref(), GavPolicy::IlpBudget { .. }) {
        let n_prof = n_imgs.min(8);
        builder = builder.profile_set(&images[..n_prof * 3072], n_prof, run.batch);
    }
    let engine = Arc::new(or_die(builder.build()));
    let mut opts = match &args.cfg {
        Some(cfg) => or_die(ServeOptions::from_config(cfg)),
        None => ServeOptions::default(),
    };
    // `[serve]` batching from the config wins; otherwise the `[run]`
    // batch knob keeps its historical meaning for the *default* tier.
    // (Exact tiers batch too now: per-image activation quantization is
    // the determinism guarantee, not max_batch = 1.)
    let config_sets_batching = args.cfg.as_ref().is_some_and(|c| {
        c.get("serve.max_batch").is_some() || c.keys_with_prefix("serve.tier.").next().is_some()
    });
    if !config_sets_batching {
        let default_tier = opts.default_tier.clone();
        if let Some(t) = opts.tiers.iter_mut().find(|t| t.name == default_tier) {
            t.max_batch = run.batch;
        }
    }
    eprintln!(
        "serve: {} replicas/tier × {} intra-batch threads, admission depth {}, {} backend, tiers [{}]{}",
        opts.replicas,
        gavina::util::parallel::resolve_threads(engine.threads()),
        opts.queue_depth,
        engine.backend_name(),
        opts.tiers
            .iter()
            .map(|t| format!("{} (batch {})", t.name, t.max_batch))
            .collect::<Vec<_>>()
            .join(", "),
        match (opts.governor.is_some(), opts.canary.is_some()) {
            (true, true) => ", governor on, canary on",
            (true, false) => ", governor on",
            (false, true) => ", canary on",
            (false, false) => "",
        },
    );
    let service = or_die(Arc::clone(&engine).serve(opts));
    let session = service.session();
    let t0 = std::time::Instant::now();
    let wait_ok = |t: gavina::serve::Ticket| -> bool {
        // wait() blocks until the service answers; shutdown guarantees
        // every accepted ticket is answered.
        t.wait().map(|r| r.is_ok()).unwrap_or(false)
    };
    // Closed-loop against the bounded admission queue: when it is full,
    // drain the oldest outstanding ticket and retry, so `-n` beyond
    // queue_depth is served, not rejected.
    let mut pending: std::collections::VecDeque<gavina::serve::Ticket> = Default::default();
    let mut ok = 0usize;
    let mut backoffs = 0usize;
    'submit: for i in 0..n_imgs {
        loop {
            match session.submit(images[i * 3072..(i + 1) * 3072].to_vec()) {
                Ok(t) => {
                    pending.push_back(t);
                    break;
                }
                Err(GavinaError::Overloaded { .. }) => {
                    backoffs += 1;
                    match pending.pop_front() {
                        Some(t) => ok += wait_ok(t) as usize,
                        // Capacity held by someone else: brief backoff.
                        None => std::thread::sleep(std::time::Duration::from_millis(1)),
                    }
                }
                Err(e) => {
                    eprintln!("submit failed: {e}");
                    break 'submit;
                }
            }
        }
    }
    for t in pending {
        ok += wait_ok(t) as usize;
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = service.shutdown();
    let power = PowerModel::paper_calibrated();
    println!(
        "served {ok}/{n_imgs} requests in {wall:.2}s ({backoffs} admission backoffs)"
    );
    for m in &report.tiers {
        if m.requests == 0 && m.errors == 0 && m.cancelled == 0 {
            continue;
        }
        // Energy is modelled per tier on its own schedule (exact runs
        // fully guarded, aggressive at G=0; the governed tier's snapshot
        // carries its final allocation).
        println!(
            "  tier {:10} {:6} reqs  {:7.1} req/s  p50 {:.1} ms  p99 {:.1} ms  max {:.1} ms  \
             {:.3} mJ  {} corrupted",
            m.tier,
            m.requests,
            m.requests_per_sec,
            m.p50_us as f64 / 1e3,
            m.p99_us as f64 / 1e3,
            m.max_us as f64 / 1e3,
            m.energy_mj(&power, &m.effective_schedule(engine.precision())),
            m.corrupted,
        );
    }
    if !report.governor.is_empty() {
        let mean_gs: Vec<String> = report
            .governor
            .iter()
            .map(|s| format!("{:.1}", s.mean_g))
            .collect();
        println!(
            "  governor: {} ticks, mean-G trajectory [{}]",
            report.governor.len(),
            mean_gs.join(" ")
        );
        let last = report.governor.last().expect("non-empty");
        println!("  governor: final trigger {}", last.trigger);
    }
    for c in &report.canary {
        println!("  {}", c.summary_line());
        let hot = c.hot_layers();
        if !hot.is_empty() {
            println!("    hot layers (step-error rate): {hot}");
        }
    }
}

fn cmd_selfcheck(run: &RunConfig) {
    use gavina::quant::PackedPlanes;
    let dir = &run.artifacts_dir;
    let mut rt = match gavina::runtime::Runtime::new(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("PJRT runtime unavailable: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "PJRT platform: {}; {} artifacts in manifest",
        rt.platform(),
        rt.manifest.len()
    );
    let (c, l, k) = (576, 8, 16);
    let prec = Precision::new(4, 4);
    let mut rng = gavina::util::Prng::new(run.seed);
    let (a, b) = gavina::workload::gemm_workload(c, l, k, prec, &mut rng);
    let pa = PackedPlanes::from_a_matrix(&a, c, l, prec.a_bits);
    let pb = PackedPlanes::from_b_matrix(&b, k, c, prec.b_bits);
    let mut a_planes = Vec::new();
    for plane in 0..prec.a_bits {
        let dense = pa.unpack_plane(plane); // [l, c]
        for ci in 0..c {
            for li in 0..l {
                a_planes.push(dense[li * c + ci]);
            }
        }
    }
    let mut b_planes = Vec::new();
    for plane in 0..prec.b_bits {
        b_planes.extend_from_slice(&pb.unpack_plane(plane));
    }
    let hlo = rt
        .bitserial_gemm_tile(prec, &a_planes, &b_planes, c, l, k)
        .expect("executing artifact");
    let native = gavina::gemm::bitserial_gemm(&pa, &pb);
    let ok = hlo.iter().zip(&native).all(|(h, n)| *h as i64 == *n);
    assert!(ok, "PJRT artifact and native bit-serial GEMM disagree");
    println!("selfcheck OK: AOT artifact ≡ native bit-serial GEMM on a random {c}x{l}x{k} tile");
}

fn main() {
    let args = parse_args();
    match args.cmd.as_str() {
        "table1" => cmd_table1(),
        "schedule" => cmd_schedule(&args.run),
        "calibrate" => {
            calibrate(&args.run, args.quick);
        }
        "eval" => cmd_eval(&args),
        "allocate" => cmd_allocate(&args),
        "serve" => cmd_serve(&args),
        "selfcheck" => cmd_selfcheck(&args.run),
        _ => usage(),
    }
}
