//! Baselines and state-of-the-art comparison data (paper Fig. 1, Table II,
//! §V).
//!
//! * [`LITERATURE`] — the published accelerator datapoints the paper
//!   plots/tabulates (taken from Table II and the Fig. 1 survey). These
//!   are *reported* numbers, not things we simulate; they give the benches
//!   their comparison rows.
//! * [`TedAccelerator`] — a simplified Timing-Error-Detection baseline in
//!   the style of Shin et al. [2]: fixed 8-bit MACs, per-MAC error
//!   detection, erroneous results dropped to zero (value-drop recovery).
//! * [`FixedLsbTep`] — a Timing-Error-Propagation baseline in the style of
//!   X-NVDLA [7]: undervolting applied to a *fixed* number of multiplier
//!   LSBs (no runtime reconfigurability — the contrast GAV §II draws).
//!
//! Both baseline models reuse the alpha-power delay physics of
//! [`crate::gls::DelayModel`] at the error-rate level so comparisons
//! against GAVINA share assumptions.

use crate::gls::DelayModel;
use crate::util::Prng;

/// One published accelerator datapoint (Fig. 1 / Table II).
#[derive(Clone, Copy, Debug)]
pub struct LiteratureEntry {
    pub name: &'static str,
    pub reference: &'static str,
    pub technology_nm: u32,
    /// Best-precision energy efficiency reported [TOP/sW].
    pub tops_per_w: f64,
    /// Precision of that datapoint (bits, symmetric).
    pub precision_bits: u8,
    pub undervolting: bool,
    pub bit_serial: bool,
}

/// Survey rows (paper Fig. 1 / Table II; the Table II column values).
pub const LITERATURE: &[LiteratureEntry] = &[
    LiteratureEntry {
        name: "RBE (Marsellus)",
        reference: "[20]",
        technology_nm: 22,
        tops_per_w: 22.0,
        precision_bits: 2,
        undervolting: false,
        bit_serial: true,
    },
    LiteratureEntry {
        name: "BitBlade",
        reference: "[18]",
        technology_nm: 28,
        tops_per_w: 98.8,
        precision_bits: 2,
        undervolting: false,
        bit_serial: true,
    },
    LiteratureEntry {
        name: "Shin et al. (TED)",
        reference: "[2]",
        technology_nm: 65,
        tops_per_w: 15.1,
        precision_bits: 8,
        undervolting: true,
        bit_serial: false,
    },
    LiteratureEntry {
        name: "X-NVDLA (TEP)",
        reference: "[7]",
        technology_nm: 15,
        tops_per_w: f64::NAN, // relative savings only (+35%)
        precision_bits: 8,
        undervolting: true,
        bit_serial: false,
    },
    LiteratureEntry {
        name: "X-TPU (TEP)",
        reference: "[8]",
        technology_nm: 15,
        tops_per_w: f64::NAN, // relative savings only (+57%)
        precision_bits: 8,
        undervolting: true,
        bit_serial: false,
    },
    LiteratureEntry {
        name: "Colonnade",
        reference: "[15]",
        technology_nm: 65,
        tops_per_w: 117.3,
        precision_bits: 1,
        undervolting: false,
        bit_serial: true,
    },
    LiteratureEntry {
        name: "TCN-CUTIE",
        reference: "[19]",
        technology_nm: 22,
        tops_per_w: 1036.0,
        precision_bits: 2, // ternary
        undervolting: false,
        bit_serial: false,
    },
];

/// Technology scaling per DeepScaleTool [31]: energy-efficiency factor
/// from `from_nm` to `to_nm` (linear interpolation in the deep-submicron
/// table the paper uses; coarse — good enough for the Table II footnote
/// scaling).
pub fn tech_scale_efficiency(from_nm: u32, to_nm: u32) -> f64 {
    // Relative energy/op (lower = better) indexed by node.
    fn energy_per_op(nm: u32) -> f64 {
        match nm {
            n if n >= 65 => 6.0,
            n if n >= 28 => 2.6,
            n if n >= 22 => 2.0,
            n if n >= 15 => 1.35,
            n if n >= 14 => 1.3,
            n if n >= 12 => 1.0,
            _ => 0.8,
        }
    }
    energy_per_op(from_nm) / energy_per_op(to_nm)
}

/// Error characteristics shared by the simplified baselines: probability
/// that an 8-bit MAC misses timing at supply `v`, given the fraction of
/// the clock period its critical path uses at nominal voltage.
fn mac_error_prob(model: &DelayModel, v: f64, path_frac: f64) -> f64 {
    let f = model.factor(v);
    // Path-delay population model: per-MAC critical paths are spread over
    // [0.3·path_frac, path_frac] (short LSB paths to the full carry
    // chain); the error probability is the fraction whose scaled delay
    // exceeds the clock period. Zero when the slowest path still meets
    // timing (f·path_frac ≤ 1) — the design closes timing at V_nom.
    let x = path_frac * f;
    if x <= 1.0 {
        return 0.0;
    }
    ((x - 1.0) / (0.7 * x)).clamp(0.0, 1.0)
}

/// Shin-et-al-style TED accelerator: on a detected timing error the MAC
/// result is dropped to zero.
pub struct TedAccelerator {
    pub model: DelayModel,
    /// Critical-path fraction of the 8-bit MAC at nominal voltage.
    pub path_frac: f64,
}

impl Default for TedAccelerator {
    fn default() -> Self {
        Self {
            model: DelayModel::default(),
            path_frac: 0.93,
        }
    }
}

impl TedAccelerator {
    /// Run an 8-bit GEMM at supply `v`: per scalar MAC, with probability
    /// `p_err` the product is dropped (TED value-drop recovery).
    #[allow(clippy::too_many_arguments)]
    pub fn gemm(
        &self,
        a: &[i32],
        b: &[i32],
        c_dim: usize,
        l_dim: usize,
        k_dim: usize,
        v: f64,
        rng: &mut Prng,
    ) -> Vec<i64> {
        let p_err = mac_error_prob(&self.model, v, self.path_frac);
        let mut p = vec![0i64; k_dim * l_dim];
        for k in 0..k_dim {
            for c in 0..c_dim {
                let bv = b[k * c_dim + c] as i64;
                for l in 0..l_dim {
                    if p_err > 0.0 && rng.chance(p_err) {
                        continue; // dropped MAC
                    }
                    p[k * l_dim + l] += bv * a[c * l_dim + l] as i64;
                }
            }
        }
        p
    }

    /// Relative MAC-array power at supply `v` (V² dynamic).
    pub fn array_power_scale(&self, v: f64) -> f64 {
        (v / self.model.v_nom).powi(2)
    }
}

/// X-NVDLA-style fixed-LSB TEP: only the `n_lsb` low bits of each product
/// are computed in the undervolted domain; errors flip those bits only.
pub struct FixedLsbTep {
    pub model: DelayModel,
    pub n_lsb: u32,
    pub path_frac: f64,
}

impl Default for FixedLsbTep {
    fn default() -> Self {
        Self {
            model: DelayModel::default(),
            n_lsb: 8,
            path_frac: 0.93,
        }
    }
}

impl FixedLsbTep {
    /// 8-bit GEMM with undervolting on the LSB part of each product.
    #[allow(clippy::too_many_arguments)]
    pub fn gemm(
        &self,
        a: &[i32],
        b: &[i32],
        c_dim: usize,
        l_dim: usize,
        k_dim: usize,
        v: f64,
        rng: &mut Prng,
    ) -> Vec<i64> {
        let p_err = mac_error_prob(&self.model, v, self.path_frac);
        let mask = (1i64 << self.n_lsb) - 1;
        let mut p = vec![0i64; k_dim * l_dim];
        for k in 0..k_dim {
            for c in 0..c_dim {
                let bv = b[k * c_dim + c] as i64;
                for l in 0..l_dim {
                    let mut prod = bv * a[c * l_dim + l] as i64;
                    if p_err > 0.0 && rng.chance(p_err) {
                        // Flip a random bit within the undervolted LSB part.
                        let bit = rng.index(self.n_lsb as usize) as i64;
                        prod ^= (1 << bit) & mask;
                    }
                    p[k * l_dim + l] += prod;
                }
            }
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::gemm_exact;

    fn operands(rng: &mut Prng, c: usize, l: usize, k: usize) -> (Vec<i32>, Vec<i32>) {
        crate::workload::gemm_workload(c, l, k, crate::arch::Precision::new(8, 8), rng)
    }

    #[test]
    fn ted_exact_at_nominal_voltage() {
        let mut rng = Prng::new(1);
        let (a, b) = operands(&mut rng, 64, 8, 8);
        let ted = TedAccelerator::default();
        let p = ted.gemm(&a, &b, 64, 8, 8, 0.55, &mut rng);
        assert_eq!(p, gemm_exact(&a, &b, 64, 8, 8));
    }

    #[test]
    fn ted_degrades_with_voltage() {
        let mut rng = Prng::new(2);
        let (a, b) = operands(&mut rng, 128, 8, 8);
        let exact = gemm_exact(&a, &b, 128, 8, 8);
        let ted = TedAccelerator::default();
        let v_mid = crate::stats::var_ned(&exact, &ted.gemm(&a, &b, 128, 8, 8, 0.48, &mut rng));
        let v_low = crate::stats::var_ned(&exact, &ted.gemm(&a, &b, 128, 8, 8, 0.40, &mut rng));
        assert!(v_low > v_mid, "lower V must hurt more: {v_low} vs {v_mid}");
    }

    #[test]
    fn fixed_lsb_errors_are_bounded() {
        // Error magnitude per MAC is < 2^n_lsb, so the GEMM deviation is
        // bounded by C · 2^n_lsb — unlike TED drops which lose whole
        // products.
        let mut rng = Prng::new(3);
        let (a, b) = operands(&mut rng, 64, 4, 4);
        let exact = gemm_exact(&a, &b, 64, 4, 4);
        let tep = FixedLsbTep {
            n_lsb: 4,
            ..Default::default()
        };
        let p = tep.gemm(&a, &b, 64, 4, 4, 0.40, &mut rng);
        for (e, ap) in exact.iter().zip(&p) {
            assert!((e - ap).abs() <= 64 * 16, "{e} vs {ap}");
        }
    }

    #[test]
    fn tech_scaling_direction() {
        // Scaling 28 nm -> 12 nm improves efficiency; 12 -> 28 hurts.
        assert!(tech_scale_efficiency(28, 12) > 1.0);
        assert!(tech_scale_efficiency(12, 28) < 1.0);
        assert_eq!(tech_scale_efficiency(12, 12), 1.0);
    }

    #[test]
    fn literature_table_sane() {
        assert!(LITERATURE.len() >= 5);
        for e in LITERATURE {
            assert!(e.technology_nm >= 5 && e.technology_nm <= 65);
            if !e.tops_per_w.is_nan() {
                assert!(e.tops_per_w > 0.0);
            }
        }
        // The Table II bit-serial rows the paper compares against.
        assert!(LITERATURE.iter().any(|e| e.name.contains("BitBlade")));
        assert!(LITERATURE.iter().any(|e| e.name.contains("RBE")));
    }
}
