//! Multi-level GAV (paper §II/§III extension: *"this approach can be
//! extended to more sophisticated policies with several voltage values
//! instead of two"*).
//!
//! Each discrete voltage level gets its own GLS-calibrated [`ErrorTables`]
//! (milder undervolting → sparser tables); a [`GavSchedule`] built with
//! [`GavSchedule::custom`] assigns [`VoltageMode::Level`] indices per
//! significance, and [`MultiLevelTables::inject`] samples each step from
//! the tables of its level. `Guarded` steps stay exact; plain
//! `Approximate` steps use level 0 (the most aggressive voltage), so
//! two-level schedules behave identically to [`ErrorTables::inject`].

use super::ErrorTables;
use crate::arch::{GavSchedule, VoltageMode};
use crate::util::Prng;

/// Per-level calibrated tables, most aggressive first.
pub struct MultiLevelTables {
    /// `(supply voltage, tables calibrated at that voltage)`; index = the
    /// `VoltageMode::Level` id. Entry 0 doubles as the `Approximate`
    /// voltage.
    pub levels: Vec<(f64, ErrorTables)>,
}

impl MultiLevelTables {
    pub fn new(levels: Vec<(f64, ErrorTables)>) -> Self {
        assert!(!levels.is_empty());
        // Most aggressive (lowest voltage) first, by convention.
        for w in levels.windows(2) {
            assert!(
                w[0].0 <= w[1].0,
                "levels must be ordered aggressive -> mild"
            );
        }
        Self { levels }
    }

    /// Voltage of a mode (guarded voltage must come from the ArchConfig).
    pub fn level_voltage(&self, mode: VoltageMode) -> Option<f64> {
        match mode {
            VoltageMode::Guarded => None,
            VoltageMode::Approximate => Some(self.levels[0].0),
            VoltageMode::Level(i) => Some(self.levels[i as usize].0),
        }
    }

    /// Inject errors step by step, each from its level's tables
    /// ([`ErrorTables::inject_step`] — the previous-value dependency is
    /// on each step's *exact* output, which `inject_step` records into
    /// `prev` before corrupting). Returns the number of modified values.
    /// Semantics per step are identical to
    /// [`ErrorTables::inject_masked`] (prev carried across all steps,
    /// guarded steps exact).
    pub fn inject(&self, seq: &mut [Vec<u16>], sched: &GavSchedule, rng: &mut Prng) -> u64 {
        let n = seq.first().map_or(0, Vec::len);
        let mut prev: Vec<u16> = vec![0; n];
        let mut modified = 0u64;
        for (t, step) in seq.iter_mut().enumerate() {
            let tables = match sched.mode(t) {
                VoltageMode::Guarded => None,
                VoltageMode::Approximate => Some(&self.levels[0].1),
                VoltageMode::Level(i) => Some(&self.levels[i as usize].1),
            };
            match tables {
                Some(tables) => modified += tables.inject_step(step, &mut prev, rng),
                // Guarded step: exact by definition, only feeds `prev`.
                None => prev.copy_from_slice(step),
            }
        }
        modified
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Precision;
    use crate::errmodel::ModelParams;

    fn const_tables(p: ModelParams, prob: f32, bit: usize) -> ErrorTables {
        let mut t = ErrorTables::zeroed(p);
        for e in 0..=p.c_dim as u16 {
            for pb in 0..p.p_bins {
                for cd in 0..p.n_cond(bit) {
                    t.set_prob(bit, e, pb, cd, prob);
                }
            }
        }
        t
    }

    fn params() -> ModelParams {
        ModelParams {
            s_bits: 6,
            c_dim: 36,
            p_bins: 4,
            n_nei: 2,
        }
    }

    #[test]
    fn levels_apply_their_own_tables() {
        let p = params();
        // Level 0 (aggressive): bit 0 always flips. Level 1 (mild): never.
        let ml = MultiLevelTables::new(vec![
            (0.35, const_tables(p, 1.0, 0)),
            (0.45, const_tables(p, 0.0, 0)),
        ]);
        let prec = Precision::new(2, 2); // s_max = 2
        // Custom: s=0 -> level 0, s=1 -> level 1, s=2 -> guarded.
        let sched = GavSchedule::custom(prec, |s| match s {
            0 => VoltageMode::Level(0),
            1 => VoltageMode::Level(1),
            _ => VoltageMode::Guarded,
        });
        // Step order (ba,bb): (0,0)s=0, (1,0)s=1, (0,1)s=1, (1,1)s=2.
        let mut seq = vec![vec![4u16; 8], vec![4; 8], vec![4; 8], vec![4; 8]];
        let mut rng = Prng::new(1);
        let n = ml.inject(&mut seq, &sched, &mut rng);
        assert_eq!(n, 8, "only the s=0 step flips");
        assert!(seq[0].iter().all(|&v| v == 5));
        assert!(seq[1].iter().all(|&v| v == 4));
        assert!(seq[2].iter().all(|&v| v == 4));
        assert!(seq[3].iter().all(|&v| v == 4));
    }

    #[test]
    fn two_level_equivalence_with_plain_inject() {
        // A multi-level injector with a single level must match
        // ErrorTables::inject on an Approximate-only schedule, given the
        // same RNG stream.
        let p = params();
        let tables = const_tables(p, 0.3, 2);
        let prec = Precision::new(3, 3);
        let sched = GavSchedule::all_approx(prec);
        let base: Vec<Vec<u16>> = (0..prec.steps()).map(|s| vec![s as u16 * 3; 16]).collect();

        let mut seq_a = base.clone();
        let mut rng_a = Prng::new(9);
        let na = tables.inject(&mut seq_a, &sched, &mut rng_a);

        let ml = MultiLevelTables::new(vec![(0.35, tables)]);
        let mut seq_b = base;
        let mut rng_b = Prng::new(9);
        let nb = ml.inject(&mut seq_b, &sched, &mut rng_b);

        assert_eq!(na, nb);
        assert_eq!(seq_a, seq_b);
    }

    #[test]
    #[should_panic(expected = "aggressive -> mild")]
    fn rejects_misordered_levels() {
        let p = params();
        MultiLevelTables::new(vec![
            (0.45, ErrorTables::zeroed(p)),
            (0.35, ErrorTables::zeroed(p)),
        ]);
    }
}
