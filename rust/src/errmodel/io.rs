//! Serialization of calibrated error tables (`artifacts/caltables_*.bin`)
//! so the expensive GLS calibration runs once and every downstream tool
//! (benches, examples, the serving layer) loads the same tables.
//!
//! Format (little-endian):
//! ```text
//! magic  b"GVCT"  | version u32 (=1)
//! s_bits u32 | c_dim u32 | p_bins u32 | n_nei u32 | v_aprox f64
//! per bit: len u32 | len * f32
//! ```

use super::{ErrorTables, ModelParams};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"GVCT";

/// Save tables (+ the voltage they were calibrated at).
pub fn save(path: &Path, tables: &ErrorTables, v_aprox: f64) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&1u32.to_le_bytes())?;
    let p = tables.params;
    for v in [p.s_bits as u32, p.c_dim as u32, p.p_bins as u32, p.n_nei as u32] {
        f.write_all(&v.to_le_bytes())?;
    }
    f.write_all(&v_aprox.to_le_bytes())?;
    for bit in 0..p.s_bits {
        let t = tables.bit_table(bit);
        f.write_all(&(t.len() as u32).to_le_bytes())?;
        for &x in t {
            f.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Load tables; returns `(tables, v_aprox)`.
pub fn load(path: &Path) -> std::io::Result<(ErrorTables, f64)> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad magic in {}", path.display()),
        ));
    }
    let mut u32buf = [0u8; 4];
    let mut read_u32 = |f: &mut dyn Read| -> std::io::Result<u32> {
        f.read_exact(&mut u32buf)?;
        Ok(u32::from_le_bytes(u32buf))
    };
    let version = read_u32(&mut f)?;
    if version != 1 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("unsupported caltable version {version}"),
        ));
    }
    let s_bits = read_u32(&mut f)? as usize;
    let c_dim = read_u32(&mut f)? as usize;
    let p_bins = read_u32(&mut f)? as usize;
    let n_nei = read_u32(&mut f)? as usize;
    let mut f64buf = [0u8; 8];
    f.read_exact(&mut f64buf)?;
    let v_aprox = f64::from_le_bytes(f64buf);

    let params = ModelParams {
        s_bits,
        c_dim,
        p_bins,
        n_nei,
    };
    let mut tables = ErrorTables::zeroed(params);
    for bit in 0..s_bits {
        let len = read_u32(&mut f)? as usize;
        let expect = tables.bit_table(bit).len();
        if len != expect {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("bit {bit}: table length {len} != expected {expect}"),
            ));
        }
        let dst = tables.bit_table_mut(bit);
        let mut buf = vec![0u8; len * 4];
        f.read_exact(&mut buf)?;
        for (i, chunk) in buf.chunks_exact(4).enumerate() {
            dst[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
    }
    Ok((tables, v_aprox))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn roundtrip() {
        let params = ModelParams {
            s_bits: 6,
            c_dim: 36,
            p_bins: 4,
            n_nei: 2,
        };
        let mut t = ErrorTables::zeroed(params);
        let mut rng = Prng::new(1);
        for bit in 0..params.s_bits {
            for v in t.bit_table_mut(bit) {
                *v = rng.next_f32();
            }
        }
        let dir = std::env::temp_dir().join("gavina_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tables.bin");
        save(&path, &t, 0.35).unwrap();
        let (t2, v) = load(&path).unwrap();
        assert_eq!(v, 0.35);
        assert_eq!(t2.params, params);
        for bit in 0..params.s_bits {
            assert_eq!(t.bit_table(bit), t2.bit_table(bit));
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("gavina_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.bin");
        std::fs::write(&path, b"NOPE1234").unwrap();
        assert!(load(&path).is_err());
    }
}
