//! The GAVINA undervolting error model (paper §IV-C).
//!
//! GLS is the ground truth but is far too slow for DNN-scale evaluation
//! (the paper: ~2 h per CIFAR-10 image; here: ~seconds per tile vs ~µs).
//! The model replaces it with a 4-D probability look-up table sampled per
//! iPE output bit:
//!
//! ```text
//! P(flip bit b) = TABLES[b][exact_value][prev_value_bin][neighbour_cond]
//! ```
//!
//! indexed by the four empirically-observed dependencies (§IV-C): bit
//! significance, the exact output value, the previous output value
//! (binned into `p_bins`), and the error state of the `n_nei` more
//! significant neighbour bits. Bits are sampled MSB → LSB so neighbour
//! conditions are available when a bit is drawn (Listing 2).
//!
//! [`calibrate`] fills the tables with flip frequencies measured from GLS
//! traces, with hierarchical back-off for sparsely-observed index
//! combinations; [`ErrorTables::inject`] is the fast sampling hot path.

pub mod calibrate;
pub mod io;
pub mod multi;

pub use calibrate::{calibrate, calibrate_with_params, CalibrationConfig, CalibrationStats};
pub use multi::MultiLevelTables;

use crate::arch::GavSchedule;
use crate::util::Prng;
use std::sync::OnceLock;

/// Model hyper-parameters (paper: `[n_nei, p_bins] = [2, 16]`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelParams {
    /// iPE output width (10 for C = 576).
    pub s_bits: usize,
    /// Reduction dimension C (tables index exact values `0..=C`).
    pub c_dim: usize,
    /// Number of previous-value bins.
    pub p_bins: usize,
    /// Number of more-significant neighbour bits conditioned on.
    pub n_nei: usize,
}

impl ModelParams {
    pub fn paper(c_dim: usize) -> Self {
        Self {
            s_bits: crate::util::bits_for(c_dim as u64) as usize,
            c_dim,
            p_bins: 16,
            n_nei: 2,
        }
    }

    /// Conditions for bit `b`: `2^min(n_nei, s_bits-1-b)` (ragged tables —
    /// the MSB has no more-significant neighbours).
    pub fn n_cond(&self, bit: usize) -> usize {
        1 << self.n_nei.min(self.s_bits - 1 - bit)
    }

    /// Flat table size for one bit.
    fn bit_table_len(&self, bit: usize) -> usize {
        (self.c_dim + 1) * self.p_bins * self.n_cond(bit)
    }

    /// Map a previous output value to its bin.
    #[inline]
    pub fn prev_bin(&self, prev: u16) -> usize {
        (((prev as usize) * self.p_bins) / (self.c_dim + 1)).min(self.p_bins - 1)
    }
}

/// The calibrated probability tables (ragged per bit).
#[derive(Clone, Debug)]
pub struct ErrorTables {
    pub params: ModelParams,
    /// `tables[bit][ (exact · p_bins + prev_bin) · n_cond(bit) + cond ]`.
    tables: Vec<Vec<f32>>,
    /// Sampling-optimized layout, built lazily (§Perf): one contiguous
    /// block per `(exact, prev_bin)` holding every `(bit, cond)` prob, so
    /// sampling one value touches 1–2 cache lines instead of `s_bits`
    /// scattered tables, plus a per-block max for a zero-probability fast
    /// path.
    sampler: OnceLock<Sampler>,
}

/// See [`ErrorTables::sampler`].
#[derive(Clone, Debug, Default)]
struct Sampler {
    /// `[exact][pbin][bit_off(bit) + cond]`, bits ordered MSB→LSB.
    flat: Vec<f32>,
    /// Max probability within each `(exact, pbin)` block.
    block_max: Vec<f32>,
    /// Offset of each bit's cond slots within a block (indexed by bit).
    bit_off: Vec<usize>,
    block: usize,
}

impl ErrorTables {
    /// All-zero tables (no errors — the guarded model).
    pub fn zeroed(params: ModelParams) -> Self {
        let tables = (0..params.s_bits)
            .map(|b| vec![0.0f32; params.bit_table_len(b)])
            .collect();
        Self {
            params,
            tables,
            sampler: OnceLock::new(),
        }
    }

    fn build_sampler(&self) -> Sampler {
        let p = self.params;
        let mut bit_off = vec![0usize; p.s_bits];
        let mut block = 0usize;
        for bit in (0..p.s_bits).rev() {
            bit_off[bit] = block;
            block += p.n_cond(bit);
        }
        let n_blocks = (p.c_dim + 1) * p.p_bins;
        let mut flat = vec![0.0f32; n_blocks * block];
        let mut block_max = vec![0.0f32; n_blocks];
        for e in 0..=p.c_dim as u16 {
            for pb in 0..p.p_bins {
                let b = e as usize * p.p_bins + pb;
                for bit in 0..p.s_bits {
                    for cond in 0..p.n_cond(bit) {
                        let v = self.prob(bit, e, pb, cond);
                        flat[b * block + bit_off[bit] + cond] = v;
                        block_max[b] = block_max[b].max(v);
                    }
                }
            }
        }
        Sampler {
            flat,
            block_max,
            bit_off,
            block,
        }
    }

    fn sampler(&self) -> &Sampler {
        self.sampler.get_or_init(|| self.build_sampler())
    }

    #[inline]
    fn index(&self, bit: usize, exact: u16, pbin: usize, cond: usize) -> usize {
        let nc = self.params.n_cond(bit);
        debug_assert!(cond < nc);
        ((exact as usize) * self.params.p_bins + pbin) * nc + cond
    }

    /// Flip probability of `bit` under the given conditions.
    #[inline]
    pub fn prob(&self, bit: usize, exact: u16, pbin: usize, cond: usize) -> f32 {
        self.tables[bit][self.index(bit, exact, pbin, cond)]
    }

    pub fn set_prob(&mut self, bit: usize, exact: u16, pbin: usize, cond: usize, p: f32) {
        let i = self.index(bit, exact, pbin, cond);
        self.tables[bit][i] = p;
        self.sampler = OnceLock::new(); // invalidate the sampling layout
    }

    /// Raw table slice for bit `b` (serialization, PJRT cross-checks).
    pub fn bit_table(&self, bit: usize) -> &[f32] {
        &self.tables[bit]
    }

    pub fn bit_table_mut(&mut self, bit: usize) -> &mut [f32] {
        self.sampler = OnceLock::new(); // invalidate the sampling layout
        &mut self.tables[bit]
    }

    /// Dense export `[s_bits, C+1, p_bins, 2^n_nei]` (fixed n_cond; ragged
    /// bits broadcast over the missing condition axis) — the layout the
    /// AOT `errinject` artifact takes as input.
    pub fn to_dense(&self) -> Vec<f32> {
        let p = &self.params;
        let nc_full = 1 << p.n_nei;
        let mut out = vec![0.0f32; p.s_bits * (p.c_dim + 1) * p.p_bins * nc_full];
        for bit in 0..p.s_bits {
            let nc = p.n_cond(bit);
            for exact in 0..=p.c_dim as u16 {
                for pbin in 0..p.p_bins {
                    for cond in 0..nc_full {
                        let v = self.prob(bit, exact, pbin, cond % nc);
                        let idx = ((bit * (p.c_dim + 1) + exact as usize) * p.p_bins + pbin)
                            * nc_full
                            + cond;
                        out[idx] = v;
                    }
                }
            }
        }
        out
    }

    /// Mean flip probability per bit (diagnostics / Fig. 7 maps).
    pub fn mean_prob_per_bit(&self) -> Vec<f64> {
        self.tables
            .iter()
            .map(|t| t.iter().map(|&p| p as f64).sum::<f64>() / t.len() as f64)
            .collect()
    }

    /// Inject sampled errors onto an exact iPE output sequence
    /// (`seq[t][i]`, iPE-major within step), in place, under a GAV
    /// schedule. Guarded steps pass through. Returns the number of
    /// modified values.
    ///
    /// This mirrors `python/compile/kernels/ref.py::errmodel_ref`
    /// semantics exactly: prev starts at 0 (registers reset), bits sampled
    /// MSB → LSB, neighbour condition built from already-sampled flips of
    /// the `n_nei` more significant bits.
    pub fn inject(&self, seq: &mut [Vec<u16>], sched: &GavSchedule, rng: &mut Prng) -> u64 {
        let approx = sched.approx_mask();
        assert_eq!(seq.len(), approx.len());
        self.inject_masked(seq, &approx, rng)
    }

    /// [`Self::inject`] with an explicit per-step undervolt mask.
    pub fn inject_masked(&self, seq: &mut [Vec<u16>], approx: &[bool], rng: &mut Prng) -> u64 {
        let n = seq.first().map_or(0, Vec::len);
        let mut prev: Vec<u16> = vec![0; n];
        let mut modified = 0u64;
        for (t, step) in seq.iter_mut().enumerate() {
            debug_assert_eq!(step.len(), n);
            if !approx[t] {
                prev.copy_from_slice(step);
                continue;
            }
            modified += self.inject_step(step, &mut prev, rng);
        }
        modified
    }

    /// Inject errors onto **one** undervolted step in place: exactly the
    /// per-approx-step body of [`Self::inject_masked`], factored out so
    /// the cycle simulator can stream steps through a single reused
    /// buffer (fusing the guarded steps) instead of materializing the
    /// full sequence; also the per-step building block of the multi-level
    /// injector ([`crate::errmodel::MultiLevelTables`]). `prev` must hold
    /// the previous step's *exact* iPE
    /// outputs (zeros before the first step; a guarded step's outputs
    /// verbatim) and is updated to this step's exact outputs. The RNG
    /// consumption order is identical to the sequence path, so streamed
    /// and materialized injection are bit-identical.
    pub fn inject_step(&self, step: &mut [u16], prev: &mut [u16], rng: &mut Prng) -> u64 {
        let p = self.params;
        let s = self.sampler();
        debug_assert_eq!(step.len(), prev.len());
        let mut modified = 0u64;
        for (v, pv) in step.iter_mut().zip(prev.iter_mut()) {
            let exact = *v;
            let pbin = p.prev_bin(*pv);
            *pv = exact;
            let flips = sample_flips(p, s, exact, pbin, rng);
            if flips != 0 {
                *v = exact ^ flips as u16;
                modified += 1;
            }
        }
        modified
    }
}

/// Sample the flip mask for one value: bits MSB→LSB within one contiguous
/// `(exact, pbin)` sampler block; returns 0 immediately when the block is
/// all-zero (the common case for guarded-quality voltages).
#[inline]
fn sample_flips(
    p: ModelParams,
    s: &Sampler,
    exact: u16,
    pbin: usize,
    rng: &mut Prng,
) -> u32 {
    let b = exact as usize * p.p_bins + pbin;
    if s.block_max[b] <= 0.0 {
        return 0;
    }
    let blk = &s.flat[b * s.block..(b + 1) * s.block];
    let mut flips: u32 = 0;
    for bit in (0..p.s_bits).rev() {
        let nei = p.s_bits - 1 - bit;
        let cond = if nei == 0 {
            0
        } else {
            let take = p.n_nei.min(nei);
            ((flips >> (bit + 1)) & ((1 << take) - 1)) as usize
        };
        let prob = blk[s.bit_off[bit] + cond];
        if prob > 0.0 && rng.next_f32() < prob {
            flips |= 1 << bit;
        }
    }
    flips
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Precision;

    fn params() -> ModelParams {
        ModelParams {
            s_bits: 6,
            c_dim: 36,
            p_bins: 4,
            n_nei: 2,
        }
    }

    #[test]
    fn ragged_cond_sizes() {
        let p = ModelParams::paper(576);
        assert_eq!(p.s_bits, 10);
        assert_eq!(p.n_cond(9), 1); // MSB: no neighbours
        assert_eq!(p.n_cond(8), 2); // one neighbour
        assert_eq!(p.n_cond(7), 4); // two
        assert_eq!(p.n_cond(0), 4);
    }

    #[test]
    fn prev_bin_ranges() {
        let p = ModelParams::paper(576);
        assert_eq!(p.prev_bin(0), 0);
        assert_eq!(p.prev_bin(576), 15);
        assert!(p.prev_bin(288) < 16);
        // Bins are monotone in prev.
        let mut last = 0;
        for v in 0..=576u16 {
            let b = p.prev_bin(v);
            assert!(b >= last);
            last = b;
        }
    }

    #[test]
    fn zero_tables_inject_nothing() {
        let t = ErrorTables::zeroed(params());
        let prec = Precision::new(3, 3);
        let mut seq: Vec<Vec<u16>> = (0..prec.steps()).map(|s| vec![s as u16; 8]).collect();
        let orig = seq.clone();
        let mut rng = Prng::new(1);
        let n = t.inject(&mut seq, &GavSchedule::all_approx(prec), &mut rng);
        assert_eq!(n, 0);
        assert_eq!(seq, orig);
    }

    #[test]
    fn certain_flip_applies_everywhere() {
        let p = params();
        let mut t = ErrorTables::zeroed(p);
        // Bit 2 always flips regardless of conditions.
        for exact in 0..=p.c_dim as u16 {
            for pbin in 0..p.p_bins {
                for cond in 0..p.n_cond(2) {
                    t.set_prob(2, exact, pbin, cond, 1.0);
                }
            }
        }
        let prec = Precision::new(2, 2);
        let mut seq: Vec<Vec<u16>> = (0..prec.steps()).map(|_| vec![0u16; 4]).collect();
        let mut rng = Prng::new(2);
        t.inject(&mut seq, &GavSchedule::all_approx(prec), &mut rng);
        for step in &seq {
            assert!(step.iter().all(|&v| v == 4), "bit 2 must be flipped: {step:?}");
        }
    }

    #[test]
    fn guarded_steps_pass_through() {
        let p = params();
        let mut t = ErrorTables::zeroed(p);
        for bit in 0..p.s_bits {
            for exact in 0..=p.c_dim as u16 {
                for pbin in 0..p.p_bins {
                    for cond in 0..p.n_cond(bit) {
                        t.set_prob(bit, exact, pbin, cond, 0.9);
                    }
                }
            }
        }
        let prec = Precision::new(4, 4);
        let sched = GavSchedule::two_level(prec, 3);
        let approx = sched.approx_mask();
        let mut seq: Vec<Vec<u16>> = (0..prec.steps()).map(|_| vec![5u16; 4]).collect();
        let orig = seq.clone();
        let mut rng = Prng::new(3);
        t.inject(&mut seq, &sched, &mut rng);
        for (s, (step, o)) in seq.iter().zip(&orig).enumerate() {
            if !approx[s] {
                assert_eq!(step, o, "guarded step {s} modified");
            } else {
                assert_ne!(step, o, "approx step {s} should be hit at p=0.9");
            }
        }
    }

    #[test]
    fn streamed_inject_step_matches_sequence_injection() {
        // inject_step is the simulator's streaming entry point: walking
        // the steps with one reused buffer (guarded steps only copied
        // into `prev`) must consume the same RNG and produce the same
        // corrupted values as the materialized inject_masked sequence.
        let p = params();
        let mut t = ErrorTables::zeroed(p);
        for bit in 0..p.s_bits {
            for exact in 0..=p.c_dim as u16 {
                for pbin in 0..p.p_bins {
                    for cond in 0..p.n_cond(bit) {
                        t.set_prob(bit, exact, pbin, cond, 0.2);
                    }
                }
            }
        }
        let prec = Precision::new(4, 4);
        let sched = GavSchedule::two_level(prec, 3);
        let approx = sched.approx_mask();
        let mut vals = Prng::new(40);
        let exact_seq: Vec<Vec<u16>> = (0..prec.steps())
            .map(|_| (0..16).map(|_| vals.int_in(0, p.c_dim as i64) as u16).collect())
            .collect();

        let mut seq = exact_seq.clone();
        let mut rng_a = Prng::new(41);
        let n_seq = t.inject_masked(&mut seq, &approx, &mut rng_a);

        let mut rng_b = Prng::new(41);
        let mut prev = vec![0u16; 16];
        let mut cur = vec![0u16; 16];
        let mut n_stream = 0u64;
        for (s, step) in exact_seq.iter().enumerate() {
            cur.copy_from_slice(step);
            if approx[s] {
                n_stream += t.inject_step(&mut cur, &mut prev, &mut rng_b);
                assert_eq!(cur, seq[s], "approx step {s}");
            } else {
                prev.copy_from_slice(&cur);
            }
        }
        assert_eq!(n_seq, n_stream);
        assert!(n_seq > 0, "test must actually inject");
    }

    #[test]
    fn neighbour_condition_couples_bits() {
        // P(flip b4) = 1 given b5 flipped, 0 otherwise; P(flip b5) = 0.5.
        // Then b4 flips exactly when b5 does — their empirical rates match.
        let p = params();
        let mut t = ErrorTables::zeroed(p);
        for exact in 0..=p.c_dim as u16 {
            for pbin in 0..p.p_bins {
                t.set_prob(5, exact, pbin, 0, 0.5);
                // bit 4 has 1 neighbour (bit 5): cond bit 0 = b5 flip.
                t.set_prob(4, exact, pbin, 1, 1.0);
                t.set_prob(4, exact, pbin, 0, 0.0);
            }
        }
        let prec = Precision::new(8, 8);
        let mut seq: Vec<Vec<u16>> = (0..prec.steps()).map(|_| vec![0u16; 64]).collect();
        let mut rng = Prng::new(4);
        t.inject(&mut seq, &GavSchedule::all_approx(prec), &mut rng);
        let mut n5 = 0;
        let mut n45 = 0;
        let mut n4_only = 0;
        for step in &seq {
            for &v in step {
                let b5 = (v >> 5) & 1 == 1;
                let b4 = (v >> 4) & 1 == 1;
                n5 += b5 as u32;
                n45 += (b4 && b5) as u32;
                n4_only += (b4 && !b5) as u32;
            }
        }
        assert!(n5 > 500, "b5 should flip about half the time: {n5}");
        assert_eq!(n45, n5, "b4 must flip whenever b5 does");
        assert_eq!(n4_only, 0, "b4 must never flip alone");
    }

    #[test]
    fn prev_value_dependency_observed() {
        // Flip prob 1.0 only for prev bin 0: only steps whose previous
        // output fell in bin 0 get errors.
        let p = params();
        let mut t = ErrorTables::zeroed(p);
        for exact in 0..=p.c_dim as u16 {
            t.set_prob(0, exact, 0, 0, 1.0);
        }
        let prec = Precision::new(2, 2);
        // Sequence of outputs: 0 (prev=0 -> bin0: flip), 30 (prev=0 -> bin0:
        // flip), 30 (prev=30 -> bin3: exact), 0 (prev=30: exact).
        let mut seq = vec![vec![0u16], vec![30u16], vec![30u16], vec![0u16]];
        let mut rng = Prng::new(5);
        t.inject(&mut seq, &GavSchedule::all_approx(prec), &mut rng);
        assert_eq!(seq, vec![vec![1], vec![31], vec![30], vec![0]]);
    }

    #[test]
    fn dense_export_shape_and_broadcast() {
        let p = params();
        let mut t = ErrorTables::zeroed(p);
        t.set_prob(p.s_bits - 1, 3, 1, 0, 0.25); // MSB, single condition
        let dense = t.to_dense();
        let nc_full = 1 << p.n_nei;
        assert_eq!(dense.len(), p.s_bits * (p.c_dim + 1) * p.p_bins * nc_full);
        // MSB's single condition is broadcast over all 4 dense slots.
        for cond in 0..nc_full {
            let idx = (((p.s_bits - 1) * (p.c_dim + 1) + 3) * p.p_bins + 1) * nc_full + cond;
            assert_eq!(dense[idx], 0.25);
        }
    }
}
