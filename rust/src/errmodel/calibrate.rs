//! Calibration of the error-model probability tables from GLS traces
//! ("The probability tables of the GAVINA model are calibrated by filling
//! the look-up tables with empirical error frequencies obtained from
//! running GLS", §IV-C).
//!
//! Coverage strategy: the 4-D index space `(bit, exact, prev_bin, cond)`
//! has ~370 k cells for the paper configuration, most of which real
//! operand streams never visit. We drive the GLS with random bit-planes of
//! *swept density* so the exact outputs cover the whole `0..=C` range (the
//! same reason the paper forces its calibration GEMMs to a uniform
//! inner-product distribution), and finalize sparse cells with
//! hierarchical back-off:
//!
//! ```text
//! (bit, exact, pbin, cond) → (bit, exact, cond) → (bit, ebin, cond)
//!                          → (bit, cond) → (bit) → 0
//! ```
//!
//! where `ebin` coarsens the exact value into `p_bins` ranges.

use super::{ErrorTables, ModelParams};
use crate::gls::GlsContext;
use crate::util::Prng;

/// Calibration run parameters.
#[derive(Clone, Copy, Debug)]
pub struct CalibrationConfig {
    /// Independent iPE streams (fresh circuit state each).
    pub n_streams: usize,
    /// Steps per stream (consecutive, so previous-value dependencies are
    /// exercised).
    pub seq_len: usize,
    /// The undervolted supply the tables describe.
    pub v_aprox: f64,
    /// Minimum observations for a cell to use its own frequency.
    pub min_count: u32,
    pub seed: u64,
}

impl Default for CalibrationConfig {
    fn default() -> Self {
        Self {
            n_streams: 2048,
            seq_len: 64,
            v_aprox: 0.35,
            min_count: 12,
            seed: 0xCA11B,
        }
    }
}

/// Calibration diagnostics.
#[derive(Clone, Debug)]
pub struct CalibrationStats {
    /// Total (step × iPE) samples ingested.
    pub samples: u64,
    /// Fraction of table cells resolved at each back-off level
    /// (0 = full 4-D index … 4 = per-bit marginal).
    pub level_fractions: [f64; 5],
    /// Empirical flip rate per output bit over the whole run.
    pub flip_rate_per_bit: Vec<f64>,
    /// Wall-clock seconds spent in GLS.
    pub gls_seconds: f64,
}

/// Raw observation counters, full-resolution only; coarser levels are
/// derived at finalize time.
struct Counts {
    params: ModelParams,
    /// Per bit: flat `[exact][pbin][cond]` pairs.
    count: Vec<Vec<u32>>,
    flip: Vec<Vec<u32>>,
}

impl Counts {
    fn new(params: ModelParams) -> Self {
        let count = (0..params.s_bits)
            .map(|b| vec![0u32; (params.c_dim + 1) * params.p_bins * params.n_cond(b)])
            .collect::<Vec<_>>();
        let flip = count.clone();
        Self {
            params,
            count,
            flip,
        }
    }

    #[inline]
    fn idx(&self, bit: usize, exact: u16, pbin: usize, cond: usize) -> usize {
        ((exact as usize) * self.params.p_bins + pbin) * self.params.n_cond(bit) + cond
    }

    /// Ingest one (exact, sampled, prev) observation.
    #[inline]
    fn observe(&mut self, exact: u16, sampled: u16, prev: u16) {
        let p = self.params;
        let pbin = p.prev_bin(prev);
        let flips = (exact ^ sampled) as u32;
        for bit in (0..p.s_bits).rev() {
            let nei = p.s_bits - 1 - bit;
            let cond = if nei == 0 {
                0
            } else {
                let take = p.n_nei.min(nei);
                ((flips >> (bit + 1)) & ((1 << take) - 1)) as usize
            };
            let i = self.idx(bit, exact, pbin, cond);
            self.count[bit][i] += 1;
            self.flip[bit][i] += ((flips >> bit) & 1) as u32;
        }
    }
}

/// Run GLS and calibrate probability tables for the given context, with
/// the paper's model hyper-parameters (`[n_nei, p_bins] = [2, 16]`).
pub fn calibrate(
    ctx: &GlsContext,
    cfg: CalibrationConfig,
) -> (ErrorTables, CalibrationStats) {
    calibrate_with_params(ctx, cfg, ModelParams::paper(ctx.nl.c_dim))
}

/// [`calibrate`] with explicit model hyper-parameters (the n_nei/p_bins
/// ablation of the model-design choices).
pub fn calibrate_with_params(
    ctx: &GlsContext,
    cfg: CalibrationConfig,
    params: ModelParams,
) -> (ErrorTables, CalibrationStats) {
    assert_eq!(params.c_dim, ctx.nl.c_dim);
    let mut counts = Counts::new(params);
    let mut rng = Prng::new(cfg.seed);
    let c = ctx.nl.c_dim;

    let t0 = std::time::Instant::now();
    let mut flip_totals = vec![0u64; params.s_bits];
    let mut samples = 0u64;
    for stream in 0..cfg.n_streams {
        let mut sim = ctx.spawn(stream as u64);
        let mut prev_exact: u16 = 0;
        // Per-stream base densities, re-jittered per step so consecutive
        // exact values are correlated (realistic) but the run as a whole
        // sweeps the range.
        let pa0 = 0.03 + 0.94 * (stream as f64 / cfg.n_streams.max(1) as f64);
        for _ in 0..cfg.seq_len {
            let pa = (pa0 + 0.25 * (rng.next_f64() - 0.5)).clamp(0.01, 0.99);
            let pb = (0.3 + 0.7 * rng.next_f64()).clamp(0.01, 0.99);
            let a: Vec<bool> = (0..c).map(|_| rng.chance(pa)).collect();
            let w: Vec<bool> = (0..c).map(|_| rng.chance(pb)).collect();
            let r = sim.step(&a, &w, cfg.v_aprox);
            counts.observe(r.exact, r.sampled, prev_exact);
            let x = r.exact ^ r.sampled;
            for (bit, ft) in flip_totals.iter_mut().enumerate() {
                *ft += ((x >> bit) & 1) as u64;
            }
            samples += 1;
            prev_exact = r.exact;
        }
    }
    let gls_seconds = t0.elapsed().as_secs_f64();

    let (tables, level_fractions) = finalize(&counts, cfg.min_count);
    let stats = CalibrationStats {
        samples,
        level_fractions,
        flip_rate_per_bit: flip_totals
            .iter()
            .map(|&f| f as f64 / samples.max(1) as f64)
            .collect(),
        gls_seconds,
    };
    (tables, stats)
}

/// Build tables directly from externally-collected (exact, sampled, prev)
/// triples — used by the tile-trace calibration path and tests.
pub fn calibrate_from_observations(
    params: ModelParams,
    observations: impl Iterator<Item = (u16, u16, u16)>,
    min_count: u32,
) -> (ErrorTables, [f64; 5]) {
    let mut counts = Counts::new(params);
    for (exact, sampled, prev) in observations {
        counts.observe(exact, sampled, prev);
    }
    finalize(&counts, min_count)
}

/// Resolve each cell with hierarchical back-off; returns per-level
/// resolution fractions.
fn finalize(counts: &Counts, min_count: u32) -> (ErrorTables, [f64; 5]) {
    let p = counts.params;
    let mut tables = ErrorTables::zeroed(p);
    let mut resolved = [0u64; 5];
    let mut total_cells = 0u64;

    // ebin: coarse exact bins, reuse p_bins granularity.
    let ebin_of = |e: usize| (e * p.p_bins / (p.c_dim + 1)).min(p.p_bins - 1);

    for bit in 0..p.s_bits {
        let nc = p.n_cond(bit);
        let cnt = &counts.count[bit];
        let flp = &counts.flip[bit];

        // Level-1 aggregates: (exact, cond) over pbin.
        let mut c1 = vec![0u64; (p.c_dim + 1) * nc];
        let mut f1 = vec![0u64; (p.c_dim + 1) * nc];
        // Level-2: (ebin, cond).
        let mut c2 = vec![0u64; p.p_bins * nc];
        let mut f2 = vec![0u64; p.p_bins * nc];
        // Level-3: (cond,). Level-4: scalar.
        let mut c3 = vec![0u64; nc];
        let mut f3 = vec![0u64; nc];
        let (mut c4, mut f4) = (0u64, 0u64);

        for e in 0..=p.c_dim {
            for pb in 0..p.p_bins {
                for cd in 0..nc {
                    let i = (e * p.p_bins + pb) * nc + cd;
                    let (cc, ff) = (cnt[i] as u64, flp[i] as u64);
                    c1[e * nc + cd] += cc;
                    f1[e * nc + cd] += ff;
                    c2[ebin_of(e) * nc + cd] += cc;
                    f2[ebin_of(e) * nc + cd] += ff;
                    c3[cd] += cc;
                    f3[cd] += ff;
                    c4 += cc;
                    f4 += ff;
                }
            }
        }

        let mc = min_count as u64;
        for e in 0..=p.c_dim {
            for pb in 0..p.p_bins {
                for cd in 0..nc {
                    let i = (e * p.p_bins + pb) * nc + cd;
                    total_cells += 1;
                    let (prob, level) = if cnt[i] as u64 >= mc {
                        (flp[i] as f64 / cnt[i] as f64, 0)
                    } else if c1[e * nc + cd] >= mc {
                        (f1[e * nc + cd] as f64 / c1[e * nc + cd] as f64, 1)
                    } else if c2[ebin_of(e) * nc + cd] >= mc {
                        (
                            f2[ebin_of(e) * nc + cd] as f64 / c2[ebin_of(e) * nc + cd] as f64,
                            2,
                        )
                    } else if c3[cd] >= mc {
                        (f3[cd] as f64 / c3[cd] as f64, 3)
                    } else if c4 >= mc {
                        (f4 as f64 / c4 as f64, 4)
                    } else {
                        (0.0, 4)
                    };
                    resolved[level] += 1;
                    tables.set_prob(bit, e as u16, pb, cd, prob as f32);
                }
            }
        }
    }

    let fractions = [
        resolved[0] as f64 / total_cells as f64,
        resolved[1] as f64 / total_cells as f64,
        resolved[2] as f64 / total_cells as f64,
        resolved[3] as f64 / total_cells as f64,
        resolved[4] as f64 / total_cells as f64,
    ];
    (tables, fractions)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchConfig, GavSchedule, Precision};
    use crate::gls::DelayModel;

    fn tiny_ctx() -> GlsContext {
        let arch = ArchConfig::tiny();
        GlsContext::new(
            arch.c_dim,
            arch.clk_period_ps() as f64,
            DelayModel::default(),
            3,
        )
    }

    #[test]
    fn synthetic_observation_calibration_recovers_rate() {
        // Feed observations where bit 1 flips iff exact >= 18: the table
        // must learn a high prob there and ~0 elsewhere.
        let params = ModelParams {
            s_bits: 6,
            c_dim: 36,
            p_bins: 4,
            n_nei: 2,
        };
        let obs = (0..36u16).cycle().take(72_00).map(|e| {
            let sampled = if e >= 18 { e ^ 2 } else { e };
            (e, sampled, e.saturating_sub(1))
        });
        let (tables, fractions) = calibrate_from_observations(params, obs, 10);
        assert!(fractions[0] > 0.0);
        // Bit-1 prob high for a large exact, low for a small one.
        let pbin_hi = params.prev_bin(25);
        let pbin_lo = params.prev_bin(4);
        assert!(tables.prob(1, 30, pbin_hi, 0) > 0.9);
        assert!(tables.prob(1, 5, pbin_lo, 0) < 0.1);
    }

    #[test]
    fn gls_calibration_smoke() {
        let ctx = tiny_ctx();
        let cfg = CalibrationConfig {
            n_streams: 40,
            seq_len: 24,
            v_aprox: 0.35,
            min_count: 8,
            seed: 5,
        };
        let (tables, stats) = calibrate(&ctx, cfg);
        assert_eq!(stats.samples, 40 * 24);
        // The tiny circuit under aggressive undervolting must show errors.
        let total_rate: f64 = stats.flip_rate_per_bit.iter().sum();
        assert!(total_rate > 0.01, "flip rates {:?}", stats.flip_rate_per_bit);
        // Tables must carry nonzero probabilities.
        let mean = tables.mean_prob_per_bit();
        assert!(mean.iter().any(|&m| m > 0.0), "{mean:?}");
        // Back-off fractions sum to 1.
        let s: f64 = stats.level_fractions.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn model_reproduces_gls_error_level() {
        // End-to-end sanity: calibrate on the tiny circuit, then compare
        // model-injected VAR_NED against a fresh GLS run on the same
        // operands — they should be within a loose band (paper: within 8%
        // on average for the real config; the tiny config is noisier).
        let ctx = tiny_ctx();
        let arch = ArchConfig::tiny();
        let (tables, _) = calibrate(
            &ctx,
            CalibrationConfig {
                n_streams: 220,
                seq_len: 32,
                v_aprox: 0.35,
                min_count: 10,
                seed: 6,
            },
        );

        let prec = Precision::new(4, 4);
        let sched = GavSchedule::all_approx(prec);
        let mut rng = Prng::new(77);
        let hi = 7i64;
        let mut gls_vars = Vec::new();
        let mut model_vars = Vec::new();
        let mut tg = crate::gls::TileGls::new(&ctx, arch.clone());
        for _ in 0..6 {
            let a: Vec<i32> = (0..arch.c_dim * arch.l_dim)
                .map(|_| rng.int_in(-hi - 1, hi) as i32)
                .collect();
            let b: Vec<i32> = (0..arch.k_dim * arch.c_dim)
                .map(|_| rng.int_in(-hi - 1, hi) as i32)
                .collect();
            let pa = crate::quant::PackedPlanes::from_a_matrix(&a, arch.c_dim, arch.l_dim, 4);
            let pb = crate::quant::PackedPlanes::from_b_matrix(&b, arch.k_dim, arch.c_dim, 4);
            let exact = crate::gemm::gemm_exact(&a, &b, arch.c_dim, arch.l_dim, arch.k_dim);

            let trace = tg.run_tile(&pa, &pb, &sched);
            gls_vars.push(crate::stats::var_ned(&exact, &trace.approx_gemm(prec)));

            let mut seq = crate::gemm::ipe_sequence(&pa, &pb);
            tables.inject(&mut seq, &sched, &mut rng);
            model_vars.push(crate::stats::var_ned(
                &exact,
                &crate::gemm::recombine(&seq, prec),
            ));
        }
        let g = crate::stats::mean(&gls_vars);
        let m = crate::stats::mean(&model_vars);
        assert!(g > 0.0, "GLS must show errors");
        assert!(m > 0.0, "model must inject errors");
        let ratio = m / g;
        assert!(
            (0.2..5.0).contains(&ratio),
            "model VAR_NED {m:.3e} vs GLS {g:.3e} (ratio {ratio:.2})"
        );
    }
}
