//! x86-64 dots for the fused bit-serial kernel: AVX2 (`vpand` + the
//! `vpshufb` nibble-LUT popcount + `vpsllvq` weighted fold), AVX-512
//! (native `vpopcntq` when AVX-512-VPOPCNTDQ is present), and the
//! Harley–Seal AVX-512 path for pre-Ice-Lake hosts (AVX-512F + BW only:
//! carry-save-adder compression so only every eighth vector pays a LUT
//! popcount), plus the AVX `dense_affine` column block. Lane semantics
//! come from [`super::StepTables`]; pointer and tail-pad contracts are
//! documented on the dispatchers in `super`.

use std::arch::x86_64::*;

use super::StepTables;

/// Per-u64-lane popcount of a 256-bit vector (AVX2 has no `vpopcntq`):
/// two `vpshufb` nibble-LUT lookups summed per 8-byte group by `vpsadbw`
/// — the classic Mula algorithm. Safe fn: every intrinsic here is pure
/// register arithmetic, unsafe only without AVX2 — which the
/// `target_feature` attribute guarantees to the body.
#[inline]
#[target_feature(enable = "avx2")]
fn popcnt_epi64_avx2(v: __m256i) -> __m256i {
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3,
        3, 4,
    );
    let low = _mm256_set1_epi8(0x0f);
    let lo = _mm256_and_si256(v, low);
    let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), low);
    let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
    _mm256_sad_epu8(cnt, _mm256_setzero_si256())
}

/// AVX2 weighted plane dot over one reduction strip: 4 A-plane lanes per
/// vector, one broadcast per B-plane word, per-lane
/// `(popcount & inc) << shift` folded with the sign trick
/// `(x ^ sign) − sign` into i64 lane accumulators; one horizontal
/// reduction per strip.
///
/// # Safety
///
/// Caller upholds the contract of `super::dot` and has verified AVX2.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn dot_avx2(
    a: *const u64,
    b: *const u64,
    words: usize,
    pa: usize,
    pb: usize,
    tab: &StepTables,
) -> i64 {
    debug_assert_eq!(tab.lanes, 4);
    let chunks = tab.chunks;
    debug_assert!(chunks <= 2 && pb <= 8);
    // SAFETY: the `super::dot` contract the caller upholds.
    // - Provenance/bounds: `a` is valid for `words * pa` u64 reads and `b`
    //   for `words * pb`; every `aw.add(ch * 4)` 4-lane load stays inside
    //   the plane-interleaved buffer because its `TAIL_PAD_WORDS` zeroed
    //   tail covers the `chunks * 4 >= pa` lane overread of the last word.
    // - Table bounds: `tab.row(bp, ch)` indexes `shifts`/`signs`/`incs`
    //   rows padded to 4 i64 lanes, so each 256-bit load is in bounds.
    // - `lanes` is a local `[i64; 4]`, exactly one 256-bit store wide.
    unsafe {
        // Hoist the lane tables out of the strip loop (loop-invariant).
        let mut shv = [_mm256_setzero_si256(); 16];
        let mut sgv = [_mm256_setzero_si256(); 16];
        let mut inv = [_mm256_setzero_si256(); 16];
        for bp in 0..pb {
            for ch in 0..chunks {
                let (i, r) = (bp * chunks + ch, tab.row(bp, ch));
                shv[i] = _mm256_loadu_si256(tab.shifts.as_ptr().add(r).cast());
                sgv[i] = _mm256_loadu_si256(tab.signs.as_ptr().add(r).cast());
                inv[i] = _mm256_loadu_si256(tab.incs.as_ptr().add(r).cast());
            }
        }
        let mut acc = [_mm256_setzero_si256(); 2];
        for w in 0..words {
            let aw = a.add(w * pa);
            let bw = b.add(w * pb);
            for bp in 0..pb {
                let bv = _mm256_set1_epi64x(*bw.add(bp) as i64);
                for ch in 0..chunks {
                    let i = bp * chunks + ch;
                    let av = _mm256_loadu_si256(aw.add(ch * 4).cast());
                    let pop = popcnt_epi64_avx2(_mm256_and_si256(av, bv));
                    let v = _mm256_sllv_epi64(_mm256_and_si256(pop, inv[i]), shv[i]);
                    let v = _mm256_sub_epi64(_mm256_xor_si256(v, sgv[i]), sgv[i]);
                    acc[ch] = _mm256_add_epi64(acc[ch], v);
                }
            }
        }
        let mut lanes = [0i64; 4];
        let mut total = 0i64;
        for &acc_ch in acc.iter().take(chunks) {
            _mm256_storeu_si256(lanes.as_mut_ptr().cast(), acc_ch);
            total += lanes.iter().sum::<i64>();
        }
        total
    }
}

/// AVX-512 weighted plane dot: all (up to) 8 A-planes of a chunk in one
/// vector, native `vpopcntq`, single reducing accumulator.
///
/// # Safety
///
/// Caller upholds the contract of `super::dot` and has verified
/// AVX-512F + AVX-512-VPOPCNTDQ.
#[target_feature(enable = "avx512f,avx512vpopcntdq")]
pub(crate) unsafe fn dot_avx512(
    a: *const u64,
    b: *const u64,
    words: usize,
    pa: usize,
    pb: usize,
    tab: &StepTables,
) -> i64 {
    debug_assert_eq!(tab.lanes, 8);
    debug_assert_eq!(tab.chunks, 1);
    debug_assert!(pb <= 8);
    // SAFETY: the `super::dot` contract the caller upholds.
    // - Provenance/bounds: `a` is valid for `words * pa` u64 reads and `b`
    //   for `words * pb`; the single 8-lane load per word stays inside the
    //   plane-interleaved buffer because its `TAIL_PAD_WORDS` zeroed tail
    //   covers the `8 >= pa` lane overread of the last word.
    // - Table bounds: `tab.row(bp, 0)` indexes `shifts`/`signs`/`incs`
    //   rows padded to 8 i64 lanes, so each 512-bit load is in bounds.
    unsafe {
        let mut shv = [_mm512_setzero_si512(); 8];
        let mut sgv = [_mm512_setzero_si512(); 8];
        let mut inv = [_mm512_setzero_si512(); 8];
        for bp in 0..pb {
            let r = tab.row(bp, 0);
            shv[bp] = _mm512_loadu_epi64(tab.shifts.as_ptr().add(r).cast());
            sgv[bp] = _mm512_loadu_epi64(tab.signs.as_ptr().add(r).cast());
            inv[bp] = _mm512_loadu_epi64(tab.incs.as_ptr().add(r).cast());
        }
        let mut acc = _mm512_setzero_si512();
        for w in 0..words {
            let av = _mm512_loadu_epi64(a.add(w * pa).cast());
            let bw = b.add(w * pb);
            for bp in 0..pb {
                let bv = _mm512_set1_epi64(*bw.add(bp) as i64);
                let pop = _mm512_popcnt_epi64(_mm512_and_si512(av, bv));
                let v = _mm512_sllv_epi64(_mm512_and_si512(pop, inv[bp]), shv[bp]);
                let v = _mm512_sub_epi64(_mm512_xor_si512(v, sgv[bp]), sgv[bp]);
                acc = _mm512_add_epi64(acc, v);
            }
        }
        _mm512_reduce_add_epi64(acc)
    }
}

/// Per-u64-lane popcount of a 512-bit vector without `vpopcntq`: the
/// Mula nibble-LUT (as in [`popcnt_epi64_avx2`]) widened to 512 bits —
/// `vpshufb`, `vpsrlw` and `vpsadbw` at this width need only AVX-512BW.
/// Safe fn: every intrinsic here is pure register arithmetic, unsafe
/// only without the features the `target_feature` attribute guarantees
/// to the body.
#[inline]
#[target_feature(enable = "avx512f,avx512bw")]
fn popcnt_epi64_avx512bw(v: __m512i) -> __m512i {
    let lut = _mm512_broadcast_i32x4(_mm_setr_epi8(0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4));
    let low = _mm512_set1_epi8(0x0f);
    let lo = _mm512_and_si512(v, low);
    let hi = _mm512_and_si512(_mm512_srli_epi16::<4>(v), low);
    let cnt = _mm512_add_epi8(_mm512_shuffle_epi8(lut, lo), _mm512_shuffle_epi8(lut, hi));
    _mm512_sad_epu8(cnt, _mm512_setzero_si512())
}

/// Carry-save adder over three bit-vectors in one `vpternlogq` pair:
/// returns `(carry, sum)` with `a + b + c = 2·carry + sum` per bit
/// position (imm `0x96` = three-way XOR, `0xE8` = majority). Safe fn:
/// register arithmetic only, guarded by the `target_feature` attribute.
#[inline]
#[target_feature(enable = "avx512f")]
fn csa(a: __m512i, b: __m512i, c: __m512i) -> (__m512i, __m512i) {
    let sum = _mm512_ternarylogic_epi64::<0x96>(a, b, c);
    let carry = _mm512_ternarylogic_epi64::<0xE8>(a, b, c);
    (carry, sum)
}

/// Harley–Seal AVX-512 weighted plane dot for hosts **without**
/// `vpopcntq` (pre-Ice-Lake Skylake-X/Cascade Lake): per B-plane, the
/// strip's AND-ed chunk vectors are compressed eight at a time through a
/// carry-save-adder tree (`ones`/`twos`/`fours` partial bit-sums), so
/// only one [`popcnt_epi64_avx512bw`] runs per 8 input vectors (weighted
/// `× 8`); the tree is drained (`× 4`, `× 2`, `× 1`) and remainder words
/// counted directly. The per-lane step weighting
/// `sign · (count << (ba+bb))` is applied **once per strip** to the
/// accumulated counts — exact because shift and sign are constant per
/// `(lane, b_plane)` and distribute over the integer sum, and because
/// `inc` masks zero dead/garbage lanes before the shift (counts stay
/// ≪ 2⁶³ · 2⁻¹⁴, so no overflow).
///
/// # Safety
///
/// Caller upholds the contract of `super::dot` and has verified
/// AVX-512F + AVX-512BW.
#[target_feature(enable = "avx512f,avx512bw")]
pub(crate) unsafe fn dot_avx512hs(
    a: *const u64,
    b: *const u64,
    words: usize,
    pa: usize,
    pb: usize,
    tab: &StepTables,
) -> i64 {
    debug_assert_eq!(tab.lanes, 8);
    debug_assert_eq!(tab.chunks, 1);
    debug_assert!(pb <= 8);
    // SAFETY: the `super::dot` contract the caller upholds.
    // - Provenance/bounds: `a` is valid for `words * pa` u64 reads and `b`
    //   for `words * pb`; every 8-lane chunk load stays inside the
    //   plane-interleaved buffer because its `TAIL_PAD_WORDS` zeroed tail
    //   covers the `8 >= pa` lane overread of the last word (lanes past
    //   `pa` carry garbage counts that `inv` masks to zero at fold time,
    //   exactly as in `dot_avx512`).
    // - Table bounds: `tab.row(bp, 0)` indexes `shifts`/`signs`/`incs`
    //   rows padded to 8 i64 lanes, so each 512-bit load is in bounds.
    unsafe {
        let mut shv = [_mm512_setzero_si512(); 8];
        let mut sgv = [_mm512_setzero_si512(); 8];
        let mut inv = [_mm512_setzero_si512(); 8];
        for bp in 0..pb {
            let r = tab.row(bp, 0);
            shv[bp] = _mm512_loadu_epi64(tab.shifts.as_ptr().add(r).cast());
            sgv[bp] = _mm512_loadu_epi64(tab.signs.as_ptr().add(r).cast());
            inv[bp] = _mm512_loadu_epi64(tab.incs.as_ptr().add(r).cast());
        }
        let mut acc = _mm512_setzero_si512();
        for bp in 0..pb {
            // One AND-ed chunk vector of this B-plane's strip.
            macro_rules! xw {
                ($w:expr) => {
                    _mm512_and_si512(
                        _mm512_loadu_epi64(a.add(($w) * pa).cast()),
                        _mm512_set1_epi64(*b.add(($w) * pb + bp) as i64),
                    )
                };
            }
            let mut ones = _mm512_setzero_si512();
            let mut twos = _mm512_setzero_si512();
            let mut fours = _mm512_setzero_si512();
            let mut count = _mm512_setzero_si512();
            let mut w = 0usize;
            while w + 8 <= words {
                let (t0, s0) = csa(ones, xw!(w), xw!(w + 1));
                let (t1, s1) = csa(s0, xw!(w + 2), xw!(w + 3));
                let (t2, s2) = csa(s1, xw!(w + 4), xw!(w + 5));
                let (t3, s3) = csa(s2, xw!(w + 6), xw!(w + 7));
                ones = s3;
                let (f0, tw0) = csa(twos, t0, t1);
                let (f1, tw1) = csa(tw0, t2, t3);
                twos = tw1;
                let (eights, f2) = csa(fours, f0, f1);
                fours = f2;
                count = _mm512_add_epi64(
                    count,
                    _mm512_slli_epi64::<3>(popcnt_epi64_avx512bw(eights)),
                );
                w += 8;
            }
            count = _mm512_add_epi64(count, _mm512_slli_epi64::<2>(popcnt_epi64_avx512bw(fours)));
            count = _mm512_add_epi64(count, _mm512_slli_epi64::<1>(popcnt_epi64_avx512bw(twos)));
            count = _mm512_add_epi64(count, popcnt_epi64_avx512bw(ones));
            while w < words {
                count = _mm512_add_epi64(count, popcnt_epi64_avx512bw(xw!(w)));
                w += 1;
            }
            // Deferred weighted fold: sign · (count << shift) per lane.
            let v = _mm512_sllv_epi64(_mm512_and_si512(count, inv[bp]), shv[bp]);
            let v = _mm512_sub_epi64(_mm512_xor_si512(v, sgv[bp]), sgv[bp]);
            acc = _mm512_add_epi64(acc, v);
        }
        _mm512_reduce_add_epi64(acc)
    }
}

/// AVX `dense_affine` column block over 8 output classes: broadcast each
/// input, multiply by the 8-wide weight row, then add — two separate
/// roundings per term, exactly like the scalar `acc += x * w`, so every
/// lane is bit-identical to the scalar loop.
///
/// # Safety
///
/// Caller upholds the contract of `super::affine_cols` and has verified
/// AVX2 (which implies AVX).
#[target_feature(enable = "avx")]
pub(crate) unsafe fn affine_cols8_avx(
    x: *const f32,
    w: *const f32,
    stride: usize,
    cin: usize,
    bias: *const f32,
    out: *mut f32,
) {
    // SAFETY: the `super::affine_cols` contract the caller upholds:
    // `x` is valid for `cin` f32 reads, `bias` and `out` for 8 each, and
    // `w.add(ci * stride)` for 8 reads at every `ci < cin` — the caller
    // only takes this path when a full 8-column block is in bounds.
    unsafe {
        let mut acc = _mm256_loadu_ps(bias);
        for ci in 0..cin {
            let xv = _mm256_set1_ps(*x.add(ci));
            let wv = _mm256_loadu_ps(w.add(ci * stride));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, wv));
        }
        _mm256_storeu_ps(out, acc);
    }
}
