//! Runtime-dispatched SIMD backends for the fused bit-serial micro-kernel.
//!
//! The fused kernel's inner operation — AND + popcount between every
//! `(a_plane, b_plane)` pair of one 64-element C-chunk, weighted by
//! `sign · 2^(ba+bb)` — is exactly the shape vector ISAs popcount
//! fastest, and the interleaved layout (`[vec][word][plane]`, see
//! [`crate::quant::InterleavedPlanes`]) already stores all A-planes of a
//! chunk contiguously. So the vector axis here is the **plane axis**: one
//! load grabs `LANES` A-plane words, one broadcast splats a B-plane word,
//! and a single AND + per-lane popcount retires `LANES` significance
//! steps at once. Per-lane shift/sign/include tables ([`StepTables`])
//! then fold the step weights in-register, with lanes past `a_bits` (and
//! masked-out steps) zeroed by their include mask — full, masked and
//! multithreaded GEMM all run the same code path.
//!
//! One implementation is selected **once per process** by [`active`], in
//! detection order AVX-512 → AVX-512-HS → AVX2 → NEON → scalar:
//!
//! | kind | ISA | per-lane popcount | u64 lanes |
//! |------|-----|-------------------|-----------|
//! | `avx512` | AVX-512F + AVX-512-VPOPCNTDQ | `vpopcntq` | 8 |
//! | `avx512hs` | AVX-512F + AVX-512BW | Harley–Seal CSA tree + `vpshufb` LUT | 8 |
//! | `avx2` | AVX2 | `vpshufb` nibble LUT + `vpsadbw` (Mula) | 4 |
//! | `neon` | AArch64 NEON | `cnt` + pairwise widening adds | 2 |
//! | `scalar` | portable | `u64::count_ones` | 1 |
//!
//! `avx512hs` is the pre-Ice-Lake x86 tier: 512-bit vectors without
//! `vpopcntq`, so eight AND-ed vectors at a time are compressed through a
//! carry-save-adder tree (`vpternlogq`) and only every eighth vector pays
//! the byte-LUT popcount — the Harley–Seal construction.
//!
//! `GAVINA_KERNEL=scalar|avx2|avx512|avx512hs|neon` overrides detection
//! (the CI matrix pins its forced-scalar job with it, and probes for an
//! `avx512hs` host); requesting a kernel the host cannot run aborts
//! loudly rather than silently falling back.
//! `GAVINA_BLOCK=<c_words>x<l_cols>` likewise pins the cache-block shape
//! that [`block_shape`] otherwise autotunes at first use.
//!
//! Every SIMD path is pinned bit-identical to the scalar kernel by the
//! per-kernel property matrix in [`super::kernel`]; exactness never
//! depends on which path ran (the outputs are exact `i64` sums, so any
//! lane/block order is the same sum).

#[cfg(target_arch = "aarch64")]
mod aarch64;
#[cfg(target_arch = "x86_64")]
mod x86;

use std::sync::OnceLock;

use super::kernel::{plane_steps, PlaneStep};
use crate::quant::InterleavedPlanes;

/// One fused-kernel implementation, selectable at runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Portable `u64::count_ones` register-block kernel — always
    /// available, and the ground truth every SIMD path is tested against.
    Scalar,
    /// 256-bit AVX2: `vpand` + the `vpshufb` nibble-LUT popcount.
    Avx2,
    /// 512-bit AVX-512: native `vpopcntq` (needs AVX-512-VPOPCNTDQ), all
    /// 8 planes of an a8 operand in one vector.
    Avx512,
    /// 512-bit AVX-512 without `vpopcntq` (pre-Ice-Lake: needs only
    /// AVX-512F + AVX-512BW): Harley–Seal carry-save-adder compression
    /// over 8 vectors per LUT popcount.
    Avx512Hs,
    /// 128-bit NEON: `and` + `cnt` with pairwise widening adds.
    Neon,
}

impl KernelKind {
    /// Stable lowercase name — the `GAVINA_KERNEL` vocabulary and the
    /// kernel tag in `BENCH_hotpath.json`.
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Avx2 => "avx2",
            KernelKind::Avx512 => "avx512",
            KernelKind::Avx512Hs => "avx512hs",
            KernelKind::Neon => "neon",
        }
    }

    /// Parse a [`Self::name`] (the values `GAVINA_KERNEL` accepts).
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelKind::Scalar),
            "avx2" => Some(KernelKind::Avx2),
            "avx512" => Some(KernelKind::Avx512),
            "avx512hs" => Some(KernelKind::Avx512Hs),
            "neon" => Some(KernelKind::Neon),
            _ => None,
        }
    }

    /// `u64` bit-plane lanes one vector of this ISA carries.
    pub fn lanes(self) -> usize {
        match self {
            KernelKind::Scalar => 1,
            KernelKind::Avx2 => 4,
            KernelKind::Avx512 | KernelKind::Avx512Hs => 8,
            KernelKind::Neon => 2,
        }
    }

    /// f32 lanes of the vectorized `dense_affine` column block (0 means
    /// the scalar path handles everything).
    pub(crate) fn f32_lanes(self) -> usize {
        match self {
            KernelKind::Scalar => 0,
            // avx512hs implies AVX2, whose 8-wide AVX float block is all
            // the f32 head needs.
            KernelKind::Avx2 | KernelKind::Avx512 | KernelKind::Avx512Hs => 8,
            KernelKind::Neon => 4,
        }
    }
}

impl std::fmt::Display for KernelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Detection preference order (best first); [`KernelKind::Scalar`] is the
/// implicit fallback.
const PREFERENCE: [KernelKind; 4] = [
    KernelKind::Avx512,
    KernelKind::Avx512Hs,
    KernelKind::Avx2,
    KernelKind::Neon,
];

/// Can this host execute `kind`?
pub fn is_available(kind: KernelKind) -> bool {
    match kind {
        KernelKind::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        KernelKind::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
        #[cfg(target_arch = "x86_64")]
        KernelKind::Avx512 => {
            std::arch::is_x86_feature_detected!("avx512f")
                && std::arch::is_x86_feature_detected!("avx512vpopcntdq")
        }
        #[cfg(target_arch = "x86_64")]
        KernelKind::Avx512Hs => {
            std::arch::is_x86_feature_detected!("avx512f")
                && std::arch::is_x86_feature_detected!("avx512bw")
        }
        #[cfg(target_arch = "aarch64")]
        KernelKind::Neon => std::arch::is_aarch64_feature_detected!("neon"),
        _ => false,
    }
}

/// Every kernel this host can run — [`KernelKind::Scalar`] first, then
/// the detected SIMD paths in preference order. The per-kernel property
/// tests in [`super::kernel`] iterate this.
pub fn available() -> Vec<KernelKind> {
    let mut v = vec![KernelKind::Scalar];
    v.extend(PREFERENCE.iter().copied().filter(|&k| is_available(k)));
    v
}

fn detect_best() -> KernelKind {
    PREFERENCE
        .into_iter()
        .find(|&k| is_available(k))
        .unwrap_or(KernelKind::Scalar)
}

/// The kernel the exported entry points ([`super::kernel::fused_gemm`]
/// and friends) run on, resolved once per process: the `GAVINA_KERNEL`
/// override if set and non-empty (it must name an available kernel — an
/// impossible request panics rather than silently falling back), else
/// the best detected path.
pub fn active() -> KernelKind {
    static ACTIVE: OnceLock<KernelKind> = OnceLock::new();
    *ACTIVE.get_or_init(|| match std::env::var("GAVINA_KERNEL") {
        Ok(s) if !s.trim().is_empty() => {
            let kind = KernelKind::parse(&s).unwrap_or_else(|| {
                panic!("GAVINA_KERNEL='{s}': expected scalar|avx2|avx512|avx512hs|neon")
            });
            assert!(
                is_available(kind),
                "GAVINA_KERNEL={} requested but this host cannot run it",
                kind.name()
            );
            kind
        }
        _ => detect_best(),
    })
}

/// Cache-block shape of the SIMD loop nest: the fused GEMM walks
/// `c_words`-word slices of the reduction axis (an L1-resident strip of
/// plane data) across `l_cols` output columns at a time (the A-panel a
/// B-row is reused against before it leaves L2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockShape {
    /// 64-element C-chunks per reduction strip.
    pub c_words: usize,
    /// Output columns sharing one resident A-panel.
    pub l_cols: usize,
}

impl BlockShape {
    /// The fallback shape (also what Miri and scalar-only hosts report):
    /// an 8 KiB-per-vector a8 reduction strip × 8 columns ≈ 64 KiB panel.
    pub const DEFAULT: BlockShape = BlockShape {
        c_words: 128,
        l_cols: 8,
    };
}

/// Candidate shapes the first-use autotuner times.
const CANDIDATES: [BlockShape; 3] = [
    BlockShape {
        c_words: 64,
        l_cols: 8,
    },
    BlockShape {
        c_words: 128,
        l_cols: 8,
    },
    BlockShape {
        c_words: 256,
        l_cols: 16,
    },
];

/// The block shape the SIMD loop nest runs with, resolved once per
/// process: `GAVINA_BLOCK=<c_words>x<l_cols>` if set, else the fastest
/// candidate by a one-shot timing of a synthetic a4w4 tile on the active
/// kernel (≲ 10 ms, amortized over the process). Scalar-only hosts and
/// Miri (which has no clock) skip the timing and report
/// [`BlockShape::DEFAULT`].
pub fn block_shape() -> BlockShape {
    static SHAPE: OnceLock<BlockShape> = OnceLock::new();
    *SHAPE.get_or_init(|| {
        if let Ok(s) = std::env::var("GAVINA_BLOCK") {
            if !s.trim().is_empty() {
                return parse_block(&s).unwrap_or_else(|| {
                    panic!("GAVINA_BLOCK='{s}': expected <c_words>x<l_cols>, e.g. 128x8")
                });
            }
        }
        let kind = active();
        if kind == KernelKind::Scalar || cfg!(miri) {
            return BlockShape::DEFAULT;
        }
        autotune(kind)
    })
}

fn parse_block(s: &str) -> Option<BlockShape> {
    let (c, l) = s.trim().split_once('x')?;
    let c_words: usize = c.trim().parse().ok()?;
    let l_cols: usize = l.trim().parse().ok()?;
    if c_words == 0 || l_cols == 0 {
        return None;
    }
    Some(BlockShape { c_words, l_cols })
}

/// Time each candidate on a synthetic tile big enough to spill L1 and
/// keep the fastest. Deliberately tiny: the point is to pick between
/// *cache* strategies per target at first use, not to run a full search.
fn autotune(kind: KernelKind) -> BlockShape {
    use crate::arch::Precision;
    use crate::util::Prng;
    let prec = Precision::new(4, 4);
    let (c, l, k) = (16384usize, 16usize, 8usize);
    let mut rng = Prng::new(0xB10C);
    let a: Vec<i32> = (0..c * l).map(|_| rng.int_in(-7, 7) as i32).collect();
    let b: Vec<i32> = (0..k * c).map(|_| rng.int_in(-7, 7) as i32).collect();
    let ia = InterleavedPlanes::from_a_matrix(&a, c, l, prec.a_bits);
    let ib = InterleavedPlanes::from_b_matrix(&b, k, c, prec.b_bits);
    let steps = plane_steps(prec, |_| true);
    let mut out = vec![0i64; k * l];
    let mut best = (BlockShape::DEFAULT, f64::INFINITY);
    for &shape in &CANDIDATES {
        // One warm-up, then keep the best of two reps (least noise).
        fused_rows_shaped(kind, shape, &ia, &ib, &steps, 0, &mut out);
        let mut secs = f64::INFINITY;
        for _ in 0..2 {
            let t0 = std::time::Instant::now();
            fused_rows_shaped(kind, shape, &ia, &ib, &steps, 0, &mut out);
            secs = secs.min(t0.elapsed().as_secs_f64());
        }
        if secs < best.1 {
            best = (shape, secs);
        }
    }
    best.0
}

/// Per-`(b_plane, lane-chunk)` lane tables encoding the significance-step
/// grid for the SIMD dots: left-shift counts `ba + bb`, sign masks
/// (all-ones where `step_weight < 0`) and include masks (all-ones where
/// the step participates; zero both for masked-out steps and for padding
/// lanes past `a_bits`). Built once per GEMM from the same `PlaneStep`
/// list the scalar kernel walks, so the two paths cannot disagree about
/// a step's weight.
pub(crate) struct StepTables {
    pub(crate) lanes: usize,
    pub(crate) chunks: usize,
    pub(crate) shifts: Vec<u64>,
    pub(crate) signs: Vec<u64>,
    pub(crate) incs: Vec<u64>,
}

impl StepTables {
    pub(crate) fn new(steps: &[PlaneStep], pa: usize, pb: usize, lanes: usize) -> Self {
        debug_assert!(lanes > 1 && lanes <= 8);
        let chunks = pa.div_ceil(lanes);
        let n = pb * chunks * lanes;
        let mut shifts = vec![0u64; n];
        let mut signs = vec![0u64; n];
        let mut incs = vec![0u64; n];
        for st in steps {
            debug_assert!(st.a_plane < pa && st.b_plane < pb);
            let idx = (st.b_plane * chunks + st.a_plane / lanes) * lanes + st.a_plane % lanes;
            let sh = (st.a_plane + st.b_plane) as u32;
            debug_assert_eq!(
                st.weight.unsigned_abs(),
                1u64 << sh,
                "step weight must be ±2^(ba+bb)"
            );
            shifts[idx] = sh as u64;
            signs[idx] = if st.weight < 0 { u64::MAX } else { 0 };
            incs[idx] = u64::MAX;
        }
        Self {
            lanes,
            chunks,
            shifts,
            signs,
            incs,
        }
    }

    /// Flat index of `(b_plane, chunk)`'s first lane.
    #[inline]
    pub(crate) fn row(&self, bp: usize, chunk: usize) -> usize {
        (bp * self.chunks + chunk) * self.lanes
    }
}

/// SIMD row-block worker — the vector analogue of the scalar
/// `fused_rows`: computes output rows `k0 ..` of the fused GEMM into
/// `out_block` with `shape` cache blocking, dispatching each reduction
/// strip to `kind`'s dot kernel.
///
/// Exactness: every output is an exact `i64` sum of step contributions,
/// and integer addition is associative and commutative, so any blocking
/// and lane order yields the identical value to the scalar kernel.
pub(crate) fn fused_rows_shaped(
    kind: KernelKind,
    shape: BlockShape,
    a: &InterleavedPlanes,
    b: &InterleavedPlanes,
    steps: &[PlaneStep],
    k0: usize,
    out_block: &mut [i64],
) {
    let l_dim = a.n_vecs;
    if out_block.is_empty() || l_dim == 0 {
        return;
    }
    debug_assert_eq!(a.c_dim, b.c_dim);
    debug_assert_eq!(out_block.len() % l_dim, 0);
    let words = a.words;
    let (pa, pb) = (a.bits as usize, b.bits as usize);
    let rows = out_block.len() / l_dim;
    out_block.fill(0);
    if words == 0 {
        return;
    }
    let tab = StepTables::new(steps, pa, pb, kind.lanes());
    // Pointers derive from the *padded* backing store (`raw`), not from
    // per-vector subslices: the last partial-chunk load of a strip may
    // read up to `lanes − 1` words past the strip's A-plane words, which
    // the InterleavedPlanes tail pad keeps inside the borrow (see the
    // layout contract in `quant::interleaved`).
    let araw = a.raw();
    let braw = b.raw();
    assert!(kind.lanes() <= InterleavedPlanes::TAIL_PAD_WORDS + 1);
    let (a_stride, b_stride) = (words * pa, words * pb);
    for lb0 in (0..l_dim).step_by(shape.l_cols) {
        let lbn = shape.l_cols.min(l_dim - lb0);
        for cb0 in (0..words).step_by(shape.c_words) {
            let cbn = shape.c_words.min(words - cb0);
            for r in 0..rows {
                let b_off = (k0 + r) * b_stride + cb0 * pb;
                for dl in 0..lbn {
                    let a_off = (lb0 + dl) * a_stride + cb0 * pa;
                    // SAFETY: `a_off`/`b_off` index live words; the dot
                    // reads at most `cbn·pa + lanes − 2` A words past
                    // `a_off` and `cbn·pb − 1` B words past `b_off`, all
                    // within `raw()` (tail-pad contract). `kind` was
                    // checked available by the public `_with` entry.
                    let v = unsafe {
                        dot(
                            kind,
                            araw.as_ptr().add(a_off),
                            braw.as_ptr().add(b_off),
                            cbn,
                            pa,
                            pb,
                            &tab,
                        )
                    };
                    out_block[r * l_dim + lb0 + dl] += v;
                }
            }
        }
    }
}

/// Dispatch one reduction-strip dot product to `kind`'s ISA module.
///
/// # Safety
///
/// `kind` must be SIMD (not scalar) and available on this host; `a`/`b`
/// must point at `words` interleaved chunks of `pa`/`pb` plane words
/// each, with at least `kind.lanes() − 1` readable words past the final
/// A chunk (the tail-pad contract of `InterleavedPlanes`); `tab` must be
/// built with `kind.lanes()` lanes for the same `pa`/`pb`.
#[inline]
unsafe fn dot(
    kind: KernelKind,
    a: *const u64,
    b: *const u64,
    words: usize,
    pa: usize,
    pb: usize,
    tab: &StepTables,
) -> i64 {
    let _ = (a, b, words, pa, pb, tab);
    // SAFETY: this fn's contract is forwarded verbatim to the ISA callee —
    // the caller guarantees `kind` is available on this host (so the
    // callee's `target_feature` precondition holds) and that the pointer,
    // tail-pad and `tab` obligations above are met.
    unsafe {
        match kind {
            #[cfg(target_arch = "x86_64")]
            KernelKind::Avx2 => x86::dot_avx2(a, b, words, pa, pb, tab),
            #[cfg(target_arch = "x86_64")]
            KernelKind::Avx512 => x86::dot_avx512(a, b, words, pa, pb, tab),
            #[cfg(target_arch = "x86_64")]
            KernelKind::Avx512Hs => x86::dot_avx512hs(a, b, words, pa, pb, tab),
            #[cfg(target_arch = "aarch64")]
            KernelKind::Neon => aarch64::dot_neon(a, b, words, pa, pb, tab),
            _ => unreachable!("no SIMD dot for kernel '{}' on this target", kind.name()),
        }
    }
}

/// Vectorized `dense_affine` column block: `out[0..f32_lanes] = bias +
/// Σ_ci x[ci] · w[ci · stride + ..]`, with one multiply **then** one add
/// per term (never an FMA), so each lane reproduces the scalar
/// accumulation's rounding sequence bit for bit.
///
/// # Safety
///
/// `kind` must be SIMD, available, with `f32_lanes() > 0`; `x` must have
/// `cin` readable f32s, `w` at least `(cin − 1) · stride + f32_lanes()`,
/// and `bias`/`out` at least `f32_lanes()`.
pub(crate) unsafe fn affine_cols(
    kind: KernelKind,
    x: *const f32,
    w: *const f32,
    stride: usize,
    cin: usize,
    bias: *const f32,
    out: *mut f32,
) {
    let _ = (x, w, stride, cin, bias, out);
    // SAFETY: this fn's contract is forwarded verbatim to the ISA callee —
    // the caller guarantees `kind` is available with `f32_lanes() > 0`
    // (so the callee's `target_feature` precondition holds) and that
    // `x`/`w`/`bias`/`out` cover the lane counts documented above.
    unsafe {
        match kind {
            #[cfg(target_arch = "x86_64")]
            KernelKind::Avx2 | KernelKind::Avx512 | KernelKind::Avx512Hs => {
                x86::affine_cols8_avx(x, w, stride, cin, bias, out)
            }
            #[cfg(target_arch = "aarch64")]
            KernelKind::Neon => aarch64::affine_cols4_neon(x, w, stride, cin, bias, out),
            _ => unreachable!("no SIMD affine for kernel '{}' on this target", kind.name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Precision;

    #[test]
    fn scalar_always_available_and_active_is_available() {
        assert!(is_available(KernelKind::Scalar));
        let av = available();
        assert_eq!(av[0], KernelKind::Scalar);
        assert!(av.contains(&active()), "active kernel must be available");
        for k in av {
            assert!(is_available(k), "{}", k.name());
        }
    }

    #[test]
    fn kernel_names_roundtrip() {
        for k in [
            KernelKind::Scalar,
            KernelKind::Avx2,
            KernelKind::Avx512,
            KernelKind::Avx512Hs,
            KernelKind::Neon,
        ] {
            assert_eq!(KernelKind::parse(k.name()), Some(k));
            assert_eq!(format!("{k}"), k.name());
        }
        assert_eq!(KernelKind::parse(" AVX2 "), Some(KernelKind::Avx2));
        assert_eq!(KernelKind::parse("mmx"), None);
        assert_eq!(KernelKind::parse(""), None);
    }

    #[test]
    fn block_shape_parses_and_resolves() {
        assert_eq!(
            parse_block("128x8"),
            Some(BlockShape {
                c_words: 128,
                l_cols: 8
            })
        );
        assert_eq!(
            parse_block(" 64 x 4 "),
            Some(BlockShape {
                c_words: 64,
                l_cols: 4
            })
        );
        assert_eq!(parse_block("0x4"), None);
        assert_eq!(parse_block("abc"), None);
        let s = block_shape();
        assert!(s.c_words > 0 && s.l_cols > 0);
    }

    #[test]
    fn step_tables_encode_the_weight_grid() {
        // Every (ba, bb) lane carries shift = ba + bb and the sign of the
        // step weight; dead lanes past a_bits are excluded.
        let prec = Precision::new(3, 5);
        let steps = plane_steps(prec, |_| true);
        let tab = StepTables::new(&steps, 3, 5, 4);
        assert_eq!(tab.chunks, 1);
        for bb in 0..5usize {
            for ba in 0..4usize {
                let idx = tab.row(bb, 0) + ba;
                if ba >= 3 {
                    assert_eq!(tab.incs[idx], 0, "dead lane must be excluded");
                    continue;
                }
                assert_eq!(tab.incs[idx], u64::MAX);
                assert_eq!(tab.shifts[idx], (ba + bb) as u64);
                let w = prec.step_weight(ba as u8, bb as u8);
                assert_eq!(tab.signs[idx] == u64::MAX, w < 0, "ba={ba} bb={bb}");
            }
        }
        // A masked subset zeroes exactly the excluded steps' lanes.
        let masked = plane_steps(prec, |t| t % 2 == 0);
        let mtab = StepTables::new(&masked, 3, 5, 4);
        let n_inc = mtab.incs.iter().filter(|&&m| m == u64::MAX).count();
        assert_eq!(n_inc, masked.len());
    }
}
