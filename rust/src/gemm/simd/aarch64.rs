//! AArch64 NEON dots for the fused bit-serial kernel: `and` + `cnt`
//! (per-byte popcount) with pairwise widening adds up to u64 lanes, plus
//! the NEON `dense_affine` column block. Lane semantics come from
//! [`super::StepTables`]; pointer and tail-pad contracts are documented
//! on the dispatchers in `super`.

use std::arch::aarch64::*;

use super::StepTables;

/// NEON weighted plane dot over one reduction strip: 2 A-plane lanes per
/// vector (up to 4 chunks for a8), per-lane popcount via
/// `cnt` → `vpaddlq_u8/u16/u32`, weighted fold with `vshlq_u64` and the
/// `(x ^ sign) − sign` trick into i64 lane accumulators.
///
/// # Safety
///
/// Caller upholds the contract of `super::dot` and has verified NEON.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn dot_neon(
    a: *const u64,
    b: *const u64,
    words: usize,
    pa: usize,
    pb: usize,
    tab: &StepTables,
) -> i64 {
    debug_assert_eq!(tab.lanes, 2);
    let chunks = tab.chunks;
    debug_assert!(chunks <= 4 && pb <= 8);
    // SAFETY: the `super::dot` contract the caller upholds.
    // - Provenance/bounds: `a` is valid for `words * pa` u64 reads and `b`
    //   for `words * pb`; every `aw.add(ch * 2)` 2-lane load stays inside
    //   the plane-interleaved buffer because its `TAIL_PAD_WORDS` zeroed
    //   tail covers the `chunks * 2 >= pa` lane overread of the last word.
    // - Table bounds: `tab.row(bp, ch)` indexes `shifts`/`signs`/`incs`
    //   rows padded to 2 u64 lanes, so each 128-bit load is in bounds.
    unsafe {
        // Hoist the lane tables out of the strip loop (loop-invariant).
        let mut shv = [vdupq_n_s64(0); 32];
        let mut sgv = [vdupq_n_s64(0); 32];
        let mut inv = [vdupq_n_u64(0); 32];
        for bp in 0..pb {
            for ch in 0..chunks {
                let (i, r) = (bp * chunks + ch, tab.row(bp, ch));
                shv[i] = vld1q_s64(tab.shifts.as_ptr().add(r).cast());
                sgv[i] = vld1q_s64(tab.signs.as_ptr().add(r).cast());
                inv[i] = vld1q_u64(tab.incs.as_ptr().add(r));
            }
        }
        let mut acc = [vdupq_n_s64(0); 4];
        for w in 0..words {
            let aw = a.add(w * pa);
            let bw = b.add(w * pb);
            for bp in 0..pb {
                let bv = vdupq_n_u64(*bw.add(bp));
                for ch in 0..chunks {
                    let i = bp * chunks + ch;
                    let av = vld1q_u64(aw.add(ch * 2));
                    let anded = vandq_u64(av, bv);
                    let bytes = vcntq_u8(vreinterpretq_u8_u64(anded));
                    let pop = vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(bytes)));
                    let v = vreinterpretq_s64_u64(vshlq_u64(vandq_u64(pop, inv[i]), shv[i]));
                    let v = vsubq_s64(veorq_s64(v, sgv[i]), sgv[i]);
                    acc[ch] = vaddq_s64(acc[ch], v);
                }
            }
        }
        let mut total = 0i64;
        for &acc_ch in acc.iter().take(chunks) {
            total += vaddvq_s64(acc_ch);
        }
        total
    }
}

/// NEON `dense_affine` column block over 4 output classes: broadcast each
/// input, multiply by the 4-wide weight row, then add — two separate
/// roundings per term (no fused multiply-add), exactly like the scalar
/// `acc += x * w`, so every lane is bit-identical to the scalar loop.
///
/// # Safety
///
/// Caller upholds the contract of `super::affine_cols` and has verified
/// NEON.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn affine_cols4_neon(
    x: *const f32,
    w: *const f32,
    stride: usize,
    cin: usize,
    bias: *const f32,
    out: *mut f32,
) {
    // SAFETY: the `super::affine_cols` contract the caller upholds:
    // `x` is valid for `cin` f32 reads, `bias` and `out` for 4 each, and
    // `w.add(ci * stride)` for 4 reads at every `ci < cin` — the caller
    // only takes this path when a full 4-column block is in bounds.
    unsafe {
        let mut acc = vld1q_f32(bias);
        for ci in 0..cin {
            let xv = vdupq_n_f32(*x.add(ci));
            let wv = vld1q_f32(w.add(ci * stride));
            acc = vaddq_f32(acc, vmulq_f32(xv, wv));
        }
        vst1q_f32(out, acc);
    }
}
