//! The fused plane-interleaved bit-serial GEMM micro-kernel.
//!
//! The reference composition ([`super::bitserial_gemm_ref`]) mirrors the
//! hardware's control flow: one binary-plane GEMM per `(ba, bb)`
//! significance step — `a_bits × b_bits` full passes over the packed
//! operands (64 at a8w8), each materializing a `[K, L]` `u16` step buffer
//! that a separate shift-accumulate pass then folds into the `i64`
//! product. The paper's energy/error argument only needs that per-step
//! output *sequence* on undervolted steps; the exact compute path is free
//! to exploit that the bit-serial decomposition is associative over
//! significance steps and fuse the whole loop.
//!
//! This kernel does exactly that, over [`InterleavedPlanes`] operands
//! (`[vec][word][plane]` — every plane of one 64-element C-chunk
//! adjacent): per C-word it loads the A-side and B-side plane words once
//! and accumulates `sign · (popcount << (ba + bb))` directly into a
//! `KR × LR` register block of `i64` accumulators. One pass over memory
//! total, no step buffer, and each loaded B word is reused across `LR`
//! columns (each A word across `KR` rows).
//!
//! Bit-identical to [`super::gemm_exact`] / the reference kernels by the
//! associativity of exact `i64` addition — property-tested here across
//! random shapes, precisions 2–8 and thread counts, plus the a8w8
//! worst-case accumulator tile.
//!
//! The scalar register-block kernel below is the always-on ground truth;
//! the public entry points dispatch to the SIMD paths in [`super::simd`]
//! when the host has one (override with `GAVINA_KERNEL`, or call the
//! `_with` variants to pin a path explicitly — that is how the property
//! tests here run the identical matrix once per available kernel).

use super::simd::{self, KernelKind};
use crate::arch::Precision;
use crate::quant::InterleavedPlanes;
use crate::util::parallel;

/// K-row height of the register block.
pub const KR: usize = 4;
/// L-column width of the register block (also the class-block width of
/// [`dense_affine`]).
pub const LR: usize = 4;

/// One significance step resolved to plane indices and its signed
/// shift-weight `sign(ba, bb) · 2^(ba+bb)`.
#[derive(Clone, Copy, Debug)]
pub(crate) struct PlaneStep {
    pub(crate) a_plane: usize,
    pub(crate) b_plane: usize,
    pub(crate) weight: i64,
}

/// Resolve the controller-order steps `include(t)` selects into plane
/// pairs + weights.
pub(crate) fn plane_steps(prec: Precision, include: impl Fn(usize) -> bool) -> Vec<PlaneStep> {
    prec.step_order()
        .enumerate()
        .filter(|&(t, _)| include(t))
        .map(|(_, (ba, bb))| PlaneStep {
            a_plane: ba as usize,
            b_plane: bb as usize,
            weight: prec.step_weight(ba, bb),
        })
        .collect()
}

/// Row-block worker: computes output rows `k0 ..` of the fused GEMM into
/// `out_block` (a `[rows, L]` row-major slice of the full `[K, L]`
/// output), restricted to the given significance steps, on the requested
/// kernel path.
fn fused_rows(
    kind: KernelKind,
    a: &InterleavedPlanes,
    b: &InterleavedPlanes,
    steps: &[PlaneStep],
    k0: usize,
    out_block: &mut [i64],
) {
    if kind == KernelKind::Scalar {
        fused_rows_scalar(a, b, steps, k0, out_block);
    } else {
        simd::fused_rows_shaped(kind, simd::block_shape(), a, b, steps, k0, out_block);
    }
}

/// The scalar `KR × LR` register-block row worker — the ground truth the
/// SIMD paths are pinned against.
fn fused_rows_scalar(
    a: &InterleavedPlanes,
    b: &InterleavedPlanes,
    steps: &[PlaneStep],
    k0: usize,
    out_block: &mut [i64],
) {
    let l_dim = a.n_vecs;
    if out_block.is_empty() || l_dim == 0 {
        return;
    }
    debug_assert_eq!(a.c_dim, b.c_dim);
    debug_assert_eq!(out_block.len() % l_dim, 0);
    let words = a.words;
    let (pa, pb) = (a.bits as usize, b.bits as usize);
    let rows = out_block.len() / l_dim;
    let mut kb = 0usize;
    while kb < rows {
        let krn = KR.min(rows - kb);
        let mut b_vecs: [&[u64]; KR] = [&[]; KR];
        for (kr, slot) in b_vecs.iter_mut().enumerate().take(krn) {
            *slot = b.vec_words(k0 + kb + kr);
        }
        let mut lb = 0usize;
        while lb < l_dim {
            let lrn = LR.min(l_dim - lb);
            let mut a_vecs: [&[u64]; LR] = [&[]; LR];
            for (lr, slot) in a_vecs.iter_mut().enumerate().take(lrn) {
                *slot = a.vec_words(lb + lr);
            }
            let mut acc = [[0i64; LR]; KR];
            for w in 0..words {
                let (wa, wb) = (w * pa, w * pb);
                for (bv, arow) in b_vecs.iter().zip(acc.iter_mut()).take(krn) {
                    let bw = &bv[wb..wb + pb];
                    for (av, av_acc) in a_vecs.iter().zip(arow.iter_mut()).take(lrn) {
                        let aw = &av[wa..wa + pa];
                        let mut s = 0i64;
                        for st in steps {
                            s += st.weight
                                * ((aw[st.a_plane] & bw[st.b_plane]).count_ones() as i64);
                        }
                        *av_acc += s;
                    }
                }
            }
            for (kr, arow) in acc.iter().enumerate().take(krn) {
                let orow = &mut out_block[(kb + kr) * l_dim + lb..(kb + kr) * l_dim + lb + lrn];
                orow.copy_from_slice(&arow[..lrn]);
            }
            lb += LR;
        }
        kb += KR;
    }
}

fn fused_gemm_steps(
    kind: KernelKind,
    a: &InterleavedPlanes,
    b: &InterleavedPlanes,
    steps: &[PlaneStep],
) -> Vec<i64> {
    assert_eq!(a.c_dim, b.c_dim, "reduction axis mismatch");
    let mut p = vec![0i64; b.n_vecs * a.n_vecs];
    if !steps.is_empty() {
        fused_rows(kind, a, b, steps, 0, &mut p);
    }
    p
}

fn assert_runnable(kind: KernelKind) {
    assert!(
        simd::is_available(kind),
        "kernel '{}' is not available on this host",
        kind.name()
    );
}

/// Full exact fused bit-serial GEMM `P[K, L] = B[K, C] · A[C, L]` over
/// interleaved planes — one pass over memory instead of
/// `a_bits × b_bits`, on the process-wide [`simd::active`] kernel path.
/// Must equal [`super::gemm_exact`] on the operands the planes encode.
pub fn fused_gemm(a: &InterleavedPlanes, b: &InterleavedPlanes) -> Vec<i64> {
    fused_gemm_with(simd::active(), a, b)
}

/// [`fused_gemm`] on an explicit kernel path — the per-kernel property
/// tests and the bench's scalar-vs-SIMD comparison. Panics if `kind` is
/// not available on this host.
pub fn fused_gemm_with(kind: KernelKind, a: &InterleavedPlanes, b: &InterleavedPlanes) -> Vec<i64> {
    assert_runnable(kind);
    let prec = Precision::new(a.bits, b.bits);
    fused_gemm_steps(kind, a, b, &plane_steps(prec, |_| true))
}

/// [`fused_gemm`] restricted to the controller-order steps where
/// `include[t]` is true — how the cycle simulator fuses the guarded
/// (non-GAV) steps of a tile while still materializing the undervolted
/// steps for error injection. The excluded steps contribute zero.
pub fn fused_gemm_masked(
    a: &InterleavedPlanes,
    b: &InterleavedPlanes,
    include: &[bool],
) -> Vec<i64> {
    fused_gemm_masked_with(simd::active(), a, b, include)
}

/// [`fused_gemm_masked`] on an explicit kernel path. The SIMD paths run
/// masked steps through the same include-mask lane tables as full GEMMs,
/// so the mask costs nothing extra.
pub fn fused_gemm_masked_with(
    kind: KernelKind,
    a: &InterleavedPlanes,
    b: &InterleavedPlanes,
    include: &[bool],
) -> Vec<i64> {
    assert_runnable(kind);
    let prec = Precision::new(a.bits, b.bits);
    assert_eq!(include.len(), prec.steps(), "step mask vs precision");
    fused_gemm_steps(kind, a, b, &plane_steps(prec, |t| include[t]))
}

/// [`fused_gemm`] tiled across K-row blocks on up to `threads` scoped
/// workers (the same row-block scheme as
/// [`super::bitserial_gemm_ref_mt`]). Bit-exact with the serial kernel:
/// every output row runs the identical row worker.
pub fn fused_gemm_mt(a: &InterleavedPlanes, b: &InterleavedPlanes, threads: usize) -> Vec<i64> {
    fused_gemm_mt_with(simd::active(), a, b, threads)
}

/// [`fused_gemm_mt`] on an explicit kernel path.
pub fn fused_gemm_mt_with(
    kind: KernelKind,
    a: &InterleavedPlanes,
    b: &InterleavedPlanes,
    threads: usize,
) -> Vec<i64> {
    assert_runnable(kind);
    assert_eq!(a.c_dim, b.c_dim, "reduction axis mismatch");
    let prec = Precision::new(a.bits, b.bits);
    let l_dim = a.n_vecs;
    let mut p = vec![0i64; b.n_vecs * l_dim];
    if p.is_empty() {
        return p;
    }
    let steps = plane_steps(prec, |_| true);
    parallel::parallel_spans_mut(&mut p, l_dim, threads, |start, block| {
        fused_rows(kind, a, b, &steps, start / l_dim, block);
    });
    p
}

/// Register-blocked dense affine `out[n, classes] = x[n, cin] · w[cin,
/// classes] + bias` — the float classifier head on the same micro-kernel
/// blocking: one pass over each input row per class block instead of one
/// pass per class, on the process-wide [`simd::active`] kernel path.
/// Each output is still accumulated in ascending-`ci` order starting
/// from its bias, so the result is bit-identical to the scalar triple
/// loop (f32 addition order per output is unchanged; only independent
/// outputs are batched — and the SIMD block uses separate multiply and
/// add, never an FMA, to keep the per-term rounding identical too).
pub fn dense_affine(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    n: usize,
    cin: usize,
    classes: usize,
) -> Vec<f32> {
    dense_affine_with(simd::active(), x, w, bias, n, cin, classes)
}

/// [`dense_affine`] on an explicit kernel path. Panics if `kind` is not
/// available on this host.
pub fn dense_affine_with(
    kind: KernelKind,
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    n: usize,
    cin: usize,
    classes: usize,
) -> Vec<f32> {
    assert_runnable(kind);
    assert_eq!(x.len(), n * cin);
    assert_eq!(w.len(), cin * classes);
    assert_eq!(bias.len(), classes);
    let mut out = vec![0.0f32; n * classes];
    if classes == 0 {
        return out;
    }
    let vw = kind.f32_lanes();
    for ni in 0..n {
        let xrow = &x[ni * cin..(ni + 1) * cin];
        let orow = &mut out[ni * classes..(ni + 1) * classes];
        let mut k0 = 0usize;
        // Full vector-width class blocks on the SIMD path …
        while vw > 0 && k0 + vw <= classes {
            // SAFETY: the class block [k0, k0 + vw) is in bounds for
            // every w row, for bias and for orow (k0 + vw ≤ classes);
            // `kind` availability was asserted above.
            unsafe {
                simd::affine_cols(
                    kind,
                    xrow.as_ptr(),
                    w.as_ptr().add(k0),
                    classes,
                    cin,
                    bias.as_ptr().add(k0),
                    orow.as_mut_ptr().add(k0),
                );
            }
            k0 += vw;
        }
        // … and the scalar `LR`-wide register block for the remainder
        // (the whole row when `kind` is scalar).
        while k0 < classes {
            let kn = LR.min(classes - k0);
            let mut acc = [0.0f32; LR];
            acc[..kn].copy_from_slice(&bias[k0..k0 + kn]);
            for (ci, &xv) in xrow.iter().enumerate() {
                let wrow = &w[ci * classes + k0..ci * classes + k0 + kn];
                for (a, &wv) in acc.iter_mut().zip(wrow) {
                    *a += xv * wv;
                }
            }
            orow[k0..k0 + kn].copy_from_slice(&acc[..kn]);
            k0 += LR;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::{bitserial_gemm_ref, bitserial_gemm_ref_mt, gemm_exact, ipe_sequence};
    use crate::quant::PackedPlanes;
    use crate::util::proptest::check;
    use crate::util::Prng;

    fn rand_mat(rng: &mut Prng, n: usize, bits: u8) -> Vec<i32> {
        let hi = (1i64 << (bits - 1)) - 1;
        (0..n).map(|_| rng.int_in(-hi - 1, hi) as i32).collect()
    }

    fn operands(
        a: &[i32],
        b: &[i32],
        c: usize,
        l: usize,
        k: usize,
        a_bits: u8,
        b_bits: u8,
    ) -> (
        PackedPlanes,
        PackedPlanes,
        InterleavedPlanes,
        InterleavedPlanes,
    ) {
        let pa = PackedPlanes::from_a_matrix(a, c, l, a_bits);
        let pb = PackedPlanes::from_b_matrix(b, k, c, b_bits);
        let ia = InterleavedPlanes::from_packed(&pa);
        let ib = InterleavedPlanes::from_packed(&pb);
        (pa, pb, ia, ib)
    }

    #[test]
    fn fused_matches_reference_across_shape_matrix() {
        // The satellite matrix, run once per available kernel path:
        // boundary shapes (c = 1, 64, 65, 130 — word boundaries and a
        // partial final word; l = 1 — a partial register block
        // everywhere), asymmetric precisions including 3/5/7 bits (not
        // divisible by any vector lane count, so every SIMD path
        // exercises dead lanes), and serial + MT at 1/2/64 threads.
        let shapes = [
            (1usize, 1usize, 1usize),
            (64, 1, 5),
            (65, 4, 7),
            (64, 5, 4),
            (130, 9, 3),
        ];
        let precs = [(2u8, 5u8), (5, 2), (3, 8), (8, 3), (7, 3), (4, 7)];
        let kinds = simd::available();
        let mut rng = Prng::new(0xF0);
        for &(c, l, k) in &shapes {
            for &(a_bits, b_bits) in &precs {
                let a = rand_mat(&mut rng, c * l, a_bits);
                let b = rand_mat(&mut rng, k * c, b_bits);
                let (pa, pb, ia, ib) = operands(&a, &b, c, l, k, a_bits, b_bits);
                let exact = gemm_exact(&a, &b, c, l, k);
                assert_eq!(bitserial_gemm_ref(&pa, &pb), exact, "ref a{a_bits}w{b_bits} c={c}");
                for &kind in &kinds {
                    assert_eq!(
                        fused_gemm_with(kind, &ia, &ib),
                        exact,
                        "fused[{kind}] a{a_bits}w{b_bits} c={c} l={l} k={k}"
                    );
                    for threads in [1usize, 2, 64] {
                        assert_eq!(
                            fused_gemm_mt_with(kind, &ia, &ib, threads),
                            exact,
                            "fused[{kind}] mt={threads} a{a_bits}w{b_bits} c={c} l={l} k={k}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fused_matches_reference_random() {
        check("fused == reference == exact GEMM", 50, |rng| {
            let a_bits = rng.int_in(2, 8) as u8;
            let b_bits = rng.int_in(2, 8) as u8;
            let c = rng.int_in(1, 200) as usize;
            let l = rng.int_in(1, 11) as usize;
            let k = rng.int_in(1, 19) as usize;
            let a = rand_mat(rng, c * l, a_bits);
            let b = rand_mat(rng, k * c, b_bits);
            let (pa, pb, ia, ib) = operands(&a, &b, c, l, k, a_bits, b_bits);
            let exact = gemm_exact(&a, &b, c, l, k);
            let fused = fused_gemm(&ia, &ib);
            assert_eq!(fused, exact, "a{a_bits}w{b_bits} c={c} l={l} k={k}");
            assert_eq!(fused, bitserial_gemm_ref(&pa, &pb));
            let threads = rng.int_in(1, 8) as usize;
            assert_eq!(fused, fused_gemm_mt(&ia, &ib, threads), "threads={threads}");
            assert_eq!(fused, bitserial_gemm_ref_mt(&pa, &pb, threads));
            for kind in simd::available() {
                assert_eq!(
                    fused_gemm_with(kind, &ia, &ib),
                    exact,
                    "kind={kind} a{a_bits}w{b_bits} c={c} l={l} k={k}"
                );
            }
        });
    }

    #[test]
    fn masked_fusion_matches_masked_recombine() {
        // fused_gemm_masked over a random step subset must equal summing
        // exactly those steps of the iPE sequence with their weights —
        // the identity the simulator's guarded-step fusion rests on.
        check("masked fused == masked recombine", 30, |rng| {
            let a_bits = rng.int_in(2, 6) as u8;
            let b_bits = rng.int_in(2, 6) as u8;
            let prec = Precision::new(a_bits, b_bits);
            let c = rng.int_in(1, 120) as usize;
            let l = rng.int_in(1, 6) as usize;
            let k = rng.int_in(1, 9) as usize;
            let a = rand_mat(rng, c * l, a_bits);
            let b = rand_mat(rng, k * c, b_bits);
            let (pa, pb, ia, ib) = operands(&a, &b, c, l, k, a_bits, b_bits);
            let include: Vec<bool> = (0..prec.steps()).map(|_| rng.chance(0.5)).collect();
            let masked = fused_gemm_masked(&ia, &ib, &include);
            // Every kernel path must agree on the masked product too (the
            // SIMD paths fold the mask into their include-lane tables).
            for kind in simd::available() {
                assert_eq!(
                    fused_gemm_masked_with(kind, &ia, &ib, &include),
                    masked,
                    "kind={kind} a{a_bits}w{b_bits}"
                );
            }
            let seq = ipe_sequence(&pa, &pb);
            let mut want = vec![0i64; k * l];
            for (t, (ba, bb)) in prec.step_order().enumerate() {
                if !include[t] {
                    continue;
                }
                let w = prec.step_weight(ba, bb);
                for (pi, &s) in want.iter_mut().zip(&seq[t]) {
                    *pi += w * s as i64;
                }
            }
            assert_eq!(masked, want, "a{a_bits}w{b_bits} include={include:?}");
            // The two mask halves must sum to the full product.
            let excl: Vec<bool> = include.iter().map(|&x| !x).collect();
            let other = fused_gemm_masked(&ia, &ib, &excl);
            let full = fused_gemm(&ia, &ib);
            let sum: Vec<i64> = masked.iter().zip(&other).map(|(x, y)| x + y).collect();
            assert_eq!(sum, full);
        });
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy fixed-shape tile; covered by the property matrix")]
    fn paper_tile_shape_worst_case_accumulators_a8w8() {
        // The paper's full hardware tile at a8w8 with every operand at
        // the most negative code (-128): the widest partial products the
        // fused i64 register accumulators must carry, all same-signed so
        // nothing cancels early. Run on every kernel path — this is the
        // accumulator-width worst case for the SIMD lane sums too.
        let (c, l, k) = (576, 8, 16);
        let a = vec![-128i32; c * l];
        let b = vec![-128i32; k * c];
        let (_, _, ia, ib) = operands(&a, &b, c, l, k, 8, 8);
        for kind in simd::available() {
            let fused = fused_gemm_with(kind, &ia, &ib);
            // (-128 · -128) summed over C = 16384 · 576 per output.
            assert!(fused.iter().all(|&v| v == 16384 * 576), "kind={kind}");
            assert_eq!(fused, gemm_exact(&a, &b, c, l, k), "kind={kind}");
        }
        // And a random a8w8 tile for good measure (the
        // `paper_tile_shape_exactness` analogue for the fused kernel).
        let mut rng = Prng::new(31);
        let a = rand_mat(&mut rng, c * l, 8);
        let b = rand_mat(&mut rng, k * c, 8);
        let (_, _, ia, ib) = operands(&a, &b, c, l, k, 8, 8);
        let exact = gemm_exact(&a, &b, c, l, k);
        for kind in simd::available() {
            assert_eq!(fused_gemm_with(kind, &ia, &ib), exact, "kind={kind}");
            assert_eq!(fused_gemm_mt_with(kind, &ia, &ib, 4), exact, "kind={kind}");
        }
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        let ia = InterleavedPlanes::zeroed(2, 0, 4);
        let ib = InterleavedPlanes::zeroed(2, 3, 4);
        assert!(fused_gemm(&ia, &ib).is_empty());
        assert!(fused_gemm_mt(&ia, &ib, 4).is_empty());
        let ia = InterleavedPlanes::zeroed(2, 2, 4);
        let ib = InterleavedPlanes::zeroed(2, 0, 4);
        assert!(fused_gemm(&ia, &ib).is_empty());
        // All-excluded mask: a zero product of the right shape.
        let ia = InterleavedPlanes::from_a_matrix(&[1, -1, 1, -1], 2, 2, 2);
        let ib = InterleavedPlanes::from_b_matrix(&[1, 1, -1, 1, 0, 1], 3, 2, 2);
        assert_eq!(fused_gemm_masked(&ia, &ib, &[false; 4]), vec![0i64; 6]);
    }

    #[test]
    fn dense_affine_matches_scalar_loop_bitwise() {
        check("dense_affine == scalar fc loop", 40, |rng| {
            let n = rng.int_in(1, 5) as usize;
            let cin = rng.int_in(1, 40) as usize;
            let classes = rng.int_in(1, 13) as usize;
            let x: Vec<f32> = (0..n * cin).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
            let w: Vec<f32> = (0..cin * classes).map(|_| rng.next_f32() - 0.5).collect();
            let bias: Vec<f32> = (0..classes).map(|_| rng.next_f32() - 0.5).collect();
            let got = dense_affine(&x, &w, &bias, n, cin, classes);
            for ni in 0..n {
                for k in 0..classes {
                    let mut acc = bias[k];
                    for ci in 0..cin {
                        acc += x[ni * cin + ci] * w[ci * classes + k];
                    }
                    assert_eq!(
                        got[ni * classes + k].to_bits(),
                        acc.to_bits(),
                        "n={ni} k={k} cin={cin} classes={classes}"
                    );
                }
            }
            // Every kernel path must produce the identical f32 bits: the
            // SIMD column blocks use separate mul + add (no FMA) in the
            // same ascending-ci order.
            for kind in simd::available() {
                let via = dense_affine_with(kind, &x, &w, &bias, n, cin, classes);
                assert!(
                    got.iter().zip(&via).all(|(p, q)| p.to_bits() == q.to_bits()),
                    "kind={kind} n={n} cin={cin} classes={classes}"
                );
            }
        });
    }

    #[test]
    fn dense_affine_vector_width_boundaries() {
        // Class counts straddling the 4- and 8-wide SIMD column blocks
        // (and their remainders) all reduce to the same bits.
        let mut rng = Prng::new(0xAF1);
        let (n, cin) = (3usize, 17usize);
        let x: Vec<f32> = (0..n * cin).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        for classes in [1usize, 3, 4, 5, 7, 8, 9, 15, 16, 17] {
            let w: Vec<f32> = (0..cin * classes).map(|_| rng.next_f32() - 0.5).collect();
            let bias: Vec<f32> = (0..classes).map(|_| rng.next_f32() - 0.5).collect();
            let scalar = dense_affine_with(KernelKind::Scalar, &x, &w, &bias, n, cin, classes);
            for kind in simd::available() {
                let via = dense_affine_with(kind, &x, &w, &bias, n, cin, classes);
                assert!(
                    scalar.iter().zip(&via).all(|(p, q)| p.to_bits() == q.to_bits()),
                    "kind={kind} classes={classes}"
                );
            }
        }
    }
}
