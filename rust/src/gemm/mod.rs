//! The bit-serial GEMM compute path (paper Listing 1) and its bit-packed
//! hot-path implementation.
//!
//! One Parallel-Array cycle computes, for every iPE `(k, l)`:
//!
//! ```text
//! iPE[k, l] = popcount_c( A_plane[c, l] & B_plane[k, c] )   ∈ 0..=C
//! ```
//!
//! With the packed layout of [`crate::quant::PackedPlanes`] this is a
//! straight `u64` AND+`count_ones` loop — the L3 hot path that the
//! [`hotpath`](../../benches) bench profiles and that the whole evaluation
//! pipeline (error model, DNN executor) runs on.
//!
//! `recombine` implements the L0/L1 shift-accumulate with the
//! two's-complement sign rule; `bitserial_gemm_ref` composes the two.
//!
//! Since the compile-once data plane, operands arrive **pre-packed**: the
//! B-side planes come from a [`crate::dnn::LayerPlan`] (packed once at
//! `EngineBuilder::build()`), the A-side planes are packed once per layer
//! per request by the executor, and the cycle simulator carves hardware
//! tiles out of them with [`PackedPlanes::extract_tile`] instead of
//! re-packing dense tiles.
//!
//! Two exact compute paths coexist:
//!
//! * the **fused** micro-kernel ([`kernel`]) — the default:
//!   plane-interleaved operands, the whole `a_bits × b_bits` significance
//!   loop in one pass over memory, `i64` register-block accumulation.
//!   [`bitserial_gemm`]/[`bitserial_gemm_mt`] route here (re-laying
//!   plane-major operands once); the executor and `LayerPlan` feed it
//!   interleaved operands directly with no conversion at all.
//! * the **reference** step-sequence path ([`bitserial_gemm_ref`],
//!   [`ipe_sequence`] + [`recombine`]) — one pass per step, `u16` step
//!   buffers. It mirrors the hardware's per-cycle control flow, which is
//!   why the cycle simulator keeps it for undervolted steps (error
//!   injection consumes per-step iPE outputs), and it is the ground truth
//!   the fused kernel is property-tested against.
//!
//! Both equal the plain integer GEMM ([`gemm_exact`]) bit for bit — the
//! same identity `pytest` checks for the Pallas kernel.

pub mod kernel;
pub mod simd;

use crate::arch::Precision;
use crate::quant::{InterleavedPlanes, PackedPlanes};
use crate::util::parallel;

/// Plain integer GEMM reference: `P[K,L] = B[K,C] · A[C,L]` in i64.
pub fn gemm_exact(a: &[i32], b: &[i32], c_dim: usize, l_dim: usize, k_dim: usize) -> Vec<i64> {
    assert_eq!(a.len(), c_dim * l_dim);
    assert_eq!(b.len(), k_dim * c_dim);
    let mut p = vec![0i64; k_dim * l_dim];
    for k in 0..k_dim {
        for c in 0..c_dim {
            let bv = b[k * c_dim + c] as i64;
            if bv == 0 {
                continue;
            }
            let arow = &a[c * l_dim..(c + 1) * l_dim];
            let prow = &mut p[k * l_dim..(k + 1) * l_dim];
            for l in 0..l_dim {
                prow[l] += bv * arow[l] as i64;
            }
        }
    }
    p
}

/// Row-block worker shared by the serial and tiled kernels: computes
/// output rows `k0..k0 + out_block.len() / L` of one binary-plane GEMM
/// into `out_block` (a `[rows, L]` row-major slice of the full output).
#[inline]
fn binary_plane_gemm_rows(
    a: &PackedPlanes,
    a_plane: u8,
    b: &PackedPlanes,
    b_plane: u8,
    k0: usize,
    out_block: &mut [u16],
) {
    let l_dim = a.n_vecs;
    if l_dim == 0 || out_block.is_empty() {
        return;
    }
    debug_assert!(
        a.c_dim <= u16::MAX as usize,
        "iPE output (popcount over C={}) would truncate in u16",
        a.c_dim
    );
    // The whole A plane, sliced per column below — hoisted out of the K
    // loop, which used to re-derive the same `vec_words` slice (plane
    // base + bounds checks) for every output row.
    let apw = a.plane_words(a_plane);
    let words = a.words;
    for (dk, orow) in out_block.chunks_mut(l_dim).enumerate() {
        let bw = b.vec_words(b_plane, k0 + dk);
        for (l, o) in orow.iter_mut().enumerate() {
            let aw = &apw[l * words..(l + 1) * words];
            let mut acc = 0u32;
            for (x, y) in aw.iter().zip(bw) {
                acc += (x & y).count_ones();
            }
            *o = acc as u16;
        }
    }
}

/// One Parallel-Array cycle on packed planes: writes the `[K, L]`
/// (row-major) iPE outputs into `out`. Values are in `0..=C`.
#[inline]
pub fn binary_plane_gemm(
    a: &PackedPlanes,
    a_plane: u8,
    b: &PackedPlanes,
    b_plane: u8,
    out: &mut [u16],
) {
    debug_assert_eq!(a.c_dim, b.c_dim);
    debug_assert_eq!(out.len(), b.n_vecs * a.n_vecs);
    binary_plane_gemm_rows(a, a_plane, b, b_plane, 0, out);
}

/// [`binary_plane_gemm`] tiled across K-row blocks on up to `threads`
/// scoped workers. Bit-exact with the serial kernel by construction:
/// every output row runs the identical row worker, just on a different
/// thread.
pub fn binary_plane_gemm_mt(
    a: &PackedPlanes,
    a_plane: u8,
    b: &PackedPlanes,
    b_plane: u8,
    out: &mut [u16],
    threads: usize,
) {
    let l_dim = a.n_vecs;
    debug_assert_eq!(a.c_dim, b.c_dim);
    debug_assert_eq!(out.len(), b.n_vecs * l_dim);
    if out.is_empty() {
        return;
    }
    parallel::parallel_spans_mut(out, l_dim, threads, |start, block| {
        binary_plane_gemm_rows(a, a_plane, b, b_plane, start / l_dim, block);
    });
}

/// Stream the exact iPE output sequence step by step through
/// `f(t, step)` in controller order, reusing **one** step buffer — for
/// callers that consume each step immediately, instead of materializing
/// the full `a_bits × b_bits × K × L` sequence [`ipe_sequence`] returns.
pub fn for_each_ipe_step(a: &PackedPlanes, b: &PackedPlanes, mut f: impl FnMut(usize, &[u16])) {
    let prec = Precision::new(a.bits, b.bits);
    let mut step = vec![0u16; b.n_vecs * a.n_vecs];
    for (t, (ba, bb)) in prec.step_order().enumerate() {
        binary_plane_gemm(a, ba, b, bb, &mut step);
        f(t, &step);
    }
}

/// The exact iPE output sequence of one tile in controller order
/// (bb outer, ba inner): `seq[t][k·L + l]`, `t = bb·a_bits + ba`.
pub fn ipe_sequence(a: &PackedPlanes, b: &PackedPlanes) -> Vec<Vec<u16>> {
    let mut seq = Vec::with_capacity(Precision::new(a.bits, b.bits).steps());
    for_each_ipe_step(a, b, |_, step| seq.push(step.to_vec()));
    seq
}

/// L0/L1 shift-accumulate: recombine an iPE output sequence (possibly with
/// injected undervolting errors) into the `[K, L]` integer GEMM result.
pub fn recombine(seq: &[Vec<u16>], prec: Precision) -> Vec<i64> {
    assert_eq!(seq.len(), prec.steps());
    let n = seq[0].len();
    let mut p = vec![0i64; n];
    for (t, (ba, bb)) in prec.step_order().enumerate() {
        let w = prec.step_weight(ba, bb);
        let step = &seq[t];
        debug_assert_eq!(step.len(), n);
        for (pi, &s) in p.iter_mut().zip(step) {
            *pi += w * s as i64;
        }
    }
    p
}

/// Full exact bit-serial GEMM over packed planes; must equal
/// [`gemm_exact`] on the operands the planes encode.
///
/// Routed through the fused plane-interleaved micro-kernel
/// ([`kernel::fused_gemm`]): the operands are re-laid out once, then the
/// whole significance loop runs in one pass over memory. Call sites that
/// already hold [`InterleavedPlanes`] (the executor, `LayerPlan`) should
/// call the kernel directly and skip even that conversion.
pub fn bitserial_gemm(a: &PackedPlanes, b: &PackedPlanes) -> Vec<i64> {
    kernel::fused_gemm(
        &InterleavedPlanes::from_packed(a),
        &InterleavedPlanes::from_packed(b),
    )
}

/// [`bitserial_gemm`] tiled across K-row blocks on up to `threads` scoped
/// workers — the L3 hot path at serving scale. Each worker runs the full
/// fused kernel over its own rows of `B` and writes its own rows of `P`,
/// so there is no cross-thread reduction and the result is bit-exact with
/// the serial path (property-tested below).
pub fn bitserial_gemm_mt(a: &PackedPlanes, b: &PackedPlanes, threads: usize) -> Vec<i64> {
    kernel::fused_gemm_mt(
        &InterleavedPlanes::from_packed(a),
        &InterleavedPlanes::from_packed(b),
        threads,
    )
}

/// Reference bit-serial composition: one [`binary_plane_gemm`] pass per
/// `(ba, bb)` step (streamed through [`for_each_ipe_step`]'s single
/// reused buffer), shift-accumulated exactly like the L0/L1 hardware.
/// Kept as the ground truth the fused kernel is pinned against.
pub fn bitserial_gemm_ref(a: &PackedPlanes, b: &PackedPlanes) -> Vec<i64> {
    let prec = Precision::new(a.bits, b.bits);
    let wts: Vec<i64> = prec
        .step_order()
        .map(|(ba, bb)| prec.step_weight(ba, bb))
        .collect();
    let mut p = vec![0i64; b.n_vecs * a.n_vecs];
    for_each_ipe_step(a, b, |t, step| {
        let w = wts[t];
        for (pi, &s) in p.iter_mut().zip(step) {
            *pi += w * s as i64;
        }
    });
    p
}

/// [`bitserial_gemm_ref`] tiled across K-row blocks (the reference
/// multithreaded path; the fused [`bitserial_gemm_mt`] uses the same
/// row-block scheme).
pub fn bitserial_gemm_ref_mt(a: &PackedPlanes, b: &PackedPlanes, threads: usize) -> Vec<i64> {
    let prec = Precision::new(a.bits, b.bits);
    let l_dim = a.n_vecs;
    let mut p = vec![0i64; b.n_vecs * l_dim];
    if p.is_empty() {
        return p;
    }
    parallel::parallel_spans_mut(&mut p, l_dim, threads, |start, block| {
        let k0 = start / l_dim;
        let mut step = vec![0u16; block.len()];
        for (ba, bb) in prec.step_order() {
            binary_plane_gemm_rows(a, ba, b, bb, k0, &mut step);
            let w = prec.step_weight(ba, bb);
            for (pi, &s) in block.iter_mut().zip(&step) {
                *pi += w * s as i64;
            }
        }
    });
    p
}

/// Number of bit-MACs one tile executes (`L·C·K·a_bits·b_bits` AND ops) —
/// the unit the hot-path bench reports throughput in.
pub fn bit_macs(c_dim: usize, l_dim: usize, k_dim: usize, prec: Precision) -> u64 {
    (c_dim * l_dim * k_dim) as u64 * prec.steps() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::Prng;

    fn rand_mat(rng: &mut Prng, n: usize, bits: u8) -> Vec<i32> {
        let hi = (1i64 << (bits - 1)) - 1;
        (0..n).map(|_| rng.int_in(-hi - 1, hi) as i32).collect()
    }

    #[test]
    fn bitserial_equals_exact_gemm() {
        check("bitserial == exact GEMM", 60, |rng| {
            let a_bits = rng.int_in(2, 8) as u8;
            let b_bits = rng.int_in(2, 8) as u8;
            let c = rng.int_in(1, 130) as usize;
            let l = rng.int_in(1, 9) as usize;
            let k = rng.int_in(1, 17) as usize;
            let a = rand_mat(rng, c * l, a_bits);
            let b = rand_mat(rng, k * c, b_bits);
            let pa = PackedPlanes::from_a_matrix(&a, c, l, a_bits);
            let pb = PackedPlanes::from_b_matrix(&b, k, c, b_bits);
            let exact = gemm_exact(&a, &b, c, l, k);
            assert_eq!(
                bitserial_gemm(&pa, &pb),
                exact,
                "a{a_bits}w{b_bits} c={c} l={l} k={k}"
            );
            assert_eq!(
                bitserial_gemm_ref(&pa, &pb),
                exact,
                "ref a{a_bits}w{b_bits} c={c} l={l} k={k}"
            );
        });
    }

    #[test]
    fn sequence_recombines_to_exact() {
        check("ipe seq recombine == exact", 40, |rng| {
            let a_bits = rng.int_in(2, 6) as u8;
            let b_bits = rng.int_in(2, 6) as u8;
            let c = rng.int_in(1, 80) as usize;
            let l = rng.int_in(1, 5) as usize;
            let k = rng.int_in(1, 9) as usize;
            let a = rand_mat(rng, c * l, a_bits);
            let b = rand_mat(rng, k * c, b_bits);
            let pa = PackedPlanes::from_a_matrix(&a, c, l, a_bits);
            let pb = PackedPlanes::from_b_matrix(&b, k, c, b_bits);
            let seq = ipe_sequence(&pa, &pb);
            assert_eq!(
                recombine(&seq, Precision::new(a_bits, b_bits)),
                gemm_exact(&a, &b, c, l, k)
            );
        });
    }

    #[test]
    fn ipe_outputs_bounded_by_c() {
        check("iPE outputs in 0..=C", 30, |rng| {
            let c = rng.int_in(1, 200) as usize;
            let a = rand_mat(rng, c * 2, 3);
            let b = rand_mat(rng, 4 * c, 3);
            let pa = PackedPlanes::from_a_matrix(&a, c, 2, 3);
            let pb = PackedPlanes::from_b_matrix(&b, 4, c, 3);
            for step in ipe_sequence(&pa, &pb) {
                for &v in &step {
                    assert!((v as usize) <= c);
                }
            }
        });
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy fixed-shape tile; property tests cover the identity")]
    fn all_ones_saturates_popcount() {
        // A = all -1 (all bits set), B = all -1: every iPE output = C.
        let (c, l, k) = (576, 8, 16);
        let a = vec![-1i32; c * l];
        let b = vec![-1i32; k * c];
        let pa = PackedPlanes::from_a_matrix(&a, c, l, 2);
        let pb = PackedPlanes::from_b_matrix(&b, k, c, 2);
        let seq = ipe_sequence(&pa, &pb);
        for step in &seq {
            assert!(step.iter().all(|&v| v as usize == c));
        }
        // And the recombined GEMM is B·A = C (product of -1·-1 summed).
        let p = recombine(&seq, Precision::new(2, 2));
        assert!(p.iter().all(|&v| v == c as i64));
    }

    #[test]
    fn tiled_mt_kernels_bitexact_with_serial() {
        // The multithreaded row-block kernels must match the serial path
        // bit for bit on random packed matrices, for thread counts below,
        // at, and above the row count.
        check("MT GEMM == serial GEMM", 25, |rng| {
            let a_bits = rng.int_in(2, 8) as u8;
            let b_bits = rng.int_in(2, 8) as u8;
            let c = rng.int_in(1, 200) as usize;
            let l = rng.int_in(1, 9) as usize;
            let k = rng.int_in(1, 33) as usize;
            let a = rand_mat(rng, c * l, a_bits);
            let b = rand_mat(rng, k * c, b_bits);
            let pa = PackedPlanes::from_a_matrix(&a, c, l, a_bits);
            let pb = PackedPlanes::from_b_matrix(&b, k, c, b_bits);
            let serial = bitserial_gemm(&pa, &pb);
            assert_eq!(serial, bitserial_gemm_ref(&pa, &pb), "fused vs ref c={c} l={l} k={k}");
            for threads in [1usize, 2, 3, 64] {
                assert_eq!(
                    bitserial_gemm_mt(&pa, &pb, threads),
                    serial,
                    "bitserial_gemm_mt threads={threads} c={c} l={l} k={k}"
                );
                assert_eq!(
                    bitserial_gemm_ref_mt(&pa, &pb, threads),
                    serial,
                    "bitserial_gemm_ref_mt threads={threads} c={c} l={l} k={k}"
                );
            }
            let mut out_s = vec![0u16; k * l];
            let mut out_p = vec![0u16; k * l];
            binary_plane_gemm(&pa, 0, &pb, b_bits - 1, &mut out_s);
            binary_plane_gemm_mt(&pa, 0, &pb, b_bits - 1, &mut out_p, 4);
            assert_eq!(out_s, out_p, "binary_plane_gemm_mt c={c} l={l} k={k}");
        });
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy fixed-shape tile; property tests cover the identity")]
    fn mt_gemm_matches_exact_integer_gemm() {
        let mut rng = Prng::new(77);
        let (c, l, k) = (576, 8, 64);
        let a = rand_mat(&mut rng, c * l, 4);
        let b = rand_mat(&mut rng, k * c, 4);
        let pa = PackedPlanes::from_a_matrix(&a, c, l, 4);
        let pb = PackedPlanes::from_b_matrix(&b, k, c, 4);
        assert_eq!(bitserial_gemm_mt(&pa, &pb, 4), gemm_exact(&a, &b, c, l, k));
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy fixed-shape tile; property tests cover the identity")]
    fn paper_tile_shape_exactness() {
        // The paper's full hardware tile at a8w8 — the widest case the
        // accumulators must carry.
        let mut rng = Prng::new(31);
        let (c, l, k) = (576, 8, 16);
        let a = rand_mat(&mut rng, c * l, 8);
        let b = rand_mat(&mut rng, k * c, 8);
        let pa = PackedPlanes::from_a_matrix(&a, c, l, 8);
        let pb = PackedPlanes::from_b_matrix(&b, k, c, 8);
        assert_eq!(bitserial_gemm(&pa, &pb), gemm_exact(&a, &b, c, l, k));
    }
}
